"""AOT lowering: JAX/Pallas -> HLO text artifacts + manifest.

Interchange format is HLO *text*, not a serialized HloModuleProto: jax
>= 0.5 emits protos with 64-bit instruction ids that the xla crate's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Outputs, per shape config x variant:
    artifacts/som_step_<shape>_<kind>_<map>.hlo.txt
    artifacts/umatrix_<shape>.hlo.txt
    artifacts/manifest.json   — shapes + input/output order for rust

Python runs only here; the rust binary is self-contained once artifacts
exist (`make artifacts` is a no-op while inputs are unchanged).
"""

import argparse
import functools
import json
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import configs, model


def to_hlo_text(lowered):
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True so the
    rust side unwraps one tuple)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_som_step(shape_cfg, kind, map_type):
    s, d, n = shape_cfg["s"], shape_cfg["d"], shape_cfg["n"]
    bs, bn = shape_cfg["block_s"], shape_cfg["block_n"]

    fn = functools.partial(
        model.som_epoch_step, kind=kind, map_type=map_type,
        block_s=bs, block_n=bn, interpret=True)

    spec = jax.ShapeDtypeStruct
    args = (
        spec((s, d), jnp.float32),    # data
        spec((s,), jnp.float32),      # data_mask
        spec((n, d), jnp.float32),    # codebook
        spec((n, 2), jnp.float32),    # coords
        spec((n,), jnp.float32),      # node_valid
        spec((2,), jnp.float32),      # span
        spec((), jnp.float32),        # radius
        spec((), jnp.float32),        # scale
    )
    lowered = jax.jit(lambda *a: tuple(fn(*a))).lower(*args)
    return to_hlo_text(lowered)


def lower_bmu(shape_cfg, variant):
    """BMU-only artifact for the hybrid kernel (paper §3.1: the GPU does
    the distance search, OpenMP threads do the weight update). `variant`
    selects the Gram-trick kernel or the naive direct formulation (the
    paper's rejected design, kept for the ablation bench)."""
    from compile.kernels import distance

    s, d, n = shape_cfg["s"], shape_cfg["d"], shape_cfg["n"]
    bs, bn = shape_cfg["block_s"], shape_cfg["block_n"]
    fn = distance.bmu_pallas if variant == "gram" else distance.bmu_pallas_direct

    spec = jax.ShapeDtypeStruct
    args = (
        spec((s, d), jnp.float32),    # data
        spec((n, d), jnp.float32),    # codebook
        spec((n,), jnp.float32),      # node_valid
    )
    lowered = jax.jit(
        lambda data, cb, valid: tuple(
            fn(data, cb, valid, block_s=bs, block_n=bn, interpret=True))
    ).lower(*args)
    return to_hlo_text(lowered)


def lower_umatrix(um_cfg):
    n, k, d = um_cfg["n"], um_cfg["k"], um_cfg["d"]
    spec = jax.ShapeDtypeStruct
    args = (
        spec((n, d), jnp.float32),    # codebook
        spec((n, k), jnp.int32),      # neighbor_idx
        spec((n, k), jnp.float32),    # neighbor_mask
        spec((n,), jnp.float32),      # node_valid
    )
    lowered = jax.jit(lambda *a: (model.umatrix_step(*a),)).lower(*args)
    return to_hlo_text(lowered)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default=None,
                    help="artifact directory (default: ../artifacts)")
    ap.add_argument("--out", default=None,
                    help="compat: path of a marker artifact; its parent "
                         "directory becomes --out-dir")
    ap.add_argument("--only", default=None,
                    help="comma-separated shape config names to build")
    args = ap.parse_args()

    out_dir = args.out_dir
    if out_dir is None and args.out is not None:
        out_dir = os.path.dirname(args.out) or "."
    if out_dir is None:
        out_dir = os.path.join(os.path.dirname(__file__), "..", "..",
                               "artifacts")
    os.makedirs(out_dir, exist_ok=True)

    only = set(args.only.split(",")) if args.only else None
    manifest = {"som_step": [], "umatrix": [], "bmu": []}

    for name, cfg in configs.SHAPE_CONFIGS.items():
        if only and name not in only:
            continue
        for kind, map_type in configs.VARIANTS:
            art = configs.artifact_name(name, kind, map_type)
            path = os.path.join(out_dir, art + ".hlo.txt")
            text = lower_som_step(cfg, kind, map_type)
            with open(path, "w") as f:
                f.write(text)
            manifest["som_step"].append({
                "name": art,
                "file": art + ".hlo.txt",
                "shape": name,
                "kind": kind,
                "map_type": map_type,
                "s": cfg["s"], "d": cfg["d"], "n": cfg["n"],
                "block_s": cfg["block_s"], "block_n": cfg["block_n"],
                # input order for the rust runtime:
                "inputs": ["data", "data_mask", "codebook", "coords",
                           "node_valid", "span", "radius", "scale"],
                "outputs": ["bmus", "num", "den", "qe_sum"],
            })
            print(f"lowered {art}: {len(text)} chars", file=sys.stderr)

    for name, cfg in configs.SHAPE_CONFIGS.items():
        if only and name not in only:
            continue
        for variant in ("gram", "direct"):
            art = f"som_bmu_{name}_{variant}"
            path = os.path.join(out_dir, art + ".hlo.txt")
            text = lower_bmu(cfg, variant)
            with open(path, "w") as f:
                f.write(text)
            manifest["bmu"].append({
                "name": art,
                "file": art + ".hlo.txt",
                "shape": name,
                "variant": variant,
                "s": cfg["s"], "d": cfg["d"], "n": cfg["n"],
                "block_s": cfg["block_s"], "block_n": cfg["block_n"],
                "inputs": ["data", "codebook", "node_valid"],
                "outputs": ["best_sq", "bmus"],
            })
            print(f"lowered {art}: {len(text)} chars", file=sys.stderr)

    for name, cfg in configs.UMATRIX_CONFIGS.items():
        if only and name not in only:
            continue
        art = configs.umatrix_name(name)
        path = os.path.join(out_dir, art + ".hlo.txt")
        text = lower_umatrix(cfg)
        with open(path, "w") as f:
            f.write(text)
        manifest["umatrix"].append({
            "name": art,
            "file": art + ".hlo.txt",
            "shape": name,
            "n": cfg["n"], "k": cfg["k"], "d": cfg["d"],
            "inputs": ["codebook", "neighbor_idx", "neighbor_mask",
                       "node_valid"],
            "outputs": ["umatrix"],
        })
        print(f"lowered {art}: {len(text)} chars", file=sys.stderr)

    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    # marker for make's dependency tracking
    if args.out is not None:
        with open(args.out, "w") as f:
            f.write("ok\n")
    print(f"wrote manifest with {len(manifest['som_step'])} som_step and "
          f"{len(manifest['umatrix'])} umatrix artifacts to {out_dir}",
          file=sys.stderr)


if __name__ == "__main__":
    main()
