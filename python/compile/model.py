"""L2: the batch-SOM epoch step as a JAX computation calling the L1 kernels.

`som_epoch_step` is the unit the rust coordinator executes per shard per
epoch through PJRT (the paper's `trainOneEpoch` inner body, minus the MPI
allreduce which lives in rust):

    1. BMU search            — Pallas kernel (distance.py), fused argmin.
    2. neighborhood weights  — grid distances from node *coordinates*
                               (planar or toroid wrap) + gaussian/bubble
                               window (plain jnp; memory-bound, no MXU win).
    3. accumulators          — Pallas kernel (update.py): num = H^T X,
                               den = H^T 1.
    4. qe_sum                — sum of winning Euclidean distances (for the
                               quantization-error curve the driver logs).

Geometry: square and hexagonal grids are both expressed as 2-D node
coordinates `coords [N, 2]` computed once by the rust side (hex rows get
the usual 0.5 column offset and sqrt(3)/2 row pitch), so one artifact
serves both grid types. Toroid maps additionally wrap distances with the
`span [2]` input (map extent per axis). The neighborhood *kind*
(gaussian / gaussian-compact / bubble) and map type (planar / toroid)
change the HLO graph, so they are separate artifact variants (configs.py).
A coordinate pair instead of an N x N grid-distance matrix is what keeps
emergent maps (the paper's 200 x 200 benchmark) feasible: the paper makes
the same point about codebook storage being the binding constraint.

Padding: `data_mask [S]` zeroes padded rows, `node_valid [N]` keeps padded
nodes from winning the argmin. Radius/scale are runtime scalars, so one
artifact serves every cooling schedule.
"""

import jax.numpy as jnp

from compile.kernels import distance, update

NEIGHBORHOOD_KINDS = ("gaussian", "gaussian_compact", "bubble")
MAP_TYPES = ("planar", "toroid")


def grid_distances(bmus, coords, span, *, map_type):
    """Grid distance from each sample's BMU to every node: [S, N].

    coords [N, 2] node grid coordinates; span [2] map extent per axis,
    used only for toroid wrap-around (min(|d|, span - |d|) per axis).
    """
    bmu_xy = coords[bmus]                                # [S, 2]
    d = jnp.abs(coords[None, :, :] - bmu_xy[:, None, :])  # [S, N, 2]
    if map_type == "toroid":
        d = jnp.minimum(d, span[None, None, :] - d)
    elif map_type == "planar":
        # Keep `span` in the planar graph too (0-weight use), so every
        # artifact variant has the same 8-input signature — otherwise
        # lowering drops the unused parameter and the rust runtime would
        # need per-variant argument lists.
        d = d + 0.0 * span[None, None, :]
    else:
        raise ValueError(f"unknown map type {map_type!r}")
    return jnp.sqrt(jnp.sum(d * d, axis=-1))


def neighborhood(grid_dist, radius, *, kind):
    """H = h(grid_dist; radius) per Eq. 5 of the paper."""
    r = jnp.maximum(radius, 1e-6)
    if kind == "gaussian":
        return jnp.exp(-(grid_dist * grid_dist) / (2.0 * r * r))
    if kind == "gaussian_compact":
        h = jnp.exp(-(grid_dist * grid_dist) / (2.0 * r * r))
        return jnp.where(grid_dist <= r, h, 0.0)
    if kind == "bubble":
        return jnp.where(grid_dist <= radius, 1.0, 0.0)
    raise ValueError(f"unknown neighborhood kind {kind!r}")


def som_epoch_step(data, data_mask, codebook, coords, node_valid, span,
                   radius, scale, *, kind="gaussian", map_type="planar",
                   block_s=distance.DEFAULT_BS, block_n=distance.DEFAULT_BN,
                   interpret=True):
    """One shard-level batch-SOM accumulation pass.

    data       [S, D] f32   shard rows (padded rows arbitrary)
    data_mask  [S]    f32   1.0 real row, 0.0 padding
    codebook   [N, D] f32   current global codebook (padded nodes = 0)
    coords     [N, 2] f32   node grid coordinates
    node_valid [N]    f32   1.0 real node, 0.0 padding
    span       [2]    f32   map extent per axis (toroid wrap)
    radius     []     f32   current neighborhood radius (grid units)
    scale      []     f32   current learning-rate factor

    Returns (bmus [S] i32, num [N, D] f32, den [N] f32, qe_sum [] f32).
    """
    best_sq, bmus = distance.bmu_pallas(
        data, codebook, node_valid,
        block_s=block_s, block_n=block_n, interpret=interpret)

    qe_sum = jnp.sum(jnp.sqrt(jnp.maximum(best_sq, 0.0)) * data_mask)

    gd = grid_distances(bmus, coords, span, map_type=map_type)
    h = neighborhood(gd, radius, kind=kind)
    h = h * scale * data_mask[:, None]

    num, den = update.accumulate_pallas(
        h, data, block_s=block_s, block_n=block_n, interpret=interpret)

    return bmus, num, den, qe_sum


def umatrix_step(codebook, neighbor_idx, neighbor_mask, node_valid):
    """U-matrix heights (Eq. 7) as an AOT-able graph.

    neighbor_idx  [N, K] i32  indices of up-to-K grid neighbors per node
                              (K = 8 square / 6 hex; padded entries point
                              anywhere and are masked off)
    neighbor_mask [N, K] f32  1.0 for a real neighbor edge

    U(j) = mean over real neighbors of ||w_i - w_j||.
    """
    gathered = codebook[neighbor_idx]                    # [N, K, D]
    diff = gathered - codebook[:, None, :]
    dist = jnp.sqrt(jnp.maximum(jnp.sum(diff * diff, axis=-1), 0.0))
    cnt = jnp.maximum(jnp.sum(neighbor_mask, axis=1), 1.0)
    u = jnp.sum(dist * neighbor_mask, axis=1) / cnt
    return u * node_valid
