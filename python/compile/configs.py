"""Artifact shape configurations for AOT lowering.

HLO is shape-static: each named config freezes (S, D, N) = (shard rows,
feature dims, codebook nodes) plus the Pallas block sizes. For every shape
config, aot.py lowers one artifact per (neighborhood kind x map type)
variant; the rust runtime picks the smallest config whose padded capacity
fits the job (see rust/src/runtime/).

S and N must be multiples of the block sizes (the rust side pads rows and
nodes and passes validity masks). D is free (the kernels keep the feature
axis whole per block) but the rust side pads D with zeros to match, which
is distance- and update-neutral.

Keep this list small: every entry costs lowering time at `make artifacts`
and the interpret-mode runtime scales with S*N*D.
"""

from compile.model import MAP_TYPES, NEIGHBORHOOD_KINDS

# name -> dict(s, d, n, block_s, block_n)
SHAPE_CONFIGS = {
    # tiny: integration tests and the quickstart example (toy data).
    "tiny": dict(s=256, d=16, n=256, block_s=64, block_n=64),
    # small: 20x20-ish maps, low-dim data (rgb example pads D 3 -> 16).
    "small": dict(s=512, d=16, n=512, block_s=128, block_n=128),
    # mid: 20x20..25x25 maps (<= 640 nodes), mid-dim dense data — the
    # examples/bench geometry; added in the §Perf pass because routing a
    # 400-node map to the 2560-node artifact wasted 6.4x padded FLOPs.
    "mid": dict(s=1024, d=256, n=640, block_s=128, block_n=128),
    # medium: 50x50 map (2500 -> 2560 nodes), mid-dim dense data.
    "medium": dict(s=1024, d=256, n=2560, block_s=128, block_n=128),
    # bench: the paper's Fig. 5 dense configuration, D = 1000, 50x50 map.
    "bench": dict(s=1024, d=1000, n=2560, block_s=128, block_n=128),
    # emergent: scaled-down stand-in for the paper's 200x200 emergent map
    # (64x64 = 4096 nodes; full 200x200 is infeasible under interpret mode
    # — see DESIGN.md §3 substitutions).
    "emergent": dict(s=512, d=256, n=4096, block_s=128, block_n=128),
}

# Variants lowered for every shape config. gaussian/planar is the default
# training path; the rest cover the paper's -n/-m/-p CLI options.
VARIANTS = [(kind, map_type)
            for kind in NEIGHBORHOOD_KINDS
            for map_type in MAP_TYPES]

# U-matrix artifact configs: (n, k, d) — nodes, max neighbors, dims.
UMATRIX_CONFIGS = {
    "tiny": dict(n=256, k=8, d=16),
    "small": dict(n=512, k=8, d=16),
    "mid": dict(n=640, k=8, d=256),
    "medium": dict(n=2560, k=8, d=256),
    "bench": dict(n=2560, k=8, d=1000),
    "emergent": dict(n=4096, k=8, d=256),
}


def artifact_name(shape_name, kind, map_type):
    return f"som_step_{shape_name}_{kind}_{map_type}"


def umatrix_name(shape_name):
    return f"umatrix_{shape_name}"
