"""Generate golden-reference fixtures for the Rust test suite.

Runs the pure-jnp oracle (kernels/ref.py) for a small, fully pinned
training configuration and writes text fixtures that
rust/tests/golden_reference.rs replays: the input data, the initial
codebook, and the expected per-epoch QE trajectory, final codebook and
final-epoch BMUs.

The configuration mirrors the Rust side exactly:

  * map: 6x6, square grid, planar topology (coords (x, y) = (col, row))
  * neighborhood: gaussian, no compact support
  * radius: linear 3.0 -> 1.0 over 3 epochs  => [3.0, 2.0, 1.0]
  * scale:  linear 1.0 -> 0.01 over 3 epochs => [1.0, 0.505, 0.01]
  * batch update: w_n = num_n / den_n where den_n > eps, else unchanged
  * QE(epoch) = mean Euclidean distance to the BMU *before* that epoch's
    update (somoclu convention, matching coordinator/train.rs)

As a self-check, the script also simulates the Rust dense kernel's
Gram-trick BMU formulation (||w||^2/2 - x.w) in float32 and insists it
picks identical BMUs every epoch — if a near-tie makes the two distance
formulations disagree, the data seed is rejected and the next one tried,
so the checked-in fixture is robustly away from argmin ties.

Usage: python3 python/compile/gen_golden.py
Rewrites rust/tests/fixtures/golden_* in place; rerun only when the
training semantics intentionally change, and commit the result.
"""

import importlib.util
import json
import pathlib

import numpy as np

HERE = pathlib.Path(__file__).resolve().parent
REPO = HERE.parent.parent
FIXTURES = REPO / "rust" / "tests" / "fixtures"

_spec = importlib.util.spec_from_file_location("ref", HERE / "kernels" / "ref.py")
ref = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(ref)

import jax.numpy as jnp  # noqa: E402  (after ref import to keep one jax init)

# --- pinned configuration (mirrored in golden_reference.rs) ------------
MAP_ROWS, MAP_COLS = 6, 6
DIM = 5
DATA_ROWS = 64
BLOBS = 3
EPOCHS = 3
RADIUS0, RADIUS_N = np.float32(3.0), np.float32(1.0)
SCALE0, SCALE_N = np.float32(1.0), np.float32(0.01)
SPREAD = np.float32(0.15)


def schedule(start, end, epoch, n_epochs):
    """Rust som::cooling Schedule::at, linear branch, in float32."""
    t = np.float32(epoch) / np.float32(n_epochs - 1)
    return np.float32(start + (end - start) * t)


def square_planar_coords():
    coords = np.zeros((MAP_ROWS * MAP_COLS, 2), dtype=np.float32)
    for r in range(MAP_ROWS):
        for c in range(MAP_COLS):
            coords[r * MAP_COLS + c] = (c, r)  # (x, y), rust Grid::new
    return coords


def gen_case(seed):
    rng = np.random.default_rng(seed)
    centers = rng.uniform(-2.0, 2.0, size=(BLOBS, DIM)).astype(np.float32)
    data = np.empty((DATA_ROWS, DIM), dtype=np.float32)
    for i in range(DATA_ROWS):
        c = i % BLOBS
        data[i] = centers[c] + SPREAD * rng.standard_normal(DIM).astype(np.float32)
    init_cb = rng.uniform(-1.0, 1.0, size=(MAP_ROWS * MAP_COLS, DIM)).astype(
        np.float32
    )
    return data, init_cb


def rust_like_bmus(data, cb):
    """The dense CPU kernel's Gram-trick argmin, float32, first-min-wins."""
    w2 = np.sum(cb.astype(np.float32) ** 2, axis=1, dtype=np.float32)
    dots = (data.astype(np.float32) @ cb.astype(np.float32).T).astype(np.float32)
    scores = np.float32(0.5) * w2[None, :] - dots
    return np.argmin(scores, axis=1).astype(np.int32)


def run(seed):
    data, init_cb = gen_case(seed)
    coords = square_planar_coords()
    grid_dist = np.asarray(
        ref.grid_distance_matrix(jnp.asarray(coords), map_type="planar"),
        dtype=np.float32,
    )

    cb = jnp.asarray(init_cb)
    data_j = jnp.asarray(data)
    qes, bmus = [], None
    for epoch in range(EPOCHS):
        radius = schedule(RADIUS0, RADIUS_N, epoch, EPOCHS)
        scale = schedule(SCALE0, SCALE_N, epoch, EPOCHS)
        bmus, num, den, qe_sum = ref.epoch_accumulators(
            data_j, cb, jnp.asarray(grid_dist), radius, scale, kind="gaussian"
        )
        # Self-check: the rust Gram formulation must agree on every BMU.
        alt = rust_like_bmus(data, np.asarray(cb))
        if not np.array_equal(np.asarray(bmus), alt):
            return None
        qes.append(float(qe_sum) / DATA_ROWS)
        cb = ref.apply_update(cb, num, den)
    return data, init_cb, np.asarray(cb), qes, np.asarray(bmus)


def fmt(v):
    """Shortest round-tripping decimal for a float32 value."""
    return str(np.float32(v))


def write_dense(path, mat):
    with open(path, "w") as f:
        for row in np.asarray(mat, dtype=np.float32):
            f.write(" ".join(fmt(v) for v in row) + "\n")


def main():
    FIXTURES.mkdir(parents=True, exist_ok=True)
    for seed in range(1347, 1400):
        out = run(seed)
        if out is not None:
            break
    else:
        raise SystemExit("no tie-free seed found")
    data, init_cb, final_cb, qes, bmus = out

    write_dense(FIXTURES / "golden_data.txt", data)
    write_dense(FIXTURES / "golden_init_codebook.txt", init_cb)
    write_dense(FIXTURES / "golden_codebook_after3.txt", final_cb)
    with open(FIXTURES / "golden_qe.txt", "w") as f:
        for qe in qes:
            f.write(format(qe, ".12e") + "\n")
    with open(FIXTURES / "golden_bmus.txt", "w") as f:
        for b in bmus:
            f.write(f"{int(b)}\n")
    meta = {
        "generator": "python/compile/gen_golden.py",
        "oracle": "python/compile/kernels/ref.py",
        "seed": seed,
        "map": [MAP_ROWS, MAP_COLS],
        "grid": "square",
        "topology": "planar",
        "neighborhood": "gaussian",
        "compact_support": False,
        "dim": DIM,
        "rows": DATA_ROWS,
        "epochs": EPOCHS,
        "radius": [float(RADIUS0), float(RADIUS_N)],
        "scale": [float(SCALE0), float(SCALE_N)],
        "cooling": "linear",
        "qe": "mean Euclidean distance to BMU before the epoch's update",
    }
    with open(FIXTURES / "golden_meta.json", "w") as f:
        json.dump(meta, f, indent=2)
        f.write("\n")
    print(f"wrote fixtures for seed {seed}: qe trajectory {qes}")


if __name__ == "__main__":
    main()
