"""Pure-jnp correctness oracles for the Pallas kernels and the L2 model.

These are the ground truth the Pallas kernels (distance.py, update.py) and
the composed epoch step (model.py) are validated against in pytest.

Everything here mirrors the batch-SOM formulation of the paper (Eq. 2/5/6):

  dist[s, n]  = || x_s - w_n ||^2                       (squared Euclidean)
  bmu[s]      = argmin_n dist[s, n]                     (first min wins)
  H[s, n]     = h(grid_dist(bmu[s], n); radius)         (neighborhood)
  num[n, :]   = sum_s H[s, n] * x_s                     (Eq. 6 numerator)
  den[n]      = sum_s H[s, n]                           (Eq. 6 denominator)

Masking: `data_mask[s] in {0,1}` zeroes the contribution of padded data
rows; `node_valid[n] in {0,1}` prevents padded codebook rows from winning
the argmin (their distance gets +BIG).
"""

import jax.numpy as jnp

# Large-but-finite penalty for invalid nodes. Using +inf would poison
# 0 * inf = nan in downstream masking, so stay finite.
BIG = jnp.float32(1e30)


def sq_distance_matrix(data, codebook):
    """Squared Euclidean distances, [S, D] x [N, D] -> [S, N].

    Direct formulation (no Gram trick) — numerically the most transparent
    oracle. float32 in, float32 out.
    """
    diff = data[:, None, :] - codebook[None, :, :]
    return jnp.sum(diff * diff, axis=-1)


def sq_distance_matrix_gram(data, codebook):
    """Gram-trick formulation: ||x||^2 + ||w||^2 - 2 x.w — what the paper's
    GPU kernel (and our Pallas kernel) actually computes. Clamped at 0 to
    kill tiny negative values from cancellation."""
    x2 = jnp.sum(data * data, axis=1)[:, None]
    w2 = jnp.sum(codebook * codebook, axis=1)[None, :]
    cross = data @ codebook.T
    return jnp.maximum(x2 + w2 - 2.0 * cross, 0.0)


def bmu(data, codebook, node_valid=None):
    """Best-matching-unit indices [S] (int32) and their squared distances.

    First minimum wins (matches jnp.argmin and the rust kernels).
    """
    dist = sq_distance_matrix(data, codebook)
    if node_valid is not None:
        dist = dist + (1.0 - node_valid)[None, :] * BIG
    idx = jnp.argmin(dist, axis=1).astype(jnp.int32)
    best = jnp.min(dist, axis=1)
    return idx, best


def neighborhood_weights(grid_dist_rows, radius, *, kind="gaussian",
                         compact=False):
    """Neighborhood function h(.) of Eq. 5 applied to grid distances.

    grid_dist_rows: [S, N] grid distances from each sample's BMU to node n.
    kind='gaussian': exp(-d^2 / (2 r^2)); kind='bubble': 1[d <= r].
    compact=True cuts the gaussian off beyond the radius (paper's -p flag).
    """
    r = jnp.maximum(radius, 1e-6)
    if kind == "gaussian":
        h = jnp.exp(-(grid_dist_rows * grid_dist_rows) / (2.0 * r * r))
        if compact:
            h = jnp.where(grid_dist_rows <= r, h, 0.0)
    elif kind == "bubble":
        h = jnp.where(grid_dist_rows <= r, 1.0, 0.0)
    else:
        raise ValueError(f"unknown neighborhood kind {kind!r}")
    return h


def grid_distance_matrix(coords, span=None, *, map_type="planar"):
    """Dense node-to-node grid distances [N, N] from coordinates [N, 2].

    Oracle counterpart of model.grid_distances: toroid wraps each axis
    with min(|d|, span - |d|).
    """
    d = jnp.abs(coords[:, None, :] - coords[None, :, :])
    if map_type == "toroid":
        d = jnp.minimum(d, span[None, None, :] - d)
    elif map_type != "planar":
        raise ValueError(f"unknown map type {map_type!r}")
    return jnp.sqrt(jnp.sum(d * d, axis=-1))


def epoch_accumulators(data, codebook, node_grid_dist, radius, scale,
                       data_mask=None, node_valid=None, *,
                       kind="gaussian", compact=False):
    """One batch-SOM accumulation pass (the L2 model's contract).

    Returns (bmus[S] i32, num[N, D], den[N], qe_sum scalar).
    `scale` multiplies H (the learning-rate factor folded into the batch
    update the way somoclu's kernels do).
    """
    S = data.shape[0]
    if data_mask is None:
        data_mask = jnp.ones((S,), jnp.float32)
    dist = sq_distance_matrix(data, codebook)
    if node_valid is not None:
        dist = dist + (1.0 - node_valid)[None, :] * BIG
    bmus = jnp.argmin(dist, axis=1).astype(jnp.int32)
    best = jnp.min(dist, axis=1)
    # qe accumulates the *Euclidean* (not squared) distance of valid rows.
    qe_sum = jnp.sum(jnp.sqrt(jnp.maximum(best, 0.0)) * data_mask)
    grid_rows = node_grid_dist[bmus]                      # [S, N]
    h = neighborhood_weights(grid_rows, radius, kind=kind, compact=compact)
    h = h * scale * data_mask[:, None]                    # [S, N]
    num = h.T @ data                                      # [N, D]
    den = jnp.sum(h, axis=0)                              # [N]
    return bmus, num, den, qe_sum


def apply_update(codebook, num, den, node_valid=None, eps=1e-12):
    """Master-side codebook update: w_n = num_n / den_n where den_n > 0,
    keep old weights elsewhere (somoclu behaviour for unhit nodes)."""
    hit = den > eps
    new = num / jnp.where(hit, den, 1.0)[:, None]
    out = jnp.where(hit[:, None], new, codebook)
    if node_valid is not None:
        out = jnp.where((node_valid > 0.5)[:, None], out, codebook)
    return out
