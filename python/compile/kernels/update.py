"""L1 Pallas kernel: tiled weighted codebook accumulation (Eq. 6).

Computes the batch-update accumulators

    num[n, :] = sum_s H[s, n] * x[s, :]   =  (H^T @ X)[n, :]
    den[n]    = sum_s H[s, n]             =  (H^T @ 1)[n]

as one tiled MXU matmul with an S-reduction carried across the minor grid
axis. H is the (already masked and scaled) neighborhood weight matrix
produced by the L2 model between the two kernels.

Tiling: grid = (N/BN, S/BS) with S minor so each (num, den) output block is
revisited across the S sweep and accumulated in VMEM. The D axis is kept
whole per block (codebook feature dim fits VMEM for the paper's configs;
see DESIGN.md §Perf for the footprint table).

interpret=True required on the CPU PJRT plugin (see distance.py).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BS = 128
DEFAULT_BN = 128


def _accum_kernel(h_ref, x_ref, num_ref, den_ref):
    """One (i, k) grid step: accumulate H^T X and H^T 1 for node block i.

    h_ref:   [BS, BN]  neighborhood weight tile (sample-block k, node-block i)
    x_ref:   [BS, D]   data row block k
    num_ref: [BN, D]   accumulator (revisited across k)
    den_ref: [BN]      accumulator (revisited across k)
    """
    k = pl.program_id(1)

    ht = h_ref[...].T                                   # [BN, BS]
    part_num = jnp.dot(ht, x_ref[...],
                       preferred_element_type=jnp.float32)  # [BN, D]
    part_den = jnp.sum(ht, axis=1)                      # [BN]

    @pl.when(k == 0)
    def _init():
        num_ref[...] = part_num
        den_ref[...] = part_den

    @pl.when(k > 0)
    def _accum():
        num_ref[...] = num_ref[...] + part_num
        den_ref[...] = den_ref[...] + part_den


@functools.partial(jax.jit, static_argnames=("block_s", "block_n",
                                             "interpret"))
def accumulate_pallas(h, data, *, block_s=DEFAULT_BS, block_n=DEFAULT_BN,
                      interpret=True):
    """Weighted accumulation. h [S, N] (masked+scaled), data [S, D].

    Returns (num [N, D] f32, den [N] f32). S % block_s == 0 and
    N % block_n == 0 (AOT configs guarantee; rust runtime pads).
    """
    s, n = h.shape
    _, d = data.shape
    bs = min(block_s, s)
    bn = min(block_n, n)
    assert s % bs == 0 and n % bn == 0, (s, n, bs, bn)

    grid = (n // bn, s // bs)
    num, den = pl.pallas_call(
        _accum_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bs, bn), lambda i, k: (k, i)),
            pl.BlockSpec((bs, d), lambda i, k: (k, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bn, d), lambda i, k: (i, 0)),
            pl.BlockSpec((bn,), lambda i, k: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, d), jnp.float32),
            jax.ShapeDtypeStruct((n,), jnp.float32),
        ],
        interpret=interpret,
    )(h, data)
    return num, den
