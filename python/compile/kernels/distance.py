"""L1 Pallas kernel: fused tiled distance-matrix + running arg-min (BMU).

This is the reproduction of Somoclu's GPU kernel. The paper's insight
(Section 3.1) is that the Euclidean Gram matrix should be computed with
dense linear algebra ("a magnitude faster ... mainly due to a more
favorable memory access pattern") instead of a naive distance loop:

    dist[s, n] = ||x_s||^2 + ||w_n||^2 - 2 * (x @ w^T)[s, n]

TPU adaptation (DESIGN.md §Hardware-Adaptation): instead of CUDA
threadblocks + Thrust reductions, we tile the [S, N] distance matrix into
(BS x BN) VMEM blocks via BlockSpec. The cross term is one MXU matmul per
tile; the squared norms are precomputed rank-1 corrections. The arg-min is
*fused* into the same kernel: the full S x N distance matrix is never
materialized in HBM (the paper's memory-frugality claim — their kernel
avoids transposes and temporary Gram storage; ours avoids the Gram matrix
entirely on the BMU path).

Grid layout: (S/BS, N/BN); the N axis is the minor (fastest) grid axis, so
each output row-block is revisited across the N sweep carrying a running
(best distance, best index) pair. First minimum wins on exact ties:
within a tile `argmin` picks the first, and across tiles a strict `<`
keeps the earlier tile's winner.

Must be lowered with interpret=True in this environment: real-TPU lowering
emits a Mosaic custom-call the CPU PJRT plugin cannot execute.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Plain python float (not a jnp scalar): pallas kernels may not capture
# traced constants, and a python literal folds into the kernel body.
BIG = 1e30

# Default MXU-shaped tiles. BS x BN distance tile (f32) is 128 KiB at
# 128x256; with the x tile [BS, D] and w tile [BN, D] at D=1024 the VMEM
# footprint stays under ~1.5 MiB per grid step (see DESIGN.md §Perf).
DEFAULT_BS = 128
DEFAULT_BN = 128


def _bmu_kernel(x_ref, w_ref, x2_ref, w2_ref, valid_ref,
                best_ref, idx_ref):
    """One (i, j) grid step: tile distances + running arg-min update.

    x_ref:  [BS, D]   data row block (full feature dim in VMEM)
    w_ref:  [BN, D]   codebook row block
    x2_ref: [BS]      precomputed ||x||^2 for the row block
    w2_ref: [BN]      precomputed ||w||^2 for the codebook block
    valid_ref: [BN]   1.0 for real nodes, 0.0 for padding
    best_ref: [BS]    carried best squared distance (output, revisited)
    idx_ref:  [BS]    carried best node index (output, revisited)
    """
    j = pl.program_id(1)
    bn = w_ref.shape[0]

    # MXU cross term + rank-1 corrections = squared Euclidean distances.
    cross = jnp.dot(x_ref[...], w_ref[...].T,
                    preferred_element_type=jnp.float32)
    dist = x2_ref[...][:, None] + w2_ref[...][None, :] - 2.0 * cross
    # Cancellation can push tiny distances negative; clamp like the oracle.
    dist = jnp.maximum(dist, 0.0)
    # Padding nodes must never win.
    dist = dist + (1.0 - valid_ref[...])[None, :] * BIG

    local_arg = jnp.argmin(dist, axis=1)
    local_min = jnp.min(dist, axis=1)
    local_idx = (j * bn + local_arg).astype(jnp.int32)

    @pl.when(j == 0)
    def _init():
        best_ref[...] = local_min
        idx_ref[...] = local_idx

    @pl.when(j > 0)
    def _update():
        prev_best = best_ref[...]
        prev_idx = idx_ref[...]
        better = local_min < prev_best  # strict: first (lowest-j) min wins
        best_ref[...] = jnp.where(better, local_min, prev_best)
        idx_ref[...] = jnp.where(better, local_idx, prev_idx)


def _bmu_direct_kernel(x_ref, w_ref, valid_ref, best_ref, idx_ref):
    """Naive-formulation variant (the paper's rejected GPU design):
    materializes the (BS, BN, D) difference tensor per tile instead of
    using the Gram trick — §3.1 found the linear-algebra formulation "a
    magnitude faster ... mainly due to a more favorable memory access
    pattern". Kept as an AOT variant so the ablation bench can reproduce
    that design comparison.
    """
    j = pl.program_id(1)
    bn = w_ref.shape[0]

    diff = x_ref[...][:, None, :] - w_ref[...][None, :, :]
    dist = jnp.sum(diff * diff, axis=-1)
    dist = dist + (1.0 - valid_ref[...])[None, :] * BIG

    local_arg = jnp.argmin(dist, axis=1)
    local_min = jnp.min(dist, axis=1)
    local_idx = (j * bn + local_arg).astype(jnp.int32)

    @pl.when(j == 0)
    def _init():
        best_ref[...] = local_min
        idx_ref[...] = local_idx

    @pl.when(j > 0)
    def _update():
        prev_best = best_ref[...]
        prev_idx = idx_ref[...]
        better = local_min < prev_best
        best_ref[...] = jnp.where(better, local_min, prev_best)
        idx_ref[...] = jnp.where(better, local_idx, prev_idx)


@functools.partial(jax.jit, static_argnames=("block_s", "block_n",
                                             "interpret"))
def bmu_pallas_direct(data, codebook, node_valid, *, block_s=DEFAULT_BS,
                      block_n=DEFAULT_BN, interpret=True):
    """Direct-formulation BMU search (ablation baseline; see
    `_bmu_direct_kernel`). Same contract as `bmu_pallas`."""
    s, d = data.shape
    n, _ = codebook.shape
    bs = min(block_s, s)
    bn = min(block_n, n)
    assert s % bs == 0 and n % bn == 0, (s, n, bs, bn)

    grid = (s // bs, n // bn)
    best, idx = pl.pallas_call(
        _bmu_direct_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bs, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, d), lambda i, j: (j, 0)),
            pl.BlockSpec((bn,), lambda i, j: (j,)),
        ],
        out_specs=[
            pl.BlockSpec((bs,), lambda i, j: (i,)),
            pl.BlockSpec((bs,), lambda i, j: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((s,), jnp.float32),
            jax.ShapeDtypeStruct((s,), jnp.int32),
        ],
        interpret=interpret,
    )(data, codebook, node_valid)
    return best, idx


@functools.partial(jax.jit, static_argnames=("block_s", "block_n",
                                             "interpret"))
def bmu_pallas(data, codebook, node_valid, *, block_s=DEFAULT_BS,
               block_n=DEFAULT_BN, interpret=True):
    """Fused BMU search. data [S, D], codebook [N, D], node_valid [N].

    Returns (best_sq_dist [S] f32, bmu_idx [S] i32). S must be a multiple
    of block_s and N of block_n (the AOT configs guarantee this; the rust
    runtime pads).
    """
    s, d = data.shape
    n, _ = codebook.shape
    bs = min(block_s, s)
    bn = min(block_n, n)
    assert s % bs == 0 and n % bn == 0, (s, n, bs, bn)

    x2 = jnp.sum(data * data, axis=1)
    w2 = jnp.sum(codebook * codebook, axis=1)

    grid = (s // bs, n // bn)
    best, idx = pl.pallas_call(
        _bmu_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bs, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, d), lambda i, j: (j, 0)),
            pl.BlockSpec((bs,), lambda i, j: (i,)),
            pl.BlockSpec((bn,), lambda i, j: (j,)),
            pl.BlockSpec((bn,), lambda i, j: (j,)),
        ],
        out_specs=[
            pl.BlockSpec((bs,), lambda i, j: (i,)),
            pl.BlockSpec((bs,), lambda i, j: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((s,), jnp.float32),
            jax.ShapeDtypeStruct((s,), jnp.int32),
        ],
        interpret=interpret,
    )(data, codebook, x2, w2, node_valid)
    return best, idx
