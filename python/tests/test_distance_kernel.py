"""Pallas BMU kernel vs the pure-jnp oracle.

The hypothesis sweep varies shapes, block sizes, masks and data scales;
every case asserts exact index agreement and allclose distances.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import distance, ref

RNG = np.random.default_rng(0)


def _rand(shape, scale=1.0, seed=None):
    rng = np.random.default_rng(seed) if seed is not None else RNG
    return (rng.standard_normal(shape) * scale).astype(np.float32)


def _check(data, codebook, node_valid, block_s, block_n, exact_idx=True):
    best, idx = distance.bmu_pallas(
        jnp.asarray(data), jnp.asarray(codebook), jnp.asarray(node_valid),
        block_s=block_s, block_n=block_n, interpret=True)
    best, idx = np.asarray(best), np.asarray(idx)
    ref_idx, ref_best = ref.bmu(jnp.asarray(data), jnp.asarray(codebook),
                                jnp.asarray(node_valid))
    ref_idx, ref_best = np.asarray(ref_idx), np.asarray(ref_best)
    # The Gram trick cancels ||x||^2 + ||w||^2 against 2 x.w, so its f32
    # absolute error scales with the norm magnitudes, not with the
    # distance itself (same trade-off as the paper's GPU kernel).
    mag = float(np.square(data).sum(1).max() + np.square(codebook).sum(1).max())
    tol = 1e-4 + 1e-5 * mag
    if exact_idx:
        np.testing.assert_array_equal(idx, ref_idx)
    else:
        # Near-ties may flip the argmin between the Gram and direct
        # formulations; require an ε-argmin: the chosen node's true
        # distance must be within tol of the oracle minimum.
        chosen = np.square(
            data - codebook[idx]).sum(axis=1).astype(np.float64)
        np.testing.assert_allclose(chosen, ref_best, rtol=1e-4, atol=tol)
        assert node_valid[idx].min() > 0.5
    np.testing.assert_allclose(best, ref_best, rtol=1e-4, atol=tol)


def test_basic():
    data = _rand((128, 32), seed=1)
    cb = _rand((256, 32), seed=2)
    _check(data, cb, np.ones(256, np.float32), 64, 64)


def test_single_tile():
    data = _rand((64, 8), seed=3)
    cb = _rand((64, 8), seed=4)
    _check(data, cb, np.ones(64, np.float32), 64, 64)


def test_node_padding_never_wins():
    # Padded codebook rows are zero vectors — without masking they would
    # win for any data far from the origin.
    data = _rand((64, 4), scale=0.01, seed=5)
    cb = np.zeros((128, 4), np.float32)
    cb[:100] = _rand((100, 4), scale=10.0, seed=6)
    valid = np.zeros(128, np.float32)
    valid[:100] = 1.0
    _, idx = distance.bmu_pallas(
        jnp.asarray(data), jnp.asarray(cb), jnp.asarray(valid),
        block_s=64, block_n=64, interpret=True)
    assert np.asarray(idx).max() < 100


def test_tie_first_min_wins():
    # Identical codebook rows in different tiles: the lower index wins.
    data = _rand((64, 4), seed=7)
    row = _rand((1, 4), seed=8)
    cb = np.tile(row, (128, 1)).astype(np.float32)
    _, idx = distance.bmu_pallas(
        jnp.asarray(data), jnp.asarray(cb), jnp.asarray(np.ones(128, np.float32)),
        block_s=64, block_n=64, interpret=True)
    np.testing.assert_array_equal(np.asarray(idx), np.zeros(64, np.int32))


def test_exact_match_distance_zero():
    cb = _rand((128, 16), seed=9)
    data = cb[:64].copy()
    best, idx = distance.bmu_pallas(
        jnp.asarray(data), jnp.asarray(cb), jnp.asarray(np.ones(128, np.float32)),
        block_s=64, block_n=64, interpret=True)
    np.testing.assert_array_equal(np.asarray(idx), np.arange(64))
    np.testing.assert_allclose(np.asarray(best), 0.0, atol=1e-4)


@settings(deadline=None, max_examples=25)
@given(
    s_tiles=st.integers(1, 3),
    n_tiles=st.integers(1, 3),
    d=st.integers(1, 48),
    block=st.sampled_from([32, 64]),
    scale=st.sampled_from([0.01, 1.0, 100.0]),
    n_invalid=st.integers(0, 16),
    seed=st.integers(0, 2**31 - 1),
)
def test_hypothesis_sweep(s_tiles, n_tiles, d, block, scale, n_invalid,
                          seed):
    s = s_tiles * block
    n = n_tiles * block
    data = _rand((s, d), scale=scale, seed=seed)
    cb = _rand((n, d), scale=scale, seed=seed + 1)
    valid = np.ones(n, np.float32)
    if n_invalid:
        valid[n - min(n_invalid, n - 1):] = 0.0
    _check(data, cb, valid, block, block, exact_idx=False)


def test_rejects_non_multiple_shapes():
    data = _rand((100, 8), seed=10)  # 100 not a multiple of 64
    cb = _rand((64, 8), seed=11)
    with pytest.raises(AssertionError):
        distance.bmu_pallas(jnp.asarray(data), jnp.asarray(cb),
                            jnp.asarray(np.ones(64, np.float32)),
                            block_s=64, block_n=64, interpret=True)
