"""Pallas accumulation kernel (H^T X, H^T 1) vs plain matmul oracle."""

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels import update


def _rand(shape, seed, scale=1.0):
    rng = np.random.default_rng(seed)
    return (rng.standard_normal(shape) * scale).astype(np.float32)


def _check(h, data, block_s, block_n, rtol=1e-4, atol=1e-4):
    num, den = update.accumulate_pallas(
        jnp.asarray(h), jnp.asarray(data),
        block_s=block_s, block_n=block_n, interpret=True)
    ref_num = h.T.astype(np.float64) @ data.astype(np.float64)
    ref_den = h.sum(axis=0, dtype=np.float64)
    np.testing.assert_allclose(np.asarray(num), ref_num, rtol=rtol,
                               atol=atol)
    np.testing.assert_allclose(np.asarray(den), ref_den, rtol=rtol,
                               atol=atol)


def test_basic():
    _check(_rand((128, 128), 0), _rand((128, 32), 1), 64, 64)


def test_multi_tile_accumulation():
    # 4 S-tiles: exercises the k>0 accumulate branch.
    _check(_rand((256, 64), 2), _rand((256, 16), 3), 64, 64)


def test_zero_weights_zero_output():
    h = np.zeros((128, 64), np.float32)
    data = _rand((128, 8), 4)
    num, den = update.accumulate_pallas(
        jnp.asarray(h), jnp.asarray(data), block_s=64, block_n=64,
        interpret=True)
    assert np.abs(np.asarray(num)).max() == 0.0
    assert np.abs(np.asarray(den)).max() == 0.0


def test_one_hot_weights_select_rows():
    # H is a permutation-ish one-hot: num[n] must equal the selected row.
    s, n, d = 64, 64, 8
    h = np.zeros((s, n), np.float32)
    perm = np.random.default_rng(5).permutation(s)
    for i, p in enumerate(perm):
        h[i, p] = 1.0
    data = _rand((s, d), 6)
    num, den = update.accumulate_pallas(
        jnp.asarray(h), jnp.asarray(data), block_s=32, block_n=32,
        interpret=True)
    np.testing.assert_allclose(np.asarray(den), 1.0, atol=1e-6)
    np.testing.assert_allclose(np.asarray(num)[perm], data, atol=1e-5)


@settings(deadline=None, max_examples=20)
@given(
    s_tiles=st.integers(1, 3),
    n_tiles=st.integers(1, 3),
    d=st.integers(1, 40),
    block=st.sampled_from([32, 64]),
    scale=st.sampled_from([0.01, 1.0, 10.0]),
    seed=st.integers(0, 2**31 - 1),
)
def test_hypothesis_sweep(s_tiles, n_tiles, d, block, scale, seed):
    s, n = s_tiles * block, n_tiles * block
    h = np.abs(_rand((s, n), seed, scale))
    data = _rand((s, d), seed + 1, scale)
    # f32 accumulation over multiple tiles: loosen tolerance with scale.
    _check(h, data, block, block, rtol=1e-3, atol=1e-3 * scale * scale)
