"""Direct-formulation BMU kernel (ablation variant) vs Gram kernel vs
oracle: identical indices, same distances within f32 tolerance. The
direct formulation is *more* accurate at large scales (no cancellation),
so it anchors the Gram kernel's error band too."""

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels import distance, ref


def _rand(shape, seed, scale=1.0):
    rng = np.random.default_rng(seed)
    return (rng.standard_normal(shape) * scale).astype(np.float32)


def test_direct_matches_oracle_exactly():
    data = _rand((128, 24), 0)
    cb = _rand((128, 24), 1)
    valid = np.ones(128, np.float32)
    best, idx = distance.bmu_pallas_direct(
        jnp.asarray(data), jnp.asarray(cb), jnp.asarray(valid),
        block_s=64, block_n=64, interpret=True)
    ref_idx, ref_best = ref.bmu(jnp.asarray(data), jnp.asarray(cb),
                                jnp.asarray(valid))
    np.testing.assert_array_equal(np.asarray(idx), np.asarray(ref_idx))
    np.testing.assert_allclose(np.asarray(best), np.asarray(ref_best),
                               rtol=1e-5, atol=1e-5)


def test_direct_and_gram_agree():
    data = _rand((64, 16), 2)
    cb = _rand((128, 16), 3)
    valid = np.ones(128, np.float32)
    bd, id_d = distance.bmu_pallas_direct(
        jnp.asarray(data), jnp.asarray(cb), jnp.asarray(valid),
        block_s=32, block_n=32, interpret=True)
    bg, id_g = distance.bmu_pallas(
        jnp.asarray(data), jnp.asarray(cb), jnp.asarray(valid),
        block_s=32, block_n=32, interpret=True)
    np.testing.assert_array_equal(np.asarray(id_d), np.asarray(id_g))
    np.testing.assert_allclose(np.asarray(bd), np.asarray(bg),
                               rtol=1e-4, atol=1e-4)


def test_direct_masking():
    data = _rand((32, 8), 4, scale=0.01)
    cb = np.zeros((64, 8), np.float32)
    cb[:40] = _rand((40, 8), 5, scale=5.0)
    valid = np.zeros(64, np.float32)
    valid[:40] = 1.0
    _, idx = distance.bmu_pallas_direct(
        jnp.asarray(data), jnp.asarray(cb), jnp.asarray(valid),
        block_s=32, block_n=32, interpret=True)
    assert np.asarray(idx).max() < 40


@settings(deadline=None, max_examples=15)
@given(
    s_tiles=st.integers(1, 2),
    n_tiles=st.integers(1, 2),
    d=st.integers(1, 32),
    block=st.sampled_from([32, 64]),
    seed=st.integers(0, 2**31 - 1),
)
def test_direct_hypothesis_sweep(s_tiles, n_tiles, d, block, seed):
    s, n = s_tiles * block, n_tiles * block
    data = _rand((s, d), seed)
    cb = _rand((n, d), seed + 1)
    valid = np.ones(n, np.float32)
    best, idx = distance.bmu_pallas_direct(
        jnp.asarray(data), jnp.asarray(cb), jnp.asarray(valid),
        block_s=block, block_n=block, interpret=True)
    ref_idx, ref_best = ref.bmu(jnp.asarray(data), jnp.asarray(cb),
                                jnp.asarray(valid))
    np.testing.assert_array_equal(np.asarray(idx), np.asarray(ref_idx))
    np.testing.assert_allclose(np.asarray(best), np.asarray(ref_best),
                               rtol=1e-4, atol=1e-4)
