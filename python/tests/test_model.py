"""L2 epoch step vs the pure-jnp oracle, across geometry/kind variants."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref


def square_coords(rows, cols):
    ys, xs = np.mgrid[0:rows, 0:cols].astype(np.float32)
    return np.stack([xs.ravel(), ys.ravel()], axis=1)


def hex_coords(rows, cols):
    ys, xs = np.mgrid[0:rows, 0:cols].astype(np.float32)
    xs = xs + 0.5 * (ys % 2)
    ys = ys * np.float32(np.sqrt(3) / 2)
    return np.stack([xs.ravel(), ys.ravel()], axis=1)


def _pad_nodes(coords, n_pad):
    n = coords.shape[0]
    out = np.zeros((n_pad, 2), np.float32)
    out[:n] = coords
    valid = np.zeros(n_pad, np.float32)
    valid[:n] = 1.0
    return out, valid


def _run_case(kind, map_type, rows=8, cols=8, s=64, d=12, seed=0,
              radius=2.5, scale=0.7, n_masked=5, grid="square"):
    rng = np.random.default_rng(seed)
    n_real = rows * cols
    n = 128  # padded
    coords_real = (square_coords if grid == "square" else hex_coords)(rows, cols)
    coords, valid = _pad_nodes(coords_real, n)
    span = np.array([cols, rows], np.float32) if grid == "square" else \
        np.array([cols, rows * np.sqrt(3) / 2], np.float32)

    data = rng.standard_normal((s, d)).astype(np.float32)
    mask = np.ones(s, np.float32)
    if n_masked:
        mask[s - n_masked:] = 0.0
    codebook = np.zeros((n, d), np.float32)
    codebook[:n_real] = rng.standard_normal((n_real, d)).astype(np.float32)

    bmus, num, den, qe = model.som_epoch_step(
        jnp.asarray(data), jnp.asarray(mask), jnp.asarray(codebook),
        jnp.asarray(coords), jnp.asarray(valid), jnp.asarray(span),
        jnp.float32(radius), jnp.float32(scale),
        kind=kind, map_type=map_type, block_s=32, block_n=32,
        interpret=True)

    # Oracle: dense grid-distance matrix from the same coords.
    gd = ref.grid_distance_matrix(jnp.asarray(coords), jnp.asarray(span),
                                  map_type=map_type)
    okind = "gaussian" if kind.startswith("gaussian") else "bubble"
    compact = kind == "gaussian_compact"
    rbmus, rnum, rden, rqe = ref.epoch_accumulators(
        jnp.asarray(data), jnp.asarray(codebook), gd,
        jnp.float32(radius), jnp.float32(scale),
        data_mask=jnp.asarray(mask), node_valid=jnp.asarray(valid),
        kind=okind, compact=compact)

    bmus = np.asarray(bmus)
    if (bmus == np.asarray(rbmus)).all():
        np.testing.assert_allclose(np.asarray(num), np.asarray(rnum),
                                   rtol=1e-3, atol=1e-3)
        np.testing.assert_allclose(np.asarray(den), np.asarray(rden),
                                   rtol=1e-3, atol=1e-3)
        np.testing.assert_allclose(float(qe), float(rqe), rtol=1e-3,
                                   atol=1e-3)
    else:
        # Gram-vs-direct near-ties can flip an argmin; accept an ε-argmin
        # and verify the rest of the pipeline against the kernel's BMUs.
        chosen = np.square(data - codebook[bmus]).sum(axis=1)
        np.testing.assert_allclose(chosen, np.asarray(rqe * 0 + 0) +
                                   np.square(data - codebook[np.asarray(rbmus)]).sum(axis=1),
                                   rtol=1e-3, atol=1e-3)
        h = ref.neighborhood_weights(np.asarray(gd)[bmus],
                                     jnp.float32(radius), kind=okind,
                                     compact=compact)
        h = np.asarray(h) * scale * mask[:, None]
        np.testing.assert_allclose(np.asarray(num), h.T @ data,
                                   rtol=1e-3, atol=1e-3)
        np.testing.assert_allclose(np.asarray(den), h.sum(0),
                                   rtol=1e-3, atol=1e-3)
    return bmus, np.asarray(num), np.asarray(den)


@pytest.mark.parametrize("kind", model.NEIGHBORHOOD_KINDS)
@pytest.mark.parametrize("map_type", model.MAP_TYPES)
def test_variants_match_oracle(kind, map_type):
    _run_case(kind, map_type)


def test_hex_grid():
    _run_case("gaussian", "planar", grid="hex")


def test_hex_toroid():
    _run_case("gaussian", "toroid", grid="hex")


def test_masked_rows_contribute_nothing():
    bm_a, num_a, den_a = _run_case("gaussian", "planar", n_masked=0, s=64,
                                   seed=3)
    # Same data but last 16 rows masked: accumulators must equal the
    # 48-row run on the unmasked prefix.
    rng = np.random.default_rng(3)
    n, d, s = 128, 12, 64
    coords, valid = _pad_nodes(square_coords(8, 8), n)
    span = np.array([8, 8], np.float32)
    data = rng.standard_normal((s, d)).astype(np.float32)
    codebook = np.zeros((n, d), np.float32)
    codebook[:64] = rng.standard_normal((64, d)).astype(np.float32)
    mask = np.ones(s, np.float32)
    mask[48:] = 0.0
    _, num_m, den_m, qe_m = model.som_epoch_step(
        jnp.asarray(data), jnp.asarray(mask), jnp.asarray(codebook),
        jnp.asarray(coords), jnp.asarray(valid), jnp.asarray(span),
        jnp.float32(2.0), jnp.float32(1.0), kind="gaussian",
        map_type="planar", block_s=32, block_n=32, interpret=True)

    gd = ref.grid_distance_matrix(jnp.asarray(coords), jnp.asarray(span))
    _, num_r, den_r, qe_r = ref.epoch_accumulators(
        jnp.asarray(data[:48]), jnp.asarray(codebook), gd,
        jnp.float32(2.0), jnp.float32(1.0),
        node_valid=jnp.asarray(valid))
    np.testing.assert_allclose(np.asarray(num_m), np.asarray(num_r),
                               rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.asarray(den_m), np.asarray(den_r),
                               rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(float(qe_m), float(qe_r), rtol=1e-3)


def test_toroid_wraps():
    # On a toroid the grid distance between opposite edges is 1, so a BMU
    # at column 0 must pull nodes at the far column with weight exp(-1/(2r^2))
    # rather than the planar exp(-49/(2r^2)). Verified through the oracle
    # comparison in _run_case; here check wrap explicitly via model helper.
    coords = jnp.asarray(square_coords(1, 8))
    span = jnp.asarray(np.array([8.0, 1.0], np.float32))
    gd = model.grid_distances(jnp.asarray(np.array([0], np.int32)),
                              coords, span, map_type="toroid")
    np.testing.assert_allclose(
        np.asarray(gd)[0], [0, 1, 2, 3, 4, 3, 2, 1], atol=1e-6)


def test_full_training_convergence_interpret():
    """Mini end-to-end: iterating the epoch step shrinks QE (batch SOM
    actually converges on blob data)."""
    rng = np.random.default_rng(7)
    s, d = 64, 8
    centers = rng.standard_normal((4, d)).astype(np.float32) * 3
    data = np.concatenate([
        centers[i] + 0.1 * rng.standard_normal((s // 4, d)).astype(np.float32)
        for i in range(4)])
    mask = np.ones(s, np.float32)
    rows = cols = 6
    n = 64
    coords, valid = _pad_nodes(square_coords(rows, cols), n)
    span = np.array([cols, rows], np.float32)
    codebook = 0.1 * rng.standard_normal((n, d)).astype(np.float32)
    codebook[36:] = 0.0

    qes = []
    for epoch in range(6):
        radius = np.float32(3.0 - epoch * 0.5 + 0.5)
        _, num, den, qe = model.som_epoch_step(
            jnp.asarray(data), jnp.asarray(mask), jnp.asarray(codebook),
            jnp.asarray(coords), jnp.asarray(valid), jnp.asarray(span),
            radius, np.float32(1.0), kind="gaussian", map_type="planar",
            block_s=32, block_n=32, interpret=True)
        codebook = np.asarray(ref.apply_update(
            jnp.asarray(codebook), num, den, jnp.asarray(valid)))
        qes.append(float(qe) / s)
    assert qes[-1] < qes[0] * 0.5, qes


@settings(deadline=None, max_examples=10)
@given(
    kind=st.sampled_from(model.NEIGHBORHOOD_KINDS),
    map_type=st.sampled_from(model.MAP_TYPES),
    radius=st.floats(0.5, 6.0),
    scale=st.floats(0.05, 1.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_hypothesis_model_sweep(kind, map_type, radius, scale, seed):
    _run_case(kind, map_type, radius=np.float32(radius),
              scale=np.float32(scale), seed=seed)


def test_umatrix_matches_naive():
    rng = np.random.default_rng(11)
    rows = cols = 6
    n, d, k = 64, 8, 8
    codebook = rng.standard_normal((n, d)).astype(np.float32)
    valid = np.zeros(n, np.float32)
    valid[:rows * cols] = 1.0

    # 8-neighborhood on a square planar grid.
    idx = np.zeros((n, k), np.int32)
    msk = np.zeros((n, k), np.float32)
    for r in range(rows):
        for c in range(cols):
            j = r * cols + c
            t = 0
            for dr in (-1, 0, 1):
                for dc in (-1, 0, 1):
                    if dr == 0 and dc == 0:
                        continue
                    rr, cc = r + dr, c + dc
                    if 0 <= rr < rows and 0 <= cc < cols:
                        idx[j, t] = rr * cols + cc
                        msk[j, t] = 1.0
                        t += 1

    u = model.umatrix_step(jnp.asarray(codebook), jnp.asarray(idx),
                           jnp.asarray(msk), jnp.asarray(valid))
    u = np.asarray(u)

    for j in range(rows * cols):
        nb = [idx[j, t] for t in range(k) if msk[j, t] > 0]
        want = np.mean([np.linalg.norm(codebook[i] - codebook[j])
                        for i in nb])
        np.testing.assert_allclose(u[j], want, rtol=1e-4)
    assert np.abs(u[rows * cols:]).max() == 0.0
