//! Internal profiling helper (not a figure bench): runs many dense
//! epochs so `perf record` gets a clean profile of the hot path.
use somoclu::kernels::dense_cpu::DenseCpuKernel;
use somoclu::kernels::{DataShard, TrainingKernel};
use somoclu::som::{Codebook, Grid, GridType, MapType, Neighborhood};
use somoclu::util::rng::Rng;

fn main() {
    let (rows, dims, side) = (2048usize, 256usize, 20usize);
    let grid = Grid::new(side, side, GridType::Square, MapType::Planar);
    let mut rng = Rng::new(0xabc);
    let cb = Codebook::random_init(grid.node_count(), dims, &mut rng);
    let data = somoclu::data::random_dense(rows, dims, &mut rng);
    let mut k = DenseCpuKernel::new(1);
    let shard = DataShard::Dense { data: &data, dim: dims };
    let t0 = std::time::Instant::now();
    for _ in 0..30 {
        std::hint::black_box(
            k.epoch_accumulate(shard, &cb, &grid, Neighborhood::gaussian(false), 5.0, 1.0)
                .unwrap(),
        );
    }
    println!("30 epochs in {:?}", t0.elapsed());
}
