//! PROFILE — per-phase epoch timing and the stencil-speedup gate
//! (ISSUE 5). Splits one dense epoch into its three phases and times
//! each on a large emergent map:
//!
//!   * BMU search        — `DenseCpuKernel::project` (the pure search)
//!   * Phase A (bucket)  — counting-sort grouping + per-BMU sums
//!   * Phase B (spread)  — neighborhood-weighted accumulation, measured
//!                         under BOTH `SweepMode::FullSweep` (the
//!                         pre-stencil dense sweep) and `SweepMode::Auto`
//!                         (the windowed stencil gather)
//!
//! The headline number is `phase_b_speedup = full / stencil` at a small
//! radius — a machine-independent ratio (same map, same data, same
//! machine, two algorithms), which is what the CI gate checks.
//!
//! Modes (mirroring benches/stream_memory.rs):
//!
//! * `--quick`       CI-friendly sizes (128x128 map — the ISSUE's
//!                   acceptance geometry — with fewer rows/dims)
//! * `--json PATH`   write the phase table as JSON (BENCH_epoch.json)
//! * `--check PATH`  regression gate: fail if the small-radius Phase B
//!                   speedup falls below the baseline's
//!                   `min_phase_b_speedup`; a null baseline passes
//!                   (bootstrap). `--json` and `--check` may share the
//!                   path — the baseline is read before the write.
//!
//! The bench also asserts Phase B bit-identity (num/den) between the
//! two sweep modes on every lane, so a CI perf run doubles as an
//! equivalence check under release codegen.

use somoclu::kernels::dense_cpu::{accumulate_node_parallel_ext, DenseCpuKernel};
use somoclu::kernels::{AccumConfig, DataShard, SweepMode, TrainingKernel};
use somoclu::som::{Codebook, Grid, GridType, MapType, Neighborhood};
use somoclu::util::json::Json;
use somoclu::util::rng::Rng;
use somoclu::util::threadpool;
use somoclu::util::timer::best_secs;

struct Lane {
    radius: f32,
    phase_a: f64,
    phase_b_full: f64,
    phase_b_stencil: f64,
    window_cells: usize,
    active_bmus: usize,
    stencil_used: bool,
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let quick = args.iter().any(|a| a == "--quick");
    let arg_value = |flag: &str| {
        args.iter()
            .position(|a| a == flag)
            .and_then(|i| args.get(i + 1))
            .cloned()
    };
    let json_path = arg_value("--json");
    let check_path = arg_value("--check");
    // Read the baseline BEFORE any write so --json/--check can share a path.
    let baseline = check_path.as_ref().map(|p| {
        std::fs::read_to_string(p).unwrap_or_else(|e| panic!("--check {p}: {e}"))
    });
    // The committed floor is carried forward into the artifact we write:
    // committing a CI artifact verbatim over the baseline (the
    // documented refresh workflow) must not silently disable the gate.
    let baseline_floor = baseline
        .as_ref()
        .and_then(|text| Json::parse(text).ok())
        .and_then(|json| json.get("min_phase_b_speedup").and_then(|v| v.as_f64()));

    let side = 128usize; // the ISSUE 5 acceptance geometry
    let (rows, dim) = if quick { (4096, 32) } else { (16384, 128) };
    let reps = if quick { 3 } else { 1 };
    let threads = threadpool::default_threads();
    let nb = Neighborhood::gaussian(true);
    let grid = Grid::new(side, side, GridType::Square, MapType::Planar);
    let mut rng = Rng::new(0xE70C4);
    let cb = Codebook::random_init(grid.node_count(), dim, &mut rng);
    let data: Vec<f32> = (0..rows * dim).map(|_| rng.normal_f32()).collect();
    let shard = DataShard::Dense { data: &data, dim };

    println!(
        "PROFILE: {side}x{side} map, {rows} rows x {dim} dims, {threads} threads{}",
        if quick { "  [--quick]" } else { "" }
    );

    // --- BMU search (radius-independent).
    let mut kernel = DenseCpuKernel::new(threads);
    kernel.epoch_begin(&cb).unwrap();
    let (bmus, t_search) = best_secs(reps, || {
        kernel.project(shard, &cb, &grid, nb).unwrap()
    });
    println!("\nBMU search: {t_search:.3}s ({:.0} rows/s)", rows as f64 / t_search);

    println!(
        "\n{:>7} {:>11} {:>14} {:>16} {:>9} {:>8} {:>8}",
        "radius", "phase A", "phase B full", "phase B stencil", "speedup", "window", "active"
    );

    let add_row = |num_row: &mut [f32], r: usize, h: f32| {
        let x = &data[r * dim..(r + 1) * dim];
        for (acc, v) in num_row.iter_mut().zip(x) {
            *acc += h * v;
        }
    };
    let run = |radius: f32, mode: SweepMode| {
        accumulate_node_parallel_ext(
            &AccumConfig {
                rows,
                nodes: grid.node_count(),
                dim,
                threads,
                grid: &grid,
                neighborhood: nb,
                radius,
                scale: 0.6,
                mode,
            },
            &bmus,
            add_row,
        )
    };

    // Per-mode measurement keeping the BEST per-phase timer across reps
    // (phase A is common to both modes; the gated ratio is phase B vs
    // phase B, so the phase timers — not whole-call wall clock — are
    // what gets compared).
    let measure = |radius: f32, mode: SweepMode| {
        let mut best_a = f64::INFINITY;
        let mut best_b = f64::INFINITY;
        let mut out = None;
        for _ in 0..reps {
            let (num, den, stats) = run(radius, mode);
            best_a = best_a.min(stats.phase_a.as_secs_f64());
            best_b = best_b.min(stats.phase_b.as_secs_f64());
            out = Some((num, den, stats));
        }
        let (num, den, stats) = out.expect("reps >= 1");
        (num, den, stats, best_a, best_b)
    };

    let mut lanes = Vec::new();
    for radius in [1.0f32, 4.0, 16.0] {
        let (f_num, f_den, _f_stats, fa, fb) = measure(radius, SweepMode::FullSweep);
        let (s_num, s_den, s_stats, sa, sb) = measure(radius, SweepMode::Auto);
        // Equivalence under release codegen, every CI perf run — BIT
        // equality (plain == would let a -0.0/+0.0 divergence slip by).
        let bits_eq = |a: &[f32], b: &[f32]| {
            a.len() == b.len()
                && a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits())
        };
        assert!(bits_eq(&f_num, &s_num), "r={radius}: stencil num diverged");
        assert!(bits_eq(&f_den, &s_den), "r={radius}: stencil den diverged");
        let lane = Lane {
            radius,
            phase_a: fa.min(sa),
            phase_b_full: fb,
            phase_b_stencil: sb,
            window_cells: s_stats.window_cells,
            active_bmus: s_stats.active_bmus,
            stencil_used: s_stats.stencil,
        };
        println!(
            "{:>7} {:>10.3}s {:>13.3}s {:>15.3}s {:>8.2}x {:>8} {:>8}",
            lane.radius,
            lane.phase_a,
            lane.phase_b_full,
            lane.phase_b_stencil,
            lane.phase_b_full / lane.phase_b_stencil,
            lane.window_cells,
            lane.active_bmus,
        );
        lanes.push(lane);
    }

    let gate_lane = lanes
        .iter()
        .find(|l| l.radius == 4.0)
        .expect("r=4 lane exists");
    assert!(
        gate_lane.stencil_used,
        "r=4 on a 128x128 map must take the stencil path"
    );
    let speedup = gate_lane.phase_b_full / gate_lane.phase_b_stencil;
    println!(
        "\nphase B speedup at r=4 (stencil vs full sweep): {speedup:.2}x \
         (ISSUE 5 target ≥ 5x; timings include table construction)"
    );

    if let Some(path) = &json_path {
        let json = render_json(quick, side, rows, dim, t_search, &lanes, speedup, baseline_floor);
        std::fs::write(path, &json).unwrap_or_else(|e| panic!("--json {path}: {e}"));
        println!("wrote {path}");
    }
    if let Some(text) = baseline {
        match check_gate(&text, speedup) {
            Ok(msg) => println!("stencil gate: {msg}"),
            Err(msg) => {
                eprintln!("stencil gate FAILED: {msg}");
                std::process::exit(1);
            }
        }
    }
}

/// Hand-rendered JSON (no serde in the tree; fixed ASCII keys + finite
/// numbers, same approach as stream_memory.rs). `floor` is the
/// baseline's `min_phase_b_speedup`, carried forward verbatim so the
/// artifact can be committed over the baseline without un-arming the
/// gate.
#[allow(clippy::too_many_arguments)]
fn render_json(
    quick: bool,
    side: usize,
    rows: usize,
    dim: usize,
    bmu_search: f64,
    lanes: &[Lane],
    gate_speedup: f64,
    floor: Option<f64>,
) -> String {
    let lane_objs: Vec<String> = lanes
        .iter()
        .map(|l| {
            format!(
                "    {{\"radius\": {:.1}, \"phase_a\": {:.4}, \"phase_b_full\": {:.4}, \
                 \"phase_b_stencil\": {:.4}, \"speedup\": {:.3}, \"window_cells\": {}, \
                 \"active_bmus\": {}, \"stencil_used\": {}}}",
                l.radius,
                l.phase_a,
                l.phase_b_full,
                l.phase_b_stencil,
                l.phase_b_full / l.phase_b_stencil,
                l.window_cells,
                l.active_bmus,
                l.stencil_used,
            )
        })
        .collect();
    let floor_str = match floor {
        Some(f) if f.is_finite() => format!("{f:.3}"),
        _ => "null".to_string(),
    };
    format!(
        "{{\n  \"schema\": \"somoclu-epoch-bench/v1\",\n  \"quick\": {quick},\n  \
         \"map\": \"{side}x{side} square planar\",\n  \"rows\": {rows},\n  \
         \"dim\": {dim},\n  \"bmu_search_secs\": {bmu_search:.4},\n  \
         \"lanes\": [\n{}\n  ],\n  \
         \"phase_b_speedup_r4\": {gate_speedup:.3},\n  \
         \"min_phase_b_speedup\": {floor_str}\n}}\n",
        lane_objs.join(",\n"),
    )
}

/// The CI gate: the r=4 Phase B speedup (stencil vs full sweep) must
/// not fall below the committed baseline's `min_phase_b_speedup`. A
/// dimensionless algorithm-vs-algorithm ratio on identical inputs, so
/// shared runners don't flake it; a baseline without the number passes
/// (bootstrap state).
fn check_gate(baseline_text: &str, speedup: f64) -> Result<String, String> {
    let json = Json::parse(baseline_text)
        .map_err(|e| format!("baseline is not valid JSON: {e:?}"))?;
    match json.get("min_phase_b_speedup").and_then(|v| v.as_f64()) {
        None => Ok("baseline has no speedup floor (bootstrap run) - gate passes".into()),
        Some(floor) => {
            if speedup < floor {
                Err(format!(
                    "phase B stencil speedup {speedup:.2}x fell below the \
                     baseline floor {floor:.2}x"
                ))
            } else {
                Ok(format!("speedup {speedup:.2}x above the floor {floor:.2}x"))
            }
        }
    }
}
