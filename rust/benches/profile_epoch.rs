//! PROFILE — per-phase epoch timing and the stencil-speedup gate
//! (ISSUE 5). Splits one dense epoch into its three phases and times
//! each on a large emergent map:
//!
//!   * BMU search        — `DenseCpuKernel::project` (the pure search)
//!   * Phase A (bucket)  — counting-sort grouping + per-BMU sums
//!   * Phase B (spread)  — neighborhood-weighted accumulation, measured
//!                         under BOTH `SweepMode::FullSweep` (the
//!                         pre-stencil dense sweep) and `SweepMode::Auto`
//!                         (the windowed stencil gather)
//!
//! The headline numbers are machine-independent ratios (same map, same
//! data, same machine, two algorithms), which is what the CI gates
//! check:
//!
//!   * `phase_b_speedup = full / stencil` at a small radius (ISSUE 5)
//!   * `bmu_speedup = naive / blocked` — the cache-blocked, dispatched
//!     BMU microkernel vs a naive per-row scalar scan (ISSUE 6). The
//!     BMU search is radius-independent, so one ratio covers every lane.
//!
//! Modes (mirroring benches/stream_memory.rs):
//!
//! * `--quick`       CI-friendly sizes (128x128 map — the ISSUE's
//!                   acceptance geometry — with fewer rows/dims)
//! * `--json PATH`   write the phase table as JSON (BENCH_epoch.json)
//! * `--check PATH`  regression gate: fail if the small-radius Phase B
//!                   speedup falls below the baseline's
//!                   `min_phase_b_speedup`, or the BMU speedup below
//!                   `min_bmu_speedup`; a null baseline passes
//!                   (bootstrap). `--json` and `--check` may share the
//!                   path — the baseline is read before the write.
//!
//! The bench also asserts, under release codegen on every CI perf run:
//! Phase B bit-identity (num/den) between the two sweep modes on every
//! lane; BMU/distance bit-identity between the panel-tiled and flat
//! (panel = N) blocked search; and BMU/distance bit-identity between
//! the naive scalar reference and the blocked search in Scalar kind.

use somoclu::kernels::dense_cpu::{
    accumulate_node_parallel_ext, dot_unrolled, search_bmus_blocked, DenseCpuKernel,
};
use somoclu::kernels::simd::{self, SimdKind};
use somoclu::kernels::{AccumConfig, DataShard, SweepMode, TrainingKernel};
use somoclu::som::{Codebook, Grid, GridType, MapType, Neighborhood};
use somoclu::util::json::Json;
use somoclu::util::rng::Rng;
use somoclu::util::threadpool;
use somoclu::util::timer::best_secs;

struct Lane {
    radius: f32,
    phase_a: f64,
    phase_b_full: f64,
    phase_b_stencil: f64,
    window_cells: usize,
    active_bmus: usize,
    stencil_used: bool,
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let quick = args.iter().any(|a| a == "--quick");
    let arg_value = |flag: &str| {
        args.iter()
            .position(|a| a == flag)
            .and_then(|i| args.get(i + 1))
            .cloned()
    };
    let json_path = arg_value("--json");
    let check_path = arg_value("--check");
    // Read the baseline BEFORE any write so --json/--check can share a path.
    let baseline = check_path.as_ref().map(|p| {
        std::fs::read_to_string(p).unwrap_or_else(|e| panic!("--check {p}: {e}"))
    });
    // The committed floor is carried forward into the artifact we write:
    // committing a CI artifact verbatim over the baseline (the
    // documented refresh workflow) must not silently disable the gate.
    let baseline_json = baseline.as_ref().and_then(|text| Json::parse(text).ok());
    let baseline_floor = baseline_json
        .as_ref()
        .and_then(|json| json.get("min_phase_b_speedup").and_then(|v| v.as_f64()));
    let baseline_bmu_floor = baseline_json
        .as_ref()
        .and_then(|json| json.get("min_bmu_speedup").and_then(|v| v.as_f64()));

    let side = 128usize; // the ISSUE 5 acceptance geometry
    let (rows, dim) = if quick { (4096, 32) } else { (16384, 128) };
    let reps = if quick { 3 } else { 1 };
    let threads = threadpool::default_threads();
    let nb = Neighborhood::gaussian(true);
    let grid = Grid::new(side, side, GridType::Square, MapType::Planar);
    let mut rng = Rng::new(0xE70C4);
    let cb = Codebook::random_init(grid.node_count(), dim, &mut rng);
    let data: Vec<f32> = (0..rows * dim).map(|_| rng.normal_f32()).collect();
    let shard = DataShard::Dense { data: &data, dim };

    println!(
        "PROFILE: {side}x{side} map, {rows} rows x {dim} dims, {threads} threads{}",
        if quick { "  [--quick]" } else { "" }
    );

    // --- BMU search (radius-independent).
    let mut kernel = DenseCpuKernel::new(threads);
    kernel.epoch_begin(&cb).unwrap();
    let (bmus, t_search) = best_secs(reps, || {
        kernel.project(shard, &cb, &grid, nb).unwrap()
    });
    println!("\nBMU search: {t_search:.3}s ({:.0} rows/s)", rows as f64 / t_search);

    // --- BMU microkernel lanes (ISSUE 6): naive per-row scalar scan vs
    // the cache-blocked dispatched search, same threads, same data —
    // algorithm vs algorithm, so the ratio is machine-independent.
    let kind = simd::dispatch();
    let w2 = cb.sq_norms();
    let naive_search = || -> (Vec<u32>, Vec<f32>) {
        let parts = threadpool::parallel_ranges(rows, threads, |_, range| {
            let mut bmus = Vec::with_capacity(range.len());
            let mut dists = Vec::with_capacity(range.len());
            for r in range {
                let x = &data[r * dim..(r + 1) * dim];
                let x2: f32 = x.iter().map(|v| v * v).sum();
                let (mut best, mut best_score) = (0u32, f32::INFINITY);
                for n in 0..cb.nodes {
                    let s = 0.5 * w2[n] - dot_unrolled(x, cb.row(n));
                    if s < best_score {
                        best_score = s;
                        best = n as u32;
                    }
                }
                bmus.push(best);
                dists.push((x2 + 2.0 * best_score).max(0.0));
            }
            (bmus, dists)
        });
        let mut b = Vec::with_capacity(rows);
        let mut d = Vec::with_capacity(rows);
        for (pb, pd) in parts {
            b.extend(pb);
            d.extend(pd);
        }
        (b, d)
    };
    let panel = simd::default_panel_nodes(dim);
    let (naive_out, t_bmu_naive) = best_secs(reps, naive_search);
    let (blocked_out, t_bmu_blocked) = best_secs(reps, || {
        search_bmus_blocked(&data, dim, &cb, &w2, threads, kind, panel)
    });
    let (nopanel_out, t_bmu_nopanel) = best_secs(reps, || {
        search_bmus_blocked(&data, dim, &cb, &w2, threads, kind, cb.nodes)
    });
    // Exact-BMU contract under release codegen, every CI perf run.
    let search_bits_eq = |a: &(Vec<u32>, Vec<f32>), b: &(Vec<u32>, Vec<f32>), what: &str| {
        assert_eq!(a.0, b.0, "{what}: BMUs diverged");
        assert!(
            a.1.iter().zip(&b.1).all(|(x, y)| x.to_bits() == y.to_bits()),
            "{what}: distance bits diverged"
        );
    };
    search_bits_eq(&blocked_out, &nopanel_out, "panel vs flat blocked search");
    let scalar_blocked = if kind == SimdKind::Scalar {
        blocked_out.clone()
    } else {
        search_bmus_blocked(&data, dim, &cb, &w2, threads, SimdKind::Scalar, panel)
    };
    search_bits_eq(&naive_out, &scalar_blocked, "naive scalar vs blocked scalar");
    drop((naive_out, blocked_out, nopanel_out, scalar_blocked));
    let bmu_speedup = t_bmu_naive / t_bmu_blocked;
    let bmu_panel_speedup = t_bmu_nopanel / t_bmu_blocked;
    println!(
        "BMU microkernel [{}]: naive {t_bmu_naive:.3}s, blocked {t_bmu_blocked:.3}s \
         ({bmu_speedup:.2}x; panel tiling alone {bmu_panel_speedup:.2}x over flat, \
         panel = {panel} nodes)",
        simd::kernel_name(kind)
    );

    println!(
        "\n{:>7} {:>11} {:>14} {:>16} {:>9} {:>8} {:>8}",
        "radius", "phase A", "phase B full", "phase B stencil", "speedup", "window", "active"
    );

    let add_row = |num_row: &mut [f32], r: usize, h: f32| {
        let x = &data[r * dim..(r + 1) * dim];
        for (acc, v) in num_row.iter_mut().zip(x) {
            *acc += h * v;
        }
    };
    let run = |radius: f32, mode: SweepMode| {
        accumulate_node_parallel_ext(
            &AccumConfig {
                rows,
                nodes: grid.node_count(),
                dim,
                threads,
                grid: &grid,
                neighborhood: nb,
                radius,
                scale: 0.6,
                mode,
            },
            &bmus,
            add_row,
        )
    };

    // Per-mode measurement keeping the BEST per-phase timer across reps
    // (phase A is common to both modes; the gated ratio is phase B vs
    // phase B, so the phase timers — not whole-call wall clock — are
    // what gets compared).
    let measure = |radius: f32, mode: SweepMode| {
        let mut best_a = f64::INFINITY;
        let mut best_b = f64::INFINITY;
        let mut out = None;
        for _ in 0..reps {
            let (num, den, stats) = run(radius, mode);
            best_a = best_a.min(stats.phase_a.as_secs_f64());
            best_b = best_b.min(stats.phase_b.as_secs_f64());
            out = Some((num, den, stats));
        }
        let (num, den, stats) = out.expect("reps >= 1");
        (num, den, stats, best_a, best_b)
    };

    let mut lanes = Vec::new();
    for radius in [1.0f32, 4.0, 16.0] {
        let (f_num, f_den, _f_stats, fa, fb) = measure(radius, SweepMode::FullSweep);
        let (s_num, s_den, s_stats, sa, sb) = measure(radius, SweepMode::Auto);
        // Equivalence under release codegen, every CI perf run — BIT
        // equality (plain == would let a -0.0/+0.0 divergence slip by).
        let bits_eq = |a: &[f32], b: &[f32]| {
            a.len() == b.len()
                && a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits())
        };
        assert!(bits_eq(&f_num, &s_num), "r={radius}: stencil num diverged");
        assert!(bits_eq(&f_den, &s_den), "r={radius}: stencil den diverged");
        let lane = Lane {
            radius,
            phase_a: fa.min(sa),
            phase_b_full: fb,
            phase_b_stencil: sb,
            window_cells: s_stats.window_cells,
            active_bmus: s_stats.active_bmus,
            stencil_used: s_stats.stencil,
        };
        println!(
            "{:>7} {:>10.3}s {:>13.3}s {:>15.3}s {:>8.2}x {:>8} {:>8}",
            lane.radius,
            lane.phase_a,
            lane.phase_b_full,
            lane.phase_b_stencil,
            lane.phase_b_full / lane.phase_b_stencil,
            lane.window_cells,
            lane.active_bmus,
        );
        lanes.push(lane);
    }

    let gate_lane = lanes
        .iter()
        .find(|l| l.radius == 4.0)
        .expect("r=4 lane exists");
    assert!(
        gate_lane.stencil_used,
        "r=4 on a 128x128 map must take the stencil path"
    );
    let speedup = gate_lane.phase_b_full / gate_lane.phase_b_stencil;
    println!(
        "\nphase B speedup at r=4 (stencil vs full sweep): {speedup:.2}x \
         (ISSUE 5 target ≥ 5x; timings include table construction)"
    );

    if let Some(path) = &json_path {
        let json = render_json(&RenderInputs {
            quick,
            side,
            rows,
            dim,
            bmu_search: t_search,
            bmu_kernel: simd::kernel_name(kind),
            bmu_naive: t_bmu_naive,
            bmu_blocked: t_bmu_blocked,
            bmu_speedup,
            bmu_panel_speedup,
            panel_nodes: panel,
            lanes: &lanes,
            gate_speedup: speedup,
            floor: baseline_floor,
            bmu_floor: baseline_bmu_floor,
        });
        std::fs::write(path, &json).unwrap_or_else(|e| panic!("--json {path}: {e}"));
        println!("wrote {path}");
    }
    if let Some(text) = baseline {
        match check_gate(&text, speedup, bmu_speedup) {
            Ok(msg) => println!("perf gates: {msg}"),
            Err(msg) => {
                eprintln!("perf gate FAILED: {msg}");
                std::process::exit(1);
            }
        }
    }
}

/// Everything `render_json` needs, bundled to keep the call readable.
struct RenderInputs<'a> {
    quick: bool,
    side: usize,
    rows: usize,
    dim: usize,
    bmu_search: f64,
    bmu_kernel: &'a str,
    bmu_naive: f64,
    bmu_blocked: f64,
    bmu_speedup: f64,
    bmu_panel_speedup: f64,
    panel_nodes: usize,
    lanes: &'a [Lane],
    gate_speedup: f64,
    floor: Option<f64>,
    bmu_floor: Option<f64>,
}

/// Hand-rendered JSON (no serde in the tree; fixed ASCII keys + finite
/// numbers, same approach as stream_memory.rs). `floor`/`bmu_floor` are
/// the baseline's `min_phase_b_speedup`/`min_bmu_speedup`, carried
/// forward verbatim so the artifact can be committed over the baseline
/// without un-arming either gate.
fn render_json(r: &RenderInputs<'_>) -> String {
    let lane_objs: Vec<String> = r
        .lanes
        .iter()
        .map(|l| {
            format!(
                "    {{\"radius\": {:.1}, \"phase_a\": {:.4}, \"phase_b_full\": {:.4}, \
                 \"phase_b_stencil\": {:.4}, \"speedup\": {:.3}, \"window_cells\": {}, \
                 \"active_bmus\": {}, \"stencil_used\": {}, \"bmu_speedup\": {:.3}}}",
                l.radius,
                l.phase_a,
                l.phase_b_full,
                l.phase_b_stencil,
                l.phase_b_full / l.phase_b_stencil,
                l.window_cells,
                l.active_bmus,
                l.stencil_used,
                // The BMU phase is radius-independent: every lane's
                // search sped up by the same measured ratio.
                r.bmu_speedup,
            )
        })
        .collect();
    let floor_json = |f: Option<f64>| match f {
        Some(f) if f.is_finite() => format!("{f:.3}"),
        _ => "null".to_string(),
    };
    format!(
        "{{\n  \"schema\": \"somoclu-epoch-bench/v2\",\n  \"quick\": {},\n  \
         \"map\": \"{}x{} square planar\",\n  \"rows\": {},\n  \
         \"dim\": {},\n  \"bmu_search_secs\": {:.4},\n  \
         \"bmu_kernel\": \"{}\",\n  \"bmu_naive_secs\": {:.4},\n  \
         \"bmu_blocked_secs\": {:.4},\n  \"bmu_speedup\": {:.3},\n  \
         \"bmu_panel_speedup\": {:.3},\n  \"bmu_panel_nodes\": {},\n  \
         \"lanes\": [\n{}\n  ],\n  \
         \"phase_b_speedup_r4\": {:.3},\n  \
         \"min_phase_b_speedup\": {},\n  \
         \"min_bmu_speedup\": {}\n}}\n",
        r.quick,
        r.side,
        r.side,
        r.rows,
        r.dim,
        r.bmu_search,
        r.bmu_kernel,
        r.bmu_naive,
        r.bmu_blocked,
        r.bmu_speedup,
        r.bmu_panel_speedup,
        r.panel_nodes,
        lane_objs.join(",\n"),
        r.gate_speedup,
        floor_json(r.floor),
        floor_json(r.bmu_floor),
    )
}

/// The CI gates: the r=4 Phase B speedup (stencil vs full sweep) must
/// not fall below the committed baseline's `min_phase_b_speedup`, and
/// the BMU-search speedup (blocked microkernel vs naive scalar scan)
/// not below `min_bmu_speedup`. Both are dimensionless
/// algorithm-vs-algorithm ratios on identical inputs, so shared runners
/// don't flake them; a baseline missing a number passes that gate
/// (bootstrap state).
fn check_gate(
    baseline_text: &str,
    speedup: f64,
    bmu_speedup: f64,
) -> Result<String, String> {
    let json = Json::parse(baseline_text)
        .map_err(|e| format!("baseline is not valid JSON: {e:?}"))?;
    let mut msgs = Vec::new();
    match json.get("min_phase_b_speedup").and_then(|v| v.as_f64()) {
        None => msgs.push("no phase B floor (bootstrap) - passes".to_string()),
        Some(floor) => {
            if speedup < floor {
                return Err(format!(
                    "phase B stencil speedup {speedup:.2}x fell below the \
                     baseline floor {floor:.2}x"
                ));
            }
            msgs.push(format!("phase B {speedup:.2}x >= floor {floor:.2}x"));
        }
    }
    match json.get("min_bmu_speedup").and_then(|v| v.as_f64()) {
        None => msgs.push("no BMU floor (bootstrap) - passes".to_string()),
        Some(floor) => {
            if bmu_speedup < floor {
                return Err(format!(
                    "BMU microkernel speedup {bmu_speedup:.2}x fell below the \
                     baseline floor {floor:.2}x"
                ));
            }
            msgs.push(format!("BMU {bmu_speedup:.2}x >= floor {floor:.2}x"));
        }
    }
    Ok(msgs.join("; "))
}
