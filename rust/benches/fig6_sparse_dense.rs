//! FIG6 — "Training time on a single node with dense and sparse kernels"
//! (D = 1000, 5% nonzero) + the §5.1 memory claims:
//!   * "Execution time was about two times faster with the sparse kernel."
//!   * "the sparse kernel using only twenty per cent of the memory of the
//!      dense one with 100,000 instances."
//! Plus §3.1's CLAIM-MEM50 (threads share the codebook, ranks copy it).
//!
//! Paper-size run: SOM_BENCH_SCALE=10 cargo bench --bench fig6_sparse_dense

mod common;

use somoclu::cluster::netmodel::NetModel;
use somoclu::cluster::runner::ClusterData;
use somoclu::session::Som;
use somoclu::kernels::{DataShard, KernelType};
use somoclu::sparse::Csr;
use somoclu::util::memtrack::{fmt_bytes, MemRegion};
use somoclu::util::rng::Rng;
use somoclu::util::timer::{bench_scale, time_once};

fn main() {
    let scale = bench_scale(1.0);
    common::banner("FIG6: dense vs sparse kernel (time + memory)", scale);
    let p = common::fig5_regular(scale);
    let density = 0.05;

    println!(
        "\n{:>10} {:>13} {:>13} {:>9} {:>14} {:>14} {:>8}",
        "n", "dense time", "sparse time", "speedup", "dense mem", "sparse mem", "ratio"
    );
    for &n in &p.sizes {
        let mut rng = Rng::new(n as u64 ^ 0xf16);
        let m = Csr::random(n, p.dims, density, &mut rng);
        let dense = m.to_dense();

        let dense_cfg = common::base_config(p.map_side, p.epochs, KernelType::DenseCpu);
        let sparse_cfg = common::base_config(p.map_side, p.epochs, KernelType::SparseCpu);

        let region = MemRegion::start();
        let (r1, t_dense) = time_once(|| {
            Som::builder()
                .config(dense_cfg.clone())
                .build()?
                .fit_shard(DataShard::Dense {
                    data: &dense,
                    dim: p.dims,
                })
        });
        r1.unwrap();
        // Working set = run peak + the input representation itself.
        let mem_dense = region.peak_delta() + dense.len() * 4;

        let region = MemRegion::start();
        let (r2, t_sparse) = time_once(|| {
            Som::builder()
                .config(sparse_cfg.clone())
                .build()?
                .fit_shard(DataShard::Sparse(m.view()))
        });
        r2.unwrap();
        let mem_sparse = region.peak_delta() + m.heap_bytes();

        println!(
            "{n:>10} {:>12.3}s {:>12.3}s {:>8.2}x {:>14} {:>14} {:>7.2}",
            t_dense.as_secs_f64(),
            t_sparse.as_secs_f64(),
            t_dense.as_secs_f64() / t_sparse.as_secs_f64(),
            fmt_bytes(mem_dense),
            fmt_bytes(mem_sparse),
            mem_sparse as f64 / mem_dense as f64,
        );
    }

    // CLAIM-MEM50: 2 threads sharing a codebook vs 2 ranks copying it.
    println!("\n-- §3.1 memory claim: OpenMP-style threads vs MPI-style ranks --");
    let dim = 512;
    let side = 24;
    let mut rng = Rng::new(99);
    let (d, _) = somoclu::data::gaussian_blobs(512, dim, 4, 0.3, &mut rng);
    let codebook_bytes = side * side * dim * 4;

    let mut tc = common::base_config(side, 2, KernelType::DenseCpu);
    tc.threads = 2;
    let region = MemRegion::start();
    Som::builder()
        .config(tc.clone())
        .build()
        .unwrap()
        .fit_shard(DataShard::Dense { data: &d, dim })
        .unwrap();
    let threaded = region.peak_delta();

    let mut rc = common::base_config(side, 2, KernelType::DenseCpu);
    rc.threads = 1;
    rc.ranks = 2;
    let region = MemRegion::start();
    Som::builder()
        .config(rc.clone())
        .net(NetModel::ideal())
        .build()
        .unwrap()
        .fit_cluster(ClusterData::Dense {
            data: d.clone(),
            dim,
        })
        .unwrap();
    let ranked = region.peak_delta();

    println!(
        "codebook {}; peak: 2 threads {} vs 2 ranks {} -> threads use {:.0}% \
         of the rank-path memory (paper: \"minimum fifty per cent reduction\")",
        fmt_bytes(codebook_bytes),
        fmt_bytes(threaded),
        fmt_bytes(ranked),
        100.0 * threaded as f64 / ranked as f64,
    );
}
