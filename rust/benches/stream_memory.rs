//! STREAM — out-of-core training: memory profile AND epoch throughput.
//!
//! Part 1 (memory, ISSUE 1 acceptance): with chunked streaming the peak
//! data-buffer allocation is O(chunk_rows * dim) — flat as rows grow —
//! while the in-memory path is O(rows * dim). QE and BMUs match the
//! in-memory run (asserted on the smallest size).
//!
//! Part 2 (throughput, ISSUE 2/3 acceptance): per-epoch rows/s of every
//! streaming backend — text, buffered binary, binary + prefetch, pread
//! (shared fd), mmap (zero-copy) — against fully resident training on
//! the same data. The headline number is the `vs mem` column —
//! binary-family paths must sit near the resident epoch wall-clock,
//! where text re-parsing pays multiple ×.
//!
//! CI modes (ISSUE 3):
//!
//! * `--quick`             small sizes, CI-friendly wall-clock
//! * `--json PATH`         write the throughput table + peak gauges as
//!                         JSON (the `BENCH_stream.json` trajectory)
//! * `--check PATH`        regression gate: compare this run's
//!                         binary-path slowdown (binary rows/s relative
//!                         to resident rows/s — machine-independent)
//!                         against the committed baseline; exit nonzero
//!                         if more than 25% worse. A baseline without
//!                         numbers (nulls) passes as a bootstrap run.
//!
//! `--json` and `--check` may point at the same file: the baseline is
//! read fully before the result is written.
//!
//! Paper-scale run (100k+ rows): SOM_BENCH_SCALE=10 cargo bench --bench stream_memory

mod common;

use somoclu::coordinator::config::TrainConfig;
use somoclu::coordinator::train::TrainResult;
use somoclu::data;
use somoclu::io::stream::DataSource;
use somoclu::session::Som;
use somoclu::io::binary::{convert_dense_to_binary, BinaryDenseFileSource, SharedFd};
use somoclu::io::dense;
use somoclu::io::stream::{ChunkedDenseFileSource, PrefetchSource};
use somoclu::io::MmapDenseSource;
use somoclu::kernels::{DataShard, KernelType};
use somoclu::util::json::Json;
use somoclu::util::memtrack::{self, fmt_bytes, MemRegion};
use somoclu::util::rng::Rng;
use somoclu::util::timer::{bench_scale, best_secs, time_once};

/// Out-of-core training through the session API (the surface the CLI
/// and library users drive).
fn fit_source(cfg: &TrainConfig, source: &mut dyn DataSource) -> TrainResult {
    Som::builder()
        .config(cfg.clone())
        .build()
        .unwrap()
        .fit_source(source)
        .unwrap()
}

/// One backend's throughput measurement.
struct Lane {
    key: &'static str,
    rows_per_s: f64,
    slowdown: f64,
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let quick = args.iter().any(|a| a == "--quick");
    let arg_value = |flag: &str| {
        args.iter()
            .position(|a| a == flag)
            .and_then(|i| args.get(i + 1))
            .cloned()
    };
    let json_path = arg_value("--json");
    let check_path = arg_value("--check");
    // Read the baseline BEFORE any write so --json and --check can name
    // the same file.
    let baseline = check_path.as_ref().map(|p| {
        std::fs::read_to_string(p)
            .unwrap_or_else(|e| panic!("--check {p}: {e}"))
    });

    let scale = bench_scale(1.0);
    common::banner("STREAM: out-of-core chunked training memory + throughput", scale);

    let dim = 32;
    let chunk_rows = if quick { 256 } else { 1000 };
    let base: &[usize] = if quick {
        &[2_000, 4_000]
    } else {
        &[10_000, 20_000, 40_000]
    };
    let sizes: Vec<usize> = base
        .iter()
        .map(|&s| ((s as f64 * scale) as usize).max(1_000))
        .collect();
    let epochs_p1 = if quick { 2 } else { 3 };
    let cfg = common::base_config(12, epochs_p1, KernelType::DenseCpu);

    let dir = std::env::temp_dir().join(format!("somoclu_bench_stream_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();

    println!(
        "\nchunk window: {chunk_rows} rows x {dim} dims = {}{}\n",
        fmt_bytes(chunk_rows * dim * 4),
        if quick { "  [--quick]" } else { "" }
    );
    println!(
        "{:>10} {:>12} {:>14} {:>14} {:>14} {:>10}",
        "n", "stream time", "stream databuf", "stream peak", "in-mem peak", "QE match"
    );

    let mut first_checked = false;
    for &n in &sizes {
        let mut rng = Rng::new(n as u64 ^ 0x57_52);
        let path = dir.join(format!("stream_{n}.txt"));
        {
            let rows_data = data::random_dense(n, dim, &mut rng);
            dense::write_dense(&path, n, dim, &rows_data, false).unwrap();
            // rows_data dropped here: the streaming run must not depend
            // on the generator's resident copy.
        }

        // Streamed, bounded-window run.
        memtrack::reset_data_buffer_peak();
        let region = MemRegion::start();
        let (stream_res, t_stream) = time_once(|| {
            let mut src = ChunkedDenseFileSource::open(&path, chunk_rows).unwrap();
            fit_source(&cfg, &mut src)
        });
        let stream_peak = region.peak_delta();
        let stream_databuf = memtrack::data_buffer_peak();

        // In-memory reference run (also provides the QE cross-check).
        let m = dense::read_dense(&path).unwrap();
        let region = MemRegion::start();
        let mem_res = Som::builder()
            .config(cfg.clone())
            .build()
            .unwrap()
            .fit_shard(DataShard::Dense {
                data: &m.data,
                dim: m.cols,
            })
            .unwrap();
        let mem_peak = region.peak_delta() + m.data.len() * 4;

        let qe_match = (stream_res.final_qe() - mem_res.final_qe()).abs() < 1e-4
            && stream_res.bmus == mem_res.bmus;
        if !first_checked {
            assert!(qe_match, "streamed run diverged from in-memory run");
            first_checked = true;
        }

        println!(
            "{n:>10} {:>11.3}s {:>14} {:>14} {:>14} {:>10}",
            t_stream.as_secs_f64(),
            fmt_bytes(stream_databuf),
            fmt_bytes(stream_peak),
            fmt_bytes(mem_peak),
            if qe_match { "yes" } else { "NO" },
        );
        std::fs::remove_file(&path).ok();
    }

    println!(
        "\nexpected shape: 'stream databuf' flat across n (the window), \
         'in-mem peak' growing ~linearly with n."
    );

    // ------------------------------------------------------------------
    // Part 2: epoch throughput — every streaming backend vs resident.
    // ------------------------------------------------------------------
    let n = *sizes.last().unwrap();
    let epochs = if quick { 2usize } else { 3 };
    let tcfg = TrainConfig {
        epochs,
        ..common::base_config(12, epochs, KernelType::DenseCpu)
    };
    let txt = dir.join("tp.txt");
    {
        let mut rng = Rng::new(0x7470);
        let d = data::random_dense(n, dim, &mut rng);
        dense::write_dense(&txt, n, dim, &d, false).unwrap();
    }
    let bin = dir.join("tp.somb");
    {
        let mut src = ChunkedDenseFileSource::open(&txt, chunk_rows).unwrap();
        convert_dense_to_binary(&mut src, &bin).unwrap();
    }

    println!(
        "\nthroughput: {n} rows x {dim} dims, {epochs} epochs, \
         {chunk_rows}-row chunks\n"
    );
    println!(
        "{:<22} {:>12} {:>14} {:>8}",
        "input path", "epoch time", "rows/s", "vs mem"
    );

    // In --quick (CI gate) mode every lane is measured three times and
    // the minimum is kept, so the gated ratio reflects code, not a
    // shared runner's scheduler noise.
    let reps = if quick { 3 } else { 1 };

    // Resident baseline.
    let m = dense::read_dense(&txt).unwrap();
    let (mem_res, best_mem) = best_secs(reps, || {
        Som::builder()
            .config(tcfg.clone())
            .build()
            .unwrap()
            .fit_shard(DataShard::Dense {
                data: &m.data,
                dim: m.cols,
            })
            .unwrap()
    });
    drop(m);
    let per_epoch_mem = best_mem / epochs as f64;
    println!(
        "{:<22} {:>11.3}s {:>14.0} {:>7.2}x",
        "resident (baseline)",
        per_epoch_mem,
        n as f64 / per_epoch_mem,
        1.0
    );

    let mut lanes: Vec<Lane> = vec![Lane {
        key: "resident",
        rows_per_s: n as f64 / per_epoch_mem,
        slowdown: 1.0,
    }];
    let lane = |key: &'static str,
                    label: &str,
                    secs: f64,
                    bmus: &[u32],
                    lanes: &mut Vec<Lane>| {
        assert_eq!(bmus, &mem_res.bmus[..], "{label}: BMUs diverged from resident run");
        let per_epoch = secs / epochs as f64;
        let slowdown = per_epoch / per_epoch_mem;
        println!(
            "{label:<22} {per_epoch:>11.3}s {:>14.0} {slowdown:>7.2}x",
            n as f64 / per_epoch,
        );
        lanes.push(Lane {
            key,
            rows_per_s: n as f64 / per_epoch,
            slowdown,
        });
    };

    // Sources open OUTSIDE the timed region, like read_dense for the
    // resident baseline: every row then measures pure epoch wall-clock
    // (the text open's validation parse would otherwise inflate its
    // per-epoch number by a third extra parse).
    let mut src = ChunkedDenseFileSource::open(&txt, chunk_rows).unwrap();
    let (res, t) = best_secs(reps, || fit_source(&tcfg, &mut src));
    drop(src);
    lane("text", "text stream", t, &res.bmus, &mut lanes);

    memtrack::reset_data_buffer_peak();
    let mut src = BinaryDenseFileSource::open(&bin, chunk_rows).unwrap();
    let (res, t) = best_secs(reps, || fit_source(&tcfg, &mut src));
    drop(src);
    let peak_databuf = memtrack::data_buffer_peak();
    lane("binary", "binary stream", t, &res.bmus, &mut lanes);

    let mut src =
        PrefetchSource::new(BinaryDenseFileSource::open(&bin, chunk_rows).unwrap());
    let (res, t) = best_secs(reps, || fit_source(&tcfg, &mut src));
    drop(src);
    lane("binary_prefetch", "binary + prefetch", t, &res.bmus, &mut lanes);

    let mut src = SharedFd::open(&bin)
        .unwrap()
        .dense_shard(chunk_rows, 0, 1)
        .unwrap();
    let (res, t) = best_secs(reps, || fit_source(&tcfg, &mut src));
    drop(src);
    lane("pread", "pread (shared fd)", t, &res.bmus, &mut lanes);

    let mut peak_mapped = 0usize;
    if somoclu::io::mmap::SUPPORTED {
        memtrack::reset_data_map_peak();
        let mut src = MmapDenseSource::open(&bin, chunk_rows).unwrap();
        let (res, t) = best_secs(reps, || fit_source(&tcfg, &mut src));
        drop(src);
        peak_mapped = memtrack::data_map_peak();
        lane("mmap", "mmap (zero-copy)", t, &res.bmus, &mut lanes);
    } else {
        println!("{:<22} {:>12}", "mmap (zero-copy)", "unavailable");
    }

    let slowdown_of = |key: &str| lanes.iter().find(|l| l.key == key).map(|l| l.slowdown);
    println!(
        "\nacceptance: binary+prefetch / resident = {:.2}x (target ≤ ~1.1x; \
         text pays the re-parse penalty above)",
        slowdown_of("binary_prefetch").unwrap()
    );
    println!(
        "peak data-buffer gauge (binary run): {}; peak mapped chunk views \
         (mmap run): {}",
        fmt_bytes(peak_databuf),
        fmt_bytes(peak_mapped)
    );

    if let Some(path) = &json_path {
        let json = render_json(quick, n, dim, chunk_rows, epochs, &lanes, peak_databuf, peak_mapped);
        std::fs::write(path, &json).unwrap_or_else(|e| panic!("--json {path}: {e}"));
        println!("wrote {path}");
    }

    std::fs::remove_file(&txt).ok();
    std::fs::remove_file(&bin).ok();

    if let Some(text) = baseline {
        match check_regression(&text, &lanes) {
            Ok(msg) => println!("regression gate: {msg}"),
            Err(msg) => {
                eprintln!("regression gate FAILED: {msg}");
                std::process::exit(1);
            }
        }
    }
}

/// Serialize the run (no serde in the tree; fields are fixed ASCII keys
/// and finite numbers, so hand-rendering is safe).
#[allow(clippy::too_many_arguments)]
fn render_json(
    quick: bool,
    rows: usize,
    dim: usize,
    chunk_rows: usize,
    epochs: usize,
    lanes: &[Lane],
    peak_databuf: usize,
    peak_mapped: usize,
) -> String {
    let num = |v: Option<f64>| match v {
        Some(x) if x.is_finite() => format!("{x:.3}"),
        _ => "null".to_string(),
    };
    let get = |key: &str| lanes.iter().find(|l| l.key == key);
    let keys = ["resident", "text", "binary", "binary_prefetch", "pread", "mmap"];
    let rps: Vec<String> = keys
        .iter()
        .map(|k| format!("    \"{k}\": {}", num(get(k).map(|l| l.rows_per_s))))
        .collect();
    let slow: Vec<String> = keys
        .iter()
        .skip(1) // resident is the 1.0 reference
        .map(|k| format!("    \"{k}\": {}", num(get(k).map(|l| l.slowdown))))
        .collect();
    format!(
        "{{\n  \"schema\": \"somoclu-stream-bench/v1\",\n  \"quick\": {quick},\n  \
         \"rows\": {rows},\n  \"dim\": {dim},\n  \"chunk_rows\": {chunk_rows},\n  \
         \"epochs\": {epochs},\n  \"rows_per_s\": {{\n{}\n  }},\n  \
         \"slowdown_vs_resident\": {{\n{}\n  }},\n  \
         \"min_binary_rows_per_s\": null,\n  \
         \"peak_data_buffer_bytes\": {peak_databuf},\n  \
         \"peak_mapped_bytes\": {peak_mapped}\n}}\n",
        rps.join(",\n"),
        slow.join(",\n"),
    )
}

/// The CI gate. The primary metric is the binary path's *slowdown vs
/// resident* — a dimensionless ratio that transfers across runner
/// hardware, unlike raw rows/s. Optional absolute floor: a non-null
/// `min_binary_rows_per_s` in the baseline also gates raw throughput
/// (for pinned, dedicated runners). A baseline without numbers passes —
/// that is the bootstrap state of an empty bench trajectory.
fn check_regression(baseline_text: &str, lanes: &[Lane]) -> Result<String, String> {
    let json = Json::parse(baseline_text)
        .map_err(|e| format!("baseline is not valid JSON: {e:?}"))?;
    let cur = lanes
        .iter()
        .find(|l| l.key == "binary")
        .ok_or("current run has no binary lane")?;
    let base_slow = json
        .get("slowdown_vs_resident")
        .and_then(|o| o.get("binary"))
        .and_then(|v| v.as_f64());
    let mut report = Vec::new();
    match base_slow {
        None => report.push(
            "baseline has no binary slowdown number (bootstrap run) - gate passes"
                .to_string(),
        ),
        Some(b) => {
            let limit = b * 1.25;
            if cur.slowdown > limit {
                return Err(format!(
                    "binary streaming slowdown {:.2}x vs resident exceeds \
                     baseline {b:.2}x by more than 25% (limit {limit:.2}x)",
                    cur.slowdown
                ));
            }
            report.push(format!(
                "binary slowdown {:.2}x within 25% of baseline {b:.2}x",
                cur.slowdown
            ));
        }
    }
    if let Some(floor) = json.get("min_binary_rows_per_s").and_then(|v| v.as_f64()) {
        if cur.rows_per_s < floor {
            return Err(format!(
                "binary streaming {:.0} rows/s below the baseline floor {floor:.0}",
                cur.rows_per_s
            ));
        }
        report.push(format!(
            "binary {:.0} rows/s above the floor {floor:.0}",
            cur.rows_per_s
        ));
    }
    Ok(report.join("; "))
}
