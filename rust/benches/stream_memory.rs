//! STREAM — out-of-core training: memory profile AND epoch throughput.
//!
//! Part 1 (memory, ISSUE 1 acceptance): with chunked streaming the peak
//! data-buffer allocation is O(chunk_rows * dim) — flat as rows grow —
//! while the in-memory path is O(rows * dim). QE and BMUs match the
//! in-memory run (asserted on the smallest size).
//!
//! Part 2 (throughput, ISSUE 2 acceptance): per-epoch rows/s of
//! text-streamed vs binary-streamed vs binary+prefetch vs fully
//! resident training on the same data. The headline number is the
//! `vs mem` column — binary+prefetch must sit within ~1.1× of the
//! resident epoch wall-clock, where text re-parsing pays multiple ×.
//!
//! Paper-scale run (100k+ rows): SOM_BENCH_SCALE=10 cargo bench --bench stream_memory

mod common;

use somoclu::coordinator::config::TrainConfig;
use somoclu::coordinator::train::{train, train_stream};
use somoclu::data;
use somoclu::io::binary::{convert_dense_to_binary, BinaryDenseFileSource};
use somoclu::io::dense;
use somoclu::io::stream::{ChunkedDenseFileSource, DataSource, PrefetchSource};
use somoclu::kernels::{DataShard, KernelType};
use somoclu::util::memtrack::{self, fmt_bytes, MemRegion};
use somoclu::util::rng::Rng;
use somoclu::util::timer::{bench_scale, time_once};

fn main() {
    let scale = bench_scale(1.0);
    common::banner("STREAM: out-of-core chunked training memory + throughput", scale);

    let dim = 32;
    let chunk_rows = 1000;
    let base = [10_000usize, 20_000, 40_000];
    let sizes: Vec<usize> = base
        .iter()
        .map(|&s| ((s as f64 * scale) as usize).max(2_000))
        .collect();
    let cfg = common::base_config(12, 3, KernelType::DenseCpu);

    let dir = std::env::temp_dir().join(format!("somoclu_bench_stream_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();

    println!(
        "\nchunk window: {chunk_rows} rows x {dim} dims = {}\n",
        fmt_bytes(chunk_rows * dim * 4)
    );
    println!(
        "{:>10} {:>12} {:>14} {:>14} {:>14} {:>10}",
        "n", "stream time", "stream databuf", "stream peak", "in-mem peak", "QE match"
    );

    let mut first_checked = false;
    for &n in &sizes {
        let mut rng = Rng::new(n as u64 ^ 0x57_52);
        let path = dir.join(format!("stream_{n}.txt"));
        {
            let rows_data = data::random_dense(n, dim, &mut rng);
            dense::write_dense(&path, n, dim, &rows_data, false).unwrap();
            // rows_data dropped here: the streaming run must not depend
            // on the generator's resident copy.
        }

        // Streamed, bounded-window run.
        memtrack::reset_data_buffer_peak();
        let region = MemRegion::start();
        let (stream_res, t_stream) = time_once(|| {
            let mut src = ChunkedDenseFileSource::open(&path, chunk_rows).unwrap();
            train_stream(&cfg, &mut src, None, None)
        });
        let stream_res = stream_res.unwrap();
        let stream_peak = region.peak_delta();
        let stream_databuf = memtrack::data_buffer_peak();

        // In-memory reference run (also provides the QE cross-check).
        let m = dense::read_dense(&path).unwrap();
        let region = MemRegion::start();
        let mem_res = train(
            &cfg,
            DataShard::Dense {
                data: &m.data,
                dim: m.cols,
            },
            None,
            None,
        )
        .unwrap();
        let mem_peak = region.peak_delta() + m.data.len() * 4;

        let qe_match = (stream_res.final_qe() - mem_res.final_qe()).abs() < 1e-4
            && stream_res.bmus == mem_res.bmus;
        if !first_checked {
            assert!(qe_match, "streamed run diverged from in-memory run");
            first_checked = true;
        }

        println!(
            "{n:>10} {:>11.3}s {:>14} {:>14} {:>14} {:>10}",
            t_stream.as_secs_f64(),
            fmt_bytes(stream_databuf),
            fmt_bytes(stream_peak),
            fmt_bytes(mem_peak),
            if qe_match { "yes" } else { "NO" },
        );
        std::fs::remove_file(&path).ok();
    }

    println!(
        "\nexpected shape: 'stream databuf' flat across n (the window), \
         'in-mem peak' growing ~linearly with n."
    );

    // ------------------------------------------------------------------
    // Part 2: epoch throughput — text vs binary vs binary+prefetch vs
    // resident (ISSUE 2 acceptance: binary+prefetch ≤ ~1.1× resident).
    // ------------------------------------------------------------------
    let n = *sizes.last().unwrap();
    let epochs = 3usize;
    let tcfg = TrainConfig {
        epochs,
        ..common::base_config(12, epochs, KernelType::DenseCpu)
    };
    let txt = dir.join("tp.txt");
    {
        let mut rng = Rng::new(0x7470);
        let d = data::random_dense(n, dim, &mut rng);
        dense::write_dense(&txt, n, dim, &d, false).unwrap();
    }
    let bin = dir.join("tp.somb");
    {
        let mut src = ChunkedDenseFileSource::open(&txt, chunk_rows).unwrap();
        convert_dense_to_binary(&mut src, &bin).unwrap();
    }

    println!(
        "\nthroughput: {n} rows x {dim} dims, {epochs} epochs, \
         {chunk_rows}-row chunks\n"
    );
    println!(
        "{:<22} {:>12} {:>14} {:>8}",
        "input path", "epoch time", "rows/s", "vs mem"
    );

    // Resident baseline.
    let m = dense::read_dense(&txt).unwrap();
    let (mem_res, t_mem) = time_once(|| {
        train(
            &tcfg,
            DataShard::Dense {
                data: &m.data,
                dim: m.cols,
            },
            None,
            None,
        )
        .unwrap()
    });
    drop(m);
    let per_epoch_mem = t_mem.as_secs_f64() / epochs as f64;

    let report = |name: &str, t: std::time::Duration, bmus: &[u32]| {
        assert_eq!(bmus, &mem_res.bmus[..], "{name}: BMUs diverged from resident run");
        let per_epoch = t.as_secs_f64() / epochs as f64;
        println!(
            "{name:<22} {:>11.3}s {:>14.0} {:>7.2}x",
            per_epoch,
            n as f64 / per_epoch,
            per_epoch / per_epoch_mem
        );
    };
    println!(
        "{:<22} {:>11.3}s {:>14.0} {:>7.2}x",
        "resident (baseline)",
        per_epoch_mem,
        n as f64 / per_epoch_mem,
        1.0
    );

    // Sources open OUTSIDE the timed region, like read_dense for the
    // resident baseline: every row then measures pure epoch wall-clock
    // (the text open's validation parse would otherwise inflate its
    // per-epoch number by a third extra parse).
    let mut src = ChunkedDenseFileSource::open(&txt, chunk_rows).unwrap();
    let (res, t) = time_once(|| train_stream(&tcfg, &mut src, None, None).unwrap());
    drop(src);
    report("text stream", t, &res.bmus);

    let mut src = BinaryDenseFileSource::open(&bin, chunk_rows).unwrap();
    let (res, t) = time_once(|| train_stream(&tcfg, &mut src, None, None).unwrap());
    drop(src);
    report("binary stream", t, &res.bmus);

    let mut src =
        PrefetchSource::new(BinaryDenseFileSource::open(&bin, chunk_rows).unwrap());
    let (res, t) = time_once(|| train_stream(&tcfg, &mut src, None, None).unwrap());
    drop(src);
    let per_epoch_pf = t.as_secs_f64() / epochs as f64;
    report("binary + prefetch", t, &res.bmus);

    println!(
        "\nacceptance: binary+prefetch / resident = {:.2}x (target ≤ ~1.1x; \
         text pays the re-parse penalty above)",
        per_epoch_pf / per_epoch_mem
    );
    std::fs::remove_file(&txt).ok();
    std::fs::remove_file(&bin).ok();
}
