//! STREAM — out-of-core training memory profile: peak data-buffer bytes
//! and wall time as the input grows with a fixed `--chunk-rows` window.
//!
//! The claim under test (ISSUE 1 acceptance): with chunked streaming the
//! peak data-buffer allocation is O(chunk_rows * dim) — flat as rows
//! grow — while the in-memory path is O(rows * dim). QE and BMUs match
//! the in-memory run (asserted here on the smallest size).
//!
//! Paper-scale run (100k+ rows): SOM_BENCH_SCALE=10 cargo bench --bench stream_memory

mod common;

use somoclu::coordinator::config::TrainConfig;
use somoclu::coordinator::train::{train, train_stream};
use somoclu::data;
use somoclu::io::dense;
use somoclu::io::stream::ChunkedDenseFileSource;
use somoclu::kernels::{DataShard, KernelType};
use somoclu::util::memtrack::{self, fmt_bytes, MemRegion};
use somoclu::util::rng::Rng;
use somoclu::util::timer::{bench_scale, time_once};

fn main() {
    let scale = bench_scale(1.0);
    common::banner("STREAM: out-of-core chunked training memory", scale);

    let dim = 32;
    let chunk_rows = 1000;
    let base = [10_000usize, 20_000, 40_000];
    let sizes: Vec<usize> = base
        .iter()
        .map(|&s| ((s as f64 * scale) as usize).max(2_000))
        .collect();
    let cfg = common::base_config(12, 3, KernelType::DenseCpu);

    let dir = std::env::temp_dir().join(format!("somoclu_bench_stream_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();

    println!(
        "\nchunk window: {chunk_rows} rows x {dim} dims = {}\n",
        fmt_bytes(chunk_rows * dim * 4)
    );
    println!(
        "{:>10} {:>12} {:>14} {:>14} {:>14} {:>10}",
        "n", "stream time", "stream databuf", "stream peak", "in-mem peak", "QE match"
    );

    let mut first_checked = false;
    for &n in &sizes {
        let mut rng = Rng::new(n as u64 ^ 0x57_52);
        let path = dir.join(format!("stream_{n}.txt"));
        {
            let rows_data = data::random_dense(n, dim, &mut rng);
            dense::write_dense(&path, n, dim, &rows_data, false).unwrap();
            // rows_data dropped here: the streaming run must not depend
            // on the generator's resident copy.
        }

        // Streamed, bounded-window run.
        memtrack::reset_data_buffer_peak();
        let region = MemRegion::start();
        let (stream_res, t_stream) = time_once(|| {
            let mut src = ChunkedDenseFileSource::open(&path, chunk_rows).unwrap();
            train_stream(&cfg, &mut src, None, None)
        });
        let stream_res = stream_res.unwrap();
        let stream_peak = region.peak_delta();
        let stream_databuf = memtrack::data_buffer_peak();

        // In-memory reference run (also provides the QE cross-check).
        let m = dense::read_dense(&path).unwrap();
        let region = MemRegion::start();
        let mem_res = train(
            &cfg,
            DataShard::Dense {
                data: &m.data,
                dim: m.cols,
            },
            None,
            None,
        )
        .unwrap();
        let mem_peak = region.peak_delta() + m.data.len() * 4;

        let qe_match = (stream_res.final_qe() - mem_res.final_qe()).abs() < 1e-4
            && stream_res.bmus == mem_res.bmus;
        if !first_checked {
            assert!(qe_match, "streamed run diverged from in-memory run");
            first_checked = true;
        }

        println!(
            "{n:>10} {:>11.3}s {:>14} {:>14} {:>14} {:>10}",
            t_stream.as_secs_f64(),
            fmt_bytes(stream_databuf),
            fmt_bytes(stream_peak),
            fmt_bytes(mem_peak),
            if qe_match { "yes" } else { "NO" },
        );
        std::fs::remove_file(&path).ok();
    }

    println!(
        "\nexpected shape: 'stream databuf' flat across n (the window), \
         'in-mem peak' growing ~linearly with n."
    );
}
