//! FIG5 — "Training time on a single node with CPU and GPU kernels and
//! the R package kohonen" (+ the 200x200 emergent-map variant).
//!
//! Series reproduced: kohonen-like single-core online baseline, dense
//! CPU kernel, accel (XLA/PJRT = the paper's GPU column). Rows: data
//! sizes. The paper's claims to check: CPU kernel >= 10x the baseline
//! (growing with data size), accel >= CPU at large dense shards, map
//! size does not change the ordering, and the baseline cannot run
//! emergent maps at all.
//!
//! Paper-size run: SOM_BENCH_SCALE=10 cargo bench --bench fig5_single_node

mod common;

use somoclu::baseline;
use somoclu::session::Som;
use somoclu::data;
use somoclu::kernels::{DataShard, KernelType};
use somoclu::runtime::Manifest;
use somoclu::som::{Cooling, Neighborhood, Schedule};
use somoclu::util::rng::Rng;
use somoclu::util::timer::{bench_scale, time_once};

fn run_baseline(p: &common::Fig5Params, data: &[f32], rows: usize) -> Option<f64> {
    let grid = somoclu::som::Grid::new(
        p.map_side,
        p.map_side,
        somoclu::som::GridType::Square,
        somoclu::som::MapType::Planar,
    );
    let mut rng = Rng::new(1);
    let cb = baseline::kohonen_like_init(&grid, data, p.dims, &mut rng).ok()?;
    let radius = Schedule::new(p.map_side as f32 / 2.0, 1.0, Cooling::Linear, p.epochs);
    let alpha = Schedule::new(0.5, 0.02, Cooling::Linear, p.epochs);
    let (_, dt) = time_once(|| {
        baseline::train_online(
            &grid,
            cb,
            data,
            p.dims,
            p.epochs,
            radius,
            alpha,
            Neighborhood::gaussian(false),
        )
    });
    let _ = rows;
    Some(dt.as_secs_f64())
}

fn run_kernel(
    p: &common::Fig5Params,
    data: &[f32],
    kernel: KernelType,
) -> anyhow::Result<f64> {
    let cfg = common::base_config(p.map_side, p.epochs, kernel);
    let (res, dt) = time_once(|| {
        Som::builder().config(cfg.clone()).build()?.fit_shard(DataShard::Dense {
            data,
            dim: p.dims,
        })
    });
    res?;
    Ok(dt.as_secs_f64())
}

fn sweep(name: &str, p: &common::Fig5Params, with_baseline: bool, accel_ok: bool) {
    println!("\n-- {name}: {0}x{0} map, D={1}, {2} epochs --", p.map_side, p.dims, p.epochs);
    println!(
        "{:>10} {:>14} {:>14} {:>14} {:>10} {:>10}",
        "n", "kohonen-like", "dense-cpu", "accel-xla", "cpu/koh", "acc vs cpu"
    );
    for &n in &p.sizes {
        let mut rng = Rng::new(n as u64);
        let data = data::random_dense(n, p.dims, &mut rng);

        let t_base = if with_baseline {
            run_baseline(p, &data, n)
        } else {
            None
        };
        let t_cpu = run_kernel(p, &data, KernelType::DenseCpu).unwrap();
        let t_accel = if accel_ok {
            run_kernel(p, &data, KernelType::Accel).ok()
        } else {
            None
        };

        let fmt = |t: Option<f64>| match t {
            Some(t) => format!("{t:>13.3}s"),
            None => format!("{:>14}", "n/a"),
        };
        println!(
            "{n:>10} {} {} {} {:>9.1}x {:>9.2}x",
            fmt(t_base),
            fmt(Some(t_cpu)),
            fmt(t_accel),
            t_base.map(|b| b / t_cpu).unwrap_or(f64::NAN),
            t_accel.map(|a| t_cpu / a).unwrap_or(f64::NAN),
        );
    }
}

fn main() {
    let scale = bench_scale(1.0);
    common::banner("FIG5: single-node training time", scale);
    println!(
        "paper claims: dense CPU >= 10x kohonen (gap grows with n); GPU >= 2x \
         CPU on their testbed; map size does not change the ordering.\n\
         accel here runs interpret-mode Pallas on CPU, so its absolute time \
         is NOT a TPU estimate — see DESIGN.md §Perf for the roofline model."
    );

    let accel_ok = Manifest::default_dir().join("manifest.json").exists();
    if !accel_ok {
        println!("(accel column skipped: run `make artifacts`)");
    }

    let regular = common::fig5_regular(scale);
    sweep("regular map (paper: 50x50)", &regular, true, accel_ok);

    let emergent = common::fig5_emergent(scale);
    // The kohonen-like baseline refuses emergent maps (nodes > rows for
    // the small sizes) — the paper makes exactly this point.
    sweep("emergent map (paper: 200x200)", &emergent, true, accel_ok);

    println!(
        "\nseries notes: 'n/a' under kohonen-like on emergent rows = the \
         baseline cannot initialize maps with more nodes than instances \
         (kohonen exits with an error — §5.1)."
    );
}
