//! Micro-benchmarks of the training hot paths (the §Perf working set):
//! BMU search, node-parallel accumulation, full epoch per kernel, and
//! the accel path split into marshaling vs execution.
//!
//! cargo bench --bench micro_kernels

mod common;

use somoclu::kernels::dense_cpu::{search_bmus_blocked, DenseCpuKernel};
use somoclu::kernels::simd::{self, SimdKind};
use somoclu::kernels::sparse_cpu::SparseCpuKernel;
use somoclu::kernels::{DataShard, TrainingKernel};
use somoclu::runtime::Manifest;
use somoclu::som::{Codebook, Grid, GridType, MapType, Neighborhood};
use somoclu::sparse::Csr;
use somoclu::util::rng::Rng;
use somoclu::util::timer::{bench, bench_scale, print_row};

fn main() {
    let scale = bench_scale(1.0);
    common::banner("micro: kernel hot paths", scale);
    let rows = (2048.0 * scale) as usize;
    let dims = 256;
    let side = 20;
    let grid = Grid::new(side, side, GridType::Square, MapType::Planar);
    let mut rng = Rng::new(0xabc);
    let cb = Codebook::random_init(grid.node_count(), dims, &mut rng);
    let data = somoclu::data::random_dense(rows, dims, &mut rng);
    let nb = Neighborhood::gaussian(false);

    println!(
        "\nworkload: rows={rows} dims={dims} map {side}x{side} \
         ({} nodes)\n",
        grid.node_count()
    );

    // Dense epoch (BMU + accumulate).
    let mut dense = DenseCpuKernel::new(1);
    let shard = DataShard::Dense {
        data: &data,
        dim: dims,
    };
    let stats = bench(1, 5, || {
        dense
            .epoch_accumulate(shard, &cb, &grid, nb, 5.0, 1.0)
            .unwrap()
    });
    print_row("dense-cpu epoch", rows, &stats);
    let macs = rows as f64 * grid.node_count() as f64 * dims as f64;
    println!(
        "{:>24} {:>12.2} GMAC/s (BMU search bound)",
        "",
        macs / stats.min.as_secs_f64() / 1e9
    );

    // BMU search microkernel in isolation (ISSUE 6): dispatched blocked
    // search vs the flat (panel = N) nest vs forced-scalar, plus the raw
    // dot8 kernel.
    let w2 = cb.sq_norms();
    let kind = simd::dispatch();
    let panel = simd::default_panel_nodes(dims);
    let stats = bench(1, 5, || {
        search_bmus_blocked(&data, dims, &cb, &w2, 1, kind, panel)
    });
    print_row(
        &format!("bmu blocked [{}]", simd::kernel_name(kind)),
        rows,
        &stats,
    );
    println!(
        "{:>24} {:>12.2} GMAC/s (panel = {panel} nodes)",
        "",
        macs / stats.min.as_secs_f64() / 1e9
    );
    let stats = bench(1, 5, || {
        search_bmus_blocked(&data, dims, &cb, &w2, 1, kind, cb.nodes)
    });
    print_row("bmu flat (panel = N)", rows, &stats);
    if kind != SimdKind::Scalar {
        let stats = bench(1, 5, || {
            search_bmus_blocked(&data, dims, &cb, &w2, 1, SimdKind::Scalar, panel)
        });
        print_row("bmu blocked [scalar]", rows, &stats);
    }
    // Raw dot8: 8 rows x one codebook row, the innermost kernel.
    let x: [&[f32]; 8] = std::array::from_fn(|k| &data[k * dims..(k + 1) * dims]);
    let w = cb.row(0);
    let stats = bench(2, 10, || {
        let mut acc = 0.0f32;
        for _ in 0..10_000 {
            let d = simd::dot8(kind, &x, std::hint::black_box(w));
            acc += d[0];
        }
        acc
    });
    print_row("dot8 x 10k", 80_000, &stats);

    // Sparse epoch at 5% density.
    let m = Csr::random(rows, dims, 0.05, &mut rng);
    let mut sparse = SparseCpuKernel::new(1);
    let stats = bench(1, 5, || {
        sparse
            .epoch_accumulate(DataShard::Sparse(m.view()), &cb, &grid, nb, 5.0, 1.0)
            .unwrap()
    });
    print_row("sparse-cpu epoch (5%)", rows, &stats);

    // Radius thresholding effect (compact support shrinks the update).
    let compact = Neighborhood::gaussian(true);
    let stats = bench(1, 5, || {
        dense
            .epoch_accumulate(shard, &cb, &grid, compact, 2.0, 1.0)
            .unwrap()
    });
    print_row("dense epoch r=2 compact", rows, &stats);

    // Accel path, split into stages.
    if Manifest::default_dir().join("manifest.json").exists() {
        let mut accel =
            somoclu::kernels::accel::AccelKernel::from_env().unwrap();
        // Warm: includes artifact compile.
        let t0 = std::time::Instant::now();
        accel
            .epoch_accumulate(shard, &cb, &grid, nb, 5.0, 1.0)
            .unwrap();
        println!(
            "{:<24} {:>12}  first call (incl. HLO compile) {:?}",
            "accel-xla epoch", rows, t0.elapsed()
        );
        let stats = bench(0, 3, || {
            accel
                .epoch_accumulate(shard, &cb, &grid, nb, 5.0, 1.0)
                .unwrap()
        });
        print_row("accel-xla epoch (warm)", rows, &stats);
        println!(
            "{:>24} note: interpret-mode Pallas on CPU — structural bench \
             only, not a TPU time estimate",
            ""
        );
    } else {
        println!("accel rows skipped: run `make artifacts`");
    }

    // U-matrix.
    let stats = bench(1, 10, || {
        somoclu::som::umatrix::umatrix(&grid, &cb, 1)
    });
    print_row("umatrix", grid.node_count(), &stats);

    // Baseline per-epoch cost for context.
    let small = &data[..512.min(rows) * dims];
    let gridb = Grid::new(side, side, GridType::Square, MapType::Planar);
    let cbb = Codebook::sample_init(
        gridb.node_count(),
        dims,
        small,
        small.len() / dims,
        &mut rng,
    );
    let radius = somoclu::som::Schedule::new(10.0, 1.0, somoclu::som::Cooling::Linear, 2);
    let alpha = somoclu::som::Schedule::new(0.5, 0.02, somoclu::som::Cooling::Linear, 2);
    let stats = bench(0, 3, || {
        somoclu::baseline::train_online(
            &gridb,
            cbb.clone(),
            small,
            dims,
            1,
            radius,
            alpha,
            nb,
        )
    });
    print_row("baseline online epoch", small.len() / dims, &stats);
}
