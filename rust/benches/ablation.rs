//! Ablations of the design choices DESIGN.md calls out:
//!
//!  1. Gram-trick vs naive direct distance on the accelerator — the
//!     paper's own §3.1 benchmark: "Benchmarking the two approaches, we
//!     found that the latter approach is a magnitude faster on the GPU,
//!     mainly due to a more favorable memory access pattern."
//!  2. Radius thresholding (compact support) — §3.1: "translates to
//!     speed improvements without compromising the quality of the map."
//!  3. BMU-histogram accumulation vs per-sample accumulation — our §Perf
//!     choice, checked for exactness and speed.
//!  4. Hybrid (accel BMU + CPU update) vs full-accel vs full-CPU — the
//!     paper's kernel architecture decision.
//!
//! cargo bench --bench ablation

mod common;

use somoclu::coordinator::config::TrainConfig;
use somoclu::session::Som;
use somoclu::kernels::dense_cpu::DenseCpuKernel;
use somoclu::kernels::hybrid::HybridKernel;
use somoclu::kernels::{DataShard, KernelType, TrainingKernel};
use somoclu::runtime::Manifest;
use somoclu::som::{Codebook, Grid, GridType, MapType, Neighborhood};
use somoclu::util::rng::Rng;
use somoclu::util::timer::{bench, bench_scale, print_row};

fn main() {
    let scale = bench_scale(1.0);
    common::banner("ablations", scale);
    let have_artifacts = Manifest::default_dir().join("manifest.json").exists();

    let rows = (2048.0 * scale) as usize;
    let dims = 256;
    let side = 20;
    let grid = Grid::new(side, side, GridType::Square, MapType::Planar);
    let mut rng = Rng::new(0xab1);
    let cb = Codebook::random_init(grid.node_count(), dims, &mut rng);
    let data = somoclu::data::random_dense(rows, dims, &mut rng);
    let shard = DataShard::Dense {
        data: &data,
        dim: dims,
    };
    let nb = Neighborhood::gaussian(false);

    // --- 1. Gram vs direct distance formulation (accelerator path).
    if have_artifacts {
        println!("\n-- ablation 1: Gram-trick vs naive direct distance (accel BMU) --");
        for variant in ["gram", "direct"] {
            let mut k = HybridKernel::from_env(1).unwrap().with_variant(match variant {
                "gram" => "gram",
                _ => "direct",
            });
            // warm (compile)
            k.epoch_accumulate(shard, &cb, &grid, nb, 5.0, 1.0).unwrap();
            let stats = bench(0, 3, || {
                k.epoch_accumulate(shard, &cb, &grid, nb, 5.0, 1.0).unwrap()
            });
            print_row(&format!("bmu {variant}"), rows, &stats);
        }
        println!(
            "   paper §3.1: the linear-algebra (Gram) formulation won \"by a \
             magnitude\" on GPU; interpret-mode proxy shows the memory-\
             traffic gap (direct materializes a (BS,BN,D) tile)."
        );
    } else {
        println!("(ablation 1 skipped: run `make artifacts`)");
    }

    // --- 2. Radius thresholding: speed AND quality.
    println!("\n-- ablation 2: radius thresholding (compact support) --");
    let mut kern = DenseCpuKernel::new(1);
    for (label, n) in [
        ("gaussian noncompact", Neighborhood::gaussian(false)),
        ("gaussian compact", Neighborhood::gaussian(true)),
    ] {
        let stats = bench(1, 5, || {
            kern.epoch_accumulate(shard, &cb, &grid, n, 2.0, 1.0).unwrap()
        });
        print_row(label, rows, &stats);
    }
    // Quality: train both to completion on blobs and compare final QE.
    let (blob, _) = somoclu::data::gaussian_blobs(1000, 16, 5, 0.2, &mut rng);
    let qe = |compact: bool| {
        let cfg = TrainConfig {
            rows: 16,
            cols: 16,
            epochs: 8,
            neighborhood: Neighborhood::gaussian(compact),
            threads: 1,
            radius0: Some(8.0),
            kernel: KernelType::DenseCpu,
            ..Default::default()
        };
        Som::builder()
            .config(cfg)
            .build()
            .unwrap()
            .fit_shard(DataShard::Dense { data: &blob, dim: 16 })
            .unwrap()
            .final_qe()
    };
    let (q_non, q_com) = (qe(false), qe(true));
    println!(
        "   final QE on blobs: noncompact {q_non:.5} vs compact {q_com:.5} \
         ({:+.2}% — paper: \"without compromising the quality\")",
        100.0 * (q_com - q_non) / q_non
    );

    // --- 3. BMU-histogram vs per-sample accumulation.
    println!("\n-- ablation 3: BMU-histogram vs per-sample accumulation --");
    let w2: Vec<f32> = cb.sq_norms();
    let _ = w2;
    let mut k1 = DenseCpuKernel::new(1);
    let accum = k1
        .epoch_accumulate(shard, &cb, &grid, nb, 5.0, 1.0)
        .unwrap();
    let stats = bench(1, 5, || {
        k1.epoch_accumulate(shard, &cb, &grid, nb, 5.0, 1.0).unwrap()
    });
    print_row("histogram (current)", rows, &stats);
    // Per-sample reference implementation (the pre-§Perf design).
    let per_sample = || {
        let bmus = &accum.bmus;
        let nodes = grid.node_count();
        let cutoff = nb.cutoff(5.0);
        let mut num = vec![0.0f32; nodes * dims];
        let mut den = vec![0.0f32; nodes];
        for node in 0..nodes {
            let num_row = &mut num[node * dims..(node + 1) * dims];
            let mut d = 0.0f32;
            for (r, &b) in bmus.iter().enumerate() {
                let gd = grid.distance(b as usize, node);
                if gd > cutoff {
                    continue;
                }
                let h = nb.weight(gd, 5.0);
                d += h;
                let x = &data[r * dims..(r + 1) * dims];
                for (a, v) in num_row.iter_mut().zip(x) {
                    *a = v.mul_add(h, *a);
                }
            }
            den[node] = d;
        }
        (num, den)
    };
    let stats = bench(0, 2, per_sample);
    print_row("per-sample (old)", rows, &stats);
    let (num2, den2) = per_sample();
    let max_num_diff = accum
        .num
        .iter()
        .zip(&num2)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    let max_den_diff = accum
        .den
        .iter()
        .zip(&den2)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    println!(
        "   equivalence: max |num delta| {max_num_diff:.2e}, max |den delta| \
         {max_den_diff:.2e} (f32 ordering only)"
    );

    // --- 4. Kernel architecture: cpu vs hybrid vs full accel.
    if have_artifacts {
        println!("\n-- ablation 4: kernel architecture (one epoch) --");
        let mut cpu = DenseCpuKernel::new(1);
        let stats = bench(1, 3, || {
            cpu.epoch_accumulate(shard, &cb, &grid, nb, 5.0, 1.0).unwrap()
        });
        print_row("full CPU", rows, &stats);
        let mut hy = HybridKernel::from_env(1).unwrap();
        hy.epoch_accumulate(shard, &cb, &grid, nb, 5.0, 1.0).unwrap();
        let stats = bench(0, 3, || {
            hy.epoch_accumulate(shard, &cb, &grid, nb, 5.0, 1.0).unwrap()
        });
        print_row("hybrid accel+CPU", rows, &stats);
        let mut ac = somoclu::kernels::accel::AccelKernel::from_env().unwrap();
        ac.epoch_accumulate(shard, &cb, &grid, nb, 5.0, 1.0).unwrap();
        let stats = bench(0, 3, || {
            ac.epoch_accumulate(shard, &cb, &grid, nb, 5.0, 1.0).unwrap()
        });
        print_row("full accel", rows, &stats);
        println!(
            "   (interpret-mode accel: the CPU wins here; on real TPU the \
             paper's ordering — hybrid > full-CPU — applies, see DESIGN.md \
             §Perf projection.)"
        );
    }
}
