//! FIG8 — "Speedup on multiple nodes with CPU kernel compared to a
//! single node" (paper: 100k x 1000 dims, 50x50 map, near-linear),
//! plus the collective-algorithm comparison the ring/tree exchange adds.
//!
//! Two sections:
//!
//! **Measured collectives** (always; the only section in `--quick`):
//! real `fit_cluster` runs at P ∈ {2, 4, 8} under `--collective star`
//! and `--collective ring`, with per-op byte/message/time tables from
//! `CommStats`. Aggregate volumes are near-identical (star:
//! (P−1)·(2·N·D+N) f32 per epoch — accumulators up, codebook down;
//! ring: 2·(P−1)·(N·D+N) — allreduced accumulators, no codebook
//! broadcast); the difference is the busiest sender — star's root
//! pushes ~(P−1)·M while every ring rank pushes 2·(P−1)/P·M. Both
//! closed forms are asserted here, and the busiest-sender ratio
//! (ring/star at P = 4, theory ~2/P = 0.5) is the CI trajectory gate.
//!
//! **Modeled multi-node speedup** (skipped in `--quick`): this host
//! exposes ONE core, so wall-clock multi-node speedup is physically
//! impossible; per DESIGN.md §3 the scaling is modeled exactly the way
//! the paper's own argument goes: T(R) = max_r compute(shard_r) +
//! comm(R), with compute measured per real shard and comm from the
//! alpha-beta model over the true byte counts.
//!
//! Modes (mirroring benches/profile_epoch.rs):
//!
//! * `--quick`       CI-friendly sizes, measured section only
//! * `--json PATH`   write the collective table as JSON (BENCH_cluster.json)
//! * `--check PATH`  regression gate: fail if the P=4 busiest-sender
//!                   ratio rises above the baseline's
//!                   `max_ring_star_ratio_p4`; a null ceiling passes
//!                   (bootstrap). `--json`/`--check` may share the path
//!                   — the baseline is read before the write.
//!
//! Paper-size run: SOM_BENCH_SCALE=10 cargo bench --bench fig8_multinode

mod common;

use somoclu::cluster::comm::CollectiveAlgo;
use somoclu::cluster::runner::{ClusterData, ClusterReport};
use somoclu::coordinator::config::TrainConfig;
use somoclu::kernels::dense_cpu::DenseCpuKernel;
use somoclu::kernels::{DataShard, TrainingKernel};
use somoclu::session::Som;
use somoclu::som::Neighborhood;
use somoclu::util::json::Json;
use somoclu::util::rng::Rng;
use somoclu::util::threadpool::split_ranges;
use somoclu::util::timer::{bench_scale, time_once};

struct RankEntry {
    ranks: usize,
    star_bytes: u64,
    ring_bytes: u64,
    star_max_rank: u64,
    ring_max_rank: u64,
    ratio: f64,
}

fn op_bytes(report: &ClusterReport, name: &str) -> u64 {
    report
        .per_op
        .iter()
        .find(|o| o.name == name)
        .map_or(0, |o| o.bytes)
}

fn print_per_op(report: &ClusterReport) {
    for op in &report.per_op {
        if op.messages > 0 {
            println!(
                "        {:<9} {:>12} bytes {:>8} msgs {:>9.3} ms",
                op.name,
                op.bytes,
                op.messages,
                op.nanos as f64 / 1e6
            );
        }
    }
}

/// Real `fit_cluster` runs star-vs-ring; returns the per-P table.
fn measured_collectives(quick: bool) -> Vec<RankEntry> {
    let (rows, dims, side, epochs) = if quick {
        (256usize, 16usize, 8usize, 3usize)
    } else {
        (2048, 64, 16, 5)
    };
    let nodes = side * side; // divisible by 8, so ring segments are even
    let mut rng = Rng::new(0xc011);
    let data = somoclu::data::random_dense(rows, dims, &mut rng);

    println!(
        "\nmeasured collectives: n={rows}, D={dims}, map {side}x{side}, {epochs} epochs"
    );
    println!(
        "{:>6} {:>6} {:>14} {:>16} {:>8}",
        "ranks", "algo", "total bytes", "busiest sender", "ratio"
    );

    let mut entries = Vec::new();
    for p in [2usize, 4, 8] {
        let mut reports = Vec::new();
        for algo in [CollectiveAlgo::Star, CollectiveAlgo::Ring] {
            let cfg = TrainConfig {
                rows: side,
                cols: side,
                epochs,
                threads: 1,
                ranks: p,
                radius0: Some(side as f32 / 2.0),
                collective: algo,
                ..Default::default()
            };
            let (_, report) = Som::builder()
                .config(cfg)
                .build()
                .unwrap()
                .fit_cluster(ClusterData::Dense {
                    data: data.clone(),
                    dim: dims,
                })
                .unwrap();
            reports.push((algo, report));
        }
        let star = &reports[0].1;
        let ring = &reports[1].1;
        let ratio = ring.max_rank_bytes as f64 / star.max_rank_bytes as f64;
        for (algo, report) in &reports {
            println!(
                "{:>6} {:>6} {:>14} {:>16} {:>8}",
                p,
                algo.as_str(),
                report.bytes_sent,
                report.max_rank_bytes,
                if matches!(algo, CollectiveAlgo::Ring) {
                    format!("{ratio:.3}")
                } else {
                    "-".to_string()
                }
            );
            print_per_op(report);
        }

        // Closed forms, asserted on every run. Star per epoch: slaves
        // send num+den up ((P−1)·(N·D+N)·4), the root broadcasts the
        // updated codebook down ((P−1)·N·D·4). Ring per epoch:
        // allreduce of num and den, 2·(P−1)·(N·D+N)·4 in aggregate
        // (each rank 2·total − seg(r+1) − seg(r+2); the sum telescopes
        // to 2·(P−1)·M for any length).
        let m = ((nodes * dims + nodes) * 4) as u64;
        let star_want =
            epochs as u64 * (p as u64 - 1) * ((2 * nodes * dims + nodes) * 4) as u64;
        let ring_want = epochs as u64 * 2 * (p as u64 - 1) * m;
        for (algo, want) in [(CollectiveAlgo::Star, star_want), (CollectiveAlgo::Ring, ring_want)] {
            let report = &reports
                .iter()
                .find(|(a, _)| *a == algo)
                .expect("both algos ran")
                .1;
            assert_eq!(
                op_bytes(report, "allreduce"),
                want,
                "P={p} {}: aggregate allreduce bytes off the closed form",
                algo.as_str()
            );
        }
        // Ring's busiest sender: 2·(P−1)/P·M per epoch on the f32
        // allreduces, plus small non-allreduce traffic (the f64 QE
        // scalar per epoch and the one BMU gather per run).
        let ring_allreduce_per_rank = epochs as u64 * 2 * (p as u64 - 1) * m / p as u64;
        let slack = epochs as u64 * 64 * p as u64 + rows as u64 * 8 + 1024;
        assert!(
            ring.max_rank_bytes <= ring_allreduce_per_rank + slack,
            "P={p}: ring busiest sender {} exceeds 2(P-1)/P*M = {} (+{} slack)",
            ring.max_rank_bytes,
            ring_allreduce_per_rank,
            slack
        );
        entries.push(RankEntry {
            ranks: p,
            star_bytes: star.bytes_sent,
            ring_bytes: ring.bytes_sent,
            star_max_rank: star.max_rank_bytes,
            ring_max_rank: ring.max_rank_bytes,
            ratio,
        });
    }
    entries
}

/// The original Fig. 8 section: measured shard compute + alpha-beta
/// modeled communication (star exchange, as the paper describes it).
fn modeled_speedup(scale: f64) {
    let p = common::fig5_regular(scale);
    let n = *p.sizes.last().unwrap(); // the paper uses the largest size
    let dims = p.dims;
    let side = p.map_side;
    let nodes = side * side;
    let epochs = p.epochs;
    let net = somoclu::cluster::netmodel::NetModel::ethernet_10g();

    let mut rng = Rng::new(0xf18);
    let data = somoclu::data::random_dense(n, dims, &mut rng);
    let cfg = TrainConfig {
        rows: side,
        cols: side,
        epochs,
        radius0: Some(side as f32 / 2.0),
        ..Default::default()
    };
    let grid = cfg.grid();
    let radius_sched = cfg.radius_schedule(&grid);
    let scale_sched = cfg.scale_schedule();
    let mut codebook = somoclu::coordinator::train::init_codebook(&cfg, &grid, dims);

    println!(
        "\nworkload: n={n}, D={dims}, map {side}x{side}, {epochs} epochs, 10GbE model"
    );
    println!(
        "{:>6} {:>14} {:>14} {:>14} {:>9} {:>11}",
        "ranks", "max compute", "comm (model)", "T(R) total", "speedup", "efficiency"
    );

    let mut t1: Option<f64> = None;
    for ranks in [1usize, 2, 4, 8, 16] {
        let ranges = split_ranges(n, ranks);
        let mut total = 0.0f64;
        let mut comm_total = 0.0f64;
        // Fresh kernel per rank-count (codebook cache is rebuilt).
        let mut kernel = DenseCpuKernel::new(1);
        for epoch in 0..epochs {
            let radius = radius_sched.at(epoch);
            let sc = scale_sched.at(epoch);
            // Measure each rank's shard compute serially; model overlap
            // as max (the shards are independent BMU+accumulate passes).
            let mut slowest = 0.0f64;
            let mut merged: Option<somoclu::kernels::EpochAccum> = None;
            for r in ranges.iter() {
                let shard = DataShard::Dense {
                    data: &data[r.start * dims..r.end * dims],
                    dim: dims,
                };
                let (accum, dt) = time_once(|| {
                    kernel
                        .epoch_accumulate(
                            shard,
                            &codebook,
                            &grid,
                            Neighborhood::gaussian(false),
                            radius,
                            sc,
                        )
                        .unwrap()
                });
                slowest = slowest.max(dt.as_secs_f64());
                match &mut merged {
                    None => merged = Some(accum),
                    Some(m) => m.merge(&accum),
                }
            }
            // Communication per epoch: each slave sends num (N*D) + den
            // (N) and receives the codebook (N*D); the master's receives
            // serialize (single NIC), sends pipeline.
            let bytes_up = (nodes * dims + nodes) * 4;
            let bytes_down = nodes * dims * 4;
            let comm = (ranks - 1) as f64
                * (net.cost(bytes_up).as_secs_f64()
                    + net.cost(bytes_down).as_secs_f64());
            let acc = merged.unwrap();
            codebook.apply_batch_update(&acc.num, &acc.den);
            total += slowest + comm;
            comm_total += comm;
        }
        let t = total;
        if t1.is_none() {
            t1 = Some(t);
        }
        let speedup = t1.unwrap() / t;
        println!(
            "{ranks:>6} {:>13.3}s {:>13.3}s {:>13.3}s {:>8.2}x {:>10.1}%",
            t - comm_total,
            comm_total,
            t,
            speedup,
            100.0 * speedup / ranks as f64,
        );
    }
    println!(
        "\nexpected shape (paper Fig. 8): near-linear speedup — per-epoch \
         communication is one accumulator exchange, independent of n, so \
         compute/comm stays large until rank counts get extreme."
    );
}

/// Hand-rendered JSON (no serde in the tree; same approach as
/// profile_epoch.rs). The baseline's `max_ring_star_ratio_p4` ceiling
/// is carried forward verbatim so the artifact can be committed over
/// the baseline without un-arming the gate.
fn render_json(quick: bool, entries: &[RankEntry], ceiling: Option<f64>) -> String {
    let rows: Vec<String> = entries
        .iter()
        .map(|e| {
            format!(
                "    {{\"ranks\": {}, \"star_bytes\": {}, \"ring_bytes\": {}, \
                 \"star_max_rank_bytes\": {}, \"ring_max_rank_bytes\": {}, \
                 \"ratio\": {:.3}}}",
                e.ranks, e.star_bytes, e.ring_bytes, e.star_max_rank, e.ring_max_rank, e.ratio
            )
        })
        .collect();
    let ratio_p4 = entries
        .iter()
        .find(|e| e.ranks == 4)
        .map(|e| e.ratio)
        .unwrap_or(f64::NAN);
    let ceiling_json = match ceiling {
        Some(c) if c.is_finite() => format!("{c:.3}"),
        _ => "null".to_string(),
    };
    format!(
        "{{\n  \"schema\": \"somoclu-cluster-bench/v1\",\n  \"quick\": {},\n  \
         \"collectives\": [\n{}\n  ],\n  \
         \"ratio_p4\": {:.3},\n  \
         \"max_ring_star_ratio_p4\": {}\n}}\n",
        quick,
        rows.join(",\n"),
        ratio_p4,
        ceiling_json,
    )
}

/// The CI gate: the busiest-sender byte ratio (ring/star) at P = 4 must
/// not rise above the committed ceiling. A dimensionless byte-count
/// ratio on identical workloads — deterministic, so shared runners
/// can't flake it; a baseline without a ceiling passes (bootstrap).
fn check_gate(baseline_text: &str, ratio_p4: f64) -> Result<String, String> {
    let json = Json::parse(baseline_text)
        .map_err(|e| format!("baseline is not valid JSON: {e:?}"))?;
    match json.get("max_ring_star_ratio_p4").and_then(|v| v.as_f64()) {
        None => Ok("no ratio ceiling (bootstrap) - passes".to_string()),
        Some(ceiling) => {
            if ratio_p4 > ceiling {
                Err(format!(
                    "ring/star busiest-sender ratio at P=4 is {ratio_p4:.3}, \
                     above the baseline ceiling {ceiling:.3}"
                ))
            } else {
                Ok(format!("ratio@P4 {ratio_p4:.3} <= ceiling {ceiling:.3}"))
            }
        }
    }
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let quick = args.iter().any(|a| a == "--quick");
    let arg_value = |flag: &str| {
        args.iter()
            .position(|a| a == flag)
            .and_then(|i| args.get(i + 1))
            .cloned()
    };
    let json_path = arg_value("--json");
    let check_path = arg_value("--check");
    // Read the baseline BEFORE any write so --json/--check can share a path.
    let baseline = check_path.as_ref().map(|p| {
        std::fs::read_to_string(p).unwrap_or_else(|e| panic!("--check {p}: {e}"))
    });
    let ceiling = baseline
        .as_ref()
        .and_then(|text| Json::parse(text).ok())
        .and_then(|json| {
            json.get("max_ring_star_ratio_p4").and_then(|v| v.as_f64())
        });

    let scale = bench_scale(1.0);
    common::banner("FIG8: multi-node collectives + modeled speedup", scale);

    let entries = measured_collectives(quick);
    let ratio_p4 = entries
        .iter()
        .find(|e| e.ranks == 4)
        .map(|e| e.ratio)
        .expect("P=4 entry exists");
    println!(
        "\nbusiest-sender ratio ring/star at P=4: {ratio_p4:.3} (theory 2/P = 0.5)"
    );

    if quick {
        println!("(--quick: modeled multi-node section skipped)");
    } else {
        modeled_speedup(scale);
    }

    if let Some(path) = &json_path {
        let json = render_json(quick, &entries, ceiling);
        std::fs::write(path, &json).unwrap_or_else(|e| panic!("--json {path}: {e}"));
        println!("wrote {path}");
    }
    if let Some(text) = baseline {
        match check_gate(&text, ratio_p4) {
            Ok(msg) => println!("perf gates: {msg}"),
            Err(msg) => {
                eprintln!("perf gate FAILED: {msg}");
                std::process::exit(1);
            }
        }
    }
}
