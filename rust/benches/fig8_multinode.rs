//! FIG8 — "Speedup on multiple nodes with CPU kernel compared to a
//! single node" (paper: 100k x 1000 dims, 50x50 map, near-linear).
//!
//! This host exposes ONE core, so wall-clock multi-thread speedup is
//! physically impossible; per DESIGN.md §3 the scaling is *modeled*
//! exactly the way the paper's own argument goes:
//!
//!   T(R) = max_r compute(shard_r)  +  comm(R)
//!
//! compute(shard_r) is *measured* by running each rank's epoch kernel
//! serially on its real shard; comm(R) comes from the alpha-beta network
//! model over the true byte counts of the reduce+broadcast exchange
//! (which the simulated cluster also counts on the wire). This keeps the
//! claim honest: the compute term is measured, only its overlap is
//! modeled, and the communication term uses the paper's own structure.
//!
//! Paper-size run: SOM_BENCH_SCALE=10 cargo bench --bench fig8_multinode

mod common;

use somoclu::coordinator::config::TrainConfig;
use somoclu::kernels::dense_cpu::DenseCpuKernel;
use somoclu::kernels::{DataShard, TrainingKernel};
use somoclu::som::Neighborhood;
use somoclu::util::rng::Rng;
use somoclu::util::threadpool::split_ranges;
use somoclu::util::timer::{bench_scale, time_once};

fn main() {
    let scale = bench_scale(1.0);
    common::banner("FIG8: multi-node speedup (modeled overlap)", scale);

    let p = common::fig5_regular(scale);
    let n = *p.sizes.last().unwrap(); // the paper uses the largest size
    let dims = p.dims;
    let side = p.map_side;
    let nodes = side * side;
    let epochs = p.epochs;
    let net = somoclu::cluster::netmodel::NetModel::ethernet_10g();

    let mut rng = Rng::new(0xf18);
    let data = somoclu::data::random_dense(n, dims, &mut rng);
    let cfg = TrainConfig {
        rows: side,
        cols: side,
        epochs,
        radius0: Some(side as f32 / 2.0),
        ..Default::default()
    };
    let grid = cfg.grid();
    let radius_sched = cfg.radius_schedule(&grid);
    let scale_sched = cfg.scale_schedule();
    let mut codebook =
        somoclu::coordinator::train::init_codebook(&cfg, &grid, dims);

    println!(
        "\nworkload: n={n}, D={dims}, map {side}x{side}, {epochs} epochs, 10GbE model"
    );
    println!(
        "{:>6} {:>14} {:>14} {:>14} {:>9} {:>11}",
        "ranks", "max compute", "comm (model)", "T(R) total", "speedup", "efficiency"
    );

    let mut t1: Option<f64> = None;
    for ranks in [1usize, 2, 4, 8, 16] {
        let ranges = split_ranges(n, ranks);
        let mut total = 0.0f64;
        let mut comm_total = 0.0f64;
        // Fresh kernel per rank-count (codebook cache is rebuilt).
        let mut kernel = DenseCpuKernel::new(1);
        for epoch in 0..epochs {
            let radius = radius_sched.at(epoch);
            let sc = scale_sched.at(epoch);
            // Measure each rank's shard compute serially; model overlap
            // as max (the shards are independent BMU+accumulate passes).
            let mut slowest = 0.0f64;
            let mut merged: Option<somoclu::kernels::EpochAccum> = None;
            for r in ranges.iter() {
                let shard = DataShard::Dense {
                    data: &data[r.start * dims..r.end * dims],
                    dim: dims,
                };
                let (accum, dt) = time_once(|| {
                    kernel
                        .epoch_accumulate(
                            shard,
                            &codebook,
                            &grid,
                            Neighborhood::gaussian(false),
                            radius,
                            sc,
                        )
                        .unwrap()
                });
                slowest = slowest.max(dt.as_secs_f64());
                match &mut merged {
                    None => merged = Some(accum),
                    Some(m) => m.merge(&accum),
                }
            }
            // Communication per epoch: each slave sends num (N*D) + den
            // (N) and receives the codebook (N*D); the master's receives
            // serialize (single NIC), sends pipeline.
            let bytes_up = (nodes * dims + nodes) * 4;
            let bytes_down = nodes * dims * 4;
            let comm = (ranks - 1) as f64
                * (net.cost(bytes_up).as_secs_f64()
                    + net.cost(bytes_down).as_secs_f64());
            let acc = merged.unwrap();
            codebook.apply_batch_update(&acc.num, &acc.den);
            total += slowest + comm;
            comm_total += comm;
        }
        let t = total;
        if t1.is_none() {
            t1 = Some(t);
        }
        let speedup = t1.unwrap() / t;
        println!(
            "{ranks:>6} {:>13.3}s {:>13.3}s {:>13.3}s {:>8.2}x {:>10.1}%",
            t - comm_total,
            comm_total,
            t,
            speedup,
            100.0 * speedup / ranks as f64,
        );
    }
    println!(
        "\nexpected shape (paper Fig. 8): near-linear speedup — per-epoch \
         communication is one accumulator exchange, independent of n, so \
         compute/comm stays large until rank counts get extreme."
    );
}
