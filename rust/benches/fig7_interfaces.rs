//! FIG7 — "Memory overhead of the Python, R, and MATLAB interfaces
//! compared to the command-line version."
//!
//! Reproduced mechanism (DESIGN.md §3): the bindings differ in calling
//! convention, not computation —
//!   C++ CLI      -> file load straight into the core's f32 buffers
//!   Python/numpy -> zero-copy f32 pointer pass (BorrowedF32)
//!   R / MATLAB   -> f64 host structures converted (duplicated) to f32
//!                   (ConvertedF64; R/MATLAB also hold the original f64,
//!                   which we account as the caller-side buffer)
//!
//! Expected shape: CLI ≈ Python (flat gap), R/MATLAB gap grows linearly
//! with data size.
//!
//! Paper-size run: SOM_BENCH_SCALE=10 cargo bench --bench fig7_interfaces

mod common;

use somoclu::api::DataInput;
use somoclu::session::Som;
use somoclu::io::dense;
use somoclu::kernels::KernelType;
use somoclu::util::memtrack::{fmt_bytes, MemRegion};
use somoclu::util::rng::Rng;
use somoclu::util::timer::bench_scale;

fn main() {
    let scale = bench_scale(1.0);
    common::banner("FIG7: interface memory overhead", scale);
    let p = common::fig5_regular(scale);
    let cfg = common::base_config(p.map_side, 2, KernelType::DenseCpu);
    let dir = std::env::temp_dir().join("somoclu_fig7");
    std::fs::create_dir_all(&dir).unwrap();

    println!(
        "\n{:>10} {:>14} {:>14} {:>14} {:>12}",
        "n", "C++ (CLI)", "Python-like", "R/MATLAB-like", "R overhead"
    );
    for &n in &p.sizes {
        let mut rng = Rng::new(n as u64 ^ 0xf17);
        let data = somoclu::data::random_dense(n, p.dims, &mut rng);

        // CLI path: parse the file into fresh buffers, then train.
        let path = dir.join(format!("d{n}.txt"));
        dense::write_dense(&path, n, p.dims, &data, false).unwrap();
        let region = MemRegion::start();
        {
            let m = dense::read_dense(&path).unwrap();
            Som::builder()
                .config(cfg.clone())
                .build()
                .unwrap()
                .fit(DataInput::BorrowedF32 {
                    data: &m.data,
                    dim: m.cols,
                })
                .unwrap();
        }
        let cli_peak = region.peak_delta();
        std::fs::remove_file(&path).ok();

        // Python-like: data already in memory as f32, passed by pointer.
        let region = MemRegion::start();
        Som::builder()
            .config(cfg.clone())
            .build()
            .unwrap()
            .fit(DataInput::BorrowedF32 {
                data: &data,
                dim: p.dims,
            })
            .unwrap();
        let py_peak = region.peak_delta() + data.len() * 4; // caller buffer

        // R/MATLAB-like: caller holds f64; binding converts to f32.
        let data64: Vec<f64> = data.iter().map(|&v| v as f64).collect();
        let region = MemRegion::start();
        Som::builder()
            .config(cfg.clone())
            .build()
            .unwrap()
            .fit(DataInput::ConvertedF64 {
                data: &data64,
                dim: p.dims,
            })
            .unwrap();
        let r_peak = region.peak_delta() + data64.len() * 8; // caller buffer
        drop(data64);

        // cli_peak already contains the file-parsed data buffer (it is
        // allocated inside the measured region); the binding paths add
        // their caller-side buffer explicitly instead.
        println!(
            "{n:>10} {:>14} {:>14} {:>14} {:>11.2}x",
            fmt_bytes(cli_peak),
            fmt_bytes(py_peak),
            fmt_bytes(r_peak),
            r_peak as f64 / py_peak as f64,
        );
    }
    println!(
        "\nexpected shape (paper Fig. 7): Python-like ≈ CLI; R/MATLAB-like \
         gap grows with data size (f64 host copy + f32 conversion copy)."
    );
}
