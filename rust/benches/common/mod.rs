//! Shared helpers for the figure-reproduction benches (the in-repo
//! criterion substitute; see util::timer).
#![allow(dead_code)] // each bench uses a different subset

use somoclu::coordinator::config::TrainConfig;
use somoclu::kernels::KernelType;

/// Paper-parameter presets for the Fig. 5/6/8 experiments, shrunk by
//  SOM_BENCH_SCALE (1.0 = the scaled default recorded in EXPERIMENTS.md).
pub struct Fig5Params {
    pub dims: usize,
    pub sizes: Vec<usize>,
    pub map_side: usize,
    pub epochs: usize,
}

/// Fig. 5 regular map: paper is 50x50, D=1000, n = 12.5k..100k.
/// Scale 1.0 default: 20x20, D=256, n = 1.25k..10k (single-core budget).
pub fn fig5_regular(scale: f64) -> Fig5Params {
    let base = [12_500usize, 25_000, 50_000, 100_000];
    let f = scale / 10.0; // scale=10 reproduces the paper sizes
    Fig5Params {
        dims: if scale >= 10.0 { 1000 } else { 256 },
        sizes: base
            .iter()
            .map(|&s| ((s as f64 * f) as usize).max(256))
            .collect(),
        map_side: if scale >= 10.0 { 50 } else { 20 },
        epochs: 3,
    }
}

/// Fig. 5 emergent map: paper 200x200, n = 1.25k..10k. Scaled: 64x64.
pub fn fig5_emergent(scale: f64) -> Fig5Params {
    let base = [1_250usize, 2_500, 5_000, 10_000];
    let f = scale / 10.0;
    Fig5Params {
        dims: if scale >= 10.0 { 1000 } else { 256 },
        sizes: base
            .iter()
            .map(|&s| ((s as f64 * f) as usize).max(128))
            .collect(),
        map_side: if scale >= 10.0 { 200 } else { 64 },
        epochs: 2,
    }
}

pub fn base_config(map_side: usize, epochs: usize, kernel: KernelType) -> TrainConfig {
    TrainConfig {
        rows: map_side,
        cols: map_side,
        epochs,
        kernel,
        radius0: Some(map_side as f32 / 2.0),
        ..Default::default()
    }
}

/// Environment banner all benches print first.
pub fn banner(name: &str, scale: f64) {
    println!("== {name} ==");
    println!(
        "scale {scale} (SOM_BENCH_SCALE; 10 = paper-size), {} core(s), \
         threads/proc {}",
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
        somoclu::util::threadpool::default_threads(),
    );
    println!(
        "NOTE: this host exposes a single core — speedups are *modeled* \
         (per-shard compute measured serially + alpha-beta comm model); \
         see DESIGN.md §3."
    );
}
