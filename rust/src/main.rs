//! `somoclu` — the command-line batch trainer (paper §4.1).
//!
//! Single process: `somoclu [OPTIONS] INPUT OUTPUT_PREFIX`.
//! Simulated cluster: add `--ranks N` (stands in for `mpirun -np N`).

use somoclu::cli;
use somoclu::cluster::runner::{train_cluster, ClusterData};
use somoclu::coordinator::train::{train, train_stream};
use somoclu::io::output::OutputWriter;
use somoclu::io::{read_dense, read_sparse, ChunkedDenseFileSource, ChunkedSparseFileSource, DataSource};
use somoclu::kernels::{DataShard, KernelType};
use somoclu::som::Codebook;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let spec = cli::arg_spec();
    if args.iter().any(|a| a == "-h" || a == "--help") {
        print!("{}", spec.usage("somoclu"));
        return;
    }
    let parsed = match spec.parse(args) {
        Ok(p) => p,
        Err(e) => {
            eprintln!("error: {e}\n\n{}", spec.usage("somoclu"));
            std::process::exit(2);
        }
    };
    let opts = match cli::parse_cli(&parsed) {
        Ok(o) => o,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    if let Err(e) = run(opts) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run(opts: cli::CliOptions) -> anyhow::Result<()> {
    let cfg = &opts.config;
    let writer = OutputWriter::new(&opts.output_prefix);

    // Load the initial codebook if requested (paper -c).
    let grid = cfg.grid();
    let initial = match &opts.initial_codebook {
        Some(path) => {
            let m = read_dense(path)?;
            anyhow::ensure!(
                m.rows == grid.node_count(),
                "initial codebook has {} rows, map has {} nodes",
                m.rows,
                grid.node_count()
            );
            Some(Codebook {
                nodes: m.rows,
                dim: m.cols,
                weights: m.data,
            })
        }
        None => None,
    };

    if cfg.ranks > 1 && cfg.chunk_rows > 0 {
        eprintln!(
            "note: --chunk-rows with --ranks still loads the full input and \
             shards it in memory; each rank then streams its shard in \
             {}-row windows (file-backed rank streaming is a ROADMAP item)",
            cfg.chunk_rows
        );
    }

    let t0 = std::time::Instant::now();
    let result = if cfg.ranks == 1 && cfg.chunk_rows > 0 {
        // Out-of-core path: never materialize the full data set — the
        // file is re-parsed per epoch in `--chunk-rows` windows, capping
        // data memory at O(chunk_rows * dim).
        if cfg.kernel == KernelType::SparseCpu {
            let mut src =
                ChunkedSparseFileSource::open(&opts.input_file, 0, cfg.chunk_rows)?;
            eprintln!(
                "streaming sparse input: {} rows x {} dims in {}-row chunks",
                src.rows(),
                src.dim(),
                cfg.chunk_rows
            );
            train_stream(cfg, &mut src, initial, Some(&writer))?
        } else {
            let mut src = ChunkedDenseFileSource::open(&opts.input_file, cfg.chunk_rows)?;
            eprintln!(
                "streaming dense input: {} rows x {} dims in {}-row chunks",
                src.rows(),
                src.dim(),
                cfg.chunk_rows
            );
            train_stream(cfg, &mut src, initial, Some(&writer))?
        }
    } else if cfg.kernel == KernelType::SparseCpu {
        let m = read_sparse(&opts.input_file, 0)?;
        eprintln!(
            "loaded sparse input: {} rows x {} dims, {:.2}% nonzero",
            m.rows,
            m.cols,
            m.density() * 100.0
        );
        if cfg.ranks > 1 {
            anyhow::ensure!(initial.is_none(), "--ranks with -c is not supported");
            let (res, report) =
                train_cluster(cfg, ClusterData::Sparse(m), opts.net.clone())?;
            eprintln!(
                "cluster: {} ranks, {} msgs, {} bytes on the wire",
                report.ranks, report.messages_sent, report.bytes_sent
            );
            res
        } else {
            train(cfg, DataShard::Sparse(&m), initial, Some(&writer))?
        }
    } else {
        let m = read_dense(&opts.input_file)?;
        eprintln!("loaded dense input: {} rows x {} dims", m.rows, m.cols);
        if cfg.ranks > 1 {
            anyhow::ensure!(initial.is_none(), "--ranks with -c is not supported");
            let (res, report) = train_cluster(
                cfg,
                ClusterData::Dense {
                    data: m.data,
                    dim: m.cols,
                },
                opts.net.clone(),
            )?;
            eprintln!(
                "cluster: {} ranks, {} msgs, {} bytes on the wire",
                report.ranks, report.messages_sent, report.bytes_sent
            );
            res
        } else {
            train(
                cfg,
                DataShard::Dense {
                    data: &m.data,
                    dim: m.cols,
                },
                initial,
                Some(&writer),
            )?
        }
    };

    // Cluster path does not stream snapshots; write final outputs here.
    if cfg.ranks > 1 {
        writer.write_final(&grid, &result.codebook, &result.bmus, &result.umatrix)?;
    }

    if opts.verbose {
        for e in &result.epochs {
            eprintln!(
                "epoch {:>3}  radius {:>7.3}  scale {:>6.4}  QE {:>10.6}  ({:?})",
                e.epoch, e.radius, e.scale, e.qe, e.duration
            );
        }
    }
    eprintln!(
        "trained {} epochs on a {}x{} {:?}/{:?} map with the {} kernel in {:?}; final QE {:.6}",
        cfg.epochs,
        cfg.rows,
        cfg.cols,
        cfg.grid_type,
        cfg.map_type,
        match cfg.kernel {
            KernelType::DenseCpu => "dense-cpu",
            KernelType::Accel => "accel-xla",
            KernelType::SparseCpu => "sparse-cpu",
            KernelType::Hybrid => "hybrid-xla-cpu",
        },
        t0.elapsed(),
        result.final_qe()
    );
    eprintln!(
        "peak data-buffer memory: {} (heap peak {})",
        somoclu::util::memtrack::fmt_bytes(somoclu::util::memtrack::data_buffer_peak()),
        somoclu::util::memtrack::fmt_bytes(somoclu::util::memtrack::peak_bytes()),
    );
    eprintln!(
        "wrote {p}.wts, {p}.bm, {p}.umx",
        p = opts.output_prefix
    );
    Ok(())
}
