//! `somoclu` — the command-line batch trainer (paper §4.1).
//!
//! Single process: `somoclu [OPTIONS] INPUT OUTPUT_PREFIX`.
//! Simulated cluster: add `--ranks N` (stands in for `mpirun -np N`).
//! Transcode to the binary fast path: `somoclu convert IN OUT`.
//!
//! Binary container inputs (written by `convert`) are auto-detected by
//! magic; they always stream (chunked by `--chunk-rows`, whole-file
//! otherwise) with zero per-epoch parsing. `--prefetch` overlaps chunk
//! I/O with kernel compute. `--ranks N --chunk-rows M` streams per-rank
//! disjoint shards of one file — no resident copy is ever built.

use std::path::PathBuf;

use somoclu::cli;
use somoclu::cluster::runner::{train_cluster, train_cluster_stream, ClusterData, StreamInput};
use somoclu::coordinator::train::{train, train_stream};
use somoclu::io::binary::{self, BinaryKind};
use somoclu::io::output::OutputWriter;
use somoclu::io::{
    read_dense, read_sparse, BinaryDenseFileSource, BinarySparseFileSource,
    ChunkedDenseFileSource, ChunkedSparseFileSource, DataSource, PrefetchSource,
};
use somoclu::kernels::{DataShard, KernelType};
use somoclu::som::Codebook;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();

    // Subcommand: `somoclu convert [OPTIONS] INPUT OUTPUT`.
    if args.first().map(String::as_str) == Some("convert") {
        let spec = cli::convert_spec();
        if args.iter().any(|a| a == "-h" || a == "--help") {
            print!("{}", spec.usage("somoclu convert"));
            return;
        }
        let opts = match spec
            .parse(args[1..].iter().cloned())
            .and_then(|p| cli::parse_convert(&p))
        {
            Ok(o) => o,
            Err(e) => {
                eprintln!("error: {e}\n\n{}", spec.usage("somoclu convert"));
                std::process::exit(2);
            }
        };
        if let Err(e) = run_convert(opts) {
            eprintln!("error: {e:#}");
            std::process::exit(1);
        }
        return;
    }

    let spec = cli::arg_spec();
    if args.iter().any(|a| a == "-h" || a == "--help") {
        print!("{}", spec.usage("somoclu"));
        return;
    }
    let parsed = match spec.parse(args) {
        Ok(p) => p,
        Err(e) => {
            eprintln!("error: {e}\n\n{}", spec.usage("somoclu"));
            std::process::exit(2);
        }
    };
    let opts = match cli::parse_cli(&parsed) {
        Ok(o) => o,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    if let Err(e) = run(opts) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

/// Transcode a text input into the binary container, streaming in
/// `chunk_rows` windows so conversion memory stays bounded too.
/// Do `a` and `b` name the same on-disk file? Inode identity on Unix
/// (catches hard links, not just symlink/relative aliases), canonical
/// path elsewhere. A nonexistent path matches nothing.
#[cfg(unix)]
fn same_file(a: &str, b: &str) -> bool {
    use std::os::unix::fs::MetadataExt;
    match (std::fs::metadata(a), std::fs::metadata(b)) {
        (Ok(x), Ok(y)) => x.dev() == y.dev() && x.ino() == y.ino(),
        _ => false,
    }
}

#[cfg(not(unix))]
fn same_file(a: &str, b: &str) -> bool {
    match (std::fs::canonicalize(a), std::fs::canonicalize(b)) {
        (Ok(x), Ok(y)) => x == y,
        _ => false,
    }
}

fn run_convert(opts: cli::ConvertOptions) -> anyhow::Result<()> {
    // Refuse in-place conversion BEFORE File::create truncates the
    // input (a nonexistent output cannot alias an existing input).
    anyhow::ensure!(
        !same_file(&opts.input_file, &opts.output_file),
        "convert: input and output are the same file ({}); pick a \
         different output path",
        opts.input_file
    );
    anyhow::ensure!(
        binary::sniff(&opts.input_file)?.is_none(),
        "{}: already a somoclu binary container",
        opts.input_file
    );
    let t0 = std::time::Instant::now();
    if opts.sparse {
        let mut src =
            ChunkedSparseFileSource::open(&opts.input_file, opts.min_cols, opts.chunk_rows)?;
        let (rows, cols, nnz) =
            binary::convert_sparse_to_binary(&mut src, &opts.output_file)?;
        eprintln!(
            "converted {rows} rows x {cols} dims ({nnz} nonzeros, {:.2}% dense) \
             to sparse binary {} in {:?}",
            100.0 * nnz as f64 / (rows as f64 * cols as f64),
            opts.output_file,
            t0.elapsed()
        );
    } else {
        let mut src = ChunkedDenseFileSource::open(&opts.input_file, opts.chunk_rows)?;
        let (rows, dim) = binary::convert_dense_to_binary(&mut src, &opts.output_file)?;
        eprintln!(
            "converted {rows} rows x {dim} dims to dense binary {} in {:?}",
            opts.output_file,
            t0.elapsed()
        );
    }
    Ok(())
}

/// Build the single-process streaming source for `input`: binary
/// containers stream natively; text files stream re-parsed. `--prefetch`
/// wraps either in the double-buffered read-ahead adapter.
fn open_stream_source(
    input: &str,
    kind: Option<BinaryKind>,
    kernel: KernelType,
    chunk_rows: usize,
    prefetch: bool,
) -> anyhow::Result<Box<dyn DataSource + Send>> {
    let mut src: Box<dyn DataSource + Send> = match kind {
        Some(BinaryKind::Dense) => {
            let s = BinaryDenseFileSource::open(input, chunk_rows)?;
            eprintln!(
                "streaming dense binary input: {} rows x {} dims ({} chunks)",
                s.rows(),
                s.dim(),
                chunk_desc(chunk_rows)
            );
            Box::new(s)
        }
        Some(BinaryKind::Sparse) => {
            let s = BinarySparseFileSource::open(input, chunk_rows)?;
            eprintln!(
                "streaming sparse binary input: {} rows x {} dims ({} chunks)",
                s.rows(),
                s.dim(),
                chunk_desc(chunk_rows)
            );
            Box::new(s)
        }
        None if kernel == KernelType::SparseCpu => {
            let s = ChunkedSparseFileSource::open(input, 0, chunk_rows)?;
            eprintln!(
                "streaming sparse input: {} rows x {} dims ({} chunks; run \
                 `somoclu convert --sparse` once to skip per-epoch parsing)",
                s.rows(),
                s.dim(),
                chunk_desc(chunk_rows)
            );
            Box::new(s)
        }
        None => {
            let s = ChunkedDenseFileSource::open(input, chunk_rows)?;
            eprintln!(
                "streaming dense input: {} rows x {} dims ({} chunks; run \
                 `somoclu convert` once to skip per-epoch parsing)",
                s.rows(),
                s.dim(),
                chunk_desc(chunk_rows)
            );
            Box::new(s)
        }
    };
    if prefetch {
        eprintln!("prefetch on: chunk k+1 loads while the kernel runs chunk k");
        src = Box::new(PrefetchSource::new(src));
    }
    Ok(src)
}

fn chunk_desc(chunk_rows: usize) -> String {
    if chunk_rows == 0 {
        "whole-pass".to_string()
    } else {
        format!("{chunk_rows}-row")
    }
}

fn run(opts: cli::CliOptions) -> anyhow::Result<()> {
    let cfg = &opts.config;
    let writer = OutputWriter::new(&opts.output_prefix);

    // Load the initial codebook if requested (paper -c).
    let grid = cfg.grid();
    let initial = match &opts.initial_codebook {
        Some(path) => {
            let m = read_dense(path)?;
            anyhow::ensure!(
                m.rows == grid.node_count(),
                "initial codebook has {} rows, map has {} nodes",
                m.rows,
                grid.node_count()
            );
            Some(Codebook {
                nodes: m.rows,
                dim: m.cols,
                weights: m.data,
            })
        }
        None => None,
    };

    if cfg.ranks > 1 {
        anyhow::ensure!(initial.is_none(), "--ranks with -c is not supported");
    }

    // Binary containers (written by `somoclu convert`) are detected by
    // magic and always stream — there is no reason to materialize them.
    let binary_kind = binary::sniff(&opts.input_file)
        .map_err(|e| anyhow::anyhow!("{}: {e}", opts.input_file))?;
    let streaming = cfg.chunk_rows > 0 || binary_kind.is_some();

    let t0 = std::time::Instant::now();
    let result = if cfg.ranks > 1 && streaming {
        // Out-of-core cluster path: every rank opens its own disjoint
        // row window of the input file — the full data set is never
        // resident anywhere.
        let path = PathBuf::from(&opts.input_file);
        let input = if binary_kind.is_some() {
            StreamInput::Binary { path }
        } else if cfg.kernel == KernelType::SparseCpu {
            StreamInput::SparseText { path, min_cols: 0 }
        } else {
            StreamInput::DenseText { path }
        };
        eprintln!(
            "streaming {} per-rank shards ({} chunks each{})",
            cfg.ranks,
            chunk_desc(cfg.chunk_rows),
            if cfg.prefetch { ", prefetched" } else { "" }
        );
        let (res, report) = train_cluster_stream(cfg, input, opts.net.clone())?;
        eprintln!(
            "cluster: {} ranks, {} msgs, {} bytes on the wire",
            report.ranks, report.messages_sent, report.bytes_sent
        );
        res
    } else if cfg.ranks == 1 && streaming {
        // Out-of-core single-process path: never materialize the full
        // data set — binary inputs seek-read chunks, text inputs are
        // re-parsed per epoch in `--chunk-rows` windows.
        let mut src = open_stream_source(
            &opts.input_file,
            binary_kind,
            cfg.kernel,
            cfg.chunk_rows,
            cfg.prefetch,
        )?;
        train_stream(cfg, &mut src, initial, Some(&writer))?
    } else if cfg.kernel == KernelType::SparseCpu {
        let m = read_sparse(&opts.input_file, 0)?;
        eprintln!(
            "loaded sparse input: {} rows x {} dims, {:.2}% nonzero",
            m.rows,
            m.cols,
            m.density() * 100.0
        );
        if cfg.ranks > 1 {
            let (res, report) =
                train_cluster(cfg, ClusterData::Sparse(m), opts.net.clone())?;
            eprintln!(
                "cluster: {} ranks, {} msgs, {} bytes on the wire",
                report.ranks, report.messages_sent, report.bytes_sent
            );
            res
        } else {
            train(cfg, DataShard::Sparse(&m), initial, Some(&writer))?
        }
    } else {
        let m = read_dense(&opts.input_file)?;
        eprintln!("loaded dense input: {} rows x {} dims", m.rows, m.cols);
        if cfg.ranks > 1 {
            let (res, report) = train_cluster(
                cfg,
                ClusterData::Dense {
                    data: m.data,
                    dim: m.cols,
                },
                opts.net.clone(),
            )?;
            eprintln!(
                "cluster: {} ranks, {} msgs, {} bytes on the wire",
                report.ranks, report.messages_sent, report.bytes_sent
            );
            res
        } else {
            train(
                cfg,
                DataShard::Dense {
                    data: &m.data,
                    dim: m.cols,
                },
                initial,
                Some(&writer),
            )?
        }
    };

    // Cluster paths do not stream snapshots; write final outputs here.
    if cfg.ranks > 1 {
        writer.write_final(&grid, &result.codebook, &result.bmus, &result.umatrix)?;
    }

    if opts.verbose {
        for e in &result.epochs {
            eprintln!(
                "epoch {:>3}  radius {:>7.3}  scale {:>6.4}  QE {:>10.6}  ({:?})",
                e.epoch, e.radius, e.scale, e.qe, e.duration
            );
        }
    }
    eprintln!(
        "trained {} epochs on a {}x{} {:?}/{:?} map with the {} kernel in {:?}; final QE {:.6}",
        cfg.epochs,
        cfg.rows,
        cfg.cols,
        cfg.grid_type,
        cfg.map_type,
        match cfg.kernel {
            KernelType::DenseCpu => "dense-cpu",
            KernelType::Accel => "accel-xla",
            KernelType::SparseCpu => "sparse-cpu",
            KernelType::Hybrid => "hybrid-xla-cpu",
        },
        t0.elapsed(),
        result.final_qe()
    );
    eprintln!(
        "peak data-buffer memory: {} (heap peak {})",
        somoclu::util::memtrack::fmt_bytes(somoclu::util::memtrack::data_buffer_peak()),
        somoclu::util::memtrack::fmt_bytes(somoclu::util::memtrack::peak_bytes()),
    );
    eprintln!(
        "wrote {p}.wts, {p}.bm, {p}.umx",
        p = opts.output_prefix
    );
    Ok(())
}
