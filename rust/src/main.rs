//! `somoclu` — the command-line front end (paper §4.1), organized as
//! subcommands:
//!
//! - `somoclu train [OPTIONS] INPUT OUTPUT_PREFIX` — batch training.
//!   Single process by default; `--ranks N` simulates a cluster
//!   (stands in for `mpirun -np N`); `--rank K --peers ...` (or
//!   `--listen`/`--connect`) runs one rank of a real multi-process
//!   cluster, rank 0 writing the outputs. Long runs are interruptible:
//!   `--checkpoint-every N` writes `OUTPUT_PREFIX.epoch<k>.somc` as
//!   training progresses (`--keep-last M` caps how many survive), and
//!   `--resume CKPT` finishes the run bit-identically to an
//!   uninterrupted one.
//! - `somoclu ensemble [OPTIONS] INPUT OUTPUT_PREFIX` — train K
//!   independently-seeded maps concurrently ([`somoclu::ensemble`]),
//!   cluster each codebook, and combine the labelings into one
//!   consensus with per-sample agreement scores (aweSOM's SCE rule).
//!   Writes `.m<i>.bm` per member, `.consensus.lbl`, and a versioned
//!   `.ensemble.json` report.
//! - `somoclu quality [OPTIONS] CHECKPOINT DATA` — load a SOMC
//!   checkpoint, project the data through it, and emit the versioned
//!   quality JSON (QE, TE, trustworthiness, neighborhood preservation,
//!   component-plane and U-matrix digests).
//! - `somoclu serve [OPTIONS] LISTEN_ADDR` — the checkpoint-serving
//!   daemon ([`somoclu::serve`]): answers `bmu`/`project`/`quality`
//!   requests over TCP or Unix sockets and runs a journaled training
//!   job queue whose finished maps hot-swap into the serving slot.
//! - `somoclu convert [OPTIONS] IN OUT` — transcode text inputs to the
//!   binary container that streams with zero per-epoch parsing.
//! - `somoclu info [OPTIONS] IN` — decode a container header (+ shard
//!   windows with `--ranks N`).
//!
//! The historical flat invocation `somoclu [OPTIONS] INPUT
//! OUTPUT_PREFIX` keeps working as an alias for `train`, printing a
//! one-line deprecation notice to stderr.

use std::path::PathBuf;

use somoclu::cli;
use somoclu::cluster::runner::{ClusterData, StreamInput};
use somoclu::coordinator::config::IoMode;
use somoclu::error::SomError;
use somoclu::io::binary;
use somoclu::io::output::OutputWriter;
use somoclu::io::{
    chunk_desc, open_stream_source, read_dense, read_sparse, ChunkedDenseFileSource,
    ChunkedSparseFileSource, InMemorySource,
};
use somoclu::kernels::{DataShard, KernelType};
use somoclu::serve::ServeOptions;
use somoclu::session::{Som, SomSession};
use somoclu::som::Codebook;

const TOP_USAGE: &str = "\
somoclu — massively parallel self-organizing maps

Usage:
  somoclu train [OPTIONS] INPUT_FILE OUTPUT_PREFIX
  somoclu ensemble [OPTIONS] INPUT_FILE OUTPUT_PREFIX
  somoclu quality [OPTIONS] CHECKPOINT DATA_FILE
  somoclu serve [OPTIONS] LISTEN_ADDR
  somoclu convert [OPTIONS] INPUT_FILE OUTPUT_FILE
  somoclu info [OPTIONS] INPUT_FILE

Run `somoclu <subcommand> --help` for that subcommand's flags.

The historical flat form `somoclu [OPTIONS] INPUT_FILE OUTPUT_PREFIX`
still works as an alias for `train` (deprecated).
";

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let code = match args.first().map(String::as_str) {
        Some("train") => cmd_train(&args[1..], "somoclu train"),
        Some("ensemble") => cmd_ensemble(&args[1..]),
        Some("quality") => cmd_quality(&args[1..]),
        Some("serve") => cmd_serve(&args[1..]),
        Some("convert") => cmd_convert(&args[1..]),
        Some("info") => cmd_info(&args[1..]),
        Some("help") | Some("-h") | Some("--help") | None => {
            print!("{TOP_USAGE}");
            0
        }
        _ => {
            // Flat invocation: the pre-subcommand grammar, still the
            // `train` grammar verbatim.
            eprintln!(
                "note: the flat `somoclu [OPTIONS] INPUT OUTPUT_PREFIX` form is \
                 deprecated; use `somoclu train ...`"
            );
            cmd_train(&args, "somoclu")
        }
    };
    std::process::exit(code);
}

fn wants_help(args: &[String]) -> bool {
    args.iter().any(|a| a == "-h" || a == "--help")
}

fn cmd_train(args: &[String], prog: &str) -> i32 {
    let spec = cli::train_spec();
    if wants_help(args) {
        print!("{}", spec.usage(prog));
        return 0;
    }
    let opts = match spec
        .parse(args.iter().cloned())
        .and_then(|p| cli::parse_cli(&p))
    {
        Ok(o) => o,
        Err(e) => {
            eprintln!("error: {e}\n\n{}", spec.usage(prog));
            return 2;
        }
    };
    if let Err(e) = run(opts) {
        eprintln!("error: {e:#}");
        return 1;
    }
    0
}

fn cmd_ensemble(args: &[String]) -> i32 {
    let spec = cli::ensemble_spec();
    if wants_help(args) {
        print!("{}", spec.usage("somoclu ensemble"));
        return 0;
    }
    let opts = match spec
        .parse(args.iter().cloned())
        .and_then(|p| cli::parse_ensemble(&p))
    {
        Ok(o) => o,
        Err(e) => {
            eprintln!("error: {e}\n\n{}", spec.usage("somoclu ensemble"));
            return 2;
        }
    };
    if let Err(e) = run_ensemble(opts) {
        eprintln!("error: {e:#}");
        return 1;
    }
    0
}

fn cmd_quality(args: &[String]) -> i32 {
    let spec = cli::quality_spec();
    if wants_help(args) {
        print!("{}", spec.usage("somoclu quality"));
        return 0;
    }
    let opts = match spec
        .parse(args.iter().cloned())
        .and_then(|p| cli::parse_quality(&p))
    {
        Ok(o) => o,
        Err(e) => {
            eprintln!("error: {e}\n\n{}", spec.usage("somoclu quality"));
            return 2;
        }
    };
    if let Err(e) = run_quality(opts) {
        eprintln!("error: {e:#}");
        return 1;
    }
    0
}

/// Train the ensemble and write per-member `.m<i>.bm` files, the
/// consensus labeling (`.consensus.lbl`), and the versioned JSON report
/// (`.ensemble.json`).
fn run_ensemble(opts: cli::EnsembleCliOptions) -> anyhow::Result<()> {
    let m = read_dense(&opts.input_file)?;
    eprintln!("loaded dense input: {} rows x {} dims", m.rows, m.cols);
    let t0 = std::time::Instant::now();
    let mut builder = somoclu::ensemble::EnsembleBuilder::new()
        .config(opts.config.clone())
        .members(opts.members)
        .clusters(opts.clusters)
        .kmeans_iters(opts.kmeans_iters);
    if opts.checkpoint_every > 0 {
        builder = builder.checkpoint_every(opts.checkpoint_every, &opts.output_prefix);
        eprintln!(
            "checkpointing every {} epochs to {}.m<i>.epoch<k>.somc (existing \
             member checkpoints are resumed)",
            opts.checkpoint_every, opts.output_prefix
        );
    }
    let result = builder.run(&m.data, m.cols)?;

    let grid = opts.config.grid();
    for (i, member) in result.members.iter().enumerate() {
        let path = format!("{}.m{i}.bm", opts.output_prefix);
        somoclu::io::esom::write_bm(&path, &grid, &member.bmus)?;
        if opts.verbose {
            eprintln!(
                "member {i}: seed {}  QE {:.6}  k-means inertia {:.4} \
                 ({} iters)",
                member.seed, member.qe, member.inertia, member.kmeans_iterations
            );
        }
    }
    let lbl_path = format!("{}.consensus.lbl", opts.output_prefix);
    somoclu::io::esom::write_consensus_labels(
        &lbl_path,
        &result.consensus.labels,
        &result.consensus.agreement,
    )?;
    let json_path = format!("{}.ensemble.json", opts.output_prefix);
    std::fs::write(&json_path, format!("{}\n", result.to_json()))?;
    eprintln!(
        "ensemble: {} members x {} epochs on {}x{} maps, {} clusters; mean \
         agreement {:.4} over {} samples in {:?}",
        opts.members,
        opts.config.epochs,
        opts.config.rows,
        opts.config.cols,
        opts.clusters,
        result.consensus.mean_agreement,
        result.consensus.labels.len(),
        t0.elapsed()
    );
    eprintln!(
        "wrote {p}.m<i>.bm, {lbl_path}, {json_path}",
        p = opts.output_prefix
    );
    Ok(())
}

/// Evaluate a trained checkpoint against a data set and emit the
/// versioned quality JSON to stdout (or `-o FILE`).
fn run_quality(opts: cli::QualityCliOptions) -> anyhow::Result<()> {
    let mut session = Som::resume(&opts.checkpoint)?;
    if opts.threads > 0 {
        session.set_threads(opts.threads);
    }
    let m = read_dense(&opts.data_file)?;
    let codebook = session
        .codebook()
        .ok_or_else(|| anyhow::anyhow!("{}: checkpoint holds no codebook", opts.checkpoint))?
        .clone();
    anyhow::ensure!(
        m.cols == codebook.dim,
        "{}: data has {} dims, the checkpointed map was trained on {}",
        opts.data_file,
        m.cols,
        codebook.dim
    );
    let bmus = session.project(somoclu::api::DataInput::BorrowedF32 {
        data: &m.data,
        dim: m.cols,
    })?;
    let umatrix = session.umatrix();
    let mut report = somoclu::som::quality::QualityReport::compute(
        &m.data,
        m.cols,
        session.grid(),
        &codebook,
        &bmus,
        umatrix.as_deref(),
        opts.knn,
        opts.threads,
    );
    if opts.planes {
        report = report.with_plane_values(&codebook);
    }
    let text = format!("{}\n", report.to_json());
    match &opts.out {
        Some(path) => {
            std::fs::write(path, &text)?;
            eprintln!("wrote {path}");
        }
        None => print!("{text}"),
    }
    eprintln!(
        "quality: QE {:.6}  TE {:.4}  trustworthiness {:.4}  neighborhood \
         preservation {:.4} (k={}) over {} rows",
        report.qe,
        report.te,
        report.rank.trustworthiness,
        report.rank.neighborhood_preservation,
        report.rank.k,
        report.rows
    );
    Ok(())
}

fn cmd_serve(args: &[String]) -> i32 {
    let spec = cli::serve_spec();
    if wants_help(args) {
        print!("{}", spec.usage("somoclu serve"));
        return 0;
    }
    let opts = match spec
        .parse(args.iter().cloned())
        .and_then(|p| cli::parse_serve(&p))
    {
        Ok(o) => o,
        Err(e) => {
            eprintln!("error: {e}\n\n{}", spec.usage("somoclu serve"));
            return 2;
        }
    };
    let serve_opts = ServeOptions {
        addr: opts.addr,
        checkpoint: opts.checkpoint.map(PathBuf::from),
        state_dir: PathBuf::from(opts.state_dir),
        threads: opts.threads,
        handle_signals: true,
        job_retries: opts.job_retries as u32,
        verbose: opts.verbose,
    };
    if let Err(e) = somoclu::serve::run(serve_opts) {
        eprintln!("error: {e}");
        return 1;
    }
    0
}

fn cmd_convert(args: &[String]) -> i32 {
    let spec = cli::convert_spec();
    if wants_help(args) {
        print!("{}", spec.usage("somoclu convert"));
        return 0;
    }
    let opts = match spec
        .parse(args.iter().cloned())
        .and_then(|p| cli::parse_convert(&p))
    {
        Ok(o) => o,
        Err(e) => {
            eprintln!("error: {e}\n\n{}", spec.usage("somoclu convert"));
            return 2;
        }
    };
    if let Err(e) = run_convert(opts) {
        eprintln!("error: {e:#}");
        return 1;
    }
    0
}

fn cmd_info(args: &[String]) -> i32 {
    let spec = cli::info_spec();
    if wants_help(args) {
        print!("{}", spec.usage("somoclu info"));
        return 0;
    }
    let opts = match spec
        .parse(args.iter().cloned())
        .and_then(|p| cli::parse_info(&p))
    {
        Ok(o) => o,
        Err(e) => {
            eprintln!("error: {e}\n\n{}", spec.usage("somoclu info"));
            return 2;
        }
    };
    match binary::info_report(&opts.input_file, opts.ranks) {
        Ok(report) => {
            print!("{report}");
            0
        }
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    }
}

/// Do `a` and `b` name the same on-disk file? Inode identity on Unix
/// (catches hard links, not just symlink/relative aliases), canonical
/// path elsewhere. A nonexistent path matches nothing.
#[cfg(unix)]
fn same_file(a: &str, b: &str) -> bool {
    use std::os::unix::fs::MetadataExt;
    match (std::fs::metadata(a), std::fs::metadata(b)) {
        (Ok(x), Ok(y)) => x.dev() == y.dev() && x.ino() == y.ino(),
        _ => false,
    }
}

#[cfg(not(unix))]
fn same_file(a: &str, b: &str) -> bool {
    match (std::fs::canonicalize(a), std::fs::canonicalize(b)) {
        (Ok(x), Ok(y)) => x == y,
        _ => false,
    }
}

/// Transcode a text input into the binary container, streaming in
/// `chunk_rows` windows so conversion memory stays bounded too.
fn run_convert(opts: cli::ConvertOptions) -> anyhow::Result<()> {
    // Refuse in-place conversion BEFORE File::create truncates the
    // input (a nonexistent output cannot alias an existing input).
    anyhow::ensure!(
        !same_file(&opts.input_file, &opts.output_file),
        "convert: input and output are the same file ({}); pick a \
         different output path",
        opts.input_file
    );
    anyhow::ensure!(
        binary::sniff(&opts.input_file)?.is_none(),
        "{}: already a somoclu binary container",
        opts.input_file
    );
    let t0 = std::time::Instant::now();
    if opts.sparse {
        let mut src =
            ChunkedSparseFileSource::open(&opts.input_file, opts.min_cols, opts.chunk_rows)?;
        let (rows, cols, nnz) =
            binary::convert_sparse_to_binary(&mut src, &opts.output_file)?;
        eprintln!(
            "converted {rows} rows x {cols} dims ({nnz} nonzeros, {:.2}% dense) \
             to sparse binary {} in {:?}",
            100.0 * nnz as f64 / (rows as f64 * cols as f64),
            opts.output_file,
            t0.elapsed()
        );
    } else {
        let mut src = ChunkedDenseFileSource::open(&opts.input_file, opts.chunk_rows)?;
        let (rows, dim) = binary::convert_dense_to_binary(&mut src, &opts.output_file)?;
        eprintln!(
            "converted {rows} rows x {dim} dims to dense binary {} in {:?}",
            opts.output_file,
            t0.elapsed()
        );
    }
    Ok(())
}

/// Build the session this invocation drives: fresh from the flags, or
/// resumed from a `SOMC` checkpoint — in which case the checkpoint owns
/// the map/schedule/kernel settings and only the runtime knobs
/// (threads, ranks, chunking, prefetch, I/O backend, snapshots, net)
/// come from the flags.
fn build_session(opts: &cli::CliOptions) -> anyhow::Result<SomSession> {
    match &opts.resume {
        Some(ckpt) => {
            let mut session = Som::resume(ckpt)?;
            let rt = &opts.config;
            session.set_threads(rt.threads);
            session.set_ranks(rt.ranks);
            session.set_chunk_rows(rt.chunk_rows);
            session.set_prefetch(rt.prefetch);
            session.set_io_mode(rt.io_mode);
            session.set_snapshot(rt.snapshot);
            session.set_net(opts.net.clone());
            eprintln!(
                "resumed {ckpt}: epoch {}/{} on a {}x{} map ({} epochs to go)",
                session.epoch(),
                session.epochs_total(),
                session.config().rows,
                session.config().cols,
                session.remaining_epochs(),
            );
            Ok(session)
        }
        None => {
            // Load the initial codebook if requested (paper -c).
            let grid = opts.config.grid();
            let initial = match &opts.initial_codebook {
                Some(path) => {
                    let m = read_dense(path)?;
                    anyhow::ensure!(
                        m.rows == grid.node_count(),
                        "initial codebook has {} rows, map has {} nodes",
                        m.rows,
                        grid.node_count()
                    );
                    Some(Codebook {
                        nodes: m.rows,
                        dim: m.cols,
                        weights: m.data,
                    })
                }
                None => None,
            };
            match &opts.multiproc {
                // Real multi-process run: rank 0 owns initial state and
                // broadcasts it at bootstrap, so -c belongs to rank 0.
                Some(mp) => anyhow::ensure!(
                    initial.is_none() || mp.rank == 0,
                    "-c is rank 0's flag in a multi-process run (initial \
                     state is broadcast at bootstrap)"
                ),
                None if opts.config.ranks > 1 => {
                    anyhow::ensure!(initial.is_none(), "--ranks with -c is not supported")
                }
                None => {}
            }
            let mut builder = Som::builder()
                .config(opts.config.clone())
                .net(opts.net.clone());
            if let Some(cb) = initial {
                builder = builder.initial_codebook(cb);
            }
            Ok(builder.build()?)
        }
    }
}

/// Per-run communication summary: the aggregate line every cluster mode
/// always printed, plus the busiest sender (the bandwidth bottleneck the
/// ring collective exists to flatten) and a per-collective breakdown.
fn print_comm_report(report: &somoclu::cluster::runner::ClusterReport) {
    eprintln!(
        "cluster: {} ranks, {} msgs, {} bytes on the wire (busiest sender: {} bytes)",
        report.ranks, report.messages_sent, report.bytes_sent, report.max_rank_bytes
    );
    for op in &report.per_op {
        if op.messages > 0 {
            eprintln!(
                "  {:<9} {:>14} bytes {:>9} msgs {:>10.3} ms",
                op.name,
                op.bytes,
                op.messages,
                op.nanos as f64 / 1e6
            );
        }
    }
}

fn run(opts: cli::CliOptions) -> anyhow::Result<()> {
    let writer = OutputWriter::new(&opts.output_prefix);
    let mut session = build_session(&opts)?;
    let is_root = opts.multiproc.as_ref().map_or(true, |m| m.rank == 0);
    if opts.recovery.max_restarts > 0 {
        // Applies to fresh and resumed sessions alike: recovery is a
        // runtime knob, never restored from a checkpoint.
        session.set_recovery(opts.recovery.clone());
        eprintln!(
            "rank-failure recovery on: up to {} window restart(s), {:?} base backoff",
            opts.recovery.max_restarts, opts.recovery.backoff
        );
    }
    if opts.checkpoint_every > 0 {
        if is_root {
            session.set_checkpoint_every(opts.checkpoint_every, &opts.output_prefix);
            eprintln!(
                "checkpointing every {} epochs to {}.epoch<k>.somc",
                opts.checkpoint_every, opts.output_prefix
            );
            if opts.keep_last > 0 {
                session.set_checkpoint_keep_last(opts.keep_last);
                eprintln!(
                    "retaining only the newest {} checkpoints (--keep-last)",
                    opts.keep_last
                );
            }
        } else {
            eprintln!("--checkpoint-every ignored on this rank (rank 0 owns checkpoints)");
        }
    }

    // The effective config: resumed sessions take map/schedule/kernel
    // settings from the checkpoint, so dispatch on the session's view,
    // not the raw flags. Fail config conflicts (e.g. --io mmap with
    // --prefetch) before any file is opened or mapped.
    let cfg = session.config().clone();
    cfg.validate()?;

    // Binary containers (written by `somoclu convert`) are detected by
    // magic and always stream — there is no reason to materialize them.
    let binary_kind = binary::sniff(&opts.input_file)
        .map_err(|e| anyhow::anyhow!("{}: {e}", opts.input_file))?;
    let streaming = cfg.chunk_rows > 0 || binary_kind.is_some();
    // The zero-copy backends are defined over the binary container only;
    // refuse early (covering the resident path too) instead of silently
    // falling back on text inputs.
    anyhow::ensure!(
        cfg.io_mode == IoMode::Buffered || binary_kind.is_some(),
        cfg.io_mode.text_input_error()
    );

    // Interim snapshots (paper -s) for the single-process paths.
    let mut on_epoch =
        |s: &SomSession| -> Result<(), SomError> { s.write_epoch_snapshot(&writer) };

    let t0 = std::time::Instant::now();
    let result = if let Some(mp) = &opts.multiproc {
        // Real multi-process run: this process is one rank; the data
        // file must be readable at the same path by every rank.
        let path = PathBuf::from(&opts.input_file);
        let input = if binary_kind.is_some() {
            StreamInput::Binary { path }
        } else if cfg.kernel == KernelType::SparseCpu {
            StreamInput::SparseText { path, min_cols: 0 }
        } else {
            StreamInput::DenseText { path }
        };
        eprintln!(
            "rank {} of {}: rendezvous with peers ({} collective)",
            mp.rank,
            cfg.ranks,
            cfg.collective.as_str()
        );
        let (res, report) = session.fit_cluster_net(input, mp)?;
        print_comm_report(&report);
        match res {
            Some(r) => r,
            None => {
                eprintln!(
                    "rank {} done after epoch {}; outputs are written by rank 0",
                    mp.rank,
                    session.epoch()
                );
                return Ok(());
            }
        }
    } else if cfg.ranks > 1 && streaming {
        // Out-of-core cluster path: every rank opens its own disjoint
        // row window of the input file — the full data set is never
        // resident anywhere.
        let path = PathBuf::from(&opts.input_file);
        let input = if binary_kind.is_some() {
            StreamInput::Binary { path }
        } else if cfg.kernel == KernelType::SparseCpu {
            StreamInput::SparseText { path, min_cols: 0 }
        } else {
            StreamInput::DenseText { path }
        };
        eprintln!(
            "streaming {} per-rank shards ({} chunks each, --io {}{})",
            cfg.ranks,
            chunk_desc(cfg.chunk_rows),
            cfg.io_mode.as_str(),
            if cfg.prefetch { ", prefetched" } else { "" }
        );
        let (res, report) = session.fit_cluster_stream(input)?;
        print_comm_report(&report);
        res
    } else if cfg.ranks == 1 && streaming {
        // Out-of-core single-process path: never materialize the full
        // data set — binary inputs seek-read chunks, text inputs are
        // re-parsed per epoch in `--chunk-rows` windows.
        let mut src = open_stream_source(
            &opts.input_file,
            binary_kind,
            cfg.kernel,
            cfg.chunk_rows,
            cfg.prefetch,
            cfg.io_mode,
            false,
        )?;
        session.fit_source_with(&mut *src, &mut on_epoch)?
    } else if cfg.kernel == KernelType::SparseCpu {
        let m = read_sparse(&opts.input_file, 0)?;
        eprintln!(
            "loaded sparse input: {} rows x {} dims, {:.2}% nonzero",
            m.rows,
            m.cols,
            m.density() * 100.0
        );
        if cfg.ranks > 1 {
            let (res, report) = session.fit_cluster(ClusterData::Sparse(m))?;
            print_comm_report(&report);
            res
        } else {
            let mut src =
                InMemorySource::new(DataShard::Sparse(m.view()), cfg.chunk_rows);
            session.fit_source_with(&mut src, &mut on_epoch)?
        }
    } else {
        let m = read_dense(&opts.input_file)?;
        eprintln!("loaded dense input: {} rows x {} dims", m.rows, m.cols);
        if cfg.ranks > 1 {
            let (res, report) = session.fit_cluster(ClusterData::Dense {
                data: m.data,
                dim: m.cols,
            })?;
            print_comm_report(&report);
            res
        } else {
            let mut src = InMemorySource::new(
                DataShard::Dense {
                    data: &m.data,
                    dim: m.cols,
                },
                cfg.chunk_rows,
            );
            session.fit_source_with(&mut src, &mut on_epoch)?
        }
    };

    // One final-output write for every path (cluster runs do not stream
    // snapshots; single-process runs wrote those per epoch above).
    writer.write_final(session.grid(), &result.codebook, &result.bmus, &result.umatrix)?;

    if opts.verbose {
        for e in &result.epochs {
            eprintln!(
                "epoch {:>3}  radius {:>7.3}  scale {:>6.4}  QE {:>10.6}  ({:?})",
                e.epoch, e.radius, e.scale, e.qe, e.duration
            );
        }
    }
    let kernel_name = match cfg.kernel {
        KernelType::DenseCpu => "dense-cpu",
        KernelType::Accel => "accel-xla",
        KernelType::SparseCpu => "sparse-cpu",
        KernelType::Hybrid => "hybrid-xla-cpu",
    };
    // Which BMU-search microkernel the runtime dispatch resolved
    // (scalar / avx2+fma; `SOMOCLU_FORCE_SCALAR=1` forces scalar) —
    // the observable handle the README's Performance section documents.
    eprintln!(
        "BMU search kernel: {}",
        somoclu::kernels::simd::active_kernel_name()
    );
    if result.epochs.is_empty() {
        // A --resume of an already-complete run: no epoch trained, the
        // BMUs were re-projected against the input (final_qe would be
        // NaN on an empty history — don't alarm scripts with it).
        eprintln!(
            "schedule already complete — re-projected {} BMUs on the {}x{} \
             map with the {} kernel in {:?} (0 new epochs)",
            result.bmus.len(),
            cfg.rows,
            cfg.cols,
            kernel_name,
            t0.elapsed(),
        );
    } else {
        eprintln!(
            "trained {} epochs on a {}x{} {:?}/{:?} map with the {} kernel in {:?}; final QE {:.6}",
            result.epochs.len(),
            cfg.rows,
            cfg.cols,
            cfg.grid_type,
            cfg.map_type,
            kernel_name,
            t0.elapsed(),
            result.final_qe()
        );
    }
    let map_peak = somoclu::util::memtrack::data_map_peak();
    eprintln!(
        "peak data-buffer memory: {} (heap peak {}{})",
        somoclu::util::memtrack::fmt_bytes(somoclu::util::memtrack::data_buffer_peak()),
        somoclu::util::memtrack::fmt_bytes(somoclu::util::memtrack::peak_bytes()),
        if map_peak > 0 {
            format!(
                ", peak mapped chunk views {}",
                somoclu::util::memtrack::fmt_bytes(map_peak)
            )
        } else {
            String::new()
        },
    );
    eprintln!(
        "wrote {p}.wts, {p}.bm, {p}.umx",
        p = opts.output_prefix
    );
    Ok(())
}
