//! # somoclu-rs — parallel self-organizing maps (paper reproduction)
//!
//! Reproduction of *Somoclu: An Efficient Parallel Library for
//! Self-Organizing Maps* (Wittek et al.) as a three-layer
//! Rust + JAX + Pallas system:
//!
//! * **L3 (this crate)** — the coordinator: threaded CPU kernels, a
//!   simulated-MPI cluster runtime, the full somoclu CLI, file formats,
//!   and the training loop.
//! * **L2/L1 (python/, build time only)** — the batch-SOM epoch step in
//!   JAX calling Pallas kernels, AOT-lowered to HLO text artifacts that
//!   [`runtime`] executes through the PJRT CPU client (the paper's GPU
//!   kernel, re-thought for the MXU — see DESIGN.md).
//!
//! Entry points — the **single facade**: [`session::Som::builder`] for
//! library use (one builder-driven construction path over
//! resident/streamed/cluster training, incremental epochs, inference,
//! and checkpoint/resume), the `somoclu` binary with its `train` /
//! `serve` / `ensemble` / `quality` / `convert` / `info` subcommands
//! for the paper's CLI, [`serve`] for the long-lived checkpoint-serving
//! daemon with its training job queue, and [`ensemble`] for
//! statistically combined multi-map clustering with consensus labels
//! and per-sample agreement scores. The pre-session free-function entry points
//! (`api::train`, `coordinator::train::{train, train_stream}`,
//! `cluster::runner::{train_cluster, train_cluster_stream}`) are gone
//! as of 0.2; every path constructs a [`session::SomSession`]. Errors
//! crossing the public session/serve surface are typed
//! [`error::SomError`] values with stable machine-readable codes.

pub mod api;
pub mod baseline;
pub mod cli;
pub mod cluster;
pub mod coordinator;
pub mod data;
pub mod ensemble;
pub mod error;
pub mod io;
pub mod kernels;
pub mod runtime;
pub mod serve;
pub mod session;
pub mod som;
pub mod sparse;
pub mod util;
pub mod viz;

/// Allocation tracking drives the paper's memory claims (Figs. 6–7); the
/// wrapper adds two relaxed atomics per alloc, invisible next to the
/// training arithmetic.
#[global_allocator]
static ALLOC: util::memtrack::TrackingAlloc = util::memtrack::TrackingAlloc;
