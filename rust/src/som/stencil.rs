//! Stencil/window neighborhood tables — the per-epoch precomputation
//! behind the windowed Phase B accumulator (ISSUE 5 tentpole).
//!
//! The batch update's weight `h(d(bmu, node); r)·scale` depends only on
//! the *grid displacement* between the BMU and the node (plus, on
//! hexagonal grids, which rows are involved — see below), and the
//! paper's §3.1 radius thresholding zeroes it beyond
//! [`Neighborhood::cutoff`]. So once per accumulation pass we can tabulate
//! every weight the sweep could ever apply over the O(r²) displacement
//! window, and each node then gathers only from BMUs whose window
//! reaches it: Phase B drops from O(N·B·D) to O(Σ_b window(b)·D) ≈
//! O(B·r²·D). The gather (in `kernels::dense_cpu`) visits contributing
//! BMUs in ascending node-index order, so the f32 summation order — and
//! therefore every output bit — is identical to the full sweep's.
//!
//! ## Why hexagonal tables are keyed by the node's own row
//!
//! Square-grid coordinates are small integers, and toroid spans are too,
//! so every axis delta the full sweep computes (`|xa−xb|`,
//! `span−dx`) is *exact* in f32 — the distance truly is a function of
//! the wrapped (|Δrow|, |Δcol|) displacement, and one shared table
//! serves every node. Hexagonal y-coordinates are `row · √3/2` rounded
//! to f32, and the rounded difference `f32(a·s) − f32(b·s)` is **not** a
//! function of `a−b` alone (measured: thousands of bit mismatches vs a
//! displacement-keyed value on a 200-row map). A table keyed by row
//! *parity* — the obvious choice, since the x-offset only depends on
//! parity — would therefore be bit-*close* but not bit-*identical*.
//! Keying the table by the node's actual row (one `n_dr × n_dc` block
//! per row) uses the very coordinates the sweep uses and restores exact
//! equality; the x-axis side stays displacement-keyed because
//! `c + 0.5·parity` arithmetic is exact (halves are representable).
//!
//! Construction cost is O(rows · r²) weight evaluations per pass
//! (square: O(r²)), amortized against the O(B·r²·D) gather it enables.

use crate::som::grid::{AxisExtent, AxisIntervals, Grid, GridType, MapType};
use crate::som::Neighborhood;

/// Precomputed neighborhood-weight tables over the displacement window
/// of one accumulation pass (one `(radius, scale)` point of the cooling
/// schedule).
///
/// Built by [`NeighborhoodStencil::build`]; consumed by the windowed
/// Phase B in `kernels::dense_cpu::accumulate_node_parallel_ext`.
#[derive(Clone, Debug)]
pub struct NeighborhoodStencil {
    rows: usize,
    cols: usize,
    row_ext: AxisExtent,
    col_ext: AxisExtent,
    n_dr: usize,
    n_dc: usize,
    /// `blocks × n_dr × n_dc` weights, where `blocks` is 1 on square
    /// grids (displacement-keyed) and `rows` on hexagonal grids (keyed
    /// by the node's own row). A zero entry means "the sweep would skip
    /// this pair". **Empty in lazy mode** — workers materialize one
    /// row's block at a time via [`Self::fill_row_block`].
    table: Vec<f32>,
    per_row: bool,
    /// Lazy mode: the eager per-row table would exceed
    /// [`MAX_TABLE_CELLS_PER_NODE`] cells per node, so no table is
    /// precomputed; Phase B workers fill one `window_cells()` block on
    /// demand as they advance through node rows.
    lazy: bool,
    /// The weight function's inputs, kept for lazy block fills (the
    /// same values the key hashes).
    nb: Neighborhood,
    radius: f32,
    scale: f32,
    /// Everything the table contents depend on (see [`Self::matches`]).
    key: StencilKey,
}

/// The full set of inputs a stencil's tables are a function of.
type StencilKey = (
    usize,        // grid rows
    usize,        // grid cols
    GridType,
    MapType,
    Neighborhood,
    u32,          // radius bits
    u32,          // scale bits
);

fn stencil_key(grid: &Grid, nb: Neighborhood, radius: f32, scale: f32) -> StencilKey {
    (
        grid.rows,
        grid.cols,
        grid.grid_type,
        grid.map_type,
        nb,
        radius.to_bits(),
        scale.to_bits(),
    )
}

impl NeighborhoodStencil {
    /// Build the window tables for one pass, or `None` when windowing
    /// cannot win: the displacement window has at least as many cells
    /// as the lattice (early epochs, where the cooling radius spans the
    /// map — or a non-compact gaussian whose 7.5·r cutoff exceeds the
    /// span), so each node's gather would visit everything anyway. The
    /// caller should then run the dense full sweep, which pays no table
    /// construction and no interval bookkeeping.
    ///
    /// A second regime exists on hexagonal grids, whose tables carry a
    /// per-row block: a window well under the lattice size can still
    /// demand a `rows ×` larger table. When the total table would
    /// exceed [`MAX_TABLE_CELLS_PER_NODE`] cells per lattice node
    /// (multi-GB tables and O(rows·r²) construction on large maps at
    /// mid-schedule radii), the stencil is returned in **lazy mode**
    /// ([`Self::is_lazy`]): no table is precomputed, and each Phase B
    /// worker fills one row's `window_cells()` block on demand with
    /// [`Self::fill_row_block`] as it advances through node rows —
    /// O(window) scratch per worker, ~`rows + threads` block fills per
    /// pass, same per-entry arithmetic bit for bit. Before lazy mode
    /// these configurations fell back to the dense sweep.
    pub fn build(grid: &Grid, nb: Neighborhood, radius: f32, scale: f32) -> Option<Self> {
        let cutoff = nb.cutoff(radius);
        let row_ext = grid.row_extent(cutoff);
        let col_ext = grid.col_extent(cutoff);
        let n_dr = row_ext.slots(grid.rows);
        let n_dc = col_ext.slots(grid.cols);
        let per_row = grid.grid_type == GridType::Hexagonal;
        let blocks = if per_row { grid.rows } else { 1 };
        let window_cells = n_dr.saturating_mul(n_dc);
        if window_cells >= grid.node_count() {
            return None;
        }
        let lazy = window_cells.saturating_mul(blocks)
            >= grid.node_count().saturating_mul(MAX_TABLE_CELLS_PER_NODE);

        let mut st = NeighborhoodStencil {
            rows: grid.rows,
            cols: grid.cols,
            row_ext,
            col_ext,
            n_dr,
            n_dc,
            table: Vec::new(),
            per_row,
            lazy,
            nb,
            radius,
            scale,
            key: stencil_key(grid, nb, radius, scale),
        };
        if !lazy {
            let mut table = vec![0.0f32; blocks * n_dr * n_dc];
            for (block, chunk) in table.chunks_exact_mut(n_dr * n_dc).enumerate() {
                st.fill_block_into(grid, block, chunk);
            }
            st.table = table;
        }
        Some(st)
    }

    /// Fill one block's weights into `chunk` (`n_dr × n_dc` entries,
    /// zeroed first) — the single shared table-entry arithmetic behind
    /// both the eager build and lazy per-worker fills, so the two modes
    /// are bit-identical by construction.
    fn fill_block_into(&self, grid: &Grid, block: usize, chunk: &mut [f32]) {
        chunk.fill(0.0);
        for sr in 0..self.n_dr {
            // Representative row pair for this slot: the node row and
            // the BMU row it reaches. Hexagonal blocks pin the node
            // row to the block's row; square grids pick any in-range
            // pair with the right displacement (the distance is an
            // exact function of it — module docs).
            let Some((ra, rb)) =
                rep_pair(self.row_ext, block, self.per_row, sr, grid.rows, grid.map_type)
            else {
                continue;
            };
            let row = &mut chunk[sr * self.n_dc..(sr + 1) * self.n_dc];
            for (sc, slot) in row.iter_mut().enumerate() {
                let Some((ca, cb)) =
                    rep_pair(self.col_ext, 0, false, sc, grid.cols, grid.map_type)
                else {
                    continue;
                };
                // Same argument order as the sweep: distance(bmu, node).
                let d = grid.distance(grid.index(rb, cb), grid.index(ra, ca));
                *slot = self.nb.table_entry(d, self.radius, self.scale);
            }
        }
    }

    /// True when no table was precomputed ([`Self::build`]'s per-row
    /// size cap): Phase B workers must materialize blocks on demand via
    /// [`Self::fill_row_block`] + [`Self::table_row_in`] instead of
    /// calling [`Self::table_row`].
    #[inline]
    pub fn is_lazy(&self) -> bool {
        self.lazy
    }

    /// Materialize node row `rn`'s weight block into `out`
    /// (`window_cells()` entries) — the lazy-mode counterpart of the
    /// eager table lookup. Valid in both modes (eager callers get the
    /// same bits the table holds); workers advancing through ascending
    /// node ranges refill only when the node row changes, so a pass
    /// performs about `rows + threads` fills in total.
    pub fn fill_row_block(&self, grid: &Grid, rn: usize, out: &mut [f32]) {
        assert_eq!(out.len(), self.window_cells(), "block buffer size mismatch");
        let block = if self.per_row { rn } else { 0 };
        self.fill_block_into(grid, block, out);
    }

    /// The weight row for row slot `slot_r` inside a caller-held block
    /// buffer previously filled by [`Self::fill_row_block`], indexed by
    /// column slot. Zero entries are "skip".
    #[inline]
    pub fn table_row_in<'a>(&self, buf: &'a [f32], slot_r: usize) -> &'a [f32] {
        &buf[slot_r * self.n_dc..(slot_r + 1) * self.n_dc]
    }

    /// True when this stencil was built for exactly these inputs — the
    /// precondition for using it in an accumulation pass. Distances
    /// depend only on the grid's shape/type, so two `Grid` values with
    /// equal dimensions share tables safely.
    pub fn matches(&self, grid: &Grid, nb: Neighborhood, radius: f32, scale: f32) -> bool {
        self.key == stencil_key(grid, nb, radius, scale)
    }

    /// Row-axis window shape (feed to [`Grid::axis_intervals`]).
    pub fn row_ext(&self) -> AxisExtent {
        self.row_ext
    }

    /// Column-axis window shape (feed to [`Grid::axis_intervals`]).
    pub fn col_ext(&self) -> AxisExtent {
        self.col_ext
    }

    /// Displacement cells per node gather (`n_dr · n_dc`) — the `r²`
    /// factor of the stencil complexity, reported by benches/tests.
    pub fn window_cells(&self) -> usize {
        self.n_dr * self.n_dc
    }

    /// The weight row for (node row `rn`, row slot `slot_r`), indexed by
    /// column slot. Zero entries are "skip". Eager mode only — lazy
    /// stencils hold no table; use [`Self::fill_row_block`] +
    /// [`Self::table_row_in`].
    #[inline]
    pub fn table_row(&self, rn: usize, slot_r: usize) -> &[f32] {
        debug_assert!(!self.lazy, "table_row on a lazy stencil (use fill_row_block)");
        let block = if self.per_row { rn } else { 0 };
        let off = (block * self.n_dr + slot_r) * self.n_dc;
        &self.table[off..off + self.n_dc]
    }

    /// Physical BMU rows reachable from node row `rn`, ascending.
    #[inline]
    pub fn row_intervals(&self, grid: &Grid, rn: usize) -> AxisIntervals {
        grid.axis_intervals(rn, self.row_ext, self.rows)
    }

    /// Physical BMU columns reachable from node column `cn`, ascending.
    #[inline]
    pub fn col_intervals(&self, grid: &Grid, cn: usize) -> AxisIntervals {
        grid.axis_intervals(cn, self.col_ext, self.cols)
    }
}

/// One-slot memo over [`NeighborhoodStencil::build`]. Chunked/streamed
/// training runs one accumulation per chunk with identical
/// `(grid, neighborhood, radius, scale)` across a whole epoch; without
/// a memo every chunk would rebuild the same tables — on hexagonal
/// grids up to [`MAX_TABLE_CELLS_PER_NODE`]·nodes weight evaluations,
/// which for small chunks can rival the gather itself. Each CPU kernel
/// owns one and hands the resolved decision to
/// `kernels::dense_cpu::accumulate_node_parallel_with`. A "this window
/// covers the lattice, run the dense sweep" outcome is memoized too.
#[derive(Default, Debug)]
pub struct StencilCache {
    key: Option<StencilKey>,
    value: Option<NeighborhoodStencil>,
}

impl StencilCache {
    pub fn new() -> Self {
        Self::default()
    }

    /// The Phase B decision for this pass — `Some` windowed tables or
    /// `None` (dense sweep) — rebuilding only when the inputs changed
    /// since the previous call.
    ///
    /// `scale <= 0.0` (the default `TrainingKernel::project` path)
    /// returns `None` without touching the memo: the accumulator
    /// short-circuits such passes to all-zero output anyway, and
    /// building (then evicting the training-radius entry for) an
    /// all-zero table would make every project/train interleave rebuild
    /// tables twice.
    pub fn get(
        &mut self,
        grid: &Grid,
        nb: Neighborhood,
        radius: f32,
        scale: f32,
    ) -> Option<&NeighborhoodStencil> {
        if scale <= 0.0 {
            return None;
        }
        let key = stencil_key(grid, nb, radius, scale);
        if self.key != Some(key) {
            self.value = NeighborhoodStencil::build(grid, nb, radius, scale);
            self.key = Some(key);
        }
        self.value.as_ref()
    }
}

/// Table-size guard for [`NeighborhoodStencil::build`]: switch to lazy
/// per-worker row blocks when the precomputed table would exceed this
/// many cells per lattice node. Only hexagonal grids (whose tables
/// carry a per-row block) can hit it before the window-vs-lattice check
/// does; at 16 the eager table stays within the accumulators' own
/// O(nodes·dim) memory scale (≤ 64 bytes/node) and construction stays a
/// few weight evaluations per node. Beyond the cap, lazy mode keeps the
/// windowed gather (instead of the old dense-sweep fallback) at
/// O(window) scratch per worker and ~`rows + threads` block fills per
/// pass — large hex maps at mid-schedule radii stay windowed.
pub const MAX_TABLE_CELLS_PER_NODE: usize = 16;

/// Representative (node index, BMU index) pair along one axis for table
/// slot `slot`: both in `[0, len)`, with the BMU at the slot's canonical
/// displacement from the node. `None` when a planar window slot sticks
/// out past the axis edge (such slots are unreachable by construction —
/// `Grid::axis_intervals` clips to the lattice — so their entries stay
/// zero). `pin` fixes the node index (hexagonal per-row blocks); square
/// grids pass `pinned = false` and any in-range pair works.
fn rep_pair(
    ext: AxisExtent,
    pin: usize,
    pinned: bool,
    slot: usize,
    len: usize,
    map: MapType,
) -> Option<(usize, usize)> {
    match ext {
        AxisExtent::Full => {
            let a = if pinned { pin } else { 0 };
            Some((a, (a + slot) % len))
        }
        AxisExtent::Window { half } => {
            let d = slot as isize - half as isize;
            if pinned {
                let b = pin as isize + d;
                match map {
                    MapType::Toroid => Some((pin, b.rem_euclid(len as isize) as usize)),
                    MapType::Planar => (0..len as isize)
                        .contains(&b)
                        .then_some((pin, b as usize)),
                }
            } else {
                match map {
                    MapType::Toroid => Some((0, d.rem_euclid(len as isize) as usize)),
                    MapType::Planar => {
                        let a = d.min(0).unsigned_abs();
                        let b = a as isize + d;
                        (a < len && (0..len as isize).contains(&b))
                            .then_some((a, b as usize))
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::som::grid::{GridType, MapType};

    fn combos() -> Vec<Grid> {
        let mut v = Vec::new();
        for (r, c) in [(6, 5), (5, 8), (1, 7), (9, 1), (3, 12)] {
            for gt in [GridType::Square, GridType::Hexagonal] {
                for mt in [MapType::Planar, MapType::Toroid] {
                    v.push(Grid::new(r, c, gt, mt));
                }
            }
        }
        v
    }

    fn neighborhoods() -> [Neighborhood; 3] {
        [
            Neighborhood::gaussian(false),
            Neighborhood::gaussian(true),
            Neighborhood::bubble(),
        ]
    }

    /// The defining invariant: for every node, every BMU its window
    /// reaches carries EXACTLY the weight the full sweep would compute,
    /// and every BMU its window misses would be skipped by the sweep.
    #[test]
    fn table_matches_direct_weights_bitwise_and_covers_cutoff() {
        let mut built = 0usize;
        for grid in combos() {
            for nb in neighborhoods() {
                for radius in [0.4f32, 1.0, 1.7, 2.5] {
                    let scale = 0.83f32;
                    let Some(st) = NeighborhoodStencil::build(&grid, nb, radius, scale)
                    else {
                        continue;
                    };
                    built += 1;
                    let cutoff = nb.cutoff(radius);
                    for node in 0..grid.node_count() {
                        let (rn, cn) = grid.position(node);
                        let mut reached = vec![false; grid.node_count()];
                        for riv in st.row_intervals(&grid, rn).as_slice() {
                            for rb in riv.start..riv.end {
                                let trow = st.table_row(rn, riv.slot0 + (rb - riv.start));
                                for civ in st.col_intervals(&grid, cn).as_slice() {
                                    for cb in civ.start..civ.end {
                                        let b = grid.index(rb, cb);
                                        reached[b] = true;
                                        let got = trow[civ.slot0 + (cb - civ.start)];
                                        let want =
                                            nb.table_entry(grid.distance(b, node), radius, scale);
                                        assert_eq!(
                                            got.to_bits(),
                                            want.to_bits(),
                                            "entry ({b},{node}) {got} != {want} on \
                                             {:?}/{:?} r={radius}",
                                            grid.grid_type,
                                            grid.map_type,
                                        );
                                    }
                                }
                            }
                        }
                        for (b, &r) in reached.iter().enumerate() {
                            if !r {
                                assert!(
                                    grid.distance(b, node) > cutoff,
                                    "window missed in-cutoff pair ({b},{node})"
                                );
                            }
                        }
                    }
                }
            }
        }
        assert!(built > 30, "too few windowed cases exercised: {built}");
    }

    #[test]
    fn build_declines_when_window_covers_lattice() {
        // Non-compact gaussian: cutoff 7.5·r spans any small map.
        let g = Grid::new(16, 16, GridType::Square, MapType::Planar);
        assert!(NeighborhoodStencil::build(&g, Neighborhood::gaussian(false), 2.0, 1.0).is_none());
        // Same radius with compact support: window is a small disc.
        assert!(NeighborhoodStencil::build(&g, Neighborhood::gaussian(true), 2.0, 1.0).is_some());
        // Early-epoch radius half the map: window ≥ lattice, dense wins.
        let big = Grid::new(16, 16, GridType::Square, MapType::Toroid);
        assert!(NeighborhoodStencil::build(&big, Neighborhood::bubble(), 8.0, 1.0).is_none());
        assert!(NeighborhoodStencil::build(&big, Neighborhood::bubble(), 2.0, 1.0).is_some());
    }

    #[test]
    fn window_cells_scale_with_radius_not_map() {
        let small = Grid::new(16, 16, GridType::Square, MapType::Toroid);
        let large = Grid::new(64, 64, GridType::Square, MapType::Toroid);
        let st_s = NeighborhoodStencil::build(&small, Neighborhood::bubble(), 2.0, 1.0).unwrap();
        let st_l = NeighborhoodStencil::build(&large, Neighborhood::bubble(), 2.0, 1.0).unwrap();
        assert_eq!(st_s.window_cells(), st_l.window_cells());
        assert!(st_l.window_cells() < large.node_count() / 40);
    }

    #[test]
    fn zero_scale_tables_are_all_zero() {
        // The project() path accumulates with scale 0: every entry must
        // be a skip, exactly like the sweep's `h <= 0` guard. (12x12:
        // an 8x8 toroid's r=2 window degrades to Full on both axes and
        // build declines — see build_declines_when_window_covers_lattice.)
        let g = Grid::new(12, 12, GridType::Hexagonal, MapType::Toroid);
        let st = NeighborhoodStencil::build(&g, Neighborhood::gaussian(true), 2.0, 0.0).unwrap();
        for rn in 0..12 {
            for sr in 0..st.row_ext().slots(12) {
                assert!(st.table_row(rn, sr).iter().all(|&h| h == 0.0));
            }
        }
    }

    #[test]
    fn cache_memoizes_and_invalidates_per_input() {
        let g = Grid::new(16, 16, GridType::Square, MapType::Toroid);
        let nb = Neighborhood::gaussian(true);
        let mut cache = StencilCache::new();
        // Windowed decision, memoized: repeated gets agree with a fresh
        // build bit-for-bit.
        let fresh = NeighborhoodStencil::build(&g, nb, 2.0, 0.5).unwrap();
        for _ in 0..3 {
            let st = cache.get(&g, nb, 2.0, 0.5).expect("windowed");
            assert!(st.matches(&g, nb, 2.0, 0.5));
            assert_eq!(st.table, fresh.table);
        }
        // Any input change re-keys: a new radius...
        let st = cache.get(&g, nb, 1.0, 0.5).expect("windowed");
        assert!(st.matches(&g, nb, 1.0, 0.5) && !st.matches(&g, nb, 2.0, 0.5));
        // ...a new scale...
        assert!(cache.get(&g, nb, 1.0, 0.25).unwrap().matches(&g, nb, 1.0, 0.25));
        // Zero-scale (project) passes get None and do not thrash the
        // memo: the previous entry answers the next training call.
        assert!(cache.get(&g, nb, 1.0, 0.0).is_none());
        assert!(cache.get(&g, nb, 1.0, 0.25).unwrap().matches(&g, nb, 1.0, 0.25));
        // ...and a dense-sweep outcome (radius spanning the map) is
        // memoized as None, then flips back.
        assert!(cache.get(&g, nb, 9.0, 0.5).is_none());
        assert!(cache.get(&g, nb, 9.0, 0.5).is_none());
        assert!(cache.get(&g, nb, 2.0, 0.5).is_some());
        // An equal-shape different Grid value shares the tables (the
        // key is geometric, not by address).
        let g2 = Grid::new(16, 16, GridType::Square, MapType::Toroid);
        assert!(cache.get(&g2, nb, 2.0, 0.5).unwrap().matches(&g, nb, 2.0, 0.5));
    }

    #[test]
    fn hex_oversized_per_row_tables_go_lazy() {
        // Hexagonal tables carry a per-row block: a window that is
        // smaller than the lattice can still demand a rows× larger
        // table. Past the MAX_TABLE_CELLS_PER_NODE cap such configs now
        // build in lazy mode (no precomputed table, per-worker row
        // blocks) instead of falling back to the dense sweep; the same
        // geometry on a square grid (one shared block) eagerly windows.
        let hex = Grid::new(200, 200, GridType::Hexagonal, MapType::Planar);
        let sq = Grid::new(200, 200, GridType::Square, MapType::Planar);
        let nb = Neighborhood::gaussian(true);
        // r=40: window ~95x85 ≈ 8k cells < 40k nodes, but 200 hex blocks
        // would make ~1.6M table cells ≥ 16 * 40k.
        let st = NeighborhoodStencil::build(&hex, nb, 40.0, 1.0).expect("lazy window");
        assert!(st.is_lazy());
        let st_sq = NeighborhoodStencil::build(&sq, nb, 40.0, 1.0).unwrap();
        assert!(!st_sq.is_lazy());
        // Lazy blocks carry EXACTLY the weights the sweep computes:
        // sample a few node rows and verify per-entry bit-equality via
        // the window intervals.
        let radius = 40.0f32;
        let mut buf = vec![0.0f32; st.window_cells()];
        for rn in [0usize, 97, 199] {
            st.fill_row_block(&hex, rn, &mut buf);
            let cn = 100usize;
            let node = hex.index(rn, cn);
            for riv in st.row_intervals(&hex, rn).as_slice() {
                for rb in (riv.start..riv.end).step_by(13) {
                    let trow = st.table_row_in(&buf, riv.slot0 + (rb - riv.start));
                    for civ in st.col_intervals(&hex, cn).as_slice() {
                        for cb in (civ.start..civ.end).step_by(17) {
                            let b = hex.index(rb, cb);
                            let got = trow[civ.slot0 + (cb - civ.start)];
                            let want = nb.table_entry(hex.distance(b, node), radius, 1.0);
                            assert_eq!(got.to_bits(), want.to_bits(), "entry ({b},{node})");
                        }
                    }
                }
            }
        }
        // Small radii — the regime the eager table exists for — still
        // precompute on hex.
        let st = NeighborhoodStencil::build(&hex, nb, 4.0, 1.0).unwrap();
        assert!(!st.is_lazy());
        assert!(st.window_cells() * hex.rows < hex.node_count() * MAX_TABLE_CELLS_PER_NODE);
    }

    #[test]
    fn lazy_and_eager_blocks_are_bit_identical() {
        // fill_row_block is valid in eager mode too and must reproduce
        // the precomputed table exactly — the bridge invariant that lets
        // the equivalence suite trust either path.
        for grid in combos() {
            for nb in neighborhoods() {
                let Some(st) = NeighborhoodStencil::build(&grid, nb, 1.7, 0.83) else {
                    continue;
                };
                assert!(!st.is_lazy(), "small maps stay eager");
                let mut buf = vec![0.0f32; st.window_cells()];
                for rn in 0..grid.rows {
                    st.fill_row_block(&grid, rn, &mut buf);
                    for sr in 0..st.row_ext().slots(grid.rows) {
                        let eager = st.table_row(rn, sr);
                        let lazy = st.table_row_in(&buf, sr);
                        for (a, b) in eager.iter().zip(lazy) {
                            assert_eq!(a.to_bits(), b.to_bits());
                        }
                    }
                }
            }
        }
    }
}
