//! The code book W (Eq. 1): one weight vector per neuron, dense f32.
//!
//! "Storing the code book in memory is the primary constraint for single
//! node execution" (§3.2) — so this is a single flat allocation, shared
//! read-only across worker threads during BMU search (the OpenMP memory
//! model the paper credits for its ≥50% memory reduction), and updated in
//! place at the end of each epoch.

use crate::som::grid::Grid;
use crate::util::rng::Rng;

/// Dense row-major [nodes x dim] weight matrix.
#[derive(Clone, Debug)]
pub struct Codebook {
    pub nodes: usize,
    pub dim: usize,
    pub weights: Vec<f32>,
}

impl Codebook {
    pub fn zeros(nodes: usize, dim: usize) -> Self {
        Codebook {
            nodes,
            dim,
            weights: vec![0.0; nodes * dim],
        }
    }

    /// Random initialization uniform in [-1, 1) per component — classic
    /// somoclu's default (`-c` absent).
    pub fn random_init(nodes: usize, dim: usize, rng: &mut Rng) -> Self {
        let weights = (0..nodes * dim)
            .map(|_| rng.range_f32(-1.0, 1.0))
            .collect();
        Codebook { nodes, dim, weights }
    }

    /// Initialize by sampling data rows (kohonen-style init; needs
    /// nodes <= rows, which the paper notes makes emergent maps
    /// impossible in the R package — we allow it and fall back to random
    /// for the surplus nodes).
    pub fn sample_init(
        nodes: usize,
        dim: usize,
        data: &[f32],
        rows: usize,
        rng: &mut Rng,
    ) -> Self {
        let mut cb = Codebook::zeros(nodes, dim);
        let k = nodes.min(rows);
        let picks = rng.sample_indices(rows, k);
        for (node, &row) in picks.iter().enumerate() {
            cb.row_mut(node)
                .copy_from_slice(&data[row * dim..(row + 1) * dim]);
        }
        for node in k..nodes {
            for v in cb.row_mut(node) {
                *v = rng.range_f32(-1.0, 1.0);
            }
        }
        cb
    }

    /// Linear gradient initialization across the grid between two random
    /// anchors (a cheap PCA-free structured init; keeps examples
    /// deterministic and already "unfolded").
    pub fn gradient_init(grid: &Grid, dim: usize, rng: &mut Rng) -> Self {
        let nodes = grid.node_count();
        let a: Vec<f32> = (0..dim).map(|_| rng.range_f32(-1.0, 1.0)).collect();
        let b: Vec<f32> = (0..dim).map(|_| rng.range_f32(-1.0, 1.0)).collect();
        let c: Vec<f32> = (0..dim).map(|_| rng.range_f32(-1.0, 1.0)).collect();
        let mut cb = Codebook::zeros(nodes, dim);
        let (w, h) = (grid.cols.max(2) - 1, grid.rows.max(2) - 1);
        for node in 0..nodes {
            let (r, col) = grid.position(node);
            let tx = col as f32 / w.max(1) as f32;
            let ty = r as f32 / h.max(1) as f32;
            let row = cb.row_mut(node);
            for d in 0..dim {
                row[d] = a[d] + (b[d] - a[d]) * tx + (c[d] - a[d]) * ty;
            }
        }
        cb
    }

    #[inline]
    pub fn row(&self, node: usize) -> &[f32] {
        &self.weights[node * self.dim..(node + 1) * self.dim]
    }

    #[inline]
    pub fn row_mut(&mut self, node: usize) -> &mut [f32] {
        &mut self.weights[node * self.dim..(node + 1) * self.dim]
    }

    /// Apply the batch update w_n = num_n / den_n for hit nodes (Eq. 6);
    /// unhit nodes keep their weights (somoclu behaviour).
    pub fn apply_batch_update(&mut self, num: &[f32], den: &[f32]) {
        assert_eq!(num.len(), self.nodes * self.dim);
        assert_eq!(den.len(), self.nodes);
        let dim = self.dim;
        for n in 0..self.nodes {
            let d = den[n];
            if d > 1e-12 {
                let inv = 1.0 / d;
                let row = self.row_mut(n);
                let src = &num[n * dim..(n + 1) * dim];
                for (w, s) in row.iter_mut().zip(src) {
                    *w = s * inv;
                }
            }
        }
    }

    /// Squared L2 norm per node (precomputed for Gram-trick kernels).
    pub fn sq_norms(&self) -> Vec<f32> {
        (0..self.nodes)
            .map(|n| self.row(n).iter().map(|v| v * v).sum())
            .collect()
    }

    pub fn heap_bytes(&self) -> usize {
        self.weights.capacity() * std::mem::size_of::<f32>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::som::grid::{GridType, MapType};

    #[test]
    fn random_init_in_range() {
        let mut rng = Rng::new(1);
        let cb = Codebook::random_init(10, 4, &mut rng);
        assert!(cb.weights.iter().all(|w| (-1.0..1.0).contains(w)));
    }

    #[test]
    fn batch_update_divides_and_skips_unhit() {
        let mut cb = Codebook::zeros(2, 2);
        cb.row_mut(0).copy_from_slice(&[5.0, 5.0]);
        cb.row_mut(1).copy_from_slice(&[7.0, 7.0]);
        let num = vec![2.0, 4.0, 99.0, 99.0];
        let den = vec![2.0, 0.0];
        cb.apply_batch_update(&num, &den);
        assert_eq!(cb.row(0), &[1.0, 2.0]); // updated
        assert_eq!(cb.row(1), &[7.0, 7.0]); // unhit: unchanged
    }

    #[test]
    fn sample_init_copies_rows() {
        let mut rng = Rng::new(2);
        let data = vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0];
        let cb = Codebook::sample_init(2, 2, &data, 3, &mut rng);
        for node in 0..2 {
            let row = cb.row(node);
            let found = (0..3).any(|r| row == &data[r * 2..r * 2 + 2]);
            assert!(found, "node {node} = {row:?} not a data row");
        }
    }

    #[test]
    fn gradient_init_is_smooth() {
        let grid = Grid::new(10, 10, GridType::Square, MapType::Planar);
        let mut rng = Rng::new(3);
        let cb = Codebook::gradient_init(&grid, 3, &mut rng);
        // Adjacent nodes must be closer than far-apart nodes on average.
        let d_adj = euclid(cb.row(grid.index(0, 0)), cb.row(grid.index(0, 1)));
        let d_far = euclid(cb.row(grid.index(0, 0)), cb.row(grid.index(9, 9)));
        assert!(d_adj < d_far);
    }

    fn euclid(a: &[f32], b: &[f32]) -> f32 {
        a.iter()
            .zip(b)
            .map(|(x, y)| (x - y) * (x - y))
            .sum::<f32>()
            .sqrt()
    }

    #[test]
    fn sq_norms() {
        let mut cb = Codebook::zeros(2, 2);
        cb.row_mut(0).copy_from_slice(&[3.0, 4.0]);
        assert_eq!(cb.sq_norms(), vec![25.0, 0.0]);
    }
}
