//! Cooling schedules for radius and learning rate (paper `-t`/`-T`,
//! `-r`/`-R`, `-l`/`-L`).
//!
//! Linear interpolates start→end across epochs; exponential decays
//! geometrically so that the final epoch lands exactly on the end value.

/// Cooling strategy (paper: linear is the default for both knobs).
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub enum Cooling {
    Linear,
    Exponential,
}

impl std::str::FromStr for Cooling {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "linear" => Ok(Cooling::Linear),
            "exponential" | "exp" => Ok(Cooling::Exponential),
            other => Err(format!("unknown cooling strategy: {other}")),
        }
    }
}

/// A start→end schedule over `n_epochs`.
#[derive(Copy, Clone, Debug)]
pub struct Schedule {
    pub start: f32,
    pub end: f32,
    pub cooling: Cooling,
    pub n_epochs: usize,
}

impl Schedule {
    pub fn new(start: f32, end: f32, cooling: Cooling, n_epochs: usize) -> Self {
        assert!(n_epochs > 0);
        assert!(start.is_finite() && end.is_finite());
        Schedule {
            start,
            end,
            cooling,
            n_epochs,
        }
    }

    /// Value at `epoch` ∈ [0, n_epochs): epoch 0 = start; the last epoch
    /// = end (single-epoch schedules return start).
    pub fn at(&self, epoch: usize) -> f32 {
        debug_assert!(epoch < self.n_epochs);
        if self.n_epochs == 1 {
            return self.start;
        }
        let t = epoch as f32 / (self.n_epochs - 1) as f32;
        match self.cooling {
            Cooling::Linear => self.start + (self.end - self.start) * t,
            Cooling::Exponential => {
                // start * (end/start)^t, guarded for zero/negative ends.
                let s = self.start.max(1e-6);
                let e = self.end.max(1e-6);
                s * (e / s).powf(t)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::prop;

    #[test]
    fn linear_endpoints_and_midpoint() {
        let s = Schedule::new(10.0, 1.0, Cooling::Linear, 10);
        assert_eq!(s.at(0), 10.0);
        assert_eq!(s.at(9), 1.0);
        assert!((s.at(4) - (10.0 - 9.0 * 4.0 / 9.0)).abs() < 1e-6);
    }

    #[test]
    fn exponential_endpoints() {
        let s = Schedule::new(100.0, 1.0, Cooling::Exponential, 5);
        assert!((s.at(0) - 100.0).abs() < 1e-4);
        assert!((s.at(4) - 1.0).abs() < 1e-4);
        // Geometric: constant ratio between consecutive epochs.
        let r1 = s.at(1) / s.at(0);
        let r2 = s.at(3) / s.at(2);
        assert!((r1 - r2).abs() < 1e-4);
    }

    #[test]
    fn single_epoch_is_start() {
        let s = Schedule::new(5.0, 1.0, Cooling::Linear, 1);
        assert_eq!(s.at(0), 5.0);
    }

    #[test]
    fn exponential_zero_end_guarded() {
        let s = Schedule::new(10.0, 0.0, Cooling::Exponential, 4);
        for e in 0..4 {
            assert!(s.at(e).is_finite() && s.at(e) >= 0.0);
        }
    }

    #[test]
    fn prop_monotone_and_bounded() {
        prop::check("cooling", |g| {
            let start = g.f32_in(0.5, 100.0);
            let end = g.f32_in(0.01, start);
            let cooling = *g.choice(&[Cooling::Linear, Cooling::Exponential]);
            let n = g.usize_in(2, 40);
            let s = Schedule::new(start, end, cooling, n);
            let mut prev = f32::INFINITY;
            for e in 0..n {
                let v = s.at(e);
                prop_assert!(v <= prev + 1e-4, "not decreasing at {e}: {prev} -> {v}");
                prop_assert!(
                    v <= start + 1e-4 && v >= end - 1e-4,
                    "out of range at {e}: {v} not in [{end}, {start}]"
                );
                prev = v;
            }
            prop_assert!((s.at(0) - start).abs() < 1e-3, "start endpoint");
            prop_assert!(
                (s.at(n - 1) - end).abs() < end.abs() * 1e-3 + 1e-3,
                "end endpoint: {} vs {end}",
                s.at(n - 1)
            );
            Ok(())
        });
    }

    #[test]
    fn parse() {
        assert_eq!("linear".parse::<Cooling>().unwrap(), Cooling::Linear);
        assert_eq!(
            "exponential".parse::<Cooling>().unwrap(),
            Cooling::Exponential
        );
        assert!("quadratic".parse::<Cooling>().is_err());
    }
}
