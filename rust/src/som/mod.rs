//! Batch self-organizing map core: geometry, schedules, codebook,
//! quality measures (paper §2).

pub mod codebook;
pub mod cooling;
pub mod grid;
pub mod kmeans;
pub mod neighborhood;
pub mod pca;
pub mod quality;
pub mod stencil;
pub mod umatrix;

pub use codebook::Codebook;
pub use cooling::{Cooling, Schedule};
pub use grid::{Grid, GridType, MapType};
pub use neighborhood::{Neighborhood, NeighborhoodKind};
pub use stencil::{NeighborhoodStencil, StencilCache};
