//! Map geometry: square/hexagonal grids on planar/toroid topologies.
//!
//! Nodes live on an `rows x cols` lattice; each node has 2-D coordinates
//! used for both the neighborhood function (grid distances, Eq. 5) and
//! the AOT accel kernel (which receives `coords [N, 2]` + `span [2]`
//! inputs — see python/compile/model.py). Hexagonal grids use the usual
//! offset coordinates: odd rows shifted +0.5 in x, rows √3/2 apart, which
//! is how classic somoclu computes hex distances.

/// Grid layout of the neuron lattice (paper `-g`).
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub enum GridType {
    Square,
    Hexagonal,
}

/// Map topology (paper `-m`).
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub enum MapType {
    Planar,
    Toroid,
}

impl std::str::FromStr for GridType {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "square" | "rectangular" => Ok(GridType::Square),
            "hexagonal" | "hex" => Ok(GridType::Hexagonal),
            other => Err(format!("unknown grid type: {other}")),
        }
    }
}

impl std::str::FromStr for MapType {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "planar" => Ok(MapType::Planar),
            "toroid" | "toroidal" => Ok(MapType::Toroid),
            other => Err(format!("unknown map type: {other}")),
        }
    }
}

pub const SQRT3_2: f32 = 0.866_025_4; // sqrt(3)/2

/// The neuron lattice.
#[derive(Clone, Debug)]
pub struct Grid {
    pub rows: usize,
    pub cols: usize,
    pub grid_type: GridType,
    pub map_type: MapType,
    /// Node coordinates, row-major node order, [n][0]=x, [n][1]=y.
    coords: Vec<[f32; 2]>,
    /// Wrap extent per axis for toroid distance.
    span: [f32; 2],
}

impl Grid {
    pub fn new(rows: usize, cols: usize, grid_type: GridType, map_type: MapType) -> Self {
        assert!(rows > 0 && cols > 0);
        let mut coords = Vec::with_capacity(rows * cols);
        for r in 0..rows {
            for c in 0..cols {
                let (x, y) = match grid_type {
                    GridType::Square => (c as f32, r as f32),
                    GridType::Hexagonal => (
                        c as f32 + 0.5 * (r % 2) as f32,
                        r as f32 * SQRT3_2,
                    ),
                };
                coords.push([x, y]);
            }
        }
        let span = match grid_type {
            GridType::Square => [cols as f32, rows as f32],
            GridType::Hexagonal => [cols as f32, rows as f32 * SQRT3_2],
        };
        Grid {
            rows,
            cols,
            grid_type,
            map_type,
            coords,
            span,
        }
    }

    pub fn node_count(&self) -> usize {
        self.rows * self.cols
    }

    /// Node index for (row, col).
    #[inline]
    pub fn index(&self, row: usize, col: usize) -> usize {
        debug_assert!(row < self.rows && col < self.cols);
        row * self.cols + col
    }

    /// (row, col) for node index.
    #[inline]
    pub fn position(&self, node: usize) -> (usize, usize) {
        (node / self.cols, node % self.cols)
    }

    #[inline]
    pub fn coord(&self, node: usize) -> [f32; 2] {
        self.coords[node]
    }

    pub fn coords_flat(&self) -> Vec<f32> {
        self.coords.iter().flat_map(|c| [c[0], c[1]]).collect()
    }

    pub fn span(&self) -> [f32; 2] {
        self.span
    }

    /// Grid distance between two nodes (Euclidean over coordinates,
    /// wrapped per-axis on a toroid).
    #[inline]
    pub fn distance(&self, a: usize, b: usize) -> f32 {
        let (pa, pb) = (self.coords[a], self.coords[b]);
        let mut dx = (pa[0] - pb[0]).abs();
        let mut dy = (pa[1] - pb[1]).abs();
        if self.map_type == MapType::Toroid {
            dx = dx.min(self.span[0] - dx);
            dy = dy.min(self.span[1] - dy);
        }
        (dx * dx + dy * dy).sqrt()
    }

    /// Immediate lattice neighbors N(j) for the U-matrix (Eq. 7):
    /// 8-neighborhood on square grids, 6 on hexagonal; wraps on toroids.
    pub fn neighbors(&self, node: usize) -> Vec<usize> {
        let (r, c) = self.position(node);
        let (rows, cols) = (self.rows as isize, self.cols as isize);
        let (ri, ci) = (r as isize, c as isize);
        let offsets: &[(isize, isize)] = match self.grid_type {
            GridType::Square => &[
                (-1, -1),
                (-1, 0),
                (-1, 1),
                (0, -1),
                (0, 1),
                (1, -1),
                (1, 0),
                (1, 1),
            ],
            // Hex neighbor offsets depend on row parity (offset coords).
            GridType::Hexagonal => {
                if r % 2 == 0 {
                    &[(0, -1), (0, 1), (-1, -1), (-1, 0), (1, -1), (1, 0)]
                } else {
                    &[(0, -1), (0, 1), (-1, 0), (-1, 1), (1, 0), (1, 1)]
                }
            }
        };
        let mut out = Vec::with_capacity(offsets.len());
        for &(dr, dc) in offsets {
            let (mut rr, mut cc) = (ri + dr, ci + dc);
            match self.map_type {
                MapType::Planar => {
                    if rr < 0 || rr >= rows || cc < 0 || cc >= cols {
                        continue;
                    }
                }
                MapType::Toroid => {
                    rr = rr.rem_euclid(rows);
                    cc = cc.rem_euclid(cols);
                }
            }
            let n = (rr as usize) * self.cols + cc as usize;
            if n != node && !out.contains(&n) {
                out.push(n);
            }
        }
        out
    }

    /// Default starting radius: "half of the map size in the smaller
    /// direction" (paper -r default).
    pub fn default_radius0(&self) -> f32 {
        (self.rows.min(self.cols) as f32) / 2.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::prop;

    #[test]
    fn square_planar_distances() {
        let g = Grid::new(5, 7, GridType::Square, MapType::Planar);
        assert_eq!(g.node_count(), 35);
        assert_eq!(g.distance(g.index(0, 0), g.index(0, 3)), 3.0);
        assert_eq!(g.distance(g.index(0, 0), g.index(4, 0)), 4.0);
        assert_eq!(g.distance(g.index(0, 0), g.index(3, 4)), 5.0);
    }

    #[test]
    fn toroid_wraps() {
        let g = Grid::new(1, 8, GridType::Square, MapType::Toroid);
        assert_eq!(g.distance(0, 7), 1.0);
        assert_eq!(g.distance(0, 4), 4.0);
        let planar = Grid::new(1, 8, GridType::Square, MapType::Planar);
        assert_eq!(planar.distance(0, 7), 7.0);
    }

    #[test]
    fn hex_unit_neighbors() {
        let g = Grid::new(4, 4, GridType::Hexagonal, MapType::Planar);
        // Every hex neighbor is at distance ~1.
        for node in 0..g.node_count() {
            for nb in g.neighbors(node) {
                let d = g.distance(node, nb);
                assert!((d - 1.0).abs() < 1e-5, "{node}->{nb}: {d}");
            }
        }
    }

    #[test]
    fn neighbor_counts() {
        let g = Grid::new(3, 3, GridType::Square, MapType::Planar);
        assert_eq!(g.neighbors(g.index(1, 1)).len(), 8);
        assert_eq!(g.neighbors(g.index(0, 0)).len(), 3);
        let t = Grid::new(3, 3, GridType::Square, MapType::Toroid);
        assert_eq!(t.neighbors(t.index(0, 0)).len(), 8);
        let h = Grid::new(4, 4, GridType::Hexagonal, MapType::Planar);
        assert_eq!(h.neighbors(h.index(1, 1)).len(), 6);
    }

    #[test]
    fn neighbors_symmetric() {
        for grid_type in [GridType::Square, GridType::Hexagonal] {
            for map_type in [MapType::Planar, MapType::Toroid] {
                let g = Grid::new(4, 6, grid_type, map_type);
                for a in 0..g.node_count() {
                    for b in g.neighbors(a) {
                        assert!(
                            g.neighbors(b).contains(&a),
                            "{grid_type:?}/{map_type:?}: {a}->{b} not symmetric"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn default_radius_half_smaller_side() {
        let g = Grid::new(20, 50, GridType::Square, MapType::Planar);
        assert_eq!(g.default_radius0(), 10.0);
    }

    #[test]
    fn prop_metric_invariants() {
        prop::check("grid-metric", |gen| {
            let rows = gen.usize_in(1, 9);
            let cols = gen.usize_in(1, 9);
            let gt = *gen.choice(&[GridType::Square, GridType::Hexagonal]);
            let mt = *gen.choice(&[MapType::Planar, MapType::Toroid]);
            let g = Grid::new(rows, cols, gt, mt);
            let n = g.node_count();
            let a = gen.usize_in(0, n - 1);
            let b = gen.usize_in(0, n - 1);
            let c = gen.usize_in(0, n - 1);
            let (dab, dba) = (g.distance(a, b), g.distance(b, a));
            prop_assert!((dab - dba).abs() < 1e-5, "symmetry {dab} {dba}");
            prop_assert!(g.distance(a, a) == 0.0, "identity");
            prop_assert!(
                dab >= 0.0 && dab.is_finite(),
                "non-negative finite: {dab}"
            );
            // Triangle inequality (holds for per-axis wrapped L2).
            let (dac, dcb) = (g.distance(a, c), g.distance(c, b));
            prop_assert!(
                dab <= dac + dcb + 1e-4,
                "triangle: d({a},{b})={dab} > {dac}+{dcb}"
            );
            // Toroid distance never exceeds planar distance.
            if mt == MapType::Toroid {
                let gp = Grid::new(rows, cols, gt, MapType::Planar);
                prop_assert!(
                    dab <= gp.distance(a, b) + 1e-5,
                    "toroid shortcut"
                );
            }
            Ok(())
        });
    }

    #[test]
    fn parse_types() {
        assert_eq!("hexagonal".parse::<GridType>().unwrap(), GridType::Hexagonal);
        assert_eq!("square".parse::<GridType>().unwrap(), GridType::Square);
        assert_eq!("toroid".parse::<MapType>().unwrap(), MapType::Toroid);
        assert!("blob".parse::<GridType>().is_err());
        assert!("blob".parse::<MapType>().is_err());
    }
}
