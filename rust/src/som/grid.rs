//! Map geometry: square/hexagonal grids on planar/toroid topologies.
//!
//! Nodes live on an `rows x cols` lattice; each node has 2-D coordinates
//! used for both the neighborhood function (grid distances, Eq. 5) and
//! the AOT accel kernel (which receives `coords [N, 2]` + `span [2]`
//! inputs — see python/compile/model.py). Hexagonal grids use the usual
//! offset coordinates: odd rows shifted +0.5 in x, rows √3/2 apart, which
//! is how classic somoclu computes hex distances.

/// Grid layout of the neuron lattice (paper `-g`).
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub enum GridType {
    Square,
    Hexagonal,
}

/// Map topology (paper `-m`).
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub enum MapType {
    Planar,
    Toroid,
}

impl std::str::FromStr for GridType {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "square" | "rectangular" => Ok(GridType::Square),
            "hexagonal" | "hex" => Ok(GridType::Hexagonal),
            other => Err(format!("unknown grid type: {other}")),
        }
    }
}

impl std::str::FromStr for MapType {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "planar" => Ok(MapType::Planar),
            "toroid" | "toroidal" => Ok(MapType::Toroid),
            other => Err(format!("unknown map type: {other}")),
        }
    }
}

pub const SQRT3_2: f32 = 0.866_025_4; // sqrt(3)/2

/// The neuron lattice.
#[derive(Clone, Debug)]
pub struct Grid {
    pub rows: usize,
    pub cols: usize,
    pub grid_type: GridType,
    pub map_type: MapType,
    /// Node coordinates, row-major node order, [n][0]=x, [n][1]=y.
    coords: Vec<[f32; 2]>,
    /// Wrap extent per axis for toroid distance.
    span: [f32; 2],
}

impl Grid {
    pub fn new(rows: usize, cols: usize, grid_type: GridType, map_type: MapType) -> Self {
        assert!(rows > 0 && cols > 0);
        let mut coords = Vec::with_capacity(rows * cols);
        for r in 0..rows {
            for c in 0..cols {
                let (x, y) = match grid_type {
                    GridType::Square => (c as f32, r as f32),
                    GridType::Hexagonal => (
                        c as f32 + 0.5 * (r % 2) as f32,
                        r as f32 * SQRT3_2,
                    ),
                };
                coords.push([x, y]);
            }
        }
        let span = match grid_type {
            GridType::Square => [cols as f32, rows as f32],
            GridType::Hexagonal => [cols as f32, rows as f32 * SQRT3_2],
        };
        Grid {
            rows,
            cols,
            grid_type,
            map_type,
            coords,
            span,
        }
    }

    pub fn node_count(&self) -> usize {
        self.rows * self.cols
    }

    /// Node index for (row, col).
    #[inline]
    pub fn index(&self, row: usize, col: usize) -> usize {
        debug_assert!(row < self.rows && col < self.cols);
        row * self.cols + col
    }

    /// (row, col) for node index.
    #[inline]
    pub fn position(&self, node: usize) -> (usize, usize) {
        (node / self.cols, node % self.cols)
    }

    #[inline]
    pub fn coord(&self, node: usize) -> [f32; 2] {
        self.coords[node]
    }

    pub fn coords_flat(&self) -> Vec<f32> {
        self.coords.iter().flat_map(|c| [c[0], c[1]]).collect()
    }

    pub fn span(&self) -> [f32; 2] {
        self.span
    }

    /// Grid distance between two nodes (Euclidean over coordinates,
    /// wrapped per-axis on a toroid).
    #[inline]
    pub fn distance(&self, a: usize, b: usize) -> f32 {
        let (pa, pb) = (self.coords[a], self.coords[b]);
        let mut dx = (pa[0] - pb[0]).abs();
        let mut dy = (pa[1] - pb[1]).abs();
        if self.map_type == MapType::Toroid {
            dx = dx.min(self.span[0] - dx);
            dy = dy.min(self.span[1] - dy);
        }
        (dx * dx + dy * dy).sqrt()
    }

    /// Immediate lattice neighbors N(j) for the U-matrix (Eq. 7):
    /// 8-neighborhood on square grids, 6 on hexagonal; wraps on toroids.
    pub fn neighbors(&self, node: usize) -> Vec<usize> {
        let (r, c) = self.position(node);
        let (rows, cols) = (self.rows as isize, self.cols as isize);
        let (ri, ci) = (r as isize, c as isize);
        let offsets: &[(isize, isize)] = match self.grid_type {
            GridType::Square => &[
                (-1, -1),
                (-1, 0),
                (-1, 1),
                (0, -1),
                (0, 1),
                (1, -1),
                (1, 0),
                (1, 1),
            ],
            // Hex neighbor offsets depend on row parity (offset coords).
            GridType::Hexagonal => {
                if r % 2 == 0 {
                    &[(0, -1), (0, 1), (-1, -1), (-1, 0), (1, -1), (1, 0)]
                } else {
                    &[(0, -1), (0, 1), (-1, 0), (-1, 1), (1, 0), (1, 1)]
                }
            }
        };
        let mut out = Vec::with_capacity(offsets.len());
        for &(dr, dc) in offsets {
            let (mut rr, mut cc) = (ri + dr, ci + dc);
            match self.map_type {
                MapType::Planar => {
                    if rr < 0 || rr >= rows || cc < 0 || cc >= cols {
                        continue;
                    }
                }
                MapType::Toroid => {
                    rr = rr.rem_euclid(rows);
                    cc = cc.rem_euclid(cols);
                }
            }
            let n = (rr as usize) * self.cols + cc as usize;
            if n != node && !out.contains(&n) {
                out.push(n);
            }
        }
        out
    }

    /// Default starting radius: "half of the map size in the smaller
    /// direction" (paper -r default).
    pub fn default_radius0(&self) -> f32 {
        (self.rows.min(self.cols) as f32) / 2.0
    }

    /// Lattice pitch along the row axis: vertical distance between
    /// adjacent rows (1 on square grids, √3/2 on hexagonal ones). The
    /// column pitch is 1 on both. Used to convert a neighborhood cutoff
    /// distance into a per-axis window half-width.
    pub fn row_pitch(&self) -> f32 {
        match self.grid_type {
            GridType::Square => 1.0,
            GridType::Hexagonal => SQRT3_2,
        }
    }

    /// Window shape along one axis for a neighborhood `cutoff` distance
    /// (`pitch` = lattice step along that axis, `len` = axis length).
    ///
    /// The half-width is `floor(cutoff / pitch) +`[`WINDOW_MARGIN`] —
    /// deliberately conservative: any lattice point *outside* the window
    /// is more than one full step beyond the cutoff, a gap no f32
    /// rounding in the distance computation can bridge, so the window
    /// provably contains every displacement the thresholded sweep would
    /// accept. (Cells inside the window but beyond the cutoff get a zero
    /// table entry and are skipped — see `som::stencil`.) On a toroid a
    /// window at least as wide as the axis would alias wrapped
    /// displacements onto one node, so it degrades to [`AxisExtent::Full`]
    /// (each physical index visited exactly once).
    pub fn axis_extent(&self, cutoff: f32, pitch: f32, len: usize) -> AxisExtent {
        let half = if cutoff.is_finite() && cutoff >= 0.0 {
            let h = (cutoff / pitch).floor() + WINDOW_MARGIN as f32;
            if h >= len as f32 {
                len
            } else {
                h as usize
            }
        } else {
            len
        };
        match self.map_type {
            MapType::Toroid if 2 * half + 1 > len => AxisExtent::Full,
            MapType::Toroid => AxisExtent::Window { half },
            MapType::Planar => AxisExtent::Window {
                half: half.min(len.saturating_sub(1)),
            },
        }
    }

    /// [`Self::axis_extent`] along the row axis.
    pub fn row_extent(&self, cutoff: f32) -> AxisExtent {
        self.axis_extent(cutoff, self.row_pitch(), self.rows)
    }

    /// [`Self::axis_extent`] along the column axis.
    pub fn col_extent(&self, cutoff: f32) -> AxisExtent {
        self.axis_extent(cutoff, 1.0, self.cols)
    }

    /// The physical indices an axis window reaches from `center`, as up
    /// to two contiguous intervals in **ascending physical order** (a
    /// toroid window that wraps splits in two). Ascending order is what
    /// lets the stencil gather visit BMUs in ascending node-index order,
    /// keeping its f32 summation order identical to the full sweep's.
    ///
    /// Each interval carries the displacement-table slot of its first
    /// element; slots advance 1:1 with the physical index inside an
    /// interval, so gather loops index tables without wrap arithmetic.
    pub fn axis_intervals(&self, center: usize, ext: AxisExtent, len: usize) -> AxisIntervals {
        debug_assert!(center < len);
        match ext {
            AxisExtent::Full => {
                if center == 0 {
                    AxisIntervals::one(AxisInterval {
                        start: 0,
                        end: len,
                        slot0: 0,
                    })
                } else {
                    AxisIntervals::two(
                        AxisInterval {
                            start: 0,
                            end: center,
                            slot0: len - center,
                        },
                        AxisInterval {
                            start: center,
                            end: len,
                            slot0: 0,
                        },
                    )
                }
            }
            AxisExtent::Window { half } => {
                let lo = center as isize - half as isize;
                let hi = center + half;
                match self.map_type {
                    MapType::Planar => {
                        let s = lo.max(0) as usize;
                        let e = hi.min(len - 1);
                        AxisIntervals::one(AxisInterval {
                            start: s,
                            end: e + 1,
                            slot0: s + half - center,
                        })
                    }
                    MapType::Toroid if lo >= 0 && hi < len => {
                        AxisIntervals::one(AxisInterval {
                            start: lo as usize,
                            end: hi + 1,
                            slot0: 0,
                        })
                    }
                    MapType::Toroid if lo < 0 => AxisIntervals::two(
                        // Wraps below: [0, hi] then the wrapped tail.
                        AxisInterval {
                            start: 0,
                            end: hi + 1,
                            slot0: half - center,
                        },
                        AxisInterval {
                            start: (lo + len as isize) as usize,
                            end: len,
                            slot0: 0,
                        },
                    ),
                    MapType::Toroid => AxisIntervals::two(
                        // Wraps above: the wrapped head, then [lo, len).
                        AxisInterval {
                            start: 0,
                            end: hi - len + 1,
                            slot0: len - center + half,
                        },
                        AxisInterval {
                            start: lo as usize,
                            end: len,
                            slot0: 0,
                        },
                    ),
                }
            }
        }
    }
}

/// Safety margin (in lattice steps) added to every stencil window
/// half-width, so f32 rounding in [`Grid::distance`] can never push a
/// lattice point the thresholded sweep accepts outside the window.
pub const WINDOW_MARGIN: usize = 2;

/// Per-axis stencil window shape (see [`Grid::axis_extent`]).
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub enum AxisExtent {
    /// Displacements `-half..=half`; table slot = `dr + half`.
    Window { half: usize },
    /// Toroid axis fully covered: every physical index is visited once;
    /// table slot = `(phys - center).rem_euclid(len)`.
    Full,
}

impl AxisExtent {
    /// Number of distinct displacement slots along an axis of length `len`.
    pub fn slots(&self, len: usize) -> usize {
        match self {
            AxisExtent::Window { half } => 2 * half + 1,
            AxisExtent::Full => len,
        }
    }
}

/// One contiguous run of physical indices inside an axis window, with
/// the displacement-table slot of its first element (slot for physical
/// index `i` is `slot0 + (i - start)`).
#[derive(Copy, Clone, Debug, Default, PartialEq, Eq)]
pub struct AxisInterval {
    /// First physical index.
    pub start: usize,
    /// One past the last physical index.
    pub end: usize,
    /// Table slot of `start`.
    pub slot0: usize,
}

/// Up to two [`AxisInterval`]s in ascending physical order.
#[derive(Copy, Clone, Debug, Default)]
pub struct AxisIntervals {
    items: [AxisInterval; 2],
    len: usize,
}

impl AxisIntervals {
    fn one(iv: AxisInterval) -> Self {
        AxisIntervals {
            items: [iv, AxisInterval::default()],
            len: 1,
        }
    }

    fn two(a: AxisInterval, b: AxisInterval) -> Self {
        debug_assert!(a.end <= b.start, "intervals must ascend: {a:?} {b:?}");
        AxisIntervals {
            items: [a, b],
            len: 2,
        }
    }

    /// The intervals, ascending by physical index.
    pub fn as_slice(&self) -> &[AxisInterval] {
        &self.items[..self.len]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::prop;

    #[test]
    fn square_planar_distances() {
        let g = Grid::new(5, 7, GridType::Square, MapType::Planar);
        assert_eq!(g.node_count(), 35);
        assert_eq!(g.distance(g.index(0, 0), g.index(0, 3)), 3.0);
        assert_eq!(g.distance(g.index(0, 0), g.index(4, 0)), 4.0);
        assert_eq!(g.distance(g.index(0, 0), g.index(3, 4)), 5.0);
    }

    #[test]
    fn toroid_wraps() {
        let g = Grid::new(1, 8, GridType::Square, MapType::Toroid);
        assert_eq!(g.distance(0, 7), 1.0);
        assert_eq!(g.distance(0, 4), 4.0);
        let planar = Grid::new(1, 8, GridType::Square, MapType::Planar);
        assert_eq!(planar.distance(0, 7), 7.0);
    }

    #[test]
    fn hex_unit_neighbors() {
        let g = Grid::new(4, 4, GridType::Hexagonal, MapType::Planar);
        // Every hex neighbor is at distance ~1.
        for node in 0..g.node_count() {
            for nb in g.neighbors(node) {
                let d = g.distance(node, nb);
                assert!((d - 1.0).abs() < 1e-5, "{node}->{nb}: {d}");
            }
        }
    }

    #[test]
    fn neighbor_counts() {
        let g = Grid::new(3, 3, GridType::Square, MapType::Planar);
        assert_eq!(g.neighbors(g.index(1, 1)).len(), 8);
        assert_eq!(g.neighbors(g.index(0, 0)).len(), 3);
        let t = Grid::new(3, 3, GridType::Square, MapType::Toroid);
        assert_eq!(t.neighbors(t.index(0, 0)).len(), 8);
        let h = Grid::new(4, 4, GridType::Hexagonal, MapType::Planar);
        assert_eq!(h.neighbors(h.index(1, 1)).len(), 6);
    }

    #[test]
    fn neighbors_symmetric() {
        for grid_type in [GridType::Square, GridType::Hexagonal] {
            for map_type in [MapType::Planar, MapType::Toroid] {
                let g = Grid::new(4, 6, grid_type, map_type);
                for a in 0..g.node_count() {
                    for b in g.neighbors(a) {
                        assert!(
                            g.neighbors(b).contains(&a),
                            "{grid_type:?}/{map_type:?}: {a}->{b} not symmetric"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn default_radius_half_smaller_side() {
        let g = Grid::new(20, 50, GridType::Square, MapType::Planar);
        assert_eq!(g.default_radius0(), 10.0);
    }

    #[test]
    fn prop_metric_invariants() {
        prop::check("grid-metric", |gen| {
            let rows = gen.usize_in(1, 9);
            let cols = gen.usize_in(1, 9);
            let gt = *gen.choice(&[GridType::Square, GridType::Hexagonal]);
            let mt = *gen.choice(&[MapType::Planar, MapType::Toroid]);
            let g = Grid::new(rows, cols, gt, mt);
            let n = g.node_count();
            let a = gen.usize_in(0, n - 1);
            let b = gen.usize_in(0, n - 1);
            let c = gen.usize_in(0, n - 1);
            let (dab, dba) = (g.distance(a, b), g.distance(b, a));
            prop_assert!((dab - dba).abs() < 1e-5, "symmetry {dab} {dba}");
            prop_assert!(g.distance(a, a) == 0.0, "identity");
            prop_assert!(
                dab >= 0.0 && dab.is_finite(),
                "non-negative finite: {dab}"
            );
            // Triangle inequality (holds for per-axis wrapped L2).
            let (dac, dcb) = (g.distance(a, c), g.distance(c, b));
            prop_assert!(
                dab <= dac + dcb + 1e-4,
                "triangle: d({a},{b})={dab} > {dac}+{dcb}"
            );
            // Toroid distance never exceeds planar distance.
            if mt == MapType::Toroid {
                let gp = Grid::new(rows, cols, gt, MapType::Planar);
                prop_assert!(
                    dab <= gp.distance(a, b) + 1e-5,
                    "toroid shortcut"
                );
            }
            Ok(())
        });
    }

    /// Canonical displacement of `p` from `center` along a wrapped or
    /// clipped axis (test oracle).
    fn oracle_disp(p: usize, center: usize, len: usize, mt: MapType) -> isize {
        let raw = p as isize - center as isize;
        match mt {
            MapType::Planar => raw,
            MapType::Toroid => {
                // wrapped displacement with smallest magnitude
                let m = raw.rem_euclid(len as isize);
                if m * 2 > len as isize {
                    m - len as isize
                } else {
                    m
                }
            }
        }
    }

    #[test]
    fn prop_axis_intervals_cover_window_once_with_linear_slots() {
        prop::check("axis-intervals", |gen| {
            let len = gen.usize_in(1, 40);
            let center = gen.usize_in(0, len - 1);
            let gt = *gen.choice(&[GridType::Square, GridType::Hexagonal]);
            let mt = *gen.choice(&[MapType::Planar, MapType::Toroid]);
            let cutoff = gen.f32_in(0.0, 12.0);
            let g = Grid::new(len, len, gt, mt);
            let ext = g.axis_extent(cutoff, 1.0, len);
            let ivs = g.axis_intervals(center, ext, len);
            let mut seen = vec![false; len];
            let mut last_end = 0usize;
            for iv in ivs.as_slice() {
                prop_assert!(iv.start >= last_end, "ascending physical order");
                prop_assert!(iv.end <= len && iv.start < iv.end, "in bounds");
                last_end = iv.end;
                for p in iv.start..iv.end {
                    prop_assert!(!seen[p], "physical index {p} visited twice");
                    seen[p] = true;
                    let slot = iv.slot0 + (p - iv.start);
                    match ext {
                        AxisExtent::Window { half } => {
                            let d = oracle_disp(p, center, len, mt);
                            prop_assert!(
                                d.unsigned_abs() <= half,
                                "phys {p} outside window (d={d}, half={half})"
                            );
                            prop_assert!(
                                slot as isize == d + half as isize,
                                "slot {slot} != d {d} + half {half}"
                            );
                        }
                        AxisExtent::Full => {
                            let d = (p as isize - center as isize)
                                .rem_euclid(len as isize);
                            prop_assert!(slot as isize == d, "full slot {slot} != {d}");
                        }
                    }
                }
            }
            // Completeness: every index within the window is covered.
            if let AxisExtent::Window { half } = ext {
                for (p, &s) in seen.iter().enumerate() {
                    let inside = oracle_disp(p, center, len, mt).unsigned_abs() <= half;
                    prop_assert!(s == inside, "coverage mismatch at {p}");
                }
            } else {
                prop_assert!(seen.iter().all(|&s| s), "Full must cover the axis");
            }
            Ok(())
        });
    }

    #[test]
    fn axis_extent_window_contains_cutoff_plus_margin() {
        // Any lattice point outside the window is > cutoff away (the
        // bit-identity precondition of the stencil path).
        for mt in [MapType::Planar, MapType::Toroid] {
            let g = Grid::new(64, 64, GridType::Square, mt);
            for cutoff in [0.0f32, 0.5, 1.0, 2.0, 7.3, 20.0] {
                match g.axis_extent(cutoff, 1.0, 64) {
                    AxisExtent::Window { half } => {
                        assert!((half as f32) > cutoff, "half {half} vs {cutoff}");
                    }
                    AxisExtent::Full => assert!(2.0 * cutoff + 1.0 >= 60.0),
                }
            }
        }
    }

    #[test]
    fn axis_extent_degenerate_axes() {
        // len-1 axes and non-finite cutoffs must not panic or alias.
        for gt in [GridType::Square, GridType::Hexagonal] {
            for mt in [MapType::Planar, MapType::Toroid] {
                let g = Grid::new(1, 1, gt, mt);
                let ext = g.axis_extent(5.0, 1.0, 1);
                assert_eq!(ext.slots(1), 1);
                let ivs = g.axis_intervals(0, ext, 1);
                assert_eq!(ivs.as_slice().len(), 1);
                assert_eq!(ivs.as_slice()[0], AxisInterval { start: 0, end: 1, slot0: 0 });
                let inf = g.axis_extent(f32::INFINITY, 1.0, 1);
                assert_eq!(inf.slots(1), 1);
            }
        }
    }

    #[test]
    fn parse_types() {
        assert_eq!("hexagonal".parse::<GridType>().unwrap(), GridType::Hexagonal);
        assert_eq!("square".parse::<GridType>().unwrap(), GridType::Square);
        assert_eq!("toroid".parse::<MapType>().unwrap(), MapType::Toroid);
        assert!("blob".parse::<GridType>().is_err());
        assert!("blob".parse::<MapType>().is_err());
    }
}
