//! PCA (linear) codebook initialization — the `initialization='pca'`
//! option of somoclu's Python API: span the map across the plane of the
//! first two principal components so training starts from an already
//! unfolded sheet.
//!
//! The eigensolver is an in-repo substrate (no LAPACK offline): power
//! iteration with Gram-deflation on the centered data, computing
//! X^T (X v) products so the D x D covariance is never materialized —
//! important for the paper's high-dimensional text spaces.

use crate::som::codebook::Codebook;
use crate::som::grid::Grid;
use crate::util::rng::Rng;

/// Result of the 2-component PCA.
#[derive(Clone, Debug)]
pub struct Pca2 {
    pub mean: Vec<f32>,
    /// First two principal directions, each of length dim, unit norm.
    pub components: [Vec<f32>; 2],
    /// Corresponding standard deviations along each component.
    pub sdev: [f32; 2],
}

fn dot(a: &[f32], b: &[f32]) -> f64 {
    a.iter().zip(b).map(|(x, y)| *x as f64 * *y as f64).sum()
}

fn normalize(v: &mut [f32]) -> f64 {
    let n = dot(v, v).sqrt();
    if n > 0.0 {
        for x in v.iter_mut() {
            *x = (*x as f64 / n) as f32;
        }
    }
    n
}

/// Power iteration for the top-2 principal components of `data`
/// ([rows x dim], row-major). Deterministic given the seed.
pub fn pca2(data: &[f32], dim: usize, rng: &mut Rng) -> Pca2 {
    let rows = data.len() / dim;
    assert!(rows > 1, "need at least 2 rows for PCA");

    let mut mean = vec![0.0f32; dim];
    for r in 0..rows {
        for d in 0..dim {
            mean[d] += data[r * dim + d];
        }
    }
    for m in mean.iter_mut() {
        *m /= rows as f32;
    }

    // Centered matvec: y = X_c^T (X_c v) / (rows - 1).
    let cov_apply = |v: &[f32], out: &mut Vec<f32>| {
        out.clear();
        out.resize(dim, 0.0);
        for r in 0..rows {
            let row = &data[r * dim..(r + 1) * dim];
            let mut proj = 0.0f64;
            for d in 0..dim {
                proj += (row[d] - mean[d]) as f64 * v[d] as f64;
            }
            let p = (proj / (rows - 1) as f64) as f32;
            for d in 0..dim {
                out[d] += (row[d] - mean[d]) * p;
            }
        }
    };

    let mut components: [Vec<f32>; 2] = [vec![0.0; dim], vec![0.0; dim]];
    let mut sdev = [0.0f32; 2];
    let mut tmp = Vec::with_capacity(dim);
    for c in 0..2 {
        let mut v: Vec<f32> = (0..dim).map(|_| rng.normal_f32()).collect();
        // Deflate against earlier components before and during iteration.
        for _ in 0..60 {
            for prev in 0..c {
                let p = dot(&v, &components[prev]);
                for (x, e) in v.iter_mut().zip(&components[prev]) {
                    *x -= (p * *e as f64) as f32;
                }
            }
            normalize(&mut v);
            cov_apply(&v, &mut tmp);
            std::mem::swap(&mut v, &mut tmp);
        }
        for prev in 0..c {
            let p = dot(&v, &components[prev]);
            for (x, e) in v.iter_mut().zip(&components[prev]) {
                *x -= (p * *e as f64) as f32;
            }
        }
        let eig = normalize(&mut v); // last matvec norm ≈ eigenvalue
        sdev[c] = (eig.max(0.0)).sqrt() as f32;
        components[c] = v;
    }

    Pca2 {
        mean,
        components,
        sdev,
    }
}

/// Linear initialization: node (r, c) = mean + a·PC1 + b·PC2 with (a, b)
/// spanning ±2 standard deviations across the grid (kohonen/somtoolbox
/// convention).
pub fn pca_init(grid: &Grid, data: &[f32], dim: usize, rng: &mut Rng) -> Codebook {
    let p = pca2(data, dim, rng);
    let mut cb = Codebook::zeros(grid.node_count(), dim);
    let (max_r, max_c) = (grid.rows.max(2) - 1, grid.cols.max(2) - 1);
    for node in 0..grid.node_count() {
        let (r, c) = grid.position(node);
        // map grid position to [-2σ, +2σ] along each component
        let a = (c as f32 / max_c.max(1) as f32 - 0.5) * 4.0 * p.sdev[0];
        let b = (r as f32 / max_r.max(1) as f32 - 0.5) * 4.0 * p.sdev[1];
        let row = cb.row_mut(node);
        for d in 0..dim {
            row[d] = p.mean[d] + a * p.components[0][d] + b * p.components[1][d];
        }
    }
    cb
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::som::grid::{GridType, MapType};

    /// Anisotropic gaussian: variance 9 along e0, 1 along e1, 0.01 rest.
    fn aniso(rows: usize, dim: usize, rng: &mut Rng) -> Vec<f32> {
        let mut d = vec![0.0f32; rows * dim];
        for r in 0..rows {
            d[r * dim] = 3.0 * rng.normal_f32() + 5.0; // offset mean
            d[r * dim + 1] = 1.0 * rng.normal_f32();
            for k in 2..dim {
                d[r * dim + k] = 0.1 * rng.normal_f32();
            }
        }
        d
    }

    #[test]
    fn recovers_dominant_directions() {
        let mut rng = Rng::new(71);
        let data = aniso(2000, 6, &mut rng);
        let p = pca2(&data, 6, &mut rng);
        // PC1 ≈ ±e0, PC2 ≈ ±e1.
        assert!(p.components[0][0].abs() > 0.99, "{:?}", p.components[0]);
        assert!(p.components[1][1].abs() > 0.99, "{:?}", p.components[1]);
        assert!((p.sdev[0] - 3.0).abs() < 0.3, "{}", p.sdev[0]);
        assert!((p.sdev[1] - 1.0).abs() < 0.15, "{}", p.sdev[1]);
        // mean recovered
        assert!((p.mean[0] - 5.0).abs() < 0.3);
    }

    #[test]
    fn components_orthonormal() {
        let mut rng = Rng::new(72);
        let data: Vec<f32> = (0..500 * 8).map(|_| rng.normal_f32()).collect();
        let p = pca2(&data, 8, &mut rng);
        let d01 = dot(&p.components[0], &p.components[1]).abs();
        assert!(d01 < 1e-3, "{d01}");
        for c in 0..2 {
            let n = dot(&p.components[c], &p.components[c]);
            assert!((n - 1.0).abs() < 1e-4, "{n}");
        }
    }

    #[test]
    fn init_spans_the_data_plane() {
        let mut rng = Rng::new(73);
        let data = aniso(1000, 5, &mut rng);
        let grid = Grid::new(10, 10, GridType::Square, MapType::Planar);
        let cb = pca_init(&grid, &data, 5, &mut rng);
        // Corner-to-corner variation along dim 0 spans ~4 sdev ≈ 12.
        let span = (cb.row(grid.index(0, 0))[0] - cb.row(grid.index(0, 9))[0]).abs();
        assert!(span > 8.0, "{span}");
        // Grid is smooth: adjacent nodes closer than distant ones.
        let d_adj = crate::som::quality::sq_dist(
            cb.row(grid.index(5, 5)),
            cb.row(grid.index(5, 6)),
        );
        let d_far = crate::som::quality::sq_dist(
            cb.row(grid.index(0, 0)),
            cb.row(grid.index(9, 9)),
        );
        assert!(d_adj < d_far);
    }

    #[test]
    fn pca_init_beats_random_init_on_first_epoch() {
        let mut rng = Rng::new(74);
        let data = aniso(600, 8, &mut rng);
        let grid = Grid::new(8, 8, GridType::Square, MapType::Planar);
        let pca_cb = pca_init(&grid, &data, 8, &mut rng);
        let rand_cb = Codebook::random_init(64, 8, &mut rng);
        let qe = |cb: &Codebook| {
            let mut total = 0.0f64;
            for r in 0..600 {
                let x = &data[r * 8..(r + 1) * 8];
                let best = (0..64)
                    .map(|n| crate::som::quality::sq_dist(x, cb.row(n)))
                    .fold(f32::INFINITY, f32::min);
                total += (best as f64).sqrt();
            }
            total / 600.0
        };
        assert!(
            qe(&pca_cb) < qe(&rand_cb) * 0.8,
            "pca {} vs random {}",
            qe(&pca_cb),
            qe(&rand_cb)
        );
    }
}
