//! Neighborhood functions h(d; r) of Eq. 5 (paper `-n` and `-p`).
//!
//! Gaussian: exp(-d² / (2 r²)); bubble: 1[d ≤ r]. `compact_support`
//! (paper `-p 1`) cuts the gaussian off beyond the radius — the paper
//! credits this thresholding for "speed improvements without compromising
//! the quality of the trained map" because far-field updates vanish.

/// Neighborhood kind (paper `-n`).
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub enum NeighborhoodKind {
    Gaussian,
    Bubble,
}

impl std::str::FromStr for NeighborhoodKind {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "gaussian" => Ok(NeighborhoodKind::Gaussian),
            "bubble" => Ok(NeighborhoodKind::Bubble),
            other => Err(format!("unknown neighborhood function: {other}")),
        }
    }
}

/// Neighborhood function with its compact-support flag.
#[derive(Copy, Clone, Debug, PartialEq)]
pub struct Neighborhood {
    pub kind: NeighborhoodKind,
    pub compact_support: bool,
}

impl Neighborhood {
    pub fn gaussian(compact_support: bool) -> Self {
        Neighborhood {
            kind: NeighborhoodKind::Gaussian,
            compact_support,
        }
    }

    pub fn bubble() -> Self {
        Neighborhood {
            kind: NeighborhoodKind::Bubble,
            compact_support: true, // bubble is inherently compact
        }
    }

    /// Weight for grid distance `d` at radius `r`.
    #[inline]
    pub fn weight(&self, d: f32, r: f32) -> f32 {
        let r = r.max(1e-6);
        match self.kind {
            NeighborhoodKind::Gaussian => {
                if self.compact_support && d > r {
                    0.0
                } else {
                    (-(d * d) / (2.0 * r * r)).exp()
                }
            }
            NeighborhoodKind::Bubble => {
                if d <= r {
                    1.0
                } else {
                    0.0
                }
            }
        }
    }

    /// Effective cutoff distance: beyond this the weight is (near) zero,
    /// letting kernels skip nodes entirely (the paper's radius
    /// thresholding optimization in §3.1).
    pub fn cutoff(&self, r: f32) -> f32 {
        match self.kind {
            NeighborhoodKind::Gaussian => {
                if self.compact_support {
                    r
                } else {
                    // exp(-d²/(2r²)) < 1e-12 beyond ~7.4 r; contributions
                    // there are numerically invisible in f32 accumulation.
                    7.5 * r.max(1e-6)
                }
            }
            NeighborhoodKind::Bubble => r,
        }
    }

    /// Thresholded update weight exactly as the accumulation sweep
    /// applies it: the Eq. 5 weight times the learning `scale`,
    /// hard-zeroed beyond [`Self::cutoff`]. This is the table entry of
    /// the stencil accumulator ([`crate::som::stencil`]): the full sweep
    /// skips a (BMU, node) pair iff `gd > cutoff || weight·scale <= 0`,
    /// so a zero entry encodes "skip" and precomputed tables reproduce
    /// the sweep's decisions — and its contributions — bit-for-bit.
    /// (Without the cutoff guard a *non-compact* gaussian would emit
    /// tiny positive weights beyond the cutoff that the sweep never
    /// adds.)
    #[inline]
    pub fn table_entry(&self, d: f32, r: f32, scale: f32) -> f32 {
        if d > self.cutoff(r) {
            0.0
        } else {
            self.weight(d, r) * scale
        }
    }

    /// Artifact variant name this neighborhood maps to (accel kernel).
    pub fn artifact_kind(&self) -> &'static str {
        match (self.kind, self.compact_support) {
            (NeighborhoodKind::Gaussian, false) => "gaussian",
            (NeighborhoodKind::Gaussian, true) => "gaussian_compact",
            (NeighborhoodKind::Bubble, _) => "bubble",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::prop;

    #[test]
    fn gaussian_values() {
        let nb = Neighborhood::gaussian(false);
        assert_eq!(nb.weight(0.0, 3.0), 1.0);
        let w = nb.weight(3.0, 3.0);
        assert!((w - (-0.5f32).exp()).abs() < 1e-6);
        assert!(nb.weight(30.0, 3.0) < 1e-6);
    }

    #[test]
    fn compact_support_cuts() {
        let nb = Neighborhood::gaussian(true);
        assert!(nb.weight(2.9, 3.0) > 0.0);
        assert_eq!(nb.weight(3.1, 3.0), 0.0);
    }

    #[test]
    fn bubble_indicator() {
        let nb = Neighborhood::bubble();
        assert_eq!(nb.weight(2.0, 3.0), 1.0);
        assert_eq!(nb.weight(3.0, 3.0), 1.0);
        assert_eq!(nb.weight(3.01, 3.0), 0.0);
    }

    #[test]
    fn tiny_radius_safe() {
        for nb in [
            Neighborhood::gaussian(false),
            Neighborhood::gaussian(true),
            Neighborhood::bubble(),
        ] {
            let w = nb.weight(0.0, 0.0);
            assert!(w.is_finite());
            assert_eq!(w, 1.0); // BMU itself always gets full weight
        }
    }

    #[test]
    fn artifact_kind_names_match_python_configs() {
        assert_eq!(Neighborhood::gaussian(false).artifact_kind(), "gaussian");
        assert_eq!(
            Neighborhood::gaussian(true).artifact_kind(),
            "gaussian_compact"
        );
        assert_eq!(Neighborhood::bubble().artifact_kind(), "bubble");
    }

    #[test]
    fn table_entry_matches_sweep_decision() {
        // table_entry == the full sweep's skip logic + contribution, bit
        // for bit: zero iff (d > cutoff or weight*scale <= 0), else
        // exactly weight*scale.
        for nb in [
            Neighborhood::gaussian(false),
            Neighborhood::gaussian(true),
            Neighborhood::bubble(),
        ] {
            for r in [0.3f32, 1.0, 2.5, 8.0] {
                for scale in [0.0f32, 0.4, 1.0] {
                    for i in 0..200 {
                        let d = i as f32 * 0.11;
                        let entry = nb.table_entry(d, r, scale);
                        if d > nb.cutoff(r) {
                            assert_eq!(entry, 0.0, "{nb:?} d={d} r={r}");
                        } else {
                            let h = nb.weight(d, r) * scale;
                            assert_eq!(entry.to_bits(), h.to_bits());
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn prop_monotone_decreasing_and_cutoff() {
        prop::check("neighborhood", |g| {
            let nb = *g.choice(&[
                Neighborhood::gaussian(false),
                Neighborhood::gaussian(true),
                Neighborhood::bubble(),
            ]);
            let r = g.f32_in(0.1, 20.0);
            let d1 = g.f32_in(0.0, 25.0);
            let d2 = d1 + g.f32_in(0.0, 10.0);
            let (w1, w2) = (nb.weight(d1, r), nb.weight(d2, r));
            prop_assert!(w2 <= w1 + 1e-6, "not decreasing: {w1} -> {w2}");
            prop_assert!((0.0..=1.0).contains(&w1), "range: {w1}");
            let beyond = nb.cutoff(r) + 0.01;
            prop_assert!(
                nb.weight(beyond, r) < 1e-9,
                "cutoff leak at {beyond} (r={r})"
            );
            Ok(())
        });
    }
}
