//! Map quality measures: quantization error and topographic error.
//!
//! QE = mean distance of each data row to its BMU — the loss-curve the
//! end-to-end driver logs per epoch. TE = fraction of rows whose first
//! and second BMUs are not grid neighbors (a topology-preservation
//! check; not in the paper's tables but standard for SOM evaluation and
//! used in our integration tests).

use crate::kernels::simd::{self, BLOCK_ROWS};
use crate::som::codebook::Codebook;
use crate::som::grid::Grid;
use crate::util::threadpool;

/// Squared Euclidean distance between two equal-length slices.
#[inline]
pub fn sq_dist(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = 0.0f32;
    for (x, y) in a.iter().zip(b) {
        let d = x - y;
        acc += d * d;
    }
    acc
}

/// Best-matching unit of one dense vector by plain linear scan:
/// `(node, distance)`, ties to the lowest node index. Kernel-independent
/// and deterministic — **the** BMU-lookup arithmetic shared by
/// [`crate::session::SomSession::bmu`] and the serving daemon's `bmu`
/// request path, so a served answer is bit-identical to the offline one
/// by construction, not by coincidence.
///
/// The caller guarantees `x.len() == codebook.dim` and a non-empty map;
/// distance is `sqrt(max(sq_dist, 0))` in f32 (the clamp guards the
/// tiny negative residue cancellation can leave).
pub fn linear_bmu(codebook: &Codebook, x: &[f32]) -> (usize, f32) {
    debug_assert_eq!(x.len(), codebook.dim);
    let mut best = 0usize;
    let mut best_d = f32::INFINITY;
    for n in 0..codebook.nodes {
        let d = sq_dist(x, codebook.row(n));
        if d < best_d {
            best_d = d;
            best = n;
        }
    }
    (best, best_d.max(0.0).sqrt())
}

/// Mean quantization error over dense rows given their BMUs.
///
/// Each row's Euclidean distance is computed in f32 (`sq_dist(..).sqrt()`
/// — the same bits the training kernels see), but the running **sum
/// accumulates in f64** and only the final mean rounds back to f32. A
/// single-f32 running sum loses increments once it dwarfs them (~1e7×
/// smaller increments vanish entirely), which at streaming scale
/// (millions of rows) made the logged loss curve drift and plateau
/// falsely; with f64 accumulation the result is within one f32 ulp of an
/// exact mean of the per-row f32 distances (see the 1e6-row property
/// test in `rust/tests/bmu_search_equivalence.rs`).
pub fn quantization_error(
    data: &[f32],
    dim: usize,
    codebook: &Codebook,
    bmus: &[usize],
) -> f32 {
    let rows = bmus.len();
    assert_eq!(data.len(), rows * dim);
    if rows == 0 {
        return 0.0;
    }
    let sum: f64 = (0..rows)
        .map(|r| {
            sq_dist(&data[r * dim..(r + 1) * dim], codebook.row(bmus[r])).sqrt() as f64
        })
        .sum();
    (sum / rows as f64) as f32
}

/// First and second BMU per row (dense, threaded), via the cache-blocked
/// [`crate::kernels::simd`] microkernel ([`simd::top2_scan_panel`] — the
/// Gram-score form `||w||²/2 − x·w`, which orders nodes exactly like the
/// squared distance for a fixed row). Ties break to the lowest node
/// index in both slots.
///
/// Invariant: every returned pair satisfies `b2 != b1` — the runner-up
/// is a *different* node even when all distances are equal (duplicate
/// codebook rows) or non-finite. Requires `codebook.nodes >= 2`
/// (asserted); single-node maps have no runner-up, and
/// [`topographic_error`] special-cases them before calling this.
pub fn best_two(
    data: &[f32],
    dim: usize,
    codebook: &Codebook,
    threads: usize,
) -> Vec<(usize, usize)> {
    assert!(
        codebook.nodes >= 2,
        "best_two needs at least 2 nodes (got {})",
        codebook.nodes
    );
    let rows = data.len() / dim;
    let kind = simd::dispatch();
    let panel_nodes = simd::default_panel_nodes(dim);
    let w2 = codebook.sq_norms();
    let (w2, nodes) = (w2.as_slice(), codebook.nodes);
    let parts = threadpool::parallel_ranges(rows, threads, |_, range| {
        let cnt = range.len();
        let mut b1 = vec![0u32; cnt];
        let mut s1 = vec![f32::INFINITY; cnt];
        let mut b2 = vec![0u32; cnt];
        let mut s2 = vec![f32::INFINITY; cnt];
        // Same panel-outer / 8-row-block-inner nest as
        // `search_bmus_blocked`; per-row top-2 state persists across
        // panels, so nodes are still visited in ascending order.
        let mut n0 = 0usize;
        while n0 < nodes {
            let n1 = (n0 + panel_nodes.max(1)).min(nodes);
            let panel = &codebook.weights[n0 * dim..n1 * dim];
            let pw2 = &w2[n0..n1];
            let mut off = 0usize;
            while off < cnt {
                let blen = (cnt - off).min(BLOCK_ROWS);
                let r0 = range.start + off;
                let x: [&[f32]; BLOCK_ROWS] = std::array::from_fn(|k| {
                    let r = r0 + k.min(blen - 1);
                    &data[r * dim..(r + 1) * dim]
                });
                let mut lb1 = [0u32; BLOCK_ROWS];
                let mut ls1 = [f32::INFINITY; BLOCK_ROWS];
                let mut lb2 = [0u32; BLOCK_ROWS];
                let mut ls2 = [f32::INFINITY; BLOCK_ROWS];
                lb1[..blen].copy_from_slice(&b1[off..off + blen]);
                ls1[..blen].copy_from_slice(&s1[off..off + blen]);
                lb2[..blen].copy_from_slice(&b2[off..off + blen]);
                ls2[..blen].copy_from_slice(&s2[off..off + blen]);
                simd::top2_scan_panel(
                    kind, &x, blen, panel, dim, pw2, n0 as u32, &mut lb1, &mut ls1, &mut lb2,
                    &mut ls2,
                );
                b1[off..off + blen].copy_from_slice(&lb1[..blen]);
                s1[off..off + blen].copy_from_slice(&ls1[..blen]);
                b2[off..off + blen].copy_from_slice(&lb2[..blen]);
                s2[off..off + blen].copy_from_slice(&ls2[..blen]);
                off += blen;
            }
            n0 = n1;
        }
        b1.iter()
            .zip(&b2)
            .map(|(&a, &b)| {
                let (a, mut b) = (a as usize, b as usize);
                // b2 == b1 is only reachable when every score after the
                // first was NaN (strict `<` never filled the runner-up
                // slot); keep the invariant with an arbitrary other node.
                if b == a {
                    b = if a == 0 { 1 } else { 0 };
                }
                (a, b)
            })
            .collect::<Vec<_>>()
    });
    parts.concat()
}

/// Topographic error: share of rows whose top-2 BMUs are not neighbors.
///
/// Degenerate maps: a single-node map (`codebook.nodes < 2`) has no
/// meaningful runner-up, so TE is defined as 0 — every row trivially
/// maps to the only topology there is. (Previously node 0 was scored by
/// whether it neighbors itself, which depends on the grid's neighbor
/// convention rather than on the map.)
pub fn topographic_error(
    data: &[f32],
    dim: usize,
    grid: &Grid,
    codebook: &Codebook,
    threads: usize,
) -> f32 {
    if codebook.nodes < 2 {
        return 0.0;
    }
    let pairs = best_two(data, dim, codebook, threads);
    if pairs.is_empty() {
        return 0.0;
    }
    let bad = pairs
        .iter()
        .filter(|(b1, b2)| !grid.neighbors(*b1).contains(b2))
        .count();
    bad as f32 / pairs.len() as f32
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::som::grid::{GridType, MapType};

    #[test]
    fn qe_zero_for_exact_match() {
        let mut cb = Codebook::zeros(2, 2);
        cb.row_mut(0).copy_from_slice(&[1.0, 2.0]);
        cb.row_mut(1).copy_from_slice(&[3.0, 4.0]);
        let data = vec![1.0, 2.0, 3.0, 4.0];
        assert_eq!(quantization_error(&data, 2, &cb, &[0, 1]), 0.0);
    }

    #[test]
    fn qe_known_value() {
        let mut cb = Codebook::zeros(1, 2);
        cb.row_mut(0).copy_from_slice(&[0.0, 0.0]);
        let data = vec![3.0, 4.0]; // distance 5
        assert_eq!(quantization_error(&data, 2, &cb, &[0]), 5.0);
    }

    #[test]
    fn best_two_ordering() {
        let mut cb = Codebook::zeros(3, 1);
        cb.row_mut(0)[0] = 0.0;
        cb.row_mut(1)[0] = 1.0;
        cb.row_mut(2)[0] = 10.0;
        let pairs = best_two(&[0.4], 1, &cb, 1);
        assert_eq!(pairs, vec![(0, 1)]);
    }

    #[test]
    fn te_zero_when_adjacent() {
        // Codebook forms a smooth ramp along one row: top-2 are adjacent.
        let grid = Grid::new(1, 10, GridType::Square, MapType::Planar);
        let mut cb = Codebook::zeros(10, 1);
        for n in 0..10 {
            cb.row_mut(n)[0] = n as f32;
        }
        let data: Vec<f32> = (0..10).map(|i| i as f32 + 0.3).collect();
        let te = topographic_error(&data, 1, &grid, &cb, 2);
        assert_eq!(te, 0.0);
    }

    #[test]
    fn qe_mean_accumulates_in_f64() {
        // 1 + eps + eps + ... with an increment small enough that a
        // single-f32 running sum would drop every addend after the
        // first: the f64 accumulator must keep them.
        let rows = 4097usize;
        let mut cb = Codebook::zeros(2, 1);
        cb.row_mut(1)[0] = 1e-5;
        let mut data = vec![0.0f32; rows];
        data[0] = 1e4; // distance 1e4 to node 0
        let mut bmus = vec![1usize; rows]; // distance 1e-5 each
        bmus[0] = 0;
        let got = quantization_error(&data, 1, &cb, &bmus) as f64;
        let want = (1e4 + (rows - 1) as f64 * 1e-5) / rows as f64;
        assert!((got - want).abs() < want * 1e-6, "{got} vs {want}");
        // The f32-sum version would report exactly 1e4/rows.
        let f32_sum = 1e4f64 / rows as f64;
        assert!((got - f32_sum).abs() > want * 1e-9);
    }

    #[test]
    fn te_zero_for_single_node_map() {
        let grid = Grid::new(1, 1, GridType::Square, MapType::Planar);
        let cb = Codebook::zeros(1, 2);
        let data = vec![0.5, 0.5, 1.0, -1.0];
        assert_eq!(topographic_error(&data, 2, &grid, &cb, 2), 0.0);
    }

    #[test]
    #[should_panic(expected = "at least 2 nodes")]
    fn best_two_rejects_single_node_map() {
        let cb = Codebook::zeros(1, 2);
        best_two(&[0.0, 0.0], 2, &cb, 1);
    }

    #[test]
    fn best_two_distinct_even_when_all_nodes_equal() {
        // Duplicate codebook rows: every distance ties. Lowest-index tie
        // rule ⇒ (0, 1), and the b2 != b1 invariant must hold.
        let cb = Codebook::zeros(6, 3);
        let data = vec![0.25f32; 4 * 3];
        for (b1, b2) in best_two(&data, 3, &cb, 2) {
            assert_eq!((b1, b2), (0, 1));
        }
    }

    #[test]
    fn te_detects_folding() {
        // Node values alternate so top-2 BMUs are far apart on the grid.
        let grid = Grid::new(1, 10, GridType::Square, MapType::Planar);
        let mut cb = Codebook::zeros(10, 1);
        for n in 0..10 {
            cb.row_mut(n)[0] = if n % 2 == 0 { n as f32 } else { 100.0 };
        }
        let data = vec![1.0, 3.0, 5.0];
        let te = topographic_error(&data, 1, &grid, &cb, 1);
        assert!(te > 0.99);
    }
}
