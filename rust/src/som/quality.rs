//! Map quality measures: quantization error and topographic error.
//!
//! QE = mean distance of each data row to its BMU — the loss-curve the
//! end-to-end driver logs per epoch. TE = fraction of rows whose first
//! and second BMUs are not grid neighbors (a topology-preservation
//! check; not in the paper's tables but standard for SOM evaluation and
//! used in our integration tests).

use crate::som::codebook::Codebook;
use crate::som::grid::Grid;
use crate::util::threadpool;

/// Squared Euclidean distance between two equal-length slices.
#[inline]
pub fn sq_dist(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = 0.0f32;
    for (x, y) in a.iter().zip(b) {
        let d = x - y;
        acc += d * d;
    }
    acc
}

/// Mean quantization error over dense rows given their BMUs.
pub fn quantization_error(
    data: &[f32],
    dim: usize,
    codebook: &Codebook,
    bmus: &[usize],
) -> f32 {
    let rows = bmus.len();
    assert_eq!(data.len(), rows * dim);
    if rows == 0 {
        return 0.0;
    }
    let sum: f32 = (0..rows)
        .map(|r| {
            sq_dist(&data[r * dim..(r + 1) * dim], codebook.row(bmus[r])).sqrt()
        })
        .sum();
    sum / rows as f32
}

/// First and second BMU per row (dense, threaded).
pub fn best_two(
    data: &[f32],
    dim: usize,
    codebook: &Codebook,
    threads: usize,
) -> Vec<(usize, usize)> {
    let rows = data.len() / dim;
    let parts = threadpool::parallel_ranges(rows, threads, |_, range| {
        let mut out = Vec::with_capacity(range.len());
        for r in range {
            let x = &data[r * dim..(r + 1) * dim];
            let (mut b1, mut d1) = (0usize, f32::INFINITY);
            let (mut b2, mut d2) = (0usize, f32::INFINITY);
            for n in 0..codebook.nodes {
                let d = sq_dist(x, codebook.row(n));
                if d < d1 {
                    b2 = b1;
                    d2 = d1;
                    b1 = n;
                    d1 = d;
                } else if d < d2 {
                    b2 = n;
                    d2 = d;
                }
            }
            out.push((b1, b2));
        }
        out
    });
    parts.concat()
}

/// Topographic error: share of rows whose top-2 BMUs are not neighbors.
pub fn topographic_error(
    data: &[f32],
    dim: usize,
    grid: &Grid,
    codebook: &Codebook,
    threads: usize,
) -> f32 {
    let pairs = best_two(data, dim, codebook, threads);
    if pairs.is_empty() {
        return 0.0;
    }
    let bad = pairs
        .iter()
        .filter(|(b1, b2)| !grid.neighbors(*b1).contains(b2))
        .count();
    bad as f32 / pairs.len() as f32
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::som::grid::{GridType, MapType};

    #[test]
    fn qe_zero_for_exact_match() {
        let mut cb = Codebook::zeros(2, 2);
        cb.row_mut(0).copy_from_slice(&[1.0, 2.0]);
        cb.row_mut(1).copy_from_slice(&[3.0, 4.0]);
        let data = vec![1.0, 2.0, 3.0, 4.0];
        assert_eq!(quantization_error(&data, 2, &cb, &[0, 1]), 0.0);
    }

    #[test]
    fn qe_known_value() {
        let mut cb = Codebook::zeros(1, 2);
        cb.row_mut(0).copy_from_slice(&[0.0, 0.0]);
        let data = vec![3.0, 4.0]; // distance 5
        assert_eq!(quantization_error(&data, 2, &cb, &[0]), 5.0);
    }

    #[test]
    fn best_two_ordering() {
        let mut cb = Codebook::zeros(3, 1);
        cb.row_mut(0)[0] = 0.0;
        cb.row_mut(1)[0] = 1.0;
        cb.row_mut(2)[0] = 10.0;
        let pairs = best_two(&[0.4], 1, &cb, 1);
        assert_eq!(pairs, vec![(0, 1)]);
    }

    #[test]
    fn te_zero_when_adjacent() {
        // Codebook forms a smooth ramp along one row: top-2 are adjacent.
        let grid = Grid::new(1, 10, GridType::Square, MapType::Planar);
        let mut cb = Codebook::zeros(10, 1);
        for n in 0..10 {
            cb.row_mut(n)[0] = n as f32;
        }
        let data: Vec<f32> = (0..10).map(|i| i as f32 + 0.3).collect();
        let te = topographic_error(&data, 1, &grid, &cb, 2);
        assert_eq!(te, 0.0);
    }

    #[test]
    fn te_detects_folding() {
        // Node values alternate so top-2 BMUs are far apart on the grid.
        let grid = Grid::new(1, 10, GridType::Square, MapType::Planar);
        let mut cb = Codebook::zeros(10, 1);
        for n in 0..10 {
            cb.row_mut(n)[0] = if n % 2 == 0 { n as f32 } else { 100.0 };
        }
        let data = vec![1.0, 3.0, 5.0];
        let te = topographic_error(&data, 1, &grid, &cb, 1);
        assert!(te > 0.99);
    }
}
