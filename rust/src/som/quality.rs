//! Map quality measures: from QE/TE to a full metrics module.
//!
//! QE = mean distance of each data row to its BMU — the loss-curve the
//! end-to-end driver logs per epoch. TE = fraction of rows whose first
//! and second BMUs are not grid neighbors (a topology-preservation
//! check; not in the paper's tables but standard for SOM evaluation and
//! used in our integration tests).
//!
//! Beyond those two, this module computes rank-based projection metrics
//! ([`rank_metrics`]: trustworthiness + neighborhood preservation),
//! per-dimension component-plane summaries ([`component_planes`]), and
//! U-matrix statistics ([`umatrix_stats`]) — all bundled into a
//! versioned [`QualityReport`] that `somoclu quality` emits as JSON.
//! [`assert_quality_invariant`] is the reusable harness future perf PRs
//! use to assert "metrics unchanged within tolerance" instead of only
//! bit-equality.

use std::collections::BTreeMap;

use crate::kernels::simd::{self, BLOCK_ROWS};
use crate::som::codebook::Codebook;
use crate::som::grid::{Grid, GridType, MapType};
use crate::util::json::Json;
use crate::util::threadpool;

/// Squared Euclidean distance between two equal-length slices.
#[inline]
pub fn sq_dist(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = 0.0f32;
    for (x, y) in a.iter().zip(b) {
        let d = x - y;
        acc += d * d;
    }
    acc
}

/// Best-matching unit of one dense vector by plain linear scan:
/// `(node, distance)`, ties to the lowest node index. Kernel-independent
/// and deterministic — **the** BMU-lookup arithmetic shared by
/// [`crate::session::SomSession::bmu`] and the serving daemon's `bmu`
/// request path, so a served answer is bit-identical to the offline one
/// by construction, not by coincidence.
///
/// The caller guarantees `x.len() == codebook.dim` and a non-empty map;
/// distance is `sqrt(max(sq_dist, 0))` in f32 (the clamp guards the
/// tiny negative residue cancellation can leave).
pub fn linear_bmu(codebook: &Codebook, x: &[f32]) -> (usize, f32) {
    debug_assert_eq!(x.len(), codebook.dim);
    let mut best = 0usize;
    let mut best_d = f32::INFINITY;
    for n in 0..codebook.nodes {
        let d = sq_dist(x, codebook.row(n));
        if d < best_d {
            best_d = d;
            best = n;
        }
    }
    (best, best_d.max(0.0).sqrt())
}

/// Mean quantization error over dense rows given their BMUs.
///
/// Each row's Euclidean distance is computed in f32 (`sq_dist(..).sqrt()`
/// — the same bits the training kernels see), but the running **sum
/// accumulates in f64** and only the final mean rounds back to f32. A
/// single-f32 running sum loses increments once it dwarfs them (~1e7×
/// smaller increments vanish entirely), which at streaming scale
/// (millions of rows) made the logged loss curve drift and plateau
/// falsely; with f64 accumulation the result is within one f32 ulp of an
/// exact mean of the per-row f32 distances (see the 1e6-row property
/// test in `rust/tests/bmu_search_equivalence.rs`).
pub fn quantization_error(
    data: &[f32],
    dim: usize,
    codebook: &Codebook,
    bmus: &[usize],
) -> f32 {
    let rows = bmus.len();
    assert_eq!(data.len(), rows * dim);
    if rows == 0 {
        return 0.0;
    }
    let sum: f64 = (0..rows)
        .map(|r| {
            sq_dist(&data[r * dim..(r + 1) * dim], codebook.row(bmus[r])).sqrt() as f64
        })
        .sum();
    (sum / rows as f64) as f32
}

/// First and second BMU per row (dense, threaded), via the cache-blocked
/// [`crate::kernels::simd`] microkernel ([`simd::top2_scan_panel`] — the
/// Gram-score form `||w||²/2 − x·w`, which orders nodes exactly like the
/// squared distance for a fixed row). Ties break to the lowest node
/// index in both slots.
///
/// Invariant: every returned pair satisfies `b2 != b1` — the runner-up
/// is a *different* node even when all distances are equal (duplicate
/// codebook rows) or non-finite. Requires `codebook.nodes >= 2`
/// (asserted); single-node maps have no runner-up, and
/// [`topographic_error`] special-cases them before calling this.
pub fn best_two(
    data: &[f32],
    dim: usize,
    codebook: &Codebook,
    threads: usize,
) -> Vec<(usize, usize)> {
    assert!(
        codebook.nodes >= 2,
        "best_two needs at least 2 nodes (got {})",
        codebook.nodes
    );
    let rows = data.len() / dim;
    let kind = simd::dispatch();
    let panel_nodes = simd::default_panel_nodes(dim);
    let w2 = codebook.sq_norms();
    let (w2, nodes) = (w2.as_slice(), codebook.nodes);
    let parts = threadpool::parallel_ranges(rows, threads, |_, range| {
        let cnt = range.len();
        let mut b1 = vec![0u32; cnt];
        let mut s1 = vec![f32::INFINITY; cnt];
        let mut b2 = vec![0u32; cnt];
        let mut s2 = vec![f32::INFINITY; cnt];
        // Same panel-outer / 8-row-block-inner nest as
        // `search_bmus_blocked`; per-row top-2 state persists across
        // panels, so nodes are still visited in ascending order.
        let mut n0 = 0usize;
        while n0 < nodes {
            let n1 = (n0 + panel_nodes.max(1)).min(nodes);
            let panel = &codebook.weights[n0 * dim..n1 * dim];
            let pw2 = &w2[n0..n1];
            let mut off = 0usize;
            while off < cnt {
                let blen = (cnt - off).min(BLOCK_ROWS);
                let r0 = range.start + off;
                let x: [&[f32]; BLOCK_ROWS] = std::array::from_fn(|k| {
                    let r = r0 + k.min(blen - 1);
                    &data[r * dim..(r + 1) * dim]
                });
                let mut lb1 = [0u32; BLOCK_ROWS];
                let mut ls1 = [f32::INFINITY; BLOCK_ROWS];
                let mut lb2 = [0u32; BLOCK_ROWS];
                let mut ls2 = [f32::INFINITY; BLOCK_ROWS];
                lb1[..blen].copy_from_slice(&b1[off..off + blen]);
                ls1[..blen].copy_from_slice(&s1[off..off + blen]);
                lb2[..blen].copy_from_slice(&b2[off..off + blen]);
                ls2[..blen].copy_from_slice(&s2[off..off + blen]);
                simd::top2_scan_panel(
                    kind, &x, blen, panel, dim, pw2, n0 as u32, &mut lb1, &mut ls1, &mut lb2,
                    &mut ls2,
                );
                b1[off..off + blen].copy_from_slice(&lb1[..blen]);
                s1[off..off + blen].copy_from_slice(&ls1[..blen]);
                b2[off..off + blen].copy_from_slice(&lb2[..blen]);
                s2[off..off + blen].copy_from_slice(&ls2[..blen]);
                off += blen;
            }
            n0 = n1;
        }
        b1.iter()
            .zip(&b2)
            .map(|(&a, &b)| {
                let (a, mut b) = (a as usize, b as usize);
                // b2 == b1 is only reachable when every score after the
                // first was NaN (strict `<` never filled the runner-up
                // slot); keep the invariant with an arbitrary other node.
                if b == a {
                    b = if a == 0 { 1 } else { 0 };
                }
                (a, b)
            })
            .collect::<Vec<_>>()
    });
    parts.concat()
}

/// Topographic error: share of rows whose top-2 BMUs are not neighbors.
///
/// Degenerate maps: a single-node map (`codebook.nodes < 2`) has no
/// meaningful runner-up, so TE is defined as 0 — every row trivially
/// maps to the only topology there is. (Previously node 0 was scored by
/// whether it neighbors itself, which depends on the grid's neighbor
/// convention rather than on the map.)
pub fn topographic_error(
    data: &[f32],
    dim: usize,
    grid: &Grid,
    codebook: &Codebook,
    threads: usize,
) -> f32 {
    if codebook.nodes < 2 {
        return 0.0;
    }
    let pairs = best_two(data, dim, codebook, threads);
    if pairs.is_empty() {
        return 0.0;
    }
    let bad = pairs
        .iter()
        .filter(|(b1, b2)| !grid.neighbors(*b1).contains(b2))
        .count();
    bad as f32 / pairs.len() as f32
}

/// Rank-based projection quality: trustworthiness + neighborhood
/// preservation (continuity) at one neighborhood size.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RankMetrics {
    /// Trustworthiness T(k) ∈ (−∞, 1]: penalizes samples that look like
    /// map-space neighbors but are far apart in input space ("false
    /// friends" the projection invented). 1.0 = none.
    pub trustworthiness: f64,
    /// Neighborhood preservation / continuity C(k): penalizes input-space
    /// neighbors the projection tore apart. 1.0 = none.
    pub neighborhood_preservation: f64,
    /// The neighborhood size actually used, after clamping the request
    /// to `min(k, (2N−2)/3).max(1)` so the normalizer stays positive.
    pub k: usize,
}

/// Compute [`RankMetrics`] for a trained map.
///
/// Input-space neighbors of sample `i` are ranked by squared Euclidean
/// distance ([`sq_dist`]); map-space neighbors by the grid distance
/// between BMU nodes ([`Grid::distance`]). Both rankings break distance
/// ties by the lower sample index, so ranks — and therefore both
/// metrics — are fully deterministic. Penalties accumulate as exact
/// integers (`u64` rank excesses) summed over per-thread partials, so
/// the result is **bit-identical across thread counts**.
///
/// Definitions (Venna & Kaski): with `r_in(i,j)` the input-space rank
/// of `j` among `i`'s neighbors and `U_k(i)` the samples inside `i`'s
/// map-space k-NN but outside its input-space k-NN,
///
/// ```text
/// T(k) = 1 − 2/(N·k·(2N−3k−1)) · Σ_i Σ_{j ∈ U_k(i)} (r_in(i,j) − k)
/// ```
///
/// and neighborhood preservation is the same with the two spaces
/// swapped. Maps with `N ≤ 3` samples have no meaningful neighborhood
/// structure and score 1.0 by definition.
///
/// Cost is O(N² log N) — fine for evaluation-sized sets; `somoclu
/// quality` runs it once per invocation, never inside training.
pub fn rank_metrics(
    data: &[f32],
    dim: usize,
    grid: &Grid,
    bmus: &[u32],
    k: usize,
    threads: usize,
) -> RankMetrics {
    let rows = bmus.len();
    assert_eq!(data.len(), rows * dim, "data shape mismatch");
    if rows <= 3 {
        return RankMetrics {
            trustworthiness: 1.0,
            neighborhood_preservation: 1.0,
            k: k.max(1),
        };
    }
    let n = rows;
    let k_eff = k.min((2 * n - 2) / 3).max(1);
    let parts = threadpool::parallel_ranges(rows, threads, |_, range| {
        let mut trust_pen = 0u64;
        let mut np_pen = 0u64;
        // Scratch reused across samples in this shard.
        let mut order: Vec<u32> = Vec::with_capacity(n - 1);
        let mut rank_in = vec![0u32; n];
        let mut rank_out = vec![0u32; n];
        let mut out_knn: Vec<u32> = Vec::with_capacity(k_eff);
        let mut in_knn: Vec<u32> = Vec::with_capacity(k_eff);
        for i in range {
            let xi = &data[i * dim..(i + 1) * dim];
            let bi = bmus[i] as usize;
            // Input-space ranking: (distance, index) under total_cmp.
            order.clear();
            order.extend((0..n as u32).filter(|&j| j as usize != i));
            order.sort_unstable_by(|&a, &b| {
                let da = sq_dist(xi, &data[a as usize * dim..(a as usize + 1) * dim]);
                let db = sq_dist(xi, &data[b as usize * dim..(b as usize + 1) * dim]);
                da.total_cmp(&db).then(a.cmp(&b))
            });
            for (p, &j) in order.iter().enumerate() {
                rank_in[j as usize] = p as u32 + 1;
            }
            in_knn.clear();
            in_knn.extend_from_slice(&order[..k_eff]);
            // Map-space ranking: grid distance between BMU nodes.
            order.sort_unstable_by(|&a, &b| {
                let da = grid.distance(bi, bmus[a as usize] as usize);
                let db = grid.distance(bi, bmus[b as usize] as usize);
                da.total_cmp(&db).then(a.cmp(&b))
            });
            for (p, &j) in order.iter().enumerate() {
                rank_out[j as usize] = p as u32 + 1;
            }
            out_knn.clear();
            out_knn.extend_from_slice(&order[..k_eff]);
            // Trustworthiness: map-space neighbors that are input-far.
            for &j in &out_knn {
                let r = rank_in[j as usize] as u64;
                if r > k_eff as u64 {
                    trust_pen += r - k_eff as u64;
                }
            }
            // Preservation: input-space neighbors that are map-far.
            for &j in &in_knn {
                let r = rank_out[j as usize] as u64;
                if r > k_eff as u64 {
                    np_pen += r - k_eff as u64;
                }
            }
        }
        (trust_pen, np_pen)
    });
    let (trust_pen, np_pen) = parts
        .iter()
        .fold((0u64, 0u64), |(t, p), &(dt, dp)| (t + dt, p + dp));
    let norm = 2.0 / (n as f64 * k_eff as f64 * (2 * n - 3 * k_eff - 1) as f64);
    RankMetrics {
        trustworthiness: 1.0 - norm * trust_pen as f64,
        neighborhood_preservation: 1.0 - norm * np_pen as f64,
        k: k_eff,
    }
}

/// Summary statistics of one codebook dimension across all nodes — the
/// scalar digest of a component plane (the per-dimension heatmap SOM
/// practice reads cluster structure from).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ComponentPlane {
    /// Which input dimension this plane describes.
    pub dim: usize,
    pub min: f32,
    pub max: f32,
    /// Mean over nodes, accumulated in f64.
    pub mean: f32,
}

/// One [`ComponentPlane`] summary per input dimension. The full
/// per-node plane values are `codebook.weights[n*dim + d]` — the CLI
/// exports them verbatim under `--planes`; this function only digests.
pub fn component_planes(codebook: &Codebook) -> Vec<ComponentPlane> {
    let (nodes, dim) = (codebook.nodes, codebook.dim);
    (0..dim)
        .map(|d| {
            let mut min = f32::INFINITY;
            let mut max = f32::NEG_INFINITY;
            let mut sum = 0.0f64;
            for n in 0..nodes {
                let w = codebook.weights[n * dim + d];
                min = min.min(w);
                max = max.max(w);
                sum += w as f64;
            }
            if nodes == 0 {
                (min, max) = (0.0, 0.0);
            }
            ComponentPlane {
                dim: d,
                min,
                max,
                mean: if nodes == 0 { 0.0 } else { (sum / nodes as f64) as f32 },
            }
        })
        .collect()
}

/// Distribution summary of a U-matrix: how sharp the cluster borders
/// are (high max/median ratio = well-separated clusters).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct UmatrixStats {
    pub min: f32,
    pub max: f32,
    /// Mean over nodes, accumulated in f64.
    pub mean: f64,
    /// Median (average of the middle two for even lengths).
    pub median: f32,
}

/// Compute [`UmatrixStats`] over per-node U-matrix values. An empty
/// slice yields all zeros.
pub fn umatrix_stats(um: &[f32]) -> UmatrixStats {
    if um.is_empty() {
        return UmatrixStats {
            min: 0.0,
            max: 0.0,
            mean: 0.0,
            median: 0.0,
        };
    }
    let mut sorted = um.to_vec();
    sorted.sort_unstable_by(|a, b| a.total_cmp(b));
    let mid = sorted.len() / 2;
    let median = if sorted.len() % 2 == 1 {
        sorted[mid]
    } else {
        (sorted[mid - 1] + sorted[mid]) / 2.0
    };
    UmatrixStats {
        min: sorted[0],
        max: sorted[sorted.len() - 1],
        mean: um.iter().map(|&v| v as f64).sum::<f64>() / um.len() as f64,
        median,
    }
}

/// Everything `somoclu quality` reports, in one struct.
///
/// Built by [`QualityReport::compute`]; serialized by
/// [`QualityReport::to_json`] as a **version 1** JSON document. QE and
/// TE are computed by the exact [`quantization_error`] /
/// [`topographic_error`] functions above, so the CLI numbers match
/// library callers bit-for-bit.
#[derive(Clone, Debug)]
pub struct QualityReport {
    /// Mean quantization error ([`quantization_error`]).
    pub qe: f32,
    /// Topographic error ([`topographic_error`]).
    pub te: f32,
    /// Rank-based metrics ([`rank_metrics`]) at the report's k.
    pub rank: RankMetrics,
    /// Number of evaluated data rows.
    pub rows: usize,
    /// Input dimensionality.
    pub dim: usize,
    /// Map geometry, echoed so a report is self-describing.
    pub map_rows: usize,
    pub map_cols: usize,
    pub grid_type: GridType,
    pub map_type: MapType,
    /// One summary per input dimension ([`component_planes`]).
    pub component_planes: Vec<ComponentPlane>,
    /// U-matrix digest, when a U-matrix was available.
    pub umatrix: Option<UmatrixStats>,
    /// Full per-node plane values (`planes[d][node]`), only when the
    /// caller asked for the heavy export (CLI `--planes`).
    pub plane_values: Option<Vec<Vec<f32>>>,
}

impl QualityReport {
    /// Evaluate a trained map against `data` (dense row-major
    /// `rows × dim`). `bmus` must be the BMUs of `data` on `codebook`
    /// (e.g. from [`crate::session::SomSession::project`]); `umatrix`
    /// is optional per-node values; `knn` is the requested neighborhood
    /// size for [`rank_metrics`] (clamped as documented there).
    pub fn compute(
        data: &[f32],
        dim: usize,
        grid: &Grid,
        codebook: &Codebook,
        bmus: &[u32],
        umatrix: Option<&[f32]>,
        knn: usize,
        threads: usize,
    ) -> QualityReport {
        let rows = bmus.len();
        assert_eq!(data.len(), rows * dim, "data shape mismatch");
        assert_eq!(codebook.dim, dim, "codebook dim mismatch");
        let bmus_usize: Vec<usize> = bmus.iter().map(|&b| b as usize).collect();
        let qe = quantization_error(data, dim, codebook, &bmus_usize);
        let te = topographic_error(data, dim, grid, codebook, threads);
        let rank = rank_metrics(data, dim, grid, bmus, knn, threads);
        QualityReport {
            qe,
            te,
            rank,
            rows,
            dim,
            map_rows: grid.rows,
            map_cols: grid.cols,
            grid_type: grid.grid_type,
            map_type: grid.map_type,
            component_planes: component_planes(codebook),
            umatrix: umatrix.map(umatrix_stats),
            plane_values: None,
        }
    }

    /// Attach the full per-node component-plane values (`planes[d]` has
    /// one entry per node) for the heavy export path.
    pub fn with_plane_values(mut self, codebook: &Codebook) -> QualityReport {
        let (nodes, dim) = (codebook.nodes, codebook.dim);
        self.plane_values = Some(
            (0..dim)
                .map(|d| (0..nodes).map(|n| codebook.weights[n * dim + d]).collect())
                .collect(),
        );
        self
    }

    /// Versioned JSON document (schema version 1). Stable keys, sorted
    /// output; `umatrix` is `null` when absent and `plane_values` is
    /// omitted entirely unless exported.
    pub fn to_json(&self) -> Json {
        let mut obj = BTreeMap::new();
        obj.insert("version".into(), Json::Num(1.0));
        obj.insert("qe".into(), Json::Num(self.qe as f64));
        obj.insert("te".into(), Json::Num(self.te as f64));
        obj.insert("knn".into(), Json::Num(self.rank.k as f64));
        obj.insert(
            "trustworthiness".into(),
            Json::Num(self.rank.trustworthiness),
        );
        obj.insert(
            "neighborhood_preservation".into(),
            Json::Num(self.rank.neighborhood_preservation),
        );
        obj.insert("rows".into(), Json::Num(self.rows as f64));
        obj.insert("dim".into(), Json::Num(self.dim as f64));
        let mut map = BTreeMap::new();
        map.insert("rows".into(), Json::Num(self.map_rows as f64));
        map.insert("cols".into(), Json::Num(self.map_cols as f64));
        map.insert(
            "grid".into(),
            Json::Str(
                match self.grid_type {
                    GridType::Square => "square",
                    GridType::Hexagonal => "hexagonal",
                }
                .into(),
            ),
        );
        map.insert(
            "topology".into(),
            Json::Str(
                match self.map_type {
                    MapType::Planar => "planar",
                    MapType::Toroid => "toroid",
                }
                .into(),
            ),
        );
        obj.insert("map".into(), Json::Obj(map));
        let planes: Vec<Json> = self
            .component_planes
            .iter()
            .map(|p| {
                let mut o = BTreeMap::new();
                o.insert("dim".into(), Json::Num(p.dim as f64));
                o.insert("min".into(), Json::Num(p.min as f64));
                o.insert("max".into(), Json::Num(p.max as f64));
                o.insert("mean".into(), Json::Num(p.mean as f64));
                Json::Obj(o)
            })
            .collect();
        obj.insert("component_planes".into(), Json::Arr(planes));
        obj.insert(
            "umatrix".into(),
            match &self.umatrix {
                None => Json::Null,
                Some(u) => {
                    let mut o = BTreeMap::new();
                    o.insert("min".into(), Json::Num(u.min as f64));
                    o.insert("max".into(), Json::Num(u.max as f64));
                    o.insert("mean".into(), Json::Num(u.mean));
                    o.insert("median".into(), Json::Num(u.median as f64));
                    Json::Obj(o)
                }
            },
        );
        if let Some(planes) = &self.plane_values {
            obj.insert(
                "plane_values".into(),
                Json::Arr(
                    planes
                        .iter()
                        .map(|p| {
                            Json::Arr(p.iter().map(|&v| Json::Num(v as f64)).collect())
                        })
                        .collect(),
                ),
            );
        }
        Json::Obj(obj)
    }
}

/// Quality-invariance harness: assert that two reports describe the
/// same map equally well, naming the first divergent metric.
///
/// Shape fields (rows, dim, map geometry) must match **exactly**; the
/// scalar metrics (QE, TE, trustworthiness, neighborhood preservation,
/// U-matrix mean) must agree within absolute tolerance `tol`. Perf PRs
/// that intentionally reorder arithmetic should pin behavior with this
/// (e.g. `tol = 1e-5`) where bit-equality is too strict — and keep
/// bit-level tests where it isn't.
///
/// Panics with the divergent metric's name and both values.
pub fn assert_quality_invariant(a: &QualityReport, b: &QualityReport, tol: f64) {
    assert_eq!(a.rows, b.rows, "quality invariant: rows differ");
    assert_eq!(a.dim, b.dim, "quality invariant: dim differs");
    assert_eq!(
        (a.map_rows, a.map_cols),
        (b.map_rows, b.map_cols),
        "quality invariant: map geometry differs"
    );
    let checks: [(&str, f64, f64); 5] = [
        ("qe", a.qe as f64, b.qe as f64),
        ("te", a.te as f64, b.te as f64),
        (
            "trustworthiness",
            a.rank.trustworthiness,
            b.rank.trustworthiness,
        ),
        (
            "neighborhood_preservation",
            a.rank.neighborhood_preservation,
            b.rank.neighborhood_preservation,
        ),
        (
            "umatrix_mean",
            a.umatrix.map_or(0.0, |u| u.mean),
            b.umatrix.map_or(0.0, |u| u.mean),
        ),
    ];
    for (name, va, vb) in checks {
        assert!(
            (va - vb).abs() <= tol,
            "quality invariant violated: {name} diverged ({va} vs {vb}, tol {tol})"
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::som::grid::{GridType, MapType};

    #[test]
    fn qe_zero_for_exact_match() {
        let mut cb = Codebook::zeros(2, 2);
        cb.row_mut(0).copy_from_slice(&[1.0, 2.0]);
        cb.row_mut(1).copy_from_slice(&[3.0, 4.0]);
        let data = vec![1.0, 2.0, 3.0, 4.0];
        assert_eq!(quantization_error(&data, 2, &cb, &[0, 1]), 0.0);
    }

    #[test]
    fn qe_known_value() {
        let mut cb = Codebook::zeros(1, 2);
        cb.row_mut(0).copy_from_slice(&[0.0, 0.0]);
        let data = vec![3.0, 4.0]; // distance 5
        assert_eq!(quantization_error(&data, 2, &cb, &[0]), 5.0);
    }

    #[test]
    fn best_two_ordering() {
        let mut cb = Codebook::zeros(3, 1);
        cb.row_mut(0)[0] = 0.0;
        cb.row_mut(1)[0] = 1.0;
        cb.row_mut(2)[0] = 10.0;
        let pairs = best_two(&[0.4], 1, &cb, 1);
        assert_eq!(pairs, vec![(0, 1)]);
    }

    #[test]
    fn te_zero_when_adjacent() {
        // Codebook forms a smooth ramp along one row: top-2 are adjacent.
        let grid = Grid::new(1, 10, GridType::Square, MapType::Planar);
        let mut cb = Codebook::zeros(10, 1);
        for n in 0..10 {
            cb.row_mut(n)[0] = n as f32;
        }
        let data: Vec<f32> = (0..10).map(|i| i as f32 + 0.3).collect();
        let te = topographic_error(&data, 1, &grid, &cb, 2);
        assert_eq!(te, 0.0);
    }

    #[test]
    fn qe_mean_accumulates_in_f64() {
        // 1 + eps + eps + ... with an increment small enough that a
        // single-f32 running sum would drop every addend after the
        // first: the f64 accumulator must keep them.
        let rows = 4097usize;
        let mut cb = Codebook::zeros(2, 1);
        cb.row_mut(1)[0] = 1e-5;
        let mut data = vec![0.0f32; rows];
        data[0] = 1e4; // distance 1e4 to node 0
        let mut bmus = vec![1usize; rows]; // distance 1e-5 each
        bmus[0] = 0;
        let got = quantization_error(&data, 1, &cb, &bmus) as f64;
        let want = (1e4 + (rows - 1) as f64 * 1e-5) / rows as f64;
        assert!((got - want).abs() < want * 1e-6, "{got} vs {want}");
        // The f32-sum version would report exactly 1e4/rows.
        let f32_sum = 1e4f64 / rows as f64;
        assert!((got - f32_sum).abs() > want * 1e-9);
    }

    #[test]
    fn te_zero_for_single_node_map() {
        let grid = Grid::new(1, 1, GridType::Square, MapType::Planar);
        let cb = Codebook::zeros(1, 2);
        let data = vec![0.5, 0.5, 1.0, -1.0];
        assert_eq!(topographic_error(&data, 2, &grid, &cb, 2), 0.0);
    }

    #[test]
    #[should_panic(expected = "at least 2 nodes")]
    fn best_two_rejects_single_node_map() {
        let cb = Codebook::zeros(1, 2);
        best_two(&[0.0, 0.0], 2, &cb, 1);
    }

    #[test]
    fn best_two_distinct_even_when_all_nodes_equal() {
        // Duplicate codebook rows: every distance ties. Lowest-index tie
        // rule ⇒ (0, 1), and the b2 != b1 invariant must hold.
        let cb = Codebook::zeros(6, 3);
        let data = vec![0.25f32; 4 * 3];
        for (b1, b2) in best_two(&data, 3, &cb, 2) {
            assert_eq!((b1, b2), (0, 1));
        }
    }

    #[test]
    fn te_detects_folding() {
        // Node values alternate so top-2 BMUs are far apart on the grid.
        let grid = Grid::new(1, 10, GridType::Square, MapType::Planar);
        let mut cb = Codebook::zeros(10, 1);
        for n in 0..10 {
            cb.row_mut(n)[0] = if n % 2 == 0 { n as f32 } else { 100.0 };
        }
        let data = vec![1.0, 3.0, 5.0];
        let te = topographic_error(&data, 1, &grid, &cb, 1);
        assert!(te > 0.99);
    }

    /// A 1-D ramp mapped onto a 1×N strip in order: every neighborhood
    /// is perfectly preserved in both directions.
    #[test]
    fn rank_metrics_perfect_on_ordered_strip() {
        let n = 12usize;
        let grid = Grid::new(1, n, GridType::Square, MapType::Planar);
        let data: Vec<f32> = (0..n).map(|i| i as f32).collect();
        let bmus: Vec<u32> = (0..n as u32).collect();
        let m = rank_metrics(&data, 1, &grid, &bmus, 3, 2);
        assert_eq!(m.k, 3);
        assert_eq!(m.trustworthiness, 1.0);
        assert_eq!(m.neighborhood_preservation, 1.0);
    }

    /// Reversing half the strip tears input neighborhoods apart and
    /// invents false map neighborhoods: both metrics must drop.
    #[test]
    fn rank_metrics_detect_a_folded_projection() {
        let n = 12usize;
        let grid = Grid::new(1, n, GridType::Square, MapType::Planar);
        let data: Vec<f32> = (0..n).map(|i| i as f32).collect();
        // Interleave the two halves: 0,6,1,7,2,8,...
        let mut bmus = vec![0u32; n];
        for i in 0..n {
            bmus[i] = if i % 2 == 0 { i as u32 / 2 } else { 6 + i as u32 / 2 };
        }
        let m = rank_metrics(&data, 1, &grid, &bmus, 3, 1);
        assert!(m.trustworthiness < 0.95, "{}", m.trustworthiness);
        assert!(
            m.neighborhood_preservation < 0.95,
            "{}",
            m.neighborhood_preservation
        );
    }

    #[test]
    fn rank_metrics_thread_invariant_bits() {
        let grid = Grid::new(4, 5, GridType::Hexagonal, MapType::Planar);
        let mut rng = crate::util::rng::Rng::new(7);
        let data: Vec<f32> = (0..40 * 3).map(|_| rng.f32()).collect();
        let bmus: Vec<u32> = (0..40).map(|_| rng.next_u64() as u32 % 20).collect();
        let a = rank_metrics(&data, 3, &grid, &bmus, 5, 1);
        for t in [2, 4, 16] {
            let b = rank_metrics(&data, 3, &grid, &bmus, 5, t);
            assert_eq!(a.trustworthiness.to_bits(), b.trustworthiness.to_bits());
            assert_eq!(
                a.neighborhood_preservation.to_bits(),
                b.neighborhood_preservation.to_bits()
            );
        }
    }

    #[test]
    fn rank_metrics_trivial_for_tiny_sets() {
        let grid = Grid::new(2, 2, GridType::Square, MapType::Planar);
        let m = rank_metrics(&[0.0, 1.0, 2.0], 1, &grid, &[0, 1, 2], 10, 1);
        assert_eq!(m.trustworthiness, 1.0);
        assert_eq!(m.neighborhood_preservation, 1.0);
    }

    #[test]
    fn component_planes_known_values() {
        let mut cb = Codebook::zeros(3, 2);
        cb.row_mut(0).copy_from_slice(&[1.0, -1.0]);
        cb.row_mut(1).copy_from_slice(&[2.0, 0.0]);
        cb.row_mut(2).copy_from_slice(&[3.0, 1.0]);
        let planes = component_planes(&cb);
        assert_eq!(planes.len(), 2);
        assert_eq!((planes[0].min, planes[0].max, planes[0].mean), (1.0, 3.0, 2.0));
        assert_eq!((planes[1].min, planes[1].max, planes[1].mean), (-1.0, 1.0, 0.0));
        assert_eq!(planes[0].dim, 0);
        assert_eq!(planes[1].dim, 1);
    }

    #[test]
    fn umatrix_stats_medians() {
        let odd = umatrix_stats(&[3.0, 1.0, 2.0]);
        assert_eq!((odd.min, odd.max, odd.median), (1.0, 3.0, 2.0));
        assert_eq!(odd.mean, 2.0);
        let even = umatrix_stats(&[4.0, 1.0, 3.0, 2.0]);
        assert_eq!(even.median, 2.5);
        let empty = umatrix_stats(&[]);
        assert_eq!((empty.min, empty.max, empty.mean, empty.median), (0.0, 0.0, 0.0, 0.0));
    }

    fn tiny_report() -> QualityReport {
        let grid = Grid::new(2, 3, GridType::Square, MapType::Planar);
        let mut cb = Codebook::zeros(6, 2);
        for n in 0..6 {
            cb.row_mut(n).copy_from_slice(&[n as f32, -(n as f32)]);
        }
        let data = vec![0.1, 0.0, 1.2, -1.0, 2.1, -2.0, 3.9, -4.0, 5.0, -5.1];
        let bmus = vec![0u32, 1, 2, 4, 5];
        let um = vec![0.5f32, 1.0, 0.25, 2.0, 1.5, 0.75];
        QualityReport::compute(&data, 2, &grid, &cb, &bmus, Some(&um), 2, 2)
    }

    #[test]
    fn report_qe_te_match_the_direct_functions() {
        let grid = Grid::new(2, 3, GridType::Square, MapType::Planar);
        let mut cb = Codebook::zeros(6, 2);
        for n in 0..6 {
            cb.row_mut(n).copy_from_slice(&[n as f32, -(n as f32)]);
        }
        let data = vec![0.1, 0.0, 1.2, -1.0, 2.1, -2.0, 3.9, -4.0, 5.0, -5.1];
        let bmus = vec![0u32, 1, 2, 4, 5];
        let r = tiny_report();
        let bmus_usize: Vec<usize> = bmus.iter().map(|&b| b as usize).collect();
        assert_eq!(
            r.qe.to_bits(),
            quantization_error(&data, 2, &cb, &bmus_usize).to_bits()
        );
        assert_eq!(
            r.te.to_bits(),
            topographic_error(&data, 2, &grid, &cb, 2).to_bits()
        );
    }

    #[test]
    fn report_json_is_versioned_and_round_trips() {
        let r = tiny_report();
        let j = r.to_json();
        assert_eq!(j.get("version").and_then(|v| v.as_usize()), Some(1));
        assert_eq!(j.get("rows").and_then(|v| v.as_usize()), Some(5));
        assert_eq!(j.get("dim").and_then(|v| v.as_usize()), Some(2));
        let map = j.get("map").unwrap();
        assert_eq!(map.get("grid").and_then(|v| v.as_str()), Some("square"));
        assert_eq!(map.get("topology").and_then(|v| v.as_str()), Some("planar"));
        assert!(j.get("plane_values").is_none());
        let planes = j.get("component_planes").unwrap().as_arr().unwrap();
        assert_eq!(planes.len(), 2);
        assert!(j.get("umatrix").unwrap().as_obj().is_some());
        // Round-trip through the text form.
        let rt = crate::util::json::Json::parse(&j.to_string()).unwrap();
        assert_eq!(
            rt.get("qe").and_then(|v| v.as_f64()),
            j.get("qe").and_then(|v| v.as_f64())
        );
    }

    #[test]
    fn report_plane_values_exported_on_request() {
        let grid = Grid::new(2, 3, GridType::Square, MapType::Planar);
        let mut cb = Codebook::zeros(6, 2);
        for n in 0..6 {
            cb.row_mut(n).copy_from_slice(&[n as f32, -(n as f32)]);
        }
        let r = tiny_report().with_plane_values(&cb);
        let planes = r.plane_values.as_ref().unwrap();
        assert_eq!(planes.len(), 2);
        assert_eq!(planes[0].len(), 6);
        assert_eq!(planes[0][3], 3.0);
        assert_eq!(planes[1][3], -3.0);
        let j = r.to_json();
        let pv = j.get("plane_values").unwrap().as_arr().unwrap();
        assert_eq!(pv.len(), 2);
        assert_eq!(pv[0].as_arr().unwrap().len(), 6);
        let _ = grid;
    }

    #[test]
    fn quality_invariant_accepts_small_drift() {
        let a = tiny_report();
        let mut b = a.clone();
        b.qe += 1e-7;
        assert_quality_invariant(&a, &b, 1e-5);
    }

    #[test]
    #[should_panic(expected = "te diverged")]
    fn quality_invariant_names_the_divergent_metric() {
        let a = tiny_report();
        let mut b = a.clone();
        b.te += 0.5;
        assert_quality_invariant(&a, &b, 1e-5);
    }
}
