//! K-means clustering of the trained codebook — somoclu's Python API
//! offers `som.cluster()` to post-process the map into discrete
//! clusters (neurons -> cluster labels, which the BMU mapping then
//! extends to data points). In-repo substrate: k-means++ seeding +
//! Lloyd iterations, deterministic given the seed.

use crate::som::codebook::Codebook;
use crate::som::quality::sq_dist;
use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct KmeansResult {
    pub k: usize,
    /// Cluster label per codebook node.
    pub labels: Vec<u32>,
    /// Cluster centroids, [k x dim].
    pub centroids: Vec<f32>,
    /// Sum of squared distances to assigned centroids.
    pub inertia: f64,
    pub iterations: usize,
}

/// k-means++ seeding: spread initial centroids by D² sampling.
fn seed_centroids(cb: &Codebook, k: usize, rng: &mut Rng) -> Vec<f32> {
    let n = cb.nodes;
    let dim = cb.dim;
    let mut centroids = Vec::with_capacity(k * dim);
    let first = rng.below(n as u64) as usize;
    centroids.extend_from_slice(cb.row(first));
    let mut d2: Vec<f64> = (0..n)
        .map(|i| sq_dist(cb.row(i), cb.row(first)) as f64)
        .collect();
    for _ in 1..k {
        let total: f64 = d2.iter().sum();
        let pick = if total <= 0.0 {
            rng.below(n as u64) as usize
        } else {
            let mut target = rng.f64() * total;
            let mut chosen = n - 1;
            for (i, &w) in d2.iter().enumerate() {
                target -= w;
                if target <= 0.0 {
                    chosen = i;
                    break;
                }
            }
            chosen
        };
        let c0 = centroids.len();
        centroids.extend_from_slice(cb.row(pick));
        let new_c = &centroids[c0..c0 + dim];
        for i in 0..n {
            let d = sq_dist(cb.row(i), new_c) as f64;
            if d < d2[i] {
                d2[i] = d;
            }
        }
    }
    centroids
}

/// Cluster the codebook into `k` groups (Lloyd's algorithm, max_iter
/// cap, convergence when assignments stop changing).
pub fn kmeans(cb: &Codebook, k: usize, max_iter: usize, rng: &mut Rng) -> KmeansResult {
    let n = cb.nodes;
    let dim = cb.dim;
    assert!(k >= 1 && k <= n, "k={k} out of range for {n} nodes");

    let mut centroids = seed_centroids(cb, k, rng);
    let mut labels = vec![0u32; n];
    let mut iterations = 0;
    for it in 0..max_iter.max(1) {
        iterations = it + 1;
        // Assign.
        let mut changed = false;
        for i in 0..n {
            let row = cb.row(i);
            let (mut best, mut best_d) = (0u32, f32::INFINITY);
            for c in 0..k {
                let d = sq_dist(row, &centroids[c * dim..(c + 1) * dim]);
                if d < best_d {
                    best_d = d;
                    best = c as u32;
                }
            }
            if labels[i] != best {
                labels[i] = best;
                changed = true;
            }
        }
        if !changed && it > 0 {
            break;
        }
        // Update (empty clusters keep their previous centroid).
        let mut sums = vec![0.0f64; k * dim];
        let mut counts = vec![0usize; k];
        for i in 0..n {
            let c = labels[i] as usize;
            counts[c] += 1;
            for (s, v) in sums[c * dim..(c + 1) * dim].iter_mut().zip(cb.row(i)) {
                *s += *v as f64;
            }
        }
        for c in 0..k {
            if counts[c] > 0 {
                for d in 0..dim {
                    centroids[c * dim + d] =
                        (sums[c * dim + d] / counts[c] as f64) as f32;
                }
            }
        }
    }

    let inertia: f64 = (0..n)
        .map(|i| {
            sq_dist(
                cb.row(i),
                &centroids[labels[i] as usize * dim..(labels[i] as usize + 1) * dim],
            ) as f64
        })
        .sum();

    KmeansResult {
        k,
        labels,
        centroids,
        inertia,
        iterations,
    }
}

/// Extend node labels to data labels through the BMU mapping (what
/// `som.cluster()` gives back for the data set).
pub fn data_labels(result: &KmeansResult, bmus: &[u32]) -> Vec<u32> {
    bmus.iter().map(|&b| result.labels[b as usize]).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn blob_codebook(k: usize, per: usize, dim: usize, rng: &mut Rng) -> Codebook {
        let mut cb = Codebook::zeros(k * per, dim);
        for c in 0..k {
            for i in 0..per {
                let row = cb.row_mut(c * per + i);
                for d in 0..dim {
                    row[d] = (c * 10) as f32 + 0.05 * rng.normal_f32();
                }
            }
        }
        cb
    }

    #[test]
    fn recovers_separated_clusters() {
        let mut rng = Rng::new(81);
        let cb = blob_codebook(3, 20, 4, &mut rng);
        let res = kmeans(&cb, 3, 50, &mut rng);
        // All nodes of a true group share a label; groups have distinct
        // labels.
        for c in 0..3 {
            let l0 = res.labels[c * 20];
            for i in 0..20 {
                assert_eq!(res.labels[c * 20 + i], l0, "group {c}");
            }
        }
        let mut uniq: Vec<u32> = res.labels.clone();
        uniq.sort_unstable();
        uniq.dedup();
        assert_eq!(uniq.len(), 3);
        assert!(res.inertia < 1.0, "{}", res.inertia);
    }

    #[test]
    fn deterministic_given_seed() {
        let mut rng1 = Rng::new(82);
        let cb = blob_codebook(4, 10, 3, &mut rng1);
        let a = kmeans(&cb, 4, 50, &mut Rng::new(5));
        let b = kmeans(&cb, 4, 50, &mut Rng::new(5));
        assert_eq!(a.labels, b.labels);
        assert_eq!(a.centroids, b.centroids);
    }

    #[test]
    fn k_equals_one_and_n() {
        let mut rng = Rng::new(83);
        let cb = blob_codebook(2, 5, 3, &mut rng);
        let one = kmeans(&cb, 1, 10, &mut rng);
        assert!(one.labels.iter().all(|&l| l == 0));
        let all = kmeans(&cb, 10, 10, &mut rng);
        assert_eq!(all.labels.len(), 10);
        assert!(all.inertia < 1.0);
    }

    #[test]
    fn data_labels_follow_bmus() {
        let mut rng = Rng::new(84);
        let cb = blob_codebook(2, 4, 3, &mut rng);
        let res = kmeans(&cb, 2, 20, &mut rng);
        let bmus = vec![0u32, 5, 7, 2];
        let labels = data_labels(&res, &bmus);
        assert_eq!(labels[0], res.labels[0]);
        assert_eq!(labels[1], res.labels[5]);
        assert_eq!(labels.len(), 4);
    }

    #[test]
    fn inertia_decreases_with_k() {
        let mut rng = Rng::new(85);
        let cb = blob_codebook(4, 15, 5, &mut rng);
        let i2 = kmeans(&cb, 2, 50, &mut Rng::new(1)).inertia;
        let i4 = kmeans(&cb, 4, 50, &mut Rng::new(1)).inertia;
        assert!(i4 < i2, "{i4} !< {i2}");
    }
}
