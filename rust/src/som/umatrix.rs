//! U-matrix (Eq. 7): mean codebook distance to immediate grid neighbors.
//!
//! "The purpose of the U-matrix is to give a visual representation of the
//! topology of the network." Computed CPU-side here (cheap: N·K·D flops),
//! or through the AOT `umatrix_*` artifact on the accel path.

use crate::som::codebook::Codebook;
use crate::som::grid::Grid;
use crate::util::threadpool;

/// U(j) for every node, parallelized over nodes.
pub fn umatrix(grid: &Grid, codebook: &Codebook, threads: usize) -> Vec<f32> {
    assert_eq!(grid.node_count(), codebook.nodes);
    let parts = threadpool::parallel_ranges(codebook.nodes, threads, |_, range| {
        let mut out = Vec::with_capacity(range.len());
        for node in range {
            let nbs = grid.neighbors(node);
            if nbs.is_empty() {
                out.push(0.0);
                continue;
            }
            let wj = codebook.row(node);
            let mut sum = 0.0f32;
            for nb in &nbs {
                let wi = codebook.row(*nb);
                let mut d2 = 0.0f32;
                for (a, b) in wj.iter().zip(wi) {
                    let diff = a - b;
                    d2 += diff * diff;
                }
                sum += d2.sqrt();
            }
            out.push(sum / nbs.len() as f32);
        }
        out
    });
    parts.concat()
}

/// Neighbor index/mask tables for the AOT umatrix artifact
/// ([N, K] i32 indices + [N, K] f32 mask, K = max neighbor count).
pub fn neighbor_tables(grid: &Grid, k: usize) -> (Vec<i32>, Vec<f32>) {
    let n = grid.node_count();
    let mut idx = vec![0i32; n * k];
    let mut mask = vec![0f32; n * k];
    for node in 0..n {
        for (t, nb) in grid.neighbors(node).into_iter().take(k).enumerate() {
            idx[node * k + t] = nb as i32;
            mask[node * k + t] = 1.0;
        }
    }
    (idx, mask)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::som::grid::{GridType, MapType};

    #[test]
    fn uniform_codebook_zero_umatrix() {
        let grid = Grid::new(4, 4, GridType::Square, MapType::Planar);
        let mut cb = Codebook::zeros(16, 3);
        for n in 0..16 {
            cb.row_mut(n).copy_from_slice(&[1.0, 2.0, 3.0]);
        }
        let u = umatrix(&grid, &cb, 2);
        assert!(u.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn boundary_node_height() {
        // 1x2 map: each node has exactly one neighbor; U = distance.
        let grid = Grid::new(1, 2, GridType::Square, MapType::Planar);
        let mut cb = Codebook::zeros(2, 2);
        cb.row_mut(0).copy_from_slice(&[0.0, 0.0]);
        cb.row_mut(1).copy_from_slice(&[3.0, 4.0]);
        let u = umatrix(&grid, &cb, 1);
        assert_eq!(u, vec![5.0, 5.0]);
    }

    #[test]
    fn cluster_boundary_is_ridge() {
        // Left half of the map at 0, right half at 10: the tallest
        // U-values must lie on the boundary columns.
        let grid = Grid::new(6, 8, GridType::Square, MapType::Planar);
        let mut cb = Codebook::zeros(48, 1);
        for node in 0..48 {
            let (_, c) = grid.position(node);
            cb.row_mut(node)[0] = if c < 4 { 0.0 } else { 10.0 };
        }
        let u = umatrix(&grid, &cb, 4);
        let max = u.iter().cloned().fold(0.0f32, f32::max);
        for node in 0..48 {
            let (_, c) = grid.position(node);
            if u[node] == max {
                assert!(c == 3 || c == 4, "ridge off boundary at col {c}");
            }
        }
        assert!(max > 0.0);
    }

    #[test]
    fn threads_do_not_change_result() {
        let grid = Grid::new(5, 5, GridType::Hexagonal, MapType::Toroid);
        let mut rng = crate::util::rng::Rng::new(9);
        let cb = Codebook::random_init(25, 7, &mut rng);
        let u1 = umatrix(&grid, &cb, 1);
        let u4 = umatrix(&grid, &cb, 4);
        assert_eq!(u1, u4);
    }

    #[test]
    fn neighbor_tables_shape_and_mask() {
        let grid = Grid::new(3, 3, GridType::Square, MapType::Planar);
        let (idx, mask) = neighbor_tables(&grid, 8);
        assert_eq!(idx.len(), 9 * 8);
        // Corner has 3 neighbors, center has 8.
        let corner_cnt: f32 = mask[0..8].iter().sum();
        let center = grid.index(1, 1);
        let center_cnt: f32 = mask[center * 8..center * 8 + 8].iter().sum();
        assert_eq!(corner_cnt, 3.0);
        assert_eq!(center_cnt, 8.0);
    }
}
