//! Training collectives on top of rank endpoints, mirroring the MPI
//! calls the paper replaced MapReduce with (§3):
//!
//! * `reduce_sum_to_root` — MPI_Reduce(+) of f32 buffers: slaves send
//!   local Eq. 6 accumulators, the master sums ("the accumulation of
//!   local weights into a new global code book by one single process on
//!   the master node").
//! * `broadcast_from_root` — MPI_Bcast: "the new code book is broadcast
//!   to all slave nodes".
//! * `gather_u32_to_root` — MPI_Gather: BMU collection for output.
//! * `reduce_f64_sum` — scalar reduction (QE sum).
//! * `barrier` — token ring, used by tests.

use crate::cluster::comm::{CollectiveMsg, Endpoint};

pub const ROOT: usize = 0;

/// Sum `buf` across ranks into the root's buffer. Non-root buffers are
/// left untouched; returns true on the root.
pub fn reduce_sum_to_root(ep: &mut Endpoint, buf: &mut [f32]) -> bool {
    if ep.rank == ROOT {
        for from in 1..ep.size {
            let part = ep.recv(from).into_f32();
            assert_eq!(part.len(), buf.len(), "reduce length mismatch");
            for (a, b) in buf.iter_mut().zip(part) {
                *a += b;
            }
        }
        true
    } else {
        ep.send(ROOT, CollectiveMsg::F32(buf.to_vec()));
        false
    }
}

/// Broadcast the root's buffer to every rank (in place).
pub fn broadcast_from_root(ep: &mut Endpoint, buf: &mut [f32]) {
    if ep.rank == ROOT {
        for to in 1..ep.size {
            ep.send(to, CollectiveMsg::F32(buf.to_vec()));
        }
    } else {
        let v = ep.recv(ROOT).into_f32();
        assert_eq!(v.len(), buf.len(), "broadcast length mismatch");
        buf.copy_from_slice(&v);
    }
}

/// Gather variable-length u32 buffers to the root in rank order.
pub fn gather_u32_to_root(ep: &mut Endpoint, local: Vec<u32>) -> Option<Vec<Vec<u32>>> {
    if ep.rank == ROOT {
        let mut all = Vec::with_capacity(ep.size);
        all.push(local);
        for from in 1..ep.size {
            all.push(ep.recv(from).into_u32());
        }
        Some(all)
    } else {
        ep.send(ROOT, CollectiveMsg::U32(local));
        None
    }
}

/// Sum an f64 scalar across ranks; every rank receives the total.
pub fn allreduce_f64_sum(ep: &mut Endpoint, value: f64) -> f64 {
    if ep.rank == ROOT {
        let mut total = value;
        for from in 1..ep.size {
            total += ep.recv(from).into_f64();
        }
        for to in 1..ep.size {
            ep.send(to, CollectiveMsg::F64(total));
        }
        total
    } else {
        ep.send(ROOT, CollectiveMsg::F64(value));
        ep.recv(ROOT).into_f64()
    }
}

/// Simple barrier: everyone checks in at the root, root releases.
pub fn barrier(ep: &mut Endpoint) {
    if ep.rank == ROOT {
        for from in 1..ep.size {
            let _ = ep.recv(from);
        }
        for to in 1..ep.size {
            ep.send(to, CollectiveMsg::Token);
        }
    } else {
        ep.send(ROOT, CollectiveMsg::Token);
        let _ = ep.recv(ROOT);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::comm::World;
    use crate::cluster::netmodel::NetModel;
    use crate::util::threadpool::run_concurrent;

    fn with_world<T: Send + 'static>(
        size: usize,
        f: impl Fn(Endpoint) -> T + Send + Sync + Clone + 'static,
    ) -> Vec<T> {
        let mut world = World::new(size, NetModel::ideal());
        let eps = world.take_endpoints();
        let tasks: Vec<_> = eps
            .into_iter()
            .map(|ep| {
                let f = f.clone();
                move || f(ep)
            })
            .collect();
        run_concurrent(tasks)
    }

    #[test]
    fn reduce_sums_on_root_only() {
        let out = with_world(4, |mut ep| {
            let mut buf = vec![ep.rank as f32, 1.0];
            let is_root = reduce_sum_to_root(&mut ep, &mut buf);
            (is_root, buf)
        });
        assert_eq!(out[0], (true, vec![0.0 + 1.0 + 2.0 + 3.0, 4.0]));
        for (r, (is_root, buf)) in out.iter().enumerate().skip(1) {
            assert!(!is_root);
            assert_eq!(buf, &vec![r as f32, 1.0]);
        }
    }

    #[test]
    fn broadcast_propagates() {
        let out = with_world(3, |mut ep| {
            let mut buf = if ep.rank == ROOT {
                vec![42.0, -1.0]
            } else {
                vec![0.0, 0.0]
            };
            broadcast_from_root(&mut ep, &mut buf);
            buf
        });
        for buf in out {
            assert_eq!(buf, vec![42.0, -1.0]);
        }
    }

    #[test]
    fn reduce_then_broadcast_equals_serial_sum() {
        // The full per-epoch pattern: every rank ends with the total.
        let out = with_world(5, |mut ep| {
            let mut buf = vec![(ep.rank + 1) as f32; 3];
            reduce_sum_to_root(&mut ep, &mut buf);
            broadcast_from_root(&mut ep, &mut buf);
            buf
        });
        let want = vec![15.0; 3];
        for buf in out {
            assert_eq!(buf, want);
        }
    }

    #[test]
    fn gather_preserves_rank_order_and_lengths() {
        let out = with_world(4, |mut ep| {
            let local: Vec<u32> = (0..=ep.rank as u32).collect();
            gather_u32_to_root(&mut ep, local)
        });
        let root = out[0].as_ref().unwrap();
        assert_eq!(root.len(), 4);
        for (r, v) in root.iter().enumerate() {
            assert_eq!(v, &(0..=r as u32).collect::<Vec<_>>());
        }
        assert!(out[1..].iter().all(|o| o.is_none()));
    }

    #[test]
    fn allreduce_scalar() {
        let out = with_world(4, |mut ep| {
            let r = ep.rank as f64;
            allreduce_f64_sum(&mut ep, r)
        });
        assert!(out.iter().all(|&v| v == 6.0));
    }

    #[test]
    fn barrier_completes() {
        let out = with_world(6, |mut ep| {
            barrier(&mut ep);
            ep.rank
        });
        assert_eq!(out.len(), 6);
    }
}
