//! Training collectives on top of rank endpoints, mirroring the MPI
//! calls the paper replaced MapReduce with (§3) — now in three
//! bandwidth classes selected by [`CollectiveAlgo`]:
//!
//! * **Star** — the paper's literal master/slave pattern: slaves funnel
//!   full buffers through rank 0, which sums serially in rank order
//!   ("the accumulation of local weights into a new global code book by
//!   one single process on the master node"). O(P·M) bytes through the
//!   root; kept bit-compatible with the historical path for regression.
//! * **Ring** — segmented reduce-scatter + allgather: every rank sends
//!   exactly 2·(P−1)/P·M bytes (when P divides the buffer; within one
//!   segment otherwise), independent of rank count. The bandwidth-
//!   optimal choice for the Eq. 6 accumulators, which dominate traffic.
//! * **Tree** — binomial reduce/broadcast: O(log P) latency steps for
//!   small payloads (the QE scalar, barriers) where latency dominates.
//!
//! `Auto` resolves per call from the payload size — a pure function of
//! values every rank agrees on (buffer length, rank count), so ranks
//! never pick different algorithms for the same collective. Summation
//! order is fixed per (rank count, algorithm): results are deterministic
//! for a fixed `--collective` choice, star and ring/tree differing only
//! by f32 reassociation (BMUs stay exact; codebooks within the 5e-4
//! tolerance established by the chunking-equivalence suite).
//!
//! All payloads are little-endian bytes over [`Endpoint::send`]/`recv`,
//! so the same collectives run unchanged over in-process channels and
//! the TCP/UDS transport. Every operation returns `Result`: a dead peer
//! is a [`CommError::PeerLost`], not a panic.

use std::ops::Range;
use std::sync::Arc;
use std::time::Instant;

use crate::cluster::comm::{Bytes, CollectiveAlgo, CollectiveOp, CommError, Endpoint, Rank};

pub const ROOT: usize = 0;

/// Payloads at or below this many bytes ride the binomial tree under
/// `Auto`; larger ones ride the ring. Latency×log P beats bandwidth×2
/// only while the buffer is small relative to the latency-bandwidth
/// product (alpha-beta model; `NetModel::ethernet_10g` puts the
/// crossover in the few-KiB range).
pub const TREE_THRESHOLD_BYTES: usize = 4096;

fn effective(algo: CollectiveAlgo, payload_bytes: usize) -> CollectiveAlgo {
    match algo {
        CollectiveAlgo::Auto => {
            if payload_bytes <= TREE_THRESHOLD_BYTES {
                CollectiveAlgo::Tree
            } else {
                CollectiveAlgo::Ring
            }
        }
        fixed => fixed,
    }
}

/// Split `0..total` into exactly `parts` contiguous ranges whose sizes
/// differ by at most one (earlier ranges get the remainder). Unlike
/// `threadpool::split_ranges`, ranges may be empty — the ring needs one
/// segment per rank even when `total < parts`, with empty segments
/// moving as zero-byte frames to keep the lockstep.
pub fn segment_ranges(total: usize, parts: usize) -> Vec<Range<usize>> {
    assert!(parts > 0);
    let base = total / parts;
    let rem = total % parts;
    let mut out = Vec::with_capacity(parts);
    let mut start = 0;
    for i in 0..parts {
        let len = base + usize::from(i < rem);
        out.push(start..start + len);
        start += len;
    }
    out
}

// ---------------------------------------------------------------------
// Little-endian codecs. f32/u32/f64 round-trip bit-exactly (including
// NaN payloads), so byte transport preserves the star path's bits.

pub(crate) fn f32_to_bytes(src: &[f32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(src.len() * 4);
    for v in src {
        out.extend_from_slice(&v.to_le_bytes());
    }
    out
}

pub(crate) fn u32_to_bytes(src: &[u32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(src.len() * 4);
    for v in src {
        out.extend_from_slice(&v.to_le_bytes());
    }
    out
}

fn payload_len_check(
    bytes: &[u8],
    want: usize,
    from: Rank,
    what: &str,
) -> Result<(), CommError> {
    if bytes.len() == want {
        Ok(())
    } else {
        Err(CommError::Protocol {
            peer: from,
            what: format!("{what}: got {} bytes, want {want}", bytes.len()),
        })
    }
}

fn add_f32_from_bytes(dst: &mut [f32], bytes: &[u8], from: Rank) -> Result<(), CommError> {
    payload_len_check(bytes, dst.len() * 4, from, "f32 sum payload")?;
    for (a, c) in dst.iter_mut().zip(bytes.chunks_exact(4)) {
        *a += f32::from_le_bytes([c[0], c[1], c[2], c[3]]);
    }
    Ok(())
}

fn copy_f32_from_bytes(dst: &mut [f32], bytes: &[u8], from: Rank) -> Result<(), CommError> {
    payload_len_check(bytes, dst.len() * 4, from, "f32 payload")?;
    for (a, c) in dst.iter_mut().zip(bytes.chunks_exact(4)) {
        *a = f32::from_le_bytes([c[0], c[1], c[2], c[3]]);
    }
    Ok(())
}

fn f64_from_bytes(bytes: &[u8], from: Rank) -> Result<f64, CommError> {
    payload_len_check(bytes, 8, from, "f64 payload")?;
    let mut b = [0u8; 8];
    b.copy_from_slice(bytes);
    Ok(f64::from_le_bytes(b))
}

fn u32s_from_bytes(bytes: &[u8], from: Rank) -> Result<Vec<u32>, CommError> {
    if bytes.len() % 4 != 0 {
        return Err(CommError::Protocol {
            peer: from,
            what: format!("u32 payload length {} not a multiple of 4", bytes.len()),
        });
    }
    Ok(bytes
        .chunks_exact(4)
        .map(|c| u32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

/// Record the elapsed time of collective `op` against the endpoint's
/// stats (per-rank call time; divide by rank count for wall estimates).
fn timed<T>(
    ep: &mut Endpoint,
    op: CollectiveOp,
    f: impl FnOnce(&mut Endpoint) -> Result<T, CommError>,
) -> Result<T, CommError> {
    let t = Instant::now();
    let out = f(ep);
    ep.stats().add_op_nanos(op, t.elapsed().as_nanos() as u64);
    out
}

// ---------------------------------------------------------------------
// Star primitives (the historical wire pattern, bit-compatible).

fn star_reduce_f32(ep: &mut Endpoint, buf: &mut [f32]) -> Result<bool, CommError> {
    if ep.rank == ROOT {
        for from in 1..ep.size {
            let part = ep.recv(from)?;
            add_f32_from_bytes(buf, &part, from)?;
        }
        Ok(true)
    } else {
        ep.send(ROOT, Arc::new(f32_to_bytes(buf)), CollectiveOp::Allreduce)?;
        Ok(false)
    }
}

fn star_broadcast_f32(ep: &mut Endpoint, buf: &mut [f32]) -> Result<(), CommError> {
    if ep.rank == ROOT {
        // Serialize once, share the Arc with every destination — the
        // in-process transport then moves P−1 pointers, not P−1 copies.
        let payload = Arc::new(f32_to_bytes(buf));
        for to in 1..ep.size {
            ep.send(to, payload.clone(), CollectiveOp::Allreduce)?;
        }
        Ok(())
    } else {
        let v = ep.recv(ROOT)?;
        copy_f32_from_bytes(buf, &v, ROOT)
    }
}

fn star_gather_u32(
    ep: &mut Endpoint,
    local: Vec<u32>,
) -> Result<Option<Vec<Vec<u32>>>, CommError> {
    if ep.rank == ROOT {
        let mut all = Vec::with_capacity(ep.size);
        all.push(local);
        for from in 1..ep.size {
            let bytes = ep.recv(from)?;
            all.push(u32s_from_bytes(&bytes, from)?);
        }
        Ok(Some(all))
    } else {
        ep.send(ROOT, Arc::new(u32_to_bytes(&local)), CollectiveOp::Gather)?;
        Ok(None)
    }
}

fn star_allreduce_f64(ep: &mut Endpoint, value: f64) -> Result<f64, CommError> {
    if ep.rank == ROOT {
        let mut total = value;
        for from in 1..ep.size {
            let bytes = ep.recv(from)?;
            total += f64_from_bytes(&bytes, from)?;
        }
        let payload = Arc::new(total.to_le_bytes().to_vec());
        for to in 1..ep.size {
            ep.send(to, payload.clone(), CollectiveOp::Scalar)?;
        }
        Ok(total)
    } else {
        ep.send(
            ROOT,
            Arc::new(value.to_le_bytes().to_vec()),
            CollectiveOp::Scalar,
        )?;
        let bytes = ep.recv(ROOT)?;
        f64_from_bytes(&bytes, ROOT)
    }
}

fn star_barrier(ep: &mut Endpoint) -> Result<(), CommError> {
    if ep.rank == ROOT {
        for from in 1..ep.size {
            let _ = ep.recv(from)?;
        }
        let token = Arc::new(Vec::new());
        for to in 1..ep.size {
            ep.send(to, token.clone(), CollectiveOp::Barrier)?;
        }
    } else {
        ep.send(ROOT, Arc::new(Vec::new()), CollectiveOp::Barrier)?;
        let _ = ep.recv(ROOT)?;
    }
    Ok(())
}

// ---------------------------------------------------------------------
// Binomial tree primitives (root = 0). Reduce walks masks upward —
// rank r receives from children r|mask (for masks below r's lowest set
// bit), then sends its partial to parent r−lowbit(r). Broadcast is the
// mirror image, high mask first. O(log P) rounds.

fn tree_reduce_f32(
    ep: &mut Endpoint,
    buf: &mut [f32],
    op: CollectiveOp,
) -> Result<bool, CommError> {
    let (r, size) = (ep.rank, ep.size);
    let mut mask = 1;
    while mask < size {
        if r & mask != 0 {
            ep.send(r & !mask, Arc::new(f32_to_bytes(buf)), op)?;
            return Ok(false);
        }
        let child = r | mask;
        if child < size {
            let bytes = ep.recv(child)?;
            add_f32_from_bytes(buf, &bytes, child)?;
        }
        mask <<= 1;
    }
    Ok(true) // only rank 0 has no set bit below `size`
}

fn tree_reduce_f64(ep: &mut Endpoint, value: f64, op: CollectiveOp) -> Result<Option<f64>, CommError> {
    let (r, size) = (ep.rank, ep.size);
    let mut total = value;
    let mut mask = 1;
    while mask < size {
        if r & mask != 0 {
            ep.send(r & !mask, Arc::new(total.to_le_bytes().to_vec()), op)?;
            return Ok(None);
        }
        let child = r | mask;
        if child < size {
            let bytes = ep.recv(child)?;
            total += f64_from_bytes(&bytes, child)?;
        }
        mask <<= 1;
    }
    Ok(Some(total))
}

/// Binomial broadcast of an opaque payload from rank 0; every rank gets
/// the root's exact bytes. Root must pass `Some(payload)`, others
/// `None`. Exposed for the multi-process bootstrap (initial codebook
/// sync) as well as the tree allreduce below.
pub fn broadcast_bytes_from_root(
    ep: &mut Endpoint,
    payload: Option<Arc<Vec<u8>>>,
    op: CollectiveOp,
) -> Result<Bytes, CommError> {
    timed(ep, op, |ep| tree_broadcast_payload(ep, payload, op))
}

fn tree_broadcast_payload(
    ep: &mut Endpoint,
    payload: Option<Arc<Vec<u8>>>,
    op: CollectiveOp,
) -> Result<Bytes, CommError> {
    let (r, size) = (ep.rank, ep.size);
    let mut have = if r == ROOT {
        Some(payload.expect("root provides the broadcast payload"))
    } else {
        None
    };
    let mut top = 1usize;
    while top < size {
        top <<= 1;
    }
    let mut mask = top >> 1;
    while mask > 0 {
        if r % (mask << 1) == 0 {
            let partner = r + mask;
            if partner < size {
                let p = have.clone().expect("broadcast sender holds the payload");
                ep.send(partner, p, op)?;
            }
        } else if r % (mask << 1) == mask {
            // Exactly once per rank: mask == lowest set bit of r.
            let got = ep.recv(r - mask)?;
            have = Some(match got {
                Bytes::Shared(a) => a,
                Bytes::Owned(v) => Arc::new(v),
            });
        }
        mask >>= 1;
    }
    Ok(Bytes::Shared(have.expect("broadcast reached every rank")))
}

fn tree_gather_u32(
    ep: &mut Endpoint,
    local: Vec<u32>,
) -> Result<Option<Vec<Vec<u32>>>, CommError> {
    let (r, size) = (ep.rank, ep.size);
    let mut entries: Vec<(u32, Vec<u32>)> = vec![(r as u32, local)];
    let mut mask = 1;
    while mask < size {
        if r & mask != 0 {
            // Frame each entry [rank u32][len u32][data…] and hand the
            // subtree to the parent.
            let mut out = Vec::new();
            for (rank, data) in &entries {
                out.extend_from_slice(&rank.to_le_bytes());
                out.extend_from_slice(&(data.len() as u32).to_le_bytes());
                out.extend_from_slice(&u32_to_bytes(data));
            }
            ep.send(r & !mask, Arc::new(out), CollectiveOp::Gather)?;
            return Ok(None);
        }
        let child = r | mask;
        if child < size {
            let bytes = ep.recv(child)?;
            entries.extend(parse_gather_frames(&bytes, child)?);
        }
        mask <<= 1;
    }
    entries.sort_by_key(|(rank, _)| *rank);
    let complete = entries.len() == size
        && entries.iter().enumerate().all(|(i, (rk, _))| *rk as usize == i);
    if !complete {
        return Err(CommError::Protocol {
            peer: ROOT,
            what: "gather: missing or duplicate rank frames".into(),
        });
    }
    Ok(Some(entries.into_iter().map(|(_, d)| d).collect()))
}

fn parse_gather_frames(bytes: &[u8], from: Rank) -> Result<Vec<(u32, Vec<u32>)>, CommError> {
    let truncated = |what: &str| CommError::Protocol {
        peer: from,
        what: what.to_string(),
    };
    let mut out = Vec::new();
    let mut off = 0;
    while off < bytes.len() {
        if bytes.len() - off < 8 {
            return Err(truncated("gather frame header truncated"));
        }
        let rank = u32::from_le_bytes([bytes[off], bytes[off + 1], bytes[off + 2], bytes[off + 3]]);
        let len = u32::from_le_bytes([
            bytes[off + 4],
            bytes[off + 5],
            bytes[off + 6],
            bytes[off + 7],
        ]) as usize;
        off += 8;
        if bytes.len() - off < len * 4 {
            return Err(truncated("gather frame payload truncated"));
        }
        out.push((rank, u32s_from_bytes(&bytes[off..off + len * 4], from)?));
        off += len * 4;
    }
    Ok(out)
}

fn tree_barrier(ep: &mut Endpoint) -> Result<(), CommError> {
    let (r, size) = (ep.rank, ep.size);
    let mut mask = 1;
    while mask < size {
        if r & mask != 0 {
            ep.send(r & !mask, Arc::new(Vec::new()), CollectiveOp::Barrier)?;
            break;
        }
        let child = r | mask;
        if child < size {
            let _ = ep.recv(child)?;
        }
        mask <<= 1;
    }
    tree_broadcast_payload(ep, (r == ROOT).then(|| Arc::new(Vec::new())), CollectiveOp::Barrier)?;
    Ok(())
}

// ---------------------------------------------------------------------
// Ring allreduce: reduce-scatter then allgather around the ring
// 0 → 1 → … → P−1 → 0. After reduce-scatter, rank r owns the fully
// reduced segment (r+1) mod P (summed in the fixed order
// c, c+1, …, c−1 for segment c — deterministic per rank count); the
// allgather then byte-copies each owner's segment around the ring, so
// every rank finishes with identical bits. Each rank sends
// 2·total − seg(r+1) − seg(r+2) bytes = 2·(P−1)/P·M when P | len.
// Sends never block (buffered transports), so the lockstep is safe.

fn ring_allreduce_f32(
    ep: &mut Endpoint,
    buf: &mut [f32],
    op: CollectiveOp,
) -> Result<(), CommError> {
    let (r, p) = (ep.rank, ep.size);
    if p == 1 {
        return Ok(());
    }
    let segs = segment_ranges(buf.len(), p);
    let next = (r + 1) % p;
    let prev = (r + p - 1) % p;
    for step in 0..p - 1 {
        let send_seg = (r + p - step) % p;
        let recv_seg = (r + p - step - 1) % p;
        let payload = f32_to_bytes(&buf[segs[send_seg].clone()]);
        ep.send(next, Arc::new(payload), op)?;
        let bytes = ep.recv(prev)?;
        add_f32_from_bytes(&mut buf[segs[recv_seg].clone()], &bytes, prev)?;
    }
    for step in 0..p - 1 {
        let send_seg = (r + 1 + p - step) % p;
        let recv_seg = (r + p - step) % p;
        let payload = f32_to_bytes(&buf[segs[send_seg].clone()]);
        ep.send(next, Arc::new(payload), op)?;
        let bytes = ep.recv(prev)?;
        copy_f32_from_bytes(&mut buf[segs[recv_seg].clone()], &bytes, prev)?;
    }
    Ok(())
}

// ---------------------------------------------------------------------
// Public collectives.

/// Sum `buf` across ranks into the root's buffer (star wire pattern —
/// the paper's MPI_Reduce). Non-root buffers are left untouched;
/// returns true on the root.
pub fn reduce_sum_to_root(ep: &mut Endpoint, buf: &mut [f32]) -> Result<bool, CommError> {
    timed(ep, CollectiveOp::Allreduce, |ep| star_reduce_f32(ep, buf))
}

/// Broadcast the root's buffer to every rank in place (star wire
/// pattern — the paper's MPI_Bcast). One serialization, shared per
/// destination.
pub fn broadcast_from_root(ep: &mut Endpoint, buf: &mut [f32]) -> Result<(), CommError> {
    timed(ep, CollectiveOp::Allreduce, |ep| star_broadcast_f32(ep, buf))
}

/// Allreduce-sum `buf` in place with the selected algorithm; every rank
/// finishes with identical bytes. `Auto` resolves from the buffer size
/// (same on all ranks, so the choice is globally consistent).
pub fn allreduce_f32_sum(
    ep: &mut Endpoint,
    buf: &mut [f32],
    algo: CollectiveAlgo,
) -> Result<(), CommError> {
    let op = CollectiveOp::Allreduce;
    timed(ep, op, |ep| {
        if ep.size == 1 {
            return Ok(());
        }
        match effective(algo, buf.len() * 4) {
            CollectiveAlgo::Star => {
                star_reduce_f32(ep, buf)?;
                star_broadcast_f32(ep, buf)
            }
            CollectiveAlgo::Ring => ring_allreduce_f32(ep, buf, op),
            _ => {
                let payload = if tree_reduce_f32(ep, buf, op)? {
                    Some(Arc::new(f32_to_bytes(buf)))
                } else {
                    None
                };
                let total = tree_broadcast_payload(ep, payload, op)?;
                if ep.rank != ROOT {
                    // Attribution: the bytes originate at the root even
                    // when relayed by an intermediate rank.
                    copy_f32_from_bytes(buf, &total, ROOT)?;
                }
                Ok(())
            }
        }
    })
}

/// Sum an f64 scalar across ranks; every rank receives the total
/// (star wire pattern, root's summation order).
pub fn allreduce_f64_sum(ep: &mut Endpoint, value: f64) -> Result<f64, CommError> {
    timed(ep, CollectiveOp::Scalar, |ep| star_allreduce_f64(ep, value))
}

/// f64 scalar allreduce with algorithm selection. Eight-byte payloads
/// are latency-bound, so every non-star choice rides the binomial tree
/// (a ring would take 2·(P−1) latency steps to move 8 bytes).
pub fn allreduce_f64_sum_with(
    ep: &mut Endpoint,
    value: f64,
    algo: CollectiveAlgo,
) -> Result<f64, CommError> {
    let op = CollectiveOp::Scalar;
    timed(ep, op, |ep| {
        if ep.size == 1 {
            return Ok(value);
        }
        match algo {
            CollectiveAlgo::Star => star_allreduce_f64(ep, value),
            _ => {
                let payload = tree_reduce_f64(ep, value, op)?
                    .map(|total| Arc::new(total.to_le_bytes().to_vec()));
                let total = tree_broadcast_payload(ep, payload, op)?;
                f64_from_bytes(&total, ROOT)
            }
        }
    })
}

/// Gather variable-length u32 buffers to the root in rank order (star
/// wire pattern — the paper's MPI_Gather).
pub fn gather_u32_to_root(
    ep: &mut Endpoint,
    local: Vec<u32>,
) -> Result<Option<Vec<Vec<u32>>>, CommError> {
    timed(ep, CollectiveOp::Gather, |ep| star_gather_u32(ep, local))
}

/// Gather with algorithm selection: the binomial tree bounds the
/// *rounds* at O(log P) (tree/auto); star and ring use the direct
/// linear gather — for gather the root must absorb every byte anyway,
/// so there is no ring form.
pub fn gather_u32_with(
    ep: &mut Endpoint,
    local: Vec<u32>,
    algo: CollectiveAlgo,
) -> Result<Option<Vec<Vec<u32>>>, CommError> {
    timed(ep, CollectiveOp::Gather, |ep| match algo {
        CollectiveAlgo::Star | CollectiveAlgo::Ring => star_gather_u32(ep, local),
        _ => tree_gather_u32(ep, local),
    })
}

/// Barrier, star wire pattern: everyone checks in at the root, root
/// releases.
pub fn barrier(ep: &mut Endpoint) -> Result<(), CommError> {
    timed(ep, CollectiveOp::Barrier, star_barrier)
}

/// Barrier with algorithm selection (zero-byte tokens; every non-star
/// choice rides the tree's O(log P) rounds).
pub fn barrier_with(ep: &mut Endpoint, algo: CollectiveAlgo) -> Result<(), CommError> {
    timed(ep, CollectiveOp::Barrier, |ep| match algo {
        CollectiveAlgo::Star => star_barrier(ep),
        _ => tree_barrier(ep),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::comm::World;
    use crate::cluster::netmodel::NetModel;
    use crate::util::threadpool::run_concurrent;

    fn with_world<T: Send + 'static>(
        size: usize,
        f: impl Fn(Endpoint) -> T + Send + Sync + Clone + 'static,
    ) -> Vec<T> {
        let mut world = World::new(size, NetModel::ideal());
        let eps = world.take_endpoints();
        let tasks: Vec<_> = eps
            .into_iter()
            .map(|ep| {
                let f = f.clone();
                move || f(ep)
            })
            .collect();
        run_concurrent(tasks)
    }

    #[test]
    fn segment_ranges_cover_exactly() {
        for (total, parts) in [(10, 4), (3, 5), (0, 3), (16, 4), (7, 1)] {
            let segs = segment_ranges(total, parts);
            assert_eq!(segs.len(), parts);
            let mut cursor = 0;
            for s in &segs {
                assert_eq!(s.start, cursor);
                cursor = s.end;
            }
            assert_eq!(cursor, total);
            let (min, max) = segs
                .iter()
                .fold((usize::MAX, 0), |(lo, hi), s| (lo.min(s.len()), hi.max(s.len())));
            assert!(max - min <= 1, "{total}/{parts}: uneven split {segs:?}");
        }
    }

    #[test]
    fn reduce_sums_on_root_only() {
        let out = with_world(4, |mut ep| {
            let mut buf = vec![ep.rank as f32, 1.0];
            let is_root = reduce_sum_to_root(&mut ep, &mut buf).unwrap();
            (is_root, buf)
        });
        assert_eq!(out[0], (true, vec![0.0 + 1.0 + 2.0 + 3.0, 4.0]));
        for (r, (is_root, buf)) in out.iter().enumerate().skip(1) {
            assert!(!is_root);
            assert_eq!(buf, &vec![r as f32, 1.0]);
        }
    }

    #[test]
    fn broadcast_propagates() {
        let out = with_world(3, |mut ep| {
            let mut buf = if ep.rank == ROOT {
                vec![42.0, -1.0]
            } else {
                vec![0.0, 0.0]
            };
            broadcast_from_root(&mut ep, &mut buf).unwrap();
            buf
        });
        for buf in out {
            assert_eq!(buf, vec![42.0, -1.0]);
        }
    }

    #[test]
    fn reduce_then_broadcast_equals_serial_sum() {
        // The full per-epoch star pattern: every rank ends with the total.
        let out = with_world(5, |mut ep| {
            let mut buf = vec![(ep.rank + 1) as f32; 3];
            reduce_sum_to_root(&mut ep, &mut buf).unwrap();
            broadcast_from_root(&mut ep, &mut buf).unwrap();
            buf
        });
        let want = vec![15.0; 3];
        for buf in out {
            assert_eq!(buf, want);
        }
    }

    #[test]
    fn ring_and_tree_allreduce_match_serial_sum() {
        // Integer-valued f32s sum exactly in any association order, so
        // equality is exact across algorithms — including segment tails
        // (len % P ≠ 0) and starved ranks (len < P).
        for algo in [CollectiveAlgo::Ring, CollectiveAlgo::Tree, CollectiveAlgo::Auto] {
            for size in [1, 2, 3, 5, 8] {
                for len in [1, 3, size.saturating_sub(1).max(1), 4 * size + 3] {
                    let out = with_world(size, move |mut ep| {
                        let mut buf: Vec<f32> =
                            (0..len).map(|i| (ep.rank * len + i) as f32).collect();
                        allreduce_f32_sum(&mut ep, &mut buf, algo).unwrap();
                        buf
                    });
                    let want: Vec<f32> = (0..len)
                        .map(|i| (0..size).map(|r| (r * len + i) as f32).sum())
                        .collect();
                    for (r, buf) in out.iter().enumerate() {
                        assert_eq!(
                            buf, &want,
                            "algo {algo:?} size {size} len {len} rank {r}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn all_ranks_finish_bit_identical() {
        // Non-integer values reassociate differently per rank *order*,
        // but the design guarantees all ranks hold the root/owner bytes:
        // buffers must be bit-identical across ranks.
        for algo in [CollectiveAlgo::Ring, CollectiveAlgo::Tree] {
            let out = with_world(5, move |mut ep| {
                let mut buf: Vec<f32> =
                    (0..13).map(|i| 0.1 + ep.rank as f32 * 0.3 + i as f32 * 0.7).collect();
                allreduce_f32_sum(&mut ep, &mut buf, algo).unwrap();
                buf.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
            });
            for bits in &out[1..] {
                assert_eq!(bits, &out[0], "algo {algo:?}");
            }
        }
    }

    #[test]
    fn gather_preserves_rank_order_and_lengths() {
        for algo in [CollectiveAlgo::Star, CollectiveAlgo::Tree, CollectiveAlgo::Auto] {
            let out = with_world(4, move |mut ep| {
                let local: Vec<u32> = (0..=ep.rank as u32).collect();
                gather_u32_with(&mut ep, local, algo).unwrap()
            });
            let root = out[0].as_ref().unwrap();
            assert_eq!(root.len(), 4, "algo {algo:?}");
            for (r, v) in root.iter().enumerate() {
                assert_eq!(v, &(0..=r as u32).collect::<Vec<_>>(), "algo {algo:?}");
            }
            assert!(out[1..].iter().all(|o| o.is_none()));
        }
    }

    #[test]
    fn allreduce_scalar_all_algos() {
        for algo in [CollectiveAlgo::Star, CollectiveAlgo::Tree, CollectiveAlgo::Auto] {
            let out = with_world(4, move |mut ep| {
                let r = ep.rank as f64;
                allreduce_f64_sum_with(&mut ep, r, algo).unwrap()
            });
            assert!(out.iter().all(|&v| v == 6.0), "algo {algo:?}");
        }
        let legacy = with_world(4, |mut ep| {
            allreduce_f64_sum(&mut ep, ep.rank as f64).unwrap()
        });
        assert!(legacy.iter().all(|&v| v == 6.0));
    }

    #[test]
    fn barrier_completes_all_algos() {
        for algo in [CollectiveAlgo::Star, CollectiveAlgo::Ring, CollectiveAlgo::Tree] {
            let out = with_world(6, move |mut ep| {
                barrier_with(&mut ep, algo).unwrap();
                ep.rank
            });
            assert_eq!(out.len(), 6, "algo {algo:?}");
        }
        let out = with_world(3, |mut ep| {
            barrier(&mut ep).unwrap();
            ep.rank
        });
        assert_eq!(out.len(), 3);
    }

    #[test]
    fn ring_per_rank_bytes_match_closed_form() {
        // Each rank sends 2·total − seg(r+1) − seg(r+2) bytes; with
        // P | len that is exactly 2·(P−1)/P·M — the bandwidth-optimality
        // claim, asserted from the actual CommStats counters.
        for (p, len) in [(2usize, 64usize), (4, 64), (8, 64), (4, 7), (3, 2)] {
            let mut world = World::new(p, NetModel::ideal());
            let eps = world.take_endpoints();
            let tasks: Vec<_> = eps
                .into_iter()
                .map(|mut ep| {
                    move || {
                        let mut buf = vec![1.0f32; len];
                        allreduce_f32_sum(&mut ep, &mut buf, CollectiveAlgo::Ring).unwrap();
                    }
                })
                .collect();
            run_concurrent(tasks);
            let segs = segment_ranges(len, p);
            let total_bytes = 4 * len as u64;
            for r in 0..p {
                let skip_a = 4 * segs[(r + 1) % p].len() as u64;
                let skip_b = 4 * segs[(r + 2) % p].len() as u64;
                let want = 2 * total_bytes - skip_a - skip_b;
                assert_eq!(
                    world.stats.rank_bytes(r),
                    want,
                    "P={p} len={len} rank {r}"
                );
                if len % p == 0 {
                    assert_eq!(want, 2 * (p as u64 - 1) * total_bytes / p as u64);
                }
            }
        }
    }

    #[test]
    fn dead_peer_surfaces_as_peer_lost() {
        // Rank 1 exits before the collective: the survivors get a clean
        // CommError instead of a panic.
        let mut world = World::new(3, NetModel::ideal());
        let mut eps = world.take_endpoints();
        let e2 = eps.pop().unwrap();
        let e1 = eps.pop().unwrap();
        let e0 = eps.pop().unwrap();
        drop(e1);
        let out = run_concurrent(vec![
            Box::new(move || {
                let mut ep = e0;
                let mut buf = vec![1.0f32; 8];
                reduce_sum_to_root(&mut ep, &mut buf).map(|_| ())
            }) as Box<dyn FnOnce() -> Result<(), CommError> + Send>,
            Box::new(move || {
                let mut ep = e2;
                let mut buf = vec![1.0f32; 8];
                reduce_sum_to_root(&mut ep, &mut buf).map(|_| ())
            }),
        ]);
        let err = out[0].as_ref().unwrap_err();
        assert!(matches!(err, CommError::PeerLost { peer: 1 }));
    }
}
