//! Real multi-process distributed training: one OS process per rank.
//!
//! The in-process cluster paths simulate ranks on threads; this driver
//! runs the **same** `rank_train_loop` with a [`NetTransport`] instead
//! of a channel mesh, so `somoclu train --ranks N --rank k --peers …`
//! launched N times trains one map over per-rank shards of one input
//! file (each process opens only its own row window via `open_shard` /
//! `SharedFd` — the file must be readable at the same path on every
//! machine).
//!
//! Rank 0 is the coordinator-flavored rank: it owns the initial
//! codebook (fresh init, `-c FILE`, or `--resume` state), fires the
//! checkpoint policy per epoch, and writes the outputs. Training runs
//! in **checkpoint-aligned windows**: each window opens with rank 0
//! broadcasting a header — the window's end epoch plus
//! `[epoch u64][nodes u32][dim u32][weights…]` state — which every
//! other rank adopts before training to the fence. The hello
//! handshake's config fingerprint refuses mismatched launches before
//! any training happens.
//!
//! **Recovery (ISSUE 10).** Because every window begins with that
//! state broadcast, a lost rank is survivable under `--recover`: each
//! surviving process drops its endpoints, sleeps the policy backoff,
//! and re-enters the rendezvous (binding retries through `TIME_WAIT`);
//! rank 0 rewinds its session to the window start. When the operator
//! relaunches the dead rank — same CLI, fresh process — it joins the
//! re-formed world as a blank slate and the next window header hands it
//! the exact state every survivor rewound to. Retries are bounded by
//! the run-wide [`RecoveryPolicy`](crate::cluster::fault::RecoveryPolicy)
//! budget; exhausting it (or failing to re-form the world) surfaces the
//! typed `recovery` error naming the root-cause rank.
//!
//! Determinism: the collectives are the same algorithms as the
//! simulated path with the same fixed summation orders, so a real
//! 2-process TCP run produces BMUs identical to (and codebook bits
//! matching) the simulated `--ranks 2` run — and a recovered run is
//! byte-identical to an uninterrupted one.

use std::sync::Arc;
use std::time::Instant;

use crate::cluster::allreduce::{barrier_with, broadcast_bytes_from_root, ROOT};
use crate::cluster::comm::{CollectiveOp, CommError, CommStats, Endpoint};
use crate::cluster::fault::{FaultPlan, FaultyTransport};
use crate::cluster::runner::{
    abort_error, check_stream_kind, comm_failed, open_rank_source, rank_train_loop,
    window_end, ClusterReport, CommFailure, EpochAborted, StreamInput,
};
use crate::cluster::transport_net::NetTransport;
use crate::coordinator::config::{Initialization, TrainConfig};
use crate::coordinator::train::{init_codebook, TrainResult};
use crate::error::SomError;
use crate::io::stream::DataSource;
use crate::kernels::KernelType;
use crate::session::SomSession;
use crate::som::Codebook;

/// Where this process sits in a real multi-process run (`--rank` /
/// `--peers`, or the `--listen`/`--connect` two-process sugar).
#[derive(Clone, Debug)]
pub struct NetOptions {
    /// This process's rank; rank 0 coordinates and writes outputs.
    pub rank: usize,
    /// Rendezvous addresses, one per rank in rank order (`host:port` or
    /// `unix:PATH`); the last rank's may be omitted.
    pub peers: Vec<String>,
}

/// FNV-1a over a canonical rendering of every config field that shapes
/// the training math. Ranks exchange it in the hello handshake: two
/// processes launched with different maps, schedules, seeds, kernels,
/// rank counts, or collectives must refuse to train one map together.
/// Float endpoints hash by bit pattern, not display rounding.
pub(crate) fn config_fingerprint(cfg: &TrainConfig) -> u64 {
    let canon = format!(
        "somoclu-fp-v1|{}x{}|e{}|g{:?}|m{:?}|n{:?}|r0:{:?}|rn:{}|rc:{:?}|s0:{}|sn:{}|sc:{:?}|k{:?}|P{}|i{:?}|seed{}|coll:{}",
        cfg.rows,
        cfg.cols,
        cfg.epochs,
        cfg.grid_type,
        cfg.map_type,
        cfg.neighborhood,
        cfg.radius0.map(f32::to_bits),
        cfg.radius_n.to_bits(),
        cfg.radius_cooling,
        cfg.scale0.to_bits(),
        cfg.scale_n.to_bits(),
        cfg.scale_cooling,
        cfg.kernel,
        cfg.ranks,
        cfg.initialization,
        cfg.seed,
        cfg.collective.as_str(),
    );
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in canon.as_bytes() {
        h ^= u64::from(b);
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

fn encode_state(epoch: u64, cb: &Codebook) -> Vec<u8> {
    let mut out = Vec::with_capacity(16 + cb.weights.len() * 4);
    out.extend_from_slice(&epoch.to_le_bytes());
    out.extend_from_slice(&(cb.nodes as u32).to_le_bytes());
    out.extend_from_slice(&(cb.dim as u32).to_le_bytes());
    for w in &cb.weights {
        out.extend_from_slice(&w.to_le_bytes());
    }
    out
}

fn decode_state(bytes: &[u8]) -> anyhow::Result<(u64, Codebook)> {
    anyhow::ensure!(bytes.len() >= 16, "bootstrap state truncated");
    let epoch = u64::from_le_bytes(bytes[..8].try_into().unwrap());
    let nodes = u32::from_le_bytes(bytes[8..12].try_into().unwrap()) as usize;
    let dim = u32::from_le_bytes(bytes[12..16].try_into().unwrap()) as usize;
    let body = &bytes[16..];
    anyhow::ensure!(
        body.len() == nodes * dim * 4,
        "bootstrap state carries {} weight bytes, expected {}",
        body.len(),
        nodes * dim * 4
    );
    let weights = body
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect();
    Ok((epoch, Codebook { nodes, dim, weights }))
}

/// Per-window header: `[end u64]` then the state-sync payload — the
/// fence every rank (including a freshly relaunched replacement, which
/// has no checkpoint policy to derive it from) trains to.
fn encode_window(end: u64, epoch: u64, cb: &Codebook) -> Vec<u8> {
    let mut out = Vec::with_capacity(24 + cb.weights.len() * 4);
    out.extend_from_slice(&end.to_le_bytes());
    out.extend_from_slice(&encode_state(epoch, cb));
    out
}

fn decode_window(bytes: &[u8]) -> anyhow::Result<(u64, u64, Codebook)> {
    anyhow::ensure!(bytes.len() >= 8, "window header truncated");
    let end = u64::from_le_bytes(bytes[..8].try_into().unwrap());
    let (epoch, cb) = decode_state(&bytes[8..])?;
    Ok((end, epoch, cb))
}

/// Rendezvous and wrap this rank's endpoint. A session-installed
/// [`FaultPlan`] wraps the socket transport exactly as the in-process
/// runner wraps its channel mesh — deterministic chaos over real
/// sockets.
fn form_world(
    rank: usize,
    ranks: usize,
    peers: &[String],
    fingerprint: u64,
    stats: &Arc<CommStats>,
    fault_plan: &Option<Arc<FaultPlan>>,
) -> anyhow::Result<Endpoint> {
    let net = NetTransport::bootstrap(rank, ranks, peers, fingerprint)?;
    let transport: Box<dyn crate::cluster::comm::Transport> = match fault_plan {
        Some(plan) => Box::new(FaultyTransport::new(rank, Box::new(net), plan.clone())),
        None => Box::new(net),
    };
    Ok(Endpoint::new(rank, ranks, transport, stats.clone()))
}

/// When `e` is communication-typed, the `(failed rank, epoch, cause)`
/// the recovery driver needs; `None` marks it non-retryable.
fn comm_cause(e: &anyhow::Error, fallback_epoch: usize) -> Option<(usize, usize, String)> {
    if let Some(f) = e.downcast_ref::<CommFailure>() {
        return Some((f.source.peer(), f.epoch, f.source.to_string()));
    }
    if let Some(c) = e.downcast_ref::<CommError>() {
        return Some((c.peer(), fallback_epoch, c.to_string()));
    }
    None
}

/// One checkpoint window: adopt the root's header (fence + state), then
/// train to the fence. Returns the fence and, on rank 0, the window's
/// result.
fn run_window(
    session: &mut SomSession,
    ep: &mut Endpoint,
    source: &mut dyn DataSource,
    cfg: &TrainConfig,
    rank: usize,
    total_rows: usize,
    dim: usize,
) -> anyhow::Result<(usize, Option<TrainResult>)> {
    let payload = (rank == ROOT).then(|| {
        let end = window_end(session, cfg.epochs);
        let cb = session.codebook().expect("root codebook installed");
        Arc::new(encode_window(end as u64, session.epoch() as u64, cb))
    });
    let header = broadcast_bytes_from_root(ep, payload, CollectiveOp::Bootstrap)
        .map_err(|e| comm_failed(rank, session.epoch(), e))?;
    let (end, epoch, cb) = decode_window(&header)?;
    if rank != ROOT {
        anyhow::ensure!(
            cb.dim == dim,
            "rank 0's codebook dim {} does not match this shard's dim {dim} \
             (are all ranks reading the same file?)",
            cb.dim
        );
        session.install_codebook(cb)?;
        session.set_epoch_cursor(epoch as usize);
    }
    let result = rank_train_loop(session, ep, source, total_rows, end as usize)?;
    Ok((end as usize, result))
}

/// Train this process's rank of a real multi-process cluster (the
/// engine behind [`SomSession::fit_cluster_net`]). Returns the final
/// result on rank 0 (`None` elsewhere) plus this process's
/// communication report.
pub(crate) fn run_cluster_net(
    session: &mut SomSession,
    input: StreamInput,
    opts: &NetOptions,
) -> anyhow::Result<(Option<TrainResult>, ClusterReport)> {
    let t0 = Instant::now();
    let cfg = session.config().clone();
    cfg.validate()?;
    let ranks = cfg.ranks;
    anyhow::ensure!(
        ranks >= 2,
        "a multi-process run needs --ranks >= 2 (got {ranks})"
    );
    anyhow::ensure!(
        opts.rank < ranks,
        "--rank {} out of range for --ranks {ranks}",
        opts.rank
    );
    anyhow::ensure!(
        !matches!(cfg.kernel, KernelType::Accel | KernelType::Hybrid),
        "accel/hybrid kernels are single-node only (the paper benchmarks \
         multi-node scaling with the CPU kernel; Fig. 8)"
    );
    check_stream_kind(&cfg, &input)?;
    let (total_rows, dim) = input.probe(cfg.chunk_rows)?;
    anyhow::ensure!(total_rows >= ranks, "fewer rows than ranks");
    anyhow::ensure!(
        session.epoch() <= cfg.epochs,
        "session cursor {} beyond the {}-epoch schedule",
        session.epoch(),
        cfg.epochs
    );

    // Only rank 0 owns initial state; peers adopt it at bootstrap, so
    // `-c`/`--resume` need to be passed to rank 0 alone.
    if opts.rank == ROOT {
        match session.codebook() {
            Some(cb) => anyhow::ensure!(
                cb.dim == dim,
                "data dim {dim} does not match the session codebook dim {}",
                cb.dim
            ),
            None => {
                anyhow::ensure!(
                    cfg.initialization == Initialization::Random,
                    "PCA initialization needs the data resident in memory; \
                     multi-process runs support only --initialization random"
                );
                session.install_codebook(init_codebook(&cfg, session.grid(), dim))?;
            }
        }
    }

    let fingerprint = config_fingerprint(&cfg);
    let policy = session.recovery().clone();
    let fault_plan = session.fault_plan();
    let stats = Arc::new(CommStats::new(ranks));

    // The initial rendezvous is fatal on failure — recovery only covers
    // worlds that formed once (a typo'd --peers list should not retry).
    let mut ep = form_world(opts.rank, ranks, &opts.peers, fingerprint, &stats, &fault_plan)?;

    let mut source = open_rank_source(&input, &cfg, opts.rank, ranks)?;
    let total_epochs = cfg.epochs;
    let mut restarts_left = policy.max_restarts;
    let mut consecutive_aborts = 0usize;
    let mut final_result: Option<TrainResult> = None;
    loop {
        let window_start = session.epoch();
        let history_mark = session.history().len();
        let rewind_codebook = (opts.rank == ROOT)
            .then(|| session.codebook().expect("root codebook installed").clone());

        match run_window(session, &mut ep, &mut *source, &cfg, opts.rank, total_rows, dim) {
            Ok((end, result)) => {
                consecutive_aborts = 0;
                if end >= total_epochs {
                    final_result = result;
                    break;
                }
            }
            Err(e) => {
                let (failed_rank, epoch, cause) = match comm_cause(&e, window_start) {
                    Some(c) => c,
                    None => return Err(e), // not retryable: surface as-is
                };
                let abort = EpochAborted {
                    failed_rank,
                    epoch,
                    rewind_to: window_start,
                    cause,
                };
                if restarts_left == 0 {
                    return Err(abort_error(abort, &policy));
                }
                restarts_left -= 1;
                // Rank 0 rewinds to the window start; the other ranks
                // re-adopt that exact state from the next window header.
                if let Some(cb) = rewind_codebook {
                    session.install_codebook(cb)?;
                    session.set_epoch_cursor(window_start);
                    session.truncate_history(history_mark);
                }
                // Tear the old endpoints down first so peers unblock,
                // then wait out the backoff and re-rendezvous — the
                // window in which the operator (or a supervisor) must
                // relaunch the dead rank.
                drop(ep);
                std::thread::sleep(policy.backoff_for(consecutive_aborts));
                consecutive_aborts += 1;
                ep = form_world(opts.rank, ranks, &opts.peers, fingerprint, &stats, &fault_plan)
                    .map_err(|e| {
                        anyhow::Error::new(SomError::recovery(format!(
                            "rank {}: could not re-form the world after rank {} \
                             failed: {e:#}",
                            opts.rank, abort.failed_rank
                        )))
                    })?;
            }
        }
    }

    // Final barrier: no process tears its sockets down while a peer is
    // still inside the BMU gather.
    barrier_with(&mut ep, cfg.collective)
        .map_err(|e| comm_failed(opts.rank, session.epoch(), e))?;

    let mut report = ClusterReport::new(ranks);
    report.absorb(&stats);
    let result = final_result.map(|mut r| {
        r.total = t0.elapsed();
        r
    });
    Ok((result, report))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::comm::CollectiveAlgo;
    use crate::data;
    use crate::session::Som;
    use crate::util::rng::Rng;
    use crate::util::threadpool::run_concurrent;

    #[test]
    fn fingerprint_tracks_training_config_only() {
        let a = TrainConfig::default();
        assert_eq!(config_fingerprint(&a), config_fingerprint(&a.clone()));
        let mut b = a.clone();
        b.seed += 1;
        assert_ne!(config_fingerprint(&a), config_fingerprint(&b));
        let mut c = a.clone();
        c.collective = CollectiveAlgo::Star;
        assert_ne!(config_fingerprint(&a), config_fingerprint(&c));
        // Per-process runtime knobs must NOT change the fingerprint:
        // ranks may legitimately differ in threads or I/O strategy.
        let mut d = a.clone();
        d.threads = a.threads + 3;
        d.chunk_rows = 17;
        d.prefetch = true;
        assert_eq!(config_fingerprint(&a), config_fingerprint(&d));
    }

    #[test]
    fn state_roundtrip_is_bit_exact() {
        let cb = Codebook {
            nodes: 3,
            dim: 2,
            weights: vec![1.5, -0.25, f32::MIN_POSITIVE, 3e7, -0.0, 42.0],
        };
        let (epoch, back) = decode_state(&encode_state(9, &cb)).unwrap();
        assert_eq!(epoch, 9);
        assert_eq!(back.nodes, 3);
        assert_eq!(back.dim, 2);
        let bits: Vec<u32> = back.weights.iter().map(|w| w.to_bits()).collect();
        let want: Vec<u32> = cb.weights.iter().map(|w| w.to_bits()).collect();
        assert_eq!(bits, want);
        assert!(decode_state(&[0u8; 15]).is_err());
    }

    /// The acceptance bar: ranks as real socket peers (here: threads
    /// with their own sessions over loopback TCP, exactly what two
    /// processes run) produce BMUs identical to — and codebook bits
    /// matching — the simulated in-process 2-rank run.
    #[test]
    fn net_cluster_matches_simulated_cluster() {
        let dir = std::env::temp_dir()
            .join(format!("somoclu_multiproc_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let mut rng = Rng::new(21);
        let (dat, _) = data::gaussian_blobs(60, 4, 3, 0.2, &mut rng);
        let bin = dir.join("net.somb");
        crate::io::binary::write_binary_dense(&bin, 60, 4, &dat).unwrap();

        let cfg = TrainConfig {
            rows: 6,
            cols: 6,
            epochs: 4,
            threads: 1,
            ranks: 2,
            radius0: Some(3.0),
            chunk_rows: 16,
            ..Default::default()
        };

        let (simulated, _) = Som::builder()
            .config(cfg.clone())
            .build()
            .unwrap()
            .fit_cluster_stream(StreamInput::Binary { path: bin.clone() })
            .unwrap();

        let port = {
            let l = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap().port()
        };
        let peers = vec![format!("127.0.0.1:{port}")];
        let outcomes = run_concurrent(
            (0..2usize)
                .map(|rank| {
                    let cfg = cfg.clone();
                    let peers = peers.clone();
                    let bin = bin.clone();
                    move || -> anyhow::Result<Option<TrainResult>> {
                        let mut session = Som::builder().config(cfg).build()?;
                        let (res, report) = run_cluster_net(
                            &mut session,
                            StreamInput::Binary { path: bin },
                            &NetOptions { rank, peers },
                        )?;
                        assert!(report.bytes_sent > 0);
                        Ok(res)
                    }
                })
                .collect(),
        );
        let mut root_result = None;
        for o in outcomes {
            if let Some(r) = o.unwrap() {
                root_result = Some(r);
            }
        }
        let net = root_result.expect("rank 0 returns the result");
        assert_eq!(net.bmus, simulated.bmus);
        assert_eq!(
            net.codebook
                .weights
                .iter()
                .map(|w| w.to_bits())
                .collect::<Vec<_>>(),
            simulated
                .codebook
                .weights
                .iter()
                .map(|w| w.to_bits())
                .collect::<Vec<_>>()
        );
    }

    fn write_blob(dir: &std::path::Path, seed: u64) -> (std::path::PathBuf, Vec<f32>) {
        std::fs::create_dir_all(dir).unwrap();
        let mut rng = Rng::new(seed);
        let (dat, _) = data::gaussian_blobs(60, 4, 3, 0.2, &mut rng);
        let bin = dir.join("net.somb");
        crate::io::binary::write_binary_dense(&bin, 60, 4, &dat).unwrap();
        (bin, dat)
    }

    fn free_port() -> u16 {
        let l = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        l.local_addr().unwrap().port()
    }

    fn net_cfg() -> TrainConfig {
        TrainConfig {
            rows: 6,
            cols: 6,
            epochs: 4,
            threads: 1,
            ranks: 2,
            radius0: Some(3.0),
            chunk_rows: 16,
            ..Default::default()
        }
    }

    /// Run a 2-rank loopback-TCP cluster in threads; `tune` customizes
    /// each rank's session (fault plan, recovery, checkpoints) before
    /// training. Returns rank 0's result.
    fn run_net_pair(
        bin: &std::path::Path,
        cfg: &TrainConfig,
        tune: impl Fn(usize, &mut crate::session::SomSession) + Clone + Send + 'static,
    ) -> TrainResult {
        let peers = vec![format!("127.0.0.1:{}", free_port())];
        let outcomes = run_concurrent(
            (0..2usize)
                .map(|rank| {
                    let cfg = cfg.clone();
                    let peers = peers.clone();
                    let bin = bin.to_path_buf();
                    let tune = tune.clone();
                    move || -> anyhow::Result<Option<TrainResult>> {
                        let mut session = Som::builder().config(cfg).build()?;
                        tune(rank, &mut session);
                        let (res, _) = run_cluster_net(
                            &mut session,
                            StreamInput::Binary { path: bin },
                            &NetOptions { rank, peers },
                        )?;
                        Ok(res)
                    }
                })
                .collect(),
        );
        let mut root_result = None;
        for o in outcomes {
            if let Some(r) = o.unwrap() {
                root_result = Some(r);
            }
        }
        root_result.expect("rank 0 returns the result")
    }

    /// The windowed header protocol must not change results: a net run
    /// whose root checkpoints every 2 epochs (two windows, two header
    /// broadcasts) matches the unwindowed net run bit-for-bit.
    #[test]
    fn net_cluster_windows_are_bit_identical() {
        let dir = std::env::temp_dir()
            .join(format!("somoclu_multiproc_win_{}", std::process::id()));
        let (bin, _) = write_blob(&dir, 23);
        let cfg = net_cfg();
        let plain = run_net_pair(&bin, &cfg, |_, _| {});
        let prefix = dir.join("ck");
        let windowed = run_net_pair(&bin, &cfg, move |rank, session| {
            if rank == 0 {
                session.set_checkpoint_every(2, &prefix);
            }
        });
        assert_eq!(windowed.bmus, plain.bmus);
        assert_eq!(windowed.codebook.weights, plain.codebook.weights);
        assert!(
            crate::session::checkpoint_path(dir.join("ck"), 2).exists(),
            "window fence checkpoint missing"
        );
    }

    /// Deterministic chaos over real sockets: a rank killed mid-run by
    /// an injected fault recovers through the re-rendezvous path to a
    /// byte-identical result. (Real-process SIGKILL recovery is covered
    /// in tests/fault_recovery.rs; this exercises the same protocol
    /// in-thread.)
    #[test]
    fn net_cluster_recovers_from_injected_kill() {
        use crate::cluster::fault::{FaultPlan, RecoveryPolicy};
        use std::time::Duration;
        let dir = std::env::temp_dir()
            .join(format!("somoclu_multiproc_chaos_{}", std::process::id()));
        let (bin, _) = write_blob(&dir, 24);
        let cfg = net_cfg();
        let clean = run_net_pair(&bin, &cfg, |_, _| {});

        let plan = Arc::new(FaultPlan::observe(2).kill(1, 10));
        let check = plan.clone();
        let recovered = run_net_pair(&bin, &cfg, move |rank, session| {
            if rank == 1 {
                session.set_fault_plan(Some(plan.clone()));
            }
            session.set_recovery(
                RecoveryPolicy::restarts(2).with_backoff(Duration::from_millis(1)),
            );
        });
        assert!(check.all_fired(), "the kill never triggered");
        assert_eq!(recovered.bmus, clean.bmus);
        assert_eq!(recovered.codebook.weights, clean.codebook.weights);
    }
}
