//! Real multi-process distributed training: one OS process per rank.
//!
//! The in-process cluster paths simulate ranks on threads; this driver
//! runs the **same** `rank_train_loop` with a [`NetTransport`] instead
//! of a channel mesh, so `somoclu train --ranks N --rank k --peers …`
//! launched N times trains one map over per-rank shards of one input
//! file (each process opens only its own row window via `open_shard` /
//! `SharedFd` — the file must be readable at the same path on every
//! machine).
//!
//! Rank 0 is the coordinator-flavored rank: it owns the initial
//! codebook (fresh init, `-c FILE`, or `--resume` state), broadcasts
//! `[epoch u64][nodes u32][dim u32][weights…]` to the others at
//! bootstrap, fires the checkpoint policy per epoch, and writes the
//! outputs. Non-root ranks adopt that state and return nothing. The
//! hello handshake's config fingerprint refuses mismatched launches
//! before any training happens.
//!
//! Determinism: the collectives are the same algorithms as the
//! simulated path with the same fixed summation orders, so a real
//! 2-process TCP run produces BMUs identical to (and codebook bits
//! matching) the simulated `--ranks 2` run.

use std::sync::Arc;
use std::time::Instant;

use crate::cluster::allreduce::{barrier_with, broadcast_bytes_from_root, ROOT};
use crate::cluster::comm::{CollectiveOp, CommStats, Endpoint};
use crate::cluster::runner::{
    check_stream_kind, comm_failed, open_rank_source, rank_train_loop, ClusterReport,
    StreamInput,
};
use crate::cluster::transport_net::NetTransport;
use crate::coordinator::config::{Initialization, TrainConfig};
use crate::coordinator::train::{init_codebook, TrainResult};
use crate::kernels::KernelType;
use crate::session::SomSession;
use crate::som::Codebook;

/// Where this process sits in a real multi-process run (`--rank` /
/// `--peers`, or the `--listen`/`--connect` two-process sugar).
#[derive(Clone, Debug)]
pub struct NetOptions {
    /// This process's rank; rank 0 coordinates and writes outputs.
    pub rank: usize,
    /// Rendezvous addresses, one per rank in rank order (`host:port` or
    /// `unix:PATH`); the last rank's may be omitted.
    pub peers: Vec<String>,
}

/// FNV-1a over a canonical rendering of every config field that shapes
/// the training math. Ranks exchange it in the hello handshake: two
/// processes launched with different maps, schedules, seeds, kernels,
/// rank counts, or collectives must refuse to train one map together.
/// Float endpoints hash by bit pattern, not display rounding.
pub(crate) fn config_fingerprint(cfg: &TrainConfig) -> u64 {
    let canon = format!(
        "somoclu-fp-v1|{}x{}|e{}|g{:?}|m{:?}|n{:?}|r0:{:?}|rn:{}|rc:{:?}|s0:{}|sn:{}|sc:{:?}|k{:?}|P{}|i{:?}|seed{}|coll:{}",
        cfg.rows,
        cfg.cols,
        cfg.epochs,
        cfg.grid_type,
        cfg.map_type,
        cfg.neighborhood,
        cfg.radius0.map(f32::to_bits),
        cfg.radius_n.to_bits(),
        cfg.radius_cooling,
        cfg.scale0.to_bits(),
        cfg.scale_n.to_bits(),
        cfg.scale_cooling,
        cfg.kernel,
        cfg.ranks,
        cfg.initialization,
        cfg.seed,
        cfg.collective.as_str(),
    );
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in canon.as_bytes() {
        h ^= u64::from(b);
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

fn encode_state(epoch: u64, cb: &Codebook) -> Vec<u8> {
    let mut out = Vec::with_capacity(16 + cb.weights.len() * 4);
    out.extend_from_slice(&epoch.to_le_bytes());
    out.extend_from_slice(&(cb.nodes as u32).to_le_bytes());
    out.extend_from_slice(&(cb.dim as u32).to_le_bytes());
    for w in &cb.weights {
        out.extend_from_slice(&w.to_le_bytes());
    }
    out
}

fn decode_state(bytes: &[u8]) -> anyhow::Result<(u64, Codebook)> {
    anyhow::ensure!(bytes.len() >= 16, "bootstrap state truncated");
    let epoch = u64::from_le_bytes(bytes[..8].try_into().unwrap());
    let nodes = u32::from_le_bytes(bytes[8..12].try_into().unwrap()) as usize;
    let dim = u32::from_le_bytes(bytes[12..16].try_into().unwrap()) as usize;
    let body = &bytes[16..];
    anyhow::ensure!(
        body.len() == nodes * dim * 4,
        "bootstrap state carries {} weight bytes, expected {}",
        body.len(),
        nodes * dim * 4
    );
    let weights = body
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect();
    Ok((epoch, Codebook { nodes, dim, weights }))
}

/// Train this process's rank of a real multi-process cluster (the
/// engine behind [`SomSession::fit_cluster_net`]). Returns the final
/// result on rank 0 (`None` elsewhere) plus this process's
/// communication report.
pub(crate) fn run_cluster_net(
    session: &mut SomSession,
    input: StreamInput,
    opts: &NetOptions,
) -> anyhow::Result<(Option<TrainResult>, ClusterReport)> {
    let t0 = Instant::now();
    let cfg = session.config().clone();
    cfg.validate()?;
    let ranks = cfg.ranks;
    anyhow::ensure!(
        ranks >= 2,
        "a multi-process run needs --ranks >= 2 (got {ranks})"
    );
    anyhow::ensure!(
        opts.rank < ranks,
        "--rank {} out of range for --ranks {ranks}",
        opts.rank
    );
    anyhow::ensure!(
        !matches!(cfg.kernel, KernelType::Accel | KernelType::Hybrid),
        "accel/hybrid kernels are single-node only (the paper benchmarks \
         multi-node scaling with the CPU kernel; Fig. 8)"
    );
    check_stream_kind(&cfg, &input)?;
    let (total_rows, dim) = input.probe(cfg.chunk_rows)?;
    anyhow::ensure!(total_rows >= ranks, "fewer rows than ranks");
    anyhow::ensure!(
        session.epoch() <= cfg.epochs,
        "session cursor {} beyond the {}-epoch schedule",
        session.epoch(),
        cfg.epochs
    );

    // Only rank 0 owns initial state; peers adopt it at bootstrap, so
    // `-c`/`--resume` need to be passed to rank 0 alone.
    if opts.rank == ROOT {
        match session.codebook() {
            Some(cb) => anyhow::ensure!(
                cb.dim == dim,
                "data dim {dim} does not match the session codebook dim {}",
                cb.dim
            ),
            None => {
                anyhow::ensure!(
                    cfg.initialization == Initialization::Random,
                    "PCA initialization needs the data resident in memory; \
                     multi-process runs support only --initialization random"
                );
                session.install_codebook(init_codebook(&cfg, session.grid(), dim))?;
            }
        }
    }

    let fingerprint = config_fingerprint(&cfg);
    let transport = NetTransport::bootstrap(opts.rank, ranks, &opts.peers, fingerprint)?;
    let stats = Arc::new(CommStats::new(ranks));
    let mut ep = Endpoint::new(opts.rank, ranks, Box::new(transport), stats.clone());

    // State sync: rank 0's cursor + codebook, byte-exact on every rank.
    let payload = (opts.rank == ROOT).then(|| {
        let cb = session.codebook().expect("root codebook installed");
        Arc::new(encode_state(session.epoch() as u64, cb))
    });
    let state = broadcast_bytes_from_root(&mut ep, payload, CollectiveOp::Bootstrap)
        .map_err(|e| comm_failed(opts.rank, session.epoch(), e))?;
    if opts.rank != ROOT {
        let (epoch, cb) = decode_state(&state)?;
        anyhow::ensure!(
            cb.dim == dim,
            "rank 0's codebook dim {} does not match this shard's dim {dim} \
             (are all ranks reading the same file?)",
            cb.dim
        );
        session.install_codebook(cb)?;
        session.set_epoch_cursor(epoch as usize);
    }

    let mut source = open_rank_source(&input, &cfg, opts.rank, ranks)?;
    let result = rank_train_loop(session, &mut ep, &mut *source, total_rows, cfg.epochs)?;

    // Final barrier: no process tears its sockets down while a peer is
    // still inside the BMU gather.
    barrier_with(&mut ep, cfg.collective)
        .map_err(|e| comm_failed(opts.rank, session.epoch(), e))?;

    let mut report = ClusterReport::new(ranks);
    report.absorb(&stats);
    let result = result.map(|mut r| {
        r.total = t0.elapsed();
        r
    });
    Ok((result, report))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::comm::CollectiveAlgo;
    use crate::data;
    use crate::session::Som;
    use crate::util::rng::Rng;
    use crate::util::threadpool::run_concurrent;

    #[test]
    fn fingerprint_tracks_training_config_only() {
        let a = TrainConfig::default();
        assert_eq!(config_fingerprint(&a), config_fingerprint(&a.clone()));
        let mut b = a.clone();
        b.seed += 1;
        assert_ne!(config_fingerprint(&a), config_fingerprint(&b));
        let mut c = a.clone();
        c.collective = CollectiveAlgo::Star;
        assert_ne!(config_fingerprint(&a), config_fingerprint(&c));
        // Per-process runtime knobs must NOT change the fingerprint:
        // ranks may legitimately differ in threads or I/O strategy.
        let mut d = a.clone();
        d.threads = a.threads + 3;
        d.chunk_rows = 17;
        d.prefetch = true;
        assert_eq!(config_fingerprint(&a), config_fingerprint(&d));
    }

    #[test]
    fn state_roundtrip_is_bit_exact() {
        let cb = Codebook {
            nodes: 3,
            dim: 2,
            weights: vec![1.5, -0.25, f32::MIN_POSITIVE, 3e7, -0.0, 42.0],
        };
        let (epoch, back) = decode_state(&encode_state(9, &cb)).unwrap();
        assert_eq!(epoch, 9);
        assert_eq!(back.nodes, 3);
        assert_eq!(back.dim, 2);
        let bits: Vec<u32> = back.weights.iter().map(|w| w.to_bits()).collect();
        let want: Vec<u32> = cb.weights.iter().map(|w| w.to_bits()).collect();
        assert_eq!(bits, want);
        assert!(decode_state(&[0u8; 15]).is_err());
    }

    /// The acceptance bar: ranks as real socket peers (here: threads
    /// with their own sessions over loopback TCP, exactly what two
    /// processes run) produce BMUs identical to — and codebook bits
    /// matching — the simulated in-process 2-rank run.
    #[test]
    fn net_cluster_matches_simulated_cluster() {
        let dir = std::env::temp_dir()
            .join(format!("somoclu_multiproc_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let mut rng = Rng::new(21);
        let (dat, _) = data::gaussian_blobs(60, 4, 3, 0.2, &mut rng);
        let bin = dir.join("net.somb");
        crate::io::binary::write_binary_dense(&bin, 60, 4, &dat).unwrap();

        let cfg = TrainConfig {
            rows: 6,
            cols: 6,
            epochs: 4,
            threads: 1,
            ranks: 2,
            radius0: Some(3.0),
            chunk_rows: 16,
            ..Default::default()
        };

        let (simulated, _) = Som::builder()
            .config(cfg.clone())
            .build()
            .unwrap()
            .fit_cluster_stream(StreamInput::Binary { path: bin.clone() })
            .unwrap();

        let port = {
            let l = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap().port()
        };
        let peers = vec![format!("127.0.0.1:{port}")];
        let outcomes = run_concurrent(
            (0..2usize)
                .map(|rank| {
                    let cfg = cfg.clone();
                    let peers = peers.clone();
                    let bin = bin.clone();
                    move || -> anyhow::Result<Option<TrainResult>> {
                        let mut session = Som::builder().config(cfg).build()?;
                        let (res, report) = run_cluster_net(
                            &mut session,
                            StreamInput::Binary { path: bin },
                            &NetOptions { rank, peers },
                        )?;
                        assert!(report.bytes_sent > 0);
                        Ok(res)
                    }
                })
                .collect(),
        );
        let mut root_result = None;
        for o in outcomes {
            if let Some(r) = o.unwrap() {
                root_result = Some(r);
            }
        }
        let net = root_result.expect("rank 0 returns the result");
        assert_eq!(net.bmus, simulated.bmus);
        assert_eq!(
            net.codebook
                .weights
                .iter()
                .map(|w| w.to_bits())
                .collect::<Vec<_>>(),
            simulated
                .codebook
                .weights
                .iter()
                .map(|w| w.to_bits())
                .collect::<Vec<_>>()
        );
    }
}
