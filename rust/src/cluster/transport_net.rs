//! Length-prefixed socket transport: real multi-process ranks.
//!
//! Std-only (no async runtime): one TCP or Unix-domain stream per peer
//! pair, carrying the same byte frames the in-process channels carry,
//! so every collective in [`crate::cluster::allreduce`] runs unchanged
//! over real processes — and real machines.
//!
//! **Wire format.** Each payload is `[len: u32 LE][bytes…]`. Before any
//! frames flow, both ends exchange a 24-byte hello —
//! `[b"SOMW"][version u32][world u32][rank u32][config fingerprint u64]`
//! — and refuse to proceed on any mismatch, so two processes launched
//! with different schedules, seeds, or rank counts fail fast with a
//! clear error instead of silently training different maps.
//!
//! **Rendezvous.** `peers[k]` is rank k's listen address (`host:port`
//! or `unix:PATH`); the last rank needs none. Every rank binds first,
//! then connects to all lower ranks (retrying while the peer's listener
//! comes up) and accepts from all higher ranks — connect-up/accept-down
//! is cycle-free, so bootstrap cannot deadlock.
//!
//! **Non-blocking sends.** Ring steps have every rank send before it
//! receives; if sends blocked on full socket buffers the lockstep would
//! deadlock for segments larger than the kernel's buffer. Each peer
//! therefore gets a dedicated writer thread fed by an unbounded channel
//! of `Arc` frames — `send` enqueues and returns. Receives read the
//! caller's `BufReader` directly.

use std::collections::VecDeque;
use std::io::{BufReader, BufWriter, Read, Write};
use std::net::{TcpListener, TcpStream};
#[cfg(unix)]
use std::os::unix::net::{UnixListener, UnixStream};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::cluster::comm::{Bytes, CommError, Rank, Transport};

const MAGIC: [u8; 4] = *b"SOMW";
const VERSION: u32 = 1;
/// Sanity cap on a single frame (the largest real payload is one
/// codebook: nodes × dim × 4 bytes).
const MAX_FRAME: usize = 1 << 30;
/// How long bootstrap waits for peers (connect retries and accepts).
fn bootstrap_timeout() -> Duration {
    std::env::var("SOMOCLU_BOOTSTRAP_TIMEOUT_SECS")
        .ok()
        .and_then(|v| v.parse().ok())
        .map_or(Duration::from_secs(120), Duration::from_secs)
}

/// Receive deadline applied to every peer stream after bootstrap
/// (`SOMOCLU_COMM_TIMEOUT_SECS`, default 300; `0` disables). A peer
/// that is connected but silent for this long fails the receive with
/// the typed [`CommError::Timeout`] instead of hanging the collective —
/// and the whole cluster — forever on a wedged process.
fn comm_timeout() -> Option<Duration> {
    let secs: u64 = std::env::var("SOMOCLU_COMM_TIMEOUT_SECS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(300);
    (secs > 0).then(|| Duration::from_secs(secs))
}

/// One established peer stream, TCP or Unix-domain.
enum Conn {
    Tcp(TcpStream),
    #[cfg(unix)]
    Unix(UnixStream),
}

impl Conn {
    fn try_clone(&self) -> std::io::Result<Conn> {
        Ok(match self {
            Conn::Tcp(s) => Conn::Tcp(s.try_clone()?),
            #[cfg(unix)]
            Conn::Unix(s) => Conn::Unix(s.try_clone()?),
        })
    }

    fn set_read_timeout(&self, dur: Option<Duration>) -> std::io::Result<()> {
        match self {
            Conn::Tcp(s) => s.set_read_timeout(dur),
            #[cfg(unix)]
            Conn::Unix(s) => s.set_read_timeout(dur),
        }
    }
}

impl Read for Conn {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        match self {
            Conn::Tcp(s) => s.read(buf),
            #[cfg(unix)]
            Conn::Unix(s) => s.read(buf),
        }
    }
}

impl Write for Conn {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        match self {
            Conn::Tcp(s) => s.write(buf),
            #[cfg(unix)]
            Conn::Unix(s) => s.write(buf),
        }
    }
    fn flush(&mut self) -> std::io::Result<()> {
        match self {
            Conn::Tcp(s) => s.flush(),
            #[cfg(unix)]
            Conn::Unix(s) => s.flush(),
        }
    }
}

enum Listener {
    Tcp(TcpListener),
    #[cfg(unix)]
    Unix(UnixListener),
}

/// `unix:PATH` selects a Unix-domain socket; anything else is a TCP
/// `host:port`.
fn is_unix(addr: &str) -> bool {
    addr.starts_with("unix:")
}

/// Bind `addr`, retrying `AddrInUse` until `deadline`: a replacement
/// rank re-binding its crashed predecessor's address must ride out the
/// TCP `TIME_WAIT` (and the old writer threads' teardown) the previous
/// process left behind.
fn bind(addr: &str, deadline: Instant) -> anyhow::Result<Listener> {
    loop {
        let attempt: std::io::Result<Listener> = if let Some(path) = addr.strip_prefix("unix:")
        {
            #[cfg(unix)]
            {
                // The rendezvous path belongs to this run: clear any
                // stale socket file a crashed predecessor left behind.
                let _ = std::fs::remove_file(path);
                UnixListener::bind(path).map(Listener::Unix)
            }
            #[cfg(not(unix))]
            {
                let _ = path;
                anyhow::bail!("unix: addresses need a unix target (got {addr})");
            }
        } else {
            TcpListener::bind(addr).map(Listener::Tcp)
        };
        match attempt {
            Ok(l) => return Ok(l),
            Err(e)
                if e.kind() == std::io::ErrorKind::AddrInUse
                    && Instant::now() < deadline =>
            {
                std::thread::sleep(Duration::from_millis(50));
            }
            Err(e) => anyhow::bail!("cannot listen on {addr}: {e}"),
        }
    }
}

fn connect_with_retry(addr: &str, deadline: Instant) -> anyhow::Result<Conn> {
    loop {
        let attempt: std::io::Result<Conn> = if let Some(path) = addr.strip_prefix("unix:") {
            #[cfg(unix)]
            {
                UnixStream::connect(path).map(Conn::Unix)
            }
            #[cfg(not(unix))]
            {
                let _ = path;
                return Err(anyhow::anyhow!(
                    "unix: addresses need a unix target (got {addr})"
                ));
            }
        } else {
            TcpStream::connect(addr).map(|s| {
                let _ = s.set_nodelay(true);
                Conn::Tcp(s)
            })
        };
        match attempt {
            Ok(conn) => return Ok(conn),
            Err(e) => {
                // The peer's listener may simply not be up yet — retry
                // connection-level failures until the deadline.
                let transient = matches!(
                    e.kind(),
                    std::io::ErrorKind::ConnectionRefused
                        | std::io::ErrorKind::ConnectionReset
                        | std::io::ErrorKind::NotFound
                        | std::io::ErrorKind::AddrNotAvailable
                );
                if !transient || Instant::now() >= deadline {
                    anyhow::bail!("cannot connect to {addr}: {e}");
                }
                std::thread::sleep(Duration::from_millis(50));
            }
        }
    }
}

fn accept_with_deadline(listener: &Listener, deadline: Instant) -> anyhow::Result<Conn> {
    // Poll in non-blocking mode so a missing peer fails bootstrap with
    // a clear timeout instead of hanging the process (and CI) forever.
    match listener {
        Listener::Tcp(l) => {
            l.set_nonblocking(true)?;
            loop {
                match l.accept() {
                    Ok((s, _)) => {
                        s.set_nonblocking(false)?;
                        let _ = s.set_nodelay(true);
                        return Ok(Conn::Tcp(s));
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        anyhow::ensure!(
                            Instant::now() < deadline,
                            "timed out waiting for a peer to connect"
                        );
                        std::thread::sleep(Duration::from_millis(20));
                    }
                    Err(e) => return Err(e.into()),
                }
            }
        }
        #[cfg(unix)]
        Listener::Unix(l) => {
            l.set_nonblocking(true)?;
            loop {
                match l.accept() {
                    Ok((s, _)) => {
                        s.set_nonblocking(false)?;
                        return Ok(Conn::Unix(s));
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        anyhow::ensure!(
                            Instant::now() < deadline,
                            "timed out waiting for a peer to connect"
                        );
                        std::thread::sleep(Duration::from_millis(20));
                    }
                    Err(e) => return Err(e.into()),
                }
            }
        }
    }
}

struct Hello {
    world: u32,
    rank: u32,
    fingerprint: u64,
}

fn write_hello(w: &mut impl Write, h: &Hello) -> std::io::Result<()> {
    let mut buf = [0u8; 24];
    buf[..4].copy_from_slice(&MAGIC);
    buf[4..8].copy_from_slice(&VERSION.to_le_bytes());
    buf[8..12].copy_from_slice(&h.world.to_le_bytes());
    buf[12..16].copy_from_slice(&h.rank.to_le_bytes());
    buf[16..24].copy_from_slice(&h.fingerprint.to_le_bytes());
    w.write_all(&buf)?;
    w.flush()
}

fn read_hello(r: &mut impl Read) -> anyhow::Result<Hello> {
    let mut buf = [0u8; 24];
    r.read_exact(&mut buf)
        .map_err(|e| anyhow::anyhow!("peer hung up during handshake: {e}"))?;
    anyhow::ensure!(buf[..4] == MAGIC, "peer is not a somoclu rank (bad magic)");
    let version = u32::from_le_bytes(buf[4..8].try_into().unwrap());
    anyhow::ensure!(
        version == VERSION,
        "peer speaks wire version {version}, this build speaks {VERSION}"
    );
    Ok(Hello {
        world: u32::from_le_bytes(buf[8..12].try_into().unwrap()),
        rank: u32::from_le_bytes(buf[12..16].try_into().unwrap()),
        fingerprint: u64::from_le_bytes(buf[16..24].try_into().unwrap()),
    })
}

fn check_hello(h: &Hello, world: usize, fingerprint: u64) -> anyhow::Result<()> {
    anyhow::ensure!(
        h.world as usize == world,
        "peer was launched with --ranks {}, this process with --ranks {world}",
        h.world
    );
    anyhow::ensure!(
        h.fingerprint == fingerprint,
        "peer's training config fingerprint {:#018x} differs from ours \
         {fingerprint:#018x}: all ranks must be launched with identical map, \
         schedule, seed, and collective settings",
        h.fingerprint
    );
    Ok(())
}

fn write_frame(w: &mut impl Write, payload: &[u8]) -> std::io::Result<()> {
    w.write_all(&(payload.len() as u32).to_le_bytes())?;
    w.write_all(payload)?;
    w.flush()
}

fn read_frame(r: &mut impl Read) -> std::io::Result<Vec<u8>> {
    let mut len = [0u8; 4];
    r.read_exact(&mut len)?;
    let len = u32::from_le_bytes(len) as usize;
    if len > MAX_FRAME {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            format!("frame of {len} bytes exceeds the {MAX_FRAME}-byte cap"),
        ));
    }
    let mut buf = vec![0u8; len];
    r.read_exact(&mut buf)?;
    Ok(buf)
}

fn writer_loop(conn: Conn, rx: Receiver<Arc<Vec<u8>>>) {
    let mut w = BufWriter::new(conn);
    while let Ok(payload) = rx.recv() {
        if write_frame(&mut w, &payload).is_err() {
            // Peer is gone; drain silently — the loss surfaces as a
            // PeerLost on the next receive from that peer.
            break;
        }
    }
}

/// The socket transport for one rank: per-peer writer threads (sends
/// never block) and per-peer buffered readers. Build with
/// [`NetTransport::bootstrap`].
pub struct NetTransport {
    rank: Rank,
    writers: Vec<Option<Sender<Arc<Vec<u8>>>>>,
    readers: Vec<Option<BufReader<Conn>>>,
    loopback: VecDeque<Arc<Vec<u8>>>,
    handles: Vec<JoinHandle<()>>,
}

impl NetTransport {
    /// Establish the full mesh for `rank` of `world`. `peers[k]` is
    /// rank k's listen address (the last rank's entry may be omitted);
    /// `fingerprint` guards against ranks launched with mismatched
    /// training configs.
    pub fn bootstrap(
        rank: usize,
        world: usize,
        peers: &[String],
        fingerprint: u64,
    ) -> anyhow::Result<NetTransport> {
        anyhow::ensure!(world >= 1, "world must have at least one rank");
        anyhow::ensure!(rank < world, "rank {rank} out of range for {world} ranks");
        anyhow::ensure!(
            peers.len() == world || peers.len() + 1 == world,
            "--peers lists {} addresses for {world} ranks (one per rank; the \
             last rank's may be omitted — it only connects)",
            peers.len()
        );
        let deadline = Instant::now() + bootstrap_timeout();
        let hello = Hello {
            world: world as u32,
            rank: rank as u32,
            fingerprint,
        };

        // Bind before connecting to anyone: lower ranks' connects rely
        // on every listener (rank < world−1) existing or imminently
        // existing.
        let listener = if rank + 1 < world {
            anyhow::ensure!(
                rank < peers.len(),
                "--peers has no listen address for rank {rank}"
            );
            Some(bind(&peers[rank], deadline)?)
        } else {
            None
        };

        let mut conns: Vec<Option<Conn>> = (0..world).map(|_| None).collect();

        // Connect up to every lower rank; they are accepting below.
        for (lower, addr) in peers.iter().enumerate().take(rank) {
            let mut conn = connect_with_retry(addr, deadline)
                .map_err(|e| anyhow::anyhow!("rank {rank} → rank {lower}: {e}"))?;
            write_hello(&mut conn, &hello)
                .map_err(|e| anyhow::anyhow!("rank {rank} → rank {lower}: handshake send: {e}"))?;
            let theirs = read_hello(&mut conn)
                .map_err(|e| anyhow::anyhow!("rank {rank} → rank {lower}: {e}"))?;
            check_hello(&theirs, world, fingerprint)?;
            anyhow::ensure!(
                theirs.rank as usize == lower,
                "address {addr} answered as rank {}, expected rank {lower} \
                 (check the --peers order)",
                theirs.rank
            );
            conns[lower] = Some(conn);
        }

        // Accept one connection from every higher rank, in whatever
        // order they arrive.
        if let Some(listener) = &listener {
            for _ in rank + 1..world {
                let mut conn = accept_with_deadline(listener, deadline)
                    .map_err(|e| anyhow::anyhow!("rank {rank} accepting peers: {e}"))?;
                let theirs = read_hello(&mut conn)
                    .map_err(|e| anyhow::anyhow!("rank {rank} accepting peers: {e}"))?;
                check_hello(&theirs, world, fingerprint)?;
                let higher = theirs.rank as usize;
                anyhow::ensure!(
                    higher > rank && higher < world,
                    "accepted a connection claiming rank {higher}, which should \
                     not dial rank {rank}"
                );
                anyhow::ensure!(
                    conns[higher].is_none(),
                    "two connections both claim rank {higher}"
                );
                write_hello(&mut conn, &hello).map_err(|e| {
                    anyhow::anyhow!("rank {rank} → rank {higher}: handshake reply: {e}")
                })?;
                conns[higher] = Some(conn);
            }
        }

        // Split each stream: writer thread (owns a clone) + reader.
        let mut writers = Vec::with_capacity(world);
        let mut readers = Vec::with_capacity(world);
        let mut handles = Vec::new();
        let recv_deadline = comm_timeout();
        for conn in conns {
            match conn {
                Some(conn) => {
                    let wconn = conn.try_clone()?;
                    let (tx, rx) = channel::<Arc<Vec<u8>>>();
                    handles.push(std::thread::spawn(move || writer_loop(wconn, rx)));
                    writers.push(Some(tx));
                    // The receive deadline applies to training traffic
                    // only — bootstrap has its own (shorter) timeout.
                    conn.set_read_timeout(recv_deadline)?;
                    readers.push(Some(BufReader::new(conn)));
                }
                None => {
                    writers.push(None);
                    readers.push(None);
                }
            }
        }

        Ok(NetTransport {
            rank,
            writers,
            readers,
            loopback: VecDeque::new(),
            handles,
        })
    }
}

impl Transport for NetTransport {
    fn send(&mut self, to: Rank, payload: Arc<Vec<u8>>) -> Result<(), CommError> {
        if to == self.rank {
            self.loopback.push_back(payload);
            return Ok(());
        }
        match self.writers.get(to).and_then(Option::as_ref) {
            Some(tx) => tx.send(payload).map_err(|_| CommError::PeerLost { peer: to }),
            None => Err(CommError::PeerLost { peer: to }),
        }
    }

    fn recv(&mut self, from: Rank) -> Result<Bytes, CommError> {
        if from == self.rank {
            return self
                .loopback
                .pop_front()
                .map(Bytes::Shared)
                .ok_or(CommError::Protocol {
                    peer: from,
                    what: "loopback receive with nothing sent".into(),
                });
        }
        match self.readers.get_mut(from).and_then(Option::as_mut) {
            Some(reader) => read_frame(reader).map(Bytes::Owned).map_err(|e| {
                // A receive-deadline expiry (SO_RCVTIMEO) is a hung
                // peer, not a dead one — surface the distinction.
                match e.kind() {
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut => {
                        CommError::Timeout { peer: from }
                    }
                    _ => CommError::PeerLost { peer: from },
                }
            }),
            None => Err(CommError::PeerLost { peer: from }),
        }
    }
}

impl Drop for NetTransport {
    fn drop(&mut self) {
        // Closing the channels ends the writer loops once their queues
        // drain; join so every queued frame is flushed before the
        // process exits.
        for w in &mut self.writers {
            *w = None;
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::allreduce::{allreduce_f32_sum, barrier_with};
    use crate::cluster::comm::{CollectiveAlgo, CommStats, Endpoint};
    use crate::util::threadpool::run_concurrent;

    /// Grab an ephemeral loopback port. The listener is dropped before
    /// bootstrap rebinds it — a race in principle, but loopback tests
    /// reuse it within milliseconds.
    fn free_addr() -> String {
        let l = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = l.local_addr().unwrap();
        format!("127.0.0.1:{}", addr.port())
    }

    fn net_endpoints(
        world: usize,
        peers: Vec<String>,
        fingerprint: u64,
    ) -> Vec<anyhow::Result<Endpoint>> {
        let tasks: Vec<_> = (0..world)
            .map(|rank| {
                let peers = peers.clone();
                move || -> anyhow::Result<Endpoint> {
                    let t = NetTransport::bootstrap(rank, world, &peers, fingerprint)?;
                    Ok(Endpoint::new(
                        rank,
                        world,
                        Box::new(t),
                        Arc::new(CommStats::new(world)),
                    ))
                }
            })
            .collect();
        run_concurrent(tasks)
    }

    #[test]
    fn three_rank_tcp_allreduce() {
        let peers = vec![free_addr(), free_addr(), free_addr()];
        let eps = net_endpoints(3, peers, 0xfeed);
        let tasks: Vec<_> = eps
            .into_iter()
            .map(|ep| {
                move || {
                    let mut ep = ep.unwrap();
                    let mut buf: Vec<f32> = (0..10).map(|i| (ep.rank * 10 + i) as f32).collect();
                    allreduce_f32_sum(&mut ep, &mut buf, CollectiveAlgo::Ring).unwrap();
                    barrier_with(&mut ep, CollectiveAlgo::Tree).unwrap();
                    buf
                }
            })
            .collect();
        let out = run_concurrent(tasks);
        let want: Vec<f32> = (0..10).map(|i| (3 * i + 30) as f32).collect();
        for buf in out {
            assert_eq!(buf, want);
        }
    }

    #[cfg(unix)]
    #[test]
    fn two_rank_uds_roundtrip() {
        let dir = std::env::temp_dir().join(format!("somoclu_uds_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let peers = vec![format!("unix:{}", dir.join("rank0.sock").display())];
        let eps = net_endpoints(2, peers, 1);
        let tasks: Vec<_> = eps
            .into_iter()
            .map(|ep| {
                move || {
                    let mut ep = ep.unwrap();
                    let mut buf = vec![ep.rank as f32 + 1.0; 5];
                    allreduce_f32_sum(&mut ep, &mut buf, CollectiveAlgo::Tree).unwrap();
                    buf
                }
            })
            .collect();
        for buf in run_concurrent(tasks) {
            assert_eq!(buf, vec![3.0; 5]);
        }
    }

    #[test]
    fn fingerprint_mismatch_refused() {
        let peers = vec![free_addr()];
        let tasks: Vec<Box<dyn FnOnce() -> anyhow::Result<()> + Send>> = vec![
            {
                let peers = peers.clone();
                Box::new(move || NetTransport::bootstrap(0, 2, &peers, 0xaaaa).map(|_| ()))
            },
            {
                let peers = peers.clone();
                Box::new(move || NetTransport::bootstrap(1, 2, &peers, 0xbbbb).map(|_| ()))
            },
        ];
        let out = run_concurrent(tasks);
        // At least the connecting side must refuse with the fingerprint
        // message (the listener may instead see the resulting hangup).
        assert!(out.iter().any(|r| r
            .as_ref()
            .err()
            .is_some_and(|e| format!("{e:#}").contains("fingerprint"))));
    }

    /// A connected-but-silent peer must surface as the typed
    /// [`CommError::Timeout`], not an indefinite hang (SOMOCLU_COMM_
    /// TIMEOUT_SECS applies per stream at bootstrap).
    #[test]
    fn silent_peer_times_out_as_typed_timeout() {
        std::env::set_var("SOMOCLU_COMM_TIMEOUT_SECS", "1");
        let peers = vec![free_addr()];
        let eps = net_endpoints(2, peers, 9);
        std::env::remove_var("SOMOCLU_COMM_TIMEOUT_SECS");
        let mut it = eps.into_iter();
        let e0 = it.next().unwrap();
        let e1 = it.next().unwrap(); // alive for the duration, but mute
        let out = run_concurrent(vec![Box::new(move || {
            let mut ep = e0.unwrap();
            ep.recv(1).map(|_| ()).unwrap_err()
        })
            as Box<dyn FnOnce() -> CommError + Send>]);
        assert!(
            matches!(out[0], CommError::Timeout { peer: 1 }),
            "{:?}",
            out[0]
        );
        drop(e1);
    }

    #[test]
    fn dead_peer_read_is_peer_lost() {
        let peers = vec![free_addr()];
        let eps = net_endpoints(2, peers, 7);
        let out = run_concurrent(vec![
            Box::new({
                let mut it = eps.into_iter();
                let e0 = it.next().unwrap();
                let e1 = it.next().unwrap();
                move || {
                    drop(e1); // rank 1 dies right after bootstrap
                    let mut ep = e0.unwrap();
                    ep.recv(1).map(|_| ()).unwrap_err()
                }
            }) as Box<dyn FnOnce() -> CommError + Send>,
        ]);
        assert!(matches!(out[0], CommError::PeerLost { peer: 1 }));
    }
}
