//! Interconnect cost model for the simulated cluster.
//!
//! Classic alpha-beta model: transferring B bytes costs
//! `latency + B / bandwidth`. With `NetModel::ideal()` transfers are
//! free (pure shared-memory simulation); `NetModel::ethernet_10g()` etc.
//! approximate real fabrics so the Fig. 8 scaling curve includes a
//! realistic communication term.

use std::time::Duration;

#[derive(Clone, Debug)]
pub struct NetModel {
    /// Per-message latency (alpha).
    pub latency: Duration,
    /// Bytes per second (beta); `f64::INFINITY` = free.
    pub bandwidth: f64,
}

impl NetModel {
    /// Zero-cost interconnect (default for tests).
    pub fn ideal() -> Self {
        NetModel {
            latency: Duration::ZERO,
            bandwidth: f64::INFINITY,
        }
    }

    /// 10 GbE-class fabric: ~50 µs latency, ~1.1 GiB/s effective.
    pub fn ethernet_10g() -> Self {
        NetModel {
            latency: Duration::from_micros(50),
            bandwidth: 1.1e9,
        }
    }

    /// AWS cg1.4xlarge-era 10 GbE (the paper's testbed interconnect).
    pub fn aws_cg1() -> Self {
        Self::ethernet_10g()
    }

    /// Cost of transferring `bytes`.
    pub fn cost(&self, bytes: usize) -> Duration {
        if self.bandwidth.is_infinite() && self.latency.is_zero() {
            return Duration::ZERO;
        }
        let transfer = if self.bandwidth.is_infinite() {
            Duration::ZERO
        } else {
            Duration::from_secs_f64(bytes as f64 / self.bandwidth)
        };
        self.latency + transfer
    }

    /// Block the calling (sender) thread for the modeled duration.
    pub fn transfer_delay(&self, bytes: usize) {
        let d = self.cost(bytes);
        if !d.is_zero() {
            std::thread::sleep(d);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ideal_is_free() {
        let m = NetModel::ideal();
        assert_eq!(m.cost(1 << 30), Duration::ZERO);
    }

    #[test]
    fn alpha_beta_sum() {
        let m = NetModel {
            latency: Duration::from_millis(1),
            bandwidth: 1e6, // 1 MB/s
        };
        let c = m.cost(500_000); // 0.5 s transfer + 1 ms
        assert!((c.as_secs_f64() - 0.501).abs() < 1e-6);
    }

    #[test]
    fn latency_only() {
        let m = NetModel {
            latency: Duration::from_micros(10),
            bandwidth: f64::INFINITY,
        };
        assert_eq!(m.cost(12345), Duration::from_micros(10));
    }
}
