//! Distributed training driver (paper §3.2): shard once, per-epoch
//! reduce-accumulators-to-master + broadcast-codebook, gather BMUs.
//!
//! Each rank runs on its own OS thread with its own codebook copy — the
//! MPI-process memory model whose duplication cost the paper contrasts
//! with OpenMP threads. Within a rank, the kernel still uses
//! `threads_per_rank` workers (the paper's hybrid kernel shape).

use std::time::Instant;

use crate::cluster::allreduce::{
    allreduce_f64_sum, broadcast_from_root, gather_u32_to_root, reduce_sum_to_root,
};
use crate::cluster::comm::World;
use crate::cluster::netmodel::NetModel;
use crate::coordinator::config::TrainConfig;
use crate::coordinator::train::{init_codebook, EpochStats, TrainResult};
use crate::io::stream::{DataSource, InMemorySource};
use crate::kernels::dense_cpu::DenseCpuKernel;
use crate::kernels::sparse_cpu::SparseCpuKernel;
use crate::kernels::{DataShard, EpochAccum, KernelType, TrainingKernel};
use crate::sparse::Csr;
use crate::util::threadpool::{run_concurrent, split_ranges};

/// Input data for the cluster runner (owned, so shards can move to rank
/// threads).
pub enum ClusterData {
    Dense { data: Vec<f32>, dim: usize },
    Sparse(Csr),
}

impl ClusterData {
    pub fn rows(&self) -> usize {
        match self {
            ClusterData::Dense { data, dim } => data.len() / dim,
            ClusterData::Sparse(m) => m.rows,
        }
    }

    pub fn dim(&self) -> usize {
        match self {
            ClusterData::Dense { dim, .. } => *dim,
            ClusterData::Sparse(m) => m.cols,
        }
    }

    /// Split into per-rank shards ("equally sized parts of the data to
    /// each node, without any further communication of training data").
    fn shard(self, ranks: usize) -> Vec<ClusterData> {
        let rows = self.rows();
        let ranges = split_ranges(rows, ranks);
        match self {
            ClusterData::Dense { data, dim } => ranges
                .into_iter()
                .map(|r| ClusterData::Dense {
                    data: data[r.start * dim..r.end * dim].to_vec(),
                    dim,
                })
                .collect(),
            ClusterData::Sparse(m) => ranges
                .into_iter()
                .map(|r| ClusterData::Sparse(m.slice_rows(r)))
                .collect(),
        }
    }

    fn as_shard(&self) -> DataShard<'_> {
        match self {
            ClusterData::Dense { data, dim } => DataShard::Dense {
                data,
                dim: *dim,
            },
            ClusterData::Sparse(m) => DataShard::Sparse(m),
        }
    }
}

/// Communication volume report for the Fig. 8 harness.
#[derive(Debug, Clone)]
pub struct ClusterReport {
    pub ranks: usize,
    pub bytes_sent: u64,
    pub messages_sent: u64,
}

/// Train across `cfg.ranks` simulated nodes. Returns the master's result
/// plus the communication report.
pub fn train_cluster(
    cfg: &TrainConfig,
    data: ClusterData,
    net: NetModel,
) -> anyhow::Result<(TrainResult, ClusterReport)> {
    cfg.validate().map_err(|e| anyhow::anyhow!(e))?;
    anyhow::ensure!(
        !matches!(cfg.kernel, KernelType::Accel | KernelType::Hybrid)
            || cfg.ranks == 1,
        "accel/hybrid kernels are single-node only (the paper benchmarks \
         multi-node scaling with the CPU kernel; Fig. 8)"
    );
    let ranks = cfg.ranks;
    let grid = cfg.grid();
    let dim = data.dim();
    let total_rows = data.rows();
    anyhow::ensure!(total_rows >= ranks, "fewer rows than ranks");

    // Identical initial codebook on every rank (broadcast-equivalent).
    let init = match &data {
        ClusterData::Dense { data: d, dim } => {
            crate::coordinator::train::init_codebook_with_data(
                cfg,
                &grid,
                DataShard::Dense { data: d, dim: *dim },
            )?
        }
        ClusterData::Sparse(_) => {
            anyhow::ensure!(
                cfg.initialization
                    == crate::coordinator::config::Initialization::Random,
                "PCA initialization needs dense data"
            );
            init_codebook(cfg, &grid, dim)
        }
    };
    let radius_sched = cfg.radius_schedule(&grid);
    let scale_sched = cfg.scale_schedule();

    let mut world = World::new(ranks, net);
    let endpoints = world.take_endpoints();
    let shards = data.shard(ranks);
    let threads_per_rank = cfg.threads.max(1);

    let t0 = Instant::now();
    let tasks: Vec<_> = endpoints
        .into_iter()
        .zip(shards)
        .map(|(mut ep, shard)| {
            let mut codebook = init.clone();
            let cfg = cfg.clone();
            let grid = grid.clone();
            move || -> anyhow::Result<Option<TrainResult>> {
                let mut kernel: Box<dyn TrainingKernel> = match cfg.kernel {
                    KernelType::SparseCpu => {
                        Box::new(SparseCpuKernel::new(threads_per_rank))
                    }
                    _ => Box::new(DenseCpuKernel::new(threads_per_rank)),
                };
                let rows_local = shard.rows();
                let dim_local = shard.dim();
                // Each rank streams its shard in bounded chunks — the
                // same chunk loop as the single-node coordinator, so
                // `--chunk-rows` bounds per-rank data traffic to the
                // kernel identically in both modes.
                let mut source =
                    InMemorySource::new(shard.as_shard(), cfg.chunk_rows);
                let mut epochs = Vec::with_capacity(cfg.epochs);
                let mut bmus_local: Vec<u32> = Vec::new();

                for epoch in 0..cfg.epochs {
                    let te = Instant::now();
                    let radius = radius_sched.at(epoch);
                    let scale = scale_sched.at(epoch);
                    kernel.epoch_begin(&codebook)?;
                    source.reset()?;
                    let mut accum =
                        EpochAccum::zeros(grid.node_count(), dim_local, 0);
                    let mut epoch_bmus: Vec<u32> =
                        Vec::with_capacity(rows_local);
                    while let Some(chunk) = source.next_chunk()? {
                        let part = kernel.epoch_accumulate(
                            chunk,
                            &codebook,
                            &grid,
                            cfg.neighborhood,
                            radius,
                            scale,
                        )?;
                        epoch_bmus.extend_from_slice(&part.bmus);
                        accum.merge(&part);
                    }
                    anyhow::ensure!(
                        epoch_bmus.len() == rows_local,
                        "rank shard produced {} rows, expected {rows_local}",
                        epoch_bmus.len()
                    );
                    bmus_local = epoch_bmus;

                    // Slaves send accumulators; master reduces, updates,
                    // broadcasts the new codebook (the paper's two-way
                    // master/slave exchange).
                    let is_root = reduce_sum_to_root(&mut ep, &mut accum.num);
                    reduce_sum_to_root(&mut ep, &mut accum.den);
                    let qe_total = allreduce_f64_sum(&mut ep, accum.qe_sum);
                    if is_root {
                        codebook.apply_batch_update(&accum.num, &accum.den);
                    }
                    broadcast_from_root(&mut ep, &mut codebook.weights);

                    epochs.push(EpochStats {
                        epoch,
                        radius,
                        scale,
                        qe: qe_total / total_rows as f64,
                        duration: te.elapsed(),
                    });
                    let _ = rows_local;
                }

                // Gather BMUs in rank order for the final output.
                let gathered = gather_u32_to_root(&mut ep, bmus_local);
                if let Some(parts) = gathered {
                    let bmus: Vec<u32> = parts.concat();
                    let u = crate::som::umatrix::umatrix(
                        &grid,
                        &codebook,
                        threads_per_rank,
                    );
                    Ok(Some(TrainResult {
                        codebook,
                        bmus,
                        umatrix: u,
                        epochs,
                        total: std::time::Duration::ZERO, // set by caller
                    }))
                } else {
                    Ok(None)
                }
            }
        })
        .collect();

    let outcomes = run_concurrent(tasks);
    let total = t0.elapsed();
    let mut master: Option<TrainResult> = None;
    for o in outcomes {
        if let Some(res) = o? {
            master = Some(res);
        }
    }
    let mut result = master.expect("rank 0 must produce a result");
    result.total = total;
    let report = ClusterReport {
        ranks,
        bytes_sent: world.bytes_sent(),
        messages_sent: world.messages_sent(),
    };
    Ok((result, report))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::train::train;
    use crate::data;
    use crate::util::rng::Rng;

    fn cfg(ranks: usize) -> TrainConfig {
        TrainConfig {
            rows: 6,
            cols: 6,
            epochs: 5,
            threads: 1,
            ranks,
            radius0: Some(3.0),
            ..Default::default()
        }
    }

    /// The paper's structure guarantees the distributed run computes the
    /// *same* batch update as the serial run — verify bit-for-bit BMUs
    /// and near-identical codebooks (f32 reduce order differs).
    #[test]
    fn cluster_matches_single_node() {
        let mut rng = Rng::new(7);
        let (data, _) = data::gaussian_blobs(96, 5, 3, 0.2, &mut rng);
        let single = train(
            &cfg(1),
            DataShard::Dense { data: &data, dim: 5 },
            None,
            None,
        )
        .unwrap();
        for ranks in [2, 3, 4] {
            let (multi, report) = train_cluster(
                &cfg(ranks),
                ClusterData::Dense {
                    data: data.clone(),
                    dim: 5,
                },
                NetModel::ideal(),
            )
            .unwrap();
            assert_eq!(multi.bmus, single.bmus, "ranks={ranks}");
            for (a, b) in multi
                .codebook
                .weights
                .iter()
                .zip(&single.codebook.weights)
            {
                assert!((a - b).abs() < 1e-4, "ranks={ranks}: {a} vs {b}");
            }
            assert!(
                (multi.final_qe() - single.final_qe()).abs() < 1e-6,
                "ranks={ranks}"
            );
            assert!(report.bytes_sent > 0);
        }
    }

    #[test]
    fn sparse_cluster_matches_single() {
        let mut rng = Rng::new(8);
        let m = crate::sparse::Csr::random(60, 20, 0.15, &mut rng);
        let mut c = cfg(1);
        c.kernel = KernelType::SparseCpu;
        let single = train(&c, DataShard::Sparse(&m), None, None).unwrap();
        let mut c3 = cfg(3);
        c3.kernel = KernelType::SparseCpu;
        let (multi, _) =
            train_cluster(&c3, ClusterData::Sparse(m), NetModel::ideal()).unwrap();
        assert_eq!(multi.bmus, single.bmus);
        assert!((multi.final_qe() - single.final_qe()).abs() < 1e-6);
    }

    #[test]
    fn comm_volume_scales_with_ranks_not_rows() {
        // Per epoch each slave sends N*D + N floats and receives N*D:
        // volume ∝ (ranks-1), independent of data rows — the property
        // behind the paper's near-linear scaling.
        let mut rng = Rng::new(9);
        let (data, _) = data::gaussian_blobs(64, 4, 2, 0.3, &mut rng);
        let run = |ranks| {
            let (_, report) = train_cluster(
                &cfg(ranks),
                ClusterData::Dense {
                    data: data.clone(),
                    dim: 4,
                },
                NetModel::ideal(),
            )
            .unwrap();
            report.bytes_sent
        };
        let b2 = run(2);
        let b4 = run(4);
        let per_slave_2 = b2 as f64 / 1.0;
        let per_slave_4 = b4 as f64 / 3.0;
        let ratio = per_slave_4 / per_slave_2;
        assert!(
            (0.9..1.1).contains(&ratio),
            "per-slave volume changed with ranks: {ratio}"
        );
    }

    #[test]
    fn chunked_cluster_matches_unchunked() {
        let mut rng = Rng::new(10);
        let (data, _) = data::gaussian_blobs(96, 5, 3, 0.2, &mut rng);
        let run = |chunk_rows: usize| {
            let mut c = cfg(3);
            c.chunk_rows = chunk_rows;
            train_cluster(
                &c,
                ClusterData::Dense {
                    data: data.clone(),
                    dim: 5,
                },
                NetModel::ideal(),
            )
            .unwrap()
            .0
        };
        let a = run(0);
        let b = run(9);
        assert_eq!(a.bmus, b.bmus);
        assert!((a.final_qe() - b.final_qe()).abs() < 1e-4);
    }

    #[test]
    fn rejects_more_ranks_than_rows() {
        let out = train_cluster(
            &cfg(8),
            ClusterData::Dense {
                data: vec![0.0; 4 * 5],
                dim: 5,
            },
            NetModel::ideal(),
        );
        assert!(out.is_err());
    }
}
