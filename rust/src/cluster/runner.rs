//! Distributed training driver (paper §3.2): shard once, per-epoch
//! accumulator exchange, gather BMUs.
//!
//! The exchange comes in two shapes selected by `--collective`
//! ([`CollectiveAlgo`]): the paper's literal star (reduce to master →
//! update on master → broadcast codebook, the historical bit pattern)
//! and the allreduce family (ring/tree/auto) where every rank receives
//! bit-identical summed accumulators and applies the Eq. 6 update
//! locally — no codebook broadcast at all, and no O(P·M) hot spot at
//! rank 0. Either way the per-epoch result is deterministic for a
//! fixed (rank count, algorithm) pair.
//!
//! Each rank runs on its own OS thread with its own **rank-local
//! [`SomSession`]** — the MPI-process memory model whose duplication
//! cost the paper contrasts with OpenMP threads. The per-epoch chunk
//! loop lives in `SomSession::accumulate_epoch` (the same code the
//! single-process coordinator runs); this module only adds the
//! collectives between accumulation and update.
//!
//! Two input paths share that loop:
//!
//! * `run_cluster` ([`SomSession::fit_cluster`]) — the classic resident
//!   path: the data set is sharded in memory and each rank streams its
//!   shard (optionally in `--chunk-rows` windows).
//! * `run_cluster_stream` ([`SomSession::fit_cluster_stream`]) — the
//!   out-of-core path: every rank opens its own **disjoint row window
//!   of the same file** (`open_shard`, text or binary container), so no
//!   rank ever holds more than O(chunk_rows × dim) of data.
//!
//! Both use the identical `split_ranges` row split, so gathered BMUs
//! concatenate in file row order and the reduced batch update is the
//! same sum — multi-rank streaming matches single-rank training BMUs
//! exactly (`streamed_cluster_matches_single_node`).
//!
//! The coordinator's session drives training in **windows**: without a
//! checkpoint policy there is one window covering all remaining epochs
//! (bit-identical to the historical all-at-once run); with
//! `checkpoint_every(n, …)` each window spans `n` epochs and the
//! coordinator checkpoints between windows — so multi-rank runs resume
//! mid-schedule, and a resumed coordinator seeds every rank at its
//! cursor. Per-epoch collectives are deterministic for a fixed rank
//! count, so windowing never changes the result bits.

use std::path::PathBuf;
use std::time::Instant;

use crate::cluster::allreduce::{
    allreduce_f32_sum, allreduce_f64_sum, allreduce_f64_sum_with, broadcast_from_root,
    gather_u32_with, reduce_sum_to_root,
};
use crate::cluster::comm::{CollectiveAlgo, CommError, CommStats, Endpoint, Rank, World};
use crate::cluster::fault::{FaultyTransport, RecoveryPolicy};
use crate::cluster::netmodel::NetModel;
use crate::error::SomError;
use crate::coordinator::config::{IoMode, TrainConfig};
use crate::coordinator::train::{
    init_codebook, init_codebook_with_data, EpochStats, TrainResult,
};
use crate::io::binary::{
    self, BinaryDenseFileSource, BinaryKind, BinarySparseFileSource, SharedFd,
};
use crate::io::mmap::MappedContainer;
use crate::io::stream::{
    ChunkedDenseFileSource, ChunkedSparseFileSource, DataSource, InMemorySource,
    PrefetchSource,
};
use crate::kernels::{DataShard, KernelType};
use crate::session::SomSession;
use crate::som::Codebook;
use crate::sparse::Csr;
use crate::util::threadpool::{run_concurrent, split_ranges};

/// Input data for the cluster runner (owned, so shards can move to rank
/// threads).
pub enum ClusterData {
    Dense { data: Vec<f32>, dim: usize },
    Sparse(Csr),
}

impl ClusterData {
    pub fn rows(&self) -> usize {
        match self {
            ClusterData::Dense { data, dim } => data.len() / dim,
            ClusterData::Sparse(m) => m.rows,
        }
    }

    pub fn dim(&self) -> usize {
        match self {
            ClusterData::Dense { dim, .. } => *dim,
            ClusterData::Sparse(m) => m.cols,
        }
    }

    /// Split into per-rank shards ("equally sized parts of the data to
    /// each node, without any further communication of training data").
    fn shard(self, ranks: usize) -> Vec<ClusterData> {
        let rows = self.rows();
        let ranges = split_ranges(rows, ranks);
        match self {
            ClusterData::Dense { data, dim } => ranges
                .into_iter()
                .map(|r| ClusterData::Dense {
                    data: data[r.start * dim..r.end * dim].to_vec(),
                    dim,
                })
                .collect(),
            ClusterData::Sparse(m) => ranges
                .into_iter()
                .map(|r| ClusterData::Sparse(m.slice_rows(r)))
                .collect(),
        }
    }

    fn as_shard(&self) -> DataShard<'_> {
        match self {
            ClusterData::Dense { data, dim } => DataShard::Dense {
                data,
                dim: *dim,
            },
            ClusterData::Sparse(m) => DataShard::Sparse(m.view()),
        }
    }
}

/// File-backed input for [`SomSession::fit_cluster_stream`]: each rank
/// opens its own disjoint row window of this one file.
#[derive(Clone, Debug)]
pub enum StreamInput {
    /// Dense text (plain or ESOM-headered).
    DenseText { path: PathBuf },
    /// libsvm sparse text.
    SparseText { path: PathBuf, min_cols: usize },
    /// Binary container (`io::binary`), dense or sparse by header.
    Binary { path: PathBuf },
}

impl StreamInput {
    /// Open rank `rank` of `ranks`' shard of the file.
    fn open_shard(
        &self,
        chunk_rows: usize,
        rank: usize,
        ranks: usize,
    ) -> anyhow::Result<Box<dyn DataSource + Send>> {
        Ok(match self {
            StreamInput::DenseText { path } => Box::new(
                ChunkedDenseFileSource::open_shard(path, chunk_rows, rank, ranks)?,
            ),
            StreamInput::SparseText { path, min_cols } => Box::new(
                ChunkedSparseFileSource::open_shard(
                    path, *min_cols, chunk_rows, rank, ranks,
                )?,
            ),
            StreamInput::Binary { path } => match binary::sniff(path)? {
                Some(BinaryKind::Sparse) => Box::new(
                    BinarySparseFileSource::open_shard(path, chunk_rows, rank, ranks)?,
                ),
                _ => Box::new(BinaryDenseFileSource::open_shard(
                    path, chunk_rows, rank, ranks,
                )?),
            },
        })
    }

    /// Probe (total_rows, dim). Binary containers answer from the
    /// 40-byte header; text inputs pay one full validation parse — the
    /// same pass any single-rank open pays, and it fails fast before
    /// the rank threads spawn (each rank's own open re-validates its
    /// view by design, like every epoch re-checks for file shrinkage).
    pub(crate) fn probe(&self, chunk_rows: usize) -> anyhow::Result<(usize, usize)> {
        match self {
            StreamInput::Binary { path } => {
                let f = std::fs::File::open(path)?;
                let h = binary::read_header(&f, path)?;
                Ok((h.rows, h.dim))
            }
            _ => {
                let src = self.open_shard(chunk_rows, 0, 1)?;
                Ok((src.rows(), src.dim()))
            }
        }
    }
}

/// Communication volume report for the Fig. 8 harness. With a
/// checkpoint policy, volumes accumulate across training windows.
#[derive(Debug, Clone)]
pub struct ClusterReport {
    pub ranks: usize,
    pub bytes_sent: u64,
    pub messages_sent: u64,
    /// The busiest sender's byte total, summed across windows — the
    /// bandwidth bottleneck (rank 0 under star at (P−1)·M per
    /// allreduce; ~2·(P−1)/P·M for every rank under ring).
    pub max_rank_bytes: u64,
    /// Per-collective byte/message/time totals, accumulated across
    /// windows in [`crate::cluster::comm::OP_NAMES`] order.
    pub per_op: Vec<crate::cluster::comm::OpTotals>,
}

impl ClusterReport {
    pub(crate) fn new(ranks: usize) -> Self {
        ClusterReport {
            ranks,
            bytes_sent: 0,
            messages_sent: 0,
            max_rank_bytes: 0,
            per_op: Vec::new(),
        }
    }

    /// Fold one window's (or one process-lifetime's) counters in.
    pub(crate) fn absorb(&mut self, stats: &CommStats) {
        self.bytes_sent += stats.bytes_sent.load(std::sync::atomic::Ordering::Relaxed);
        self.messages_sent += stats
            .messages_sent
            .load(std::sync::atomic::Ordering::Relaxed);
        self.max_rank_bytes += stats.max_rank_bytes();
        let ops = stats.op_totals();
        if self.per_op.is_empty() {
            self.per_op = ops;
        } else {
            for (acc, w) in self.per_op.iter_mut().zip(ops) {
                acc.bytes += w.bytes;
                acc.messages += w.messages;
                acc.nanos += w.nanos;
            }
        }
    }
}

/// A rank's communication failure annotated with who observed it and
/// when — the clean "rank k lost at epoch e" surface a dead peer gets
/// instead of the old endpoint panic, and the typed unit the
/// window-fence abort classification consumes.
#[derive(Debug, thiserror::Error)]
#[error("rank {rank}: communication failed at epoch {epoch}")]
pub struct CommFailure {
    /// The rank that observed the failure.
    pub rank: Rank,
    /// The absolute epoch it was at when the collective failed.
    pub epoch: usize,
    /// The underlying transport failure.
    #[source]
    pub source: CommError,
}

/// Wrap a collective failure with who noticed it and when.
pub(crate) fn comm_failed(rank: Rank, epoch: usize, e: CommError) -> anyhow::Error {
    anyhow::Error::new(CommFailure {
        rank,
        epoch,
        source: e,
    })
}

/// The typed window-fence abort state (ISSUE 10): when any rank fails
/// a collective mid-window, the surviving ranks' `PeerLost` cascade
/// collapses into this one value at the fence — who died, when, and
/// which epoch the retry rewinds to. The recovery driver re-runs
/// aborted windows under the session's
/// [`RecoveryPolicy`](crate::cluster::fault::RecoveryPolicy); with the
/// restart budget exhausted (or recovery disabled) it surfaces as the
/// root cause of the run's typed [`SomError`].
#[derive(Debug, Clone, thiserror::Error)]
#[error(
    "epoch {epoch} aborted: rank {failed_rank} failed ({cause}); \
     training rewinds to epoch {rewind_to}"
)]
pub struct EpochAborted {
    /// The rank blamed for the abort: the rank whose own outcome blames
    /// itself (it died in place), or the peer most survivors lost.
    pub failed_rank: Rank,
    /// The earliest epoch at which any rank observed the failure.
    pub epoch: usize,
    /// The checkpoint-window start a retry rewinds to.
    pub rewind_to: usize,
    /// The root-cause transport failure, rendered.
    pub cause: String,
}

/// One rank's run over `[session.epoch(), end_epoch)`: per epoch, the
/// session's chunk-loop accumulation, then the reduce/update/broadcast
/// exchange (the paper's two-way master/slave pattern); finally the BMU
/// gather. A zero-epoch window (a run resumed at schedule completion)
/// still gathers — BMUs come from a projection pass. Returns
/// `Some(result)` on the master rank only.
pub(crate) fn rank_train_loop(
    session: &mut SomSession,
    ep: &mut Endpoint,
    source: &mut dyn DataSource,
    total_rows: usize,
    end_epoch: usize,
) -> anyhow::Result<Option<TrainResult>> {
    let algo = session.config().collective;
    let rows_local = source.rows();
    while session.epoch() < end_epoch {
        let te = Instant::now();
        let epoch = session.epoch();
        let (radius, scale) = session.schedule_now();
        let mut accum = session.accumulate_epoch(source)?;
        let bmus = std::mem::take(&mut accum.bmus);

        let qe_total = if algo == CollectiveAlgo::Star {
            // The paper's literal two-way master/slave exchange: slaves
            // send accumulators, the master reduces (serially, in rank
            // order — the historical bit pattern), updates, and
            // broadcasts the new codebook.
            let is_root = reduce_sum_to_root(ep, &mut accum.num)
                .map_err(|e| comm_failed(ep.rank, epoch, e))?;
            reduce_sum_to_root(ep, &mut accum.den)
                .map_err(|e| comm_failed(ep.rank, epoch, e))?;
            let qe = allreduce_f64_sum(ep, accum.qe_sum)
                .map_err(|e| comm_failed(ep.rank, epoch, e))?;
            if is_root {
                session.apply_epoch_update(&accum);
            }
            broadcast_from_root(ep, session.weights_mut())
                .map_err(|e| comm_failed(ep.rank, epoch, e))?;
            qe
        } else {
            // Ring/tree (or auto): allreduce leaves every rank holding
            // bit-identical summed accumulators, so each rank applies
            // the Eq. 6 update locally — the O(P·M) codebook broadcast
            // disappears entirely.
            allreduce_f32_sum(ep, &mut accum.num, algo)
                .map_err(|e| comm_failed(ep.rank, epoch, e))?;
            allreduce_f32_sum(ep, &mut accum.den, algo)
                .map_err(|e| comm_failed(ep.rank, epoch, e))?;
            let qe = allreduce_f64_sum_with(ep, accum.qe_sum, algo)
                .map_err(|e| comm_failed(ep.rank, epoch, e))?;
            session.apply_epoch_update(&accum);
            qe
        };
        session.finish_epoch(
            EpochStats {
                epoch,
                radius,
                scale,
                qe: qe_total / total_rows as f64,
                duration: te.elapsed(),
            },
            bmus,
        )?;
    }

    let mut bmus_local = session.last_bmus().to_vec();
    if bmus_local.len() != rows_local {
        // No epoch ran in this window: refresh the mapping with a
        // projection pass so the gather still covers every row.
        bmus_local = session.project_source(source)?;
    }

    // Gather BMUs in rank order for the final output.
    let gathered = gather_u32_with(ep, bmus_local, algo)
        .map_err(|e| comm_failed(ep.rank, session.epoch(), e))?;
    if let Some(parts) = gathered {
        let bmus: Vec<u32> = parts.concat();
        let codebook = session.codebook().expect("trained").clone();
        let u = crate::som::umatrix::umatrix(
            session.grid(),
            &codebook,
            session.config().threads,
        );
        Ok(Some(TrainResult {
            codebook,
            bmus,
            umatrix: u,
            epochs: session.history().to_vec(),
            total: std::time::Duration::ZERO, // set by caller
        }))
    } else {
        Ok(None)
    }
}

/// What one window's per-rank outcomes collapse to at the fence.
enum WindowOutcome {
    /// Every rank completed; the master's result.
    Complete(TrainResult),
    /// At least one rank failed a collective: the typed abort the
    /// recovery driver retries. Session state is untouched on abort.
    Aborted(EpochAborted),
}

/// The window-fence protocol (replaces the old `pick_master`): fold the
/// per-rank outcomes into one [`WindowOutcome`]. Communication failures
/// from any number of ranks — the victim's own error plus its peers'
/// `PeerLost`/`Timeout`/`Protocol` cascades — converge on a single
/// [`EpochAborted`] naming the root-cause rank. Non-communication
/// errors (kernel bugs, unreadable shards) surface immediately and are
/// never retried.
fn window_fence(
    outcomes: Vec<anyhow::Result<Option<TrainResult>>>,
    rewind_to: usize,
) -> anyhow::Result<WindowOutcome> {
    let mut master: Option<TrainResult> = None;
    // (observer, blamed peer, epoch, rendered cause) per failed rank.
    let mut failures: Vec<(Rank, Rank, usize, String)> = Vec::new();
    for (rank, o) in outcomes.into_iter().enumerate() {
        match o {
            Ok(Some(res)) => master = Some(res),
            Ok(None) => {}
            Err(e) => {
                if let Some(f) = e.downcast_ref::<CommFailure>() {
                    failures.push((f.rank, f.source.peer(), f.epoch, f.source.to_string()));
                } else if let Some(c) = e.downcast_ref::<CommError>() {
                    failures.push((rank, c.peer(), rewind_to, c.to_string()));
                } else {
                    return Err(e);
                }
            }
        }
    }
    if !failures.is_empty() {
        // A rank blaming itself died in place (injected kill, local
        // socket teardown); otherwise the most-blamed peer is the one
        // that vanished (ties break low).
        let failed_rank = failures
            .iter()
            .find(|(observer, peer, _, _)| observer == peer)
            .map(|&(_, peer, _, _)| peer)
            .unwrap_or_else(|| {
                let mut votes: Vec<(usize, Rank)> = Vec::new();
                for &(_, peer, _, _) in &failures {
                    match votes.iter_mut().find(|(_, p)| *p == peer) {
                        Some((n, _)) => *n += 1,
                        None => votes.push((1, peer)),
                    }
                }
                votes.sort_by_key(|&(n, p)| (std::cmp::Reverse(n), p));
                votes[0].1
            });
        let epoch = failures.iter().map(|&(_, _, e, _)| e).min().unwrap_or(rewind_to);
        let cause = failures
            .iter()
            .find(|&&(_, peer, _, _)| peer == failed_rank)
            .map(|(_, _, _, c)| c.clone())
            .unwrap_or_else(|| failures[0].3.clone());
        return Ok(WindowOutcome::Aborted(EpochAborted {
            failed_rank,
            epoch,
            rewind_to,
            cause,
        }));
    }
    master
        .map(WindowOutcome::Complete)
        .ok_or_else(|| anyhow::anyhow!("rank 0 produced no result"))
}

/// The terminal error for an abort the run will not retry: recovery
/// disabled keeps the historical `comm` error code; an exhausted
/// restart budget surfaces as the typed `recovery` code. Either way
/// the [`EpochAborted`] root cause rides the chain — never a bare
/// `PeerLost` cascade.
pub(crate) fn abort_error(abort: EpochAborted, policy: &RecoveryPolicy) -> anyhow::Error {
    let som = if policy.max_restarts == 0 {
        SomError::Comm(format!(
            "{abort}; recovery disabled (--recover max-restarts=N retries automatically)"
        ))
    } else {
        SomError::recovery(format!(
            "{abort}; recovery exhausted after {} restart(s)",
            policy.max_restarts
        ))
    };
    anyhow::Error::new(abort).context(som)
}

fn check_kernel_ranks(cfg: &TrainConfig) -> anyhow::Result<()> {
    anyhow::ensure!(
        !matches!(cfg.kernel, KernelType::Accel | KernelType::Hybrid)
            || cfg.ranks == 1,
        "accel/hybrid kernels are single-node only (the paper benchmarks \
         multi-node scaling with the CPU kernel; Fig. 8)"
    );
    Ok(())
}

/// Kind-vs-kernel mismatch must fail before any rank starts training:
/// inside a rank it would surface as a kernel error that drops the
/// rank's Endpoint and fails the peers mid-collective instead of
/// returning this message.
pub(crate) fn check_stream_kind(cfg: &TrainConfig, input: &StreamInput) -> anyhow::Result<()> {
    let wants_sparse = cfg.kernel == KernelType::SparseCpu;
    let input_sparse = match input {
        StreamInput::SparseText { .. } => true,
        StreamInput::DenseText { .. } => false,
        StreamInput::Binary { path } => {
            matches!(binary::sniff(path)?, Some(BinaryKind::Sparse))
        }
    };
    anyhow::ensure!(
        wants_sparse == input_sparse,
        "input is {} but the {} kernel was selected ({})",
        if input_sparse { "sparse" } else { "dense" },
        if wants_sparse { "sparse" } else { "dense" },
        if input_sparse { "use -k 2" } else { "drop -k 2" },
    );
    Ok(())
}

/// Open one rank's shard of `input` honoring the configured I/O backend
/// (the per-process analog of `run_cluster_stream`'s source setup; in a
/// real multi-process run each process opens only its own window).
pub(crate) fn open_rank_source(
    input: &StreamInput,
    cfg: &TrainConfig,
    rank: usize,
    ranks: usize,
) -> anyhow::Result<Box<dyn DataSource + Send>> {
    let mut source: Box<dyn DataSource + Send> = match (input, cfg.io_mode) {
        (StreamInput::Binary { path }, IoMode::Pread) => {
            let shared = SharedFd::open(path)?;
            match shared.header().kind {
                BinaryKind::Dense => {
                    Box::new(shared.dense_shard(cfg.chunk_rows, rank, ranks)?)
                }
                BinaryKind::Sparse => {
                    Box::new(shared.sparse_shard(cfg.chunk_rows, rank, ranks)?)
                }
            }
        }
        (StreamInput::Binary { path }, IoMode::Mmap) => {
            let mapped = MappedContainer::open(path)?;
            match mapped.header().kind {
                BinaryKind::Dense => {
                    Box::new(mapped.dense_shard(cfg.chunk_rows, rank, ranks)?)
                }
                BinaryKind::Sparse => {
                    Box::new(mapped.sparse_shard(cfg.chunk_rows, rank, ranks)?)
                }
            }
        }
        (_, IoMode::Buffered) => input.open_shard(cfg.chunk_rows, rank, ranks)?,
        (_, mode) => anyhow::bail!(mode.text_input_error()),
    };
    if cfg.prefetch {
        source = Box::new(PrefetchSource::new(source));
    }
    Ok(source)
}

/// The shared checkpoint-window driver behind both cluster paths: per
/// window, spin up a [`World`], hand its endpoints to `spawn` (which
/// builds one task per rank from the coordinator's codebook snapshot
/// and runs the ranks to the window end), accumulate the communication
/// report, adopt the master's state into the coordinator session
/// (firing its checkpoint policy), and repeat until the schedule
/// completes. The resident and streamed paths differ only in how
/// `spawn` builds each rank's data source.
///
/// **Recovery (ISSUE 10):** the coordinator session is only mutated
/// when a window completes, so an [`EpochAborted`] window is retried
/// for free — re-form the world (respawning every rank, including the
/// dead one, from the same pre-window codebook) and re-run. Collectives
/// are deterministic per (rank count, algorithm), so the recovered run
/// is **byte-identical** to an uninterrupted one. Retries are bounded
/// by the session's [`RecoveryPolicy`] with exponential backoff; a
/// session carrying a [`FaultPlan`](crate::cluster::fault::FaultPlan)
/// gets every rank's transport wrapped in a [`FaultyTransport`].
fn run_windows(
    session: &mut SomSession,
    net: NetModel,
    spawn: &mut dyn FnMut(
        Vec<Endpoint>,
        &Codebook,
        usize,
        usize,
    ) -> Vec<anyhow::Result<Option<TrainResult>>>,
) -> anyhow::Result<(TrainResult, ClusterReport)> {
    let ranks = session.config().ranks;
    let total_epochs = session.config().epochs;
    let policy = session.recovery().clone();
    let fault_plan = session.fault_plan();
    let t0 = Instant::now();
    let mut report = ClusterReport::new(ranks);
    let mut all_stats: Vec<EpochStats> = Vec::new();
    let mut last_master: Option<TrainResult> = None;
    let mut restarts_left = policy.max_restarts;
    let mut consecutive_aborts = 0usize;
    loop {
        let start = session.epoch();
        let end = window_end(session, total_epochs);
        let init = session.codebook().expect("codebook installed").clone();
        let mut world = match &fault_plan {
            Some(plan) => World::new_with_wrapper(ranks, net.clone(), &mut |r, t| {
                Box::new(FaultyTransport::new(r, t, plan.clone()))
            }),
            None => World::new(ranks, net.clone()),
        };
        let endpoints = world.take_endpoints();
        let outcomes = spawn(endpoints, &init, start, end);
        report.absorb(&world.stats);
        match window_fence(outcomes, start)? {
            WindowOutcome::Complete(master) => {
                all_stats.extend(master.epochs.iter().cloned());
                session.adopt_cluster_window(&master, end)?;
                last_master = Some(master);
                consecutive_aborts = 0;
                if end >= total_epochs {
                    break;
                }
            }
            WindowOutcome::Aborted(abort) => {
                if restarts_left == 0 {
                    return Err(abort_error(abort, &policy));
                }
                restarts_left -= 1;
                std::thread::sleep(policy.backoff_for(consecutive_aborts));
                consecutive_aborts += 1;
                // Fall through: the loop re-reads the untouched session
                // cursor/codebook and re-runs the same window.
            }
        }
    }
    let mut result = last_master.expect("at least one window ran");
    result.epochs = all_stats;
    result.total = t0.elapsed();
    Ok((result, report))
}

/// The window span for the coordinator's next cluster window: up to the
/// next multiple of the checkpoint cadence, capped at the schedule end.
/// Aligning to the cadence *grid* (not `start + n`) matters for resumed
/// runs: a session resumed at epoch 3 with `checkpoint_every(2)` must
/// window to 4, 6, 8, … so the `epoch % every == 0` save in
/// `adopt_cluster_window` fires after every window — the same cadence
/// the single-process path produces.
pub(crate) fn window_end(session: &SomSession, total_epochs: usize) -> usize {
    match session.checkpoint_interval() {
        Some(n) if n > 0 => ((session.epoch() / n + 1) * n).min(total_epochs),
        _ => total_epochs,
    }
}

/// Train `session` across `cfg.ranks` simulated nodes on resident data
/// (the engine behind [`SomSession::fit_cluster`]). Returns the master's
/// result plus the communication report.
pub(crate) fn run_cluster(
    session: &mut SomSession,
    data: ClusterData,
    net: NetModel,
) -> anyhow::Result<(TrainResult, ClusterReport)> {
    let cfg = session.config().clone();
    cfg.validate()?;
    check_kernel_ranks(&cfg)?;
    let ranks = cfg.ranks;
    let dim = data.dim();
    let total_rows = data.rows();
    let total_epochs = cfg.epochs;
    anyhow::ensure!(total_rows >= ranks, "fewer rows than ranks");
    anyhow::ensure!(
        session.epoch() <= total_epochs,
        "session cursor {} beyond the {total_epochs}-epoch schedule",
        session.epoch()
    );

    // Identical initial codebook on every rank (broadcast-equivalent);
    // a resumed session already carries it.
    match session.codebook() {
        Some(cb) => anyhow::ensure!(
            cb.dim == dim,
            "data dim {dim} does not match the session codebook dim {}",
            cb.dim
        ),
        None => {
            let init = match &data {
                ClusterData::Dense { data: d, dim } => init_codebook_with_data(
                    &cfg,
                    session.grid(),
                    DataShard::Dense { data: d, dim: *dim },
                )?,
                ClusterData::Sparse(_) => {
                    anyhow::ensure!(
                        cfg.initialization
                            == crate::coordinator::config::Initialization::Random,
                        "PCA initialization needs dense data"
                    );
                    init_codebook(&cfg, session.grid(), dim)
                }
            };
            session.install_codebook(init)?;
        }
    }

    let shards = data.shard(ranks);
    run_windows(session, net, &mut |endpoints, init, start, end| {
        let tasks: Vec<_> = endpoints
            .into_iter()
            .zip(&shards)
            .map(|(mut ep, shard)| {
                let cfg = cfg.clone();
                let codebook = init.clone();
                move || -> anyhow::Result<Option<TrainResult>> {
                    let chunk_rows = cfg.chunk_rows;
                    let mut rank_session =
                        SomSession::rank_local(cfg, codebook, start)?;
                    // Each rank streams its resident shard in bounded
                    // chunks — the same chunk loop as the single-node
                    // coordinator, so `--chunk-rows` bounds per-rank data
                    // traffic to the kernel identically in both modes.
                    let mut source =
                        InMemorySource::new(shard.as_shard(), chunk_rows);
                    rank_train_loop(
                        &mut rank_session,
                        &mut ep,
                        &mut source,
                        total_rows,
                        end,
                    )
                }
            })
            .collect();
        run_concurrent(tasks)
    })
}

/// Train `session` across `cfg.ranks` simulated nodes with **no
/// resident copy of the data** (the engine behind
/// [`SomSession::fit_cluster_stream`]): every rank streams its own
/// disjoint row window of the same file. Peak data memory is
/// ranks × chunk_rows × dim (× 2 with `cfg.prefetch`), independent of
/// file size. Sources are opened once and reused across checkpoint
/// windows.
pub(crate) fn run_cluster_stream(
    session: &mut SomSession,
    input: StreamInput,
    net: NetModel,
) -> anyhow::Result<(TrainResult, ClusterReport)> {
    let cfg = session.config().clone();
    cfg.validate()?;
    check_kernel_ranks(&cfg)?;
    let ranks = cfg.ranks;
    let total_epochs = cfg.epochs;
    check_stream_kind(&cfg, &input)?;
    let (total_rows, dim) = input.probe(cfg.chunk_rows)?;
    anyhow::ensure!(total_rows >= ranks, "fewer rows than ranks");
    anyhow::ensure!(
        session.epoch() <= total_epochs,
        "session cursor {} beyond the {total_epochs}-epoch schedule",
        session.epoch()
    );

    match session.codebook() {
        Some(cb) => anyhow::ensure!(
            cb.dim == dim,
            "data dim {dim} does not match the session codebook dim {}",
            cb.dim
        ),
        None => {
            anyhow::ensure!(
                cfg.initialization
                    == crate::coordinator::config::Initialization::Random,
                "PCA initialization needs the data resident in memory; streamed \
                 cluster runs support only --initialization random"
            );
            session.install_codebook(init_codebook(&cfg, session.grid(), dim))?;
        }
    }

    // Open every rank's shard BEFORE spawning rank threads: a fallible
    // open inside a thread would drop its Endpoint and panic the peers
    // blocked in collectives ("peer endpoint dropped") instead of
    // surfacing the real error. Opened up front, an unreadable file is
    // a clean anyhow error. (Mid-epoch read failures — the file mutated
    // under a running job — still abort via the collective panic, the
    // same behavior resident kernel errors always had.)
    let mut sources: Vec<Box<dyn DataSource + Send>> = Vec::with_capacity(ranks);
    match (&input, cfg.io_mode) {
        (StreamInput::Binary { path }, IoMode::Pread) => {
            // One shared fd serves every rank: each source clones the
            // Arc and issues positioned reads against its own window.
            let shared = SharedFd::open(path)?;
            for rank in 0..ranks {
                sources.push(match shared.header().kind {
                    BinaryKind::Dense => {
                        Box::new(shared.dense_shard(cfg.chunk_rows, rank, ranks)?)
                    }
                    BinaryKind::Sparse => {
                        Box::new(shared.sparse_shard(cfg.chunk_rows, rank, ranks)?)
                    }
                });
            }
        }
        (StreamInput::Binary { path }, IoMode::Mmap) => {
            // One mapping serves every rank: chunk views come straight
            // out of the shared page cache, no per-rank buffers at all.
            let mapped = MappedContainer::open(path)?;
            for rank in 0..ranks {
                sources.push(match mapped.header().kind {
                    BinaryKind::Dense => {
                        Box::new(mapped.dense_shard(cfg.chunk_rows, rank, ranks)?)
                    }
                    BinaryKind::Sparse => {
                        Box::new(mapped.sparse_shard(cfg.chunk_rows, rank, ranks)?)
                    }
                });
            }
        }
        (_, IoMode::Buffered) => {
            // Per-rank opens. These run concurrently: each text open is
            // a full validation parse, so doing them serially would cost
            // ranks × parse wall-clock at startup.
            let opens: Vec<_> = (0..ranks)
                .map(|rank| {
                    let input = input.clone();
                    let chunk_rows = cfg.chunk_rows;
                    move || input.open_shard(chunk_rows, rank, ranks)
                })
                .collect();
            for opened in run_concurrent(opens) {
                sources.push(opened?);
            }
        }
        (_, mode) => anyhow::bail!(mode.text_input_error()),
    }
    if cfg.prefetch {
        // Read-ahead per rank: each shard's chunk k+1 loads while its
        // kernel runs chunk k. (mmap + prefetch was rejected by
        // cfg.validate above — a copy thread would defeat zero-copy.)
        sources = sources
            .into_iter()
            .map(|s| Box::new(PrefetchSource::new(s)) as Box<dyn DataSource + Send>)
            .collect();
    }

    run_windows(session, net, &mut |endpoints, init, start, end| {
        let tasks: Vec<_> = endpoints
            .into_iter()
            .zip(sources.iter_mut())
            .map(|(mut ep, source)| {
                let cfg = cfg.clone();
                let codebook = init.clone();
                move || -> anyhow::Result<Option<TrainResult>> {
                    let mut rank_session =
                        SomSession::rank_local(cfg, codebook, start)?;
                    rank_train_loop(
                        &mut rank_session,
                        &mut ep,
                        &mut **source,
                        total_rows,
                        end,
                    )
                }
            })
            .collect();
        run_concurrent(tasks)
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data;
    use crate::io::dense;
    use crate::session::Som;
    use crate::util::rng::Rng;
    use std::sync::Arc;
    use std::time::Duration;

    fn cfg(ranks: usize) -> TrainConfig {
        TrainConfig {
            rows: 6,
            cols: 6,
            epochs: 5,
            threads: 1,
            ranks,
            radius0: Some(3.0),
            ..Default::default()
        }
    }

    fn fit_single(cfg: &TrainConfig, shard: DataShard<'_>) -> TrainResult {
        Som::builder()
            .config(cfg.clone())
            .build()
            .unwrap()
            .fit_shard(shard)
            .unwrap()
    }

    fn fit_cluster(
        cfg: &TrainConfig,
        data: ClusterData,
        net: NetModel,
    ) -> Result<(TrainResult, ClusterReport), crate::error::SomError> {
        Som::builder()
            .config(cfg.clone())
            .net(net)
            .build()?
            .fit_cluster(data)
    }

    fn fit_cluster_stream(
        cfg: &TrainConfig,
        input: StreamInput,
        net: NetModel,
    ) -> Result<(TrainResult, ClusterReport), crate::error::SomError> {
        Som::builder()
            .config(cfg.clone())
            .net(net)
            .build()?
            .fit_cluster_stream(input)
    }

    /// The "rank k lost at epoch e" message contract: a dead peer must
    /// surface who noticed, when, and which rank vanished — the whole
    /// error chain, not a panic.
    #[test]
    fn comm_failure_names_rank_and_epoch() {
        let err = comm_failed(2, 5, CommError::PeerLost { peer: 1 });
        let chain = format!("{err:#}");
        assert!(chain.contains("rank 2"), "{chain}");
        assert!(chain.contains("epoch 5"), "{chain}");
        assert!(chain.contains("rank 1 lost"), "{chain}");
    }

    /// The paper's structure guarantees the distributed run computes the
    /// *same* batch update as the serial run — verify bit-for-bit BMUs
    /// and near-identical codebooks (f32 reduce order differs).
    #[test]
    fn cluster_matches_single_node() {
        let mut rng = Rng::new(7);
        let (data, _) = data::gaussian_blobs(96, 5, 3, 0.2, &mut rng);
        let single = fit_single(&cfg(1), DataShard::Dense { data: &data, dim: 5 });
        for ranks in [2, 3, 4] {
            let (multi, report) = fit_cluster(
                &cfg(ranks),
                ClusterData::Dense {
                    data: data.clone(),
                    dim: 5,
                },
                NetModel::ideal(),
            )
            .unwrap();
            assert_eq!(multi.bmus, single.bmus, "ranks={ranks}");
            for (a, b) in multi
                .codebook
                .weights
                .iter()
                .zip(&single.codebook.weights)
            {
                assert!((a - b).abs() < 1e-4, "ranks={ranks}: {a} vs {b}");
            }
            assert!(
                (multi.final_qe() - single.final_qe()).abs() < 1e-6,
                "ranks={ranks}"
            );
            assert!(report.bytes_sent > 0);
        }
    }

    #[test]
    fn sparse_cluster_matches_single() {
        let mut rng = Rng::new(8);
        let m = crate::sparse::Csr::random(60, 20, 0.15, &mut rng);
        let mut c = cfg(1);
        c.kernel = KernelType::SparseCpu;
        let single = fit_single(&c, DataShard::Sparse(m.view()));
        let mut c3 = cfg(3);
        c3.kernel = KernelType::SparseCpu;
        let (multi, _) =
            fit_cluster(&c3, ClusterData::Sparse(m), NetModel::ideal()).unwrap();
        assert_eq!(multi.bmus, single.bmus);
        assert!((multi.final_qe() - single.final_qe()).abs() < 1e-6);
    }

    /// Two identically configured cluster sessions must be bit-identical
    /// (the reproducibility the pre-0.2 `train_cluster` shim-equivalence
    /// test relied on, now stated directly against the session API).
    #[test]
    fn cluster_session_runs_are_reproducible() {
        let mut rng = Rng::new(77);
        let (data, _) = data::gaussian_blobs(48, 4, 3, 0.2, &mut rng);
        let run = || {
            fit_cluster(
                &cfg(2),
                ClusterData::Dense {
                    data: data.clone(),
                    dim: 4,
                },
                NetModel::ideal(),
            )
            .unwrap()
            .0
        };
        let a = run();
        let b = run();
        assert_eq!(a.bmus, b.bmus);
        assert_eq!(a.codebook.weights, b.codebook.weights);
    }

    #[test]
    fn comm_volume_scales_with_ranks_not_rows() {
        // Per epoch each slave sends N*D + N floats and receives N*D:
        // volume ∝ (ranks-1), independent of data rows — the property
        // behind the paper's near-linear scaling.
        let mut rng = Rng::new(9);
        let (data, _) = data::gaussian_blobs(64, 4, 2, 0.3, &mut rng);
        let run = |ranks| {
            let (_, report) = fit_cluster(
                &cfg(ranks),
                ClusterData::Dense {
                    data: data.clone(),
                    dim: 4,
                },
                NetModel::ideal(),
            )
            .unwrap();
            report.bytes_sent
        };
        let b2 = run(2);
        let b4 = run(4);
        let per_slave_2 = b2 as f64 / 1.0;
        let per_slave_4 = b4 as f64 / 3.0;
        let ratio = per_slave_4 / per_slave_2;
        assert!(
            (0.9..1.1).contains(&ratio),
            "per-slave volume changed with ranks: {ratio}"
        );
    }

    #[test]
    fn chunked_cluster_matches_unchunked() {
        let mut rng = Rng::new(10);
        let (data, _) = data::gaussian_blobs(96, 5, 3, 0.2, &mut rng);
        let run = |chunk_rows: usize| {
            let mut c = cfg(3);
            c.chunk_rows = chunk_rows;
            fit_cluster(
                &c,
                ClusterData::Dense {
                    data: data.clone(),
                    dim: 5,
                },
                NetModel::ideal(),
            )
            .unwrap()
            .0
        };
        let a = run(0);
        let b = run(9);
        assert_eq!(a.bmus, b.bmus);
        assert!((a.final_qe() - b.final_qe()).abs() < 1e-4);
    }

    #[test]
    fn rejects_more_ranks_than_rows() {
        let out = fit_cluster(
            &cfg(8),
            ClusterData::Dense {
                data: vec![0.0; 4 * 5],
                dim: 5,
            },
            NetModel::ideal(),
        );
        assert!(out.is_err());
    }

    /// The ISSUE 2 acceptance bar: `--ranks N --chunk-rows M` streaming
    /// disjoint shards from one file matches single-rank training BMUs
    /// exactly — text and binary, with and without prefetch.
    #[test]
    fn streamed_cluster_matches_single_node() {
        let dir = std::env::temp_dir()
            .join(format!("somoclu_cluster_stream_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let mut rng = Rng::new(11);
        let (data, _) = data::gaussian_blobs(90, 5, 3, 0.2, &mut rng);
        let text = dir.join("stream.txt");
        dense::write_dense(&text, 90, 5, &data, false).unwrap();
        let bin = dir.join("stream.somb");
        crate::io::binary::write_binary_dense(&bin, 90, 5, &data).unwrap();

        let single = fit_single(&cfg(1), DataShard::Dense { data: &data, dim: 5 });

        for (input, prefetch) in [
            (StreamInput::DenseText { path: text.clone() }, false),
            (StreamInput::Binary { path: bin.clone() }, false),
            (StreamInput::Binary { path: bin.clone() }, true),
        ] {
            let mut c = cfg(3);
            c.chunk_rows = 8;
            c.prefetch = prefetch;
            let (multi, report) =
                fit_cluster_stream(&c, input.clone(), NetModel::ideal()).unwrap();
            assert_eq!(
                multi.bmus, single.bmus,
                "input {input:?} prefetch {prefetch}"
            );
            assert!(
                (multi.final_qe() - single.final_qe()).abs() < 1e-4,
                "input {input:?}"
            );
            assert!(report.bytes_sent > 0);
        }
    }

    #[test]
    fn streamed_sparse_cluster_matches_single_node() {
        let dir = std::env::temp_dir()
            .join(format!("somoclu_cluster_stream_sp_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let mut rng = Rng::new(12);
        let m = crate::sparse::Csr::random(60, 20, 0.2, &mut rng);
        let svm = dir.join("stream.svm");
        crate::io::sparse::write_sparse(&svm, &m).unwrap();
        // Re-read so blank-row semantics match the file exactly.
        let resident = crate::io::sparse::read_sparse(&svm, 20).unwrap();

        let mut c1 = cfg(1);
        c1.kernel = KernelType::SparseCpu;
        let single = fit_single(&c1, DataShard::Sparse(resident.view()));

        let mut c3 = cfg(3);
        c3.kernel = KernelType::SparseCpu;
        c3.chunk_rows = 7;
        let (multi, _) = fit_cluster_stream(
            &c3,
            StreamInput::SparseText {
                path: svm.clone(),
                min_cols: 20,
            },
            NetModel::ideal(),
        )
        .unwrap();
        assert_eq!(multi.bmus, single.bmus);
        assert!((multi.final_qe() - single.final_qe()).abs() < 1e-4);

        // Binary sparse container, prefetched.
        let bin = dir.join("stream_sp.somb");
        crate::io::binary::write_binary_sparse(&bin, &resident).unwrap();
        let mut cb = c3.clone();
        cb.prefetch = true;
        let (multib, _) = fit_cluster_stream(
            &cb,
            StreamInput::Binary { path: bin },
            NetModel::ideal(),
        )
        .unwrap();
        assert_eq!(multib.bmus, single.bmus);
    }

    #[test]
    fn streamed_cluster_rejects_kernel_kind_mismatch() {
        // A kind/kernel mismatch must be a clean pre-spawn error — inside
        // a rank thread it would panic the peers mid-collective.
        let dir = std::env::temp_dir()
            .join(format!("somoclu_cluster_stream_kind_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let mut rng = Rng::new(14);
        let m = crate::sparse::Csr::random(20, 8, 0.4, &mut rng);
        let bin = dir.join("kind.somb");
        crate::io::binary::write_binary_sparse(&bin, &m).unwrap();

        let mut c = cfg(2); // dense kernel (default)
        c.chunk_rows = 5;
        let err = fit_cluster_stream(
            &c,
            StreamInput::Binary { path: bin.clone() },
            NetModel::ideal(),
        );
        assert!(err.is_err());
        assert!(format!("{:#}", err.unwrap_err()).contains("-k 2"));

        let mut c = cfg(2);
        c.chunk_rows = 5;
        c.kernel = KernelType::SparseCpu;
        let err = fit_cluster_stream(
            &c,
            StreamInput::DenseText {
                path: dir.join("nope.txt"),
            },
            NetModel::ideal(),
        );
        // Dense text + sparse kernel: rejected before the (missing)
        // file is even opened.
        assert!(err.is_err());
    }

    #[test]
    fn streamed_cluster_rejects_pca_init() {
        let dir = std::env::temp_dir()
            .join(format!("somoclu_cluster_stream_pca_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("pca.txt");
        std::fs::write(&path, "1 2\n3 4\n5 6\n7 8\n").unwrap();
        let mut c = cfg(2);
        c.chunk_rows = 2;
        c.initialization = crate::coordinator::config::Initialization::Pca;
        let err = fit_cluster_stream(
            &c,
            StreamInput::DenseText { path },
            NetModel::ideal(),
        );
        assert!(err.is_err());
        assert!(format!("{:#}", err.unwrap_err()).contains("resident"));
    }

    /// Checkpoint windows must not change the result: the per-epoch
    /// collectives are deterministic for a fixed rank count, so training
    /// in 2-epoch windows is bit-identical to one 5-epoch window.
    #[test]
    fn checkpoint_windows_are_bit_identical() {
        let dir = std::env::temp_dir()
            .join(format!("somoclu_cluster_windows_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let mut rng = Rng::new(15);
        let (data, _) = data::gaussian_blobs(72, 4, 3, 0.2, &mut rng);
        let make = || ClusterData::Dense {
            data: data.clone(),
            dim: 4,
        };

        let (plain, _) = fit_cluster(&cfg(3), make(), NetModel::ideal()).unwrap();

        let prefix = dir.join("win");
        let mut windowed = Som::builder()
            .config(cfg(3))
            .checkpoint_every(2, &prefix)
            .build()
            .unwrap();
        let (res, _) = windowed.fit_cluster(make()).unwrap();
        assert_eq!(res.bmus, plain.bmus);
        assert_eq!(res.codebook.weights, plain.codebook.weights);
        assert_eq!(res.epochs.len(), plain.epochs.len());
        // Checkpoints landed at the window boundaries.
        for k in [2, 4] {
            assert!(
                crate::session::checkpoint_path(&prefix, k).exists(),
                "missing checkpoint at epoch {k}"
            );
        }
    }

    /// The fence must collapse a whole failure cascade — the victim's
    /// self-blame plus every survivor's `PeerLost` — into one abort
    /// naming the root-cause rank and the earliest failing epoch.
    #[test]
    fn window_fence_collapses_cascade_to_root_cause() {
        let outcomes: Vec<anyhow::Result<Option<TrainResult>>> = vec![
            // Rank 0 (root) noticed rank 1 vanish at epoch 4.
            Err(comm_failed(0, 4, CommError::PeerLost { peer: 1 })),
            // Rank 1 blames itself (injected kill) at epoch 3.
            Err(comm_failed(1, 3, CommError::PeerLost { peer: 1 })),
            // Rank 2's cascade arrives blaming rank 1 too.
            Err(comm_failed(2, 4, CommError::Timeout { peer: 1 })),
        ];
        match window_fence(outcomes, 2).unwrap() {
            WindowOutcome::Aborted(a) => {
                assert_eq!(a.failed_rank, 1);
                assert_eq!(a.epoch, 3, "earliest observed failure epoch");
                assert_eq!(a.rewind_to, 2);
                assert!(a.cause.contains("rank 1"), "{}", a.cause);
                let text = a.to_string();
                assert!(text.contains("rewinds to epoch 2"), "{text}");
            }
            WindowOutcome::Complete(_) => panic!("expected abort"),
        }
    }

    /// Without a self-blaming victim (a real process crash leaves no
    /// first-person report), the most-blamed peer is the failed rank.
    #[test]
    fn window_fence_votes_when_no_self_blame() {
        let outcomes: Vec<anyhow::Result<Option<TrainResult>>> = vec![
            Err(comm_failed(0, 2, CommError::PeerLost { peer: 3 })),
            Err(comm_failed(1, 2, CommError::PeerLost { peer: 3 })),
            Err(comm_failed(2, 2, CommError::PeerLost { peer: 0 })),
        ];
        match window_fence(outcomes, 0).unwrap() {
            WindowOutcome::Aborted(a) => assert_eq!(a.failed_rank, 3),
            WindowOutcome::Complete(_) => panic!("expected abort"),
        }
    }

    /// Non-communication failures (kernel bugs, unreadable shards) must
    /// surface immediately — retrying them would loop forever.
    #[test]
    fn window_fence_passes_noncomm_errors_through() {
        let outcomes: Vec<anyhow::Result<Option<TrainResult>>> = vec![
            Err(anyhow::anyhow!("kernel exploded")),
            Ok(None),
        ];
        let err = window_fence(outcomes, 0).unwrap_err();
        assert!(err.to_string().contains("kernel exploded"));
    }

    /// The terminal error code tracks the policy: unconfigured runs keep
    /// the historical `comm` code, an exhausted restart budget is the
    /// new `recovery` code — and both carry the root cause.
    #[test]
    fn abort_error_code_tracks_policy() {
        let abort = EpochAborted {
            failed_rank: 2,
            epoch: 7,
            rewind_to: 6,
            cause: "rank 2 lost (endpoint dropped mid-collective)".into(),
        };
        let disabled = abort_error(abort.clone(), &RecoveryPolicy::none());
        let s = SomError::from(disabled);
        assert_eq!(s.code(), "comm");
        assert!(s.message().contains("rank 2 failed"), "{s}");

        let exhausted = abort_error(abort, &RecoveryPolicy::restarts(3));
        let s = SomError::from(exhausted);
        assert_eq!(s.code(), "recovery");
        assert!(s.message().contains("exhausted after 3 restart(s)"), "{s}");
        assert!(s.message().contains("epoch 7 aborted"), "{s}");
    }

    /// End-to-end in-process recovery smoke: a rank killed mid-run under
    /// a restart budget recovers to a byte-identical result. (The full
    /// rank×epoch×collective sweep lives in `tests/fault_recovery.rs`.)
    #[test]
    fn injected_kill_recovers_byte_identical() {
        use crate::cluster::fault::FaultPlan;
        let mut rng = Rng::new(21);
        let (data, _) = data::gaussian_blobs(48, 4, 3, 0.2, &mut rng);
        let make = || ClusterData::Dense {
            data: data.clone(),
            dim: 4,
        };

        let (clean, _) = fit_cluster(&cfg(3), make(), NetModel::ideal()).unwrap();

        let plan = Arc::new(FaultPlan::observe(3).kill(1, 7));
        let mut session = Som::builder()
            .config(cfg(3))
            .recovery(RecoveryPolicy::restarts(2).with_backoff(Duration::from_millis(1)))
            .build()
            .unwrap();
        session.set_fault_plan(Some(plan.clone()));
        let (res, _) = session.fit_cluster(make()).unwrap();
        assert!(plan.all_fired(), "the kill never triggered");
        assert_eq!(res.bmus, clean.bmus);
        assert_eq!(res.codebook.weights, clean.codebook.weights);
    }

    /// Exhausting the restart budget surfaces the typed `recovery` error
    /// (a persistent fault re-kills the respawned rank every attempt).
    #[test]
    fn exhausted_restarts_surface_recovery_error() {
        use crate::cluster::fault::FaultPlan;
        let mut rng = Rng::new(22);
        let (data, _) = data::gaussian_blobs(48, 4, 3, 0.2, &mut rng);

        // Four kills aimed at rank 1, spaced one op apart: each retry
        // trips the next one, outlasting a 2-restart budget.
        let mut plan = FaultPlan::observe(3);
        for k in 0..4 {
            plan = plan.kill(1, 7 + k);
        }
        let mut session = Som::builder()
            .config(cfg(3))
            .recovery(RecoveryPolicy::restarts(2).with_backoff(Duration::from_millis(1)))
            .build()
            .unwrap();
        session.set_fault_plan(Some(Arc::new(plan)));
        let err = session
            .fit_cluster(ClusterData::Dense {
                data: data.clone(),
                dim: 4,
            })
            .unwrap_err();
        assert_eq!(err.code(), "recovery");
        assert!(err.message().contains("rank 1"), "{err}");
    }
}
