//! Rank-to-rank messaging: the MPI substitute.
//!
//! A `World` builds a full mesh of channels between `size` ranks; each
//! rank takes its `Endpoint` into its thread. Sends are byte-counted
//! (per-rank totals, read by the Fig. 8 harness) and optionally delayed
//! by the `NetModel` to simulate interconnect cost.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;

use crate::cluster::netmodel::NetModel;

pub type Rank = usize;

/// Payloads exchanged by the training collectives. Byte costs match what
/// MPI would put on the wire for the same buffers.
#[derive(Clone, Debug)]
pub enum CollectiveMsg {
    F32(Vec<f32>),
    U32(Vec<u32>),
    F64(f64),
    /// Control/empty message (barrier token).
    Token,
}

impl CollectiveMsg {
    pub fn byte_cost(&self) -> usize {
        match self {
            CollectiveMsg::F32(v) => v.len() * 4,
            CollectiveMsg::U32(v) => v.len() * 4,
            CollectiveMsg::F64(_) => 8,
            CollectiveMsg::Token => 1,
        }
    }

    pub fn into_f32(self) -> Vec<f32> {
        match self {
            CollectiveMsg::F32(v) => v,
            other => panic!("expected F32 message, got {other:?}"),
        }
    }

    pub fn into_u32(self) -> Vec<u32> {
        match self {
            CollectiveMsg::U32(v) => v,
            other => panic!("expected U32 message, got {other:?}"),
        }
    }

    pub fn into_f64(self) -> f64 {
        match self {
            CollectiveMsg::F64(v) => v,
            other => panic!("expected F64 message, got {other:?}"),
        }
    }
}

/// Shared communication statistics (read after the run).
#[derive(Debug, Default)]
pub struct CommStats {
    pub bytes_sent: AtomicU64,
    pub messages_sent: AtomicU64,
}

/// One rank's endpoint: senders to every rank, receivers from every rank.
pub struct Endpoint {
    pub rank: Rank,
    pub size: usize,
    txs: Vec<Sender<CollectiveMsg>>,
    rxs: Vec<Receiver<CollectiveMsg>>,
    stats: Arc<CommStats>,
    net: Arc<NetModel>,
}

impl Endpoint {
    /// Send `msg` to `to` (applies the network-model delay and counts
    /// bytes). Sending to self is allowed (loopback, no delay).
    pub fn send(&self, to: Rank, msg: CollectiveMsg) {
        let bytes = msg.byte_cost();
        if to != self.rank {
            self.stats.bytes_sent.fetch_add(bytes as u64, Ordering::Relaxed);
            self.stats.messages_sent.fetch_add(1, Ordering::Relaxed);
            self.net.transfer_delay(bytes);
        }
        self.txs[to]
            .send(msg)
            .expect("peer endpoint dropped before receiving");
    }

    /// Blocking receive from `from`.
    pub fn recv(&mut self, from: Rank) -> CollectiveMsg {
        self.rxs[from]
            .recv()
            .expect("peer endpoint dropped before sending")
    }
}

/// The communicator: build once, split into endpoints.
pub struct World {
    pub size: usize,
    pub stats: Arc<CommStats>,
    endpoints: Vec<Endpoint>,
}

impl World {
    pub fn new(size: usize, net: NetModel) -> Self {
        assert!(size > 0);
        let stats = Arc::new(CommStats::default());
        let net = Arc::new(net);
        // mesh[from][to]
        let mut senders: Vec<Vec<Option<Sender<CollectiveMsg>>>> =
            (0..size).map(|_| (0..size).map(|_| None).collect()).collect();
        let mut receivers: Vec<Vec<Option<Receiver<CollectiveMsg>>>> =
            (0..size).map(|_| (0..size).map(|_| None).collect()).collect();
        for from in 0..size {
            for to in 0..size {
                let (tx, rx) = channel();
                senders[from][to] = Some(tx);
                receivers[to][from] = Some(rx);
            }
        }
        let endpoints = senders
            .into_iter()
            .zip(receivers)
            .enumerate()
            .map(|(rank, (txs, rxs))| Endpoint {
                rank,
                size,
                txs: txs.into_iter().map(Option::unwrap).collect(),
                rxs: rxs.into_iter().map(Option::unwrap).collect(),
                stats: stats.clone(),
                net: net.clone(),
            })
            .collect();
        World {
            size,
            stats,
            endpoints,
        }
    }

    /// Take the per-rank endpoints (consumes the world's handles; stats
    /// remain readable through `self.stats`).
    pub fn take_endpoints(&mut self) -> Vec<Endpoint> {
        std::mem::take(&mut self.endpoints)
    }

    pub fn bytes_sent(&self) -> u64 {
        self.stats.bytes_sent.load(Ordering::Relaxed)
    }

    pub fn messages_sent(&self) -> u64 {
        self.stats.messages_sent.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::threadpool::run_concurrent;

    #[test]
    fn ping_pong() {
        let mut world = World::new(2, NetModel::ideal());
        let mut eps = world.take_endpoints();
        let e1 = eps.pop().unwrap();
        let e0 = eps.pop().unwrap();
        let out = run_concurrent(vec![
            Box::new(move || {
                let mut e0 = e0;
                e0.send(1, CollectiveMsg::F32(vec![1.0, 2.0]));
                e0.recv(1).into_f64()
            }) as Box<dyn FnOnce() -> f64 + Send>,
            Box::new(move || {
                let mut e1 = e1;
                let v = e1.recv(0).into_f32();
                e1.send(0, CollectiveMsg::F64(v.iter().sum::<f32>() as f64));
                0.0
            }),
        ]);
        assert_eq!(out[0], 3.0);
        assert_eq!(world.bytes_sent(), 8 + 8);
        assert_eq!(world.messages_sent(), 2);
    }

    #[test]
    fn loopback_not_counted() {
        let mut world = World::new(1, NetModel::ideal());
        let mut eps = world.take_endpoints();
        let mut e = eps.pop().unwrap();
        e.send(0, CollectiveMsg::U32(vec![1, 2, 3]));
        assert_eq!(e.recv(0).into_u32(), vec![1, 2, 3]);
        assert_eq!(world.bytes_sent(), 0);
    }

    #[test]
    fn messages_ordered_per_pair() {
        let mut world = World::new(2, NetModel::ideal());
        let mut eps = world.take_endpoints();
        let e1 = eps.pop().unwrap();
        let e0 = eps.pop().unwrap();
        let got = run_concurrent(vec![
            Box::new(move || {
                let e0 = e0;
                for i in 0..100u32 {
                    e0.send(1, CollectiveMsg::U32(vec![i]));
                }
                Vec::new()
            }) as Box<dyn FnOnce() -> Vec<u32> + Send>,
            Box::new(move || {
                let mut e1 = e1;
                (0..100).map(|_| e1.recv(0).into_u32()[0]).collect()
            }),
        ]);
        assert_eq!(got[1], (0..100).collect::<Vec<_>>());
    }
}
