//! Rank-to-rank messaging: the MPI substitute.
//!
//! The communication layer is split along two seams (ISSUE 7):
//!
//! * **[`Transport`]** moves opaque byte payloads between ranks. The
//!   in-process [`ChannelTransport`] (built by [`World`]) is a full mesh
//!   of channels — one OS thread per simulated rank, optionally delayed
//!   by the alpha-beta [`NetModel`] to model interconnect cost. The
//!   socket transport ([`crate::cluster::transport_net::NetTransport`])
//!   carries the same frames over length-prefixed TCP/UDS streams so
//!   ranks can be real processes on real machines.
//! * **[`Endpoint`]** is what the collectives in
//!   [`crate::cluster::allreduce`] program against: rank identity plus
//!   byte/message/time accounting ([`CommStats`]), independent of which
//!   transport carries the bytes.
//!
//! Every payload is raw little-endian bytes (`f32`/`u32`/`f64` buffers
//! encode bit-exactly), so the star collectives produce the same bits
//! over any transport, and byte counts match what MPI would put on the
//! wire for the same buffers. Sends and receives return `Result`: a
//! dropped peer surfaces as [`CommError::PeerLost`] instead of
//! poisoning every rank thread with a panic.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;

use crate::cluster::netmodel::NetModel;

pub type Rank = usize;

/// Which collective algorithm the cluster exchange uses (`--collective`).
///
/// A **runtime knob** like `threads`/`ranks`: not stored in checkpoints.
/// Summation order is fixed per (rank count, algorithm), so any single
/// choice is deterministic across a run — but star and ring/tree
/// reassociate f32 sums differently, so codebooks agree only within the
/// established 5e-4 reassociation tolerance (BMUs stay exact).
#[derive(Copy, Clone, Debug, Default, PartialEq, Eq)]
pub enum CollectiveAlgo {
    /// Pick by payload size: binomial tree for small (latency-bound)
    /// buffers, segmented ring for large (bandwidth-bound) ones.
    #[default]
    Auto,
    /// The paper's literal master/slave star (§3): slaves funnel full
    /// buffers through rank 0, which sums serially in rank order. Kept
    /// bit-compatible with the historical path for regression tests.
    Star,
    /// Segmented ring reduce-scatter + allgather: each rank moves
    /// 2·(P−1)/P·M bytes per allreduce regardless of rank count.
    Ring,
    /// Binomial tree reduce + broadcast: O(log P) latency steps, for
    /// small payloads where latency dominates bandwidth.
    Tree,
}

impl CollectiveAlgo {
    /// The CLI spelling (for reports and error messages).
    pub fn as_str(self) -> &'static str {
        match self {
            CollectiveAlgo::Auto => "auto",
            CollectiveAlgo::Star => "star",
            CollectiveAlgo::Ring => "ring",
            CollectiveAlgo::Tree => "tree",
        }
    }
}

impl std::str::FromStr for CollectiveAlgo {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "auto" => Ok(CollectiveAlgo::Auto),
            "star" => Ok(CollectiveAlgo::Star),
            "ring" => Ok(CollectiveAlgo::Ring),
            "tree" => Ok(CollectiveAlgo::Tree),
            other => Err(format!(
                "unknown collective algorithm: {other} (want auto | star | ring | tree)"
            )),
        }
    }
}

/// Communication failure, surfaced through the collectives as a clean
/// error instead of a panic (ISSUE 7 satellite): the cluster runner
/// annotates it with the failing rank and epoch.
#[derive(Debug, thiserror::Error)]
pub enum CommError {
    /// The peer's endpoint dropped mid-collective (rank thread returned
    /// early, process died, or socket closed).
    #[error("rank {peer} lost (endpoint dropped mid-collective)")]
    PeerLost { peer: Rank },
    /// The peer sent bytes that do not decode as the expected payload.
    #[error("protocol error talking to rank {peer}: {what}")]
    Protocol { peer: Rank, what: String },
    /// The peer is still connected but produced no bytes within the
    /// receive deadline (`SOMOCLU_COMM_TIMEOUT_SECS`) — a hung process
    /// or a partitioned link. Feeds the same abort/recovery path as
    /// [`CommError::PeerLost`].
    #[error("rank {peer} timed out (no bytes within the receive deadline)")]
    Timeout { peer: Rank },
}

impl CommError {
    /// The rank this failure implicates — the input the recovery driver
    /// needs to know which rank to respawn.
    pub fn peer(&self) -> Rank {
        match self {
            CommError::PeerLost { peer }
            | CommError::Protocol { peer, .. }
            | CommError::Timeout { peer } => *peer,
        }
    }
}

/// A received payload: shared (loopback / in-process, zero-copy) or
/// owned (read off a socket). Dereferences to `&[u8]` either way.
pub enum Bytes {
    Shared(Arc<Vec<u8>>),
    Owned(Vec<u8>),
}

impl std::ops::Deref for Bytes {
    type Target = [u8];
    fn deref(&self) -> &[u8] {
        match self {
            Bytes::Shared(b) => b,
            Bytes::Owned(b) => b,
        }
    }
}

/// Which collective a send belongs to, for the per-op accounting the
/// Fig. 8 harness reports (`CommStats::op_totals`).
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub enum CollectiveOp {
    /// f32 buffer allreduce (the Eq. 6 num/den accumulators — the
    /// bandwidth-dominant exchange; in star mode this covers the
    /// reduce-to-root and the codebook broadcast).
    Allreduce,
    /// f64 scalar allreduce (the QE total).
    Scalar,
    /// BMU gather to root.
    Gather,
    /// Barrier tokens.
    Barrier,
    /// Multi-process bootstrap (hello + initial codebook sync).
    Bootstrap,
}

/// Display names, indexed by [`CollectiveOp::index`].
pub const OP_NAMES: [&str; 5] = ["allreduce", "scalar", "gather", "barrier", "bootstrap"];

impl CollectiveOp {
    pub fn index(self) -> usize {
        match self {
            CollectiveOp::Allreduce => 0,
            CollectiveOp::Scalar => 1,
            CollectiveOp::Gather => 2,
            CollectiveOp::Barrier => 3,
            CollectiveOp::Bootstrap => 4,
        }
    }

    pub fn name(self) -> &'static str {
        OP_NAMES[self.index()]
    }
}

#[derive(Debug, Default)]
struct OpCounters {
    bytes: AtomicU64,
    messages: AtomicU64,
    nanos: AtomicU64,
}

/// One collective's totals (a [`CommStats::op_totals`] row). `nanos`
/// aggregates rank-time spent inside the collective across all ranks —
/// divide by the rank count for mean per-rank wall time.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct OpTotals {
    pub name: &'static str,
    pub bytes: u64,
    pub messages: u64,
    pub nanos: u64,
}

/// Shared communication statistics (read after the run): aggregate
/// byte/message totals, per-rank sent bytes (the star-vs-ring contrast
/// is a *max-per-rank* story — aggregate volumes are nearly equal), and
/// per-collective bytes/messages/time.
#[derive(Debug)]
pub struct CommStats {
    pub bytes_sent: AtomicU64,
    pub messages_sent: AtomicU64,
    per_rank_bytes: Vec<AtomicU64>,
    per_op: [OpCounters; OP_NAMES.len()],
}

impl CommStats {
    pub fn new(size: usize) -> Self {
        CommStats {
            bytes_sent: AtomicU64::new(0),
            messages_sent: AtomicU64::new(0),
            per_rank_bytes: (0..size).map(|_| AtomicU64::new(0)).collect(),
            per_op: Default::default(),
        }
    }

    fn record_send(&self, from: Rank, op: CollectiveOp, bytes: usize) {
        self.bytes_sent.fetch_add(bytes as u64, Ordering::Relaxed);
        self.messages_sent.fetch_add(1, Ordering::Relaxed);
        if let Some(r) = self.per_rank_bytes.get(from) {
            r.fetch_add(bytes as u64, Ordering::Relaxed);
        }
        let c = &self.per_op[op.index()];
        c.bytes.fetch_add(bytes as u64, Ordering::Relaxed);
        c.messages.fetch_add(1, Ordering::Relaxed);
    }

    /// Add rank-time spent inside a collective (each rank's call adds
    /// its own elapsed time).
    pub fn add_op_nanos(&self, op: CollectiveOp, nanos: u64) {
        self.per_op[op.index()].nanos.fetch_add(nanos, Ordering::Relaxed);
    }

    /// Bytes sent by one rank.
    pub fn rank_bytes(&self, rank: Rank) -> u64 {
        self.per_rank_bytes
            .get(rank)
            .map_or(0, |r| r.load(Ordering::Relaxed))
    }

    /// The busiest sender's byte total — the bandwidth bottleneck
    /// (rank 0 under star; ~2·(P−1)/P·M for every rank under ring).
    pub fn max_rank_bytes(&self) -> u64 {
        self.per_rank_bytes
            .iter()
            .map(|r| r.load(Ordering::Relaxed))
            .max()
            .unwrap_or(0)
    }

    /// Per-collective totals, in [`OP_NAMES`] order.
    pub fn op_totals(&self) -> Vec<OpTotals> {
        self.per_op
            .iter()
            .zip(OP_NAMES)
            .map(|(c, name)| OpTotals {
                name,
                bytes: c.bytes.load(Ordering::Relaxed),
                messages: c.messages.load(Ordering::Relaxed),
                nanos: c.nanos.load(Ordering::Relaxed),
            })
            .collect()
    }
}

/// Byte mover between ranks. Implementations must deliver payloads
/// per-pair in FIFO order; `send` must not block on the receiver making
/// progress (buffered channel or writer thread), because the ring
/// collectives run in lockstep with everyone sending before receiving.
pub trait Transport: Send {
    fn send(&mut self, to: Rank, payload: Arc<Vec<u8>>) -> Result<(), CommError>;
    fn recv(&mut self, from: Rank) -> Result<Bytes, CommError>;
}

/// One rank's endpoint: a transport plus identity and accounting. The
/// collectives in [`crate::cluster::allreduce`] are written against
/// this type only, so they run unchanged over threads or sockets.
pub struct Endpoint {
    pub rank: Rank,
    pub size: usize,
    transport: Box<dyn Transport>,
    stats: Arc<CommStats>,
}

impl Endpoint {
    pub fn new(rank: Rank, size: usize, transport: Box<dyn Transport>, stats: Arc<CommStats>) -> Self {
        Endpoint {
            rank,
            size,
            transport,
            stats,
        }
    }

    /// Send `payload` to `to`, attributed to collective `op`. Sending
    /// to self is allowed (loopback — not counted, like MPI self-sends
    /// that never touch the wire).
    pub fn send(&mut self, to: Rank, payload: Arc<Vec<u8>>, op: CollectiveOp) -> Result<(), CommError> {
        if to != self.rank {
            self.stats.record_send(self.rank, op, payload.len());
        }
        self.transport.send(to, payload)
    }

    /// Blocking receive of the next payload from `from`.
    pub fn recv(&mut self, from: Rank) -> Result<Bytes, CommError> {
        self.transport.recv(from)
    }

    /// The shared statistics handle.
    pub fn stats(&self) -> &Arc<CommStats> {
        &self.stats
    }
}

/// The in-process transport: a full mesh of unbounded channels, with
/// the alpha-beta [`NetModel`] delaying non-loopback sends to simulate
/// interconnect cost. Payloads move as `Arc` clones — a broadcast
/// serializes once and shares the buffer with every receiver.
pub struct ChannelTransport {
    rank: Rank,
    txs: Vec<Sender<Arc<Vec<u8>>>>,
    rxs: Vec<Receiver<Arc<Vec<u8>>>>,
    net: Arc<NetModel>,
}

impl Transport for ChannelTransport {
    fn send(&mut self, to: Rank, payload: Arc<Vec<u8>>) -> Result<(), CommError> {
        if to != self.rank {
            self.net.transfer_delay(payload.len());
        }
        self.txs[to]
            .send(payload)
            .map_err(|_| CommError::PeerLost { peer: to })
    }

    fn recv(&mut self, from: Rank) -> Result<Bytes, CommError> {
        self.rxs[from]
            .recv()
            .map(Bytes::Shared)
            .map_err(|_| CommError::PeerLost { peer: from })
    }
}

/// The in-process communicator: build once, split into endpoints.
pub struct World {
    pub size: usize,
    pub stats: Arc<CommStats>,
    endpoints: Vec<Endpoint>,
}

impl World {
    pub fn new(size: usize, net: NetModel) -> Self {
        World::new_with_wrapper(size, net, &mut |_, t| t)
    }

    /// [`World::new`] with a per-rank transport interception hook:
    /// `wrap(rank, transport)` runs once per rank over the freshly built
    /// channel transport, and whatever it returns becomes that rank's
    /// endpoint transport. This is the seam the deterministic
    /// fault-injection layer ([`crate::cluster::fault::FaultyTransport`])
    /// plugs into; an identity closure reproduces `World::new` exactly.
    pub fn new_with_wrapper(
        size: usize,
        net: NetModel,
        wrap: &mut dyn FnMut(Rank, Box<dyn Transport>) -> Box<dyn Transport>,
    ) -> Self {
        assert!(size > 0);
        let stats = Arc::new(CommStats::new(size));
        let net = Arc::new(net);
        // mesh[from][to]
        let mut senders: Vec<Vec<Option<Sender<Arc<Vec<u8>>>>>> =
            (0..size).map(|_| (0..size).map(|_| None).collect()).collect();
        let mut receivers: Vec<Vec<Option<Receiver<Arc<Vec<u8>>>>>> =
            (0..size).map(|_| (0..size).map(|_| None).collect()).collect();
        for from in 0..size {
            for to in 0..size {
                let (tx, rx) = channel();
                senders[from][to] = Some(tx);
                receivers[to][from] = Some(rx);
            }
        }
        let endpoints = senders
            .into_iter()
            .zip(receivers)
            .enumerate()
            .map(|(rank, (txs, rxs))| {
                let transport = ChannelTransport {
                    rank,
                    txs: txs.into_iter().map(Option::unwrap).collect(),
                    rxs: rxs.into_iter().map(Option::unwrap).collect(),
                    net: net.clone(),
                };
                Endpoint::new(rank, size, wrap(rank, Box::new(transport)), stats.clone())
            })
            .collect();
        World {
            size,
            stats,
            endpoints,
        }
    }

    /// Take the per-rank endpoints (consumes the world's handles; stats
    /// remain readable through `self.stats`).
    pub fn take_endpoints(&mut self) -> Vec<Endpoint> {
        std::mem::take(&mut self.endpoints)
    }

    pub fn bytes_sent(&self) -> u64 {
        self.stats.bytes_sent.load(Ordering::Relaxed)
    }

    pub fn messages_sent(&self) -> u64 {
        self.stats.messages_sent.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::threadpool::run_concurrent;

    #[test]
    fn ping_pong() {
        let mut world = World::new(2, NetModel::ideal());
        let mut eps = world.take_endpoints();
        let e1 = eps.pop().unwrap();
        let e0 = eps.pop().unwrap();
        let out = run_concurrent(vec![
            Box::new(move || {
                let mut e0 = e0;
                e0.send(1, Arc::new(vec![1u8; 8]), CollectiveOp::Allreduce).unwrap();
                e0.recv(1).unwrap().len()
            }) as Box<dyn FnOnce() -> usize + Send>,
            Box::new(move || {
                let mut e1 = e1;
                let got = e1.recv(0).unwrap();
                assert_eq!(&*got, &[1u8; 8]);
                e1.send(0, Arc::new(vec![2u8; 8]), CollectiveOp::Scalar).unwrap();
                0
            }),
        ]);
        assert_eq!(out[0], 8);
        assert_eq!(world.bytes_sent(), 8 + 8);
        assert_eq!(world.messages_sent(), 2);
        // Per-rank and per-op attribution.
        assert_eq!(world.stats.rank_bytes(0), 8);
        assert_eq!(world.stats.rank_bytes(1), 8);
        let ops = world.stats.op_totals();
        assert_eq!(ops[CollectiveOp::Allreduce.index()].bytes, 8);
        assert_eq!(ops[CollectiveOp::Scalar.index()].bytes, 8);
        assert_eq!(ops[CollectiveOp::Gather.index()].bytes, 0);
    }

    #[test]
    fn loopback_not_counted() {
        let mut world = World::new(1, NetModel::ideal());
        let mut eps = world.take_endpoints();
        let mut e = eps.pop().unwrap();
        e.send(0, Arc::new(vec![1, 2, 3]), CollectiveOp::Gather).unwrap();
        assert_eq!(&*e.recv(0).unwrap(), &[1, 2, 3]);
        assert_eq!(world.bytes_sent(), 0);
        assert_eq!(world.stats.max_rank_bytes(), 0);
    }

    #[test]
    fn messages_ordered_per_pair() {
        let mut world = World::new(2, NetModel::ideal());
        let mut eps = world.take_endpoints();
        let e1 = eps.pop().unwrap();
        let e0 = eps.pop().unwrap();
        let got = run_concurrent(vec![
            Box::new(move || {
                let mut e0 = e0;
                for i in 0..100u8 {
                    e0.send(1, Arc::new(vec![i]), CollectiveOp::Barrier).unwrap();
                }
                Vec::new()
            }) as Box<dyn FnOnce() -> Vec<u8> + Send>,
            Box::new(move || {
                let mut e1 = e1;
                (0..100).map(|_| e1.recv(0).unwrap()[0]).collect()
            }),
        ]);
        assert_eq!(got[1], (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn dropped_peer_is_an_error_not_a_panic() {
        let mut world = World::new(2, NetModel::ideal());
        let mut eps = world.take_endpoints();
        let e1 = eps.pop().unwrap();
        let mut e0 = eps.pop().unwrap();
        drop(e1); // rank 1 dies before communicating
        let err = e0.recv(1).unwrap_err();
        assert!(matches!(err, CommError::PeerLost { peer: 1 }));
        let err = e0
            .send(1, Arc::new(vec![0u8; 4]), CollectiveOp::Allreduce)
            .unwrap_err();
        assert!(matches!(err, CommError::PeerLost { peer: 1 }));
        assert_eq!(err.to_string(), "rank 1 lost (endpoint dropped mid-collective)");
    }

    #[test]
    fn collective_algo_parses() {
        assert_eq!("auto".parse::<CollectiveAlgo>().unwrap(), CollectiveAlgo::Auto);
        assert_eq!("STAR".parse::<CollectiveAlgo>().unwrap(), CollectiveAlgo::Star);
        assert_eq!("ring".parse::<CollectiveAlgo>().unwrap(), CollectiveAlgo::Ring);
        assert_eq!("tree".parse::<CollectiveAlgo>().unwrap(), CollectiveAlgo::Tree);
        assert!("butterfly".parse::<CollectiveAlgo>().is_err());
        assert_eq!(CollectiveAlgo::default(), CollectiveAlgo::Auto);
        assert_eq!(CollectiveAlgo::Ring.as_str(), "ring");
    }
}
