//! Deterministic fault injection and the recovery policy (ISSUE 10).
//!
//! Distributed fault tolerance is only trustworthy if every failure
//! mode is a **reproducible test case**, not a flake. This module
//! provides the two halves of that story:
//!
//! * [`FaultyTransport`] wraps any [`Transport`] (the in-process channel
//!   mesh or the TCP/UDS socket transport) and executes a [`FaultPlan`]:
//!   kill a rank at its n-th transport operation, delay an operation, or
//!   tear a frame in half. Operation counts are **cumulative across the
//!   whole run** (they survive world re-formation), so a fault fires
//!   exactly once at a deterministic point and a recovered retry of the
//!   same window does not re-trigger it — exactly how a real crashed
//!   process behaves.
//! * [`RecoveryPolicy`] is the knob the recovery drivers in
//!   [`crate::cluster::runner`] and [`crate::cluster::multiproc`]
//!   consume: how many restarts a run may spend, and the base of the
//!   bounded exponential backoff between them.
//!
//! Because every rank's sequence of transport operations is fixed per
//! (collective algorithm, rank count, schedule), `at_op` indices are
//! deterministic: probe a clean run with [`FaultPlan::ops`] once, then
//! aim faults at any epoch of any rank by arithmetic. The property
//! suite in `rust/tests/fault_recovery.rs` does exactly that.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use crate::cluster::comm::{Bytes, CommError, Rank, Transport};

/// What an injected fault does when it fires.
#[derive(Clone, Debug)]
pub enum FaultKind {
    /// The victim rank "dies": the firing operation and every later
    /// operation on its transport return [`CommError::PeerLost`] naming
    /// the victim itself. The rank's driver errors out and drops its
    /// endpoint, so peers observe a genuine mid-collective peer loss —
    /// the same cascade a crashed process produces.
    Kill,
    /// Stall the operation for the given duration, then proceed — a
    /// slow or hiccuping peer. Under a receive deadline
    /// (`SOMOCLU_COMM_TIMEOUT_SECS`) a long enough delay surfaces on
    /// the other side as [`CommError::Timeout`].
    Delay(Duration),
    /// Truncate an outgoing payload to half its bytes. The receiving
    /// collective sees a wrong-length payload and raises
    /// [`CommError::Protocol`] — the corrupted-frame failure mode.
    /// Matching a receive operation is a no-op (frames tear on send).
    TornFrame,
}

/// One scheduled fault: fire `kind` on `victim`'s `at_op`-th transport
/// operation (sends and receives counted together, 0-based, cumulative
/// across world re-formations).
#[derive(Clone, Debug)]
pub struct FaultSpec {
    pub victim: Rank,
    pub at_op: u64,
    pub kind: FaultKind,
}

struct FaultState {
    spec: FaultSpec,
    fired: AtomicBool,
}

/// A reproducible schedule of faults plus live per-rank operation
/// counters. Build one, share it (`Arc`) with every [`FaultyTransport`]
/// of a run — typically via
/// [`SomSession::set_fault_plan`](crate::session::SomSession::set_fault_plan),
/// which makes the cluster runner wrap every rank's transport.
pub struct FaultPlan {
    faults: Vec<FaultState>,
    ops: Vec<AtomicU64>,
}

impl FaultPlan {
    /// An empty plan (pure observation: counts operations, injects
    /// nothing) for a world of `ranks` ranks.
    pub fn observe(ranks: usize) -> Self {
        FaultPlan {
            faults: Vec::new(),
            ops: (0..ranks).map(|_| AtomicU64::new(0)).collect(),
        }
    }

    /// Add a kill: `victim` dies at its `at_op`-th transport operation.
    pub fn kill(mut self, victim: Rank, at_op: u64) -> Self {
        self.faults.push(FaultState {
            spec: FaultSpec { victim, at_op, kind: FaultKind::Kill },
            fired: AtomicBool::new(false),
        });
        self
    }

    /// Add a stall of `dur` at `victim`'s `at_op`-th operation.
    pub fn delay(mut self, victim: Rank, at_op: u64, dur: Duration) -> Self {
        self.faults.push(FaultState {
            spec: FaultSpec { victim, at_op, kind: FaultKind::Delay(dur) },
            fired: AtomicBool::new(false),
        });
        self
    }

    /// Add a torn frame: `victim`'s `at_op`-th operation, if a send,
    /// transmits only half its payload bytes.
    pub fn torn_frame(mut self, victim: Rank, at_op: u64) -> Self {
        self.faults.push(FaultState {
            spec: FaultSpec { victim, at_op, kind: FaultKind::TornFrame },
            fired: AtomicBool::new(false),
        });
        self
    }

    /// One pseudo-random kill derived from `seed`: victim and operation
    /// index are a pure function of the seed (splitmix64), so a seed IS
    /// a reproducible failure scenario. `max_op` bounds the operation
    /// index (probe it with an [`observe`](Self::observe) run).
    pub fn seeded_kill(seed: u64, ranks: usize, max_op: u64) -> Self {
        let mut x = seed.wrapping_add(0x9e37_79b9_7f4a_7c15);
        let mut next = move || {
            x = x.wrapping_add(0x9e37_79b9_7f4a_7c15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
            z ^ (z >> 31)
        };
        let victim = (next() % ranks.max(1) as u64) as usize;
        let at_op = next() % max_op.max(1);
        FaultPlan::observe(ranks).kill(victim, at_op)
    }

    /// Cumulative transport operations (sends + receives) performed by
    /// `rank` under this plan — the probe that maps epochs to `at_op`
    /// indices: ops are linear in epochs, so two observation runs of
    /// different lengths recover the per-epoch stride.
    pub fn ops(&self, rank: Rank) -> u64 {
        self.ops.get(rank).map_or(0, |c| c.load(Ordering::Relaxed))
    }

    /// Whether every scheduled fault has fired (a test that injects a
    /// fault should assert this — otherwise the fault aimed past the
    /// end of the run and proved nothing).
    pub fn all_fired(&self) -> bool {
        self.faults.iter().all(|f| f.fired.load(Ordering::Relaxed))
    }

    /// Record one operation by `rank`; returns the fault to apply, if
    /// one matches this exact operation index and has not fired yet.
    fn tick(&self, rank: Rank) -> Option<FaultKind> {
        let op = match self.ops.get(rank) {
            Some(c) => c.fetch_add(1, Ordering::Relaxed),
            None => return None,
        };
        for f in &self.faults {
            if f.spec.victim == rank
                && f.spec.at_op == op
                && !f.fired.swap(true, Ordering::Relaxed)
            {
                return Some(f.spec.kind.clone());
            }
        }
        None
    }
}

/// A [`Transport`] decorator that executes a shared [`FaultPlan`].
/// Wrap any transport before handing it to an
/// [`Endpoint`](crate::cluster::comm::Endpoint); the in-process runner
/// does this automatically for every rank when a session carries a
/// fault plan.
pub struct FaultyTransport {
    rank: Rank,
    inner: Box<dyn Transport>,
    plan: Arc<FaultPlan>,
    /// A fired kill is sticky for this transport instance: the rank is
    /// dead until the world re-forms with a fresh transport.
    dead: bool,
}

impl FaultyTransport {
    /// Wrap `inner` as rank `rank` under `plan`.
    pub fn new(rank: Rank, inner: Box<dyn Transport>, plan: Arc<FaultPlan>) -> Self {
        FaultyTransport { rank, inner, plan, dead: false }
    }

    fn check(&mut self) -> Result<Option<FaultKind>, CommError> {
        if self.dead {
            return Err(CommError::PeerLost { peer: self.rank });
        }
        match self.plan.tick(self.rank) {
            Some(FaultKind::Kill) => {
                self.dead = true;
                Err(CommError::PeerLost { peer: self.rank })
            }
            other => Ok(other),
        }
    }
}

impl Transport for FaultyTransport {
    fn send(&mut self, to: Rank, payload: Arc<Vec<u8>>) -> Result<(), CommError> {
        match self.check()? {
            Some(FaultKind::Delay(d)) => std::thread::sleep(d),
            Some(FaultKind::TornFrame) => {
                let torn = payload[..payload.len() / 2].to_vec();
                return self.inner.send(to, Arc::new(torn));
            }
            _ => {}
        }
        self.inner.send(to, payload)
    }

    fn recv(&mut self, from: Rank) -> Result<Bytes, CommError> {
        if let Some(FaultKind::Delay(d)) = self.check()? {
            std::thread::sleep(d);
        }
        self.inner.recv(from)
    }
}

/// How a training run responds to a communication-typed abort: retry
/// the failed checkpoint window up to `max_restarts` times, sleeping
/// `backoff * 2^k` (capped at 30 s) before the k-th consecutive retry.
/// The default (`max_restarts = 0`) preserves the historical behavior:
/// the first failure surfaces as an error.
///
/// Restarts are a **run-wide budget**, not per-window — a flapping
/// interconnect cannot spin a job forever. A window that completes
/// resets the backoff exponent but not the budget.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct RecoveryPolicy {
    /// Total aborted windows the run may retry before giving up.
    pub max_restarts: usize,
    /// Base sleep before a retry; doubles per consecutive abort.
    pub backoff: Duration,
}

impl Default for RecoveryPolicy {
    fn default() -> Self {
        RecoveryPolicy::none()
    }
}

impl RecoveryPolicy {
    /// No recovery: the first communication failure is fatal.
    pub fn none() -> Self {
        RecoveryPolicy { max_restarts: 0, backoff: Duration::ZERO }
    }

    /// Retry up to `n` times with the default 500 ms base backoff.
    pub fn restarts(n: usize) -> Self {
        RecoveryPolicy { max_restarts: n, backoff: Duration::from_millis(500) }
    }

    /// Override the backoff base.
    pub fn with_backoff(mut self, base: Duration) -> Self {
        self.backoff = base;
        self
    }

    /// The sleep before the `attempt`-th consecutive retry (0-based):
    /// `backoff * 2^attempt`, capped at 30 seconds.
    pub fn backoff_for(&self, attempt: usize) -> Duration {
        const CAP: Duration = Duration::from_secs(30);
        let factor = 1u32 << attempt.min(16) as u32;
        self.backoff.saturating_mul(factor).min(CAP)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::comm::{CollectiveOp, World};
    use crate::cluster::netmodel::NetModel;

    #[test]
    fn backoff_is_bounded_exponential() {
        let p = RecoveryPolicy::restarts(3).with_backoff(Duration::from_millis(100));
        assert_eq!(p.backoff_for(0), Duration::from_millis(100));
        assert_eq!(p.backoff_for(1), Duration::from_millis(200));
        assert_eq!(p.backoff_for(2), Duration::from_millis(400));
        assert_eq!(p.backoff_for(20), Duration::from_secs(30));
        assert_eq!(RecoveryPolicy::none().backoff_for(5), Duration::ZERO);
        assert_eq!(RecoveryPolicy::default(), RecoveryPolicy::none());
    }

    #[test]
    fn seeded_kill_is_a_pure_function_of_the_seed() {
        let a = FaultPlan::seeded_kill(7, 4, 100);
        let b = FaultPlan::seeded_kill(7, 4, 100);
        assert_eq!(a.faults[0].spec.victim, b.faults[0].spec.victim);
        assert_eq!(a.faults[0].spec.at_op, b.faults[0].spec.at_op);
        assert!(a.faults[0].spec.victim < 4);
        assert!(a.faults[0].spec.at_op < 100);
    }

    /// A kill at op N makes the victim's N-th and every later operation
    /// fail as a self-blaming PeerLost, while peers see a genuine
    /// endpoint-drop cascade once the victim's endpoint goes away.
    #[test]
    fn kill_fires_once_at_the_exact_op() {
        let plan = Arc::new(FaultPlan::observe(2).kill(1, 2));
        let mut world = World::new_with_wrapper(2, NetModel::ideal(), &mut |r, t| {
            Box::new(FaultyTransport::new(r, t, plan.clone()))
        });
        let mut eps = world.take_endpoints();
        let mut e1 = eps.pop().unwrap();
        let mut e0 = eps.pop().unwrap();
        // Victim ops 0 and 1 succeed, op 2 kills, op 3 is still dead.
        e1.send(0, Arc::new(vec![1u8]), CollectiveOp::Barrier).unwrap();
        e1.send(0, Arc::new(vec![2u8]), CollectiveOp::Barrier).unwrap();
        let err = e1.send(0, Arc::new(vec![3u8]), CollectiveOp::Barrier).unwrap_err();
        assert!(matches!(err, CommError::PeerLost { peer: 1 }));
        let err = e1.recv(0).unwrap_err();
        assert!(matches!(err, CommError::PeerLost { peer: 1 }));
        assert!(plan.all_fired());
        // Pre-kill sends were delivered; after the victim's endpoint
        // drops, the survivor sees the ordinary PeerLost cascade.
        assert_eq!(&*e0.recv(1).unwrap(), &[1u8]);
        assert_eq!(&*e0.recv(1).unwrap(), &[2u8]);
        drop(e1);
        assert!(matches!(e0.recv(1).unwrap_err(), CommError::PeerLost { peer: 1 }));
        // Op accounting: the victim ticked 4 ops, the survivor 3 recvs.
        assert_eq!(plan.ops(1), 4);
        assert_eq!(plan.ops(0), 3);
    }

    #[test]
    fn torn_frame_halves_the_payload_once() {
        let plan = Arc::new(FaultPlan::observe(2).torn_frame(0, 0));
        let mut world = World::new_with_wrapper(2, NetModel::ideal(), &mut |r, t| {
            Box::new(FaultyTransport::new(r, t, plan.clone()))
        });
        let mut eps = world.take_endpoints();
        let mut e1 = eps.pop().unwrap();
        let mut e0 = eps.pop().unwrap();
        e0.send(1, Arc::new(vec![9u8; 8]), CollectiveOp::Allreduce).unwrap();
        e0.send(1, Arc::new(vec![9u8; 8]), CollectiveOp::Allreduce).unwrap();
        assert_eq!(e1.recv(0).unwrap().len(), 4, "torn frame arrives halved");
        assert_eq!(e1.recv(0).unwrap().len(), 8, "later frames intact");
        assert!(plan.all_fired());
    }

    #[test]
    fn observation_plan_injects_nothing() {
        let plan = Arc::new(FaultPlan::observe(2));
        let mut world = World::new_with_wrapper(2, NetModel::ideal(), &mut |r, t| {
            Box::new(FaultyTransport::new(r, t, plan.clone()))
        });
        let mut eps = world.take_endpoints();
        let mut e1 = eps.pop().unwrap();
        let mut e0 = eps.pop().unwrap();
        e0.send(1, Arc::new(vec![5u8; 3]), CollectiveOp::Gather).unwrap();
        assert_eq!(&*e1.recv(0).unwrap(), &[5u8; 3]);
        assert!(plan.all_fired(), "vacuously true with no faults");
        assert_eq!(plan.ops(0), 1);
        assert_eq!(plan.ops(1), 1);
    }
}
