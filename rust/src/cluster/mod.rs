//! Distributed runtime — the paper's MPI layer (§3.2).
//!
//! The paper's communication structure is deliberately simple: data is
//! sharded once ("we can distribute equally sized parts of the data to
//! each node, without any further communication of training data later
//! on"); each epoch the slaves send local weight updates to the master,
//! the master accumulates, and the new code book is broadcast.
//!
//! We reproduce that structure — and improve on its star-shaped
//! collectives — behind a pluggable byte [`Transport`]:
//!
//! * [`comm`] — ranks, per-rank/per-op traffic accounting, the
//!   `Transport` trait, and the in-process channel mesh ([`World`])
//!   that simulates P ranks on threads with an optional
//!   latency/bandwidth network model injecting transfer delay (the
//!   Fig. 8 harness; see DESIGN.md §3).
//! * [`allreduce`] — the collectives: star (the paper's literal
//!   master/slave pattern), bandwidth-optimal segmented ring
//!   allreduce, and binomial-tree broadcast/reduce for small payloads,
//!   selected by `--collective` (auto picks by payload size).
//! * [`transport_net`] — length-prefixed TCP/UDS socket transport with
//!   a rendezvous bootstrap, so N real OS processes form one world.
//! * [`multiproc`] — the per-process driver behind
//!   `--rank`/`--peers`/`--listen`/`--connect`.
//! * [`runner`] — the shared per-rank training loop and the in-process
//!   window/checkpoint driver.
//! * [`fault`] — deterministic fault injection ([`FaultPlan`] /
//!   [`FaultyTransport`]) and the [`RecoveryPolicy`] that turns a lost
//!   rank into a bounded, byte-identical window retry instead of a
//!   dead job.

pub mod allreduce;
pub mod comm;
pub mod fault;
pub mod multiproc;
pub mod netmodel;
pub mod runner;
pub mod transport_net;

pub use comm::{CollectiveAlgo, CommStats, Endpoint, OpTotals, Rank, Transport, World};
pub use fault::{FaultKind, FaultPlan, FaultSpec, FaultyTransport, RecoveryPolicy};
pub use multiproc::NetOptions;
pub use netmodel::NetModel;
pub use runner::EpochAborted;
pub use transport_net::NetTransport;
