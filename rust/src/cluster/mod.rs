//! Simulated distributed runtime — the paper's MPI layer (§3.2).
//!
//! The paper's communication structure is deliberately simple: data is
//! sharded once ("we can distribute equally sized parts of the data to
//! each node, without any further communication of training data later
//! on"); each epoch the slaves send local weight updates to the master,
//! the master accumulates, and the new code book is broadcast.
//!
//! We reproduce that structure with one OS thread per rank connected by
//! message channels. Every message is byte-counted, and an optional
//! latency/bandwidth network model injects transfer delay, so the Fig. 8
//! scaling experiment preserves the compute-to-communication ratio that
//! makes the paper's scaling near-linear (see DESIGN.md §3).

pub mod allreduce;
pub mod comm;
pub mod netmodel;
pub mod runner;

pub use comm::{CollectiveMsg, Endpoint, Rank, World};
pub use netmodel::NetModel;
