//! The unified `Som` session API — one builder-driven facade over
//! resident, streamed, and cluster training, incremental epochs, batch
//! inference, and checkpoint/resume.
//!
//! Historically the crate grew four parallel entry points
//! (`api::train`, `coordinator::train::train_stream`,
//! `cluster::runner::train_cluster`, `train_cluster_stream`) with
//! divergent argument lists, no inference path, and no way to stop and
//! resume a long run. This module replaces all of them with two types:
//!
//! * [`SomBuilder`] (from [`Som::builder`]) — one validated construction
//!   path for every knob: map geometry, schedules, kernel, threads,
//!   ranks, streaming/chunking, I/O backend, checkpoint policy.
//! * [`SomSession`] — owns the codebook and the cooling cursor, and
//!   exposes the whole lifecycle: [`fit`](SomSession::fit) /
//!   [`fit_source`](SomSession::fit_source) /
//!   [`fit_cluster`](SomSession::fit_cluster) /
//!   [`fit_cluster_stream`](SomSession::fit_cluster_stream) for
//!   training, [`step_epoch`](SomSession::step_epoch) for incremental
//!   (online) training, [`bmu`](SomSession::bmu) /
//!   [`project`](SomSession::project) for inference on held-out data,
//!   and [`save_checkpoint`](SomSession::save_checkpoint) /
//!   [`Som::resume`] for interruptible long runs.
//!
//! The session constructs its kernel **once** and calls the kernel's
//! `epoch_begin` before each epoch's chunk loop, so per-epoch caches
//! (codebook norms, sparse transpose, device uploads) are reused across
//! every chunk of every epoch — unlike the legacy `train_one_epoch`,
//! which rebuilt the kernel on each call.
//!
//! Resume is **bit-exact**: a run checkpointed at epoch `k` and resumed
//! produces the same codebook bits and BMUs as the same run left
//! uninterrupted, because a checkpoint stores the exact f32 weights plus
//! every schedule input, and epoch `e`'s update depends only on those
//! (radius/scale are evaluated at the *absolute* epoch index). The one
//! requirement is to keep the same chunking: different `chunk_rows`
//! reassociate f32 sums (BMUs still match; weights differ in the last
//! ulps).
//!
//! # Example
//!
//! ```
//! use somoclu::api::DataInput;
//! use somoclu::session::Som;
//!
//! let data: Vec<f32> = (0..60).map(|i| (i % 7) as f32 * 0.1).collect();
//! let mut session = Som::builder()
//!     .map_size(4, 4)
//!     .epochs(3)
//!     .radius0(2.0)
//!     .threads(2)
//!     .build()
//!     .unwrap();
//! let res = session
//!     .fit(DataInput::BorrowedF32 { data: &data, dim: 6 })
//!     .unwrap();
//! assert_eq!(res.bmus.len(), 10);
//!
//! // The trained session serves BMU lookups on held-out vectors.
//! let (node, dist) = session.bmu(&data[0..6]).unwrap();
//! assert!(node < 16 && dist.is_finite());
//! let mapped = session
//!     .project(DataInput::BorrowedF32 { data: &data, dim: 6 })
//!     .unwrap();
//! assert_eq!(mapped.len(), 10);
//! ```
//!
//! Checkpoint and resume (paths elided):
//!
//! ```no_run
//! # use somoclu::api::DataInput;
//! # use somoclu::session::Som;
//! # let data: Vec<f32> = vec![0.0; 60];
//! let mut session = Som::builder().map_size(4, 4).epochs(10).build().unwrap();
//! for _ in 0..5 {
//!     session.step_epoch(DataInput::BorrowedF32 { data: &data, dim: 6 }).unwrap();
//! }
//! session.save_checkpoint("half.somc").unwrap();
//! // ... later, possibly in another process:
//! let mut resumed = Som::resume("half.somc").unwrap();
//! assert_eq!(resumed.epoch(), 5);
//! resumed.fit(DataInput::BorrowedF32 { data: &data, dim: 6 }).unwrap();
//! ```

use std::collections::{HashSet, VecDeque};
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::api::DataInput;
use crate::error::SomError;
use crate::cluster::comm::CollectiveAlgo;
use crate::cluster::fault::{FaultPlan, RecoveryPolicy};
use crate::cluster::multiproc::NetOptions;
use crate::cluster::netmodel::NetModel;
use crate::cluster::runner::{ClusterData, ClusterReport, StreamInput};
use crate::coordinator::config::{Initialization, IoMode, TrainConfig};
use crate::coordinator::train::{
    init_codebook, init_codebook_with_data, make_kernel, EpochStats, TrainResult,
};
use crate::io::output::{OutputWriter, SnapshotLevel};
use crate::io::stream::{DataSource, InMemorySource};
use crate::kernels::{DataShard, EpochAccum, KernelType, TrainingKernel};
use crate::som::{umatrix, Codebook, Cooling, Grid, GridType, MapType, Neighborhood};
use crate::sparse::Csr;

/// Entry-point namespace for the session API: [`Som::builder`] starts a
/// fresh configuration, [`Som::resume`] rebuilds a session from a
/// `SOMC` checkpoint.
pub struct Som;

impl Som {
    /// Start building a new training session (all paper defaults).
    pub fn builder() -> SomBuilder {
        SomBuilder::default()
    }

    /// Rebuild a session from a checkpoint written by
    /// [`SomSession::save_checkpoint`] (or the CLI's
    /// `--checkpoint-every`): the codebook weights are restored
    /// bit-exactly and the epoch cursor picks up where the save left
    /// off, so finishing the run matches an uninterrupted one.
    ///
    /// Runtime knobs (threads, ranks, chunking, prefetch, I/O backend)
    /// are not stored in checkpoints; apply them to the returned session
    /// with the `set_*` methods before fitting.
    ///
    /// # Errors
    ///
    /// [`SomError::Checkpoint`] for unreadable/corrupt files,
    /// [`SomError::Config`] if the stored configuration no longer
    /// validates.
    pub fn resume<P: AsRef<Path>>(path: P) -> Result<SomSession, SomError> {
        let ck = crate::io::checkpoint::load(path)?;
        let mut session = SomBuilder::default().config(ck.config).build()?;
        session
            .install_codebook(ck.codebook)
            .map_err(|e| SomError::checkpoint(format!("{e:#}")))?;
        session.epoch = ck.epoch;
        Ok(session)
    }
}

/// Builder for [`SomSession`] — the single validated construction path
/// for every training knob. Obtain one from [`Som::builder`]; finish
/// with [`build`](SomBuilder::build).
#[derive(Clone)]
pub struct SomBuilder {
    cfg: TrainConfig,
    initial: Option<Codebook>,
    net: NetModel,
    checkpoint: Option<(usize, PathBuf)>,
    keep_last: usize,
    recovery: RecoveryPolicy,
}

impl Default for SomBuilder {
    fn default() -> Self {
        SomBuilder {
            cfg: TrainConfig::default(),
            initial: None,
            net: NetModel::ideal(),
            checkpoint: None,
            keep_last: 0,
            recovery: RecoveryPolicy::none(),
        }
    }
}

impl SomBuilder {
    /// Replace the whole configuration at once (the escape hatch for
    /// callers that already hold a [`TrainConfig`], e.g. the CLI and the
    /// legacy shims). Individual setters below override on top.
    pub fn config(mut self, cfg: TrainConfig) -> Self {
        self.cfg = cfg;
        self
    }

    /// Map geometry: `rows x cols` nodes (paper `-y` / `-x`).
    pub fn map_size(mut self, rows: usize, cols: usize) -> Self {
        self.cfg.rows = rows;
        self.cfg.cols = cols;
        self
    }

    /// Grid layout (paper `-g`): square or hexagonal.
    pub fn grid_type(mut self, g: GridType) -> Self {
        self.cfg.grid_type = g;
        self
    }

    /// Map topology (paper `-m`): planar or toroid.
    pub fn map_type(mut self, m: MapType) -> Self {
        self.cfg.map_type = m;
        self
    }

    /// Neighborhood function (paper `-n` / `-p`).
    pub fn neighborhood(mut self, n: Neighborhood) -> Self {
        self.cfg.neighborhood = n;
        self
    }

    /// Total training epochs (paper `-e`).
    pub fn epochs(mut self, n: usize) -> Self {
        self.cfg.epochs = n;
        self
    }

    /// Start radius (paper `-r`); default is half the smaller map side.
    pub fn radius0(mut self, r: f32) -> Self {
        self.cfg.radius0 = Some(r);
        self
    }

    /// Final radius (paper `-R`).
    pub fn radius_n(mut self, r: f32) -> Self {
        self.cfg.radius_n = r;
        self
    }

    /// Radius cooling strategy (paper `-t`).
    pub fn radius_cooling(mut self, c: Cooling) -> Self {
        self.cfg.radius_cooling = c;
        self
    }

    /// Start learning rate (paper `-l`).
    pub fn scale0(mut self, s: f32) -> Self {
        self.cfg.scale0 = s;
        self
    }

    /// Final learning rate (paper `-L`).
    pub fn scale_n(mut self, s: f32) -> Self {
        self.cfg.scale_n = s;
        self
    }

    /// Learning-rate cooling strategy (paper `-T`).
    pub fn scale_cooling(mut self, c: Cooling) -> Self {
        self.cfg.scale_cooling = c;
        self
    }

    /// Training kernel (paper `-k`): dense CPU, sparse CPU, accel, hybrid.
    pub fn kernel(mut self, k: KernelType) -> Self {
        self.cfg.kernel = k;
        self
    }

    /// Worker threads per process/rank (OpenMP analog).
    pub fn threads(mut self, n: usize) -> Self {
        self.cfg.threads = n;
        self
    }

    /// Simulated MPI ranks; `> 1` routes [`SomSession::fit`] through the
    /// cluster runner (`mpirun -np N` analog).
    pub fn ranks(mut self, n: usize) -> Self {
        self.cfg.ranks = n;
        self
    }

    /// Codebook initialization scheme (random or PCA).
    pub fn initialization(mut self, i: Initialization) -> Self {
        self.cfg.initialization = i;
        self
    }

    /// RNG seed for codebook initialization.
    pub fn seed(mut self, s: u64) -> Self {
        self.cfg.seed = s;
        self
    }

    /// Stream epochs in windows of `n` data rows (out-of-core training;
    /// 0 = whole pass per chunk).
    pub fn chunk_rows(mut self, n: usize) -> Self {
        self.cfg.chunk_rows = n;
        self
    }

    /// Double-buffered chunk read-ahead for file-backed sources.
    pub fn prefetch(mut self, on: bool) -> Self {
        self.cfg.prefetch = on;
        self
    }

    /// Streaming I/O backend for binary containers (`--io`).
    pub fn io_mode(mut self, mode: IoMode) -> Self {
        self.cfg.io_mode = mode;
        self
    }

    /// Cluster collective algorithm (`--collective`): auto (default),
    /// star, ring, or tree. See [`CollectiveAlgo`].
    pub fn collective(mut self, algo: CollectiveAlgo) -> Self {
        self.cfg.collective = algo;
        self
    }

    /// Interconnect model for the simulated cluster (default: ideal).
    pub fn net(mut self, net: NetModel) -> Self {
        self.net = net;
        self
    }

    /// Start from an explicit codebook instead of random/PCA init (the
    /// paper's `-c FILE`; also the warm-start retraining path).
    pub fn initial_codebook(mut self, cb: Codebook) -> Self {
        self.initial = Some(cb);
        self
    }

    /// Save a `SOMC` checkpoint to `<prefix>.epoch<k>.somc` after every
    /// `every` completed epochs (0 disables). Cluster fits checkpoint at
    /// the same cadence by training in `every`-epoch windows.
    pub fn checkpoint_every<P: AsRef<Path>>(mut self, every: usize, prefix: P) -> Self {
        self.checkpoint = if every > 0 {
            Some((every, prefix.as_ref().to_path_buf()))
        } else {
            None
        };
        self
    }

    /// Retention for [`checkpoint_every`](Self::checkpoint_every)
    /// checkpoints (the CLI's `--keep-last`): after each save, delete
    /// the oldest checkpoints this session wrote until at most `n`
    /// remain. `0` (the default) keeps everything. Checkpoints pinned
    /// via [`SomSession::set_checkpoint_protected`] — e.g. the one a
    /// daemon is currently serving — are never deleted and do not count
    /// against `n`.
    pub fn checkpoint_keep_last(mut self, n: usize) -> Self {
        self.keep_last = n;
        self
    }

    /// Automatic rank-failure recovery for cluster fits (the CLI's
    /// `--recover`): when a rank is lost mid-window, survivors abort the
    /// window at the epoch fence, the session rewinds to the last
    /// completed window, and the world is re-formed and retried — up to
    /// [`RecoveryPolicy::max_restarts`] times with exponential backoff.
    /// A recovered run produces byte-identical weights and BMUs to an
    /// uninterrupted one. The default ([`RecoveryPolicy::none`])
    /// disables recovery: the first lost rank fails the fit.
    pub fn recovery(mut self, policy: RecoveryPolicy) -> Self {
        self.recovery = policy;
        self
    }

    /// Validate the configuration and produce a ready [`SomSession`].
    /// Rejects inconsistent settings (zero-sized map, zero epochs,
    /// radius growing over time, mmap + prefetch, an initial codebook
    /// whose node count does not match the map, ...) with a typed
    /// [`SomError::Config`].
    pub fn build(self) -> Result<SomSession, SomError> {
        self.cfg.validate()?;
        let grid = self.cfg.grid();
        let mut session = SomSession {
            cfg: self.cfg,
            grid,
            net: self.net,
            kernel: None,
            codebook: None,
            epoch: 0,
            history: Vec::new(),
            last_bmus: Vec::new(),
            checkpoint: self
                .checkpoint
                .map(|(every, prefix)| CheckpointPolicy::new(every, prefix, self.keep_last)),
            recovery: self.recovery,
            fault_plan: None,
        };
        if let Some(cb) = self.initial {
            session
                .install_codebook(cb)
                .map_err(|e| SomError::config(format!("{e:#}")))?;
        }
        Ok(session)
    }
}

/// Path of the `k`-th numbered checkpoint for an output prefix:
/// `<prefix>.epoch<k>.somc` (what `--checkpoint-every` writes).
pub fn checkpoint_path<P: AsRef<Path>>(prefix: P, epoch: usize) -> PathBuf {
    PathBuf::from(format!("{}.epoch{epoch}.somc", prefix.as_ref().display()))
}

/// The session's periodic-checkpoint policy: cadence, path prefix, and
/// GC retention. Owned by [`SomSession`]; configured through
/// [`SomBuilder::checkpoint_every`] /
/// [`SomBuilder::checkpoint_keep_last`] or the matching `set_*` methods.
struct CheckpointPolicy {
    /// Save after every `every` completed epochs.
    every: usize,
    /// `<prefix>.epoch<k>.somc` naming (see [`checkpoint_path`]).
    prefix: PathBuf,
    /// Retain at most this many non-protected checkpoints (0 = all).
    keep_last: usize,
    /// Paths this session wrote, oldest first — the GC candidate set.
    /// Pre-existing files from earlier runs are never touched.
    written: VecDeque<PathBuf>,
    /// Shared pin set: paths in here survive GC unconditionally (the
    /// serving daemon pins whatever checkpoint is currently hot).
    protected: Option<Arc<Mutex<HashSet<PathBuf>>>>,
}

impl CheckpointPolicy {
    fn new(every: usize, prefix: PathBuf, keep_last: usize) -> Self {
        CheckpointPolicy {
            every,
            prefix,
            keep_last,
            written: VecDeque::new(),
            protected: None,
        }
    }

    fn is_protected(&self, path: &Path) -> bool {
        match &self.protected {
            Some(set) => match set.lock() {
                Ok(guard) => guard.contains(path),
                // A poisoned pin set means some serving thread panicked;
                // err on the side of never deleting.
                Err(_) => true,
            },
            None => false,
        }
    }

    /// Delete the oldest non-protected checkpoints until at most
    /// `keep_last` remain. Best-effort: a failed unlink (already gone,
    /// permissions) is skipped, never fatal to training.
    fn gc(&mut self) {
        if self.keep_last == 0 {
            return;
        }
        let unprotected = self
            .written
            .iter()
            .filter(|p| !self.is_protected(p))
            .count();
        let mut to_delete = unprotected.saturating_sub(self.keep_last);
        let mut survivors = VecDeque::with_capacity(self.written.len());
        while to_delete > 0 {
            let old = self.written.pop_front().expect("counted above");
            if self.is_protected(&old) {
                survivors.push_back(old);
                continue;
            }
            let _ = std::fs::remove_file(&old);
            to_delete -= 1;
        }
        survivors.extend(self.written.drain(..));
        self.written = survivors;
    }
}

/// Materialize a [`DataInput`] as a borrowed [`DataShard`], converting
/// f64 input into `tmp` (the R/MATLAB duplication the Fig. 7 harness
/// measures — the copy lives for the duration of the borrow).
fn materialize<'a>(input: DataInput<'a>, tmp: &'a mut Vec<f32>) -> DataShard<'a> {
    match input {
        DataInput::BorrowedF32 { data, dim } => DataShard::Dense { data, dim },
        DataInput::ConvertedF64 { data, dim } => {
            tmp.clear();
            tmp.extend(data.iter().map(|&v| v as f32));
            DataShard::Dense {
                data: tmp.as_slice(),
                dim,
            }
        }
        DataInput::Sparse(m) => DataShard::Sparse(m.view()),
    }
}

/// Copy a borrowed shard into the owned form the cluster runner shards
/// across rank threads.
fn owned_cluster_data(shard: DataShard<'_>) -> ClusterData {
    match shard {
        DataShard::Dense { data, dim } => ClusterData::Dense {
            data: data.to_vec(),
            dim,
        },
        DataShard::Sparse(m) => ClusterData::Sparse(Csr {
            rows: m.rows,
            cols: m.cols,
            indptr: m.indptr.to_vec(),
            indices: m.indices.to_vec(),
            values: m.values.to_vec(),
        }),
    }
}

/// An owning training session: the codebook, the cooling cursor, the
/// kernel (constructed once), and the checkpoint policy. See the
/// [module docs](self) for the lifecycle and examples.
pub struct SomSession {
    cfg: TrainConfig,
    grid: Grid,
    net: NetModel,
    kernel: Option<Box<dyn TrainingKernel>>,
    codebook: Option<Codebook>,
    /// Completed epochs (the next epoch to run).
    epoch: usize,
    history: Vec<EpochStats>,
    last_bmus: Vec<u32>,
    checkpoint: Option<CheckpointPolicy>,
    /// Rank-failure recovery budget for cluster fits (see
    /// [`SomBuilder::recovery`]).
    recovery: RecoveryPolicy,
    /// Deterministic fault plan injected into the simulated cluster's
    /// transports — the chaos-testing hook (see
    /// [`set_fault_plan`](Self::set_fault_plan)).
    fault_plan: Option<Arc<FaultPlan>>,
}

impl SomSession {
    // -- accessors ----------------------------------------------------

    /// The session's configuration (read-only; use the `set_*` methods
    /// for the runtime knobs that may change between resume and fit).
    pub fn config(&self) -> &TrainConfig {
        &self.cfg
    }

    /// The map geometry.
    pub fn grid(&self) -> &Grid {
        &self.grid
    }

    /// Completed epochs — the cooling cursor (the next epoch trains at
    /// this absolute index).
    pub fn epoch(&self) -> usize {
        self.epoch
    }

    /// Total epochs the schedules are defined over (`-e`).
    pub fn epochs_total(&self) -> usize {
        self.cfg.epochs
    }

    /// Epochs left until the schedule completes (0 = fully trained).
    pub fn remaining_epochs(&self) -> usize {
        self.cfg.epochs.saturating_sub(self.epoch)
    }

    /// The owned codebook, once initialized (after the first fit/step,
    /// an explicit initial codebook, or a resume).
    pub fn codebook(&self) -> Option<&Codebook> {
        self.codebook.as_ref()
    }

    /// BMUs of the most recent training epoch (file row order).
    pub fn last_bmus(&self) -> &[u32] {
        &self.last_bmus
    }

    /// Per-epoch stats accumulated by this session (resumed sessions
    /// start empty — earlier epochs ran in another process).
    pub fn history(&self) -> &[EpochStats] {
        &self.history
    }

    /// U-matrix of the current codebook, or `None` before initialization.
    pub fn umatrix(&self) -> Option<Vec<f32>> {
        self.codebook
            .as_ref()
            .map(|cb| umatrix::umatrix(&self.grid, cb, self.cfg.threads))
    }

    /// `(hits, misses)` of the kernel's `epoch_begin` cache across this
    /// session's chunk calls, when the kernel tracks them. A session
    /// driving chunked epochs reports zero misses — the regression guard
    /// for the kernel-rebuild-per-call bug the legacy `train_one_epoch`
    /// had.
    pub fn kernel_cache_stats(&self) -> Option<(u64, u64)> {
        self.kernel.as_ref().and_then(|k| k.epoch_cache_stats())
    }

    // -- runtime knobs (resume does not store these) ------------------

    /// Set worker threads per process/rank. Takes effect immediately:
    /// the kernel bakes its thread count in at construction, so an
    /// already-built kernel is dropped and rebuilt on the next epoch
    /// (results are thread-count invariant; this is purely a
    /// performance knob — note it also resets
    /// [`kernel_cache_stats`](Self::kernel_cache_stats)).
    pub fn set_threads(&mut self, n: usize) {
        self.cfg.threads = n.max(1);
        self.kernel = None;
    }

    /// Set simulated cluster ranks (affects the `fit_cluster*` paths and
    /// [`fit`](Self::fit) dispatch).
    pub fn set_ranks(&mut self, n: usize) {
        self.cfg.ranks = n;
    }

    /// Set the streaming window in data rows (0 = whole pass).
    pub fn set_chunk_rows(&mut self, n: usize) {
        self.cfg.chunk_rows = n;
    }

    /// Enable/disable double-buffered chunk read-ahead.
    pub fn set_prefetch(&mut self, on: bool) {
        self.cfg.prefetch = on;
    }

    /// Set the streaming I/O backend for binary containers.
    pub fn set_io_mode(&mut self, mode: IoMode) {
        self.cfg.io_mode = mode;
    }

    /// Set the cluster collective algorithm. Like threads/ranks, a
    /// runtime knob (not stored in checkpoints); keep it the same for
    /// every window of one run — switching mid-run reassociates f32
    /// sums across the checkpoint boundary.
    pub fn set_collective(&mut self, algo: CollectiveAlgo) {
        self.cfg.collective = algo;
    }

    /// Set the interim snapshot level (the CLI `-s` behavior; consumed
    /// by drivers that write snapshots per epoch).
    pub fn set_snapshot(&mut self, level: SnapshotLevel) {
        self.cfg.snapshot = level;
    }

    /// Set the cluster interconnect model.
    pub fn set_net(&mut self, net: NetModel) {
        self.net = net;
    }

    /// Set (or disable, with `every` = 0) the checkpoint policy; see
    /// [`SomBuilder::checkpoint_every`]. An existing policy's retention
    /// and pin set carry over; the written-checkpoint GC ledger resets.
    pub fn set_checkpoint_every<P: AsRef<Path>>(&mut self, every: usize, prefix: P) {
        let (keep_last, protected) = match self.checkpoint.take() {
            Some(p) => (p.keep_last, p.protected),
            None => (0, None),
        };
        if every > 0 {
            let mut policy = CheckpointPolicy::new(every, prefix.as_ref().to_path_buf(), keep_last);
            policy.protected = protected;
            self.checkpoint = Some(policy);
        }
    }

    /// Set checkpoint GC retention (the CLI's `--keep-last`; see
    /// [`SomBuilder::checkpoint_keep_last`]). No effect unless a
    /// checkpoint policy is active — call
    /// [`set_checkpoint_every`](Self::set_checkpoint_every) first.
    pub fn set_checkpoint_keep_last(&mut self, n: usize) {
        if let Some(p) = self.checkpoint.as_mut() {
            p.keep_last = n;
        }
    }

    /// Set the rank-failure recovery policy for cluster fits (the CLI's
    /// `--recover`; see [`SomBuilder::recovery`]). A runtime knob, not
    /// stored in checkpoints — resumed sessions default to no recovery.
    pub fn set_recovery(&mut self, policy: RecoveryPolicy) {
        self.recovery = policy;
    }

    /// Install a deterministic fault plan: every transport of the
    /// simulated cluster world is wrapped in a
    /// [`FaultyTransport`](crate::cluster::fault::FaultyTransport) that
    /// executes the plan (kill rank *k* at collective op *n*, delay,
    /// torn frame). This is the chaos-testing hook behind the fault
    /// injection test suite; production runs leave it unset. `None`
    /// removes a previously installed plan.
    pub fn set_fault_plan(&mut self, plan: Option<Arc<FaultPlan>>) {
        self.fault_plan = plan;
    }

    /// Install a shared pin set for checkpoint GC: paths present in the
    /// set when GC runs are never deleted (and don't count against
    /// `keep_last`). The serving daemon keeps its currently-hot
    /// checkpoint in here so retention can never unlink the map being
    /// served. No effect unless a checkpoint policy is active.
    pub fn set_checkpoint_protected(&mut self, pins: Arc<Mutex<HashSet<PathBuf>>>) {
        if let Some(p) = self.checkpoint.as_mut() {
            p.protected = Some(pins);
        }
    }

    // -- training -----------------------------------------------------

    /// Train to schedule completion on resident data. With
    /// `ranks > 1` this dispatches through the simulated cluster
    /// (copying the input into per-rank shards); otherwise it streams
    /// the resident buffer in `chunk_rows` windows through the kernel.
    /// Resuming sessions continue from their cursor.
    pub fn fit(&mut self, input: DataInput<'_>) -> Result<TrainResult, SomError> {
        let mut tmp = Vec::new();
        let shard = materialize(input, &mut tmp);
        self.fit_shard(shard)
    }

    /// [`fit`](Self::fit) for callers already holding a [`DataShard`].
    pub fn fit_shard(&mut self, shard: DataShard<'_>) -> Result<TrainResult, SomError> {
        if self.cfg.ranks > 1 {
            let data = owned_cluster_data(shard);
            return self.fit_cluster(data).map(|(res, _)| res);
        }
        let mut source = InMemorySource::new(shard, self.cfg.chunk_rows);
        self.fit_source_with(&mut source, &mut |_| Ok(()))
    }

    /// Train to schedule completion over any [`DataSource`] — the
    /// out-of-core path (single process; for multi-rank streaming use
    /// [`fit_cluster_stream`](Self::fit_cluster_stream)).
    pub fn fit_source(
        &mut self,
        source: &mut dyn DataSource,
    ) -> Result<TrainResult, SomError> {
        self.fit_source_with(source, &mut |_| Ok(()))
    }

    /// [`fit_source`](Self::fit_source) with a per-epoch observer (the
    /// CLI uses it to write interim snapshots, the serving daemon to
    /// stream progress events and honor drain requests): `on_epoch` runs
    /// after every completed epoch with the session borrowed read-only;
    /// an `Err` from it aborts the fit and surfaces unchanged.
    pub fn fit_source_with(
        &mut self,
        source: &mut dyn DataSource,
        on_epoch: &mut dyn FnMut(&SomSession) -> Result<(), SomError>,
    ) -> Result<TrainResult, SomError> {
        self.cfg.validate()?;
        if self.cfg.ranks != 1 {
            return Err(SomError::config(
                "fit_source is single-process; multi-rank streaming goes through \
                 fit_cluster_stream (per-rank file shards)",
            ));
        }
        if source.rows() == 0 {
            return Err(SomError::data("no data rows"));
        }
        let t0 = Instant::now();
        let since = self.history.len();
        let start_epoch = self.epoch;
        self.ensure_codebook_for_source(source)?;
        while self.epoch < self.cfg.epochs {
            self.step_epoch_source(source)?;
            on_epoch(self)?;
        }
        if self.epoch == start_epoch {
            // No epoch ran (schedule already complete): `last_bmus` may
            // describe a *previous* fit's data, so always refresh the
            // mapping against THIS input with a projection pass.
            self.last_bmus = self.project_source(source)?;
        }
        Ok(self.result_snapshot(since, t0.elapsed()))
    }

    /// Run exactly **one** epoch at the cursor on resident data and
    /// advance — incremental/online training. The kernel is constructed
    /// once per session and its `epoch_begin` caches serve every chunk
    /// of every step (see [`kernel_cache_stats`](Self::kernel_cache_stats)).
    /// Stepping past `epochs_total` is allowed: the schedules clamp to
    /// their final values (warm retraining).
    pub fn step_epoch(&mut self, input: DataInput<'_>) -> Result<EpochStats, SomError> {
        let mut tmp = Vec::new();
        let shard = materialize(input, &mut tmp);
        let mut source = InMemorySource::new(shard, self.cfg.chunk_rows);
        self.ensure_codebook_for_source(&mut source)?;
        self.step_epoch_source(&mut source)
    }

    /// [`step_epoch`](Self::step_epoch) over any [`DataSource`].
    pub fn step_epoch_source(
        &mut self,
        source: &mut dyn DataSource,
    ) -> Result<EpochStats, SomError> {
        self.ensure_codebook_for_source(source)?;
        let te = Instant::now();
        let epoch = self.epoch;
        let (radius, scale) = self.schedule_now();
        let mut accum = self.accumulate_epoch(source)?;
        let bmus = std::mem::take(&mut accum.bmus);
        self.apply_epoch_update(&accum);
        let stats = EpochStats {
            epoch,
            radius,
            scale,
            qe: accum.qe_sum / source.rows().max(1) as f64,
            duration: te.elapsed(),
        };
        self.finish_epoch(stats.clone(), bmus)?;
        Ok(stats)
    }

    /// Train to schedule completion across `ranks` simulated nodes on
    /// resident data (the paper's §3.2 exchange). Returns the result
    /// plus the communication report. With a checkpoint policy, training
    /// proceeds in `every`-epoch windows, checkpointing between windows
    /// — so multi-rank runs resume mid-schedule too.
    pub fn fit_cluster(
        &mut self,
        data: ClusterData,
    ) -> Result<(TrainResult, ClusterReport), SomError> {
        let net = self.net.clone();
        Ok(crate::cluster::runner::run_cluster(self, data, net)?)
    }

    /// Train to schedule completion across `ranks` simulated nodes with
    /// no resident copy: every rank streams its own disjoint row window
    /// of one file (see [`StreamInput`]). Checkpoints as
    /// [`fit_cluster`](Self::fit_cluster) does.
    pub fn fit_cluster_stream(
        &mut self,
        input: StreamInput,
    ) -> Result<(TrainResult, ClusterReport), SomError> {
        let net = self.net.clone();
        Ok(crate::cluster::runner::run_cluster_stream(self, input, net)?)
    }

    /// Train this process's rank of a **real multi-process** cluster:
    /// `cfg.ranks` OS processes rendezvous over TCP/Unix sockets
    /// ([`NetOptions`]) and run the same per-epoch exchange as
    /// [`fit_cluster_stream`](Self::fit_cluster_stream), each reading
    /// only its own row window of `input` (the file must be readable at
    /// the same path by every process). Rank 0 owns initial state
    /// (fresh init, `-c FILE`, or a resumed checkpoint), broadcasts it
    /// at bootstrap, and is the only rank that returns a
    /// [`TrainResult`]; every rank gets its own [`ClusterReport`].
    /// Checkpoint policy should be set on rank 0 only.
    pub fn fit_cluster_net(
        &mut self,
        input: StreamInput,
        opts: &NetOptions,
    ) -> Result<(Option<TrainResult>, ClusterReport), SomError> {
        Ok(crate::cluster::multiproc::run_cluster_net(self, input, opts)?)
    }

    /// Write the interim snapshot for the epoch that just finished
    /// (paper `-s`) — the canonical per-epoch observer body for
    /// [`fit_source_with`](Self::fit_source_with), shared by the CLI
    /// and the legacy `train_stream` shim. No-op when the snapshot
    /// level is `None` or before any epoch completed.
    pub fn write_epoch_snapshot(&self, writer: &OutputWriter) -> Result<(), SomError> {
        if self.cfg.snapshot == SnapshotLevel::None || self.epoch == 0 {
            return Ok(());
        }
        let cb = self.codebook.as_ref().expect("epochs ran");
        let u = umatrix::umatrix(&self.grid, cb, self.cfg.threads);
        writer.write_snapshot(
            self.cfg.snapshot,
            self.epoch - 1,
            &self.grid,
            cb,
            &self.last_bmus,
            &u,
        )?;
        Ok(())
    }

    // -- inference ----------------------------------------------------

    /// Best-matching unit for one dense vector: `(node, distance)`.
    /// Delegates to [`crate::som::quality::linear_bmu`] — the plain
    /// codebook scan the serving daemon's `bmu` request path also uses,
    /// so served and offline answers are bit-identical by construction.
    /// Kernel-independent (works for maps trained with any kernel) and
    /// cheap enough to serve lookups.
    ///
    /// # Errors
    ///
    /// [`SomError::State`] before any codebook exists,
    /// [`SomError::Data`] on a dimension mismatch.
    pub fn bmu(&self, x: &[f32]) -> Result<(usize, f32), SomError> {
        let cb = self.codebook.as_ref().ok_or_else(|| {
            SomError::state("session has no codebook yet (fit or resume first)")
        })?;
        if x.len() != cb.dim {
            return Err(SomError::data(format!(
                "query has {} dims, codebook has {}",
                x.len(),
                cb.dim
            )));
        }
        Ok(crate::som::quality::linear_bmu(cb, x))
    }

    /// Batch inference: BMU per row of `input` against the current
    /// codebook, through the training kernel's BMU search (identical
    /// tie-breaking and arithmetic to the BMUs training reports, with
    /// none of the Eq. 6 accumulation work). Does **not** update the
    /// codebook or advance the cursor.
    pub fn project(&mut self, input: DataInput<'_>) -> Result<Vec<u32>, SomError> {
        let mut tmp = Vec::new();
        let shard = materialize(input, &mut tmp);
        let mut source = InMemorySource::new(shard, self.cfg.chunk_rows);
        self.project_source(&mut source)
    }

    /// [`project`](Self::project) over any [`DataSource`].
    pub fn project_source(
        &mut self,
        source: &mut dyn DataSource,
    ) -> Result<Vec<u32>, SomError> {
        if source.rows() == 0 {
            return Err(SomError::data("no data rows"));
        }
        self.ensure_kernel()?;
        let cb = self.codebook.as_ref().ok_or_else(|| {
            SomError::state("session has no codebook yet (fit or resume first)")
        })?;
        if cb.dim != source.dim() {
            return Err(SomError::data(format!(
                "data dim {} does not match the session codebook dim {}",
                source.dim(),
                cb.dim
            )));
        }
        let kernel = self.kernel.as_mut().expect("just ensured");
        let rows = source.rows();
        kernel.epoch_begin(cb)?;
        source.reset()?;
        let mut bmus: Vec<u32> = Vec::with_capacity(rows);
        while let Some(chunk) = source.next_chunk()? {
            bmus.extend(kernel.project(chunk, cb, &self.grid, self.cfg.neighborhood)?);
        }
        if bmus.len() != rows {
            return Err(SomError::data(format!(
                "data source produced {} rows this pass, expected {rows}",
                bmus.len()
            )));
        }
        Ok(bmus)
    }

    // -- checkpointing ------------------------------------------------

    /// Write a `SOMC` checkpoint of the current state (atomically; see
    /// [`crate::io::checkpoint`]). [`Som::resume`] restores it
    /// bit-exactly.
    ///
    /// # Errors
    ///
    /// [`SomError::State`] before any codebook exists,
    /// [`SomError::Checkpoint`] if the write fails.
    pub fn save_checkpoint<P: AsRef<Path>>(&self, path: P) -> Result<(), SomError> {
        let cb = self.codebook.as_ref().ok_or_else(|| {
            SomError::state("nothing to checkpoint: session has no codebook yet")
        })?;
        crate::io::checkpoint::save(path, &self.cfg, self.epoch.min(self.cfg.epochs), cb)
    }

    // -- internals (shared with the cluster runner) -------------------

    /// Radius/scale at the cursor, clamped to the schedule's final
    /// values for steps past `epochs_total`.
    pub(crate) fn schedule_now(&self) -> (f32, f32) {
        let e = self.epoch.min(self.cfg.epochs.saturating_sub(1));
        (
            self.cfg.radius_schedule(&self.grid).at(e),
            self.cfg.scale_schedule().at(e),
        )
    }

    /// Build the kernel on first use; it persists for the session.
    fn ensure_kernel(&mut self) -> anyhow::Result<()> {
        if self.kernel.is_none() {
            self.kernel = Some(make_kernel(&self.cfg)?);
        }
        Ok(())
    }

    /// Install an explicit codebook (initial, broadcast, or resumed),
    /// checking the node count against the map.
    pub(crate) fn install_codebook(&mut self, cb: Codebook) -> anyhow::Result<()> {
        if cb.nodes != self.grid.node_count() || cb.weights.len() != cb.nodes * cb.dim {
            // Embed a typed error so the public surface recovers the
            // `config` code when this crosses it via `From<anyhow::Error>`.
            return Err(anyhow::Error::new(SomError::config(format!(
                "initial codebook shape {}x{} does not match map {}x{}",
                cb.nodes, cb.dim, self.grid.rows, self.grid.cols
            ))));
        }
        self.codebook = Some(cb);
        Ok(())
    }

    /// Initialize the codebook from the source if absent (random init
    /// never touches the data; PCA needs a resident shard), or check
    /// the existing one's dimensionality against the data.
    pub(crate) fn ensure_codebook_for_source(
        &mut self,
        source: &mut dyn DataSource,
    ) -> anyhow::Result<()> {
        let dim = source.dim();
        if let Some(cb) = &self.codebook {
            if cb.dim != dim {
                return Err(anyhow::Error::new(SomError::data(format!(
                    "data dim {dim} does not match the session codebook dim {}",
                    cb.dim
                ))));
            }
            return Ok(());
        }
        let cb = if self.cfg.initialization == Initialization::Random {
            init_codebook(&self.cfg, &self.grid, dim)
        } else {
            match source.resident() {
                Some(shard) => init_codebook_with_data(&self.cfg, &self.grid, shard)?,
                None => {
                    return Err(anyhow::Error::new(SomError::config(
                        "PCA initialization needs the data resident in memory; \
                         streamed sources support only --initialization random \
                         (or an explicit -c codebook)",
                    )))
                }
            }
        };
        self.codebook = Some(cb);
        Ok(())
    }

    /// One epoch's accumulation pass: `epoch_begin`, then the chunk loop
    /// merging partial Eq. 6 accumulators and concatenating BMUs in
    /// chunk order. Does **not** apply the update or advance the cursor
    /// — the cluster runner interleaves its collectives here.
    pub(crate) fn accumulate_epoch(
        &mut self,
        source: &mut dyn DataSource,
    ) -> anyhow::Result<EpochAccum> {
        let (radius, scale) = self.schedule_now();
        self.ensure_kernel()?;
        let cb = self.codebook.as_ref().ok_or_else(|| {
            anyhow::Error::new(SomError::state(
                "session has no codebook yet (fit or resume first)",
            ))
        })?;
        if cb.dim != source.dim() {
            return Err(anyhow::Error::new(SomError::data(format!(
                "data dim {} does not match the session codebook dim {}",
                source.dim(),
                cb.dim
            ))));
        }
        let kernel = self.kernel.as_mut().expect("just ensured");
        let grid = &self.grid;
        let cfg = &self.cfg;
        let rows = source.rows();
        kernel.epoch_begin(cb)?;
        source.reset()?;
        let mut accum = EpochAccum::zeros(grid.node_count(), cb.dim, 0);
        let mut bmus: Vec<u32> = Vec::with_capacity(rows);
        while let Some(chunk) = source.next_chunk()? {
            let part = kernel.epoch_accumulate(
                chunk,
                cb,
                grid,
                cfg.neighborhood,
                radius,
                scale,
            )?;
            bmus.extend_from_slice(&part.bmus);
            accum.merge(&part);
        }
        anyhow::ensure!(
            bmus.len() == rows,
            "data source produced {} rows this epoch, expected {rows}",
            bmus.len()
        );
        accum.bmus = bmus;
        Ok(accum)
    }

    /// Apply the Eq. 6 batch update to the owned codebook.
    pub(crate) fn apply_epoch_update(&mut self, accum: &EpochAccum) {
        self.codebook
            .as_mut()
            .expect("codebook present")
            .apply_batch_update(&accum.num, &accum.den);
    }

    /// Mutable weight buffer (the cluster broadcast target).
    pub(crate) fn weights_mut(&mut self) -> &mut [f32] {
        &mut self.codebook.as_mut().expect("codebook present").weights
    }

    /// Record a completed epoch: store its BMUs and stats, advance the
    /// cursor, and fire the checkpoint policy if its cadence is due.
    pub(crate) fn finish_epoch(
        &mut self,
        stats: EpochStats,
        bmus: Vec<u32>,
    ) -> anyhow::Result<()> {
        self.last_bmus = bmus;
        self.history.push(stats);
        self.epoch += 1;
        self.maybe_checkpoint()
    }

    /// Save a numbered checkpoint when the policy cadence is due, then
    /// run retention GC over the checkpoints this session has written.
    pub(crate) fn maybe_checkpoint(&mut self) -> anyhow::Result<()> {
        let due = match &self.checkpoint {
            Some(p) if p.every > 0 && self.epoch % p.every == 0 => {
                Some(checkpoint_path(&p.prefix, self.epoch))
            }
            _ => None,
        };
        if let Some(path) = due {
            self.save_checkpoint(&path)?;
            if let Some(p) = self.checkpoint.as_mut() {
                p.written.push_back(path);
                p.gc();
            }
        }
        Ok(())
    }

    /// The checkpoint cadence, if a policy is set (the cluster runner
    /// sizes its training windows by it).
    pub(crate) fn checkpoint_interval(&self) -> Option<usize> {
        self.checkpoint.as_ref().map(|p| p.every)
    }

    /// The rank-failure recovery policy (cluster window driver input).
    pub(crate) fn recovery(&self) -> &RecoveryPolicy {
        &self.recovery
    }

    /// The installed fault plan, if any (cluster window driver input).
    pub(crate) fn fault_plan(&self) -> Option<Arc<FaultPlan>> {
        self.fault_plan.clone()
    }

    /// Adopt the master's state after a cluster training window: the
    /// broadcast codebook bits, the gathered BMUs, the window's stats,
    /// and the new cursor; then fire the checkpoint policy.
    pub(crate) fn adopt_cluster_window(
        &mut self,
        master: &TrainResult,
        end_epoch: usize,
    ) -> anyhow::Result<()> {
        self.codebook = Some(master.codebook.clone());
        self.last_bmus = master.bmus.clone();
        self.history.extend(master.epochs.iter().cloned());
        self.epoch = end_epoch;
        self.maybe_checkpoint()
    }

    /// Move the cursor (legacy `train_one_epoch` shim and rank-session
    /// construction).
    pub(crate) fn set_epoch_cursor(&mut self, epoch: usize) {
        self.epoch = epoch;
    }

    /// Drop epoch stats recorded after `len` — the recovery rewind
    /// discarding a partially trained, aborted window's statistics.
    pub(crate) fn truncate_history(&mut self, len: usize) {
        self.history.truncate(len);
    }

    /// A rank-local session for the cluster runner: owns the broadcast
    /// codebook copy and starts mid-schedule at `start_epoch`. No
    /// checkpoint policy — the coordinator session checkpoints.
    pub(crate) fn rank_local(
        cfg: TrainConfig,
        codebook: Codebook,
        start_epoch: usize,
    ) -> anyhow::Result<SomSession> {
        let grid = cfg.grid();
        let mut session = SomSession {
            cfg,
            grid,
            net: NetModel::ideal(),
            kernel: None,
            codebook: None,
            epoch: start_epoch,
            history: Vec::new(),
            last_bmus: Vec::new(),
            checkpoint: None,
            recovery: RecoveryPolicy::none(),
            fault_plan: None,
        };
        session.install_codebook(codebook)?;
        Ok(session)
    }

    /// Assemble a [`TrainResult`] from the session state (stats since
    /// `since`, codebook clone, current BMUs, fresh U-matrix).
    pub(crate) fn result_snapshot(&self, since: usize, total: Duration) -> TrainResult {
        let codebook = self.codebook.clone().expect("snapshot after training");
        let umatrix = umatrix::umatrix(&self.grid, &codebook, self.cfg.threads);
        TrainResult {
            codebook,
            bmus: self.last_bmus.clone(),
            umatrix,
            epochs: self.history[since..].to_vec(),
            total,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data;
    use crate::util::rng::Rng;

    fn blob(seed: u64) -> (Vec<f32>, usize) {
        let mut rng = Rng::new(seed);
        let (data, _) = data::gaussian_blobs(48, 5, 3, 0.2, &mut rng);
        (data, 5)
    }

    fn small() -> SomBuilder {
        Som::builder().map_size(5, 5).epochs(4).radius0(2.5).threads(2)
    }

    #[test]
    fn builder_rejects_bad_configs() {
        assert!(Som::builder().map_size(0, 5).build().is_err());
        assert!(Som::builder().epochs(0).build().is_err());
        assert!(small().radius0(0.5).radius_n(1.0).build().is_err());
        assert!(small()
            .io_mode(IoMode::Mmap)
            .prefetch(true)
            .build()
            .is_err());
        // Initial codebook with the wrong node count.
        let cb = Codebook::zeros(7, 3);
        assert!(small().initial_codebook(cb).build().is_err());
    }

    #[test]
    fn fit_then_step_continue_identically() {
        let (data, dim) = blob(51);
        let input = || DataInput::BorrowedF32 { data: &data, dim };

        let mut a = small().build().unwrap();
        let res = a.fit(input()).unwrap();
        assert_eq!(res.epochs.len(), 4);
        assert_eq!(res.bmus.len(), 48);

        // The same schedule stepped one epoch at a time is identical.
        let mut b = small().build().unwrap();
        for _ in 0..4 {
            b.step_epoch(input()).unwrap();
        }
        assert_eq!(b.epoch(), 4);
        assert_eq!(b.remaining_epochs(), 0);
        assert_eq!(
            a.codebook().unwrap().weights,
            b.codebook().unwrap().weights
        );
        assert_eq!(a.last_bmus(), b.last_bmus());
    }

    #[test]
    fn stepping_past_schedule_clamps() {
        let (data, dim) = blob(52);
        let mut s = small().build().unwrap();
        for _ in 0..6 {
            s.step_epoch(DataInput::BorrowedF32 { data: &data, dim }).unwrap();
        }
        assert_eq!(s.epoch(), 6);
        let last = s.history().last().unwrap();
        // Clamped to the final schedule values.
        assert_eq!(last.radius, 1.0);
        assert!((last.scale - 0.01).abs() < 1e-6);
    }

    #[test]
    fn bmu_and_project_agree_on_trained_map() {
        let (data, dim) = blob(53);
        let mut s = small().build().unwrap();
        s.fit(DataInput::BorrowedF32 { data: &data, dim }).unwrap();
        let projected = s.project(DataInput::BorrowedF32 { data: &data, dim }).unwrap();
        assert_eq!(projected.len(), 48);
        for (r, &p) in projected.iter().enumerate() {
            let x = &data[r * dim..(r + 1) * dim];
            let (_, dist) = s.bmu(x).unwrap();
            // The scan and the kernel agree on the winning distance
            // (indices can differ only between exactly-tied nodes, so
            // comparing distances is the robust form of agreement).
            let d_kernel = crate::som::quality::sq_dist(
                x,
                s.codebook().unwrap().row(p as usize),
            )
            .sqrt();
            assert!((dist - d_kernel).abs() < 1e-4, "row {r}: {dist} vs {d_kernel}");
        }
    }

    #[test]
    fn inference_before_fit_is_an_error() {
        let mut s = small().build().unwrap();
        assert!(s.bmu(&[0.0; 5]).is_err());
        let (data, dim) = blob(54);
        assert!(s.project(DataInput::BorrowedF32 { data: &data, dim }).is_err());
        assert!(s.save_checkpoint(std::env::temp_dir().join("never.somc")).is_err());
    }

    #[test]
    fn dim_mismatch_rejected() {
        let (data, dim) = blob(55);
        let mut s = small().build().unwrap();
        s.fit(DataInput::BorrowedF32 { data: &data, dim }).unwrap();
        assert!(s.bmu(&[0.0; 3]).is_err());
        let other = vec![0.0f32; 12];
        assert!(s
            .fit(DataInput::BorrowedF32 { data: &other, dim: 3 })
            .is_err());
    }

    #[test]
    fn checkpoint_paths_are_numbered() {
        assert_eq!(
            checkpoint_path("out/map", 12),
            PathBuf::from("out/map.epoch12.somc")
        );
    }

    #[test]
    fn checkpoint_gc_keeps_last_n() {
        let dir = std::env::temp_dir().join(format!(
            "somoclu-gc-{}-{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        let prefix = dir.join("map");
        let (data, dim) = blob(60);
        let mut s = small()
            .epochs(6)
            .checkpoint_every(1, &prefix)
            .checkpoint_keep_last(2)
            .build()
            .unwrap();
        s.fit(DataInput::BorrowedF32 { data: &data, dim }).unwrap();
        // Only the newest two survive retention.
        for e in 1..=4 {
            assert!(!checkpoint_path(&prefix, e).exists(), "epoch {e} kept");
        }
        for e in 5..=6 {
            assert!(checkpoint_path(&prefix, e).exists(), "epoch {e} deleted");
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn checkpoint_gc_never_deletes_protected() {
        let dir = std::env::temp_dir().join(format!(
            "somoclu-gc-pin-{}-{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        let prefix = dir.join("map");
        let (data, dim) = blob(61);
        let mut s = small()
            .epochs(6)
            .checkpoint_every(1, &prefix)
            .checkpoint_keep_last(1)
            .build()
            .unwrap();
        let pins = Arc::new(Mutex::new(HashSet::new()));
        pins.lock().unwrap().insert(checkpoint_path(&prefix, 2));
        s.set_checkpoint_protected(pins);
        s.fit(DataInput::BorrowedF32 { data: &data, dim }).unwrap();
        // The pinned epoch-2 checkpoint survives alongside the newest.
        assert!(checkpoint_path(&prefix, 2).exists());
        assert!(checkpoint_path(&prefix, 6).exists());
        assert!(!checkpoint_path(&prefix, 5).exists());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn errors_carry_stable_codes() {
        let mut s = small().build().unwrap();
        assert_eq!(s.bmu(&[0.0; 5]).unwrap_err().code(), "state");
        assert_eq!(
            Som::builder().epochs(0).build().unwrap_err().code(),
            "config"
        );
        let (data, dim) = blob(62);
        s.fit(DataInput::BorrowedF32 { data: &data, dim }).unwrap();
        assert_eq!(s.bmu(&[0.0; 3]).unwrap_err().code(), "data");
    }
}
