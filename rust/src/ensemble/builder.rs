//! [`EnsembleBuilder`]: train K independently-seeded sessions, cluster
//! each codebook, and combine the labelings into one consensus.

use std::path::{Path, PathBuf};

use crate::api::DataInput;
use crate::coordinator::config::TrainConfig;
use crate::ensemble::combine::{align_labels, sce_consensus, Consensus};
use crate::ensemble::{member_seed, CLUSTER_SALT};
use crate::error::SomError;
use crate::session::{checkpoint_path, Som, SomSession};
use crate::som::kmeans::{data_labels, kmeans};
use crate::som::quality;
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::util::threadpool;

/// One trained ensemble member's contribution.
#[derive(Clone, Debug)]
pub struct EnsembleMember {
    /// The member's derived training seed ([`member_seed`]).
    pub seed: u64,
    /// BMU node index per data row, projected against the member's
    /// **final** codebook (so it is identical whether the member trained
    /// fresh or resumed an already-complete checkpoint).
    pub bmus: Vec<u32>,
    /// Per-sample cluster labels, **aligned** to member 0's label space.
    pub labels: Vec<u32>,
    /// K-means inertia of the member's codebook clustering.
    pub inertia: f64,
    /// Lloyd iterations the member's k-means took to converge.
    pub kmeans_iterations: usize,
    /// Mean quantization error of the member's final map.
    pub qe: f32,
}

/// The combined result of [`EnsembleBuilder::run`].
#[derive(Clone, Debug)]
pub struct EnsembleResult {
    /// Every member, in member-index order (member 0 is the alignment
    /// reference).
    pub members: Vec<EnsembleMember>,
    /// The SCE consensus labeling + per-sample agreement.
    pub consensus: Consensus,
    /// Number of clusters each member's codebook was cut into.
    pub clusters: usize,
}

impl EnsembleResult {
    /// Versioned JSON report (`<prefix>.ensemble.json` on the CLI).
    ///
    /// Seeds are emitted as **strings**: they are full-range u64 values
    /// and JSON numbers (f64) silently lose integers above 2^53.
    pub fn to_json(&self) -> Json {
        let mut obj = std::collections::BTreeMap::new();
        obj.insert("version".into(), Json::Num(1.0));
        obj.insert("members".into(), Json::Num(self.members.len() as f64));
        obj.insert("clusters".into(), Json::Num(self.clusters as f64));
        obj.insert(
            "samples".into(),
            Json::Num(self.consensus.labels.len() as f64),
        );
        obj.insert(
            "mean_agreement".into(),
            Json::Num(self.consensus.mean_agreement),
        );
        let members: Vec<Json> = self
            .members
            .iter()
            .map(|m| {
                let mut o = std::collections::BTreeMap::new();
                o.insert("seed".into(), Json::Str(m.seed.to_string()));
                o.insert("qe".into(), Json::Num(m.qe as f64));
                o.insert("inertia".into(), Json::Num(m.inertia));
                o.insert(
                    "kmeans_iterations".into(),
                    Json::Num(m.kmeans_iterations as f64),
                );
                Json::Obj(o)
            })
            .collect();
        obj.insert("member_stats".into(), Json::Arr(members));
        Json::Obj(obj)
    }
}

/// Builder for an ensemble run: K maps trained from [`member_seed`]
/// seeds, clustered, aligned, and majority-voted into a [`Consensus`].
///
/// ```no_run
/// use somoclu::coordinator::config::TrainConfig;
/// use somoclu::ensemble::EnsembleBuilder;
///
/// # fn main() -> Result<(), somoclu::error::SomError> {
/// let data = vec![0.0f32; 400 * 4];
/// let result = EnsembleBuilder::new()
///     .config(TrainConfig { rows: 10, cols: 10, epochs: 5, ..Default::default() })
///     .members(8)
///     .clusters(4)
///     .run(&data, 4)?;
/// println!("mean agreement: {}", result.consensus.mean_agreement);
/// # Ok(())
/// # }
/// ```
///
/// Determinism: for a fixed config the consensus labels and agreement
/// scores are **bit-identical across thread counts** — member seeds
/// are index-derived, kernel outputs are thread-count invariant,
/// k-means is single-threaded and seeded, and all combination steps
/// are sequential integer arithmetic.
#[derive(Clone, Debug)]
pub struct EnsembleBuilder {
    cfg: TrainConfig,
    members: usize,
    clusters: usize,
    kmeans_iters: usize,
    checkpoint: Option<(usize, PathBuf)>,
}

impl Default for EnsembleBuilder {
    fn default() -> Self {
        EnsembleBuilder {
            cfg: TrainConfig::default(),
            members: 5,
            clusters: 8,
            kmeans_iters: 100,
            checkpoint: None,
        }
    }
}

impl EnsembleBuilder {
    pub fn new() -> Self {
        Self::default()
    }

    /// Per-member training configuration. `seed` is the ensemble's
    /// *base* seed (each member trains with [`member_seed`]`(seed, i)`);
    /// `threads` is the ensemble's total budget, split across members.
    pub fn config(mut self, cfg: TrainConfig) -> Self {
        self.cfg = cfg;
        self
    }

    /// Number of ensemble members to train (default 5).
    pub fn members(mut self, k: usize) -> Self {
        self.members = k;
        self
    }

    /// Number of clusters to cut each member's codebook into (default 8).
    pub fn clusters(mut self, c: usize) -> Self {
        self.clusters = c;
        self
    }

    /// Lloyd iteration cap for the per-member k-means (default 100).
    pub fn kmeans_iters(mut self, n: usize) -> Self {
        self.kmeans_iters = n;
        self
    }

    /// Ensemble base seed (shorthand for setting `config.seed`).
    pub fn seed(mut self, s: u64) -> Self {
        self.cfg.seed = s;
        self
    }

    /// Total thread budget (shorthand for setting `config.threads`).
    /// 0 = one thread per member (members already run concurrently).
    pub fn threads(mut self, n: usize) -> Self {
        self.cfg.threads = n;
        self
    }

    /// Checkpoint every member's session every `every` epochs under
    /// `<prefix>.m<i>.epoch<k>.somc`, and **resume** any member whose
    /// newest checkpoint already exists — an interrupted ensemble run
    /// re-invoked with the same prefix picks up each member where it
    /// stopped, bit-identically (the session checkpoint contract).
    pub fn checkpoint_every<P: AsRef<Path>>(mut self, every: usize, prefix: P) -> Self {
        self.checkpoint = if every == 0 {
            None
        } else {
            Some((every, prefix.as_ref().to_path_buf()))
        };
        self
    }

    /// Train, cluster, align, and combine. `data` is dense row-major
    /// `rows × dim`; every member trains on the full data set.
    pub fn run(&self, data: &[f32], dim: usize) -> Result<EnsembleResult, SomError> {
        if self.members == 0 {
            return Err(SomError::config("ensemble needs at least 1 member"));
        }
        if dim == 0 || data.len() % dim != 0 {
            return Err(SomError::data(format!(
                "data length {} is not a multiple of dim {dim}",
                data.len()
            )));
        }
        if data.is_empty() {
            return Err(SomError::data("ensemble training needs at least one row"));
        }
        let nodes = self.cfg.rows * self.cfg.cols;
        if self.clusters == 0 || self.clusters > nodes {
            return Err(SomError::config(format!(
                "clusters={} out of range for a {}x{} map ({} nodes)",
                self.clusters, self.cfg.rows, self.cfg.cols, nodes
            )));
        }
        // Split the thread budget: members already run concurrently, so
        // 0 (= "all cores" for a lone session) becomes 1 per member.
        let member_threads = if self.cfg.threads == 0 {
            1
        } else {
            (self.cfg.threads / self.members).max(1)
        };

        let base = self.cfg.seed;
        let tasks: Vec<_> = (0..self.members)
            .map(|i| {
                let mut mcfg = self.cfg.clone();
                mcfg.seed = member_seed(base, i);
                mcfg.threads = member_threads;
                mcfg.ranks = 1;
                let checkpoint = self.checkpoint.clone();
                let (clusters, kmeans_iters) = (self.clusters, self.kmeans_iters);
                move || -> Result<EnsembleMember, SomError> {
                    let seed = mcfg.seed;
                    let epochs = mcfg.epochs;
                    let mut session =
                        build_member_session(mcfg, i, epochs, checkpoint.as_ref())?;
                    let result = session.fit(DataInput::BorrowedF32 { data, dim })?;
                    // Project explicitly: a fit that just trained returns
                    // the last epoch's accumulation BMUs (pre-update
                    // codebook), while resuming an already-complete
                    // checkpoint returns a projection. Defining member
                    // BMUs against the FINAL codebook makes both paths —
                    // and everything built on them — bit-identical.
                    let bmus = session.project(DataInput::BorrowedF32 { data, dim })?;
                    let km = kmeans(
                        &result.codebook,
                        clusters,
                        kmeans_iters,
                        &mut Rng::new(seed ^ CLUSTER_SALT),
                    );
                    let labels = data_labels(&km, &bmus);
                    let bmus_usize: Vec<usize> =
                        bmus.iter().map(|&b| b as usize).collect();
                    let qe =
                        quality::quantization_error(data, dim, &result.codebook, &bmus_usize);
                    Ok(EnsembleMember {
                        seed,
                        bmus,
                        labels,
                        inertia: km.inertia,
                        kmeans_iterations: km.iterations,
                        qe,
                    })
                }
            })
            .collect();
        let raw: Vec<EnsembleMember> = threadpool::run_concurrent(tasks)
            .into_iter()
            .collect::<Result<_, _>>()?;

        // Sequential combination: align everyone onto member 0's label
        // space, then majority-vote. Nothing here depends on scheduling.
        let mut members = raw;
        let reference = members[0].labels.clone();
        for m in members.iter_mut().skip(1) {
            m.labels = align_labels(&reference, &m.labels, self.clusters);
        }
        let aligned: Vec<Vec<u32>> = members.iter().map(|m| m.labels.clone()).collect();
        let consensus = sce_consensus(&aligned, self.clusters);
        Ok(EnsembleResult {
            members,
            consensus,
            clusters: self.clusters,
        })
    }
}

/// Construct (or resume) member `i`'s session. With checkpointing on,
/// the newest existing `<prefix>.m<i>.epoch<k>.somc` wins — the session
/// checkpoint owns map/schedule/seed, we re-apply only runtime knobs.
fn build_member_session(
    cfg: TrainConfig,
    member: usize,
    epochs: usize,
    checkpoint: Option<&(usize, PathBuf)>,
) -> Result<SomSession, SomError> {
    let threads = cfg.threads;
    if let Some((every, prefix)) = checkpoint {
        let mprefix = PathBuf::from(format!("{}.m{member}", prefix.display()));
        for e in (1..=epochs).rev() {
            let path = checkpoint_path(&mprefix, e);
            if path.exists() {
                let mut session = Som::resume(&path)?;
                session.set_threads(threads);
                session.set_checkpoint_every(*every, &mprefix);
                return Ok(session);
            }
        }
        return Som::builder()
            .config(cfg)
            .checkpoint_every(*every, &mprefix)
            .build();
    }
    Som::builder().config(cfg).build()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data;

    fn blob_data(seed: u64) -> (Vec<f32>, usize) {
        let mut rng = Rng::new(seed);
        let (d, _) = data::gaussian_blobs(48, 5, 3, 0.2, &mut rng);
        (d, 5)
    }

    fn small() -> EnsembleBuilder {
        EnsembleBuilder::new()
            .config(TrainConfig {
                rows: 6,
                cols: 6,
                epochs: 3,
                radius0: Some(3.0),
                ..Default::default()
            })
            .members(3)
            .clusters(3)
    }

    #[test]
    fn shapes_and_ranges() {
        let (d, dim) = blob_data(90);
        let res = small().run(&d, dim).unwrap();
        assert_eq!(res.members.len(), 3);
        assert_eq!(res.consensus.labels.len(), 48);
        assert_eq!(res.consensus.agreement.len(), 48);
        for m in &res.members {
            assert_eq!(m.bmus.len(), 48);
            assert_eq!(m.labels.len(), 48);
            assert!(m.labels.iter().all(|&l| l < 3));
            assert!(m.qe.is_finite());
        }
        assert!(res.consensus.labels.iter().all(|&l| l < 3));
        for &a in &res.consensus.agreement {
            assert!((0.0..=1.0).contains(&a), "{a}");
            // With 3 members the winner has at least 1 vote.
            assert!(a >= 1.0 / 3.0);
        }
        assert!(res.consensus.mean_agreement > 0.0);
        assert!(res.consensus.mean_agreement <= 1.0);
    }

    #[test]
    fn deterministic_for_fixed_seed_across_thread_budgets() {
        let (d, dim) = blob_data(91);
        let a = small().threads(1).run(&d, dim).unwrap();
        let b = small().threads(4).run(&d, dim).unwrap();
        let c = small().threads(16).run(&d, dim).unwrap();
        for other in [&b, &c] {
            assert_eq!(a.consensus.labels, other.consensus.labels);
            let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
            assert_eq!(bits(&a.consensus.agreement), bits(&other.consensus.agreement));
            assert_eq!(
                a.consensus.mean_agreement.to_bits(),
                other.consensus.mean_agreement.to_bits()
            );
            for (ma, mo) in a.members.iter().zip(&other.members) {
                assert_eq!(ma.seed, mo.seed);
                assert_eq!(ma.bmus, mo.bmus);
                assert_eq!(ma.labels, mo.labels);
            }
        }
    }

    #[test]
    fn different_base_seeds_change_members() {
        let (d, dim) = blob_data(92);
        let a = small().seed(1).run(&d, dim).unwrap();
        let b = small().seed(2).run(&d, dim).unwrap();
        assert_ne!(a.members[0].seed, b.members[0].seed);
        // Different inits virtually always land at least one BMU apart.
        assert_ne!(a.members[0].bmus, b.members[0].bmus);
    }

    #[test]
    fn checkpointed_members_resume_bit_identically() {
        let (d, dim) = blob_data(93);
        let dir = std::env::temp_dir().join(format!("somoclu_ens_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let prefix = dir.join("ens");

        // Uninterrupted reference (no checkpoints at all).
        let want = small().run(&d, dim).unwrap();

        // First pass writes per-member cadence checkpoints...
        let first = small()
            .checkpoint_every(1, &prefix)
            .run(&d, dim)
            .unwrap();
        assert_eq!(first.consensus.labels, want.consensus.labels);
        for i in 0..3 {
            let p = checkpoint_path(format!("{}.m{i}", prefix.display()), 3);
            assert!(p.exists(), "{}", p.display());
            // Simulate an interruption: drop members back to epoch 2.
            std::fs::remove_file(&p).unwrap();
        }
        // ...second pass resumes every member from epoch 2 and must
        // reproduce the uninterrupted consensus exactly.
        let resumed = small()
            .checkpoint_every(1, &prefix)
            .run(&d, dim)
            .unwrap();
        assert_eq!(resumed.consensus.labels, want.consensus.labels);
        for (rm, wm) in resumed.members.iter().zip(&want.members) {
            assert_eq!(rm.bmus, wm.bmus);
            assert_eq!(rm.labels, wm.labels);
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn rejects_bad_configs() {
        let (d, dim) = blob_data(94);
        assert!(small().members(0).run(&d, dim).is_err());
        assert!(small().clusters(0).run(&d, dim).is_err());
        assert!(small().clusters(37).run(&d, dim).is_err()); // > 36 nodes
        assert!(small().run(&d[..d.len() - 1], dim).is_err());
        assert!(small().run(&[], dim).is_err());
    }

    #[test]
    fn json_report_shape() {
        let (d, dim) = blob_data(95);
        let res = small().run(&d, dim).unwrap();
        let j = res.to_json();
        assert_eq!(j.get("version").unwrap().as_usize(), Some(1));
        assert_eq!(j.get("members").unwrap().as_usize(), Some(3));
        assert_eq!(j.get("clusters").unwrap().as_usize(), Some(3));
        assert_eq!(j.get("samples").unwrap().as_usize(), Some(48));
        let stats = j.get("member_stats").unwrap().as_arr().unwrap();
        assert_eq!(stats.len(), 3);
        // Seeds survive the u64 round-trip as strings.
        let s0 = stats[0].get("seed").unwrap().as_str().unwrap();
        assert_eq!(s0.parse::<u64>().unwrap(), res.members[0].seed);
        // The report serializes and re-parses.
        let rt = Json::parse(&j.to_string()).unwrap();
        assert_eq!(rt.get("version").unwrap().as_usize(), Some(1));
    }
}
