//! Cross-member label alignment + the SCE consensus/agreement rule.
//!
//! K-means labels are arbitrary per member (cluster 3 of member 0 and
//! cluster 0 of member 1 may be the same group of samples), so the
//! ensemble cannot vote on raw labels. [`align_labels`] first maps each
//! member's label space onto the reference member's by maximizing label
//! co-occurrence; [`sce_consensus`] then majority-votes the aligned
//! labelings and reports per-sample agreement.

/// Consensus labeling of an aligned ensemble.
#[derive(Clone, Debug)]
pub struct Consensus {
    /// Winning label per sample (majority vote, ties to the lowest
    /// label id).
    pub labels: Vec<u32>,
    /// Per-sample agreement: fraction of members that voted for the
    /// winning label, in (0, 1]. 1.0 = unanimous.
    pub agreement: Vec<f32>,
    /// Mean agreement over all samples (computed from exact integer
    /// vote counts, so it is identical across thread counts).
    pub mean_agreement: f64,
}

/// Relabel `member` into `reference`'s label space.
///
/// Builds the k×k contingency table (how many samples carry reference
/// label `r` and member label `m` simultaneously) and greedily matches
/// the largest remaining cell until every label is paired — ties break
/// to the lowest `(r, m)` pair in scan order, so the result is fully
/// deterministic. Returns `member` with each label replaced by its
/// matched reference label.
///
/// Both labelings must have the same length and all labels `< k`
/// (asserted). Greedy maximum matching is the standard SCE alignment
/// step; an optimal assignment (Hungarian) differs only when cluster
/// overlap is highly ambiguous, where consensus agreement will be low
/// regardless.
pub fn align_labels(reference: &[u32], member: &[u32], k: usize) -> Vec<u32> {
    assert_eq!(
        reference.len(),
        member.len(),
        "label vectors must cover the same samples"
    );
    assert!(k >= 1, "k must be at least 1");
    let mut cont = vec![0u64; k * k];
    for (&r, &m) in reference.iter().zip(member) {
        let (r, m) = (r as usize, m as usize);
        assert!(r < k && m < k, "label out of range: ref {r} / member {m} vs k={k}");
        cont[r * k + m] += 1;
    }
    let mut map = vec![u32::MAX; k];
    let mut ref_used = vec![false; k];
    let mut mem_used = vec![false; k];
    for _ in 0..k {
        let (mut best, mut best_r, mut best_m) = (None::<u64>, 0usize, 0usize);
        for r in 0..k {
            if ref_used[r] {
                continue;
            }
            for m in 0..k {
                if mem_used[m] {
                    continue;
                }
                let c = cont[r * k + m];
                // Strict `>` keeps the first-scanned (lowest) pair on
                // ties — the determinism contract.
                if best.map_or(true, |b| c > b) {
                    best = Some(c);
                    best_r = r;
                    best_m = m;
                }
            }
        }
        map[best_m] = best_r as u32;
        ref_used[best_r] = true;
        mem_used[best_m] = true;
    }
    member.iter().map(|&m| map[m as usize]).collect()
}

/// Majority-vote consensus over *aligned* member labelings (aweSOM's
/// statistically-combined-ensemble rule).
///
/// Every member contributes one vote per sample; the winning label is
/// the most-voted one, ties to the lowest label id. The per-sample
/// agreement score is `winning votes / members`. All arithmetic is
/// integer until the final division, so the output is bit-deterministic
/// regardless of how the members were scheduled.
///
/// Panics if `members` is empty, the labelings disagree on length, or a
/// label is `>= k`.
pub fn sce_consensus(members: &[Vec<u32>], k: usize) -> Consensus {
    assert!(!members.is_empty(), "consensus needs at least one member");
    assert!(k >= 1, "k must be at least 1");
    let n = members[0].len();
    for (i, m) in members.iter().enumerate() {
        assert_eq!(m.len(), n, "member {i} labels {} samples, expected {n}", m.len());
    }
    let total = members.len() as u32;
    let mut labels = Vec::with_capacity(n);
    let mut agreement = Vec::with_capacity(n);
    let mut winner_votes_sum = 0u64;
    let mut counts = vec![0u32; k];
    for s in 0..n {
        counts.iter_mut().for_each(|c| *c = 0);
        for m in members {
            let l = m[s] as usize;
            assert!(l < k, "label {l} out of range for k={k}");
            counts[l] += 1;
        }
        // argmax with strict `>`: ties go to the lowest label id.
        let (mut win, mut votes) = (0u32, 0u32);
        for (l, &c) in counts.iter().enumerate() {
            if c > votes {
                votes = c;
                win = l as u32;
            }
        }
        labels.push(win);
        agreement.push(votes as f32 / total as f32);
        winner_votes_sum += votes as u64;
    }
    let mean_agreement = if n == 0 {
        0.0
    } else {
        winner_votes_sum as f64 / (n as u64 * total as u64) as f64
    };
    Consensus {
        labels,
        agreement,
        mean_agreement,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alignment_undoes_a_label_permutation() {
        // Member = reference under the permutation 0->2, 1->0, 2->1.
        let reference = vec![0u32, 0, 1, 1, 2, 2, 0, 1, 2];
        let member: Vec<u32> = reference.iter().map(|&l| [2u32, 0, 1][l as usize]).collect();
        assert_eq!(align_labels(&reference, &member, 3), reference);
    }

    #[test]
    fn alignment_is_identity_when_spaces_agree() {
        let labels = vec![1u32, 0, 3, 2, 1, 1, 0, 3];
        assert_eq!(align_labels(&labels, &labels, 4), labels);
    }

    #[test]
    fn alignment_tie_breaks_to_lowest_pair() {
        // Equal overlap everywhere (each (r, m) cell = 1): the greedy
        // scan must pair (0,0), (1,1) — the identity.
        let reference = vec![0u32, 0, 1, 1];
        let member = vec![0u32, 1, 0, 1];
        assert_eq!(align_labels(&reference, &member, 2), member);
    }

    #[test]
    fn alignment_handles_labels_absent_from_one_side() {
        // Member never emits label 2; alignment must still produce a
        // full permutation (unused labels pair with leftover cells).
        let reference = vec![0u32, 1, 2, 0, 1, 2];
        let member = vec![1u32, 0, 0, 1, 0, 0];
        let aligned = align_labels(&reference, &member, 3);
        assert_eq!(aligned.len(), 6);
        assert!(aligned.iter().all(|&l| l < 3));
        // Member label 1 co-occurs most with reference 0, member 0 with
        // reference 1 (2 hits) — check the majority pairs survived.
        assert_eq!(aligned[0], 0);
        assert_eq!(aligned[1], 1);
    }

    #[test]
    fn consensus_unanimous_members() {
        let labels = vec![2u32, 0, 1, 1];
        let members = vec![labels.clone(), labels.clone(), labels.clone()];
        let c = sce_consensus(&members, 3);
        assert_eq!(c.labels, labels);
        assert!(c.agreement.iter().all(|&a| a == 1.0));
        assert_eq!(c.mean_agreement, 1.0);
    }

    #[test]
    fn consensus_majority_and_tie_rule() {
        // Sample 0: votes {0, 0, 1} -> 0 with 2/3.
        // Sample 1: votes {1, 2, 2} -> 2 with 2/3.
        // Sample 2: three-way tie {0, 1, 2} -> lowest label 0 with 1/3.
        let members = vec![vec![0u32, 1, 0], vec![0u32, 2, 1], vec![1u32, 2, 2]];
        let c = sce_consensus(&members, 3);
        assert_eq!(c.labels, vec![0, 2, 0]);
        let want = [2.0f32 / 3.0, 2.0 / 3.0, 1.0 / 3.0];
        for (got, want) in c.agreement.iter().zip(want) {
            assert_eq!(got.to_bits(), want.to_bits());
        }
        assert!((c.mean_agreement - 5.0 / 9.0).abs() < 1e-12);
    }

    #[test]
    fn consensus_single_member_is_identity() {
        let labels = vec![0u32, 1, 0, 1];
        let members = vec![labels.clone()];
        let c = sce_consensus(&members, 2);
        assert_eq!(c.labels, labels);
        assert_eq!(c.mean_agreement, 1.0);
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn consensus_rejects_out_of_range_labels() {
        sce_consensus(&[vec![5u32]], 3);
    }
}
