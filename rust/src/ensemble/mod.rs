//! Ensemble SOM training + statistically combined cluster labeling.
//!
//! The paper positions somoclu as a *clustering analysis* tool (its
//! text-mining workflow, §5), but a single SOM run is seed-sensitive:
//! two maps trained from different random codebooks can carve the same
//! data into visibly different clusters. aweSOM's statistically
//! combined ensemble (SCE) answer is to embrace that variance — train
//! `K` maps with **independent seeds** (embarrassingly parallel; each
//! member is one [`crate::session::SomSession`]), cluster each member's
//! codebook (k-means, [`crate::som::kmeans`]), align the arbitrary
//! cluster label spaces across members, and majority-vote a single
//! consensus labeling plus a per-sample **agreement score** — the
//! fraction of members that voted for the winning label, a confidence
//! readout a single run cannot produce.
//!
//! Pipeline (all deterministic for a fixed base seed):
//!
//! 1. [`member_seed`] derives member `i`'s seed from the base seed via
//!    a SplitMix64 finalizer — decorrelated, reproducible, and
//!    independent of how many members run.
//! 2. [`EnsembleBuilder::run`] trains the members concurrently over the
//!    scoped thread pool (kernel outputs are thread-count invariant, so
//!    concurrency never changes a bit of any member's result), then
//!    clusters each member's codebook and extends node labels to data
//!    labels through the member's BMUs.
//! 3. [`combine::align_labels`] maps every member's label space onto
//!    member 0's by greedy maximum-overlap matching of the k×k
//!    contingency table (ties to the lowest label pair, so alignment is
//!    order-independent of the thread schedule).
//! 4. [`combine::sce_consensus`] majority-votes the aligned labelings
//!    (ties to the lowest label id) and scores per-sample agreement.
//!
//! The CLI front end is `somoclu ensemble`; outputs are per-member
//! ESOM `.bm` files, a `.consensus.lbl` labeling with agreement scores,
//! and a versioned `.ensemble.json` report.

pub mod builder;
pub mod combine;

pub use builder::{EnsembleBuilder, EnsembleMember, EnsembleResult};
pub use combine::{align_labels, sce_consensus, Consensus};

/// Salt XORed into a member's seed for its k-means RNG, so codebook
/// initialization and cluster seeding never share a stream.
pub const CLUSTER_SALT: u64 = 0x5ce5_ce5c_e5ce_5ce5;

/// Derive member `i`'s training seed from the ensemble's base seed.
///
/// SplitMix64 finalizer over `base ^ (i+1)·φ64` — the same mixing
/// constants as [`crate::util::rng::Rng`]'s generator, used here as a
/// one-shot hash. Properties the ensemble relies on: deterministic,
/// distinct per member (including member 0 ≠ base), and decorrelated
/// even for adjacent indices, so members never share an init stream.
pub fn member_seed(base: u64, member: usize) -> u64 {
    let mut z = base ^ (member as u64 + 1).wrapping_mul(0x9e37_79b9_7f4a_7c15);
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn member_seeds_are_distinct_and_stable() {
        let base = 1347440723u64;
        let seeds: Vec<u64> = (0..64).map(|i| member_seed(base, i)).collect();
        // Deterministic across calls.
        assert_eq!(seeds, (0..64).map(|i| member_seed(base, i)).collect::<Vec<_>>());
        // Pairwise distinct, and none equal to the base itself.
        let mut uniq = seeds.clone();
        uniq.sort_unstable();
        uniq.dedup();
        assert_eq!(uniq.len(), seeds.len());
        assert!(!seeds.contains(&base));
    }

    #[test]
    fn member_seeds_depend_on_base() {
        assert_ne!(member_seed(1, 0), member_seed(2, 0));
        assert_ne!(member_seed(0, 0), member_seed(0, 1));
    }
}
