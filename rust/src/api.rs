//! Library API (paper §4.2–4.3): the `trainOneEpoch`-style entry point
//! plus the interface-binding memory semantics Fig. 7 measures.
//!
//! The paper's point: the Python/numpy binding passes f32 pointers
//! (zero copy), while R and MATLAB default to f64 and "must duplicate all
//! data structures" converting to the core's f32. We expose both calling
//! conventions so the Fig. 7 harness can measure exactly that overhead:
//!
//! * [`DataInput::BorrowedF32`] — the numpy-style zero-copy path.
//! * [`DataInput::ConvertedF64`] — the R/MATLAB-style path: an f64 buffer
//!   converted (allocating a full f32 copy) before training.

use crate::coordinator::config::TrainConfig;
use crate::coordinator::train::{self, TrainResult};
use crate::kernels::DataShard;
use crate::sparse::Csr;

/// Calling-convention variants for dense data (Fig. 7).
pub enum DataInput<'a> {
    /// Zero-copy: caller already holds f32 row-major data (Python/numpy
    /// float32 semantics — "we pass pointers between the two languages").
    BorrowedF32 { data: &'a [f32], dim: usize },
    /// Copy-converting: f64 input duplicated into f32 (R/MATLAB
    /// semantics — "we must convert between double and float arrays").
    ConvertedF64 { data: &'a [f64], dim: usize },
    /// Sparse CSR input (always borrowed).
    Sparse(&'a Csr),
}

/// Train a map over `input` with `cfg`. The single public entry point
/// the language bindings would wrap.
pub fn train(cfg: &TrainConfig, input: DataInput<'_>) -> anyhow::Result<TrainResult> {
    match input {
        DataInput::BorrowedF32 { data, dim } => {
            train::train(cfg, DataShard::Dense { data, dim }, None, None)
        }
        DataInput::ConvertedF64 { data, dim } => {
            // The R/MATLAB duplication: a full-size converted copy lives
            // for the duration of training (and the result converts back
            // to f64 in a real binding; we account the input copy, which
            // dominates).
            let converted: Vec<f32> = data.iter().map(|&v| v as f32).collect();
            train::train(
                cfg,
                DataShard::Dense {
                    data: &converted,
                    dim,
                },
                None,
                None,
            )
        }
        DataInput::Sparse(m) => train::train(cfg, DataShard::Sparse(m.view()), None, None),
    }
}

/// One epoch of training against an existing codebook — the literal
/// `trainOneEpoch` API shape (paper §4.2): the caller owns all state.
#[allow(clippy::too_many_arguments)]
pub fn train_one_epoch(
    cfg: &TrainConfig,
    shard: DataShard<'_>,
    codebook: &mut crate::som::Codebook,
    epoch: usize,
) -> anyhow::Result<(Vec<u32>, f64)> {
    let grid = cfg.grid();
    let radius = cfg.radius_schedule(&grid).at(epoch);
    let scale = cfg.scale_schedule().at(epoch);
    let mut kernel = train::make_kernel(cfg)?;
    let accum = kernel.epoch_accumulate(
        shard,
        codebook,
        &grid,
        cfg.neighborhood,
        radius,
        scale,
    )?;
    codebook.apply_batch_update(&accum.num, &accum.den);
    let rows = shard.rows();
    Ok((accum.bmus, accum.qe_sum / rows.max(1) as f64))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::som::Codebook;
    use crate::util::rng::Rng;

    fn small_cfg() -> TrainConfig {
        TrainConfig {
            rows: 5,
            cols: 5,
            epochs: 4,
            threads: 2,
            radius0: Some(2.5),
            ..Default::default()
        }
    }

    #[test]
    fn borrowed_and_converted_agree() {
        let mut rng = Rng::new(31);
        let (data, _) = crate::data::gaussian_blobs(50, 4, 3, 0.2, &mut rng);
        let data64: Vec<f64> = data.iter().map(|&v| v as f64).collect();
        let cfg = small_cfg();
        let a = train(&cfg, DataInput::BorrowedF32 { data: &data, dim: 4 }).unwrap();
        let b = train(&cfg, DataInput::ConvertedF64 { data: &data64, dim: 4 }).unwrap();
        // f64 -> f32 of an f32-exact value is lossless: identical runs.
        assert_eq!(a.codebook.weights, b.codebook.weights);
        assert_eq!(a.bmus, b.bmus);
    }

    #[test]
    fn one_epoch_reduces_qe_progressively() {
        let mut rng = Rng::new(32);
        let (data, _) = crate::data::gaussian_blobs(60, 4, 3, 0.1, &mut rng);
        let cfg = small_cfg();
        let grid = cfg.grid();
        let mut cb = Codebook::random_init(grid.node_count(), 4, &mut rng);
        let shard = DataShard::Dense { data: &data, dim: 4 };
        let (_, qe0) = train_one_epoch(&cfg, shard, &mut cb, 0).unwrap();
        let mut qe_last = qe0;
        for e in 1..cfg.epochs {
            let (_, qe) = train_one_epoch(&cfg, shard, &mut cb, e).unwrap();
            qe_last = qe;
        }
        assert!(qe_last < qe0, "{qe0} -> {qe_last}");
    }

    #[test]
    fn sparse_input_works() {
        let mut rng = Rng::new(33);
        let m = Csr::random(40, 16, 0.2, &mut rng);
        let mut cfg = small_cfg();
        cfg.kernel = crate::kernels::KernelType::SparseCpu;
        let res = train(&cfg, DataInput::Sparse(&m)).unwrap();
        assert_eq!(res.bmus.len(), 40);
    }
}
