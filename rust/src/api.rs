//! Library API (paper §4.2–4.3): the calling-convention types the
//! language bindings wrap.
//!
//! The paper's point: the Python/numpy binding passes f32 pointers
//! (zero copy), while R and MATLAB default to f64 and "must duplicate all
//! data structures" converting to the core's f32. We expose both calling
//! conventions so the Fig. 7 harness can measure exactly that overhead:
//!
//! * [`DataInput::BorrowedF32`] — the numpy-style zero-copy path.
//! * [`DataInput::ConvertedF64`] — the R/MATLAB-style path: an f64 buffer
//!   converted (allocating a full f32 copy) before training.
//!
//! The single public surface a binding wraps is the session:
//! [`Som::builder`] → [`SomSession`] (`fit`, `step_epoch`, `project`,
//! `save_checkpoint` / [`Som::resume`]). The pre-0.2 free functions
//! (`train`, `train_one_epoch`) are gone; their exact semantics live on
//! as one-liners over the session — see the test module here for the
//! caller-owned-codebook `trainOneEpoch` shape expressed with
//! `set_epoch_cursor` + `step_epoch_source`.

use crate::sparse::Csr;

pub use crate::session::{Som, SomBuilder, SomSession};

/// Calling-convention variants for dense data (Fig. 7).
pub enum DataInput<'a> {
    /// Zero-copy: caller already holds f32 row-major data (Python/numpy
    /// float32 semantics — "we pass pointers between the two languages").
    BorrowedF32 { data: &'a [f32], dim: usize },
    /// Copy-converting: f64 input duplicated into f32 (R/MATLAB
    /// semantics — "we must convert between double and float arrays").
    ConvertedF64 { data: &'a [f64], dim: usize },
    /// Sparse CSR input (always borrowed).
    Sparse(&'a Csr),
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::config::TrainConfig;
    use crate::kernels::DataShard;
    use crate::som::Codebook;
    use crate::util::rng::Rng;

    fn small_cfg() -> TrainConfig {
        TrainConfig {
            rows: 5,
            cols: 5,
            epochs: 4,
            threads: 2,
            radius0: Some(2.5),
            ..Default::default()
        }
    }

    fn fit(cfg: &TrainConfig, input: DataInput<'_>) -> crate::coordinator::train::TrainResult {
        Som::builder()
            .config(cfg.clone())
            .build()
            .unwrap()
            .fit(input)
            .unwrap()
    }

    #[test]
    fn borrowed_and_converted_agree() {
        let mut rng = Rng::new(31);
        let (data, _) = crate::data::gaussian_blobs(50, 4, 3, 0.2, &mut rng);
        let data64: Vec<f64> = data.iter().map(|&v| v as f64).collect();
        let cfg = small_cfg();
        let a = fit(&cfg, DataInput::BorrowedF32 { data: &data, dim: 4 });
        let b = fit(&cfg, DataInput::ConvertedF64 { data: &data64, dim: 4 });
        // f64 -> f32 of an f32-exact value is lossless: identical runs.
        assert_eq!(a.codebook.weights, b.codebook.weights);
        assert_eq!(a.bmus, b.bmus);
    }

    /// The caller-owned-state `trainOneEpoch` shape (paper §4.2)
    /// expressed with the session API: a fresh session per epoch, the
    /// caller's codebook carried between them.
    fn one_epoch(
        cfg: &TrainConfig,
        shard: DataShard<'_>,
        codebook: &mut Codebook,
        epoch: usize,
    ) -> (Vec<u32>, f64) {
        let mut session = Som::builder()
            .config(cfg.clone())
            .initial_codebook(codebook.clone())
            .build()
            .unwrap();
        session.set_epoch_cursor(epoch);
        // Feed the whole shard in one call (chunk_rows = 0) to keep the
        // historical f32 summation order.
        let mut source = crate::io::stream::InMemorySource::new(shard, 0);
        let stats = session.step_epoch_source(&mut source).unwrap();
        codebook
            .weights
            .copy_from_slice(&session.codebook().expect("trained").weights);
        (session.last_bmus().to_vec(), stats.qe)
    }

    #[test]
    fn one_epoch_reduces_qe_progressively() {
        let mut rng = Rng::new(32);
        let (data, _) = crate::data::gaussian_blobs(60, 4, 3, 0.1, &mut rng);
        let cfg = small_cfg();
        let grid = cfg.grid();
        let mut cb = Codebook::random_init(grid.node_count(), 4, &mut rng);
        let shard = DataShard::Dense { data: &data, dim: 4 };
        let (_, qe0) = one_epoch(&cfg, shard, &mut cb, 0);
        let mut qe_last = qe0;
        for e in 1..cfg.epochs {
            let (_, qe) = one_epoch(&cfg, shard, &mut cb, e);
            qe_last = qe;
        }
        assert!(qe_last < qe0, "{qe0} -> {qe_last}");
    }

    /// Rebuilding a fresh session per epoch around a caller-owned
    /// codebook must be step-for-step identical to one session stepping
    /// its own state — the equivalence the pre-0.2 `train_one_epoch`
    /// shim guaranteed, now stated directly against the session API.
    #[test]
    fn fresh_session_per_epoch_matches_persistent_session() {
        let mut rng = Rng::new(34);
        let (data, _) = crate::data::gaussian_blobs(40, 4, 3, 0.2, &mut rng);
        let cfg = small_cfg();
        let grid = cfg.grid();
        let mut rng2 = Rng::new(77);
        let init = Codebook::random_init(grid.node_count(), 4, &mut rng2);
        let shard = DataShard::Dense { data: &data, dim: 4 };

        let mut cb = init.clone();
        let mut per_epoch_bmus = Vec::new();
        for e in 0..cfg.epochs {
            let (bmus, _) = one_epoch(&cfg, shard, &mut cb, e);
            per_epoch_bmus = bmus;
        }

        let mut session = Som::builder()
            .config(cfg.clone())
            .initial_codebook(init)
            .build()
            .unwrap();
        for _ in 0..cfg.epochs {
            session
                .step_epoch(DataInput::BorrowedF32 { data: &data, dim: 4 })
                .unwrap();
        }
        assert_eq!(cb.weights, session.codebook().unwrap().weights);
        assert_eq!(per_epoch_bmus, session.last_bmus());
    }

    #[test]
    fn sparse_input_works() {
        let mut rng = Rng::new(33);
        let m = Csr::random(40, 16, 0.2, &mut rng);
        let mut cfg = small_cfg();
        cfg.kernel = crate::kernels::KernelType::SparseCpu;
        let res = fit(&cfg, DataInput::Sparse(&m));
        assert_eq!(res.bmus.len(), 40);
    }
}
