//! Library API (paper §4.2–4.3): the calling-convention types the
//! language bindings wrap, plus the legacy free-function entry points
//! (now deprecated shims over [`crate::session`]).
//!
//! The paper's point: the Python/numpy binding passes f32 pointers
//! (zero copy), while R and MATLAB default to f64 and "must duplicate all
//! data structures" converting to the core's f32. We expose both calling
//! conventions so the Fig. 7 harness can measure exactly that overhead:
//!
//! * [`DataInput::BorrowedF32`] — the numpy-style zero-copy path.
//! * [`DataInput::ConvertedF64`] — the R/MATLAB-style path: an f64 buffer
//!   converted (allocating a full f32 copy) before training.
//!
//! The single public surface a binding wraps today is the session:
//! [`Som::builder`] → [`SomSession`] (`fit`, `step_epoch`, `project`,
//! `save_checkpoint` / [`Som::resume`]). [`train`] and
//! [`train_one_epoch`] remain as delegating shims.

use crate::coordinator::config::TrainConfig;
use crate::coordinator::train::TrainResult;
use crate::kernels::DataShard;
use crate::sparse::Csr;

pub use crate::session::{Som, SomBuilder, SomSession};

/// Calling-convention variants for dense data (Fig. 7).
pub enum DataInput<'a> {
    /// Zero-copy: caller already holds f32 row-major data (Python/numpy
    /// float32 semantics — "we pass pointers between the two languages").
    BorrowedF32 { data: &'a [f32], dim: usize },
    /// Copy-converting: f64 input duplicated into f32 (R/MATLAB
    /// semantics — "we must convert between double and float arrays").
    ConvertedF64 { data: &'a [f64], dim: usize },
    /// Sparse CSR input (always borrowed).
    Sparse(&'a Csr),
}

/// Train a map over `input` with `cfg`.
///
/// Legacy entry point: a delegating shim over the session API, always
/// single-process (as it historically was, whatever `cfg.ranks` says).
/// New code should build a session — it keeps the trained state for
/// inference, stepping, and checkpointing.
#[deprecated(
    since = "0.2.0",
    note = "use Som::builder().config(..).build()?.fit(input) — the session \
            API adds stepping, inference, and checkpoint/resume"
)]
pub fn train(cfg: &TrainConfig, input: DataInput<'_>) -> anyhow::Result<TrainResult> {
    // Preserve the historical contract: this function never dispatched
    // to the cluster runner, so force the single-process path.
    let mut single = cfg.clone();
    single.ranks = 1;
    Som::builder().config(single).build()?.fit(input)
}

/// One epoch of training against an existing codebook — the literal
/// `trainOneEpoch` API shape (paper §4.2): the caller owns all state.
///
/// Legacy entry point: a delegating shim over
/// [`SomSession::step_epoch`]. Because the caller owns the codebook,
/// every call builds a fresh session (and therefore a fresh kernel) —
/// the kernel-rebuild-per-call cost this shape cannot avoid. Keep a
/// session and call `step_epoch` instead: the kernel is constructed
/// once and its `epoch_begin` caches serve every chunk of every step.
#[deprecated(
    since = "0.2.0",
    note = "use SomSession::step_epoch — it owns the codebook and reuses \
            the kernel's epoch_begin caches across steps"
)]
pub fn train_one_epoch(
    cfg: &TrainConfig,
    shard: DataShard<'_>,
    codebook: &mut crate::som::Codebook,
    epoch: usize,
) -> anyhow::Result<(Vec<u32>, f64)> {
    let mut session = Som::builder()
        .config(cfg.clone())
        .initial_codebook(codebook.clone())
        .build()?;
    session.set_epoch_cursor(epoch);
    // The historical shape fed the whole shard to the kernel in one
    // call; chunk_rows = 0 preserves that exact f32 summation order.
    let mut source = crate::io::stream::InMemorySource::new(shard, 0);
    let stats = session.step_epoch_source(&mut source)?;
    codebook
        .weights
        .copy_from_slice(&session.codebook().expect("trained").weights);
    Ok((session.last_bmus().to_vec(), stats.qe))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::som::Codebook;
    use crate::util::rng::Rng;

    fn small_cfg() -> TrainConfig {
        TrainConfig {
            rows: 5,
            cols: 5,
            epochs: 4,
            threads: 2,
            radius0: Some(2.5),
            ..Default::default()
        }
    }

    #[test]
    #[allow(deprecated)]
    fn borrowed_and_converted_agree() {
        let mut rng = Rng::new(31);
        let (data, _) = crate::data::gaussian_blobs(50, 4, 3, 0.2, &mut rng);
        let data64: Vec<f64> = data.iter().map(|&v| v as f64).collect();
        let cfg = small_cfg();
        let a = train(&cfg, DataInput::BorrowedF32 { data: &data, dim: 4 }).unwrap();
        let b = train(&cfg, DataInput::ConvertedF64 { data: &data64, dim: 4 }).unwrap();
        // f64 -> f32 of an f32-exact value is lossless: identical runs.
        assert_eq!(a.codebook.weights, b.codebook.weights);
        assert_eq!(a.bmus, b.bmus);
    }

    #[test]
    #[allow(deprecated)]
    fn one_epoch_reduces_qe_progressively() {
        let mut rng = Rng::new(32);
        let (data, _) = crate::data::gaussian_blobs(60, 4, 3, 0.1, &mut rng);
        let cfg = small_cfg();
        let grid = cfg.grid();
        let mut cb = Codebook::random_init(grid.node_count(), 4, &mut rng);
        let shard = DataShard::Dense { data: &data, dim: 4 };
        let (_, qe0) = train_one_epoch(&cfg, shard, &mut cb, 0).unwrap();
        let mut qe_last = qe0;
        for e in 1..cfg.epochs {
            let (_, qe) = train_one_epoch(&cfg, shard, &mut cb, e).unwrap();
            qe_last = qe;
        }
        assert!(qe_last < qe0, "{qe0} -> {qe_last}");
    }

    /// The caller-owned-state shim must be step-for-step identical to a
    /// session stepping its own codebook.
    #[test]
    #[allow(deprecated)]
    fn one_epoch_shim_matches_session_steps() {
        let mut rng = Rng::new(34);
        let (data, _) = crate::data::gaussian_blobs(40, 4, 3, 0.2, &mut rng);
        let cfg = small_cfg();
        let grid = cfg.grid();
        let mut rng2 = Rng::new(77);
        let init = Codebook::random_init(grid.node_count(), 4, &mut rng2);
        let shard = DataShard::Dense { data: &data, dim: 4 };

        let mut cb = init.clone();
        let mut shim_bmus = Vec::new();
        for e in 0..cfg.epochs {
            let (bmus, _) = train_one_epoch(&cfg, shard, &mut cb, e).unwrap();
            shim_bmus = bmus;
        }

        let mut session = Som::builder()
            .config(cfg.clone())
            .initial_codebook(init)
            .build()
            .unwrap();
        for _ in 0..cfg.epochs {
            session
                .step_epoch(DataInput::BorrowedF32 { data: &data, dim: 4 })
                .unwrap();
        }
        assert_eq!(cb.weights, session.codebook().unwrap().weights);
        assert_eq!(shim_bmus, session.last_bmus());
    }

    #[test]
    #[allow(deprecated)]
    fn sparse_input_works() {
        let mut rng = Rng::new(33);
        let m = Csr::random(40, 16, 0.2, &mut rng);
        let mut cfg = small_cfg();
        cfg.kernel = crate::kernels::KernelType::SparseCpu;
        let res = train(&cfg, DataInput::Sparse(&m)).unwrap();
        assert_eq!(res.bmus.len(), 40);
    }
}
