//! CSR sparse matrix — the substrate behind the paper's sparse kernel.
//!
//! §3.1: "A vector space coming from a text processing pipeline typically
//! contains 1–5% nonzero elements, leading to a 20–100× reduction in
//! memory use when using a sparse representation." CSR stores row
//! pointers + (col, value) pairs, so memory is `8·nnz + 8·(rows+1)`
//! bytes vs `4·rows·cols` dense.

use crate::util::rng::Rng;

/// A borrowed CSR row window — the sparse analog of a dense `&[f32]`
/// chunk, and the type training kernels consume ([`crate::kernels::DataShard::Sparse`]).
///
/// Invariants: `indptr` is rebased to the window (`indptr[0] == 0`,
/// `len == rows + 1`); `indices`/`values` cover exactly this window's
/// nonzeros. Because every field is a borrow, a chunk view can point
/// straight into an owned [`Csr`], a reusable scratch buffer, or a
/// memory-mapped file (`io::mmap`) — the zero-copy streaming path hands
/// kernels views whose `indices`/`values` live in the OS page cache.
#[derive(Copy, Clone, Debug, PartialEq)]
pub struct CsrView<'a> {
    pub rows: usize,
    pub cols: usize,
    /// Row pointers, len = rows + 1, `indptr[0] == 0`.
    pub indptr: &'a [usize],
    /// Column indices, len = nnz, strictly increasing within a row.
    pub indices: &'a [u32],
    /// Values, len = nnz.
    pub values: &'a [f32],
}

impl<'a> CsrView<'a> {
    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    /// One row as (cols, vals) slices. The `'a` lifetime (not `&self`)
    /// lets callers hold rows across view copies.
    #[inline]
    pub fn row(&self, r: usize) -> (&'a [u32], &'a [f32]) {
        let (a, b) = (self.indptr[r], self.indptr[r + 1]);
        (&self.indices[a..b], &self.values[a..b])
    }

    /// Squared L2 norm of one row. The sparse kernel evaluates this
    /// inside its row-parallel search loop (each worker covers its own
    /// rows — no serial pre-pass, no materialized norms vector); keep
    /// the summation sequential in storage order so the value stays
    /// bit-identical to a [`Self::row_sq_norms`] entry.
    #[inline]
    pub fn row_sq_norm(&self, r: usize) -> f32 {
        let (_, vals) = self.row(r);
        vals.iter().map(|v| v * v).sum()
    }

    /// Squared L2 norm per row (see [`Self::row_sq_norm`]).
    pub fn row_sq_norms(&self) -> Vec<f32> {
        (0..self.rows).map(|r| self.row_sq_norm(r)).collect()
    }

    /// Densify (tests and the accel-kernel bridge).
    pub fn to_dense(&self) -> Vec<f32> {
        let mut out = vec![0.0; self.rows * self.cols];
        for r in 0..self.rows {
            let (cols, vals) = self.row(r);
            for (c, v) in cols.iter().zip(vals) {
                out[r * self.cols + *c as usize] = *v;
            }
        }
        out
    }

    /// Logical bytes this view spans — the gauge currency for borrowed
    /// chunks (length-based: a view owns no capacity).
    pub fn data_bytes(&self) -> usize {
        self.indptr.len() * std::mem::size_of::<usize>()
            + self.indices.len() * std::mem::size_of::<u32>()
            + self.values.len() * std::mem::size_of::<f32>()
    }
}

/// Compressed sparse row matrix, f32 values.
#[derive(Clone, Debug, PartialEq)]
pub struct Csr {
    pub rows: usize,
    pub cols: usize,
    /// Row pointers, len = rows + 1.
    pub indptr: Vec<usize>,
    /// Column indices, len = nnz, strictly increasing within a row.
    pub indices: Vec<u32>,
    /// Values, len = nnz.
    pub values: Vec<f32>,
}

impl Csr {
    pub fn new_empty(rows: usize, cols: usize) -> Self {
        Csr {
            rows,
            cols,
            indptr: vec![0; rows + 1],
            indices: Vec::new(),
            values: Vec::new(),
        }
    }

    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    /// Borrow the whole matrix as a [`CsrView`] (what
    /// [`crate::kernels::DataShard`] carries; `indptr[0] == 0` holds for
    /// any well-formed `Csr`).
    #[inline]
    pub fn view(&self) -> CsrView<'_> {
        CsrView {
            rows: self.rows,
            cols: self.cols,
            indptr: &self.indptr,
            indices: &self.indices,
            values: &self.values,
        }
    }

    /// Density in [0, 1].
    pub fn density(&self) -> f64 {
        if self.rows == 0 || self.cols == 0 {
            return 0.0;
        }
        self.nnz() as f64 / (self.rows as f64 * self.cols as f64)
    }

    /// Approximate heap bytes held by this matrix (the number the paper's
    /// memory comparison uses).
    pub fn heap_bytes(&self) -> usize {
        self.indptr.capacity() * std::mem::size_of::<usize>()
            + self.indices.capacity() * std::mem::size_of::<u32>()
            + self.values.capacity() * std::mem::size_of::<f32>()
    }

    /// One row as (cols, vals) slices.
    #[inline]
    pub fn row(&self, r: usize) -> (&[u32], &[f32]) {
        let (a, b) = (self.indptr[r], self.indptr[r + 1]);
        (&self.indices[a..b], &self.values[a..b])
    }

    /// Build from dense row-major data, keeping |v| > threshold entries.
    pub fn from_dense(data: &[f32], rows: usize, cols: usize, threshold: f32) -> Self {
        assert_eq!(data.len(), rows * cols);
        let mut m = Csr::new_empty(rows, cols);
        for r in 0..rows {
            for c in 0..cols {
                let v = data[r * cols + c];
                if v.abs() > threshold {
                    m.indices.push(c as u32);
                    m.values.push(v);
                }
            }
            m.indptr[r + 1] = m.values.len();
        }
        m
    }

    /// Build from per-row (col, value) pair lists. Pairs are sorted and
    /// duplicate columns rejected.
    pub fn from_rows(
        rows: Vec<Vec<(u32, f32)>>,
        cols: usize,
    ) -> Result<Self, String> {
        let mut m = Csr::new_empty(rows.len(), cols);
        for (r, mut row) in rows.into_iter().enumerate() {
            row.sort_by_key(|(c, _)| *c);
            for w in row.windows(2) {
                if w[0].0 == w[1].0 {
                    return Err(format!("duplicate column {} in row {r}", w[0].0));
                }
            }
            for (c, v) in row {
                if c as usize >= cols {
                    return Err(format!(
                        "column {c} out of range (cols = {cols}) in row {r}"
                    ));
                }
                m.indices.push(c);
                m.values.push(v);
            }
            m.indptr[r + 1] = m.values.len();
        }
        Ok(m)
    }

    /// Densify (tests and the accel-kernel bridge; the paper notes the GPU
    /// kernel has no sparse variant).
    pub fn to_dense(&self) -> Vec<f32> {
        self.view().to_dense()
    }

    /// Squared L2 norm per row (precomputed once per training run; the
    /// sparse kernel's distance uses ||x||² + ||w||² − 2 x·w with dense w).
    pub fn row_sq_norms(&self) -> Vec<f32> {
        self.view().row_sq_norms()
    }

    /// Slice out a contiguous row range as a new CSR (data sharding for
    /// the distributed runner).
    pub fn slice_rows(&self, range: std::ops::Range<usize>) -> Csr {
        let (a, b) = (self.indptr[range.start], self.indptr[range.end]);
        let mut indptr: Vec<usize> =
            self.indptr[range.start..=range.end].to_vec();
        for p in indptr.iter_mut() {
            *p -= a;
        }
        Csr {
            rows: range.len(),
            cols: self.cols,
            indptr,
            indices: self.indices[a..b].to_vec(),
            values: self.values[a..b].to_vec(),
        }
    }

    /// Random sparse matrix with ~`density` nonzeros per row, values in
    /// [0, 1) (the Fig. 6 workload: 1000 dims, 5% nonzero).
    pub fn random(rows: usize, cols: usize, density: f64, rng: &mut Rng) -> Self {
        let per_row = ((cols as f64 * density).round() as usize).clamp(1, cols);
        let mut m = Csr::new_empty(rows, cols);
        for r in 0..rows {
            let mut idx = rng.sample_indices(cols, per_row);
            idx.sort_unstable();
            for c in idx {
                m.indices.push(c as u32);
                m.values.push(rng.f32());
            }
            m.indptr[r + 1] = m.values.len();
        }
        m
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::prop;

    #[test]
    fn dense_round_trip() {
        let dense = vec![
            1.0, 0.0, 2.0, //
            0.0, 0.0, 0.0, //
            0.0, 3.5, 0.0,
        ];
        let m = Csr::from_dense(&dense, 3, 3, 0.0);
        assert_eq!(m.nnz(), 3);
        assert_eq!(m.to_dense(), dense);
        assert_eq!(m.row(0), (&[0u32, 2][..], &[1.0f32, 2.0][..]));
        assert_eq!(m.row(1).0.len(), 0);
    }

    #[test]
    fn from_rows_sorts_and_validates() {
        let m = Csr::from_rows(vec![vec![(3, 1.0), (1, 2.0)]], 5).unwrap();
        assert_eq!(m.row(0), (&[1u32, 3][..], &[2.0f32, 1.0][..]));
        assert!(Csr::from_rows(vec![vec![(1, 1.0), (1, 2.0)]], 5).is_err());
        assert!(Csr::from_rows(vec![vec![(9, 1.0)]], 5).is_err());
    }

    #[test]
    fn row_norms() {
        let m = Csr::from_rows(vec![vec![(0, 3.0), (2, 4.0)], vec![]], 3).unwrap();
        assert_eq!(m.row_sq_norms(), vec![25.0, 0.0]);
    }

    #[test]
    fn slice_rows_matches_dense_slice() {
        let mut rng = Rng::new(4);
        let m = Csr::random(10, 8, 0.4, &mut rng);
        let s = m.slice_rows(3..7);
        let dense = m.to_dense();
        assert_eq!(s.to_dense(), dense[3 * 8..7 * 8].to_vec());
    }

    #[test]
    fn random_density() {
        let mut rng = Rng::new(1);
        let m = Csr::random(100, 1000, 0.05, &mut rng);
        assert!((m.density() - 0.05).abs() < 0.005, "{}", m.density());
        // paper's claim territory: sparse rep much smaller than dense
        let dense_bytes = 100 * 1000 * 4;
        assert!(m.heap_bytes() * 4 < dense_bytes);
    }

    #[test]
    fn prop_round_trip_and_slice() {
        prop::check("csr-roundtrip", |g| {
            let rows = g.usize_in(1, 12);
            let cols = g.usize_in(1, 12);
            let dense = g.vec_f32(rows * cols, -1.0, 1.0);
            // Threshold some entries to zero to get real sparsity.
            let dense: Vec<f32> = dense
                .into_iter()
                .map(|v| if v.abs() < 0.5 { 0.0 } else { v })
                .collect();
            let m = Csr::from_dense(&dense, rows, cols, 0.0);
            prop_assert!(m.to_dense() == dense, "roundtrip failed");
            let lo = g.usize_in(0, rows);
            let hi = g.usize_in(lo, rows);
            let s = m.slice_rows(lo..hi);
            prop_assert!(
                s.to_dense() == dense[lo * cols..hi * cols].to_vec(),
                "slice {lo}..{hi} failed"
            );
            Ok(())
        });
    }
}
