//! L3 coordination: configuration, the training loop, and the
//! single-vs-distributed drivers (the paper's system contribution).

pub mod config;
pub mod train;

pub use config::TrainConfig;
pub use train::{train, EpochStats, TrainResult};
