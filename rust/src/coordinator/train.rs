//! Single-process training support: kernel construction, codebook
//! initialization, and the per-epoch stats record.
//!
//! The epoch loop itself lives in [`crate::session::SomSession`] (one
//! chunk loop serves the resident, streamed, and cluster paths). The
//! pre-0.2 `train`/`train_stream` free functions are gone; build a
//! session:
//!
//! ```
//! use somoclu::api::DataInput;
//! use somoclu::session::Som;
//! let data = vec![0.5f32; 40];
//! let mut session = Som::builder().map_size(4, 4).epochs(2).threads(1).build().unwrap();
//! let res = session.fit(DataInput::BorrowedF32 { data: &data, dim: 4 }).unwrap();
//! assert_eq!(res.bmus.len(), 10);
//! ```

use std::time::Duration;

use crate::coordinator::config::TrainConfig;
use crate::kernels::dense_cpu::DenseCpuKernel;
use crate::kernels::sparse_cpu::SparseCpuKernel;
use crate::kernels::{DataShard, KernelType, TrainingKernel};
use crate::som::{Codebook, Grid};
use crate::util::rng::Rng;

/// Per-epoch progress record (QE curve + timing).
#[derive(Clone, Debug)]
pub struct EpochStats {
    pub epoch: usize,
    pub radius: f32,
    pub scale: f32,
    /// Mean quantization error *before* this epoch's update (the error
    /// of the codebook the BMUs were computed against).
    pub qe: f64,
    pub duration: Duration,
}

/// Final result of a training run.
#[derive(Debug)]
pub struct TrainResult {
    pub codebook: Codebook,
    pub bmus: Vec<u32>,
    pub umatrix: Vec<f32>,
    pub epochs: Vec<EpochStats>,
    pub total: Duration,
}

impl TrainResult {
    pub fn final_qe(&self) -> f64 {
        self.epochs.last().map(|e| e.qe).unwrap_or(f64::NAN)
    }
}

/// Build the kernel for `cfg` (single-process path). The accel kernel
/// needs AOT artifacts on disk; see [`crate::runtime`].
pub fn make_kernel(cfg: &TrainConfig) -> anyhow::Result<Box<dyn TrainingKernel>> {
    Ok(match cfg.kernel {
        KernelType::DenseCpu => Box::new(DenseCpuKernel::new(cfg.threads)),
        KernelType::SparseCpu => Box::new(SparseCpuKernel::new(cfg.threads)),
        KernelType::Accel => Box::new(crate::kernels::accel::AccelKernel::from_env()?),
        KernelType::Hybrid => {
            Box::new(crate::kernels::hybrid::HybridKernel::from_env(cfg.threads)?)
        }
    })
}

/// Initialize the codebook per config (random init, like `-c` absent).
/// Used directly by the cluster runner's broadcast-equivalent init.
pub fn init_codebook(cfg: &TrainConfig, grid: &Grid, dim: usize) -> Codebook {
    let mut rng = Rng::new(cfg.seed);
    Codebook::random_init(grid.node_count(), dim, &mut rng)
}

/// Initialization honoring `cfg.initialization` (PCA needs the data).
pub fn init_codebook_with_data(
    cfg: &TrainConfig,
    grid: &Grid,
    shard: DataShard<'_>,
) -> anyhow::Result<Codebook> {
    match cfg.initialization {
        crate::coordinator::config::Initialization::Random => {
            Ok(init_codebook(cfg, grid, shard.dim()))
        }
        crate::coordinator::config::Initialization::Pca => {
            let DataShard::Dense { data, dim } = shard else {
                anyhow::bail!(
                    "PCA initialization needs dense data (densify or use \
                     random init for sparse inputs)"
                );
            };
            let mut rng = Rng::new(cfg.seed);
            Ok(crate::som::pca::pca_init(grid, data, dim, &mut rng))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::DataInput;
    use crate::data;
    use crate::session::Som;
    use crate::som::{GridType, MapType, Neighborhood};

    fn blob_config() -> TrainConfig {
        TrainConfig {
            rows: 8,
            cols: 8,
            epochs: 8,
            threads: 2,
            radius0: Some(4.0),
            ..Default::default()
        }
    }

    fn fit(cfg: &TrainConfig, shard: DataShard<'_>) -> anyhow::Result<TrainResult> {
        Som::builder().config(cfg.clone()).build()?.fit_shard(shard)
    }

    #[test]
    fn qe_decreases_on_blobs() {
        let mut rng = Rng::new(1);
        let (data, _) = data::gaussian_blobs(160, 6, 4, 0.1, &mut rng);
        let cfg = blob_config();
        let res = fit(&cfg, DataShard::Dense { data: &data, dim: 6 }).unwrap();
        assert_eq!(res.epochs.len(), 8);
        let first = res.epochs.first().unwrap().qe;
        let last = res.epochs.last().unwrap().qe;
        assert!(
            last < first * 0.5,
            "QE did not converge: {first} -> {last}"
        );
        assert_eq!(res.bmus.len(), 160);
        assert!(res.umatrix.len() == 64);
    }

    #[test]
    fn deterministic_given_seed() {
        let mut rng = Rng::new(2);
        let (data, _) = data::gaussian_blobs(60, 4, 3, 0.1, &mut rng);
        let cfg = blob_config();
        let shard = DataShard::Dense { data: &data, dim: 4 };
        let a = fit(&cfg, shard).unwrap();
        let b = fit(&cfg, shard).unwrap();
        assert_eq!(a.codebook.weights, b.codebook.weights);
        assert_eq!(a.bmus, b.bmus);
    }

    /// `fit_shard` and `fit_source` over an in-memory source are the
    /// same path (the equivalence the pre-0.2 `train` shim delegated
    /// through, now stated directly against the session API).
    #[test]
    fn fit_shard_matches_fit_source() {
        let mut rng = Rng::new(21);
        let (data, _) = data::gaussian_blobs(60, 4, 3, 0.1, &mut rng);
        let cfg = blob_config();
        let shard = DataShard::Dense { data: &data, dim: 4 };
        let via_shard = fit(&cfg, shard).unwrap();
        let mut source =
            crate::io::stream::InMemorySource::new(shard, cfg.chunk_rows);
        let via_source = Som::builder()
            .config(cfg.clone())
            .build()
            .unwrap()
            .fit_source(&mut source)
            .unwrap();
        assert_eq!(via_source.codebook.weights, via_shard.codebook.weights);
        assert_eq!(via_source.bmus, via_shard.bmus);
        assert_eq!(via_source.epochs.len(), via_shard.epochs.len());
    }

    #[test]
    fn sparse_kernel_trains() {
        let mut rng = Rng::new(3);
        let m = crate::sparse::Csr::random(80, 30, 0.2, &mut rng);
        let cfg = TrainConfig {
            rows: 6,
            cols: 6,
            epochs: 5,
            kernel: crate::kernels::KernelType::SparseCpu,
            threads: 2,
            radius0: Some(3.0),
            ..Default::default()
        };
        let res = fit(&cfg, DataShard::Sparse(m.view())).unwrap();
        let first = res.epochs.first().unwrap().qe;
        let last = res.epochs.last().unwrap().qe;
        assert!(last < first, "{first} -> {last}");
    }

    #[test]
    fn all_geometry_variants_run() {
        let mut rng = Rng::new(4);
        let (data, _) = data::gaussian_blobs(40, 3, 2, 0.2, &mut rng);
        for gt in [GridType::Square, GridType::Hexagonal] {
            for mt in [MapType::Planar, MapType::Toroid] {
                for nb in [
                    Neighborhood::gaussian(false),
                    Neighborhood::gaussian(true),
                    Neighborhood::bubble(),
                ] {
                    let cfg = TrainConfig {
                        rows: 5,
                        cols: 5,
                        epochs: 3,
                        grid_type: gt,
                        map_type: mt,
                        neighborhood: nb,
                        threads: 2,
                        radius0: Some(2.5),
                        ..Default::default()
                    };
                    let res =
                        fit(&cfg, DataShard::Dense { data: &data, dim: 3 }).unwrap();
                    assert!(res.final_qe().is_finite());
                }
            }
        }
    }

    #[test]
    fn chunked_training_matches_in_memory() {
        let mut rng = Rng::new(6);
        let (data, _) = data::gaussian_blobs(90, 5, 3, 0.15, &mut rng);
        let shard = DataShard::Dense { data: &data, dim: 5 };
        let whole = fit(&blob_config(), shard).unwrap();
        for chunk_rows in [1usize, 7, 90, 1000] {
            let cfg = TrainConfig {
                chunk_rows,
                ..blob_config()
            };
            let chunked = fit(&cfg, shard).unwrap();
            assert_eq!(chunked.bmus, whole.bmus, "chunk_rows={chunk_rows}");
            assert!(
                (chunked.final_qe() - whole.final_qe()).abs() < 1e-4,
                "chunk_rows={chunk_rows}: QE {} vs {}",
                chunked.final_qe(),
                whole.final_qe()
            );
        }
    }

    #[test]
    fn chunked_sparse_training_matches_in_memory() {
        let mut rng = Rng::new(13);
        let m = crate::sparse::Csr::random(70, 24, 0.2, &mut rng);
        let base = TrainConfig {
            rows: 6,
            cols: 6,
            epochs: 5,
            kernel: crate::kernels::KernelType::SparseCpu,
            threads: 2,
            radius0: Some(3.0),
            ..Default::default()
        };
        let whole = fit(&base, DataShard::Sparse(m.view())).unwrap();
        for chunk_rows in [1usize, 11, 70] {
            let cfg = TrainConfig {
                chunk_rows,
                ..base.clone()
            };
            let chunked = fit(&cfg, DataShard::Sparse(m.view())).unwrap();
            assert_eq!(chunked.bmus, whole.bmus, "chunk_rows={chunk_rows}");
            assert!(
                (chunked.final_qe() - whole.final_qe()).abs() < 1e-4,
                "chunk_rows={chunk_rows}"
            );
        }
    }

    #[test]
    fn streamed_pca_init_requires_resident_data() {
        // A file-backed source cannot serve PCA init; the error must be
        // actionable rather than a panic.
        let dir = std::env::temp_dir()
            .join(format!("somoclu_train_stream_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("pca.txt");
        let mut rng = Rng::new(14);
        let (data, _) = data::gaussian_blobs(20, 3, 2, 0.2, &mut rng);
        crate::io::dense::write_dense(&path, 20, 3, &data, false).unwrap();
        let mut src = crate::io::stream::ChunkedDenseFileSource::open(&path, 4).unwrap();
        let cfg = TrainConfig {
            rows: 4,
            cols: 4,
            epochs: 2,
            initialization: crate::coordinator::config::Initialization::Pca,
            radius0: Some(2.0),
            ..Default::default()
        };
        let err = Som::builder()
            .config(cfg)
            .build()
            .unwrap()
            .fit_source(&mut src);
        assert!(err.is_err());
        assert!(format!("{:#}", err.unwrap_err()).contains("resident"));
    }

    #[test]
    fn initial_codebook_shape_checked() {
        let bad = Codebook::zeros(4, 6); // wrong node count
        let err = Som::builder()
            .config(blob_config())
            .initial_codebook(bad)
            .build();
        assert!(err.is_err());
    }

    #[test]
    fn radius_and_scale_follow_schedules() {
        let mut rng = Rng::new(5);
        let (data, _) = data::gaussian_blobs(30, 3, 2, 0.2, &mut rng);
        let cfg = TrainConfig {
            rows: 4,
            cols: 4,
            epochs: 4,
            radius0: Some(2.0),
            radius_n: 1.0,
            scale0: 1.0,
            scale_n: 0.1,
            threads: 1,
            ..Default::default()
        };
        let mut session = Som::builder().config(cfg).build().unwrap();
        let res = session
            .fit(DataInput::BorrowedF32 { data: &data, dim: 3 })
            .unwrap();
        assert_eq!(res.epochs[0].radius, 2.0);
        assert_eq!(res.epochs[3].radius, 1.0);
        assert_eq!(res.epochs[0].scale, 1.0);
        assert!((res.epochs[3].scale - 0.1).abs() < 1e-6);
    }
}
