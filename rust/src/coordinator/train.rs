//! The training loop (single-process path): epoch iteration, cooling,
//! kernel dispatch, snapshots, and quality logging — the body of the
//! paper's `trainOneEpoch` driven across epochs.
//!
//! The loop is written against [`DataSource`], so one code path serves
//! both the classic resident-shard mode and out-of-core streaming
//! (`--chunk-rows`): each epoch accumulates bounded chunks, merging the
//! partial Eq. 6 accumulators (`EpochAccum::merge`, the same operator the
//! cluster allreduce uses) and reassembling BMUs in chunk order.

use std::time::{Duration, Instant};

use crate::coordinator::config::TrainConfig;
use crate::io::output::OutputWriter;
use crate::io::stream::{DataSource, InMemorySource};
use crate::kernels::dense_cpu::DenseCpuKernel;
use crate::kernels::sparse_cpu::SparseCpuKernel;
use crate::kernels::{DataShard, EpochAccum, KernelType, TrainingKernel};
use crate::som::{umatrix, Codebook, Grid};
use crate::util::rng::Rng;

/// Per-epoch progress record (QE curve + timing).
#[derive(Clone, Debug)]
pub struct EpochStats {
    pub epoch: usize,
    pub radius: f32,
    pub scale: f32,
    /// Mean quantization error *before* this epoch's update (the error
    /// of the codebook the BMUs were computed against).
    pub qe: f64,
    pub duration: Duration,
}

/// Final result of a training run.
#[derive(Debug)]
pub struct TrainResult {
    pub codebook: Codebook,
    pub bmus: Vec<u32>,
    pub umatrix: Vec<f32>,
    pub epochs: Vec<EpochStats>,
    pub total: Duration,
}

impl TrainResult {
    pub fn final_qe(&self) -> f64 {
        self.epochs.last().map(|e| e.qe).unwrap_or(f64::NAN)
    }
}

/// Build the kernel for `cfg` (single-process path). The accel kernel
/// needs AOT artifacts on disk; see [`crate::runtime`].
pub fn make_kernel(cfg: &TrainConfig) -> anyhow::Result<Box<dyn TrainingKernel>> {
    Ok(match cfg.kernel {
        KernelType::DenseCpu => Box::new(DenseCpuKernel::new(cfg.threads)),
        KernelType::SparseCpu => Box::new(SparseCpuKernel::new(cfg.threads)),
        KernelType::Accel => Box::new(crate::kernels::accel::AccelKernel::from_env()?),
        KernelType::Hybrid => {
            Box::new(crate::kernels::hybrid::HybridKernel::from_env(cfg.threads)?)
        }
    })
}

/// Initialize the codebook per config (random init, like `-c` absent).
/// Used directly by the cluster runner's broadcast-equivalent init.
pub fn init_codebook(cfg: &TrainConfig, grid: &Grid, dim: usize) -> Codebook {
    let mut rng = Rng::new(cfg.seed);
    Codebook::random_init(grid.node_count(), dim, &mut rng)
}

/// Initialization honoring `cfg.initialization` (PCA needs the data).
pub fn init_codebook_with_data(
    cfg: &TrainConfig,
    grid: &Grid,
    shard: DataShard<'_>,
) -> anyhow::Result<Codebook> {
    match cfg.initialization {
        crate::coordinator::config::Initialization::Random => {
            Ok(init_codebook(cfg, grid, shard.dim()))
        }
        crate::coordinator::config::Initialization::Pca => {
            let DataShard::Dense { data, dim } = shard else {
                anyhow::bail!(
                    "PCA initialization needs dense data (densify or use \
                     random init for sparse inputs)"
                );
            };
            let mut rng = Rng::new(cfg.seed);
            Ok(crate::som::pca::pca_init(grid, data, dim, &mut rng))
        }
    }
}

/// Train on one in-memory shard (the whole data set on the single-node
/// path). `writer` enables interim snapshots (paper `-s`). With
/// `cfg.chunk_rows > 0` the shard is processed in bounded windows — this
/// is a thin wrapper over [`train_stream`].
pub fn train(
    cfg: &TrainConfig,
    shard: DataShard<'_>,
    initial: Option<Codebook>,
    writer: Option<&OutputWriter>,
) -> anyhow::Result<TrainResult> {
    let mut source = InMemorySource::new(shard, cfg.chunk_rows);
    train_stream(cfg, &mut source, initial, writer)
}

/// Train over any [`DataSource`] — the out-of-core entry point. Each
/// epoch resets the source and folds its chunks into one Eq. 6
/// accumulator; file-backed sources keep data memory at
/// O(chunk_rows * dim) regardless of total rows.
pub fn train_stream(
    cfg: &TrainConfig,
    source: &mut dyn DataSource,
    initial: Option<Codebook>,
    writer: Option<&OutputWriter>,
) -> anyhow::Result<TrainResult> {
    cfg.validate().map_err(|e| anyhow::anyhow!(e))?;
    let grid = cfg.grid();
    let dim = source.dim();
    let rows = source.rows();
    anyhow::ensure!(rows > 0, "no data rows");

    let mut codebook = match initial {
        Some(cb) => {
            anyhow::ensure!(
                cb.nodes == grid.node_count() && cb.dim == dim,
                "initial codebook shape {}x{} does not match map {}x{} / dim {dim}",
                cb.nodes,
                cb.dim,
                grid.node_count(),
                grid.rows * grid.cols
            );
            cb
        }
        // Random init never touches the data, so only data-dependent
        // schemes consult `resident()` — which lets zero-copy sources
        // account a full-file exposure there without charging bounded
        // random-init runs for it.
        None if cfg.initialization
            == crate::coordinator::config::Initialization::Random =>
        {
            init_codebook(cfg, &grid, dim)
        }
        None => match source.resident() {
            Some(shard) => init_codebook_with_data(cfg, &grid, shard)?,
            None => {
                anyhow::bail!(
                    "PCA initialization needs the data resident in memory; \
                     streamed sources support only --initialization random \
                     (or an explicit -c codebook)"
                );
            }
        },
    };

    let radius_sched = cfg.radius_schedule(&grid);
    let scale_sched = cfg.scale_schedule();
    let mut kernel = make_kernel(cfg)?;

    let t0 = Instant::now();
    let mut epochs = Vec::with_capacity(cfg.epochs);
    let mut bmus: Vec<u32> = Vec::new();

    for epoch in 0..cfg.epochs {
        let te = Instant::now();
        let radius = radius_sched.at(epoch);
        let scale = scale_sched.at(epoch);

        kernel.epoch_begin(&codebook)?;
        source.reset()?;
        let mut accum = EpochAccum::zeros(grid.node_count(), dim, 0);
        let mut epoch_bmus: Vec<u32> = Vec::with_capacity(rows);
        while let Some(chunk) = source.next_chunk()? {
            let part = kernel.epoch_accumulate(
                chunk,
                &codebook,
                &grid,
                cfg.neighborhood,
                radius,
                scale,
            )?;
            epoch_bmus.extend_from_slice(&part.bmus);
            accum.merge(&part);
        }
        anyhow::ensure!(
            epoch_bmus.len() == rows,
            "data source produced {} rows this epoch, expected {rows}",
            epoch_bmus.len()
        );
        codebook.apply_batch_update(&accum.num, &accum.den);
        bmus = epoch_bmus;

        epochs.push(EpochStats {
            epoch,
            radius,
            scale,
            qe: accum.qe_sum / rows as f64,
            duration: te.elapsed(),
        });

        if let Some(w) = writer {
            if cfg.snapshot > crate::io::output::SnapshotLevel::None {
                let u = umatrix::umatrix(&grid, &codebook, cfg.threads);
                w.write_snapshot(cfg.snapshot, epoch, &grid, &codebook, &bmus, &u)?;
            }
        }
    }

    let u = umatrix::umatrix(&grid, &codebook, cfg.threads);
    if let Some(w) = writer {
        w.write_final(&grid, &codebook, &bmus, &u)?;
    }

    Ok(TrainResult {
        codebook,
        bmus,
        umatrix: u,
        epochs,
        total: t0.elapsed(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data;
    use crate::som::{GridType, MapType, Neighborhood};

    fn blob_config() -> TrainConfig {
        TrainConfig {
            rows: 8,
            cols: 8,
            epochs: 8,
            threads: 2,
            radius0: Some(4.0),
            ..Default::default()
        }
    }

    #[test]
    fn qe_decreases_on_blobs() {
        let mut rng = Rng::new(1);
        let (data, _) = data::gaussian_blobs(160, 6, 4, 0.1, &mut rng);
        let cfg = blob_config();
        let res = train(
            &cfg,
            DataShard::Dense { data: &data, dim: 6 },
            None,
            None,
        )
        .unwrap();
        assert_eq!(res.epochs.len(), 8);
        let first = res.epochs.first().unwrap().qe;
        let last = res.epochs.last().unwrap().qe;
        assert!(
            last < first * 0.5,
            "QE did not converge: {first} -> {last}"
        );
        assert_eq!(res.bmus.len(), 160);
        assert!(res.umatrix.len() == 64);
    }

    #[test]
    fn deterministic_given_seed() {
        let mut rng = Rng::new(2);
        let (data, _) = data::gaussian_blobs(60, 4, 3, 0.1, &mut rng);
        let cfg = blob_config();
        let shard = DataShard::Dense { data: &data, dim: 4 };
        let a = train(&cfg, shard, None, None).unwrap();
        let b = train(&cfg, shard, None, None).unwrap();
        assert_eq!(a.codebook.weights, b.codebook.weights);
        assert_eq!(a.bmus, b.bmus);
    }

    #[test]
    fn sparse_kernel_trains() {
        let mut rng = Rng::new(3);
        let m = crate::sparse::Csr::random(80, 30, 0.2, &mut rng);
        let cfg = TrainConfig {
            rows: 6,
            cols: 6,
            epochs: 5,
            kernel: crate::kernels::KernelType::SparseCpu,
            threads: 2,
            radius0: Some(3.0),
            ..Default::default()
        };
        let res = train(&cfg, DataShard::Sparse(m.view()), None, None).unwrap();
        let first = res.epochs.first().unwrap().qe;
        let last = res.epochs.last().unwrap().qe;
        assert!(last < first, "{first} -> {last}");
    }

    #[test]
    fn all_geometry_variants_run() {
        let mut rng = Rng::new(4);
        let (data, _) = data::gaussian_blobs(40, 3, 2, 0.2, &mut rng);
        for gt in [GridType::Square, GridType::Hexagonal] {
            for mt in [MapType::Planar, MapType::Toroid] {
                for nb in [
                    Neighborhood::gaussian(false),
                    Neighborhood::gaussian(true),
                    Neighborhood::bubble(),
                ] {
                    let cfg = TrainConfig {
                        rows: 5,
                        cols: 5,
                        epochs: 3,
                        grid_type: gt,
                        map_type: mt,
                        neighborhood: nb,
                        threads: 2,
                        radius0: Some(2.5),
                        ..Default::default()
                    };
                    let res = train(
                        &cfg,
                        DataShard::Dense { data: &data, dim: 3 },
                        None,
                        None,
                    )
                    .unwrap();
                    assert!(res.final_qe().is_finite());
                }
            }
        }
    }

    #[test]
    fn chunked_training_matches_in_memory() {
        let mut rng = Rng::new(6);
        let (data, _) = data::gaussian_blobs(90, 5, 3, 0.15, &mut rng);
        let shard = DataShard::Dense { data: &data, dim: 5 };
        let whole = train(&blob_config(), shard, None, None).unwrap();
        for chunk_rows in [1usize, 7, 90, 1000] {
            let cfg = TrainConfig {
                chunk_rows,
                ..blob_config()
            };
            let chunked = train(&cfg, shard, None, None).unwrap();
            assert_eq!(chunked.bmus, whole.bmus, "chunk_rows={chunk_rows}");
            assert!(
                (chunked.final_qe() - whole.final_qe()).abs() < 1e-4,
                "chunk_rows={chunk_rows}: QE {} vs {}",
                chunked.final_qe(),
                whole.final_qe()
            );
        }
    }

    #[test]
    fn chunked_sparse_training_matches_in_memory() {
        let mut rng = Rng::new(13);
        let m = crate::sparse::Csr::random(70, 24, 0.2, &mut rng);
        let base = TrainConfig {
            rows: 6,
            cols: 6,
            epochs: 5,
            kernel: crate::kernels::KernelType::SparseCpu,
            threads: 2,
            radius0: Some(3.0),
            ..Default::default()
        };
        let whole = train(&base, DataShard::Sparse(m.view()), None, None).unwrap();
        for chunk_rows in [1usize, 11, 70] {
            let cfg = TrainConfig {
                chunk_rows,
                ..base.clone()
            };
            let chunked = train(&cfg, DataShard::Sparse(m.view()), None, None).unwrap();
            assert_eq!(chunked.bmus, whole.bmus, "chunk_rows={chunk_rows}");
            assert!(
                (chunked.final_qe() - whole.final_qe()).abs() < 1e-4,
                "chunk_rows={chunk_rows}"
            );
        }
    }

    #[test]
    fn streamed_pca_init_requires_resident_data() {
        // A file-backed source cannot serve PCA init; the error must be
        // actionable rather than a panic.
        let dir = std::env::temp_dir()
            .join(format!("somoclu_train_stream_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("pca.txt");
        let mut rng = Rng::new(14);
        let (data, _) = data::gaussian_blobs(20, 3, 2, 0.2, &mut rng);
        crate::io::dense::write_dense(&path, 20, 3, &data, false).unwrap();
        let mut src = crate::io::stream::ChunkedDenseFileSource::open(&path, 4).unwrap();
        let cfg = TrainConfig {
            rows: 4,
            cols: 4,
            epochs: 2,
            initialization: crate::coordinator::config::Initialization::Pca,
            radius0: Some(2.0),
            ..Default::default()
        };
        let err = train_stream(&cfg, &mut src, None, None);
        assert!(err.is_err());
        assert!(format!("{:#}", err.unwrap_err()).contains("resident"));
    }

    #[test]
    fn initial_codebook_shape_checked() {
        let cfg = blob_config();
        let bad = Codebook::zeros(4, 6); // wrong node count
        let data = vec![0.0f32; 12];
        let err = train(
            &cfg,
            DataShard::Dense { data: &data, dim: 6 },
            Some(bad),
            None,
        );
        assert!(err.is_err());
    }

    #[test]
    fn radius_and_scale_follow_schedules() {
        let mut rng = Rng::new(5);
        let (data, _) = data::gaussian_blobs(30, 3, 2, 0.2, &mut rng);
        let cfg = TrainConfig {
            rows: 4,
            cols: 4,
            epochs: 4,
            radius0: Some(2.0),
            radius_n: 1.0,
            scale0: 1.0,
            scale_n: 0.1,
            threads: 1,
            ..Default::default()
        };
        let res = train(
            &cfg,
            DataShard::Dense { data: &data, dim: 3 },
            None,
            None,
        )
        .unwrap();
        assert_eq!(res.epochs[0].radius, 2.0);
        assert_eq!(res.epochs[3].radius, 1.0);
        assert_eq!(res.epochs[0].scale, 1.0);
        assert!((res.epochs[3].scale - 0.1).abs() < 1e-6);
    }
}
