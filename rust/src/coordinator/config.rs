//! Training configuration — the union of the paper's CLI knobs (§4.1)
//! and runtime options (threads, ranks, seed).

use crate::cluster::comm::CollectiveAlgo;
use crate::error::SomError;
use crate::io::output::SnapshotLevel;
use crate::kernels::KernelType;
use crate::som::{Cooling, Grid, GridType, MapType, Neighborhood, Schedule};

/// Codebook initialization scheme (somoclu's Python API offers random
/// and PCA/linear initialization; `-c FILE` supplies an explicit one).
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub enum Initialization {
    Random,
    Pca,
}

impl std::str::FromStr for Initialization {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "random" => Ok(Initialization::Random),
            "pca" | "linear" => Ok(Initialization::Pca),
            other => Err(format!("unknown initialization: {other}")),
        }
    }
}

/// I/O backend for streaming `SOMB` binary containers (`--io`).
///
/// * `Buffered` (default) — each source owns its fd and decodes chunks
///   through a small staging block into owned buffers. Works everywhere.
/// * `Pread` — positioned reads against **one shared fd** for all
///   cluster ranks (`io::binary::SharedFd`); same memory profile as
///   buffered, no per-rank opens, no seek-state contention.
/// * `Mmap` — map the file once and hand kernels borrowed chunk views
///   straight out of the page cache (`io::mmap`); zero data copies and
///   ~zero heap. Needs the default-on `mmap` cargo feature (and a
///   little-endian 64-bit unix target); incompatible with `--prefetch`.
///
/// Text inputs always use `Buffered` — the zero-copy layer is defined
/// over the binary container only (`somoclu convert` first).
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub enum IoMode {
    Buffered,
    Mmap,
    Pread,
}

impl IoMode {
    /// The CLI spelling (for error messages and reports).
    pub fn as_str(self) -> &'static str {
        match self {
            IoMode::Buffered => "buffered",
            IoMode::Mmap => "mmap",
            IoMode::Pread => "pread",
        }
    }

    /// The one refusal message for zero-copy backends on text inputs
    /// (every layer that can hit the mismatch — CLI early check, the
    /// single-process source factory, the cluster runner — emits this
    /// same text).
    pub fn text_input_error(self) -> String {
        format!(
            "--io {} works on binary containers only; run `somoclu convert` \
             once, or drop --io for text inputs",
            self.as_str()
        )
    }
}

impl std::str::FromStr for IoMode {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "buffered" => Ok(IoMode::Buffered),
            "mmap" => Ok(IoMode::Mmap),
            "pread" => Ok(IoMode::Pread),
            other => Err(format!("unknown io mode: {other} (want buffered | mmap | pread)")),
        }
    }
}

#[derive(Clone, Debug)]
pub struct TrainConfig {
    /// Map rows (`-y`); paper default 50.
    pub rows: usize,
    /// Map columns (`-x`); paper default 50.
    pub cols: usize,
    /// Training epochs (`-e`).
    pub epochs: usize,
    /// Grid layout (`-g`).
    pub grid_type: GridType,
    /// Map topology (`-m`).
    pub map_type: MapType,
    /// Neighborhood function (`-n`) + compact support (`-p`).
    pub neighborhood: Neighborhood,
    /// Start radius (`-r`); None = "half of the map size in the smaller
    /// direction" (paper default).
    pub radius0: Option<f32>,
    /// Final radius (`-R`); paper default 1.
    pub radius_n: f32,
    /// Radius cooling (`-t`).
    pub radius_cooling: Cooling,
    /// Start learning rate (`-l`); paper default 1.0.
    pub scale0: f32,
    /// Final learning rate (`-L`); paper default 0.01.
    pub scale_n: f32,
    /// Learning-rate cooling (`-T`).
    pub scale_cooling: Cooling,
    /// Kernel (`-k`): 0 dense CPU, 1 accel, 2 sparse CPU.
    pub kernel: KernelType,
    /// Worker threads per process (OpenMP analog).
    pub threads: usize,
    /// Simulated MPI ranks (1 = single-node path).
    pub ranks: usize,
    /// Interim snapshot level (`-s`).
    pub snapshot: SnapshotLevel,
    /// Codebook initialization (`--initialization random|pca`).
    pub initialization: Initialization,
    /// RNG seed for codebook init.
    pub seed: u64,
    /// Streaming window in data rows (`--chunk-rows`): each epoch
    /// accumulates over bounded chunks of this many rows instead of one
    /// resident shard, capping data memory at O(chunk_rows * dim).
    /// 0 = whole shard per chunk (the classic in-memory path, default).
    pub chunk_rows: usize,
    /// Double-buffered chunk read-ahead (`--prefetch`): file-backed
    /// streaming sources get a reader thread that loads chunk k+1 while
    /// the kernel runs chunk k. Data-buffer bound doubles to
    /// 2 × chunk_rows × dim per source; no effect on resident inputs.
    pub prefetch: bool,
    /// Streaming I/O backend for binary containers (`--io`): buffered
    /// per-source fds (default), one shared pread fd, or zero-copy mmap.
    pub io_mode: IoMode,
    /// Cluster collective algorithm (`--collective`): auto (size-based
    /// ring/tree selection, default), star (the paper's literal
    /// master/slave pattern, bit-compatible with the historical path),
    /// ring, or tree. A runtime knob like `threads`/`ranks` — not
    /// stored in checkpoints; a run uses one algorithm for all windows,
    /// preserving checkpoint-window bit-invariance.
    pub collective: CollectiveAlgo,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            rows: 50,
            cols: 50,
            epochs: 10,
            grid_type: GridType::Square,
            map_type: MapType::Planar,
            neighborhood: Neighborhood::gaussian(false),
            radius0: None,
            radius_n: 1.0,
            radius_cooling: Cooling::Linear,
            scale0: 1.0,
            scale_n: 0.01,
            scale_cooling: Cooling::Linear,
            kernel: KernelType::DenseCpu,
            threads: crate::util::threadpool::default_threads(),
            ranks: 1,
            snapshot: SnapshotLevel::None,
            initialization: Initialization::Random,
            seed: 0x50_4d_4f_53, // "SOMP"
            chunk_rows: 0,
            prefetch: false,
            io_mode: IoMode::Buffered,
            collective: CollectiveAlgo::Auto,
        }
    }
}

impl TrainConfig {
    pub fn grid(&self) -> Grid {
        Grid::new(self.rows, self.cols, self.grid_type, self.map_type)
    }

    pub fn radius_schedule(&self, grid: &Grid) -> Schedule {
        let r0 = self.radius0.unwrap_or_else(|| grid.default_radius0());
        Schedule::new(r0, self.radius_n, self.radius_cooling, self.epochs)
    }

    pub fn scale_schedule(&self) -> Schedule {
        Schedule::new(self.scale0, self.scale_n, self.scale_cooling, self.epochs)
    }

    /// Reject inconsistent configurations with a typed
    /// [`SomError::Config`] (code `config`) naming the offending knob.
    pub fn validate(&self) -> Result<(), SomError> {
        if self.rows == 0 || self.cols == 0 {
            return Err(SomError::config(
                "map must have at least one row and column",
            ));
        }
        if self.epochs == 0 {
            return Err(SomError::config("epochs must be > 0"));
        }
        if self.ranks == 0 {
            return Err(SomError::config("ranks must be > 0"));
        }
        if let Some(r0) = self.radius0 {
            if r0 < self.radius_n {
                return Err(SomError::config(format!(
                    "start radius {r0} smaller than final radius {}",
                    self.radius_n
                )));
            }
        }
        if self.scale0 <= 0.0 {
            return Err(SomError::config(
                "start learning rate must be positive",
            ));
        }
        if self.io_mode == IoMode::Mmap && self.prefetch {
            // Chunks come straight out of the page cache; a read-ahead
            // thread would only add a copy the mmap mode exists to
            // remove. Refusing beats silently degrading to buffered.
            return Err(SomError::config(
                "--prefetch has no effect with --io mmap (chunk views are \
                 served from the page cache); drop one of the two",
            ));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_paper() {
        let c = TrainConfig::default();
        assert_eq!((c.rows, c.cols), (50, 50));
        assert_eq!(c.chunk_rows, 0); // streaming is opt-in
        assert!(!c.prefetch); // read-ahead is opt-in too
        assert_eq!(c.radius_n, 1.0);
        assert_eq!(c.scale0, 1.0);
        assert_eq!(c.scale_n, 0.01);
        assert_eq!(c.radius_cooling, Cooling::Linear);
        assert!(c.validate().is_ok());
        // default radius0 = half the smaller map side
        let grid = c.grid();
        assert_eq!(c.radius_schedule(&grid).start, 25.0);
    }

    #[test]
    fn io_mode_parses_and_defaults() {
        let c = TrainConfig::default();
        assert_eq!(c.io_mode, IoMode::Buffered);
        assert_eq!(c.collective, CollectiveAlgo::Auto);
        assert_eq!("mmap".parse::<IoMode>().unwrap(), IoMode::Mmap);
        assert_eq!("PREAD".parse::<IoMode>().unwrap(), IoMode::Pread);
        assert!("zerocopy".parse::<IoMode>().is_err());
    }

    #[test]
    fn mmap_with_prefetch_rejected() {
        let mut c = TrainConfig::default();
        c.io_mode = IoMode::Mmap;
        c.prefetch = true;
        assert!(c.validate().is_err());
        c.prefetch = false;
        assert!(c.validate().is_ok());
        let mut c = TrainConfig::default();
        c.io_mode = IoMode::Pread;
        c.prefetch = true; // pread + prefetch is a supported combination
        assert!(c.validate().is_ok());
    }

    #[test]
    fn validation_catches_bad_configs() {
        let mut c = TrainConfig::default();
        c.epochs = 0;
        assert!(c.validate().is_err());
        let mut c = TrainConfig::default();
        c.radius0 = Some(0.5);
        c.radius_n = 1.0;
        assert!(c.validate().is_err());
        let mut c = TrainConfig::default();
        c.scale0 = 0.0;
        assert!(c.validate().is_err());
    }
}
