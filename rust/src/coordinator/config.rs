//! Training configuration — the union of the paper's CLI knobs (§4.1)
//! and runtime options (threads, ranks, seed).

use crate::io::output::SnapshotLevel;
use crate::kernels::KernelType;
use crate::som::{Cooling, Grid, GridType, MapType, Neighborhood, Schedule};

/// Codebook initialization scheme (somoclu's Python API offers random
/// and PCA/linear initialization; `-c FILE` supplies an explicit one).
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub enum Initialization {
    Random,
    Pca,
}

impl std::str::FromStr for Initialization {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "random" => Ok(Initialization::Random),
            "pca" | "linear" => Ok(Initialization::Pca),
            other => Err(format!("unknown initialization: {other}")),
        }
    }
}

#[derive(Clone, Debug)]
pub struct TrainConfig {
    /// Map rows (`-y`); paper default 50.
    pub rows: usize,
    /// Map columns (`-x`); paper default 50.
    pub cols: usize,
    /// Training epochs (`-e`).
    pub epochs: usize,
    /// Grid layout (`-g`).
    pub grid_type: GridType,
    /// Map topology (`-m`).
    pub map_type: MapType,
    /// Neighborhood function (`-n`) + compact support (`-p`).
    pub neighborhood: Neighborhood,
    /// Start radius (`-r`); None = "half of the map size in the smaller
    /// direction" (paper default).
    pub radius0: Option<f32>,
    /// Final radius (`-R`); paper default 1.
    pub radius_n: f32,
    /// Radius cooling (`-t`).
    pub radius_cooling: Cooling,
    /// Start learning rate (`-l`); paper default 1.0.
    pub scale0: f32,
    /// Final learning rate (`-L`); paper default 0.01.
    pub scale_n: f32,
    /// Learning-rate cooling (`-T`).
    pub scale_cooling: Cooling,
    /// Kernel (`-k`): 0 dense CPU, 1 accel, 2 sparse CPU.
    pub kernel: KernelType,
    /// Worker threads per process (OpenMP analog).
    pub threads: usize,
    /// Simulated MPI ranks (1 = single-node path).
    pub ranks: usize,
    /// Interim snapshot level (`-s`).
    pub snapshot: SnapshotLevel,
    /// Codebook initialization (`--initialization random|pca`).
    pub initialization: Initialization,
    /// RNG seed for codebook init.
    pub seed: u64,
    /// Streaming window in data rows (`--chunk-rows`): each epoch
    /// accumulates over bounded chunks of this many rows instead of one
    /// resident shard, capping data memory at O(chunk_rows * dim).
    /// 0 = whole shard per chunk (the classic in-memory path, default).
    pub chunk_rows: usize,
    /// Double-buffered chunk read-ahead (`--prefetch`): file-backed
    /// streaming sources get a reader thread that loads chunk k+1 while
    /// the kernel runs chunk k. Data-buffer bound doubles to
    /// 2 × chunk_rows × dim per source; no effect on resident inputs.
    pub prefetch: bool,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            rows: 50,
            cols: 50,
            epochs: 10,
            grid_type: GridType::Square,
            map_type: MapType::Planar,
            neighborhood: Neighborhood::gaussian(false),
            radius0: None,
            radius_n: 1.0,
            radius_cooling: Cooling::Linear,
            scale0: 1.0,
            scale_n: 0.01,
            scale_cooling: Cooling::Linear,
            kernel: KernelType::DenseCpu,
            threads: crate::util::threadpool::default_threads(),
            ranks: 1,
            snapshot: SnapshotLevel::None,
            initialization: Initialization::Random,
            seed: 0x50_4d_4f_53, // "SOMP"
            chunk_rows: 0,
            prefetch: false,
        }
    }
}

impl TrainConfig {
    pub fn grid(&self) -> Grid {
        Grid::new(self.rows, self.cols, self.grid_type, self.map_type)
    }

    pub fn radius_schedule(&self, grid: &Grid) -> Schedule {
        let r0 = self.radius0.unwrap_or_else(|| grid.default_radius0());
        Schedule::new(r0, self.radius_n, self.radius_cooling, self.epochs)
    }

    pub fn scale_schedule(&self) -> Schedule {
        Schedule::new(self.scale0, self.scale_n, self.scale_cooling, self.epochs)
    }

    pub fn validate(&self) -> Result<(), String> {
        if self.rows == 0 || self.cols == 0 {
            return Err("map must have at least one row and column".into());
        }
        if self.epochs == 0 {
            return Err("epochs must be > 0".into());
        }
        if self.ranks == 0 {
            return Err("ranks must be > 0".into());
        }
        if let Some(r0) = self.radius0 {
            if r0 < self.radius_n {
                return Err(format!(
                    "start radius {r0} smaller than final radius {}",
                    self.radius_n
                ));
            }
        }
        if self.scale0 <= 0.0 {
            return Err("start learning rate must be positive".into());
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_paper() {
        let c = TrainConfig::default();
        assert_eq!((c.rows, c.cols), (50, 50));
        assert_eq!(c.chunk_rows, 0); // streaming is opt-in
        assert!(!c.prefetch); // read-ahead is opt-in too
        assert_eq!(c.radius_n, 1.0);
        assert_eq!(c.scale0, 1.0);
        assert_eq!(c.scale_n, 0.01);
        assert_eq!(c.radius_cooling, Cooling::Linear);
        assert!(c.validate().is_ok());
        // default radius0 = half the smaller map side
        let grid = c.grid();
        assert_eq!(c.radius_schedule(&grid).start, 25.0);
    }

    #[test]
    fn validation_catches_bad_configs() {
        let mut c = TrainConfig::default();
        c.epochs = 0;
        assert!(c.validate().is_err());
        let mut c = TrainConfig::default();
        c.radius0 = Some(0.5);
        c.radius_n = 1.0;
        assert!(c.validate().is_err());
        let mut c = TrainConfig::default();
        c.scale0 = 0.0;
        assert!(c.validate().is_err());
    }
}
