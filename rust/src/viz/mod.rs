//! Minimal visualization: U-matrix heatmaps as PPM/PGM images (the
//! gnuplot substitute of paper §4.4 — "the simplest procedure is to use a
//! generic plotting library"; we write portable pixmaps any viewer or
//! converter understands).

use std::io::Write;
use std::path::Path;

use crate::som::Grid;

/// Map a value in [0, 1] through a blue→cyan→yellow→red heat colormap.
fn heat_rgb(t: f32) -> [u8; 3] {
    let t = t.clamp(0.0, 1.0);
    let (r, g, b) = if t < 0.25 {
        (0.0, 4.0 * t, 1.0)
    } else if t < 0.5 {
        (0.0, 1.0, 1.0 - 4.0 * (t - 0.25))
    } else if t < 0.75 {
        (4.0 * (t - 0.5), 1.0, 0.0)
    } else {
        (1.0, 1.0 - 4.0 * (t - 0.75), 0.0)
    };
    [(r * 255.0) as u8, (g * 255.0) as u8, (b * 255.0) as u8]
}

/// Normalize values to [0, 1] (min-max; constant input maps to 0).
fn normalize(values: &[f32]) -> Vec<f32> {
    let (mut lo, mut hi) = (f32::INFINITY, f32::NEG_INFINITY);
    for &v in values {
        lo = lo.min(v);
        hi = hi.max(v);
    }
    let span = (hi - lo).max(1e-12);
    values.iter().map(|v| (v - lo) / span).collect()
}

/// Render a per-node scalar field (e.g. the U-matrix) as a color PPM,
/// scaling each node to `cell x cell` pixels. Optionally overlay BMU
/// hits as black dots (the paper's Fig. 9 style: "the individual dots
/// are neurons with a weight vector that match a data instance").
pub fn write_heatmap_ppm<P: AsRef<Path>>(
    path: P,
    grid: &Grid,
    values: &[f32],
    cell: usize,
    bmus: Option<&[u32]>,
) -> std::io::Result<()> {
    assert_eq!(values.len(), grid.node_count());
    let cell = cell.max(1);
    let (w, h) = (grid.cols * cell, grid.rows * cell);
    let norm = normalize(values);

    let mut hit = vec![false; grid.node_count()];
    if let Some(bmus) = bmus {
        for &b in bmus {
            if (b as usize) < hit.len() {
                hit[b as usize] = true;
            }
        }
    }

    let mut img = vec![0u8; w * h * 3];
    for r in 0..grid.rows {
        for c in 0..grid.cols {
            let node = grid.index(r, c);
            let rgb = heat_rgb(norm[node]);
            for py in 0..cell {
                for px in 0..cell {
                    let x = c * cell + px;
                    let y = r * cell + py;
                    let o = (y * w + x) * 3;
                    // BMU dot: darken the center of the cell.
                    let center = cell / 2;
                    let is_dot = hit[node]
                        && px.abs_diff(center) <= cell / 6
                        && py.abs_diff(center) <= cell / 6;
                    let px_rgb = if is_dot { [0, 0, 0] } else { rgb };
                    img[o..o + 3].copy_from_slice(&px_rgb);
                }
            }
        }
    }

    let f = std::fs::File::create(path)?;
    let mut out = std::io::BufWriter::new(f);
    write!(out, "P6\n{w} {h}\n255\n")?;
    out.write_all(&img)?;
    Ok(())
}

/// Grayscale PGM variant (U-matrix barrier structure without color).
pub fn write_heatmap_pgm<P: AsRef<Path>>(
    path: P,
    grid: &Grid,
    values: &[f32],
    cell: usize,
) -> std::io::Result<()> {
    assert_eq!(values.len(), grid.node_count());
    let cell = cell.max(1);
    let (w, h) = (grid.cols * cell, grid.rows * cell);
    let norm = normalize(values);
    let mut img = vec![0u8; w * h];
    for r in 0..grid.rows {
        for c in 0..grid.cols {
            let v = (norm[grid.index(r, c)] * 255.0) as u8;
            for py in 0..cell {
                for px in 0..cell {
                    img[(r * cell + py) * w + c * cell + px] = v;
                }
            }
        }
    }
    let f = std::fs::File::create(path)?;
    let mut out = std::io::BufWriter::new(f);
    write!(out, "P5\n{w} {h}\n255\n")?;
    out.write_all(&img)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::som::grid::{GridType, MapType};

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("somoclu_test_viz");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn ppm_header_and_size() {
        let grid = Grid::new(3, 4, GridType::Square, MapType::Planar);
        let p = tmp("t.ppm");
        let vals: Vec<f32> = (0..12).map(|i| i as f32).collect();
        write_heatmap_ppm(&p, &grid, &vals, 5, None).unwrap();
        let bytes = std::fs::read(&p).unwrap();
        let header = b"P6\n20 15\n255\n";
        assert!(bytes.starts_with(header));
        assert_eq!(bytes.len(), header.len() + 20 * 15 * 3);
    }

    #[test]
    fn pgm_extremes_map_to_black_white() {
        let grid = Grid::new(1, 2, GridType::Square, MapType::Planar);
        let p = tmp("t.pgm");
        write_heatmap_pgm(&p, &grid, &[0.0, 10.0], 1).unwrap();
        let bytes = std::fs::read(&p).unwrap();
        let body = &bytes[bytes.len() - 2..];
        assert_eq!(body, &[0u8, 255u8]);
    }

    #[test]
    fn constant_field_no_panic() {
        let grid = Grid::new(2, 2, GridType::Square, MapType::Planar);
        write_heatmap_ppm(tmp("c.ppm"), &grid, &[1.0; 4], 2, None).unwrap();
    }

    #[test]
    fn bmu_dots_darken_cells() {
        let grid = Grid::new(1, 2, GridType::Square, MapType::Planar);
        let p = tmp("dots.ppm");
        write_heatmap_ppm(&p, &grid, &[0.5, 0.5], 9, Some(&[0])).unwrap();
        let bytes = std::fs::read(&p).unwrap();
        let off = bytes.len() - 18 * 9 * 3 + (4 * 18 + 4) * 3;
        // Center pixel of cell 0 is black, cell 1 is not.
        assert_eq!(&bytes[off..off + 3], &[0, 0, 0]);
        let off1 = off + 9 * 3;
        assert_ne!(&bytes[off1..off1 + 3], &[0, 0, 0]);
    }

    #[test]
    fn heat_rgb_endpoints() {
        assert_eq!(heat_rgb(0.0), [0, 0, 255]);
        assert_eq!(heat_rgb(1.0), [255, 0, 0]);
    }
}
