//! Zero-copy streaming over the `SOMB` binary container (`--io mmap`).
//!
//! The buffered binary sources (`io::binary`) already skip per-epoch
//! parsing, but every chunk still pays one copy: page cache → decode
//! block → typed chunk buffer. This module maps the container **once**
//! (`mmap(2)`, read-only, shared) and hands the kernels borrowed
//! [`DataShard`] views pointing straight into the mapping — the chunk
//! "read" is pointer arithmetic, the OS pages data in on first touch,
//! and the training process owns **no** data buffers at all (dense) or
//! only a `chunk_rows + 1`-entry rebased indptr scratch (sparse). The
//! data-resident bound is O(1) heap beyond whatever the OS keeps in the
//! page cache, which is the strongest form of the paper's "memory use is
//! highly optimized" claim.
//!
//! Why this is sound, and where it isn't:
//!
//! * The container is little-endian with a 40-byte header, so the dense
//!   payload and every sparse section start 4-byte (indptr: 8-byte)
//!   aligned — `&[f32]`/`&[u32]`/`&[u64]` views are valid on any
//!   little-endian 64-bit unix target. The module is compiled only
//!   there (plus the default-on `mmap` cargo feature); everywhere else
//!   the stub half of this file keeps the API and returns a clear error
//!   from `open`, so `--io buffered`/`pread` remain the portable paths.
//! * `open` validates the header *and* the exact file length (like
//!   every binary source), so all section offsets are in-bounds by
//!   construction; the typed-view helper re-checks bounds and alignment
//!   defensively anyway.
//! * Caveat inherited from mmap semantics: if another process truncates
//!   the file while it is mapped, touching the vanished pages raises
//!   SIGBUS — the buffered/pread paths turn the same mutation into a
//!   clean read error instead. Don't point `--io mmap` at files being
//!   rewritten in place.
//!
//! Mapped bytes never pass through the global allocator, so each source
//! reports the window it is currently exposing to the **mapped-window
//! gauge** (`memtrack::data_map_resize`), keeping the bounded-memory
//! assertions (`stream_bounded.rs`) meaningful on the zero-copy path.
//!
//! Cold-cache behavior: the whole mapping is advised `MADV_SEQUENTIAL`
//! at map time, and every `next_chunk` additionally issues
//! `MADV_WILLNEED` on the *next* chunk window before handing out the
//! current one — the mmap analog of `--prefetch` (which is refused in
//! mmap mode): the pager streams the coming window in from disk while
//! the kernel computes. Both hints are advisory; failures are ignored
//! and never affect results.
//!
//! Cluster use: [`MappedContainer::open`] maps once; every rank's
//! `dense_shard`/`sparse_shard` clones the `Arc` and serves its own
//! disjoint row window from the same mapping — one map, zero fds held
//! (the fd can close once mapped; POSIX keeps the mapping alive).

/// True when this build carries the real zero-copy backend (the `mmap`
/// cargo feature on little-endian 64-bit unix). When false, the types
/// below still exist but every `open` fails with an explanation, so
/// callers need no conditional compilation.
pub const SUPPORTED: bool = cfg!(all(
    feature = "mmap",
    unix,
    target_pointer_width = "64",
    target_endian = "little"
));

#[cfg(all(
    feature = "mmap",
    unix,
    target_pointer_width = "64",
    target_endian = "little"
))]
mod real {
    use std::fs::File;
    use std::os::unix::io::AsRawFd;
    use std::path::{Path, PathBuf};
    use std::sync::Arc;

    use crate::io::binary::{read_header, BinaryHeader, BinaryKind, HEADER_LEN};
    use crate::io::stream::{chunk_take, rank_window, DataSource};
    use crate::kernels::DataShard;
    use crate::sparse::CsrView;
    use crate::util::memtrack;

    /// Minimal FFI surface — the constants below are identical on Linux
    /// and macOS, the only unix targets this module compiles for in
    /// practice. Keeping the declarations local avoids a libc crate
    /// dependency the container image does not carry.
    mod sys {
        use std::os::raw::{c_int, c_void};

        pub const PROT_READ: c_int = 1;
        pub const MAP_SHARED: c_int = 1;
        pub const MADV_SEQUENTIAL: c_int = 2;
        pub const MADV_WILLNEED: c_int = 3;

        extern "C" {
            // off_t is i64 on every 64-bit unix; the module is gated to
            // target_pointer_width = "64" so this signature is the ABI.
            pub fn mmap(
                addr: *mut c_void,
                len: usize,
                prot: c_int,
                flags: c_int,
                fd: c_int,
                offset: i64,
            ) -> *mut c_void;
            pub fn munmap(addr: *mut c_void, len: usize) -> c_int;
            pub fn madvise(addr: *mut c_void, len: usize, advice: c_int) -> c_int;
        }
    }

    /// A read-only shared mapping of one file, unmapped on drop.
    pub(super) struct Mapping {
        ptr: *mut std::os::raw::c_void,
        len: usize,
    }

    // The mapping is immutable shared memory; concurrent reads from any
    // thread are safe, and the pointer is only freed in Drop.
    unsafe impl Send for Mapping {}
    unsafe impl Sync for Mapping {}

    impl Mapping {
        fn map(file: &File, len: usize, path: &Path) -> anyhow::Result<Mapping> {
            anyhow::ensure!(len > 0, "{}: cannot map an empty file", path.display());
            let ptr = unsafe {
                sys::mmap(
                    std::ptr::null_mut(),
                    len,
                    sys::PROT_READ,
                    sys::MAP_SHARED,
                    file.as_raw_fd(),
                    0,
                )
            };
            anyhow::ensure!(
                ptr as isize != -1,
                "{}: mmap failed: {}",
                path.display(),
                std::io::Error::last_os_error()
            );
            // Epochs stream the window front to back; tell the pager.
            // Purely advisory — a failure changes nothing correctness-wise.
            unsafe { sys::madvise(ptr, len, sys::MADV_SEQUENTIAL) };
            Ok(Mapping { ptr, len })
        }

        /// Advise the pager to fault in `[off, off + bytes)` ahead of
        /// use (`MADV_WILLNEED`) — the mmap answer to `--prefetch`: the
        /// next chunk window starts paging in from disk while the kernel
        /// computes on the current one, which matters on cold caches.
        /// Purely advisory and deliberately infallible: the offset is
        /// aligned down to a 16 KiB boundary (a multiple of every page
        /// size in the wild, so the address stays page-aligned), the
        /// range is clamped to the mapping, and any errno is ignored —
        /// a failed hint changes nothing correctness-wise.
        fn advise_willneed(&self, off: u64, bytes: usize) {
            const ALIGN: usize = 16 * 1024;
            let Ok(off) = usize::try_from(off) else {
                return;
            };
            if bytes == 0 || off >= self.len {
                return;
            }
            let end = off.saturating_add(bytes).min(self.len);
            let a_off = off & !(ALIGN - 1);
            unsafe {
                sys::madvise(
                    self.ptr.cast::<u8>().add(a_off).cast(),
                    end - a_off,
                    sys::MADV_WILLNEED,
                );
            }
        }

        /// Borrow `count` values of `T` at byte offset `off`, bounds- and
        /// alignment-checked. `T` must be a plain LE number type whose
        /// every bit pattern is valid (f32 / u32 / u64 here).
        fn typed<T: Copy>(&self, off: u64, count: usize) -> anyhow::Result<&[T]> {
            let size = std::mem::size_of::<T>();
            let off = usize::try_from(off)?;
            let bytes = count
                .checked_mul(size)
                .ok_or_else(|| anyhow::anyhow!("mapped view size overflow"))?;
            anyhow::ensure!(
                off.checked_add(bytes).is_some_and(|end| end <= self.len),
                "mapped view [{off}, +{bytes}) out of bounds (mapping is {} bytes)",
                self.len
            );
            let p = unsafe { self.ptr.cast::<u8>().add(off) };
            anyhow::ensure!(
                p as usize % std::mem::align_of::<T>() == 0,
                "mapped section at offset {off} is not {}-aligned",
                std::mem::align_of::<T>()
            );
            Ok(unsafe { std::slice::from_raw_parts(p.cast::<T>(), count) })
        }
    }

    impl Drop for Mapping {
        fn drop(&mut self) {
            unsafe { sys::munmap(self.ptr, self.len) };
        }
    }

    /// One mapped `SOMB` container, shareable by any number of chunk
    /// sources (the cluster runner maps once, then cuts a per-rank
    /// window source for every rank).
    pub struct MappedContainer {
        map: Arc<Mapping>,
        header: BinaryHeader,
        path: PathBuf,
    }

    impl MappedContainer {
        /// Open + validate + map `path`. The fd is closed before this
        /// returns; the mapping keeps the file content reachable.
        pub fn open<P: AsRef<Path>>(path: P) -> anyhow::Result<Self> {
            let path = path.as_ref().to_path_buf();
            let file = File::open(&path)?;
            // Validates magic/version/kind and the exact file length, so
            // every section offset derived from the header is in-bounds.
            let header = read_header(&file, &path)?;
            let len = usize::try_from(file.metadata()?.len())?;
            let map = Mapping::map(&file, len, &path)?;
            Ok(MappedContainer {
                map: Arc::new(map),
                header,
                path,
            })
        }

        pub fn header(&self) -> BinaryHeader {
            self.header
        }

        /// Rank `rank` of `ranks`' dense window over this mapping.
        pub fn dense_shard(
            &self,
            chunk_rows: usize,
            rank: usize,
            ranks: usize,
        ) -> anyhow::Result<MmapDenseSource> {
            anyhow::ensure!(
                self.header.kind == BinaryKind::Dense,
                "{}: sparse container opened as dense (use the sparse kernel, -k 2)",
                self.path.display()
            );
            let window = rank_window(self.header.rows, rank, ranks)?;
            Ok(MmapDenseSource {
                map: Arc::clone(&self.map),
                dim: self.header.dim,
                row_start: window.start,
                window_rows: window.len(),
                chunk_rows,
                cursor: 0,
                reported_map: 0,
            })
        }

        /// Rank `rank` of `ranks`' sparse window over this mapping.
        pub fn sparse_shard(
            &self,
            chunk_rows: usize,
            rank: usize,
            ranks: usize,
        ) -> anyhow::Result<MmapSparseSource> {
            anyhow::ensure!(
                self.header.kind == BinaryKind::Sparse,
                "{}: dense container opened as sparse (drop -k 2 for dense data)",
                self.path.display()
            );
            let window = rank_window(self.header.rows, rank, ranks)?;
            Ok(MmapSparseSource {
                map: Arc::clone(&self.map),
                header: self.header,
                path: self.path.clone(),
                row_start: window.start,
                window_rows: window.len(),
                chunk_rows,
                cursor: 0,
                indptr_scratch: Vec::new(),
                reported_buf: 0,
                reported_map: 0,
            })
        }
    }

    /// Zero-copy dense source: every chunk is a borrowed `&[f32]` view
    /// into the mapping. Holds no data buffers at all.
    pub struct MmapDenseSource {
        map: Arc<Mapping>,
        dim: usize,
        row_start: usize,
        window_rows: usize,
        chunk_rows: usize,
        cursor: usize,
        /// Mapped bytes currently exposed as a chunk view (gauge share).
        reported_map: usize,
    }

    impl MmapDenseSource {
        /// Map the whole file as a single-rank source.
        pub fn open<P: AsRef<Path>>(path: P, chunk_rows: usize) -> anyhow::Result<Self> {
            MappedContainer::open(path)?.dense_shard(chunk_rows, 0, 1)
        }
    }

    impl Drop for MmapDenseSource {
        fn drop(&mut self) {
            memtrack::data_map_resize(self.reported_map, 0);
        }
    }

    impl DataSource for MmapDenseSource {
        fn rows(&self) -> usize {
            self.window_rows
        }

        fn dim(&self) -> usize {
            self.dim
        }

        fn chunk_rows(&self) -> usize {
            self.chunk_rows
        }

        fn next_chunk(&mut self) -> anyhow::Result<Option<DataShard<'_>>> {
            let take = chunk_take(self.window_rows, self.cursor, self.chunk_rows);
            if take == 0 {
                return Ok(None);
            }
            let global = self.row_start + self.cursor;
            self.cursor += take;
            let count = take * self.dim;
            memtrack::data_map_resize(self.reported_map, count * 4);
            self.reported_map = count * 4;
            let off = HEADER_LEN + 4 * (global as u64) * (self.dim as u64);
            // Touch-ahead: ask the pager for the *next* chunk window
            // before handing out this one, so its pages stream in while
            // the kernel computes (the mmap `--prefetch` analog).
            let ahead = chunk_take(self.window_rows, self.cursor, self.chunk_rows);
            if ahead > 0 {
                let next_global = self.row_start + self.cursor;
                self.map.advise_willneed(
                    HEADER_LEN + 4 * (next_global as u64) * (self.dim as u64),
                    ahead * self.dim * 4,
                );
            }
            let data: &[f32] = self.map.typed(off, count)?;
            Ok(Some(DataShard::Dense {
                data,
                dim: self.dim,
            }))
        }

        fn reset(&mut self) -> anyhow::Result<()> {
            self.cursor = 0;
            Ok(())
        }

        /// Unlike every other file-backed source, a full-file mapped view
        /// IS addressable as one shard — so PCA initialization works
        /// while still streaming bounded chunks through the kernels.
        fn resident(&self) -> Option<DataShard<'_>> {
            if self.row_start != 0 || self.window_rows * self.dim == 0 {
                return None;
            }
            let off = HEADER_LEN;
            let count = self.window_rows * self.dim;
            match self.map.typed::<f32>(off, count) {
                Ok(data) if self.is_whole_file(count) => {
                    // The whole payload is being exposed (PCA init reads
                    // every row). `&self` cannot carry a share to release
                    // later, so record the exposure as a peak excursion —
                    // the mapped-window gauge must never under-report the
                    // largest view handed out. (The training loop only
                    // calls `resident()` when init actually needs the
                    // data, so bounded chunked runs keep their one-window
                    // peak.)
                    memtrack::data_map_resize(0, count * 4);
                    memtrack::data_map_resize(count * 4, 0);
                    Some(DataShard::Dense {
                        data,
                        dim: self.dim,
                    })
                }
                _ => None,
            }
        }
    }

    impl MmapDenseSource {
        /// Does this source's window cover the entire payload?
        fn is_whole_file(&self, count: usize) -> bool {
            HEADER_LEN as usize + count * 4 == self.map.len
        }
    }

    /// Zero-copy sparse source: `indices`/`values` of every chunk are
    /// borrowed views into the mapping; only the rebased indptr window
    /// (`chunk_rows + 1` usizes) lives on the heap.
    pub struct MmapSparseSource {
        map: Arc<Mapping>,
        header: BinaryHeader,
        path: PathBuf,
        row_start: usize,
        window_rows: usize,
        chunk_rows: usize,
        cursor: usize,
        /// Reusable rebased indptr window (the one owned allocation).
        indptr_scratch: Vec<usize>,
        /// Heap gauge share (the scratch).
        reported_buf: usize,
        /// Mapped-window gauge share (the exposed view).
        reported_map: usize,
    }

    impl MmapSparseSource {
        /// Map the whole file as a single-rank source.
        pub fn open<P: AsRef<Path>>(path: P, chunk_rows: usize) -> anyhow::Result<Self> {
            MappedContainer::open(path)?.sparse_shard(chunk_rows, 0, 1)
        }
    }

    impl Drop for MmapSparseSource {
        fn drop(&mut self) {
            memtrack::data_buffer_resize(self.reported_buf, 0);
            memtrack::data_map_resize(self.reported_map, 0);
        }
    }

    impl DataSource for MmapSparseSource {
        fn rows(&self) -> usize {
            self.window_rows
        }

        fn dim(&self) -> usize {
            self.header.dim
        }

        fn chunk_rows(&self) -> usize {
            self.chunk_rows
        }

        fn next_chunk(&mut self) -> anyhow::Result<Option<DataShard<'_>>> {
            let take = chunk_take(self.window_rows, self.cursor, self.chunk_rows);
            if take == 0 {
                return Ok(None);
            }
            let global = self.row_start + self.cursor;
            self.cursor += take;
            let h = self.header;

            // indptr window: borrow take + 1 cumulative offsets from the
            // map, validate (same checks and messages as the buffered
            // source), rebase into the reusable scratch.
            let (a, b) = {
                let ips: &[u64] =
                    self.map.typed(h.indptr_off() + 8 * global as u64, take + 1)?;
                for w in ips.windows(2) {
                    anyhow::ensure!(
                        w[1] >= w[0],
                        "{}: corrupt indptr section (non-monotone)",
                        self.path.display()
                    );
                }
                let a = usize::try_from(ips[0])?;
                let b = usize::try_from(ips[take])?;
                anyhow::ensure!(
                    b <= h.nnz,
                    "{}: corrupt indptr section (window [{a}, {b}), nnz {})",
                    self.path.display(),
                    h.nnz
                );
                self.indptr_scratch.clear();
                self.indptr_scratch
                    .extend(ips.iter().map(|&p| (p - ips[0]) as usize));
                (a, b)
            };

            // Gauge shares: the scratch is heap, the exposed view is map.
            let buf_bytes = self.indptr_scratch.capacity() * std::mem::size_of::<usize>();
            memtrack::data_buffer_resize(self.reported_buf, buf_bytes);
            self.reported_buf = buf_bytes;
            let map_bytes = (take + 1) * 8 + (b - a) * 8;
            memtrack::data_map_resize(self.reported_map, map_bytes);
            self.reported_map = map_bytes;

            // Touch-ahead for the next chunk window (the mmap
            // `--prefetch` analog): its indptr run starts where this
            // one ends (nnz offset `b`); one mapped indptr entry gives
            // its end, then all three sections get a WILLNEED hint.
            let ahead = chunk_take(self.window_rows, self.cursor, self.chunk_rows);
            if ahead > 0 {
                let next_global = self.row_start + self.cursor;
                self.map.advise_willneed(
                    h.indptr_off() + 8 * next_global as u64,
                    (ahead + 1) * 8,
                );
                if let Ok(end) = self
                    .map
                    .typed::<u64>(h.indptr_off() + 8 * (next_global + ahead) as u64, 1)
                {
                    if let Ok(b2) = usize::try_from(end[0]) {
                        if b2 > b && b2 <= h.nnz {
                            self.map.advise_willneed(
                                h.indices_off() + 4 * b as u64,
                                (b2 - b) * 4,
                            );
                            self.map.advise_willneed(
                                h.values_off() + 4 * b as u64,
                                (b2 - b) * 4,
                            );
                        }
                    }
                }
            }

            let indices: &[u32] = self.map.typed(h.indices_off() + 4 * a as u64, b - a)?;
            for &c in indices {
                anyhow::ensure!(
                    (c as usize) < h.dim,
                    "{}: corrupt indices section (column {c} out of range, cols {})",
                    self.path.display(),
                    h.dim
                );
            }
            let values: &[f32] = self.map.typed(h.values_off() + 4 * a as u64, b - a)?;
            Ok(Some(DataShard::Sparse(CsrView {
                rows: take,
                cols: h.dim,
                indptr: &self.indptr_scratch,
                indices,
                values,
            })))
        }

        fn reset(&mut self) -> anyhow::Result<()> {
            self.cursor = 0;
            Ok(())
        }
    }
}

#[cfg(all(
    feature = "mmap",
    unix,
    target_pointer_width = "64",
    target_endian = "little"
))]
pub use real::{MappedContainer, MmapDenseSource, MmapSparseSource};

/// Stub half: same names and signatures, every constructor explains why
/// zero-copy is unavailable in this build. Keeps call sites (CLI,
/// cluster runner, benches) free of conditional compilation and lets
/// the `--no-default-features` CI leg prove the fallback paths.
#[cfg(not(all(
    feature = "mmap",
    unix,
    target_pointer_width = "64",
    target_endian = "little"
)))]
mod stub {
    use std::path::Path;

    use crate::io::binary::BinaryHeader;
    use crate::io::stream::{ChunkBuf, DataSource};
    use crate::kernels::DataShard;

    fn unsupported() -> anyhow::Error {
        anyhow::anyhow!(
            "this build has no zero-copy mmap backend (needs the `mmap` \
             cargo feature and a little-endian 64-bit unix target); use \
             --io pread or --io buffered"
        )
    }

    pub struct MappedContainer {
        never: std::convert::Infallible,
    }

    impl MappedContainer {
        pub fn open<P: AsRef<Path>>(_path: P) -> anyhow::Result<Self> {
            Err(unsupported())
        }

        pub fn header(&self) -> BinaryHeader {
            match self.never {}
        }

        pub fn dense_shard(
            &self,
            _chunk_rows: usize,
            _rank: usize,
            _ranks: usize,
        ) -> anyhow::Result<MmapDenseSource> {
            match self.never {}
        }

        pub fn sparse_shard(
            &self,
            _chunk_rows: usize,
            _rank: usize,
            _ranks: usize,
        ) -> anyhow::Result<MmapSparseSource> {
            match self.never {}
        }
    }

    macro_rules! stub_source {
        ($name:ident) => {
            pub struct $name {
                never: std::convert::Infallible,
            }

            impl $name {
                pub fn open<P: AsRef<Path>>(
                    _path: P,
                    _chunk_rows: usize,
                ) -> anyhow::Result<Self> {
                    Err(unsupported())
                }
            }

            impl DataSource for $name {
                fn rows(&self) -> usize {
                    match self.never {}
                }

                fn dim(&self) -> usize {
                    match self.never {}
                }

                fn chunk_rows(&self) -> usize {
                    match self.never {}
                }

                fn next_chunk(&mut self) -> anyhow::Result<Option<DataShard<'_>>> {
                    match self.never {}
                }

                fn next_chunk_into(&mut self, _out: &mut ChunkBuf) -> anyhow::Result<bool> {
                    match self.never {}
                }

                fn reset(&mut self) -> anyhow::Result<()> {
                    match self.never {}
                }
            }
        };
    }

    stub_source!(MmapDenseSource);
    stub_source!(MmapSparseSource);
}

#[cfg(not(all(
    feature = "mmap",
    unix,
    target_pointer_width = "64",
    target_endian = "little"
)))]
pub use stub::{MappedContainer, MmapDenseSource, MmapSparseSource};
