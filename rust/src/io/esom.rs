//! Databionic ESOM Tools compatibility (paper §1, §4.4, §5.3): the
//! trained map is exported in the `.wts` (weights), `.bm` (best matches)
//! and `.umx` (U-matrix) formats so ESOM Tools can visualize it.
//!
//! Formats (ESOM Tools file-format spec):
//!   .wts:  `% <rows> <cols>` then `% <dim>`, then one line of `dim`
//!          floats per neuron, row-major.
//!   .bm:   `% <rows> <cols>` then `% <n>`, then `<index> <row> <col>`
//!          per data instance.
//!   .umx:  `% <rows> <cols>`, then `cols` floats per map row.

use std::io::Write;
use std::path::Path;

use crate::som::{Codebook, Grid};

/// Write the codebook as ESOM `.wts`.
pub fn write_wts<P: AsRef<Path>>(
    path: P,
    grid: &Grid,
    codebook: &Codebook,
) -> std::io::Result<()> {
    let f = std::fs::File::create(path)?;
    let mut w = std::io::BufWriter::new(f);
    writeln!(w, "% {} {}", grid.rows, grid.cols)?;
    writeln!(w, "% {}", codebook.dim)?;
    for n in 0..codebook.nodes {
        let row = codebook.row(n);
        for (i, v) in row.iter().enumerate() {
            if i > 0 {
                write!(w, " ")?;
            }
            write!(w, "{v}")?;
        }
        writeln!(w)?;
    }
    Ok(())
}

/// Write best-matching units as ESOM `.bm`.
pub fn write_bm<P: AsRef<Path>>(
    path: P,
    grid: &Grid,
    bmus: &[u32],
) -> std::io::Result<()> {
    let f = std::fs::File::create(path)?;
    let mut w = std::io::BufWriter::new(f);
    writeln!(w, "% {} {}", grid.rows, grid.cols)?;
    writeln!(w, "% {}", bmus.len())?;
    for (i, &b) in bmus.iter().enumerate() {
        let (r, c) = grid.position(b as usize);
        writeln!(w, "{i} {r} {c}")?;
    }
    Ok(())
}

/// Write the U-matrix as ESOM `.umx`.
pub fn write_umx<P: AsRef<Path>>(
    path: P,
    grid: &Grid,
    umatrix: &[f32],
) -> std::io::Result<()> {
    assert_eq!(umatrix.len(), grid.node_count());
    let f = std::fs::File::create(path)?;
    let mut w = std::io::BufWriter::new(f);
    writeln!(w, "% {} {}", grid.rows, grid.cols)?;
    for r in 0..grid.rows {
        for c in 0..grid.cols {
            if c > 0 {
                write!(w, " ")?;
            }
            write!(w, "{}", umatrix[grid.index(r, c)])?;
        }
        writeln!(w)?;
    }
    Ok(())
}

/// Write an ensemble consensus labeling as `.lbl`: one
/// `<index> <label> <agreement>` line per sample after a `%`-header, in
/// the same comment/whitespace dialect as the other ESOM-style files so
/// existing tooling can ingest it. `agreement[i]` is the fraction of
/// ensemble members that voted for `labels[i]`.
pub fn write_consensus_labels<P: AsRef<Path>>(
    path: P,
    labels: &[u32],
    agreement: &[f32],
) -> std::io::Result<()> {
    assert_eq!(labels.len(), agreement.len());
    let f = std::fs::File::create(path)?;
    let mut w = std::io::BufWriter::new(f);
    writeln!(w, "% {}", labels.len())?;
    for (i, (&l, &a)) in labels.iter().zip(agreement).enumerate() {
        writeln!(w, "{i} {l} {a}")?;
    }
    Ok(())
}

/// Parse a `.bm` file back (round-trip tests and resuming runs).
pub fn read_bm<P: AsRef<Path>>(path: P) -> std::io::Result<Vec<(usize, usize, usize)>> {
    let text = std::fs::read_to_string(path)?;
    let mut out = Vec::new();
    for line in text.lines() {
        let t = line.trim();
        if t.is_empty() || t.starts_with('%') || t.starts_with('#') {
            continue;
        }
        let mut it = t.split_whitespace();
        let (Some(i), Some(r), Some(c)) = (it.next(), it.next(), it.next()) else {
            continue;
        };
        if let (Ok(i), Ok(r), Ok(c)) =
            (i.parse::<usize>(), r.parse::<usize>(), c.parse::<usize>())
        {
            out.push((i, r, c));
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::som::grid::{GridType, MapType};

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("somoclu_test_esom");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn wts_header_and_body() {
        let grid = Grid::new(2, 3, GridType::Square, MapType::Planar);
        let mut cb = Codebook::zeros(6, 2);
        cb.row_mut(5).copy_from_slice(&[1.5, -2.0]);
        let p = tmp("t.wts");
        write_wts(&p, &grid, &cb).unwrap();
        let text = std::fs::read_to_string(&p).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines[0], "% 2 3");
        assert_eq!(lines[1], "% 2");
        assert_eq!(lines.len(), 2 + 6);
        assert_eq!(lines[7], "1.5 -2");
    }

    #[test]
    fn bm_round_trip() {
        let grid = Grid::new(4, 5, GridType::Square, MapType::Planar);
        let bmus = vec![0u32, 7, 19, 12];
        let p = tmp("t.bm");
        write_bm(&p, &grid, &bmus).unwrap();
        let rt = read_bm(&p).unwrap();
        assert_eq!(rt.len(), 4);
        for (i, &(idx, r, c)) in rt.iter().enumerate() {
            assert_eq!(idx, i);
            assert_eq!(grid.index(r, c), bmus[i] as usize);
        }
    }

    #[test]
    fn consensus_labels_layout() {
        let p = tmp("t.lbl");
        write_consensus_labels(&p, &[2, 0, 1], &[1.0, 0.5, 0.75]).unwrap();
        let text = std::fs::read_to_string(&p).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines, vec!["% 3", "0 2 1", "1 0 0.5", "2 1 0.75"]);
    }

    #[test]
    fn umx_layout() {
        let grid = Grid::new(2, 2, GridType::Square, MapType::Planar);
        let p = tmp("t.umx");
        write_umx(&p, &grid, &[1.0, 2.0, 3.0, 4.0]).unwrap();
        let text = std::fs::read_to_string(&p).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines, vec!["% 2 2", "1 2", "3 4"]);
    }

    #[test]
    fn umx_readable_as_dense_with_header_skipped() {
        // gnuplot-style consumption: the matrix body parses as dense.
        let grid = Grid::new(2, 3, GridType::Square, MapType::Planar);
        let p = tmp("t2.umx");
        write_umx(&p, &grid, &[1., 2., 3., 4., 5., 6.]).unwrap();
        let m = crate::io::dense::read_dense(&p).unwrap();
        // `% 2 3` parses as a header declaring 2 rows — consistent.
        assert_eq!((m.rows, m.cols), (2, 3));
    }
}
