//! File formats (paper §4.1): plain dense, ESOM-header dense, libsvm
//! sparse inputs; codebook / BMU / U-matrix outputs with Databionic ESOM
//! Tools compatibility (`.wts`, `.bm`, `.umx`); the out-of-core
//! streaming sources (`stream::DataSource`, CLI `--chunk-rows`); the
//! binary container format (`binary`, CLI `somoclu convert`) that
//! streams with zero per-epoch parsing; and the `SOMC` training
//! checkpoints (`checkpoint`, CLI `--checkpoint-every` / `--resume`).

pub mod binary;
pub mod checkpoint;
pub mod dense;
pub mod esom;
// Zero-copy mmap sources (`--io mmap`). Always declared: on targets or
// feature sets without the backend the module exports API-compatible
// stubs whose constructors explain the fallback, so no caller needs
// conditional compilation (see `mmap::SUPPORTED`).
pub mod mmap;
pub mod output;
pub mod sparse;
pub mod stream;

pub use binary::{
    sniff as sniff_binary, BinaryDenseFileSource, BinaryKind, BinarySparseFileSource,
    SharedFd,
};
pub use mmap::{MappedContainer, MmapDenseSource, MmapSparseSource};
pub use dense::{read_dense, DenseMatrix};
pub use sparse::read_sparse;
pub use stream::{
    ChunkBuf, ChunkedDenseFileSource, ChunkedSparseFileSource, DataSource,
    InMemorySource, PrefetchSource,
};
