//! File formats (paper §4.1): plain dense, ESOM-header dense, libsvm
//! sparse inputs; codebook / BMU / U-matrix outputs with Databionic ESOM
//! Tools compatibility (`.wts`, `.bm`, `.umx`); the out-of-core
//! streaming sources (`stream::DataSource`, CLI `--chunk-rows`); the
//! binary container format (`binary`, CLI `somoclu convert`) that
//! streams with zero per-epoch parsing; and the `SOMC` training
//! checkpoints (`checkpoint`, CLI `--checkpoint-every` / `--resume`).

pub mod binary;
pub mod checkpoint;
pub mod dense;
pub mod esom;
// Zero-copy mmap sources (`--io mmap`). Always declared: on targets or
// feature sets without the backend the module exports API-compatible
// stubs whose constructors explain the fallback, so no caller needs
// conditional compilation (see `mmap::SUPPORTED`).
pub mod mmap;
pub mod output;
pub mod sparse;
pub mod stream;

pub use binary::{
    sniff as sniff_binary, BinaryDenseFileSource, BinaryKind, BinarySparseFileSource,
    SharedFd,
};
pub use mmap::{MappedContainer, MmapDenseSource, MmapSparseSource};
pub use dense::{read_dense, DenseMatrix};
pub use sparse::read_sparse;
pub use stream::{
    ChunkBuf, ChunkedDenseFileSource, ChunkedSparseFileSource, DataSource,
    InMemorySource, PrefetchSource,
};

use crate::coordinator::config::IoMode;
use crate::error::SomError;
use crate::kernels::KernelType;

/// Human description of a chunking choice for diagnostics: `0` streams
/// the whole pass as one chunk.
pub fn chunk_desc(chunk_rows: usize) -> String {
    if chunk_rows == 0 {
        "whole-pass".to_string()
    } else {
        format!("{chunk_rows}-row")
    }
}

/// Build the single-process streaming source for `input`: binary
/// containers (pass the [`sniff_binary`] result as `kind`) stream
/// natively through the selected `--io` backend (buffered decode,
/// zero-copy mmap views, or positioned pread); text files stream
/// re-parsed (buffered only). `prefetch` wraps any `Send` source in the
/// double-buffered read-ahead adapter (mmap + prefetch was already
/// rejected by `TrainConfig::validate`). With `quiet` the per-source
/// stderr diagnostics are suppressed — the serving daemon streams
/// progress as events instead of log lines; the CLI passes `false`.
pub fn open_stream_source(
    input: &str,
    kind: Option<BinaryKind>,
    kernel: KernelType,
    chunk_rows: usize,
    prefetch: bool,
    io: IoMode,
    quiet: bool,
) -> Result<Box<dyn DataSource + Send>, SomError> {
    let mut src: Box<dyn DataSource + Send> = match (kind, io) {
        (Some(BinaryKind::Dense), IoMode::Mmap) => {
            let s = MmapDenseSource::open(input, chunk_rows)?;
            if !quiet {
                eprintln!(
                    "mapped dense binary input: {} rows x {} dims ({} zero-copy chunk views)",
                    s.rows(),
                    s.dim(),
                    chunk_desc(chunk_rows)
                );
            }
            Box::new(s)
        }
        (Some(BinaryKind::Sparse), IoMode::Mmap) => {
            let s = MmapSparseSource::open(input, chunk_rows)?;
            if !quiet {
                eprintln!(
                    "mapped sparse binary input: {} rows x {} dims ({} zero-copy chunk views)",
                    s.rows(),
                    s.dim(),
                    chunk_desc(chunk_rows)
                );
            }
            Box::new(s)
        }
        (Some(BinaryKind::Dense), IoMode::Pread) => {
            let s = SharedFd::open(input)?.dense_shard(chunk_rows, 0, 1)?;
            if !quiet {
                eprintln!(
                    "streaming dense binary input over one pread fd: {} rows x {} dims ({} chunks)",
                    s.rows(),
                    s.dim(),
                    chunk_desc(chunk_rows)
                );
            }
            Box::new(s)
        }
        (Some(BinaryKind::Sparse), IoMode::Pread) => {
            let s = SharedFd::open(input)?.sparse_shard(chunk_rows, 0, 1)?;
            if !quiet {
                eprintln!(
                    "streaming sparse binary input over one pread fd: {} rows x {} dims ({} chunks)",
                    s.rows(),
                    s.dim(),
                    chunk_desc(chunk_rows)
                );
            }
            Box::new(s)
        }
        (None, mode) if mode != IoMode::Buffered => {
            return Err(SomError::config(mode.text_input_error()));
        }
        (Some(BinaryKind::Dense), _) => {
            let s = BinaryDenseFileSource::open(input, chunk_rows)?;
            if !quiet {
                eprintln!(
                    "streaming dense binary input: {} rows x {} dims ({} chunks)",
                    s.rows(),
                    s.dim(),
                    chunk_desc(chunk_rows)
                );
            }
            Box::new(s)
        }
        (Some(BinaryKind::Sparse), _) => {
            let s = BinarySparseFileSource::open(input, chunk_rows)?;
            if !quiet {
                eprintln!(
                    "streaming sparse binary input: {} rows x {} dims ({} chunks)",
                    s.rows(),
                    s.dim(),
                    chunk_desc(chunk_rows)
                );
            }
            Box::new(s)
        }
        (None, _) if kernel == KernelType::SparseCpu => {
            let s = ChunkedSparseFileSource::open(input, 0, chunk_rows)?;
            if !quiet {
                eprintln!(
                    "streaming sparse input: {} rows x {} dims ({} chunks; run \
                     `somoclu convert --sparse` once to skip per-epoch parsing)",
                    s.rows(),
                    s.dim(),
                    chunk_desc(chunk_rows)
                );
            }
            Box::new(s)
        }
        (None, _) => {
            let s = ChunkedDenseFileSource::open(input, chunk_rows)?;
            if !quiet {
                eprintln!(
                    "streaming dense input: {} rows x {} dims ({} chunks; run \
                     `somoclu convert` once to skip per-epoch parsing)",
                    s.rows(),
                    s.dim(),
                    chunk_desc(chunk_rows)
                );
            }
            Box::new(s)
        }
    };
    if prefetch {
        if !quiet {
            eprintln!("prefetch on: chunk k+1 loads while the kernel runs chunk k");
        }
        src = Box::new(PrefetchSource::new(src));
    }
    Ok(src)
}
