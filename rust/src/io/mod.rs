//! File formats (paper §4.1): plain dense, ESOM-header dense, libsvm
//! sparse inputs; codebook / BMU / U-matrix outputs with Databionic ESOM
//! Tools compatibility (`.wts`, `.bm`, `.umx`).

pub mod dense;
pub mod esom;
pub mod output;
pub mod sparse;

pub use dense::{read_dense, DenseMatrix};
pub use sparse::read_sparse;
