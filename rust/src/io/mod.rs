//! File formats (paper §4.1): plain dense, ESOM-header dense, libsvm
//! sparse inputs; codebook / BMU / U-matrix outputs with Databionic ESOM
//! Tools compatibility (`.wts`, `.bm`, `.umx`); plus the out-of-core
//! streaming sources (`stream::DataSource`, CLI `--chunk-rows`).

pub mod dense;
pub mod esom;
pub mod output;
pub mod sparse;
pub mod stream;

pub use dense::{read_dense, DenseMatrix};
pub use sparse::read_sparse;
pub use stream::{
    ChunkedDenseFileSource, ChunkedSparseFileSource, DataSource, InMemorySource,
};
