//! Output files under an OUTPUT_PREFIX (paper §4.1): "Instead of names
//! of output files for the best matching units, code books, and
//! U-matrices, an output prefix is requested ... the resulting files will
//! be differentiated by the extension, and, if interim snapshots are
//! requested, also by the indices of the epochs".
//!
//! Snapshot levels (paper `-s`): 0 = none, 1 = U-matrix per epoch,
//! 2 = also codebook + BMUs per epoch.

use std::path::{Path, PathBuf};

use crate::io::esom;
use crate::som::{Codebook, Grid};

/// Interim snapshot level (paper `-s`).
#[derive(Copy, Clone, Debug, PartialEq, Eq, PartialOrd)]
pub enum SnapshotLevel {
    None,
    UMatrix,
    Full,
}

impl std::str::FromStr for SnapshotLevel {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "0" => Ok(SnapshotLevel::None),
            "1" => Ok(SnapshotLevel::UMatrix),
            "2" => Ok(SnapshotLevel::Full),
            other => Err(format!("bad snapshot level: {other} (want 0|1|2)")),
        }
    }
}

/// Writer bound to an output prefix.
pub struct OutputWriter {
    prefix: PathBuf,
}

impl OutputWriter {
    pub fn new<P: AsRef<Path>>(prefix: P) -> Self {
        OutputWriter {
            prefix: prefix.as_ref().to_path_buf(),
        }
    }

    fn path(&self, suffix: &str) -> PathBuf {
        let mut s = self.prefix.as_os_str().to_os_string();
        s.push(suffix);
        PathBuf::from(s)
    }

    /// Final outputs: `<prefix>.wts`, `<prefix>.bm`, `<prefix>.umx`.
    pub fn write_final(
        &self,
        grid: &Grid,
        codebook: &Codebook,
        bmus: &[u32],
        umatrix: &[f32],
    ) -> std::io::Result<()> {
        esom::write_wts(self.path(".wts"), grid, codebook)?;
        esom::write_bm(self.path(".bm"), grid, bmus)?;
        esom::write_umx(self.path(".umx"), grid, umatrix)?;
        Ok(())
    }

    /// Interim outputs for `epoch`, differentiated by epoch index.
    pub fn write_snapshot(
        &self,
        level: SnapshotLevel,
        epoch: usize,
        grid: &Grid,
        codebook: &Codebook,
        bmus: &[u32],
        umatrix: &[f32],
    ) -> std::io::Result<()> {
        if level >= SnapshotLevel::UMatrix {
            esom::write_umx(self.path(&format!(".{epoch}.umx")), grid, umatrix)?;
        }
        if level >= SnapshotLevel::Full {
            esom::write_wts(self.path(&format!(".{epoch}.wts")), grid, codebook)?;
            esom::write_bm(self.path(&format!(".{epoch}.bm")), grid, bmus)?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::som::grid::{GridType, MapType};

    fn tmpdir() -> PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "somoclu_test_out_{}",
            std::process::id()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn final_files_created_with_extensions() {
        let grid = Grid::new(2, 2, GridType::Square, MapType::Planar);
        let cb = Codebook::zeros(4, 3);
        let w = OutputWriter::new(tmpdir().join("run1"));
        w.write_final(&grid, &cb, &[0, 1, 2], &[0.0; 4]).unwrap();
        for ext in [".wts", ".bm", ".umx"] {
            assert!(w.path(ext).exists(), "{ext}");
        }
    }

    #[test]
    fn snapshot_levels() {
        let grid = Grid::new(2, 2, GridType::Square, MapType::Planar);
        let cb = Codebook::zeros(4, 3);
        let w = OutputWriter::new(tmpdir().join("run2"));
        w.write_snapshot(SnapshotLevel::None, 0, &grid, &cb, &[], &[0.0; 4])
            .unwrap();
        assert!(!w.path(".0.umx").exists());
        w.write_snapshot(SnapshotLevel::UMatrix, 1, &grid, &cb, &[], &[0.0; 4])
            .unwrap();
        assert!(w.path(".1.umx").exists());
        assert!(!w.path(".1.wts").exists());
        w.write_snapshot(SnapshotLevel::Full, 2, &grid, &cb, &[0], &[0.0; 4])
            .unwrap();
        assert!(w.path(".2.umx").exists());
        assert!(w.path(".2.wts").exists());
        assert!(w.path(".2.bm").exists());
    }

    #[test]
    fn parse_levels() {
        assert_eq!("0".parse::<SnapshotLevel>().unwrap(), SnapshotLevel::None);
        assert_eq!("1".parse::<SnapshotLevel>().unwrap(), SnapshotLevel::UMatrix);
        assert_eq!("2".parse::<SnapshotLevel>().unwrap(), SnapshotLevel::Full);
        assert!("3".parse::<SnapshotLevel>().is_err());
    }
}
