//! Binary row-major container format — the streaming fast path.
//!
//! The text readers pay a full tokenize-and-parse pass per epoch when
//! streaming (`--chunk-rows`); profile shows that parse dominates epoch
//! wall-clock long before the BMU kernel does. This module defines a
//! seekable binary container that is transcoded from the ESOM text /
//! libsvm formats **once** (`somoclu convert`) and then chunk-streamed
//! with zero per-epoch parsing: a chunk read is a header-offset
//! computation plus `read_exact` calls.
//!
//! ## Layout (all integers little-endian)
//!
//! ```text
//! offset  size  field
//!      0     4  magic  b"SOMB"
//!      4     4  version (u32, currently 1)
//!      8     4  kind    (u32: 0 = dense, 1 = sparse CSR)
//!     12     4  reserved (u32, must be 0)
//!     16     8  rows    (u64)
//!     24     8  dim     (u64; sparse: cols)
//!     32     8  nnz     (u64; dense: 0)
//!     40     …  payload
//! ```
//!
//! Dense payload: `rows * dim` f32 values, row-major.
//!
//! Sparse payload, three CSR sections back to back:
//!
//! ```text
//! indptr   u64 * (rows + 1)   cumulative nnz, indptr[0] = 0
//! indices  u32 * nnz          column ids, strictly increasing per row
//! values   f32 * nnz
//! ```
//!
//! Every section offset is computable from the header, so a reader can
//! seek straight to any row window — this is what makes per-rank file
//! sharding (`open_shard`) an O(1) positioning operation instead of a
//! skip-and-parse scan.
//!
//! Corruption handling: `open` validates magic, version, kind, reserved
//! field, and that the file length matches the header-declared payload
//! exactly (a truncated copy is rejected before training starts, the
//! same fail-fast contract as the text sources). Sparse chunk reads
//! additionally check indptr monotonicity and column range.

use std::fs::File;
use std::io::{Read, Write};
use std::path::{Path, PathBuf};
use std::sync::Arc;

use crate::io::stream::{chunk_take, rank_window, ChunkBuf, DataSource};
use crate::kernels::DataShard;
use crate::sparse::Csr;
use crate::util::memtrack;

// ---------------------------------------------------------------------
// Positioned reads (pread)
// ---------------------------------------------------------------------

/// Read exactly `buf.len()` bytes at absolute `off`, without touching
/// the fd's seek cursor (unix `pread`). Cursor independence is what lets
/// N cluster ranks stream disjoint windows through **one shared fd**
/// ([`SharedFd`]) with no per-rank opens and no seek races.
#[cfg(unix)]
pub(crate) fn pread_exact(f: &File, off: u64, buf: &mut [u8]) -> std::io::Result<()> {
    use std::os::unix::fs::FileExt;
    f.read_exact_at(buf, off)
}

/// Windows positioned read. `seek_read` also moves the fd cursor, but
/// every read in this module passes an absolute offset, so concurrent
/// sharers never depend on cursor state.
#[cfg(windows)]
pub(crate) fn pread_exact(f: &File, off: u64, buf: &mut [u8]) -> std::io::Result<()> {
    use std::os::windows::fs::FileExt;
    let mut done = 0usize;
    while done < buf.len() {
        let n = f.seek_read(&mut buf[done..], off + done as u64)?;
        if n == 0 {
            return Err(std::io::Error::new(
                std::io::ErrorKind::UnexpectedEof,
                "failed to fill whole buffer",
            ));
        }
        done += n;
    }
    Ok(())
}

/// Portability fallback: seek-then-read through a borrowed handle.
/// NOT cursor-independent — platforms landing here cannot share one fd
/// across ranks, so [`SharedFd::open`] refuses there.
#[cfg(not(any(unix, windows)))]
pub(crate) fn pread_exact(f: &File, off: u64, buf: &mut [u8]) -> std::io::Result<()> {
    use std::io::{Seek, SeekFrom};
    let mut f = f;
    f.seek(SeekFrom::Start(off))?;
    f.read_exact(buf)
}

/// `b"SOMB"` — SOM Binary.
pub const MAGIC: [u8; 4] = *b"SOMB";
/// Current container version.
pub const VERSION: u32 = 1;
/// Header length in bytes; payload starts here.
pub const HEADER_LEN: u64 = 40;

/// Payload flavor, from the header `kind` field.
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub enum BinaryKind {
    Dense,
    Sparse,
}

/// Parsed container header.
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub struct BinaryHeader {
    pub kind: BinaryKind,
    pub rows: usize,
    pub dim: usize,
    pub nnz: usize,
}

impl BinaryHeader {
    /// Declared payload size. Computed in u128 so a crafted header
    /// (rows/dim near u64::MAX) cannot wrap the product and slip past
    /// the exact-length check in `read_header`.
    fn payload_bytes(&self) -> u128 {
        match self.kind {
            BinaryKind::Dense => 4u128 * (self.rows as u128) * (self.dim as u128),
            BinaryKind::Sparse => {
                8 * (self.rows as u128 + 1) + 4 * (self.nnz as u128) + 4 * (self.nnz as u128)
            }
        }
    }

    /// Byte offset of the sparse indptr section.
    pub(crate) fn indptr_off(&self) -> u64 {
        HEADER_LEN
    }

    /// Byte offset of the sparse indices section.
    pub(crate) fn indices_off(&self) -> u64 {
        HEADER_LEN + 8 * (self.rows as u64 + 1)
    }

    /// Byte offset of the sparse values section.
    pub(crate) fn values_off(&self) -> u64 {
        self.indices_off() + 4 * self.nnz as u64
    }

    fn encode(&self) -> [u8; HEADER_LEN as usize] {
        let mut h = [0u8; HEADER_LEN as usize];
        h[0..4].copy_from_slice(&MAGIC);
        h[4..8].copy_from_slice(&VERSION.to_le_bytes());
        let kind: u32 = match self.kind {
            BinaryKind::Dense => 0,
            BinaryKind::Sparse => 1,
        };
        h[8..12].copy_from_slice(&kind.to_le_bytes());
        // h[12..16] reserved, zero.
        h[16..24].copy_from_slice(&(self.rows as u64).to_le_bytes());
        h[24..32].copy_from_slice(&(self.dim as u64).to_le_bytes());
        h[32..40].copy_from_slice(&(self.nnz as u64).to_le_bytes());
        h
    }
}

/// Read + validate a container header from the start of `f`, including
/// the exact-file-length check (rejects truncated or padded copies).
/// Positioned read: the fd's cursor is untouched, so a [`SharedFd`] can
/// re-validate without disturbing concurrent readers.
pub fn read_header(f: &File, path: &Path) -> anyhow::Result<BinaryHeader> {
    let len = f.metadata()?.len();
    anyhow::ensure!(
        len >= HEADER_LEN,
        "{}: not a somoclu binary file (shorter than the {HEADER_LEN}-byte header)",
        path.display()
    );
    let mut h = [0u8; HEADER_LEN as usize];
    pread_exact(f, 0, &mut h)?;
    anyhow::ensure!(
        h[0..4] == MAGIC,
        "{}: bad magic (not a somoclu binary file)",
        path.display()
    );
    let version = u32::from_le_bytes(h[4..8].try_into().unwrap());
    anyhow::ensure!(
        version == VERSION,
        "{}: unsupported container version {version} (this build reads {VERSION})",
        path.display()
    );
    let kind = match u32::from_le_bytes(h[8..12].try_into().unwrap()) {
        0 => BinaryKind::Dense,
        1 => BinaryKind::Sparse,
        other => anyhow::bail!("{}: unknown payload kind {other}", path.display()),
    };
    let reserved = u32::from_le_bytes(h[12..16].try_into().unwrap());
    anyhow::ensure!(
        reserved == 0,
        "{}: nonzero reserved header field (corrupt header?)",
        path.display()
    );
    let rows = u64::from_le_bytes(h[16..24].try_into().unwrap());
    let dim = u64::from_le_bytes(h[24..32].try_into().unwrap());
    let nnz = u64::from_le_bytes(h[32..40].try_into().unwrap());
    anyhow::ensure!(rows > 0, "{}: header declares zero rows", path.display());
    anyhow::ensure!(dim > 0, "{}: header declares zero dims", path.display());
    if kind == BinaryKind::Dense {
        anyhow::ensure!(
            nnz == 0,
            "{}: dense container with nonzero nnz (corrupt header?)",
            path.display()
        );
    }
    let header = BinaryHeader {
        kind,
        rows: usize::try_from(rows)?,
        dim: usize::try_from(dim)?,
        nnz: usize::try_from(nnz)?,
    };
    let want = HEADER_LEN as u128 + header.payload_bytes();
    anyhow::ensure!(
        len as u128 == want,
        "{}: file is {len} bytes but the header declares {want} \
         (truncated or corrupt copy)",
        path.display()
    );
    // Post-validation invariant: every section offset/row product below
    // is bounded by the actual file length, so u64 arithmetic in the
    // chunk readers cannot overflow.
    Ok(header)
}

/// One indptr entry, positioned-read (the `info` shard report needs two
/// boundary entries per rank, not the whole section).
fn read_indptr_entry(f: &File, h: &BinaryHeader, row: usize) -> anyhow::Result<u64> {
    let mut b = [0u8; 8];
    pread_exact(f, h.indptr_off() + 8 * row as u64, &mut b)?;
    Ok(u64::from_le_bytes(b))
}

/// Human-readable report for `somoclu info`: the decoded header plus,
/// with `ranks > 1`, every rank's `split_ranges` shard window (rows and
/// payload bytes for dense, rows and nnz span for sparse) — the view of
/// a container that previously required a hex dump. Errors on corrupt
/// or truncated headers (the caller exits nonzero).
pub fn info_report<P: AsRef<Path>>(path: P, ranks: usize) -> anyhow::Result<String> {
    use std::fmt::Write as _;
    let path = path.as_ref();
    let file = File::open(path).map_err(|e| anyhow::anyhow!("{}: {e}", path.display()))?;
    let h = read_header(&file, path)?;
    let len = file.metadata()?.len();
    let mut out = String::new();
    let kind = match h.kind {
        BinaryKind::Dense => "dense",
        BinaryKind::Sparse => "sparse (CSR)",
    };
    let _ = writeln!(out, "SOMB container: {}", path.display());
    let _ = writeln!(out, "  version {VERSION}");
    let _ = writeln!(out, "  kind    {kind}");
    let _ = writeln!(out, "  rows    {}", h.rows);
    let _ = writeln!(out, "  dim     {}", h.dim);
    if h.kind == BinaryKind::Sparse {
        let _ = writeln!(
            out,
            "  nnz     {} ({:.3}% dense)",
            h.nnz,
            100.0 * h.nnz as f64 / (h.rows as f64 * h.dim as f64)
        );
    }
    let _ = writeln!(
        out,
        "  file    {len} bytes ({HEADER_LEN}-byte header + payload)"
    );
    if ranks != 1 {
        // Same validation (and error text) as every shard open: ranks
        // must be nonzero and no larger than the row count.
        rank_window(h.rows, 0, ranks)?;
        let _ = writeln!(out, "  shard windows (--ranks {ranks}):");
        for (rank, w) in crate::util::threadpool::split_ranges(h.rows, ranks)
            .into_iter()
            .enumerate()
        {
            match h.kind {
                BinaryKind::Dense => {
                    let b0 = HEADER_LEN + 4 * (w.start as u64) * (h.dim as u64);
                    let b1 = HEADER_LEN + 4 * (w.end as u64) * (h.dim as u64);
                    let _ = writeln!(
                        out,
                        "    rank {rank}: rows [{}, {})  bytes [{b0}, {b1})",
                        w.start, w.end
                    );
                }
                BinaryKind::Sparse => {
                    let a = read_indptr_entry(&file, &h, w.start)?;
                    let b = read_indptr_entry(&file, &h, w.end)?;
                    anyhow::ensure!(
                        b >= a && b as usize <= h.nnz,
                        "{}: corrupt indptr section (window [{a}, {b}), nnz {})",
                        path.display(),
                        h.nnz
                    );
                    let _ = writeln!(
                        out,
                        "    rank {rank}: rows [{}, {})  nnz [{a}, {b})",
                        w.start, w.end
                    );
                }
            }
        }
    }
    Ok(out)
}

/// Peek at the first bytes of `path`: `Some(kind)` if it is a somoclu
/// binary container, `None` for anything else (text inputs). Used by the
/// CLI to auto-detect binary inputs without a flag.
pub fn sniff<P: AsRef<Path>>(path: P) -> std::io::Result<Option<BinaryKind>> {
    let mut f = File::open(path.as_ref())?;
    let mut head = [0u8; 12];
    if f.read_exact(&mut head).is_err() {
        return Ok(None); // shorter than a header: not binary
    }
    if head[0..4] != MAGIC {
        return Ok(None);
    }
    Ok(match u32::from_le_bytes(head[8..12].try_into().unwrap()) {
        0 => Some(BinaryKind::Dense),
        1 => Some(BinaryKind::Sparse),
        _ => Some(BinaryKind::Dense), // sniffed as binary; open() will reject
    })
}

// ---------------------------------------------------------------------
// Writers / convert
// ---------------------------------------------------------------------

/// Write a resident dense matrix (tests, data generators).
pub fn write_binary_dense<P: AsRef<Path>>(
    path: P,
    rows: usize,
    dim: usize,
    data: &[f32],
) -> anyhow::Result<()> {
    assert_eq!(data.len(), rows * dim);
    let header = BinaryHeader {
        kind: BinaryKind::Dense,
        rows,
        dim,
        nnz: 0,
    };
    let mut w = std::io::BufWriter::new(File::create(path)?);
    w.write_all(&header.encode())?;
    write_f32s(&mut w, data)?;
    w.flush()?;
    Ok(())
}

/// Write a resident CSR matrix (tests, data generators).
pub fn write_binary_sparse<P: AsRef<Path>>(path: P, m: &Csr) -> anyhow::Result<()> {
    let header = BinaryHeader {
        kind: BinaryKind::Sparse,
        rows: m.rows,
        dim: m.cols,
        nnz: m.nnz(),
    };
    let mut w = std::io::BufWriter::new(File::create(path)?);
    w.write_all(&header.encode())?;
    for &p in &m.indptr {
        w.write_all(&(p as u64).to_le_bytes())?;
    }
    for &c in &m.indices {
        w.write_all(&c.to_le_bytes())?;
    }
    write_f32s(&mut w, &m.values)?;
    w.flush()?;
    Ok(())
}

fn write_f32s<W: Write>(w: &mut W, vals: &[f32]) -> std::io::Result<()> {
    // Encode through a fixed block so huge payloads never materialize a
    // second byte copy.
    let mut block = [0u8; 8192];
    for chunk in vals.chunks(block.len() / 4) {
        for (i, v) in chunk.iter().enumerate() {
            block[i * 4..i * 4 + 4].copy_from_slice(&v.to_le_bytes());
        }
        w.write_all(&block[..chunk.len() * 4])?;
    }
    Ok(())
}

/// Transcode any [`DataSource`] yielding dense chunks into a binary
/// container, in one streaming pass — memory stays O(chunk) regardless
/// of file size. Returns (rows, dim).
pub fn convert_dense_to_binary<P: AsRef<Path>>(
    src: &mut dyn DataSource,
    out_path: P,
) -> anyhow::Result<(usize, usize)> {
    let (rows, dim) = (src.rows(), src.dim());
    let header = BinaryHeader {
        kind: BinaryKind::Dense,
        rows,
        dim,
        nnz: 0,
    };
    let mut w = std::io::BufWriter::new(File::create(out_path.as_ref())?);
    w.write_all(&header.encode())?;
    src.reset()?;
    let mut written = 0usize;
    while let Some(chunk) = src.next_chunk()? {
        let DataShard::Dense { data, .. } = chunk else {
            anyhow::bail!("convert: expected dense chunks (use --sparse for libsvm inputs)");
        };
        write_f32s(&mut w, data)?;
        written += data.len() / dim;
    }
    anyhow::ensure!(
        written == rows,
        "convert: source yielded {written} rows, expected {rows}"
    );
    w.flush()?;
    Ok((rows, dim))
}

/// Transcode any [`DataSource`] yielding sparse chunks into a binary
/// container. Three streaming passes (indptr, indices, values — the
/// sections are laid out back to back, so each pass appends one section
/// sequentially); memory stays O(chunk + rows·8) — the indptr section is
/// buffered, 8 bytes per row. Returns (rows, cols, nnz).
///
/// Known one-time-cost trade-off: after pass 1 every section offset is
/// computable, so passes 2 and 3 could merge into one text parse using
/// two seek-positioned writers. Conversion runs once per dataset, so we
/// keep the simpler sequential-append form; revisit if convert time on
/// huge sparse inputs ever matters.
pub fn convert_sparse_to_binary<P: AsRef<Path>>(
    src: &mut dyn DataSource,
    out_path: P,
) -> anyhow::Result<(usize, usize, usize)> {
    let (rows, cols) = (src.rows(), src.dim());

    // Pass 1: per-row nnz -> cumulative indptr.
    let mut indptr: Vec<u64> = Vec::with_capacity(rows + 1);
    indptr.push(0);
    src.reset()?;
    while let Some(chunk) = src.next_chunk()? {
        let DataShard::Sparse(m) = chunk else {
            anyhow::bail!("convert --sparse: expected sparse chunks");
        };
        for r in 0..m.rows {
            let (c, _) = m.row(r);
            indptr.push(indptr.last().unwrap() + c.len() as u64);
        }
    }
    anyhow::ensure!(
        indptr.len() == rows + 1,
        "convert: source yielded {} rows, expected {rows}",
        indptr.len() - 1
    );
    let nnz = usize::try_from(*indptr.last().unwrap())?;

    let header = BinaryHeader {
        kind: BinaryKind::Sparse,
        rows,
        dim: cols,
        nnz,
    };
    let mut w = std::io::BufWriter::new(File::create(out_path.as_ref())?);
    w.write_all(&header.encode())?;
    for &p in &indptr {
        w.write_all(&p.to_le_bytes())?;
    }
    drop(indptr);

    // Pass 2: indices section.
    src.reset()?;
    while let Some(chunk) = src.next_chunk()? {
        let DataShard::Sparse(m) = chunk else {
            anyhow::bail!("convert --sparse: expected sparse chunks");
        };
        for r in 0..m.rows {
            let (c, _) = m.row(r);
            for &col in c {
                w.write_all(&col.to_le_bytes())?;
            }
        }
    }

    // Pass 3: values section.
    src.reset()?;
    while let Some(chunk) = src.next_chunk()? {
        let DataShard::Sparse(m) = chunk else {
            anyhow::bail!("convert --sparse: expected sparse chunks");
        };
        for r in 0..m.rows {
            let (_, v) = m.row(r);
            write_f32s(&mut w, v)?;
        }
    }
    w.flush()?;
    Ok((rows, cols, nnz))
}

// ---------------------------------------------------------------------
// Shared positioned-read decode helpers
// ---------------------------------------------------------------------

/// Fixed staging block for LE decode: reads land here, then decode into
/// the typed chunk buffer — bounded at 8 KiB so the data-buffer ledger
/// stays the chunk window itself.
const IO_BLOCK: usize = 8192;

/// Append `count` little-endian values of byte width `W` read at
/// absolute offset `off` to `out`, decoding through the fixed staging
/// block. Positioned reads only — no seek state, so any number of
/// sources can interleave reads on one shared fd. The exact reservation
/// matters: the decode buffer never overshoots the chunk (the 2×-window
/// prefetch bound counts capacity, not length).
fn read_le_at<const W: usize, T>(
    f: &File,
    off: u64,
    count: usize,
    out: &mut Vec<T>,
    decode: fn([u8; W]) -> T,
) -> anyhow::Result<()> {
    out.reserve_exact(count);
    let mut block = [0u8; IO_BLOCK];
    let mut left = count;
    let mut pos = off;
    while left > 0 {
        let take = left.min(IO_BLOCK / W);
        pread_exact(f, pos, &mut block[..take * W])?;
        for i in 0..take {
            out.push(decode(block[i * W..(i + 1) * W].try_into().unwrap()));
        }
        pos += (take * W) as u64;
        left -= take;
    }
    Ok(())
}

fn read_f32s_at(f: &File, off: u64, count: usize, out: &mut Vec<f32>) -> anyhow::Result<()> {
    read_le_at(f, off, count, out, f32::from_le_bytes)
}

fn read_u32s_at(f: &File, off: u64, count: usize, out: &mut Vec<u32>) -> anyhow::Result<()> {
    read_le_at(f, off, count, out, u32::from_le_bytes)
}

fn read_u64s_at(f: &File, off: u64, count: usize, out: &mut Vec<u64>) -> anyhow::Result<()> {
    read_le_at(f, off, count, out, u64::from_le_bytes)
}

// ---------------------------------------------------------------------
// Shared fd (the pread streaming mode, `--io pread`)
// ---------------------------------------------------------------------

/// One open + one validated header, shareable by any number of chunk
/// sources: every rank's source clones the `Arc` and issues positioned
/// reads, so `--ranks N --io pread` holds exactly **one** fd for the
/// data file instead of N per-rank opens (the buffered mode's shape).
#[derive(Clone)]
pub struct SharedFd {
    file: Arc<File>,
    path: PathBuf,
    header: BinaryHeader,
}

impl SharedFd {
    /// Open `path` once and validate its container header.
    pub fn open<P: AsRef<Path>>(path: P) -> anyhow::Result<Self> {
        // The fallback pread_exact (seek + read) is NOT cursor-safe
        // under sharing, so the shared-fd mode refuses where real
        // positioned reads are unavailable.
        if cfg!(not(any(unix, windows))) {
            anyhow::bail!(
                "--io pread needs positioned reads (unix pread / windows \
                 seek_read); this platform has neither — use --io buffered"
            );
        }
        let path = path.as_ref().to_path_buf();
        let file = File::open(&path)?;
        let header = read_header(&file, &path)?;
        Ok(SharedFd {
            file: Arc::new(file),
            path,
            header,
        })
    }

    pub fn header(&self) -> BinaryHeader {
        self.header
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Rank `rank` of `ranks`' dense chunk source over this fd.
    pub fn dense_shard(
        &self,
        chunk_rows: usize,
        rank: usize,
        ranks: usize,
    ) -> anyhow::Result<BinaryDenseFileSource> {
        BinaryDenseFileSource::from_shared(self, chunk_rows, rank, ranks)
    }

    /// Rank `rank` of `ranks`' sparse chunk source over this fd.
    pub fn sparse_shard(
        &self,
        chunk_rows: usize,
        rank: usize,
        ranks: usize,
    ) -> anyhow::Result<BinarySparseFileSource> {
        BinarySparseFileSource::from_shared(self, chunk_rows, rank, ranks)
    }
}

// ---------------------------------------------------------------------
// Dense binary source
// ---------------------------------------------------------------------

/// Streams a dense binary container in `chunk_rows` windows: each chunk
/// is positioned `pread`s, no parsing and no seek state. Supports a
/// `(rank, ranks)` row-window view for per-rank file sharding, either
/// over its own fd (`open_shard`, the buffered default) or over a
/// [`SharedFd`] all ranks share (`--io pread`).
pub struct BinaryDenseFileSource {
    path: PathBuf,
    file: Arc<File>,
    dim: usize,
    /// Global row index of this source's window start.
    row_start: usize,
    /// Rows in this source's window (what `rows()` reports).
    window_rows: usize,
    chunk_rows: usize,
    cursor: usize,
    buf: Vec<f32>,
    reported: usize,
}

impl Drop for BinaryDenseFileSource {
    fn drop(&mut self) {
        memtrack::data_buffer_resize(self.reported, 0);
    }
}

impl BinaryDenseFileSource {
    /// Open the whole file (single-rank view).
    pub fn open<P: AsRef<Path>>(path: P, chunk_rows: usize) -> anyhow::Result<Self> {
        Self::open_shard(path, chunk_rows, 0, 1)
    }

    /// Open rank `rank` of `ranks`' disjoint row window on a private fd.
    pub fn open_shard<P: AsRef<Path>>(
        path: P,
        chunk_rows: usize,
        rank: usize,
        ranks: usize,
    ) -> anyhow::Result<Self> {
        let path = path.as_ref().to_path_buf();
        let file = File::open(&path)?;
        let header = read_header(&file, &path)?;
        Self::build(path, Arc::new(file), header, chunk_rows, rank, ranks)
    }

    /// Rank `rank` of `ranks`' row window over an already-open
    /// [`SharedFd`] (no new open; the fd's header was validated there).
    pub fn from_shared(
        shared: &SharedFd,
        chunk_rows: usize,
        rank: usize,
        ranks: usize,
    ) -> anyhow::Result<Self> {
        Self::build(
            shared.path.clone(),
            Arc::clone(&shared.file),
            shared.header,
            chunk_rows,
            rank,
            ranks,
        )
    }

    fn build(
        path: PathBuf,
        file: Arc<File>,
        header: BinaryHeader,
        chunk_rows: usize,
        rank: usize,
        ranks: usize,
    ) -> anyhow::Result<Self> {
        anyhow::ensure!(
            header.kind == BinaryKind::Dense,
            "{}: sparse container opened as dense (use the sparse kernel, -k 2)",
            path.display()
        );
        let window = rank_window(header.rows, rank, ranks)?;
        Ok(BinaryDenseFileSource {
            path,
            file,
            dim: header.dim,
            row_start: window.start,
            window_rows: window.len(),
            chunk_rows,
            cursor: 0,
            buf: Vec::new(),
            reported: 0,
        })
    }

    fn next_take(&self) -> usize {
        chunk_take(self.window_rows, self.cursor, self.chunk_rows)
    }

    /// Read the next `take` rows into `out` (cleared first) and advance.
    fn fill(&mut self, out: &mut Vec<f32>, take: usize) -> anyhow::Result<()> {
        out.clear();
        let global = self.row_start + self.cursor;
        let off = HEADER_LEN + 4 * (global as u64) * (self.dim as u64);
        read_f32s_at(&self.file, off, take * self.dim, out)?;
        self.cursor += take;
        Ok(())
    }
}

impl DataSource for BinaryDenseFileSource {
    fn rows(&self) -> usize {
        self.window_rows
    }

    fn dim(&self) -> usize {
        self.dim
    }

    fn chunk_rows(&self) -> usize {
        self.chunk_rows
    }

    fn next_chunk(&mut self) -> anyhow::Result<Option<DataShard<'_>>> {
        let take = self.next_take();
        if take == 0 {
            return Ok(None);
        }
        let mut buf = std::mem::take(&mut self.buf);
        let res = self.fill(&mut buf, take);
        self.buf = buf;
        res?;
        let bytes = self.buf.capacity() * 4;
        memtrack::data_buffer_resize(self.reported, bytes);
        self.reported = bytes;
        Ok(Some(DataShard::Dense {
            data: &self.buf,
            dim: self.dim,
        }))
    }

    fn next_chunk_into(&mut self, out: &mut ChunkBuf) -> anyhow::Result<bool> {
        let take = self.next_take();
        if take == 0 {
            return Ok(false);
        }
        let dim = self.dim;
        self.fill(out.make_dense(dim), take)?;
        Ok(true)
    }

    fn reset(&mut self) -> anyhow::Result<()> {
        self.cursor = 0;
        Ok(())
    }
}

// ---------------------------------------------------------------------
// Sparse binary source
// ---------------------------------------------------------------------

/// Streams a sparse (CSR) binary container in `chunk_rows` windows
/// through a reusable scratch CSR: per chunk, one indptr window read and
/// one positioned read per section. Supports `(rank, ranks)` row
/// windows over a private fd or a [`SharedFd`].
pub struct BinarySparseFileSource {
    path: PathBuf,
    file: Arc<File>,
    header: BinaryHeader,
    row_start: usize,
    window_rows: usize,
    chunk_rows: usize,
    cursor: usize,
    /// Reusable indptr window decode buffer (u64, absolute offsets).
    ips: Vec<u64>,
    scratch: Csr,
    reported: usize,
}

impl Drop for BinarySparseFileSource {
    fn drop(&mut self) {
        memtrack::data_buffer_resize(self.reported, 0);
    }
}

impl BinarySparseFileSource {
    /// Open the whole file (single-rank view).
    pub fn open<P: AsRef<Path>>(path: P, chunk_rows: usize) -> anyhow::Result<Self> {
        Self::open_shard(path, chunk_rows, 0, 1)
    }

    /// Open rank `rank` of `ranks`' disjoint row window on a private fd.
    pub fn open_shard<P: AsRef<Path>>(
        path: P,
        chunk_rows: usize,
        rank: usize,
        ranks: usize,
    ) -> anyhow::Result<Self> {
        let path = path.as_ref().to_path_buf();
        let file = File::open(&path)?;
        let header = read_header(&file, &path)?;
        Self::build(path, Arc::new(file), header, chunk_rows, rank, ranks)
    }

    /// Rank `rank` of `ranks`' row window over an already-open
    /// [`SharedFd`] (no new open; the fd's header was validated there).
    pub fn from_shared(
        shared: &SharedFd,
        chunk_rows: usize,
        rank: usize,
        ranks: usize,
    ) -> anyhow::Result<Self> {
        Self::build(
            shared.path.clone(),
            Arc::clone(&shared.file),
            shared.header,
            chunk_rows,
            rank,
            ranks,
        )
    }

    fn build(
        path: PathBuf,
        file: Arc<File>,
        header: BinaryHeader,
        chunk_rows: usize,
        rank: usize,
        ranks: usize,
    ) -> anyhow::Result<Self> {
        anyhow::ensure!(
            header.kind == BinaryKind::Sparse,
            "{}: dense container opened as sparse (drop -k 2 for dense data)",
            path.display()
        );
        let window = rank_window(header.rows, rank, ranks)?;
        let cols = header.dim;
        Ok(BinarySparseFileSource {
            path,
            file,
            header,
            row_start: window.start,
            window_rows: window.len(),
            chunk_rows,
            cursor: 0,
            ips: Vec::new(),
            scratch: Csr::new_empty(0, cols),
            reported: 0,
        })
    }

    fn next_take(&self) -> usize {
        chunk_take(self.window_rows, self.cursor, self.chunk_rows)
    }

    /// Read the next `take` rows into `out` (a reusable CSR) and advance.
    fn fill(&mut self, out: &mut Csr, take: usize) -> anyhow::Result<()> {
        let global = self.row_start + self.cursor;
        let h = self.header; // Copy: keeps `self` free for field borrows

        // indptr window: take + 1 cumulative offsets.
        self.ips.clear();
        read_u64s_at(
            &self.file,
            h.indptr_off() + 8 * global as u64,
            take + 1,
            &mut self.ips,
        )?;
        let a = usize::try_from(self.ips[0])?;
        let b = usize::try_from(self.ips[take])?;
        anyhow::ensure!(
            b >= a && b <= h.nnz,
            "{}: corrupt indptr section (window [{a}, {b}), nnz {})",
            self.path.display(),
            h.nnz
        );
        out.rows = take;
        out.cols = h.dim;
        out.indptr.clear();
        for w in self.ips.windows(2) {
            anyhow::ensure!(
                w[1] >= w[0],
                "{}: corrupt indptr section (non-monotone)",
                self.path.display()
            );
        }
        for &p in &self.ips {
            out.indptr.push(usize::try_from(p)? - a);
        }

        out.indices.clear();
        read_u32s_at(&self.file, h.indices_off() + 4 * a as u64, b - a, &mut out.indices)?;
        for &c in &out.indices {
            anyhow::ensure!(
                (c as usize) < h.dim,
                "{}: corrupt indices section (column {c} out of range, cols {})",
                self.path.display(),
                h.dim
            );
        }
        out.values.clear();
        read_f32s_at(&self.file, h.values_off() + 4 * a as u64, b - a, &mut out.values)?;
        self.cursor += take;
        Ok(())
    }

    /// Report this source's internal buffers (scratch CSR + indptr
    /// decode window) to the additive data-buffer gauge. Called on both
    /// drive paths — under prefetch the scratch stays empty but `ips`
    /// is still real per-source memory.
    fn sync_gauge(&mut self) {
        let bytes = self.scratch.heap_bytes() + self.ips.capacity() * 8;
        memtrack::data_buffer_resize(self.reported, bytes);
        self.reported = bytes;
    }
}

impl DataSource for BinarySparseFileSource {
    fn rows(&self) -> usize {
        self.window_rows
    }

    fn dim(&self) -> usize {
        self.header.dim
    }

    fn chunk_rows(&self) -> usize {
        self.chunk_rows
    }

    fn next_chunk(&mut self) -> anyhow::Result<Option<DataShard<'_>>> {
        let take = self.next_take();
        if take == 0 {
            return Ok(None);
        }
        let mut scratch = std::mem::replace(&mut self.scratch, Csr::new_empty(0, 0));
        let res = self.fill(&mut scratch, take);
        self.scratch = scratch;
        res?;
        self.sync_gauge();
        Ok(Some(DataShard::Sparse(self.scratch.view())))
    }

    fn next_chunk_into(&mut self, out: &mut ChunkBuf) -> anyhow::Result<bool> {
        let take = self.next_take();
        if take == 0 {
            return Ok(false);
        }
        let m = out.make_sparse(self.header.dim);
        self.fill(m, take)?;
        // The chunk itself lives in the caller's (gauge-tracked) buffer,
        // but `ips` is ours on either drive path — keep it on the ledger.
        self.sync_gauge();
        Ok(true)
    }

    fn reset(&mut self) -> anyhow::Result<()> {
        self.cursor = 0;
        Ok(())
    }
}
