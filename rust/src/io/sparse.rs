//! Sparse input format (paper §4.1): libsvm-style rows.
//!
//! "the vector [1.2 0 0 3.4] is represented as the following line in the
//! file: 0:1.2 3:3.4". Comments start with `#`. The file is parsed
//! twice in classic somoclu (dimensions, then data); we parse once and
//! track the max column index, which is equivalent for well-formed files,
//! with an optional explicit dimension override.

use std::io::{BufRead, BufReader, Read};
use std::path::Path;

use crate::sparse::Csr;

#[derive(Debug, thiserror::Error)]
pub enum SparseReadError {
    #[error("io error: {0}")]
    Io(#[from] std::io::Error),
    #[error("line {line}: bad entry '{token}' (want INDEX:VALUE)")]
    BadEntry { line: usize, token: String },
    #[error("line {line}: column indices must be non-decreasing duplicates-free; saw {prev} then {cur}")]
    Unsorted { line: usize, prev: u32, cur: u32 },
    #[error("empty input: no data rows found")]
    Empty,
}

/// Parse one libsvm line into sorted (col, value) pairs. Returns `None`
/// for blank and comment lines (they carry no data row). `lineno` is
/// 1-based, for error reporting. Shared by the whole-file reader below
/// and the chunked streaming source (io::stream).
pub(crate) fn parse_sparse_line(
    line: &str,
    lineno: usize,
) -> Result<Option<Vec<(u32, f32)>>, SparseReadError> {
    let trimmed = line.trim();
    if trimmed.is_empty() || trimmed.starts_with('#') {
        return Ok(None);
    }
    let mut row: Vec<(u32, f32)> = Vec::new();
    let mut prev: Option<u32> = None;
    for token in trimmed.split_whitespace() {
        let (idx, val) = token.split_once(':').ok_or_else(|| {
            SparseReadError::BadEntry {
                line: lineno,
                token: token.to_string(),
            }
        })?;
        let c: u32 = idx.parse().map_err(|_| SparseReadError::BadEntry {
            line: lineno,
            token: token.to_string(),
        })?;
        let v: f32 = val.parse().map_err(|_| SparseReadError::BadEntry {
            line: lineno,
            token: token.to_string(),
        })?;
        if let Some(p) = prev {
            if c <= p {
                return Err(SparseReadError::Unsorted {
                    line: lineno,
                    prev: p,
                    cur: c,
                });
            }
        }
        prev = Some(c);
        row.push((c, v));
    }
    Ok(Some(row))
}

/// Read libsvm-format sparse data. `min_cols` lets callers force a
/// dimensionality larger than max(index)+1.
pub fn read_sparse_from<R: Read>(
    reader: R,
    min_cols: usize,
) -> Result<Csr, SparseReadError> {
    let buf = BufReader::new(reader);
    let mut rows: Vec<Vec<(u32, f32)>> = Vec::new();
    let mut max_col = 0usize;

    for (lineno, line) in buf.lines().enumerate() {
        let line = line?;
        let Some(row) = parse_sparse_line(&line, lineno + 1)? else {
            continue;
        };
        for &(c, _) in &row {
            max_col = max_col.max(c as usize);
        }
        rows.push(row);
    }
    if rows.is_empty() {
        return Err(SparseReadError::Empty);
    }
    let cols = min_cols.max(if rows.iter().all(|r| r.is_empty()) {
        0
    } else {
        max_col + 1
    });
    // from_rows cannot fail here: sortedness and range already enforced.
    Ok(Csr::from_rows(rows, cols).expect("validated rows"))
}

/// Read from a file path.
pub fn read_sparse<P: AsRef<Path>>(
    path: P,
    min_cols: usize,
) -> Result<Csr, SparseReadError> {
    read_sparse_from(std::fs::File::open(path)?, min_cols)
}

/// Write libsvm format (data generators / snapshots).
pub fn write_sparse<P: AsRef<Path>>(path: P, m: &Csr) -> std::io::Result<()> {
    use std::io::Write;
    let f = std::fs::File::create(path)?;
    let mut w = std::io::BufWriter::new(f);
    for r in 0..m.rows {
        let (cols, vals) = m.row(r);
        let mut first = true;
        for (c, v) in cols.iter().zip(vals) {
            if !first {
                write!(w, " ")?;
            }
            write!(w, "{c}:{v}")?;
            first = false;
        }
        writeln!(w)?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_example() {
        // "the vector [1.2 0 0 3.4] is represented as ... 0:1.2 3:3.4"
        let m = read_sparse_from("0:1.2 3:3.4\n".as_bytes(), 0).unwrap();
        assert_eq!(m.rows, 1);
        assert_eq!(m.cols, 4);
        assert_eq!(m.to_dense(), vec![1.2, 0.0, 0.0, 3.4]);
    }

    #[test]
    fn multiple_rows_and_comments() {
        let src = "# comment\n0:1 2:2\n\n1:5\n";
        let m = read_sparse_from(src.as_bytes(), 0).unwrap();
        assert_eq!(m.rows, 2);
        assert_eq!(m.cols, 3);
        assert_eq!(m.to_dense(), vec![1.0, 0.0, 2.0, 0.0, 5.0, 0.0]);
    }

    #[test]
    fn min_cols_override() {
        let m = read_sparse_from("0:1\n".as_bytes(), 10).unwrap();
        assert_eq!(m.cols, 10);
    }

    #[test]
    fn empty_rows_allowed() {
        // A line may legitimately carry zero features only if blank lines
        // are data-free; somoclu skips them, so do we — but an explicit
        // empty vector row can be encoded as a lone newline, which we skip.
        let m = read_sparse_from("0:1\n2:3\n".as_bytes(), 0).unwrap();
        assert_eq!(m.rows, 2);
    }

    #[test]
    fn bad_entries_rejected() {
        assert!(matches!(
            read_sparse_from("0:1 nonsense\n".as_bytes(), 0),
            Err(SparseReadError::BadEntry { line: 1, .. })
        ));
        assert!(matches!(
            read_sparse_from("x:1\n".as_bytes(), 0),
            Err(SparseReadError::BadEntry { .. })
        ));
        assert!(matches!(
            read_sparse_from("0:y\n".as_bytes(), 0),
            Err(SparseReadError::BadEntry { .. })
        ));
    }

    #[test]
    fn unsorted_rejected() {
        assert!(matches!(
            read_sparse_from("3:1 1:2\n".as_bytes(), 0),
            Err(SparseReadError::Unsorted { line: 1, prev: 3, cur: 1 })
        ));
        assert!(matches!(
            read_sparse_from("1:1 1:2\n".as_bytes(), 0),
            Err(SparseReadError::Unsorted { .. })
        ));
    }

    #[test]
    fn empty_input_rejected() {
        assert!(matches!(
            read_sparse_from("# nothing\n".as_bytes(), 0),
            Err(SparseReadError::Empty)
        ));
    }

    #[test]
    fn write_read_round_trip() {
        let dir = std::env::temp_dir().join("somoclu_test_sparse");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("rt.svm");
        let m = Csr::from_rows(
            vec![vec![(0, 1.5), (4, -2.0)], vec![], vec![(2, 7.0)]],
            6,
        )
        .unwrap();
        write_sparse(&path, &m).unwrap();
        // Note: the empty middle row becomes a blank line, which readers
        // skip — classic somoclu has the same behaviour; assert on the
        // nonempty rows.
        let rt = read_sparse(&path, 6).unwrap();
        assert_eq!(rt.rows, 2);
        assert_eq!(rt.row(0), m.row(0));
        assert_eq!(rt.row(1), m.row(2));
    }
}
