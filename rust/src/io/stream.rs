//! Out-of-core streaming data sources.
//!
//! The paper claims "memory use is highly optimized, enabling training
//! large emergent maps even on a single computer" — but a fully resident
//! `Vec<f32>` / `Csr` caps the workload at RAM size. Because the batch
//! formulation (Eq. 6) is a pure sum over data rows, an epoch can
//! accumulate over bounded-memory chunks and merge them exactly like the
//! distributed runner's allreduce (`EpochAccum::merge`); BMUs concatenate
//! in row order. [`DataSource`] is that abstraction: the coordinator's
//! epoch loop becomes
//!
//! ```text
//! source.reset()?;
//! while let Some(chunk) = source.next_chunk()? {
//!     accum.merge(&kernel.epoch_accumulate(chunk, ...)?);
//! }
//! ```
//!
//! Implementations:
//!
//! * [`InMemorySource`] — wraps a resident shard (the classic path);
//!   with `chunk_rows > 0` it yields bounded windows of it, which is
//!   what the chunking-equivalence tests exercise.
//! * [`ChunkedDenseFileSource`] — re-parses a dense text file in
//!   fixed-row windows through one reusable buffer: peak data memory is
//!   O(chunk_rows * dim) regardless of file size.
//! * [`ChunkedSparseFileSource`] — the same for libsvm sparse files,
//!   through a reusable windowed CSR.
//! * [`crate::io::binary`] adds `BinaryDenseFileSource` /
//!   `BinarySparseFileSource` — positioned-read chunking over the binary
//!   container with zero per-epoch parsing (the streaming fast path),
//!   either on a private fd or on one `SharedFd` all ranks share
//!   (`--io pread`).
//! * [`crate::io::mmap`] adds `MmapDenseSource` / `MmapSparseSource` —
//!   zero-copy chunk views straight out of a page-cache mapping
//!   (`--io mmap`), accounted on the mapped-window gauge.
//! * [`PrefetchSource`] — wraps any `Send` source with a reader thread
//!   and two recycled buffers, so chunk k+1 loads while the kernel runs
//!   chunk k (I/O–compute overlap).
//!
//! The file-backed sources support a `(rank, ranks)` row-window view
//! (`open_shard`): rank r streams only its `split_ranges` share of the
//! file, which is how the cluster runner streams disjoint shards from
//! one file instead of loading it whole.
//!
//! Every source accounts its resident buffer bytes to the additive
//! data-buffer gauge ([`memtrack::data_buffer_resize`], released on
//! drop) so benches/tests can assert the bounded-memory property even
//! with one source per cluster rank alive at once. A prefetched source
//! owns two buffers, so its share of the gauge is 2 × chunk bytes.

use std::fs::File;
use std::io::{BufRead, BufReader};
use std::path::{Path, PathBuf};
use std::sync::mpsc;

use crate::io::dense::{is_comment, parse_header_token, ReadError};
use crate::io::sparse::parse_sparse_line;
use crate::kernels::DataShard;
use crate::sparse::{Csr, CsrView};
use crate::util::memtrack;
use crate::util::threadpool::split_ranges;

/// A restartable stream of bounded-size data chunks.
///
/// Contract: after `reset()`, repeated `next_chunk()` calls yield
/// non-empty chunks covering every data row exactly once, in file/buffer
/// order, then `None`. `rows()`/`dim()` are the totals across one full
/// pass and are fixed for the life of the source.
pub trait DataSource {
    /// Total data rows per pass.
    fn rows(&self) -> usize;

    /// Vector dimensionality (columns).
    fn dim(&self) -> usize;

    /// Configured window size in rows; 0 means "one chunk per pass".
    fn chunk_rows(&self) -> usize;

    /// The next chunk of this pass, or `None` when the pass is done.
    /// The returned shard borrows the source's internal buffer and is
    /// valid until the next call on the source.
    fn next_chunk(&mut self) -> anyhow::Result<Option<DataShard<'_>>>;

    /// Like [`Self::next_chunk`], but fills a caller-owned [`ChunkBuf`]
    /// instead of the source's internal buffer (returns `false` at end
    /// of pass). This is the transport [`PrefetchSource`] drives: file
    /// sources override it to read/parse *directly* into the caller's
    /// buffer, so a prefetched pass holds exactly the two transit
    /// buffers and no internal staging copy. The default implementation
    /// copies out of `next_chunk`.
    fn next_chunk_into(&mut self, out: &mut ChunkBuf) -> anyhow::Result<bool> {
        match self.next_chunk()? {
            None => Ok(false),
            Some(DataShard::Dense { data, dim }) => {
                let buf = out.make_dense(dim);
                buf.clear();
                buf.extend_from_slice(data);
                Ok(true)
            }
            Some(DataShard::Sparse(m)) => {
                let dst = out.make_sparse(m.cols);
                dst.rows = m.rows;
                dst.indptr.clear();
                dst.indptr.extend_from_slice(m.indptr);
                dst.indices.clear();
                dst.indices.extend_from_slice(m.indices);
                dst.values.clear();
                dst.values.extend_from_slice(m.values);
                Ok(true)
            }
        }
    }

    /// Rewind to the start for another pass (epoch).
    fn reset(&mut self) -> anyhow::Result<()>;

    /// Whole-data shard if it is resident in memory (used by PCA init,
    /// which needs all rows at once). File-backed sources return `None`.
    fn resident(&self) -> Option<DataShard<'_>> {
        None
    }
}

// Delegate through Box so `Box<dyn DataSource + Send>` is itself a
// source (the cluster runner hands boxed sharded sources to
// `PrefetchSource`, which needs an owned `DataSource + Send` value).
impl<S: DataSource + ?Sized> DataSource for Box<S> {
    fn rows(&self) -> usize {
        (**self).rows()
    }

    fn dim(&self) -> usize {
        (**self).dim()
    }

    fn chunk_rows(&self) -> usize {
        (**self).chunk_rows()
    }

    fn next_chunk(&mut self) -> anyhow::Result<Option<DataShard<'_>>> {
        (**self).next_chunk()
    }

    fn next_chunk_into(&mut self, out: &mut ChunkBuf) -> anyhow::Result<bool> {
        (**self).next_chunk_into(out)
    }

    fn reset(&mut self) -> anyhow::Result<()> {
        (**self).reset()
    }

    fn resident(&self) -> Option<DataShard<'_>> {
        (**self).resident()
    }
}

/// An owned, reusable chunk payload — the unit [`PrefetchSource`] ships
/// between its reader thread and the training loop. Variant switches
/// keep the underlying allocations when possible (`make_dense` /
/// `make_sparse` reuse capacity once warm).
pub enum ChunkBuf {
    Dense { data: Vec<f32>, dim: usize },
    Sparse(Csr),
}

impl ChunkBuf {
    /// Empty buffer; the first `make_dense`/`make_sparse` sets the shape.
    pub fn new() -> Self {
        ChunkBuf::Dense {
            data: Vec::new(),
            dim: 0,
        }
    }

    /// Ensure the dense variant with `dim` columns and return its data
    /// vec (contents unspecified; callers clear before filling).
    pub fn make_dense(&mut self, dim: usize) -> &mut Vec<f32> {
        if !matches!(self, ChunkBuf::Dense { .. }) {
            *self = ChunkBuf::Dense {
                data: Vec::new(),
                dim,
            };
        }
        match self {
            ChunkBuf::Dense { data, dim: d } => {
                *d = dim;
                data
            }
            _ => unreachable!(),
        }
    }

    /// Ensure the sparse variant with `cols` columns and return its CSR
    /// (contents unspecified; callers clear before filling).
    pub fn make_sparse(&mut self, cols: usize) -> &mut Csr {
        if !matches!(self, ChunkBuf::Sparse(_)) {
            *self = ChunkBuf::Sparse(Csr::new_empty(0, cols));
        }
        match self {
            ChunkBuf::Sparse(m) => {
                m.cols = cols;
                m
            }
            _ => unreachable!(),
        }
    }

    /// Borrow as a kernel-consumable shard.
    pub fn as_shard(&self) -> DataShard<'_> {
        match self {
            ChunkBuf::Dense { data, dim } => DataShard::Dense { data, dim: *dim },
            ChunkBuf::Sparse(m) => DataShard::Sparse(m.view()),
        }
    }

    /// Heap bytes currently held (capacity, the gauge currency).
    pub fn heap_bytes(&self) -> usize {
        match self {
            ChunkBuf::Dense { data, .. } => data.capacity() * std::mem::size_of::<f32>(),
            ChunkBuf::Sparse(m) => m.heap_bytes(),
        }
    }
}

impl Default for ChunkBuf {
    fn default() -> Self {
        ChunkBuf::new()
    }
}

/// Rows the next chunk of a pass should carry given the window size,
/// rows already emitted, and the chunk setting (0 = one chunk per
/// pass). Returns 0 when the pass is done. Shared by every file source.
pub(crate) fn chunk_take(window_rows: usize, emitted: usize, chunk_rows: usize) -> usize {
    let left = window_rows - emitted;
    if chunk_rows == 0 {
        left
    } else {
        chunk_rows.min(left)
    }
}

/// Row window owned by `rank` of `ranks` — the same `split_ranges`
/// split the resident cluster sharding uses, so BMUs gathered in rank
/// order concatenate in file row order.
pub(crate) fn rank_window(
    total_rows: usize,
    rank: usize,
    ranks: usize,
) -> anyhow::Result<std::ops::Range<usize>> {
    anyhow::ensure!(ranks > 0, "ranks must be > 0");
    anyhow::ensure!(rank < ranks, "rank {rank} out of range (ranks = {ranks})");
    anyhow::ensure!(
        total_rows >= ranks,
        "fewer data rows ({total_rows}) than ranks ({ranks})"
    );
    Ok(split_ranges(total_rows, ranks).swap_remove(rank))
}

// ---------------------------------------------------------------------
// In-memory source
// ---------------------------------------------------------------------

/// Wraps a resident [`DataShard`]; with `chunk_rows > 0` yields bounded
/// windows of it (dense windows are zero-copy subslices; sparse windows
/// are copied into a reusable scratch CSR).
pub struct InMemorySource<'a> {
    shard: DataShard<'a>,
    chunk_rows: usize,
    cursor: usize,
    /// Reusable window for chunked sparse iteration (rows 0 until used).
    scratch: Csr,
    /// Bytes currently accounted to the data-buffer gauge (shard +
    /// scratch).
    reported: usize,
}

fn shard_bytes(shard: &DataShard<'_>) -> usize {
    match shard {
        DataShard::Dense { data, .. } => std::mem::size_of_val(*data),
        DataShard::Sparse(m) => m.data_bytes(),
    }
}

impl<'a> InMemorySource<'a> {
    pub fn new(shard: DataShard<'a>, chunk_rows: usize) -> Self {
        let bytes = shard_bytes(&shard);
        memtrack::data_buffer_resize(0, bytes);
        InMemorySource {
            shard,
            chunk_rows,
            cursor: 0,
            scratch: Csr::new_empty(0, 0),
            reported: bytes,
        }
    }

    /// Copy rows `start..start + take` of the resident CSR view into the
    /// reusable scratch window (no per-chunk allocation once warm).
    fn fill_scratch(&mut self, m: CsrView<'_>, start: usize, take: usize) {
        let (a, b) = (m.indptr[start], m.indptr[start + take]);
        self.scratch.rows = take;
        self.scratch.cols = m.cols;
        self.scratch.indptr.clear();
        self.scratch
            .indptr
            .extend(m.indptr[start..=start + take].iter().map(|p| p - a));
        self.scratch.indices.clear();
        self.scratch.indices.extend_from_slice(&m.indices[a..b]);
        self.scratch.values.clear();
        self.scratch.values.extend_from_slice(&m.values[a..b]);
        let total = shard_bytes(&self.shard) + self.scratch.heap_bytes();
        memtrack::data_buffer_resize(self.reported, total);
        self.reported = total;
    }
}

impl Drop for InMemorySource<'_> {
    fn drop(&mut self) {
        memtrack::data_buffer_resize(self.reported, 0);
    }
}

impl DataSource for InMemorySource<'_> {
    fn rows(&self) -> usize {
        self.shard.rows()
    }

    fn dim(&self) -> usize {
        self.shard.dim()
    }

    fn chunk_rows(&self) -> usize {
        self.chunk_rows
    }

    fn next_chunk(&mut self) -> anyhow::Result<Option<DataShard<'_>>> {
        let rows = self.shard.rows();
        if self.cursor >= rows {
            return Ok(None);
        }
        let take = if self.chunk_rows == 0 {
            rows - self.cursor
        } else {
            self.chunk_rows.min(rows - self.cursor)
        };
        let start = self.cursor;
        self.cursor += take;
        match self.shard {
            DataShard::Dense { data, dim } => Ok(Some(DataShard::Dense {
                data: &data[start * dim..(start + take) * dim],
                dim,
            })),
            DataShard::Sparse(m) => {
                if take == rows {
                    // Whole-shard pass: no copy at all.
                    Ok(Some(DataShard::Sparse(m)))
                } else {
                    self.fill_scratch(m, start, take);
                    Ok(Some(DataShard::Sparse(self.scratch.view())))
                }
            }
        }
    }

    fn reset(&mut self) -> anyhow::Result<()> {
        self.cursor = 0;
        Ok(())
    }

    fn resident(&self) -> Option<DataShard<'_>> {
        Some(self.shard)
    }
}

// ---------------------------------------------------------------------
// Chunked dense file source
// ---------------------------------------------------------------------

/// Streams a dense text file (plain or ESOM-headered, like
/// [`crate::io::dense::read_dense`]) in windows of `chunk_rows` rows.
///
/// Construction runs a dimension pass ("this file is parsed twice to get
/// the basic dimensions right" — here pass 1 also validates row widths);
/// each epoch then re-parses the file through one reusable
/// `chunk_rows * dim` buffer, so the resident data memory is bounded by
/// the window, not the file. `open_shard` restricts the stream to rank
/// r's `split_ranges` row window (rows before the window are skipped
/// without parsing — they were validated at open).
pub struct ChunkedDenseFileSource {
    path: PathBuf,
    /// Global row index where this source's window starts.
    row_start: usize,
    /// Rows in this source's window (what `rows()` reports).
    window_rows: usize,
    dim: usize,
    chunk_rows: usize,
    reader: Option<BufReader<File>>,
    /// Reusable chunk buffer, capacity `chunk_rows * dim` once warm.
    buf: Vec<f32>,
    /// Reusable line buffer.
    line: String,
    line_no: usize,
    rows_emitted: usize,
    /// Bytes currently accounted to the data-buffer gauge.
    reported: usize,
}

impl Drop for ChunkedDenseFileSource {
    fn drop(&mut self) {
        memtrack::data_buffer_resize(self.reported, 0);
    }
}

impl ChunkedDenseFileSource {
    /// Open `path`, running the dimension/validation pass. `chunk_rows`
    /// of 0 streams the whole file as a single chunk per epoch.
    pub fn open<P: AsRef<Path>>(path: P, chunk_rows: usize) -> anyhow::Result<Self> {
        Self::open_shard(path, chunk_rows, 0, 1)
    }

    /// Open rank `rank` of `ranks`' disjoint row window of `path`.
    pub fn open_shard<P: AsRef<Path>>(
        path: P,
        chunk_rows: usize,
        rank: usize,
        ranks: usize,
    ) -> anyhow::Result<Self> {
        let path = path.as_ref().to_path_buf();
        let mut reader = BufReader::new(File::open(&path)?);
        let mut line = String::new();
        let mut rows = 0usize;
        let mut dim: Option<usize> = None;
        let mut line_no = 0usize;
        let mut header_first: Option<Vec<usize>> = None;
        loop {
            line.clear();
            if reader.read_line(&mut line)? == 0 {
                break;
            }
            line_no += 1;
            if is_comment(&line) {
                continue;
            }
            if let Some(nums) = parse_header_token(&line) {
                if header_first.is_none() {
                    header_first = Some(nums);
                }
                continue;
            }
            // Parse (not just count) every token so a corrupt value fails
            // here, before training starts — same fail-fast guarantee as
            // read_dense, which rejects the file before any epoch runs.
            let mut n = 0usize;
            for token in line.split_whitespace() {
                token.parse::<f32>().map_err(|_| ReadError::BadNumber {
                    line: line_no,
                    token: token.to_string(),
                })?;
                n += 1;
            }
            if n == 0 {
                continue;
            }
            match dim {
                None => dim = Some(n),
                Some(d) if d != n => {
                    return Err(ReadError::Ragged {
                        line: line_no,
                        expected: d,
                        found: n,
                    }
                    .into())
                }
                _ => {}
            }
            rows += 1;
        }
        let dim = dim.ok_or(ReadError::Empty)?;
        // Same ESOM-header check as io::dense::read_dense: a truncated
        // copy must fail here too, not train silently.
        if let Some(first) = header_first {
            let declared = first[0];
            let product: usize = first.iter().product();
            if declared != rows && product != rows {
                return Err(ReadError::HeaderMismatch {
                    declared,
                    found: rows,
                }
                .into());
            }
        }
        let window = rank_window(rows, rank, ranks)?;
        Ok(ChunkedDenseFileSource {
            path,
            row_start: window.start,
            window_rows: window.len(),
            dim,
            chunk_rows,
            reader: None,
            buf: Vec::new(),
            line: String::new(),
            line_no: 0,
            rows_emitted: 0,
            reported: 0,
        })
    }

    fn next_take(&self) -> usize {
        chunk_take(self.window_rows, self.rows_emitted, self.chunk_rows)
    }

    /// Ensure the reader is positioned at the window start (reopening
    /// lazily after `reset`), skipping `row_start` data rows without
    /// parsing — open() already validated them.
    fn ensure_reader(&mut self) -> anyhow::Result<()> {
        if self.reader.is_some() {
            return Ok(());
        }
        let mut reader = BufReader::new(File::open(&self.path)?);
        self.line_no = 0;
        let mut skipped = 0usize;
        while skipped < self.row_start {
            self.line.clear();
            if reader.read_line(&mut self.line)? == 0 {
                anyhow::bail!(
                    "{}: file shrank between passes: hit EOF skipping to row {}",
                    self.path.display(),
                    self.row_start
                );
            }
            self.line_no += 1;
            if is_comment(&self.line) || parse_header_token(&self.line).is_some() {
                continue;
            }
            if self.line.trim().is_empty() {
                continue;
            }
            skipped += 1;
        }
        self.reader = Some(reader);
        Ok(())
    }

    /// Parse the next `want` data rows into `out` (cleared first).
    fn fill(&mut self, out: &mut Vec<f32>, want: usize) -> anyhow::Result<()> {
        self.ensure_reader()?;
        let reader = self.reader.as_mut().expect("just ensured");
        out.clear();
        let mut got = 0usize;
        while got < want {
            self.line.clear();
            if reader.read_line(&mut self.line)? == 0 {
                break;
            }
            self.line_no += 1;
            if is_comment(&self.line) || parse_header_token(&self.line).is_some() {
                continue;
            }
            let trimmed = self.line.trim();
            if trimmed.is_empty() {
                continue;
            }
            let before = out.len();
            for token in trimmed.split_whitespace() {
                let v: f32 = token.parse().map_err(|_| ReadError::BadNumber {
                    line: self.line_no,
                    token: token.to_string(),
                })?;
                out.push(v);
            }
            let found = out.len() - before;
            if found != self.dim {
                return Err(ReadError::Ragged {
                    line: self.line_no,
                    expected: self.dim,
                    found,
                }
                .into());
            }
            got += 1;
        }
        anyhow::ensure!(
            got == want,
            "{}: file shrank between passes: wanted {want} rows, got {got}",
            self.path.display()
        );
        self.rows_emitted += got;
        Ok(())
    }
}

impl DataSource for ChunkedDenseFileSource {
    fn rows(&self) -> usize {
        self.window_rows
    }

    fn dim(&self) -> usize {
        self.dim
    }

    fn chunk_rows(&self) -> usize {
        self.chunk_rows
    }

    fn next_chunk(&mut self) -> anyhow::Result<Option<DataShard<'_>>> {
        let want = self.next_take();
        if want == 0 {
            return Ok(None);
        }
        let mut buf = std::mem::take(&mut self.buf);
        let res = self.fill(&mut buf, want);
        self.buf = buf;
        res?;
        let bytes = self.buf.capacity() * std::mem::size_of::<f32>();
        memtrack::data_buffer_resize(self.reported, bytes);
        self.reported = bytes;
        Ok(Some(DataShard::Dense {
            data: &self.buf,
            dim: self.dim,
        }))
    }

    fn next_chunk_into(&mut self, out: &mut ChunkBuf) -> anyhow::Result<bool> {
        let want = self.next_take();
        if want == 0 {
            return Ok(false);
        }
        // `fill` clears and refills; the caller's buffer is accounted by
        // the caller (the prefetcher), not this source's gauge share.
        let dim = self.dim;
        self.fill(out.make_dense(dim), want)?;
        Ok(true)
    }

    fn reset(&mut self) -> anyhow::Result<()> {
        self.reader = None; // reopened lazily on the next chunk
        self.rows_emitted = 0;
        self.line_no = 0;
        Ok(())
    }
}

// ---------------------------------------------------------------------
// Chunked sparse file source
// ---------------------------------------------------------------------

/// Streams a libsvm sparse file (like [`crate::io::sparse::read_sparse`])
/// in windows of `chunk_rows` rows through a reusable windowed CSR.
/// `open_shard` restricts the stream to a rank's row window, like the
/// dense source.
pub struct ChunkedSparseFileSource {
    path: PathBuf,
    row_start: usize,
    window_rows: usize,
    cols: usize,
    chunk_rows: usize,
    /// nnz capacity the scratch needs to hold any chunk of this window
    /// (computed at open, applied lazily by `reserve_scratch`).
    reserve_nnz: usize,
    reader: Option<BufReader<File>>,
    /// Reusable window. Capacity is sized once on first use to the
    /// largest chunk this window will ever yield, so no chunk — first
    /// epoch or any epoch after `reset()` — reallocates it.
    scratch: Csr,
    line: String,
    line_no: usize,
    rows_emitted: usize,
    /// Bytes currently accounted to the data-buffer gauge.
    reported: usize,
}

impl Drop for ChunkedSparseFileSource {
    fn drop(&mut self) {
        memtrack::data_buffer_resize(self.reported, 0);
    }
}

impl ChunkedSparseFileSource {
    /// Open `path`, running the dimension/validation pass. `min_cols`
    /// forces a dimensionality larger than max(index)+1 (same semantics
    /// as [`crate::io::sparse::read_sparse`]).
    pub fn open<P: AsRef<Path>>(
        path: P,
        min_cols: usize,
        chunk_rows: usize,
    ) -> anyhow::Result<Self> {
        Self::open_shard(path, min_cols, chunk_rows, 0, 1)
    }

    /// Open rank `rank` of `ranks`' disjoint row window of `path`.
    pub fn open_shard<P: AsRef<Path>>(
        path: P,
        min_cols: usize,
        chunk_rows: usize,
        rank: usize,
        ranks: usize,
    ) -> anyhow::Result<Self> {
        let path = path.as_ref().to_path_buf();
        let mut reader = BufReader::new(File::open(&path)?);
        let mut line = String::new();
        let mut line_no = 0usize;
        let mut max_col: Option<usize> = None;
        let mut rows = 0usize;
        let mut total_nnz = 0usize;
        // Scratch pre-reservation bound: the max nnz over any
        // `chunk_rows` consecutive rows (a sliding-window sum over a
        // lazily grown ring — O(min(chunk_rows, rows)) state, NOT
        // O(rows), and never more than the file actually holds even for
        // an absurd --chunk-rows) upper-bounds every chunk-aligned group
        // of every rank window, so the scratch is sized once on first
        // use and never reallocates across `reset()` epochs (the same
        // reuse `InMemorySource` gets for free).
        let mut ring: Vec<usize> = Vec::new();
        let mut win_sum = 0usize;
        let mut max_win_nnz = 0usize;
        loop {
            line.clear();
            if reader.read_line(&mut line)? == 0 {
                break;
            }
            line_no += 1;
            let Some(pairs) = parse_sparse_line(&line, line_no)? else {
                continue;
            };
            for &(c, _) in &pairs {
                max_col = Some(max_col.map_or(c as usize, |m| m.max(c as usize)));
            }
            let nnz = pairs.len();
            total_nnz += nnz;
            if chunk_rows > 0 {
                if ring.len() < chunk_rows {
                    ring.push(nnz);
                } else {
                    let slot = rows % chunk_rows;
                    win_sum -= ring[slot];
                    ring[slot] = nnz;
                }
                win_sum += nnz;
                max_win_nnz = max_win_nnz.max(win_sum);
            }
            rows += 1;
        }
        drop(ring);
        anyhow::ensure!(rows > 0, "{}: no data rows found", path.display());
        let cols = min_cols.max(max_col.map_or(0, |m| m + 1));
        let window = rank_window(rows, rank, ranks)?;

        // chunk_rows == 0 streams the whole window as one chunk: exact
        // for the single-rank view (total nnz); a multi-rank window's
        // nnz is unknowable in one pass, so let the first epoch size the
        // scratch (capacity still sticks for every later epoch).
        let reserve_nnz = if chunk_rows > 0 {
            max_win_nnz
        } else if ranks == 1 {
            total_nnz
        } else {
            0
        };

        Ok(ChunkedSparseFileSource {
            path,
            row_start: window.start,
            window_rows: window.len(),
            cols,
            chunk_rows,
            reserve_nnz,
            reader: None,
            scratch: Csr::new_empty(0, cols),
            line: String::new(),
            line_no: 0,
            rows_emitted: 0,
            reported: 0,
        })
    }

    /// One-time scratch sizing, applied on the first `next_chunk` (not
    /// at open): a source driven only through `next_chunk_into` — the
    /// prefetch path — never touches the scratch, so reserving eagerly
    /// would park a full unaccounted chunk window on the side. Once
    /// applied, no chunk of any epoch reallocates it (`reserve_nnz`
    /// bounds every chunk this window yields).
    fn reserve_scratch(&mut self) {
        if self.scratch.indices.capacity() >= self.reserve_nnz
            && self.scratch.indices.capacity() > 0
        {
            return;
        }
        let chunk_cap = if self.chunk_rows == 0 {
            self.window_rows
        } else {
            self.chunk_rows.min(self.window_rows)
        };
        self.scratch.indptr.reserve_exact(chunk_cap); // new_empty holds 1 already
        self.scratch.indices.reserve_exact(self.reserve_nnz.max(1));
        self.scratch.values.reserve_exact(self.reserve_nnz.max(1));
    }

    fn next_take(&self) -> usize {
        chunk_take(self.window_rows, self.rows_emitted, self.chunk_rows)
    }

    /// Ensure the reader is positioned at the window start, skipping
    /// `row_start` data rows without parsing entries.
    fn ensure_reader(&mut self) -> anyhow::Result<()> {
        if self.reader.is_some() {
            return Ok(());
        }
        let mut reader = BufReader::new(File::open(&self.path)?);
        self.line_no = 0;
        let mut skipped = 0usize;
        while skipped < self.row_start {
            self.line.clear();
            if reader.read_line(&mut self.line)? == 0 {
                anyhow::bail!(
                    "{}: file shrank between passes: hit EOF skipping to row {}",
                    self.path.display(),
                    self.row_start
                );
            }
            self.line_no += 1;
            let t = self.line.trim();
            if t.is_empty() || t.starts_with('#') {
                continue;
            }
            skipped += 1;
        }
        self.reader = Some(reader);
        Ok(())
    }

    /// Parse the next `want` data rows into `out` (cleared first).
    fn fill(&mut self, out: &mut Csr, want: usize) -> anyhow::Result<()> {
        self.ensure_reader()?;
        let reader = self.reader.as_mut().expect("just ensured");
        out.cols = self.cols;
        out.indices.clear();
        out.values.clear();
        out.indptr.clear();
        out.indptr.push(0);
        let mut got = 0usize;
        while got < want {
            self.line.clear();
            if reader.read_line(&mut self.line)? == 0 {
                break;
            }
            self.line_no += 1;
            let Some(pairs) = parse_sparse_line(&self.line, self.line_no)? else {
                continue;
            };
            for (c, v) in pairs {
                anyhow::ensure!(
                    (c as usize) < self.cols,
                    "{}: line {}: column {c} out of range (cols = {}): file \
                     grew between passes?",
                    self.path.display(),
                    self.line_no,
                    self.cols
                );
                out.indices.push(c);
                out.values.push(v);
            }
            out.indptr.push(out.values.len());
            got += 1;
        }
        anyhow::ensure!(
            got == want,
            "{}: file shrank between passes: wanted {want} rows, got {got}",
            self.path.display()
        );
        out.rows = got;
        self.rows_emitted += got;
        Ok(())
    }
}

impl DataSource for ChunkedSparseFileSource {
    fn rows(&self) -> usize {
        self.window_rows
    }

    fn dim(&self) -> usize {
        self.cols
    }

    fn chunk_rows(&self) -> usize {
        self.chunk_rows
    }

    fn next_chunk(&mut self) -> anyhow::Result<Option<DataShard<'_>>> {
        let want = self.next_take();
        if want == 0 {
            return Ok(None);
        }
        self.reserve_scratch();
        let mut scratch = std::mem::replace(&mut self.scratch, Csr::new_empty(0, 0));
        let res = self.fill(&mut scratch, want);
        self.scratch = scratch;
        res?;
        let bytes = self.scratch.heap_bytes();
        memtrack::data_buffer_resize(self.reported, bytes);
        self.reported = bytes;
        Ok(Some(DataShard::Sparse(self.scratch.view())))
    }

    fn next_chunk_into(&mut self, out: &mut ChunkBuf) -> anyhow::Result<bool> {
        let want = self.next_take();
        if want == 0 {
            return Ok(false);
        }
        let cols = self.cols;
        self.fill(out.make_sparse(cols), want)?;
        Ok(true)
    }

    fn reset(&mut self) -> anyhow::Result<()> {
        self.reader = None;
        self.rows_emitted = 0;
        self.line_no = 0;
        Ok(())
    }
}

// ---------------------------------------------------------------------
// Double-buffered prefetch adapter
// ---------------------------------------------------------------------

/// A [`ChunkBuf`] whose gauge share follows it across threads: the
/// reader thread re-reports after every fill, and dropping it anywhere
/// releases its share.
#[derive(Default)]
struct TrackedBuf {
    buf: ChunkBuf,
    reported: usize,
}

impl TrackedBuf {
    fn sync_gauge(&mut self) {
        let bytes = self.buf.heap_bytes();
        memtrack::data_buffer_resize(self.reported, bytes);
        self.reported = bytes;
    }
}

impl Drop for TrackedBuf {
    fn drop(&mut self) {
        memtrack::data_buffer_resize(self.reported, 0);
    }
}

enum FullMsg {
    Chunk(TrackedBuf),
    Eof,
    Err(anyhow::Error),
}

/// Double-buffered read-ahead over any `Send` [`DataSource`]: a reader
/// thread fills chunk k+1 (via [`DataSource::next_chunk_into`], straight
/// into a recycled transit buffer) while the kernel consumes chunk k.
///
/// Exactly two transit buffers exist for the life of the adapter; both
/// are accounted to the data-buffer gauge, so a prefetched file source
/// holds ≤ 2 × chunk bytes (the inner source's own staging buffer stays
/// empty — file sources fill the transit buffer directly).
///
/// Construction primes the first pass immediately, so the first chunk is
/// usually ready before the trainer asks; the coordinator's
/// reset-per-epoch contract is preserved (`reset()` before any
/// consumption is a no-op).
///
/// PCA initialization is unavailable through the adapter (`resident()`
/// is `None`), matching every other file-backed source.
pub struct PrefetchSource {
    rows: usize,
    dim: usize,
    chunk_rows: usize,
    cmd_tx: Option<mpsc::Sender<()>>,
    empty_tx: Option<mpsc::Sender<TrackedBuf>>,
    full_rx: mpsc::Receiver<FullMsg>,
    current: Option<TrackedBuf>,
    /// Chunks handed to the caller since the last pass start.
    consumed: usize,
    /// The current pass hit EOF (or failed): `next_chunk` returns `None`
    /// until the next `reset`.
    drained: bool,
    worker: Option<std::thread::JoinHandle<()>>,
}

impl PrefetchSource {
    pub fn new<S: DataSource + Send + 'static>(mut inner: S) -> Self {
        let rows = inner.rows();
        let dim = inner.dim();
        let chunk_rows = inner.chunk_rows();
        let (cmd_tx, cmd_rx) = mpsc::channel::<()>();
        let (empty_tx, empty_rx) = mpsc::channel::<TrackedBuf>();
        let (full_tx, full_rx) = mpsc::channel::<FullMsg>();
        // The two transit buffers start in the empty queue; the worker
        // recycles them forever (the channels are unbounded, but memory
        // is bounded by this buffer count, not queue capacity).
        empty_tx.send(TrackedBuf::default()).expect("receiver alive");
        empty_tx.send(TrackedBuf::default()).expect("receiver alive");

        let worker = std::thread::Builder::new()
            .name("somoclu-prefetch".into())
            .spawn(move || {
                // One iteration per pass: wait for a pass request, rewind,
                // then stream chunks until EOF/error. Exits when the
                // consumer side drops its channel ends. The buffer that
                // probed EOF is stashed locally for the next pass rather
                // than sent back through the empty channel: the worker
                // must NOT hold an empty-channel sender, or dropping the
                // consumer's sender could never disconnect `empty_rx`
                // and a mid-pass drop would deadlock the join.
                let mut spare: Option<TrackedBuf> = None;
                while cmd_rx.recv().is_ok() {
                    if let Err(e) = inner.reset() {
                        let _ = full_tx.send(FullMsg::Err(e));
                        continue;
                    }
                    loop {
                        let mut tb = match spare.take() {
                            Some(tb) => tb,
                            None => match empty_rx.recv() {
                                Ok(tb) => tb,
                                Err(_) => return,
                            },
                        };
                        match inner.next_chunk_into(&mut tb.buf) {
                            Ok(true) => {
                                tb.sync_gauge();
                                if full_tx.send(FullMsg::Chunk(tb)).is_err() {
                                    return;
                                }
                            }
                            Ok(false) => {
                                spare = Some(tb);
                                let _ = full_tx.send(FullMsg::Eof);
                                break;
                            }
                            Err(e) => {
                                spare = Some(tb);
                                let _ = full_tx.send(FullMsg::Err(e));
                                break;
                            }
                        }
                    }
                }
            })
            .expect("spawn prefetch thread");

        // Prime the first pass: reads start now, before the trainer asks.
        cmd_tx.send(()).expect("worker alive");
        PrefetchSource {
            rows,
            dim,
            chunk_rows,
            cmd_tx: Some(cmd_tx),
            empty_tx: Some(empty_tx),
            full_rx,
            current: None,
            consumed: 0,
            drained: false,
            worker: Some(worker),
        }
    }

    fn empty_tx(&self) -> &mpsc::Sender<TrackedBuf> {
        self.empty_tx.as_ref().expect("live until drop")
    }
}

impl Drop for PrefetchSource {
    fn drop(&mut self) {
        // Closing the command/empty channels unblocks the worker, which
        // exits at its next recv; join so its buffers (and the inner
        // source) release their gauge shares before we return.
        self.cmd_tx.take();
        self.empty_tx.take();
        self.current.take();
        if let Some(h) = self.worker.take() {
            let _ = h.join();
        }
        while let Ok(msg) = self.full_rx.try_recv() {
            drop(msg);
        }
    }
}

impl DataSource for PrefetchSource {
    fn rows(&self) -> usize {
        self.rows
    }

    fn dim(&self) -> usize {
        self.dim
    }

    fn chunk_rows(&self) -> usize {
        self.chunk_rows
    }

    fn next_chunk(&mut self) -> anyhow::Result<Option<DataShard<'_>>> {
        if self.drained {
            return Ok(None);
        }
        if let Some(cur) = self.current.take() {
            // Hand the consumed buffer back for recycling.
            let _ = self.empty_tx().send(cur);
        }
        match self.full_rx.recv() {
            Ok(FullMsg::Chunk(tb)) => {
                self.consumed += 1;
                self.current = Some(tb);
                Ok(Some(self.current.as_ref().expect("just set").buf.as_shard()))
            }
            Ok(FullMsg::Eof) => {
                self.drained = true;
                Ok(None)
            }
            Ok(FullMsg::Err(e)) => {
                self.drained = true;
                Err(e)
            }
            Err(_) => anyhow::bail!("prefetch worker exited unexpectedly"),
        }
    }

    fn reset(&mut self) -> anyhow::Result<()> {
        if !self.drained && self.consumed == 0 {
            // Pass already primed (constructor or a previous reset) and
            // nothing consumed yet: the stream is at position 0.
            return Ok(());
        }
        if let Some(cur) = self.current.take() {
            let _ = self.empty_tx().send(cur);
        }
        // Run the in-flight pass to completion so the worker is idle
        // (mid-pass restarts are rare; a bounded drain keeps the
        // protocol simple). Errors from the cancelled pass are dropped.
        while !self.drained {
            match self.full_rx.recv() {
                Ok(FullMsg::Chunk(tb)) => {
                    let _ = self.empty_tx().send(tb);
                }
                Ok(FullMsg::Eof) | Ok(FullMsg::Err(_)) => self.drained = true,
                Err(_) => anyhow::bail!("prefetch worker exited unexpectedly"),
            }
        }
        self.cmd_tx
            .as_ref()
            .expect("live until drop")
            .send(())
            .map_err(|_| anyhow::anyhow!("prefetch worker exited unexpectedly"))?;
        self.drained = false;
        self.consumed = 0;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::io::{dense, sparse as sparse_io};
    use crate::util::rng::Rng;

    fn tmp(name: &str) -> PathBuf {
        let dir =
            std::env::temp_dir().join(format!("somoclu_stream_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    /// Drain a source into one dense buffer, checking chunk bounds.
    fn drain_dense(src: &mut dyn DataSource) -> Vec<f32> {
        // Queried before the loop: a live chunk borrows the source.
        let want_dim = src.dim();
        let want_chunk = src.chunk_rows();
        let mut out = Vec::new();
        let mut chunks = 0;
        while let Some(chunk) = src.next_chunk().unwrap() {
            let DataShard::Dense { data, dim } = chunk else {
                panic!("expected dense chunks");
            };
            assert_eq!(dim, want_dim);
            if want_chunk > 0 {
                assert!(data.len() / dim <= want_chunk);
            }
            out.extend_from_slice(data);
            chunks += 1;
        }
        assert!(chunks >= 1);
        out
    }

    fn drain_sparse(src: &mut dyn DataSource) -> Vec<f32> {
        let want_dim = src.dim();
        let mut out = Vec::new();
        while let Some(chunk) = src.next_chunk().unwrap() {
            let DataShard::Sparse(m) = chunk else {
                panic!("expected sparse chunks");
            };
            assert_eq!(m.cols, want_dim);
            out.extend_from_slice(&m.to_dense());
        }
        out
    }

    #[test]
    fn in_memory_dense_chunks_cover_everything() {
        let data: Vec<f32> = (0..60).map(|i| i as f32).collect();
        let shard = DataShard::Dense { data: &data, dim: 4 };
        for chunk_rows in [0usize, 1, 7, 15, 100] {
            let mut src = InMemorySource::new(shard, chunk_rows);
            assert_eq!((src.rows(), src.dim()), (15, 4));
            assert_eq!(drain_dense(&mut src), data);
            // Second pass after reset is identical.
            src.reset().unwrap();
            assert_eq!(drain_dense(&mut src), data);
        }
    }

    #[test]
    fn in_memory_sparse_chunks_cover_everything() {
        let mut rng = Rng::new(21);
        let m = Csr::random(13, 9, 0.3, &mut rng);
        let whole = m.to_dense();
        for chunk_rows in [0usize, 1, 5, 13, 50] {
            let mut src = InMemorySource::new(DataShard::Sparse(m.view()), chunk_rows);
            assert_eq!((src.rows(), src.dim()), (13, 9));
            assert_eq!(drain_sparse(&mut src), whole);
            src.reset().unwrap();
            assert_eq!(drain_sparse(&mut src), whole);
        }
    }

    #[test]
    fn in_memory_resident_exposes_whole_shard() {
        let data = vec![1.0f32; 12];
        let src = InMemorySource::new(DataShard::Dense { data: &data, dim: 3 }, 2);
        let resident = src.resident().unwrap();
        assert_eq!(resident.rows(), 4);
    }

    #[test]
    fn dense_file_chunks_match_whole_read() {
        let mut rng = Rng::new(22);
        let rows = 23;
        let dim = 5;
        let data: Vec<f32> = (0..rows * dim).map(|_| rng.normal_f32()).collect();
        let path = tmp("chunked_dense.txt");
        dense::write_dense(&path, rows, dim, &data, true).unwrap();
        let whole = dense::read_dense(&path).unwrap();
        for chunk_rows in [0usize, 1, 7, 23, 64] {
            let mut src = ChunkedDenseFileSource::open(&path, chunk_rows).unwrap();
            assert_eq!((src.rows(), src.dim()), (rows, dim));
            assert_eq!(drain_dense(&mut src), whole.data);
            src.reset().unwrap();
            assert_eq!(drain_dense(&mut src), whole.data);
        }
    }

    #[test]
    fn dense_file_comments_and_headers_skipped() {
        let path = tmp("chunked_dense_hdr.txt");
        std::fs::write(&path, "% 3\n% 2\n# c\n1 2\n\n3 4\n5 6\n").unwrap();
        let mut src = ChunkedDenseFileSource::open(&path, 2).unwrap();
        assert_eq!((src.rows(), src.dim()), (3, 2));
        assert_eq!(drain_dense(&mut src), vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
    }

    #[test]
    fn dense_file_header_mismatch_rejected_at_open() {
        // A headered file declaring more rows than it holds (truncated
        // copy) must fail exactly like read_dense does.
        let path = tmp("truncated.txt");
        std::fs::write(&path, "% 5\n% 2\n1 2\n3 4\n").unwrap();
        assert!(ChunkedDenseFileSource::open(&path, 2).is_err());
    }

    #[test]
    fn dense_file_ragged_rejected_at_open() {
        let path = tmp("ragged.txt");
        std::fs::write(&path, "1 2 3\n4 5\n").unwrap();
        assert!(ChunkedDenseFileSource::open(&path, 4).is_err());
    }

    #[test]
    fn dense_file_bad_number_rejected_at_open() {
        // Corruption anywhere in the file fails before training starts,
        // like read_dense — not mid-epoch when the chunk is reached.
        let path = tmp("badnum.txt");
        std::fs::write(&path, "1 2\n3 nope\n").unwrap();
        assert!(ChunkedDenseFileSource::open(&path, 1).is_err());
    }

    #[test]
    fn dense_file_empty_rejected_at_open() {
        let path = tmp("empty.txt");
        std::fs::write(&path, "# nothing\n").unwrap();
        assert!(ChunkedDenseFileSource::open(&path, 4).is_err());
    }

    #[test]
    fn sparse_file_chunks_match_whole_read() {
        let mut rng = Rng::new(23);
        let m = Csr::random(17, 11, 0.35, &mut rng);
        let path = tmp("chunked_sparse.svm");
        sparse_io::write_sparse(&path, &m).unwrap();
        let whole = sparse_io::read_sparse(&path, 11).unwrap();
        for chunk_rows in [0usize, 1, 4, 17, 40] {
            let mut src = ChunkedSparseFileSource::open(&path, 11, chunk_rows).unwrap();
            assert_eq!((src.rows(), src.dim()), (whole.rows, 11));
            assert_eq!(drain_sparse(&mut src), whole.to_dense());
            src.reset().unwrap();
            assert_eq!(drain_sparse(&mut src), whole.to_dense());
        }
    }

    #[test]
    fn sparse_file_bad_entry_rejected_at_open() {
        let path = tmp("bad.svm");
        std::fs::write(&path, "0:1 nonsense\n").unwrap();
        assert!(ChunkedSparseFileSource::open(&path, 0, 4).is_err());
    }

    #[test]
    fn dense_file_buffer_stays_bounded() {
        // The acceptance property in miniature: a chunked pass over a
        // file must report a data buffer of O(chunk_rows * dim), far
        // below the full matrix.
        let rows = 400;
        let dim = 8;
        let mut rng = Rng::new(24);
        let data: Vec<f32> = (0..rows * dim).map(|_| rng.f32()).collect();
        let path = tmp("bounded.txt");
        dense::write_dense(&path, rows, dim, &data, false).unwrap();

        let chunk_rows = 16;
        let mut src = ChunkedDenseFileSource::open(&path, chunk_rows).unwrap();
        let _ = drain_dense(&mut src);
        // Assert on the source's own buffer (the global gauge is shared
        // with concurrently running tests): it must hold one window, not
        // the file.
        let buf_bytes = src.buf.capacity() * 4;
        let full = rows * dim * 4;
        let window = chunk_rows * dim * 4;
        assert!(buf_bytes >= window, "buffer {buf_bytes} below one window {window}");
        assert!(
            buf_bytes <= 4 * window && buf_bytes < full / 4,
            "buffer {buf_bytes} not bounded by the window (window {window}, full {full})"
        );
        // And the gauge must have seen at least one window-sized report.
        assert!(memtrack::data_buffer_peak() >= window);
    }

    // -- rank-window shards ------------------------------------------

    #[test]
    fn dense_shards_are_disjoint_and_cover_file() {
        let mut rng = Rng::new(25);
        let (rows, dim) = (29, 4);
        let data: Vec<f32> = (0..rows * dim).map(|_| rng.normal_f32()).collect();
        let path = tmp("shard_dense.txt");
        dense::write_dense(&path, rows, dim, &data, false).unwrap();
        for ranks in [1usize, 2, 3, 5] {
            let mut all = Vec::new();
            let mut total = 0;
            for rank in 0..ranks {
                let mut src =
                    ChunkedDenseFileSource::open_shard(&path, 7, rank, ranks).unwrap();
                total += src.rows();
                all.extend(drain_dense(&mut src));
                // Second epoch over the shard is identical.
                src.reset().unwrap();
                let again = drain_dense(&mut src);
                assert_eq!(again.len(), src.rows() * dim);
            }
            assert_eq!(total, rows, "ranks={ranks}");
            assert_eq!(all, data, "ranks={ranks}");
        }
    }

    #[test]
    fn sparse_shards_are_disjoint_and_cover_file() {
        let mut rng = Rng::new(26);
        let m = Csr::random(23, 9, 0.3, &mut rng);
        let path = tmp("shard_sparse.svm");
        sparse_io::write_sparse(&path, &m).unwrap();
        let whole = sparse_io::read_sparse(&path, 9).unwrap().to_dense();
        for ranks in [2usize, 4] {
            let mut all = Vec::new();
            for rank in 0..ranks {
                let mut src =
                    ChunkedSparseFileSource::open_shard(&path, 9, 5, rank, ranks).unwrap();
                all.extend(drain_sparse(&mut src));
            }
            assert_eq!(all, whole, "ranks={ranks}");
        }
    }

    #[test]
    fn shard_rejects_more_ranks_than_rows() {
        let path = tmp("tiny.txt");
        std::fs::write(&path, "1 2\n3 4\n").unwrap();
        assert!(ChunkedDenseFileSource::open_shard(&path, 0, 0, 8).is_err());
        assert!(ChunkedDenseFileSource::open_shard(&path, 0, 2, 2).is_err());
    }

    // -- sparse scratch reuse across epochs --------------------------

    #[test]
    fn sparse_scratch_never_reallocates_across_resets() {
        let mut rng = Rng::new(27);
        let m = Csr::random(40, 12, 0.4, &mut rng);
        let path = tmp("scratch_reuse.svm");
        sparse_io::write_sparse(&path, &m).unwrap();
        let mut src = ChunkedSparseFileSource::open(&path, 12, 7).unwrap();
        // Capacities are sized on first use (pre-reserved to the
        // largest chunk of the window); epochs after that must not grow
        // or move them.
        let first = drain_sparse(&mut src);
        let cap0 = (
            src.scratch.indptr.capacity(),
            src.scratch.indices.capacity(),
            src.scratch.values.capacity(),
        );
        let ptr0 = src.scratch.values.as_ptr();
        assert!(cap0.1 >= src.reserve_nnz && src.reserve_nnz > 0);
        for _ in 0..2 {
            src.reset().unwrap();
            assert_eq!(drain_sparse(&mut src), first);
        }
        let cap1 = (
            src.scratch.indptr.capacity(),
            src.scratch.indices.capacity(),
            src.scratch.values.capacity(),
        );
        assert_eq!(cap0, cap1, "scratch reallocated across epochs");
        assert_eq!(ptr0, src.scratch.values.as_ptr(), "scratch moved");
    }

    // -- ChunkBuf / next_chunk_into ----------------------------------

    #[test]
    fn chunk_buf_switches_variants_and_reports_bytes() {
        let mut buf = ChunkBuf::new();
        let d = buf.make_dense(3);
        d.extend_from_slice(&[1.0, 2.0, 3.0]);
        assert_eq!(buf.as_shard().rows(), 1);
        assert!(buf.heap_bytes() >= 12);
        let m = buf.make_sparse(5);
        m.rows = 1;
        m.indptr = vec![0, 1];
        m.indices = vec![2];
        m.values = vec![7.0];
        assert_eq!(buf.as_shard().rows(), 1);
        assert_eq!(buf.as_shard().dim(), 5);
    }

    #[test]
    fn next_chunk_into_matches_next_chunk() {
        let mut rng = Rng::new(28);
        let (rows, dim) = (19, 3);
        let data: Vec<f32> = (0..rows * dim).map(|_| rng.normal_f32()).collect();
        let path = tmp("into_dense.txt");
        dense::write_dense(&path, rows, dim, &data, false).unwrap();

        let mut by_ref = ChunkedDenseFileSource::open(&path, 4).unwrap();
        let want = drain_dense(&mut by_ref);

        let mut by_buf = ChunkedDenseFileSource::open(&path, 4).unwrap();
        let mut out = Vec::new();
        let mut buf = ChunkBuf::new();
        while by_buf.next_chunk_into(&mut buf).unwrap() {
            let DataShard::Dense { data, .. } = buf.as_shard() else {
                panic!("expected dense");
            };
            out.extend_from_slice(data);
        }
        assert_eq!(out, want);
        // The source's internal staging buffer was never used.
        assert_eq!(by_buf.buf.capacity(), 0);
    }

    #[test]
    fn next_chunk_into_default_impl_copies_in_memory_chunks() {
        let mut rng = Rng::new(29);
        let m = Csr::random(11, 6, 0.4, &mut rng);
        let whole = m.to_dense();
        let mut src = InMemorySource::new(DataShard::Sparse(m.view()), 4);
        let mut buf = ChunkBuf::new();
        let mut out = Vec::new();
        while src.next_chunk_into(&mut buf).unwrap() {
            let DataShard::Sparse(c) = buf.as_shard() else {
                panic!("expected sparse");
            };
            out.extend_from_slice(&c.to_dense());
        }
        assert_eq!(out, whole);
    }

    // -- prefetch ----------------------------------------------------

    #[test]
    fn prefetch_dense_matches_plain_stream_over_epochs() {
        let mut rng = Rng::new(30);
        let (rows, dim) = (53, 6);
        let data: Vec<f32> = (0..rows * dim).map(|_| rng.normal_f32()).collect();
        let path = tmp("prefetch_dense.txt");
        dense::write_dense(&path, rows, dim, &data, false).unwrap();

        let mut plain = ChunkedDenseFileSource::open(&path, 8).unwrap();
        let want = drain_dense(&mut plain);

        let inner = ChunkedDenseFileSource::open(&path, 8).unwrap();
        let mut pf = PrefetchSource::new(inner);
        assert_eq!((pf.rows(), pf.dim(), pf.chunk_rows()), (rows, dim, 8));
        // Three epochs: reset-before-first-pass is a no-op, later resets
        // restart the worker pass.
        for epoch in 0..3 {
            pf.reset().unwrap();
            assert_eq!(drain_dense(&mut pf), want, "epoch {epoch}");
        }
    }

    #[test]
    fn prefetch_sparse_matches_plain_stream() {
        let mut rng = Rng::new(31);
        let m = Csr::random(27, 10, 0.3, &mut rng);
        let path = tmp("prefetch_sparse.svm");
        sparse_io::write_sparse(&path, &m).unwrap();

        let mut plain = ChunkedSparseFileSource::open(&path, 10, 5).unwrap();
        let want = drain_sparse(&mut plain);

        let inner = ChunkedSparseFileSource::open(&path, 10, 5).unwrap();
        let mut pf = PrefetchSource::new(inner);
        for _ in 0..2 {
            pf.reset().unwrap();
            assert_eq!(drain_sparse(&mut pf), want);
        }
    }

    #[test]
    fn prefetch_mid_pass_reset_restarts_cleanly() {
        let mut rng = Rng::new(32);
        let (rows, dim) = (31, 4);
        let data: Vec<f32> = (0..rows * dim).map(|_| rng.normal_f32()).collect();
        let path = tmp("prefetch_reset.txt");
        dense::write_dense(&path, rows, dim, &data, false).unwrap();

        let mut plain = ChunkedDenseFileSource::open(&path, 6).unwrap();
        let want = drain_dense(&mut plain);

        let mut pf = PrefetchSource::new(ChunkedDenseFileSource::open(&path, 6).unwrap());
        pf.reset().unwrap();
        let _ = pf.next_chunk().unwrap(); // consume one chunk, then abandon
        pf.reset().unwrap();
        assert_eq!(drain_dense(&mut pf), want);
    }

    #[test]
    fn prefetch_drop_releases_gauge_share() {
        let mut rng = Rng::new(33);
        let (rows, dim) = (40, 8);
        let data: Vec<f32> = (0..rows * dim).map(|_| rng.normal_f32()).collect();
        let path = tmp("prefetch_drop.txt");
        dense::write_dense(&path, rows, dim, &data, false).unwrap();

        let before = memtrack::data_buffer_bytes();
        {
            let mut pf =
                PrefetchSource::new(ChunkedDenseFileSource::open(&path, 10).unwrap());
            pf.reset().unwrap();
            let _ = pf.next_chunk().unwrap();
        }
        // Both transit buffers and the inner source released their
        // shares on drop. The gauge is global and other unit tests run
        // concurrently in this process, so allow generous slack — a
        // leak here would be the two ~320 B transit buffers held
        // forever, visible far below this bound on repeat runs.
        let after = memtrack::data_buffer_bytes();
        assert!(
            after <= before + 64 * 1024,
            "gauge leaked: before {before}, after {after}"
        );
    }
}
