//! Out-of-core streaming data sources.
//!
//! The paper claims "memory use is highly optimized, enabling training
//! large emergent maps even on a single computer" — but a fully resident
//! `Vec<f32>` / `Csr` caps the workload at RAM size. Because the batch
//! formulation (Eq. 6) is a pure sum over data rows, an epoch can
//! accumulate over bounded-memory chunks and merge them exactly like the
//! distributed runner's allreduce (`EpochAccum::merge`); BMUs concatenate
//! in row order. [`DataSource`] is that abstraction: the coordinator's
//! epoch loop becomes
//!
//! ```text
//! source.reset()?;
//! while let Some(chunk) = source.next_chunk()? {
//!     accum.merge(&kernel.epoch_accumulate(chunk, ...)?);
//! }
//! ```
//!
//! Three implementations:
//!
//! * [`InMemorySource`] — wraps a resident shard (the classic path);
//!   with `chunk_rows > 0` it yields bounded windows of it, which is
//!   what the chunking-equivalence tests exercise.
//! * [`ChunkedDenseFileSource`] — re-parses a dense text file in
//!   fixed-row windows through one reusable buffer: peak data memory is
//!   O(chunk_rows * dim) regardless of file size.
//! * [`ChunkedSparseFileSource`] — the same for libsvm sparse files,
//!   through a reusable windowed CSR.
//!
//! Every source accounts its resident buffer bytes to the additive
//! data-buffer gauge ([`memtrack::data_buffer_resize`], released on
//! drop) so benches/tests can assert the bounded-memory property even
//! with one source per cluster rank alive at once.

use std::fs::File;
use std::io::{BufRead, BufReader};
use std::path::{Path, PathBuf};

use crate::io::dense::{is_comment, parse_header_token, ReadError};
use crate::io::sparse::parse_sparse_line;
use crate::kernels::DataShard;
use crate::sparse::Csr;
use crate::util::memtrack;

/// A restartable stream of bounded-size data chunks.
///
/// Contract: after `reset()`, repeated `next_chunk()` calls yield
/// non-empty chunks covering every data row exactly once, in file/buffer
/// order, then `None`. `rows()`/`dim()` are the totals across one full
/// pass and are fixed for the life of the source.
pub trait DataSource {
    /// Total data rows per pass.
    fn rows(&self) -> usize;

    /// Vector dimensionality (columns).
    fn dim(&self) -> usize;

    /// Configured window size in rows; 0 means "one chunk per pass".
    fn chunk_rows(&self) -> usize;

    /// The next chunk of this pass, or `None` when the pass is done.
    /// The returned shard borrows the source's internal buffer and is
    /// valid until the next call on the source.
    fn next_chunk(&mut self) -> anyhow::Result<Option<DataShard<'_>>>;

    /// Rewind to the start for another pass (epoch).
    fn reset(&mut self) -> anyhow::Result<()>;

    /// Whole-data shard if it is resident in memory (used by PCA init,
    /// which needs all rows at once). File-backed sources return `None`.
    fn resident(&self) -> Option<DataShard<'_>> {
        None
    }
}

// ---------------------------------------------------------------------
// In-memory source
// ---------------------------------------------------------------------

/// Wraps a resident [`DataShard`]; with `chunk_rows > 0` yields bounded
/// windows of it (dense windows are zero-copy subslices; sparse windows
/// are copied into a reusable scratch CSR).
pub struct InMemorySource<'a> {
    shard: DataShard<'a>,
    chunk_rows: usize,
    cursor: usize,
    /// Reusable window for chunked sparse iteration (rows 0 until used).
    scratch: Csr,
    /// Bytes currently accounted to the data-buffer gauge (shard +
    /// scratch).
    reported: usize,
}

fn shard_bytes(shard: &DataShard<'_>) -> usize {
    match shard {
        DataShard::Dense { data, .. } => std::mem::size_of_val(*data),
        DataShard::Sparse(m) => m.heap_bytes(),
    }
}

impl<'a> InMemorySource<'a> {
    pub fn new(shard: DataShard<'a>, chunk_rows: usize) -> Self {
        let bytes = shard_bytes(&shard);
        memtrack::data_buffer_resize(0, bytes);
        InMemorySource {
            shard,
            chunk_rows,
            cursor: 0,
            scratch: Csr::new_empty(0, 0),
            reported: bytes,
        }
    }

    /// Copy rows `start..start + take` of the resident CSR into the
    /// reusable scratch window (no per-chunk allocation once warm).
    fn fill_scratch(&mut self, m: &Csr, start: usize, take: usize) {
        let (a, b) = (m.indptr[start], m.indptr[start + take]);
        self.scratch.rows = take;
        self.scratch.cols = m.cols;
        self.scratch.indptr.clear();
        self.scratch
            .indptr
            .extend(m.indptr[start..=start + take].iter().map(|p| p - a));
        self.scratch.indices.clear();
        self.scratch.indices.extend_from_slice(&m.indices[a..b]);
        self.scratch.values.clear();
        self.scratch.values.extend_from_slice(&m.values[a..b]);
        let total = shard_bytes(&self.shard) + self.scratch.heap_bytes();
        memtrack::data_buffer_resize(self.reported, total);
        self.reported = total;
    }
}

impl Drop for InMemorySource<'_> {
    fn drop(&mut self) {
        memtrack::data_buffer_resize(self.reported, 0);
    }
}

impl DataSource for InMemorySource<'_> {
    fn rows(&self) -> usize {
        self.shard.rows()
    }

    fn dim(&self) -> usize {
        self.shard.dim()
    }

    fn chunk_rows(&self) -> usize {
        self.chunk_rows
    }

    fn next_chunk(&mut self) -> anyhow::Result<Option<DataShard<'_>>> {
        let rows = self.shard.rows();
        if self.cursor >= rows {
            return Ok(None);
        }
        let take = if self.chunk_rows == 0 {
            rows - self.cursor
        } else {
            self.chunk_rows.min(rows - self.cursor)
        };
        let start = self.cursor;
        self.cursor += take;
        match self.shard {
            DataShard::Dense { data, dim } => Ok(Some(DataShard::Dense {
                data: &data[start * dim..(start + take) * dim],
                dim,
            })),
            DataShard::Sparse(m) => {
                if take == rows {
                    // Whole-shard pass: no copy at all.
                    Ok(Some(DataShard::Sparse(m)))
                } else {
                    self.fill_scratch(m, start, take);
                    Ok(Some(DataShard::Sparse(&self.scratch)))
                }
            }
        }
    }

    fn reset(&mut self) -> anyhow::Result<()> {
        self.cursor = 0;
        Ok(())
    }

    fn resident(&self) -> Option<DataShard<'_>> {
        Some(self.shard)
    }
}

// ---------------------------------------------------------------------
// Chunked dense file source
// ---------------------------------------------------------------------

/// Streams a dense text file (plain or ESOM-headered, like
/// [`crate::io::dense::read_dense`]) in windows of `chunk_rows` rows.
///
/// Construction runs a dimension pass ("this file is parsed twice to get
/// the basic dimensions right" — here pass 1 also validates row widths);
/// each epoch then re-parses the file through one reusable
/// `chunk_rows * dim` buffer, so the resident data memory is bounded by
/// the window, not the file.
pub struct ChunkedDenseFileSource {
    path: PathBuf,
    rows: usize,
    dim: usize,
    chunk_rows: usize,
    reader: Option<BufReader<File>>,
    /// Reusable chunk buffer, capacity `chunk_rows * dim` once warm.
    buf: Vec<f32>,
    /// Reusable line buffer.
    line: String,
    line_no: usize,
    rows_emitted: usize,
    /// Bytes currently accounted to the data-buffer gauge.
    reported: usize,
}

impl Drop for ChunkedDenseFileSource {
    fn drop(&mut self) {
        memtrack::data_buffer_resize(self.reported, 0);
    }
}

impl ChunkedDenseFileSource {
    /// Open `path`, running the dimension/validation pass. `chunk_rows`
    /// of 0 streams the whole file as a single chunk per epoch.
    pub fn open<P: AsRef<Path>>(path: P, chunk_rows: usize) -> anyhow::Result<Self> {
        let path = path.as_ref().to_path_buf();
        let mut reader = BufReader::new(File::open(&path)?);
        let mut line = String::new();
        let mut rows = 0usize;
        let mut dim: Option<usize> = None;
        let mut line_no = 0usize;
        let mut header_first: Option<Vec<usize>> = None;
        loop {
            line.clear();
            if reader.read_line(&mut line)? == 0 {
                break;
            }
            line_no += 1;
            if is_comment(&line) {
                continue;
            }
            if let Some(nums) = parse_header_token(&line) {
                if header_first.is_none() {
                    header_first = Some(nums);
                }
                continue;
            }
            // Parse (not just count) every token so a corrupt value fails
            // here, before training starts — same fail-fast guarantee as
            // read_dense, which rejects the file before any epoch runs.
            let mut n = 0usize;
            for token in line.split_whitespace() {
                token.parse::<f32>().map_err(|_| ReadError::BadNumber {
                    line: line_no,
                    token: token.to_string(),
                })?;
                n += 1;
            }
            if n == 0 {
                continue;
            }
            match dim {
                None => dim = Some(n),
                Some(d) if d != n => {
                    return Err(ReadError::Ragged {
                        line: line_no,
                        expected: d,
                        found: n,
                    }
                    .into())
                }
                _ => {}
            }
            rows += 1;
        }
        let dim = dim.ok_or(ReadError::Empty)?;
        // Same ESOM-header check as io::dense::read_dense: a truncated
        // copy must fail here too, not train silently.
        if let Some(first) = header_first {
            let declared = first[0];
            let product: usize = first.iter().product();
            if declared != rows && product != rows {
                return Err(ReadError::HeaderMismatch {
                    declared,
                    found: rows,
                }
                .into());
            }
        }
        Ok(ChunkedDenseFileSource {
            path,
            rows,
            dim,
            chunk_rows,
            reader: None,
            buf: Vec::new(),
            line: String::new(),
            line_no: 0,
            rows_emitted: 0,
            reported: 0,
        })
    }
}

impl DataSource for ChunkedDenseFileSource {
    fn rows(&self) -> usize {
        self.rows
    }

    fn dim(&self) -> usize {
        self.dim
    }

    fn chunk_rows(&self) -> usize {
        self.chunk_rows
    }

    fn next_chunk(&mut self) -> anyhow::Result<Option<DataShard<'_>>> {
        if self.rows_emitted >= self.rows {
            return Ok(None);
        }
        if self.reader.is_none() {
            self.reader = Some(BufReader::new(File::open(&self.path)?));
            self.line_no = 0;
        }
        let want = if self.chunk_rows == 0 {
            self.rows - self.rows_emitted
        } else {
            self.chunk_rows.min(self.rows - self.rows_emitted)
        };
        let reader = self.reader.as_mut().expect("just ensured");
        self.buf.clear();
        let mut got = 0usize;
        while got < want {
            self.line.clear();
            if reader.read_line(&mut self.line)? == 0 {
                break;
            }
            self.line_no += 1;
            if is_comment(&self.line) || parse_header_token(&self.line).is_some() {
                continue;
            }
            let trimmed = self.line.trim();
            if trimmed.is_empty() {
                continue;
            }
            let before = self.buf.len();
            for token in trimmed.split_whitespace() {
                let v: f32 = token.parse().map_err(|_| ReadError::BadNumber {
                    line: self.line_no,
                    token: token.to_string(),
                })?;
                self.buf.push(v);
            }
            let found = self.buf.len() - before;
            if found != self.dim {
                return Err(ReadError::Ragged {
                    line: self.line_no,
                    expected: self.dim,
                    found,
                }
                .into());
            }
            got += 1;
        }
        anyhow::ensure!(
            got == want,
            "{}: file shrank between passes: wanted {want} rows, got {got}",
            self.path.display()
        );
        self.rows_emitted += got;
        let bytes = self.buf.capacity() * std::mem::size_of::<f32>();
        memtrack::data_buffer_resize(self.reported, bytes);
        self.reported = bytes;
        Ok(Some(DataShard::Dense {
            data: &self.buf,
            dim: self.dim,
        }))
    }

    fn reset(&mut self) -> anyhow::Result<()> {
        self.reader = None; // reopened lazily on the next chunk
        self.rows_emitted = 0;
        self.line_no = 0;
        Ok(())
    }
}

// ---------------------------------------------------------------------
// Chunked sparse file source
// ---------------------------------------------------------------------

/// Streams a libsvm sparse file (like [`crate::io::sparse::read_sparse`])
/// in windows of `chunk_rows` rows through a reusable windowed CSR.
pub struct ChunkedSparseFileSource {
    path: PathBuf,
    rows: usize,
    cols: usize,
    chunk_rows: usize,
    reader: Option<BufReader<File>>,
    /// Reusable window; `rows`/`indptr` rebuilt per chunk, `indices`/
    /// `values` reused.
    scratch: Csr,
    line: String,
    line_no: usize,
    rows_emitted: usize,
    /// Bytes currently accounted to the data-buffer gauge.
    reported: usize,
}

impl Drop for ChunkedSparseFileSource {
    fn drop(&mut self) {
        memtrack::data_buffer_resize(self.reported, 0);
    }
}

impl ChunkedSparseFileSource {
    /// Open `path`, running the dimension/validation pass. `min_cols`
    /// forces a dimensionality larger than max(index)+1 (same semantics
    /// as [`crate::io::sparse::read_sparse`]).
    pub fn open<P: AsRef<Path>>(
        path: P,
        min_cols: usize,
        chunk_rows: usize,
    ) -> anyhow::Result<Self> {
        let path = path.as_ref().to_path_buf();
        let mut reader = BufReader::new(File::open(&path)?);
        let mut line = String::new();
        let mut rows = 0usize;
        let mut max_col: Option<usize> = None;
        let mut line_no = 0usize;
        loop {
            line.clear();
            if reader.read_line(&mut line)? == 0 {
                break;
            }
            line_no += 1;
            let Some(pairs) = parse_sparse_line(&line, line_no)? else {
                continue;
            };
            for &(c, _) in &pairs {
                max_col = Some(max_col.map_or(c as usize, |m| m.max(c as usize)));
            }
            rows += 1;
        }
        anyhow::ensure!(rows > 0, "{}: no data rows found", path.display());
        let cols = min_cols.max(max_col.map_or(0, |m| m + 1));
        Ok(ChunkedSparseFileSource {
            path,
            rows,
            cols,
            chunk_rows,
            reader: None,
            scratch: Csr::new_empty(0, cols),
            line: String::new(),
            line_no: 0,
            rows_emitted: 0,
            reported: 0,
        })
    }
}

impl DataSource for ChunkedSparseFileSource {
    fn rows(&self) -> usize {
        self.rows
    }

    fn dim(&self) -> usize {
        self.cols
    }

    fn chunk_rows(&self) -> usize {
        self.chunk_rows
    }

    fn next_chunk(&mut self) -> anyhow::Result<Option<DataShard<'_>>> {
        if self.rows_emitted >= self.rows {
            return Ok(None);
        }
        if self.reader.is_none() {
            self.reader = Some(BufReader::new(File::open(&self.path)?));
            self.line_no = 0;
        }
        let want = if self.chunk_rows == 0 {
            self.rows - self.rows_emitted
        } else {
            self.chunk_rows.min(self.rows - self.rows_emitted)
        };
        let reader = self.reader.as_mut().expect("just ensured");
        self.scratch.indices.clear();
        self.scratch.values.clear();
        self.scratch.indptr.clear();
        self.scratch.indptr.push(0);
        let mut got = 0usize;
        while got < want {
            self.line.clear();
            if reader.read_line(&mut self.line)? == 0 {
                break;
            }
            self.line_no += 1;
            let Some(pairs) = parse_sparse_line(&self.line, self.line_no)? else {
                continue;
            };
            for (c, v) in pairs {
                anyhow::ensure!(
                    (c as usize) < self.cols,
                    "{}: line {}: column {c} out of range (cols = {}): file \
                     grew between passes?",
                    self.path.display(),
                    self.line_no,
                    self.cols
                );
                self.scratch.indices.push(c);
                self.scratch.values.push(v);
            }
            self.scratch.indptr.push(self.scratch.values.len());
            got += 1;
        }
        anyhow::ensure!(
            got == want,
            "{}: file shrank between passes: wanted {want} rows, got {got}",
            self.path.display()
        );
        self.scratch.rows = got;
        self.rows_emitted += got;
        let bytes = self.scratch.heap_bytes();
        memtrack::data_buffer_resize(self.reported, bytes);
        self.reported = bytes;
        Ok(Some(DataShard::Sparse(&self.scratch)))
    }

    fn reset(&mut self) -> anyhow::Result<()> {
        self.reader = None;
        self.rows_emitted = 0;
        self.line_no = 0;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::io::{dense, sparse as sparse_io};
    use crate::util::rng::Rng;

    fn tmp(name: &str) -> PathBuf {
        let dir =
            std::env::temp_dir().join(format!("somoclu_stream_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    /// Drain a source into one dense buffer, checking chunk bounds.
    fn drain_dense(src: &mut dyn DataSource) -> Vec<f32> {
        let mut out = Vec::new();
        let mut chunks = 0;
        while let Some(chunk) = src.next_chunk().unwrap() {
            let DataShard::Dense { data, dim } = chunk else {
                panic!("expected dense chunks");
            };
            assert_eq!(dim, src.dim());
            if src.chunk_rows() > 0 {
                assert!(data.len() / dim <= src.chunk_rows());
            }
            out.extend_from_slice(data);
            chunks += 1;
        }
        assert!(chunks >= 1);
        out
    }

    fn drain_sparse(src: &mut dyn DataSource) -> Vec<f32> {
        let mut out = Vec::new();
        while let Some(chunk) = src.next_chunk().unwrap() {
            let DataShard::Sparse(m) = chunk else {
                panic!("expected sparse chunks");
            };
            assert_eq!(m.cols, src.dim());
            out.extend_from_slice(&m.to_dense());
        }
        out
    }

    #[test]
    fn in_memory_dense_chunks_cover_everything() {
        let data: Vec<f32> = (0..60).map(|i| i as f32).collect();
        let shard = DataShard::Dense { data: &data, dim: 4 };
        for chunk_rows in [0usize, 1, 7, 15, 100] {
            let mut src = InMemorySource::new(shard, chunk_rows);
            assert_eq!((src.rows(), src.dim()), (15, 4));
            assert_eq!(drain_dense(&mut src), data);
            // Second pass after reset is identical.
            src.reset().unwrap();
            assert_eq!(drain_dense(&mut src), data);
        }
    }

    #[test]
    fn in_memory_sparse_chunks_cover_everything() {
        let mut rng = Rng::new(21);
        let m = Csr::random(13, 9, 0.3, &mut rng);
        let whole = m.to_dense();
        for chunk_rows in [0usize, 1, 5, 13, 50] {
            let mut src = InMemorySource::new(DataShard::Sparse(&m), chunk_rows);
            assert_eq!((src.rows(), src.dim()), (13, 9));
            assert_eq!(drain_sparse(&mut src), whole);
            src.reset().unwrap();
            assert_eq!(drain_sparse(&mut src), whole);
        }
    }

    #[test]
    fn in_memory_resident_exposes_whole_shard() {
        let data = vec![1.0f32; 12];
        let src = InMemorySource::new(DataShard::Dense { data: &data, dim: 3 }, 2);
        let resident = src.resident().unwrap();
        assert_eq!(resident.rows(), 4);
    }

    #[test]
    fn dense_file_chunks_match_whole_read() {
        let mut rng = Rng::new(22);
        let rows = 23;
        let dim = 5;
        let data: Vec<f32> = (0..rows * dim).map(|_| rng.normal_f32()).collect();
        let path = tmp("chunked_dense.txt");
        dense::write_dense(&path, rows, dim, &data, true).unwrap();
        let whole = dense::read_dense(&path).unwrap();
        for chunk_rows in [0usize, 1, 7, 23, 64] {
            let mut src = ChunkedDenseFileSource::open(&path, chunk_rows).unwrap();
            assert_eq!((src.rows(), src.dim()), (rows, dim));
            assert_eq!(drain_dense(&mut src), whole.data);
            src.reset().unwrap();
            assert_eq!(drain_dense(&mut src), whole.data);
        }
    }

    #[test]
    fn dense_file_comments_and_headers_skipped() {
        let path = tmp("chunked_dense_hdr.txt");
        std::fs::write(&path, "% 3\n% 2\n# c\n1 2\n\n3 4\n5 6\n").unwrap();
        let mut src = ChunkedDenseFileSource::open(&path, 2).unwrap();
        assert_eq!((src.rows(), src.dim()), (3, 2));
        assert_eq!(drain_dense(&mut src), vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
    }

    #[test]
    fn dense_file_header_mismatch_rejected_at_open() {
        // A headered file declaring more rows than it holds (truncated
        // copy) must fail exactly like read_dense does.
        let path = tmp("truncated.txt");
        std::fs::write(&path, "% 5\n% 2\n1 2\n3 4\n").unwrap();
        assert!(ChunkedDenseFileSource::open(&path, 2).is_err());
    }

    #[test]
    fn dense_file_ragged_rejected_at_open() {
        let path = tmp("ragged.txt");
        std::fs::write(&path, "1 2 3\n4 5\n").unwrap();
        assert!(ChunkedDenseFileSource::open(&path, 4).is_err());
    }

    #[test]
    fn dense_file_bad_number_rejected_at_open() {
        // Corruption anywhere in the file fails before training starts,
        // like read_dense — not mid-epoch when the chunk is reached.
        let path = tmp("badnum.txt");
        std::fs::write(&path, "1 2\n3 nope\n").unwrap();
        assert!(ChunkedDenseFileSource::open(&path, 1).is_err());
    }

    #[test]
    fn dense_file_empty_rejected_at_open() {
        let path = tmp("empty.txt");
        std::fs::write(&path, "# nothing\n").unwrap();
        assert!(ChunkedDenseFileSource::open(&path, 4).is_err());
    }

    #[test]
    fn sparse_file_chunks_match_whole_read() {
        let mut rng = Rng::new(23);
        let m = Csr::random(17, 11, 0.35, &mut rng);
        let path = tmp("chunked_sparse.svm");
        sparse_io::write_sparse(&path, &m).unwrap();
        let whole = sparse_io::read_sparse(&path, 11).unwrap();
        for chunk_rows in [0usize, 1, 4, 17, 40] {
            let mut src = ChunkedSparseFileSource::open(&path, 11, chunk_rows).unwrap();
            assert_eq!((src.rows(), src.dim()), (whole.rows, 11));
            assert_eq!(drain_sparse(&mut src), whole.to_dense());
            src.reset().unwrap();
            assert_eq!(drain_sparse(&mut src), whole.to_dense());
        }
    }

    #[test]
    fn sparse_file_bad_entry_rejected_at_open() {
        let path = tmp("bad.svm");
        std::fs::write(&path, "0:1 nonsense\n").unwrap();
        assert!(ChunkedSparseFileSource::open(&path, 0, 4).is_err());
    }

    #[test]
    fn dense_file_buffer_stays_bounded() {
        // The acceptance property in miniature: a chunked pass over a
        // file must report a data buffer of O(chunk_rows * dim), far
        // below the full matrix.
        let rows = 400;
        let dim = 8;
        let mut rng = Rng::new(24);
        let data: Vec<f32> = (0..rows * dim).map(|_| rng.f32()).collect();
        let path = tmp("bounded.txt");
        dense::write_dense(&path, rows, dim, &data, false).unwrap();

        let chunk_rows = 16;
        let mut src = ChunkedDenseFileSource::open(&path, chunk_rows).unwrap();
        let _ = drain_dense(&mut src);
        // Assert on the source's own buffer (the global gauge is shared
        // with concurrently running tests): it must hold one window, not
        // the file.
        let buf_bytes = src.buf.capacity() * 4;
        let full = rows * dim * 4;
        let window = chunk_rows * dim * 4;
        assert!(buf_bytes >= window, "buffer {buf_bytes} below one window {window}");
        assert!(
            buf_bytes <= 4 * window && buf_bytes < full / 4,
            "buffer {buf_bytes} not bounded by the window (window {window}, full {full})"
        );
        // And the gauge must have seen at least one window-sized report.
        assert!(memtrack::data_buffer_peak() >= window);
    }
}
