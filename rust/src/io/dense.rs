//! Dense input formats (paper §4.1):
//!
//! * basic: whitespace-separated coordinates, one row per data instance;
//!   "this file is parsed twice to get the basic dimensions right".
//! * headered: identical, but with an ESOM-style header carrying the
//!   matrix layout (`% rows [cols]` lines, Databionic-compatible).
//!
//! Comment lines starting with `#` (and `%` header lines) are ignored as
//! data. Entries may be separated by any whitespace.

use std::io::{BufRead, BufReader, Read};
use std::path::Path;

/// Row-major dense matrix as read from disk.
#[derive(Clone, Debug, PartialEq)]
pub struct DenseMatrix {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

#[derive(Debug, thiserror::Error)]
pub enum ReadError {
    #[error("io error: {0}")]
    Io(#[from] std::io::Error),
    #[error("line {line}: ragged row: expected {expected} columns, found {found}")]
    Ragged {
        line: usize,
        expected: usize,
        found: usize,
    },
    #[error("line {line}: cannot parse '{token}' as a number")]
    BadNumber { line: usize, token: String },
    #[error("empty input: no data rows found")]
    Empty,
    #[error("header declares {declared} rows but {found} were read")]
    HeaderMismatch { declared: usize, found: usize },
}

pub(crate) fn is_comment(line: &str) -> bool {
    matches!(line.trim_start().chars().next(), Some('#'))
}

/// Parse ESOM-style header lines: `% <rows>` and `% <cols>` (the first
/// two `%` lines, as written by Databionic ESOM tools / somoclu).
pub(crate) fn parse_header_token(line: &str) -> Option<Vec<usize>> {
    let rest = line.trim_start().strip_prefix('%')?;
    let nums: Result<Vec<usize>, _> =
        rest.split_whitespace().map(|t| t.parse::<usize>()).collect();
    nums.ok().filter(|v| !v.is_empty())
}

/// Read a dense matrix from a reader. Handles both plain and headered
/// formats transparently.
pub fn read_dense_from<R: Read>(reader: R) -> Result<DenseMatrix, ReadError> {
    let buf = BufReader::new(reader);
    let mut data = Vec::new();
    let mut cols: Option<usize> = None;
    let mut rows = 0usize;
    let mut header_lines: Vec<Vec<usize>> = Vec::new();

    for (lineno, line) in buf.lines().enumerate() {
        let line = line?;
        if is_comment(&line) {
            continue;
        }
        if let Some(nums) = parse_header_token(&line) {
            header_lines.push(nums);
            continue;
        }
        let trimmed = line.trim();
        if trimmed.is_empty() {
            continue;
        }
        let start = data.len();
        for token in trimmed.split_whitespace() {
            let v: f32 = token.parse().map_err(|_| ReadError::BadNumber {
                line: lineno + 1,
                token: token.to_string(),
            })?;
            data.push(v);
        }
        let found = data.len() - start;
        match cols {
            None => cols = Some(found),
            Some(c) if c != found => {
                return Err(ReadError::Ragged {
                    line: lineno + 1,
                    expected: c,
                    found,
                })
            }
            _ => {}
        }
        rows += 1;
    }

    let cols = cols.ok_or(ReadError::Empty)?;
    if let Some(first) = header_lines.first() {
        // Two conventions share the `%` header:
        //   data files:  `% <rows>` (then `% <cols>`): first value = rows
        //   .wts files:  `% <map_rows> <map_cols>` (then `% <dim>`):
        //                product of the first line = neuron count = rows
        let declared = first[0];
        let product: usize = first.iter().product();
        if declared != rows && product != rows {
            return Err(ReadError::HeaderMismatch {
                declared,
                found: rows,
            });
        }
    }
    Ok(DenseMatrix { rows, cols, data })
}

/// Read a dense matrix from a file path.
///
/// Like classic somoclu, "this file is parsed twice to get the basic
/// dimensions right": pass 1 counts rows/columns, pass 2 fills an
/// exactly-sized buffer — no reallocation growth, so peak memory equals
/// the matrix itself (the Fig. 7 CLI baseline depends on this).
pub fn read_dense<P: AsRef<Path>>(path: P) -> Result<DenseMatrix, ReadError> {
    let path = path.as_ref();
    // Pass 1: dimensions only.
    let buf = BufReader::new(std::fs::File::open(path)?);
    let mut rows = 0usize;
    let mut cols = 0usize;
    for line in buf.lines() {
        let line = line?;
        if is_comment(&line) || parse_header_token(&line).is_some() {
            continue;
        }
        let n = line.split_whitespace().count();
        if n > 0 {
            rows += 1;
            cols = cols.max(n);
        }
    }
    if rows == 0 {
        return Err(ReadError::Empty);
    }
    // Pass 2: parse into the exact-size buffer (re-using the streaming
    // parser would reallocate; fill in place instead).
    let mut out = DenseMatrix {
        rows,
        cols,
        data: Vec::with_capacity(rows * cols),
    };
    let buf = BufReader::new(std::fs::File::open(path)?);
    let mut header_lines: Vec<Vec<usize>> = Vec::new();
    let mut row_len_check: Option<usize> = None;
    for (lineno, line) in buf.lines().enumerate() {
        let line = line?;
        if is_comment(&line) {
            continue;
        }
        if let Some(nums) = parse_header_token(&line) {
            header_lines.push(nums);
            continue;
        }
        let trimmed = line.trim();
        if trimmed.is_empty() {
            continue;
        }
        let before = out.data.len();
        for token in trimmed.split_whitespace() {
            let v: f32 = token.parse().map_err(|_| ReadError::BadNumber {
                line: lineno + 1,
                token: token.to_string(),
            })?;
            out.data.push(v);
        }
        let found = out.data.len() - before;
        match row_len_check {
            None => row_len_check = Some(found),
            Some(c) if c != found => {
                return Err(ReadError::Ragged {
                    line: lineno + 1,
                    expected: c,
                    found,
                })
            }
            _ => {}
        }
    }
    if let Some(first) = header_lines.first() {
        let declared = first[0];
        let product: usize = first.iter().product();
        if declared != out.rows && product != out.rows {
            return Err(ReadError::HeaderMismatch {
                declared,
                found: out.rows,
            });
        }
    }
    Ok(out)
}

/// Write a dense matrix in the basic format (used by the data
/// generators and the snapshot writer).
pub fn write_dense<P: AsRef<Path>>(
    path: P,
    rows: usize,
    cols: usize,
    data: &[f32],
    header: bool,
) -> std::io::Result<()> {
    use std::io::Write;
    assert_eq!(data.len(), rows * cols);
    let f = std::fs::File::create(path)?;
    let mut w = std::io::BufWriter::new(f);
    if header {
        writeln!(w, "% {rows}")?;
        writeln!(w, "% {cols}")?;
    }
    for r in 0..rows {
        let row = &data[r * cols..(r + 1) * cols];
        let mut first = true;
        for v in row {
            if !first {
                write!(w, " ")?;
            }
            write!(w, "{v}")?;
            first = false;
        }
        writeln!(w)?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plain_format() {
        let src = "1.0 2.0 3.0\n4 5 6\n";
        let m = read_dense_from(src.as_bytes()).unwrap();
        assert_eq!(m.rows, 2);
        assert_eq!(m.cols, 3);
        assert_eq!(m.data, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
    }

    #[test]
    fn comments_and_blank_lines_ignored() {
        let src = "# a comment\n\n1 2\n   # another\n3 4\n\n";
        let m = read_dense_from(src.as_bytes()).unwrap();
        assert_eq!(m.rows, 2);
        assert_eq!(m.cols, 2);
    }

    #[test]
    fn header_format() {
        let src = "% 3\n% 2\n1 2\n3 4\n5 6\n";
        let m = read_dense_from(src.as_bytes()).unwrap();
        assert_eq!((m.rows, m.cols), (3, 2));
    }

    #[test]
    fn header_mismatch_rejected() {
        let src = "% 5\n% 2\n1 2\n3 4\n";
        assert!(matches!(
            read_dense_from(src.as_bytes()),
            Err(ReadError::HeaderMismatch { declared: 5, found: 2 })
        ));
    }

    #[test]
    fn ragged_rejected() {
        let src = "1 2 3\n4 5\n";
        assert!(matches!(
            read_dense_from(src.as_bytes()),
            Err(ReadError::Ragged { line: 2, expected: 3, found: 2 })
        ));
    }

    #[test]
    fn bad_number_reported_with_line() {
        let src = "1 2\n3 x\n";
        match read_dense_from(src.as_bytes()) {
            Err(ReadError::BadNumber { line, token }) => {
                assert_eq!(line, 2);
                assert_eq!(token, "x");
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn empty_rejected() {
        assert!(matches!(
            read_dense_from("# only comments\n".as_bytes()),
            Err(ReadError::Empty)
        ));
    }

    #[test]
    fn tabs_and_multi_space() {
        let src = "1\t2   3\n4\t 5  6\n";
        let m = read_dense_from(src.as_bytes()).unwrap();
        assert_eq!(m.cols, 3);
    }

    #[test]
    fn write_read_round_trip() {
        let dir = std::env::temp_dir().join("somoclu_test_dense");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("rt.txt");
        let data = vec![1.5, -2.0, 0.25, 1e6];
        write_dense(&path, 2, 2, &data, true).unwrap();
        let m = read_dense(&path).unwrap();
        assert_eq!(m.data, data);
        assert_eq!((m.rows, m.cols), (2, 2));
    }
}
