//! Versioned training checkpoints — the `SOMC` container behind
//! [`crate::session::SomSession::save_checkpoint`] and
//! [`crate::session::Som::resume`].
//!
//! A checkpoint captures everything a later process needs to continue a
//! run **bit-identically**: the schedule-relevant configuration (map
//! geometry, neighborhood, cooling endpoints, kernel, seed, total
//! epochs), the epoch cursor (how many epochs have completed), and the
//! exact f32 codebook weights. Runtime knobs (threads, ranks,
//! `--chunk-rows`, prefetch, I/O backend, snapshots) are deliberately
//! *not* stored — they do not affect the trained map, so a run saved on
//! a laptop can resume on a 64-core box with different streaming
//! settings. BMUs are not stored either: the remaining epochs recompute
//! them, and a fully-trained checkpoint re-projects them from the data.
//!
//! ## Layout (all integers little-endian, same conventions as `SOMB`)
//!
//! ```text
//! offset  size  field
//!      0     4  magic  b"SOMC"
//!      4     4  version (u32, currently 1)
//!      8     4  reserved (u32, must be 0)
//!     12     4  kernel (u32: 0 dense, 1 accel, 2 sparse, 3 hybrid)
//!     16     4  grid type (u32: 0 square, 1 hexagonal)
//!     20     4  map type (u32: 0 planar, 1 toroid)
//!     24     4  neighborhood kind (u32: 0 gaussian, 1 bubble)
//!     28     4  compact support (u32: 0 | 1)
//!     32     4  radius cooling (u32: 0 linear, 1 exponential)
//!     36     4  scale cooling (u32: 0 linear, 1 exponential)
//!     40     4  has_radius0 (u32: 0 | 1)
//!     44     4  radius0 (f32 bits; meaningful when has_radius0 = 1)
//!     48     4  radiusN (f32 bits)
//!     52     4  scale0 (f32 bits)
//!     56     4  scaleN (f32 bits)
//!     60     8  map rows (u64)
//!     68     8  map cols (u64)
//!     76     8  total epochs (u64)
//!     84     8  epoch cursor (u64; completed epochs, <= total)
//!     92     8  dim (u64)
//!    100     8  seed (u64)
//!    108     8  payload FNV-1a 64 checksum (u64)
//!    116     …  payload: rows * cols * dim f32 weights, row-major
//! ```
//!
//! Corruption handling mirrors `SOMB` and goes one step further: `load`
//! validates magic, version, the reserved field, every enum range, the
//! cursor bound, and the **exact** file length — and because any f32 bit
//! pattern is a "valid" weight (a length check alone cannot catch bit
//! rot in the payload), the header carries an FNV-1a checksum of the
//! payload bytes that `load` re-verifies. Saves are atomic: the file is
//! written to `<path>.tmp` and renamed into place, so a crash mid-save
//! never destroys the previous checkpoint (the spot-instance contract).

use std::fs::File;
use std::io::{Read, Write};
use std::path::Path;

use crate::coordinator::config::TrainConfig;
use crate::error::SomError;
use crate::kernels::KernelType;
use crate::som::{Codebook, Cooling, GridType, MapType, Neighborhood, NeighborhoodKind};

/// `b"SOMC"` — SOM Checkpoint.
pub const MAGIC: [u8; 4] = *b"SOMC";
/// Current checkpoint version.
pub const VERSION: u32 = 1;
/// Header length in bytes; the weight payload starts here.
pub const HEADER_LEN: u64 = 116;

/// A loaded checkpoint: the reconstructed schedule configuration, the
/// epoch cursor, and the codebook — exactly what
/// [`crate::session::Som::resume`] needs to rebuild a session.
#[derive(Debug, Clone)]
pub struct Checkpoint {
    /// Schedule-relevant configuration; runtime knobs (threads, ranks,
    /// chunking, I/O backend) are at their defaults and may be
    /// overridden by the resuming process.
    pub config: TrainConfig,
    /// Completed epochs (the next epoch to run).
    pub epoch: usize,
    /// The exact codebook weights at the cursor.
    pub codebook: Codebook,
}

/// FNV-1a 64 over a byte stream (the payload checksum).
fn fnv1a(h: u64, bytes: &[u8]) -> u64 {
    let mut h = h;
    for &b in bytes {
        h = (h ^ b as u64).wrapping_mul(0x100_0000_01b3);
    }
    h
}

/// FNV-1a offset basis.
const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;

fn kernel_code(k: KernelType) -> u32 {
    match k {
        KernelType::DenseCpu => 0,
        KernelType::Accel => 1,
        KernelType::SparseCpu => 2,
        KernelType::Hybrid => 3,
    }
}

fn cooling_code(c: Cooling) -> u32 {
    match c {
        Cooling::Linear => 0,
        Cooling::Exponential => 1,
    }
}

/// Checksum of the codebook payload as it is laid out on disk.
fn payload_checksum(weights: &[f32]) -> u64 {
    let mut h = FNV_OFFSET;
    let mut block = [0u8; 8192];
    for chunk in weights.chunks(block.len() / 4) {
        for (i, v) in chunk.iter().enumerate() {
            block[i * 4..i * 4 + 4].copy_from_slice(&v.to_le_bytes());
        }
        h = fnv1a(h, &block[..chunk.len() * 4]);
    }
    h
}

fn encode_header(cfg: &TrainConfig, epoch: usize, cb: &Codebook) -> [u8; HEADER_LEN as usize] {
    let mut h = [0u8; HEADER_LEN as usize];
    h[0..4].copy_from_slice(&MAGIC);
    h[4..8].copy_from_slice(&VERSION.to_le_bytes());
    // h[8..12] reserved, zero.
    h[12..16].copy_from_slice(&kernel_code(cfg.kernel).to_le_bytes());
    let grid_type: u32 = match cfg.grid_type {
        GridType::Square => 0,
        GridType::Hexagonal => 1,
    };
    h[16..20].copy_from_slice(&grid_type.to_le_bytes());
    let map_type: u32 = match cfg.map_type {
        MapType::Planar => 0,
        MapType::Toroid => 1,
    };
    h[20..24].copy_from_slice(&map_type.to_le_bytes());
    let nb_kind: u32 = match cfg.neighborhood.kind {
        NeighborhoodKind::Gaussian => 0,
        NeighborhoodKind::Bubble => 1,
    };
    h[24..28].copy_from_slice(&nb_kind.to_le_bytes());
    h[28..32].copy_from_slice(&u32::from(cfg.neighborhood.compact_support).to_le_bytes());
    h[32..36].copy_from_slice(&cooling_code(cfg.radius_cooling).to_le_bytes());
    h[36..40].copy_from_slice(&cooling_code(cfg.scale_cooling).to_le_bytes());
    h[40..44].copy_from_slice(&u32::from(cfg.radius0.is_some()).to_le_bytes());
    h[44..48].copy_from_slice(&cfg.radius0.unwrap_or(0.0).to_le_bytes());
    h[48..52].copy_from_slice(&cfg.radius_n.to_le_bytes());
    h[52..56].copy_from_slice(&cfg.scale0.to_le_bytes());
    h[56..60].copy_from_slice(&cfg.scale_n.to_le_bytes());
    h[60..68].copy_from_slice(&(cfg.rows as u64).to_le_bytes());
    h[68..76].copy_from_slice(&(cfg.cols as u64).to_le_bytes());
    h[76..84].copy_from_slice(&(cfg.epochs as u64).to_le_bytes());
    h[84..92].copy_from_slice(&(epoch as u64).to_le_bytes());
    h[92..100].copy_from_slice(&(cb.dim as u64).to_le_bytes());
    h[100..108].copy_from_slice(&cfg.seed.to_le_bytes());
    h[108..116].copy_from_slice(&payload_checksum(&cb.weights).to_le_bytes());
    h
}

/// Write a checkpoint atomically: encode to `<path>.tmp`, then rename
/// over `path`, so an interrupted save never corrupts an existing file.
/// Every failure (shape mismatch, cursor out of range, I/O) surfaces as
/// [`SomError::Checkpoint`] (code `checkpoint`).
pub fn save<P: AsRef<Path>>(
    path: P,
    cfg: &TrainConfig,
    epoch: usize,
    codebook: &Codebook,
) -> Result<(), SomError> {
    save_impl(path.as_ref(), cfg, epoch, codebook)
        .map_err(|e| SomError::checkpoint(format!("{e:#}")))
}

fn save_impl(
    path: &Path,
    cfg: &TrainConfig,
    epoch: usize,
    codebook: &Codebook,
) -> anyhow::Result<()> {
    anyhow::ensure!(
        codebook.nodes == cfg.rows * cfg.cols && codebook.weights.len() == codebook.nodes * codebook.dim,
        "checkpoint: codebook shape {}x{} does not match the {}x{} map",
        codebook.nodes,
        codebook.dim,
        cfg.rows,
        cfg.cols
    );
    anyhow::ensure!(
        epoch <= cfg.epochs,
        "checkpoint: epoch cursor {epoch} beyond total epochs {}",
        cfg.epochs
    );
    // Append ".tmp" to the FULL file name (with_extension would replace
    // the final extension, colliding distinct checkpoints that share a
    // stem — e.g. "model.a" and "model.b" would both stage through
    // "model.somc.tmp" and corrupt each other under concurrency).
    let tmp = {
        let mut os = path.as_os_str().to_os_string();
        os.push(".tmp");
        std::path::PathBuf::from(os)
    };
    {
        let mut w = std::io::BufWriter::new(File::create(&tmp)?);
        w.write_all(&encode_header(cfg, epoch, codebook))?;
        let mut block = [0u8; 8192];
        for chunk in codebook.weights.chunks(block.len() / 4) {
            for (i, v) in chunk.iter().enumerate() {
                block[i * 4..i * 4 + 4].copy_from_slice(&v.to_le_bytes());
            }
            w.write_all(&block[..chunk.len() * 4])?;
        }
        w.flush()?;
    }
    std::fs::rename(&tmp, path)?;
    Ok(())
}

fn decode_u32(h: &[u8], off: usize) -> u32 {
    u32::from_le_bytes(h[off..off + 4].try_into().unwrap())
}

fn decode_u64(h: &[u8], off: usize) -> u64 {
    u64::from_le_bytes(h[off..off + 8].try_into().unwrap())
}

fn decode_f32(h: &[u8], off: usize) -> f32 {
    f32::from_le_bytes(h[off..off + 4].try_into().unwrap())
}

/// Read + validate a `SOMC` checkpoint: magic, version, reserved field,
/// enum ranges, cursor bound, exact file length, and the payload
/// checksum. Any failure is a [`SomError::Checkpoint`] (code
/// `checkpoint`) naming the file — a truncated or bit-rotted checkpoint
/// is rejected before a resumed run starts.
pub fn load<P: AsRef<Path>>(path: P) -> Result<Checkpoint, SomError> {
    load_impl(path.as_ref()).map_err(|e| SomError::checkpoint(format!("{e:#}")))
}

fn load_impl(path: &Path) -> anyhow::Result<Checkpoint> {
    let mut f =
        File::open(path).map_err(|e| anyhow::anyhow!("{}: {e}", path.display()))?;
    let len = f.metadata()?.len();
    anyhow::ensure!(
        len >= HEADER_LEN,
        "{}: not a somoclu checkpoint (shorter than the {HEADER_LEN}-byte header)",
        path.display()
    );
    let mut h = [0u8; HEADER_LEN as usize];
    f.read_exact(&mut h)?;
    anyhow::ensure!(
        h[0..4] == MAGIC,
        "{}: bad magic (not a somoclu checkpoint)",
        path.display()
    );
    let version = decode_u32(&h, 4);
    anyhow::ensure!(
        version == VERSION,
        "{}: unsupported checkpoint version {version} (this build reads {VERSION})",
        path.display()
    );
    anyhow::ensure!(
        decode_u32(&h, 8) == 0,
        "{}: nonzero reserved header field (corrupt header?)",
        path.display()
    );
    let kernel = match decode_u32(&h, 12) {
        0 => KernelType::DenseCpu,
        1 => KernelType::Accel,
        2 => KernelType::SparseCpu,
        3 => KernelType::Hybrid,
        other => anyhow::bail!("{}: unknown kernel code {other}", path.display()),
    };
    let grid_type = match decode_u32(&h, 16) {
        0 => GridType::Square,
        1 => GridType::Hexagonal,
        other => anyhow::bail!("{}: unknown grid type code {other}", path.display()),
    };
    let map_type = match decode_u32(&h, 20) {
        0 => MapType::Planar,
        1 => MapType::Toroid,
        other => anyhow::bail!("{}: unknown map type code {other}", path.display()),
    };
    let nb_kind = match decode_u32(&h, 24) {
        0 => NeighborhoodKind::Gaussian,
        1 => NeighborhoodKind::Bubble,
        other => anyhow::bail!("{}: unknown neighborhood code {other}", path.display()),
    };
    let compact = match decode_u32(&h, 28) {
        0 => false,
        1 => true,
        other => anyhow::bail!("{}: bad compact-support flag {other}", path.display()),
    };
    let cooling = |off: usize| -> anyhow::Result<Cooling> {
        Ok(match decode_u32(&h, off) {
            0 => Cooling::Linear,
            1 => Cooling::Exponential,
            other => anyhow::bail!("{}: unknown cooling code {other}", path.display()),
        })
    };
    let radius_cooling = cooling(32)?;
    let scale_cooling = cooling(36)?;
    let radius0 = match decode_u32(&h, 40) {
        0 => None,
        1 => Some(decode_f32(&h, 44)),
        other => anyhow::bail!("{}: bad radius0 flag {other}", path.display()),
    };
    let radius_n = decode_f32(&h, 48);
    let scale0 = decode_f32(&h, 52);
    let scale_n = decode_f32(&h, 56);
    let rows = usize::try_from(decode_u64(&h, 60))?;
    let cols = usize::try_from(decode_u64(&h, 68))?;
    let epochs = usize::try_from(decode_u64(&h, 76))?;
    let epoch = usize::try_from(decode_u64(&h, 84))?;
    let dim = usize::try_from(decode_u64(&h, 92))?;
    let seed = decode_u64(&h, 100);
    let want_sum = decode_u64(&h, 108);
    anyhow::ensure!(
        rows > 0 && cols > 0 && dim > 0,
        "{}: header declares an empty map or zero dims",
        path.display()
    );
    anyhow::ensure!(
        epochs > 0 && epoch <= epochs,
        "{}: epoch cursor {epoch} out of range (total {epochs})",
        path.display()
    );
    // Exact-length check in u128 so a crafted header cannot wrap the
    // payload product (same guard as the SOMB reader).
    let nodes = (rows as u128) * (cols as u128);
    let want_len = HEADER_LEN as u128 + 4 * nodes * dim as u128;
    anyhow::ensure!(
        len as u128 == want_len,
        "{}: file is {len} bytes but the header declares {want_len} \
         (truncated or corrupt copy)",
        path.display()
    );

    // Payload: decode through a fixed block, checksumming as we go.
    let count = rows * cols * dim;
    let mut weights = Vec::with_capacity(count);
    let mut sum = FNV_OFFSET;
    let mut block = [0u8; 8192];
    let mut left = count;
    while left > 0 {
        let take = left.min(block.len() / 4);
        f.read_exact(&mut block[..take * 4])?;
        sum = fnv1a(sum, &block[..take * 4]);
        for i in 0..take {
            weights.push(f32::from_le_bytes(block[i * 4..i * 4 + 4].try_into().unwrap()));
        }
        left -= take;
    }
    anyhow::ensure!(
        sum == want_sum,
        "{}: payload checksum mismatch (corrupt codebook weights)",
        path.display()
    );

    let neighborhood = match nb_kind {
        NeighborhoodKind::Gaussian => Neighborhood::gaussian(compact),
        NeighborhoodKind::Bubble => Neighborhood::bubble(),
    };
    let config = TrainConfig {
        rows,
        cols,
        epochs,
        grid_type,
        map_type,
        neighborhood,
        radius0,
        radius_n,
        radius_cooling,
        scale0,
        scale_n,
        scale_cooling,
        kernel,
        seed,
        ..TrainConfig::default()
    };
    config.validate().map_err(|e| {
        anyhow::anyhow!("{}: checkpoint config invalid: {e}", path.display())
    })?;
    Ok(Checkpoint {
        config,
        epoch,
        codebook: Codebook {
            nodes: rows * cols,
            dim,
            weights,
        },
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("somoclu_ckpt_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    fn sample() -> (TrainConfig, Codebook) {
        let cfg = TrainConfig {
            rows: 4,
            cols: 5,
            epochs: 9,
            radius0: Some(2.5),
            seed: 42,
            kernel: KernelType::SparseCpu,
            grid_type: GridType::Hexagonal,
            map_type: MapType::Toroid,
            neighborhood: Neighborhood::gaussian(true),
            radius_cooling: Cooling::Exponential,
            ..Default::default()
        };
        let mut rng = Rng::new(9);
        let cb = Codebook::random_init(20, 3, &mut rng);
        (cfg, cb)
    }

    #[test]
    fn round_trip_is_bit_exact() {
        let (cfg, cb) = sample();
        let path = tmp("roundtrip.somc");
        save(&path, &cfg, 4, &cb).unwrap();
        let ck = load(&path).unwrap();
        assert_eq!(ck.epoch, 4);
        assert_eq!(ck.codebook.nodes, 20);
        assert_eq!(ck.codebook.dim, 3);
        // Bit-identical weights, not approximately equal.
        let a: Vec<u32> = cb.weights.iter().map(|v| v.to_bits()).collect();
        let b: Vec<u32> = ck.codebook.weights.iter().map(|v| v.to_bits()).collect();
        assert_eq!(a, b);
        let c = &ck.config;
        assert_eq!((c.rows, c.cols, c.epochs), (4, 5, 9));
        assert_eq!(c.kernel, KernelType::SparseCpu);
        assert_eq!(c.grid_type, GridType::Hexagonal);
        assert_eq!(c.map_type, MapType::Toroid);
        assert_eq!(c.radius_cooling, Cooling::Exponential);
        assert_eq!(c.radius0, Some(2.5));
        assert_eq!(c.seed, 42);
        assert!(c.neighborhood.compact_support);
    }

    #[test]
    fn truncated_rejected() {
        let (cfg, cb) = sample();
        let path = tmp("trunc.somc");
        save(&path, &cfg, 2, &cb).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() - 5]).unwrap();
        let err = load(&path).unwrap_err();
        assert!(format!("{err:#}").contains("truncated"), "{err:#}");
    }

    #[test]
    fn version_mismatch_rejected() {
        let (cfg, cb) = sample();
        let path = tmp("version.somc");
        save(&path, &cfg, 2, &cb).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        bytes[4..8].copy_from_slice(&99u32.to_le_bytes());
        std::fs::write(&path, &bytes).unwrap();
        let err = load(&path).unwrap_err();
        assert!(format!("{err:#}").contains("version"), "{err:#}");
    }

    #[test]
    fn flipped_payload_bit_rejected() {
        let (cfg, cb) = sample();
        let path = tmp("bitrot.somc");
        save(&path, &cfg, 2, &cb).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        let off = HEADER_LEN as usize + 7;
        bytes[off] ^= 0x10;
        std::fs::write(&path, &bytes).unwrap();
        let err = load(&path).unwrap_err();
        assert!(format!("{err:#}").contains("checksum"), "{err:#}");
    }

    #[test]
    fn bad_magic_and_cursor_rejected() {
        let (cfg, cb) = sample();
        let path = tmp("magic.somc");
        save(&path, &cfg, 2, &cb).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        bytes[0] = b'X';
        std::fs::write(&path, &bytes).unwrap();
        assert!(load(&path).is_err());

        // Cursor beyond total epochs is refused at save time.
        assert!(save(tmp("cursor.somc"), &cfg, cfg.epochs + 1, &cb).is_err());
    }
}
