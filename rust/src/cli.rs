//! The somoclu command-line interface (paper §4.1), organized as
//! subcommands since v0.2:
//!
//! ```text
//! somoclu train [OPTIONS] INPUT_FILE OUTPUT_PREFIX    # batch training
//! somoclu ensemble [OPTIONS] INPUT_FILE OUTPUT_PREFIX # K-map SCE consensus clustering
//! somoclu quality [OPTIONS] CHECKPOINT DATA_FILE      # map-quality JSON report
//! somoclu serve [OPTIONS] LISTEN_ADDR                 # checkpoint-serving daemon
//! somoclu convert [OPTIONS] INPUT_FILE OUTPUT_FILE    # text -> binary container
//! somoclu info [OPTIONS] INPUT_FILE                   # container inspector
//! ```
//!
//! The historical flat form `somoclu [OPTIONS] INPUT OUTPUT_PREFIX`
//! still works as an alias for `train` (with a one-line deprecation
//! notice on stderr). Training flags carry the paper's short names
//! (`-e`, `-k`, ...), plus the simulated cluster (`--ranks` replaces
//! `mpirun -np`) and determinism (`--seed`).

use crate::cluster::fault::RecoveryPolicy;
use crate::cluster::multiproc::NetOptions;
use crate::cluster::netmodel::NetModel;
use crate::coordinator::config::TrainConfig;
use crate::io::output::SnapshotLevel;
use crate::kernels::KernelType;
use crate::util::argparse::{ArgError, ArgSpec, Parsed};

/// Argument spec for `somoclu train` (and the deprecated flat
/// invocation, which is the same grammar).
pub fn train_spec() -> ArgSpec {
    ArgSpec::new()
        .opt("codebook", Some('c'), Some("codebook"),
             "initial code book file (default: random init)", None)
        .opt("epochs", Some('e'), Some("epochs"),
             "number of training epochs", Some("10"))
        .opt("grid", Some('g'), Some("grid"),
             "grid type: square | hexagonal", Some("square"))
        .opt("kernel", Some('k'), Some("kernel"),
             "kernel: 0 dense CPU, 1 accel (XLA), 2 sparse CPU, 3 hybrid", Some("0"))
        .opt("map", Some('m'), Some("map"),
             "map type: planar | toroid", Some("planar"))
        .opt("neighborhood", Some('n'), Some("neighborhood"),
             "neighborhood function: gaussian | bubble", Some("gaussian"))
        .opt("compact", Some('p'), Some("compact"),
             "1 = cut updates beyond the current radius", Some("0"))
        .opt("radius-cooling", Some('t'), Some("radius-cooling"),
             "radius cooling: linear | exponential", Some("linear"))
        .opt("radius0", Some('r'), Some("radius0"),
             "start radius (default: half of smaller map side)", None)
        .opt("radiusN", Some('R'), Some("radiusN"),
             "final radius", Some("1"))
        .opt("scale-cooling", Some('T'), Some("scale-cooling"),
             "learning-rate cooling: linear | exponential", Some("linear"))
        .opt("scale0", Some('l'), Some("scale0"),
             "starting learning rate", Some("1.0"))
        .opt("scaleN", Some('L'), Some("scaleN"),
             "final learning rate", Some("0.01"))
        .opt("snapshots", Some('s'), Some("snapshots"),
             "interim files: 0 none, 1 U-matrix, 2 +codebook/BMUs", Some("0"))
        .opt("columns", Some('x'), Some("columns"),
             "number of map columns", Some("50"))
        .opt("rows", Some('y'), Some("rows"),
             "number of map rows", Some("50"))
        .opt("ranks", None, Some("ranks"),
             "simulated cluster ranks (replaces `mpirun -np N`)", Some("1"))
        .opt("threads", None, Some("threads"),
             "worker threads per rank (default: all cores)", None)
        .opt("initialization", None, Some("initialization"),
             "codebook init: random | pca", Some("random"))
        .opt("seed", None, Some("seed"),
             "RNG seed for codebook init", Some("1347440723"))
        .opt("chunk-rows", None, Some("chunk-rows"),
             "stream the input in windows of N rows (out-of-core; 0 = \
              load fully in memory)", Some("0"))
        .opt("net", None, Some("net"),
             "cluster interconnect model: ideal | 10g", Some("ideal"))
        .opt("collective", None, Some("collective"),
             "cluster collective algorithm: auto (size-based ring/tree) | \
              star (the paper's master/slave pattern) | ring | tree",
             Some("auto"))
        .opt("rank", None, Some("rank"),
             "this process's rank in a real multi-process run (needs \
              --ranks N and --peers; rank 0 writes the outputs)", None)
        .opt("peers", None, Some("peers"),
             "comma-separated rendezvous addresses, one per rank in rank \
              order (host:port or unix:PATH; the last rank's may be \
              omitted)", None)
        .opt("listen", None, Some("listen"),
             "two-process shorthand: run as rank 0 of 2, listening on \
              ADDR for the peer started with --connect ADDR", None)
        .opt("connect", None, Some("connect"),
             "two-process shorthand: run as rank 1 of 2, dialing the \
              process started with --listen ADDR", None)
        .opt("io", None, Some("io"),
             "binary-container I/O backend: buffered | mmap (zero-copy) \
              | pread (one shared fd for all ranks)", Some("buffered"))
        .opt("resume", None, Some("resume"),
             "resume training from a SOMC checkpoint (map/schedule/kernel \
              flags come from the checkpoint; runtime flags still apply)",
             None)
        .opt("checkpoint-every", None, Some("checkpoint-every"),
             "write OUTPUT_PREFIX.epoch<k>.somc every N completed epochs \
              (0 = off)", Some("0"))
        .opt("keep-last", None, Some("keep-last"),
             "retain only the newest N cadence checkpoints, deleting \
              older ones as training progresses (0 = keep all)", Some("0"))
        .opt("recover", None, Some("recover"),
             "automatic rank-failure recovery for cluster runs: \
              max-restarts=N[,backoff-ms=M] retries a failed checkpoint \
              window up to N times with exponential backoff (default: \
              off — the first lost rank fails the run)", None)
        .flag("prefetch", None, Some("prefetch"),
              "double-buffered chunk read-ahead for file-backed streaming")
        .flag("help", Some('h'), Some("help"), "print usage")
        .flag("verbose", Some('v'), Some("verbose"), "per-epoch progress")
        .positional("INPUT_FILE", "dense or sparse (libsvm) training data")
        .positional("OUTPUT_PREFIX", "prefix for .wts/.bm/.umx outputs")
}

/// Argument spec for the `somoclu convert` subcommand: transcode a text
/// input (ESOM dense or libsvm sparse) into the binary container
/// (`io::binary`) once, so training epochs stream it with zero parsing.
pub fn convert_spec() -> ArgSpec {
    ArgSpec::new()
        .flag("sparse", Some('s'), Some("sparse"),
              "input is libsvm sparse (default: dense text)")
        .opt("min-cols", None, Some("min-cols"),
             "force at least this many columns (sparse inputs)", Some("0"))
        .opt("chunk-rows", None, Some("chunk-rows"),
             "transcode window in rows (memory bound of the conversion)",
             Some("4096"))
        .flag("help", Some('h'), Some("help"), "print usage")
        .positional("INPUT_FILE", "dense or sparse (libsvm) text data")
        .positional("OUTPUT_FILE", "binary container to write (.somb)")
}

/// Argument spec for the `somoclu info` subcommand: decode and print a
/// `SOMB` container header plus, with `--ranks N`, every rank's shard
/// window — the debugging view that previously required a hex dump.
/// Exits nonzero on corrupt or truncated headers.
pub fn info_spec() -> ArgSpec {
    ArgSpec::new()
        .opt("ranks", None, Some("ranks"),
             "also print each rank's row/byte shard window", Some("1"))
        .flag("help", Some('h'), Some("help"), "print usage")
        .positional("INPUT_FILE", "binary container to inspect (.somb)")
}

/// Parsed `somoclu info` options.
#[derive(Debug, Clone)]
pub struct InfoOptions {
    pub input_file: String,
    pub ranks: usize,
}

pub fn parse_info(parsed: &Parsed) -> Result<InfoOptions, ArgError> {
    Ok(InfoOptions {
        input_file: parsed.positional(0).to_string(),
        ranks: parsed.parse_as::<usize>("ranks")?,
    })
}

/// Parsed `somoclu convert` options.
#[derive(Debug, Clone)]
pub struct ConvertOptions {
    pub input_file: String,
    pub output_file: String,
    pub sparse: bool,
    pub min_cols: usize,
    pub chunk_rows: usize,
}

pub fn parse_convert(parsed: &Parsed) -> Result<ConvertOptions, ArgError> {
    Ok(ConvertOptions {
        input_file: parsed.positional(0).to_string(),
        output_file: parsed.positional(1).to_string(),
        sparse: parsed.flag("sparse"),
        min_cols: parsed.parse_as::<usize>("min-cols")?,
        chunk_rows: parsed.parse_as::<usize>("chunk-rows")?,
    })
}

/// Argument spec for the `somoclu serve` subcommand: the
/// checkpoint-serving daemon (`crate::serve`).
pub fn serve_spec() -> ArgSpec {
    ArgSpec::new()
        .opt("checkpoint", Some('c'), Some("checkpoint"),
             "SOMC checkpoint to serve from the start (default: start \
              empty and wait for a submitted job to publish a map)", None)
        .opt("state-dir", None, Some("state-dir"),
             "directory for the job-queue journal and job checkpoints",
             Some("somoclu-serve"))
        .opt("threads", None, Some("threads"),
             "worker threads for training jobs and quality requests \
              (default: all cores)", None)
        .opt("job-retries", None, Some("job-retries"),
             "re-queue a training job that fails with a transient error \
              (comm/io/recovery) up to N times, resuming from its newest \
              checkpoint (0 = fail the job on first error)", Some("0"))
        .flag("help", Some('h'), Some("help"), "print usage")
        .flag("verbose", Some('v'), Some("verbose"),
              "log connections and publishes to stderr")
        .positional("LISTEN_ADDR", "host:port (port 0 = any free port) or unix:PATH")
}

/// Parsed `somoclu serve` options (the CLI-facing subset of
/// `crate::serve::ServeOptions`).
#[derive(Debug, Clone)]
pub struct ServeCliOptions {
    pub addr: String,
    pub checkpoint: Option<String>,
    pub state_dir: String,
    pub threads: usize,
    /// `--job-retries N`: transient-failure retry budget per training job.
    pub job_retries: usize,
    pub verbose: bool,
}

pub fn parse_serve(parsed: &Parsed) -> Result<ServeCliOptions, ArgError> {
    let threads = match parsed.get("threads") {
        Some(t) => t
            .parse::<usize>()
            .map_err(|e| bad("threads", t, e.to_string()))?,
        None => 0,
    };
    Ok(ServeCliOptions {
        addr: parsed.positional(0).to_string(),
        checkpoint: parsed.get("checkpoint").map(str::to_string),
        state_dir: parsed.get("state-dir").unwrap().to_string(),
        threads,
        job_retries: parsed.parse_as::<usize>("job-retries")?,
        verbose: parsed.flag("verbose"),
    })
}

/// Argument spec for the `somoclu ensemble` subcommand: train K
/// independently-seeded maps, cluster each codebook, and combine the
/// labelings into one consensus (`crate::ensemble`). The training
/// knobs mirror `somoclu train` where they apply; `-k` means *members*
/// here (ensemble size), not kernel — the ensemble always trains on
/// the dense CPU path.
pub fn ensemble_spec() -> ArgSpec {
    ArgSpec::new()
        .opt("members", Some('k'), Some("members"),
             "ensemble members (independently-seeded maps) to train", Some("5"))
        .opt("clusters", Some('c'), Some("clusters"),
             "k-means clusters to cut each member's codebook into", Some("8"))
        .opt("epochs", Some('e'), Some("epochs"),
             "training epochs per member", Some("10"))
        .opt("grid", Some('g'), Some("grid"),
             "grid type: square | hexagonal", Some("square"))
        .opt("map", Some('m'), Some("map"),
             "map type: planar | toroid", Some("planar"))
        .opt("columns", Some('x'), Some("columns"),
             "number of map columns", Some("50"))
        .opt("rows", Some('y'), Some("rows"),
             "number of map rows", Some("50"))
        .opt("radius0", Some('r'), Some("radius0"),
             "start radius (default: half of smaller map side)", None)
        .opt("seed", None, Some("seed"),
             "base seed; member i trains with a seed derived from it",
             Some("1347440723"))
        .opt("kmeans-iters", None, Some("kmeans-iters"),
             "Lloyd iteration cap for the per-member k-means", Some("100"))
        .opt("threads", None, Some("threads"),
             "total worker threads, split across members (0 = one per \
              member)", Some("0"))
        .opt("checkpoint-every", None, Some("checkpoint-every"),
             "write OUTPUT_PREFIX.m<i>.epoch<k>.somc every N epochs per \
              member and resume members from existing checkpoints (0 = \
              off)", Some("0"))
        .flag("help", Some('h'), Some("help"), "print usage")
        .flag("verbose", Some('v'), Some("verbose"), "per-member summary lines")
        .positional("INPUT_FILE", "dense training data (text)")
        .positional("OUTPUT_PREFIX",
                    "prefix for .m<i>.bm / .consensus.lbl / .ensemble.json")
}

/// Parsed `somoclu ensemble` options.
#[derive(Debug, Clone)]
pub struct EnsembleCliOptions {
    pub input_file: String,
    pub output_prefix: String,
    pub members: usize,
    pub clusters: usize,
    pub kmeans_iters: usize,
    pub checkpoint_every: usize,
    pub config: TrainConfig,
    pub verbose: bool,
}

pub fn parse_ensemble(parsed: &Parsed) -> Result<EnsembleCliOptions, ArgError> {
    let mut cfg = TrainConfig {
        epochs: parsed.parse_as::<usize>("epochs")?,
        rows: parsed.parse_as::<usize>("rows")?,
        cols: parsed.parse_as::<usize>("columns")?,
        seed: parsed.parse_as::<u64>("seed")?,
        threads: parsed.parse_as::<usize>("threads")?,
        ..Default::default()
    };
    let gv = parsed.get("grid").unwrap();
    cfg.grid_type = gv.parse().map_err(|e| bad("grid", gv, e))?;
    let mv = parsed.get("map").unwrap();
    cfg.map_type = mv.parse().map_err(|e| bad("map", mv, e))?;
    if let Some(r0) = parsed.get("radius0") {
        cfg.radius0 =
            Some(r0.parse::<f32>().map_err(|e| bad("radius0", r0, e.to_string()))?);
    }
    let members = parsed.parse_as::<usize>("members")?;
    if members == 0 {
        return Err(bad("members", "0", "the ensemble needs at least 1 member".into()));
    }
    let clusters = parsed.parse_as::<usize>("clusters")?;
    if clusters == 0 {
        return Err(bad("clusters", "0", "need at least 1 cluster".into()));
    }
    Ok(EnsembleCliOptions {
        input_file: parsed.positional(0).to_string(),
        output_prefix: parsed.positional(1).to_string(),
        members,
        clusters,
        kmeans_iters: parsed.parse_as::<usize>("kmeans-iters")?,
        checkpoint_every: parsed.parse_as::<usize>("checkpoint-every")?,
        config: cfg,
        verbose: parsed.flag("verbose"),
    })
}

/// Argument spec for the `somoclu quality` subcommand: load a SOMC
/// checkpoint, project a data set through it, and emit the versioned
/// quality JSON ([`crate::som::quality::QualityReport`]).
pub fn quality_spec() -> ArgSpec {
    ArgSpec::new()
        .opt("knn", Some('k'), Some("knn"),
             "neighborhood size for trustworthiness / neighborhood \
              preservation", Some("10"))
        .opt("threads", None, Some("threads"),
             "worker threads (0 = all cores)", Some("0"))
        .opt("out", Some('o'), Some("out"),
             "write the JSON report here instead of stdout", None)
        .flag("planes", None, Some("planes"),
              "include full per-node component-plane values (large)")
        .flag("help", Some('h'), Some("help"), "print usage")
        .positional("CHECKPOINT", "trained map to evaluate (.somc)")
        .positional("DATA_FILE", "dense evaluation data (text)")
}

/// Parsed `somoclu quality` options.
#[derive(Debug, Clone)]
pub struct QualityCliOptions {
    pub checkpoint: String,
    pub data_file: String,
    pub knn: usize,
    pub threads: usize,
    pub planes: bool,
    pub out: Option<String>,
}

pub fn parse_quality(parsed: &Parsed) -> Result<QualityCliOptions, ArgError> {
    let knn = parsed.parse_as::<usize>("knn")?;
    if knn == 0 {
        return Err(bad("knn", "0", "the neighborhood size must be at least 1".into()));
    }
    Ok(QualityCliOptions {
        checkpoint: parsed.positional(0).to_string(),
        data_file: parsed.positional(1).to_string(),
        knn,
        threads: parsed.parse_as::<usize>("threads")?,
        planes: parsed.flag("planes"),
        out: parsed.get("out").map(str::to_string),
    })
}

/// Everything main() needs beyond TrainConfig.
#[derive(Debug, Clone)]
pub struct CliOptions {
    pub config: TrainConfig,
    pub input_file: String,
    pub output_prefix: String,
    pub initial_codebook: Option<String>,
    /// `--resume`: a SOMC checkpoint to continue from (the checkpoint's
    /// map/schedule/kernel settings override the corresponding flags).
    pub resume: Option<String>,
    /// `--checkpoint-every N`: save `OUTPUT_PREFIX.epoch<k>.somc` after
    /// every N completed epochs (0 = off).
    pub checkpoint_every: usize,
    /// `--keep-last N`: retain only the newest N cadence checkpoints
    /// (0 = keep all). Applied via
    /// [`crate::session::SomSession::set_checkpoint_keep_last`].
    pub keep_last: usize,
    pub net: NetModel,
    /// `--rank`/`--peers` (or the `--listen`/`--connect` shorthand):
    /// this process is one rank of a real multi-process run.
    pub multiproc: Option<NetOptions>,
    /// `--recover max-restarts=N[,backoff-ms=M]`: retry a cluster
    /// window aborted by a lost rank instead of failing the run.
    pub recovery: RecoveryPolicy,
    pub verbose: bool,
}

fn bad(opt: &str, val: &str, why: String) -> ArgError {
    ArgError::BadValue {
        opt: opt.into(),
        val: val.into(),
        why,
    }
}

/// Parse `--recover max-restarts=N[,backoff-ms=M]` into a
/// [`RecoveryPolicy`]. Key order is free; unknown keys are rejected so a
/// typo does not silently run without recovery.
fn parse_recover(val: &str) -> Result<RecoveryPolicy, ArgError> {
    let mut restarts: Option<usize> = None;
    let mut backoff_ms: Option<u64> = None;
    for part in val.split(',') {
        let (key, v) = part.split_once('=').ok_or_else(|| {
            bad("recover", val, format!("`{part}` is not key=value"))
        })?;
        match key.trim() {
            "max-restarts" => {
                restarts = Some(v.trim().parse::<usize>().map_err(|e| {
                    bad("recover", val, format!("max-restarts: {e}"))
                })?);
            }
            "backoff-ms" => {
                backoff_ms = Some(v.trim().parse::<u64>().map_err(|e| {
                    bad("recover", val, format!("backoff-ms: {e}"))
                })?);
            }
            other => {
                return Err(bad(
                    "recover",
                    val,
                    format!("unknown key `{other}`; want max-restarts=N[,backoff-ms=M]"),
                ));
            }
        }
    }
    let restarts = restarts.ok_or_else(|| {
        bad("recover", val, "max-restarts=N is required".into())
    })?;
    let mut policy = RecoveryPolicy::restarts(restarts);
    if let Some(ms) = backoff_ms {
        policy = policy.with_backoff(std::time::Duration::from_millis(ms));
    }
    Ok(policy)
}

pub fn parse_cli(parsed: &Parsed) -> Result<CliOptions, ArgError> {
    let mut cfg = TrainConfig {
        epochs: parsed.parse_as::<usize>("epochs")?,
        rows: parsed.parse_as::<usize>("rows")?,
        cols: parsed.parse_as::<usize>("columns")?,
        radius_n: parsed.parse_as::<f32>("radiusN")?,
        scale0: parsed.parse_as::<f32>("scale0")?,
        scale_n: parsed.parse_as::<f32>("scaleN")?,
        ranks: parsed.parse_as::<usize>("ranks")?,
        seed: parsed.parse_as::<u64>("seed")?,
        chunk_rows: parsed.parse_as::<usize>("chunk-rows")?,
        prefetch: parsed.flag("prefetch"),
        ..Default::default()
    };

    let gv = parsed.get("grid").unwrap();
    cfg.grid_type = gv.parse().map_err(|e| bad("grid", gv, e))?;
    let mv = parsed.get("map").unwrap();
    cfg.map_type = mv.parse().map_err(|e| bad("map", mv, e))?;
    let kv = parsed.get("kernel").unwrap();
    cfg.kernel = kv.parse().map_err(|e| bad("kernel", kv, e))?;
    let tv = parsed.get("radius-cooling").unwrap();
    cfg.radius_cooling = tv.parse().map_err(|e| bad("radius-cooling", tv, e))?;
    let sv = parsed.get("scale-cooling").unwrap();
    cfg.scale_cooling = sv.parse().map_err(|e| bad("scale-cooling", sv, e))?;
    let nv = parsed.get("neighborhood").unwrap();
    let kind: crate::som::NeighborhoodKind =
        nv.parse().map_err(|e| bad("neighborhood", nv, e))?;
    let compact = parsed.parse_as::<u8>("compact")? != 0;
    cfg.neighborhood = match kind {
        crate::som::NeighborhoodKind::Gaussian => {
            crate::som::Neighborhood::gaussian(compact)
        }
        crate::som::NeighborhoodKind::Bubble => crate::som::Neighborhood::bubble(),
    };
    if let Some(r0) = parsed.get("radius0") {
        cfg.radius0 =
            Some(r0.parse::<f32>().map_err(|e| bad("radius0", r0, e.to_string()))?);
    }
    if let Some(t) = parsed.get("threads") {
        cfg.threads = t
            .parse::<usize>()
            .map_err(|e| bad("threads", t, e.to_string()))?;
    }
    let iv = parsed.get("initialization").unwrap();
    cfg.initialization = iv.parse().map_err(|e| bad("initialization", iv, e))?;
    let snap = parsed.get("snapshots").unwrap();
    cfg.snapshot = snap
        .parse::<SnapshotLevel>()
        .map_err(|e| bad("snapshots", snap, e))?;

    let iov = parsed.get("io").unwrap();
    cfg.io_mode = iov.parse().map_err(|e| bad("io", iov, e))?;

    let netv = parsed.get("net").unwrap();
    let net = match netv {
        "ideal" => NetModel::ideal(),
        "10g" => NetModel::ethernet_10g(),
        other => return Err(bad("net", other, "want ideal | 10g".into())),
    };

    let cv = parsed.get("collective").unwrap();
    cfg.collective = cv.parse().map_err(|e| bad("collective", cv, e))?;

    let multiproc = parse_multiproc(parsed, &mut cfg)?;
    if multiproc.is_some() && netv != "ideal" {
        return Err(bad(
            "net",
            netv,
            "the interconnect model shapes the simulated cluster; a real \
             multi-process run uses the real network"
                .into(),
        ));
    }

    if matches!(cfg.kernel, KernelType::Accel | KernelType::Hybrid) && cfg.ranks > 1 {
        return Err(bad(
            "ranks",
            &cfg.ranks.to_string(),
            "accel kernel is single-node only (Fig. 8 uses the CPU kernel)".into(),
        ));
    }

    let resume = parsed.get("resume").map(str::to_string);
    if resume.is_some() && parsed.get("codebook").is_some() {
        return Err(bad(
            "resume",
            "-c",
            "--resume restores the codebook from the checkpoint; drop -c".into(),
        ));
    }

    Ok(CliOptions {
        config: cfg,
        input_file: parsed.positional(0).to_string(),
        output_prefix: parsed.positional(1).to_string(),
        initial_codebook: parsed.get("codebook").map(str::to_string),
        resume,
        checkpoint_every: parsed.parse_as::<usize>("checkpoint-every")?,
        keep_last: parsed.parse_as::<usize>("keep-last")?,
        net,
        multiproc,
        recovery: match parsed.get("recover") {
            Some(v) => parse_recover(v)?,
            None => RecoveryPolicy::none(),
        },
        verbose: parsed.flag("verbose"),
    })
}

/// Resolve `--listen`/`--connect`/`--rank`/`--peers` into [`NetOptions`]
/// (adjusting `cfg.ranks` for the two-process shorthand), or `None` for
/// single-process and simulated-cluster runs.
fn parse_multiproc(
    parsed: &Parsed,
    cfg: &mut TrainConfig,
) -> Result<Option<NetOptions>, ArgError> {
    let listen = parsed.get("listen");
    let connect = parsed.get("connect");
    let rank = parsed.get("rank");
    let peers = parsed.get("peers");

    if let Some(addr) = listen.or(connect) {
        if listen.is_some() && connect.is_some() {
            return Err(bad(
                "connect",
                connect.unwrap(),
                "a process either listens (rank 0) or connects (rank 1), \
                 not both"
                    .into(),
            ));
        }
        if rank.is_some() || peers.is_some() {
            return Err(bad(
                "listen",
                addr,
                "--listen/--connect is the two-process shorthand; spell \
                 bigger runs with --ranks N --rank K --peers ..."
                    .into(),
            ));
        }
        match cfg.ranks {
            1 => cfg.ranks = 2, // the flag's default; the shorthand implies 2
            2 => {}
            n => {
                return Err(bad(
                    "ranks",
                    &n.to_string(),
                    "--listen/--connect runs exactly 2 processes; use \
                     --rank/--peers for more ranks"
                        .into(),
                ))
            }
        }
        return Ok(Some(NetOptions {
            rank: usize::from(connect.is_some()),
            peers: vec![addr.to_string()],
        }));
    }

    match (rank, peers) {
        (None, None) => Ok(None),
        (Some(r), None) => Err(bad(
            "rank",
            r,
            "--rank needs --peers (the rendezvous addresses)".into(),
        )),
        (None, Some(p)) => Err(bad(
            "peers",
            p,
            "--peers needs --rank (which of these addresses is this \
             process)"
                .into(),
        )),
        (Some(r), Some(p)) => {
            let rank = r
                .parse::<usize>()
                .map_err(|e| bad("rank", r, e.to_string()))?;
            if cfg.ranks < 2 {
                return Err(bad(
                    "ranks",
                    &cfg.ranks.to_string(),
                    "a real multi-process run needs --ranks >= 2".into(),
                ));
            }
            if rank >= cfg.ranks {
                return Err(bad(
                    "rank",
                    r,
                    format!("rank out of range for --ranks {}", cfg.ranks),
                ));
            }
            let peers: Vec<String> = p
                .split(',')
                .map(|s| s.trim().to_string())
                .filter(|s| !s.is_empty())
                .collect();
            if peers.len() != cfg.ranks && peers.len() + 1 != cfg.ranks {
                return Err(bad(
                    "peers",
                    p,
                    format!(
                        "lists {} addresses for {} ranks (one per rank in \
                         rank order; the last rank's may be omitted)",
                        peers.len(),
                        cfg.ranks
                    ),
                ));
            }
            Ok(Some(NetOptions { rank, peers }))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::som::{Cooling, GridType, MapType, NeighborhoodKind};
    use std::time::Duration;

    fn parse(args: &[&str]) -> CliOptions {
        let spec = train_spec();
        let parsed = spec.parse(args.iter().map(|s| s.to_string())).unwrap();
        parse_cli(&parsed).unwrap()
    }

    #[test]
    fn paper_example_invocation() {
        // "$ Somoclu data/rgbs.txt data/rgbs" (all defaults)
        let o = parse(&["data/rgbs.txt", "data/rgbs"]);
        assert_eq!(o.config.rows, 50);
        assert_eq!(o.config.cols, 50);
        assert_eq!(o.config.epochs, 10);
        assert_eq!(o.config.kernel, KernelType::DenseCpu);
        assert_eq!(o.input_file, "data/rgbs.txt");
        assert_eq!(o.output_prefix, "data/rgbs");
    }

    #[test]
    fn paper_example_with_flags() {
        // "mpirun -np 4 ... Somoclu -k 0 --rows 20 --columns 20 in out"
        let o = parse(&[
            "--ranks", "4", "-k", "0", "--rows", "20", "--columns", "20",
            "in.txt", "out",
        ]);
        assert_eq!(o.config.ranks, 4);
        assert_eq!((o.config.rows, o.config.cols), (20, 20));
    }

    #[test]
    fn all_knobs() {
        let o = parse(&[
            "-e", "25", "-g", "hexagonal", "-m", "toroid", "-n", "bubble",
            "-p", "1", "-t", "exponential", "-r", "12", "-R", "2",
            "-T", "exponential", "-l", "0.5", "-L", "0.05", "-s", "2",
            "-k", "2", "--threads", "3", "--seed", "99", "in", "out",
        ]);
        let c = &o.config;
        assert_eq!(c.epochs, 25);
        assert_eq!(c.grid_type, GridType::Hexagonal);
        assert_eq!(c.map_type, MapType::Toroid);
        assert_eq!(c.neighborhood.kind, NeighborhoodKind::Bubble);
        assert_eq!(c.radius_cooling, Cooling::Exponential);
        assert_eq!(c.radius0, Some(12.0));
        assert_eq!(c.radius_n, 2.0);
        assert_eq!(c.scale_cooling, Cooling::Exponential);
        assert_eq!(c.scale0, 0.5);
        assert_eq!(c.scale_n, 0.05);
        assert_eq!(c.snapshot, SnapshotLevel::Full);
        assert_eq!(c.kernel, KernelType::SparseCpu);
        assert_eq!(c.threads, 3);
        assert_eq!(c.seed, 99);
    }

    #[test]
    fn chunk_rows_flag() {
        let o = parse(&["in", "out"]);
        assert_eq!(o.config.chunk_rows, 0); // default: fully in memory
        let o = parse(&["--chunk-rows", "4096", "in", "out"]);
        assert_eq!(o.config.chunk_rows, 4096);
    }

    #[test]
    fn prefetch_flag() {
        let o = parse(&["in", "out"]);
        assert!(!o.config.prefetch);
        let o = parse(&["--chunk-rows", "512", "--prefetch", "in", "out"]);
        assert!(o.config.prefetch);
    }

    #[test]
    fn io_flag() {
        use crate::coordinator::config::IoMode;
        let o = parse(&["in", "out"]);
        assert_eq!(o.config.io_mode, IoMode::Buffered);
        let o = parse(&["--io", "mmap", "in", "out"]);
        assert_eq!(o.config.io_mode, IoMode::Mmap);
        let o = parse(&["--io", "pread", "--ranks", "4", "in", "out"]);
        assert_eq!(o.config.io_mode, IoMode::Pread);
        let spec = train_spec();
        let parsed = spec
            .parse(["--io", "directio", "in", "out"].map(String::from))
            .unwrap();
        assert!(parse_cli(&parsed).is_err());
    }

    #[test]
    fn info_subcommand_spec() {
        let spec = info_spec();
        let parsed = spec
            .parse(["--ranks", "4", "data.somb"].map(String::from))
            .unwrap();
        let o = parse_info(&parsed).unwrap();
        assert_eq!(o.ranks, 4);
        assert_eq!(o.input_file, "data.somb");
        let parsed = spec.parse(["data.somb"].map(String::from)).unwrap();
        assert_eq!(parse_info(&parsed).unwrap().ranks, 1);
    }

    #[test]
    fn convert_subcommand_spec() {
        let spec = convert_spec();
        let parsed = spec
            .parse(["--sparse", "--min-cols", "40", "in.svm", "out.somb"].map(String::from))
            .unwrap();
        let o = parse_convert(&parsed).unwrap();
        assert!(o.sparse);
        assert_eq!(o.min_cols, 40);
        assert_eq!(o.chunk_rows, 4096); // default transcode window
        assert_eq!(o.input_file, "in.svm");
        assert_eq!(o.output_file, "out.somb");
        let parsed = spec.parse(["a.txt", "b.somb"].map(String::from)).unwrap();
        let o = parse_convert(&parsed).unwrap();
        assert!(!o.sparse);
    }

    #[test]
    fn resume_and_checkpoint_flags() {
        let o = parse(&["in", "out"]);
        assert!(o.resume.is_none());
        assert_eq!(o.checkpoint_every, 0); // default: no checkpoints
        let o = parse(&[
            "--checkpoint-every", "3", "--resume", "ck.somc", "in", "out",
        ]);
        assert_eq!(o.resume.as_deref(), Some("ck.somc"));
        assert_eq!(o.checkpoint_every, 3);
        // --resume restores the codebook; combining it with -c is a
        // contradiction and must be rejected.
        let spec = train_spec();
        let parsed = spec
            .parse(["--resume", "a.somc", "-c", "cb.wts", "in", "out"].map(String::from))
            .unwrap();
        assert!(parse_cli(&parsed).is_err());
    }

    #[test]
    fn keep_last_flag() {
        let o = parse(&["in", "out"]);
        assert_eq!(o.keep_last, 0); // default: keep every checkpoint
        let o = parse(&[
            "--checkpoint-every", "2", "--keep-last", "3", "in", "out",
        ]);
        assert_eq!(o.checkpoint_every, 2);
        assert_eq!(o.keep_last, 3);
    }

    #[test]
    fn recover_flag() {
        let o = parse(&["in", "out"]);
        assert_eq!(o.recovery.max_restarts, 0); // default: fail fast

        let o = parse(&["--recover", "max-restarts=4", "in", "out"]);
        assert_eq!(o.recovery.max_restarts, 4);
        assert_eq!(o.recovery.backoff, Duration::from_millis(500));

        let o = parse(&[
            "--recover", "backoff-ms=50,max-restarts=2", "in", "out",
        ]);
        assert_eq!(o.recovery.max_restarts, 2);
        assert_eq!(o.recovery.backoff, Duration::from_millis(50));
    }

    #[test]
    fn bad_recover_values_rejected() {
        let spec = train_spec();
        for val in [
            "3",                    // bare number: ambiguous, want key=value
            "max-restarts=many",    // non-numeric
            "backoff-ms=50",        // missing the required max-restarts
            "max-restart=3",        // typo'd key must not silently disable
            "max-restarts=2,,",     // empty segment
        ] {
            let parsed = spec
                .parse(["--recover", val, "in", "out"].map(String::from))
                .unwrap();
            assert!(parse_cli(&parsed).is_err(), "accepted --recover {val}");
        }
    }

    #[test]
    fn serve_subcommand_spec() {
        let spec = serve_spec();
        let parsed = spec
            .parse(
                ["-c", "map.somc", "--state-dir", "st", "--threads", "2",
                 "--job-retries", "3", "-v", "127.0.0.1:9009"]
                    .map(String::from),
            )
            .unwrap();
        let o = parse_serve(&parsed).unwrap();
        assert_eq!(o.addr, "127.0.0.1:9009");
        assert_eq!(o.checkpoint.as_deref(), Some("map.somc"));
        assert_eq!(o.state_dir, "st");
        assert_eq!(o.threads, 2);
        assert_eq!(o.job_retries, 3);
        assert!(o.verbose);
        // Defaults: no checkpoint, auto threads, bundled state dir,
        // jobs fail on first error.
        let parsed = spec.parse(["unix:/tmp/s.sock"].map(String::from)).unwrap();
        let o = parse_serve(&parsed).unwrap();
        assert_eq!(o.addr, "unix:/tmp/s.sock");
        assert!(o.checkpoint.is_none());
        assert_eq!(o.state_dir, "somoclu-serve");
        assert_eq!(o.threads, 0);
        assert_eq!(o.job_retries, 0);
        assert!(!o.verbose);
    }

    #[test]
    fn ensemble_subcommand_spec() {
        let spec = ensemble_spec();
        let parsed = spec
            .parse(
                ["-k", "8", "-c", "4", "-e", "7", "-g", "hexagonal",
                 "-m", "toroid", "-x", "12", "-y", "9", "-r", "5",
                 "--seed", "42", "--kmeans-iters", "30", "--threads", "6",
                 "--checkpoint-every", "2", "-v", "in.txt", "out"]
                    .map(String::from),
            )
            .unwrap();
        let o = parse_ensemble(&parsed).unwrap();
        assert_eq!(o.members, 8);
        assert_eq!(o.clusters, 4);
        assert_eq!(o.config.epochs, 7);
        assert_eq!(o.config.grid_type, GridType::Hexagonal);
        assert_eq!(o.config.map_type, MapType::Toroid);
        assert_eq!((o.config.rows, o.config.cols), (9, 12));
        assert_eq!(o.config.radius0, Some(5.0));
        assert_eq!(o.config.seed, 42);
        assert_eq!(o.kmeans_iters, 30);
        assert_eq!(o.config.threads, 6);
        assert_eq!(o.checkpoint_every, 2);
        assert!(o.verbose);
        assert_eq!(o.input_file, "in.txt");
        assert_eq!(o.output_prefix, "out");
        // Defaults.
        let parsed = spec.parse(["a.txt", "b"].map(String::from)).unwrap();
        let o = parse_ensemble(&parsed).unwrap();
        assert_eq!(o.members, 5);
        assert_eq!(o.clusters, 8);
        assert_eq!(o.config.epochs, 10);
        assert_eq!(o.config.threads, 0);
        assert_eq!(o.checkpoint_every, 0);
        assert!(!o.verbose);
        // Degenerate counts are rejected at parse time.
        let parsed = spec.parse(["-k", "0", "a", "b"].map(String::from)).unwrap();
        assert!(parse_ensemble(&parsed).is_err());
        let parsed = spec.parse(["-c", "0", "a", "b"].map(String::from)).unwrap();
        assert!(parse_ensemble(&parsed).is_err());
    }

    #[test]
    fn quality_subcommand_spec() {
        let spec = quality_spec();
        let parsed = spec
            .parse(
                ["-k", "25", "--threads", "4", "--planes", "-o", "rep.json",
                 "map.somc", "data.txt"]
                    .map(String::from),
            )
            .unwrap();
        let o = parse_quality(&parsed).unwrap();
        assert_eq!(o.knn, 25);
        assert_eq!(o.threads, 4);
        assert!(o.planes);
        assert_eq!(o.out.as_deref(), Some("rep.json"));
        assert_eq!(o.checkpoint, "map.somc");
        assert_eq!(o.data_file, "data.txt");
        // Defaults: knn 10, auto threads, stdout, no plane export.
        let parsed = spec.parse(["m.somc", "d.txt"].map(String::from)).unwrap();
        let o = parse_quality(&parsed).unwrap();
        assert_eq!(o.knn, 10);
        assert_eq!(o.threads, 0);
        assert!(!o.planes);
        assert!(o.out.is_none());
        // knn 0 makes no sense.
        let parsed = spec.parse(["-k", "0", "m", "d"].map(String::from)).unwrap();
        assert!(parse_quality(&parsed).is_err());
    }

    #[test]
    fn initialization_flag() {
        let o = parse(&["--initialization", "pca", "in", "out"]);
        assert_eq!(
            o.config.initialization,
            crate::coordinator::config::Initialization::Pca
        );
        let spec = train_spec();
        let parsed = spec
            .parse(["--initialization", "magic", "in", "out"].map(String::from))
            .unwrap();
        assert!(parse_cli(&parsed).is_err());
    }

    #[test]
    fn compact_gaussian() {
        let o = parse(&["-p", "1", "in", "out"]);
        assert!(o.config.neighborhood.compact_support);
        assert_eq!(o.config.neighborhood.artifact_kind(), "gaussian_compact");
    }

    #[test]
    fn collective_flag() {
        use crate::cluster::comm::CollectiveAlgo;
        let o = parse(&["in", "out"]);
        assert_eq!(o.config.collective, CollectiveAlgo::Auto);
        let o = parse(&["--collective", "ring", "--ranks", "4", "in", "out"]);
        assert_eq!(o.config.collective, CollectiveAlgo::Ring);
        let o = parse(&["--collective", "STAR", "in", "out"]);
        assert_eq!(o.config.collective, CollectiveAlgo::Star);
        let spec = train_spec();
        let parsed = spec
            .parse(["--collective", "mesh", "in", "out"].map(String::from))
            .unwrap();
        assert!(parse_cli(&parsed).is_err());
    }

    #[test]
    fn listen_connect_shorthand() {
        let o = parse(&["--listen", "0.0.0.0:7777", "in", "out"]);
        let mp = o.multiproc.unwrap();
        assert_eq!(mp.rank, 0);
        assert_eq!(mp.peers, vec!["0.0.0.0:7777".to_string()]);
        assert_eq!(o.config.ranks, 2); // shorthand implies two processes

        let o = parse(&["--connect", "somehost:7777", "in", "out"]);
        let mp = o.multiproc.unwrap();
        assert_eq!(mp.rank, 1);
        assert_eq!(o.config.ranks, 2);

        // Plain runs are not multiproc runs.
        assert!(parse(&["--ranks", "4", "in", "out"]).multiproc.is_none());
    }

    #[test]
    fn rank_peers_form() {
        let o = parse(&[
            "--ranks", "3", "--rank", "1",
            "--peers", "h0:9000, h1:9001", "in", "out",
        ]);
        let mp = o.multiproc.unwrap();
        assert_eq!(mp.rank, 1);
        assert_eq!(mp.peers, vec!["h0:9000".to_string(), "h1:9001".to_string()]);
    }

    #[test]
    fn bad_multiproc_combinations_rejected() {
        let try_parse = |args: &[&str]| {
            let spec = train_spec();
            let parsed = spec.parse(args.iter().map(|s| s.to_string())).unwrap();
            parse_cli(&parsed)
        };
        // listen and connect together
        assert!(try_parse(&["--listen", "a:1", "--connect", "b:2", "in", "out"]).is_err());
        // shorthand with an explicit non-2 rank count
        assert!(try_parse(&["--listen", "a:1", "--ranks", "4", "in", "out"]).is_err());
        // shorthand mixed with the explicit form
        assert!(try_parse(&["--listen", "a:1", "--rank", "0", "in", "out"]).is_err());
        // --rank without --peers, and vice versa
        assert!(try_parse(&["--ranks", "2", "--rank", "0", "in", "out"]).is_err());
        assert!(try_parse(&["--ranks", "2", "--peers", "a:1", "in", "out"]).is_err());
        // rank out of range / not enough ranks / wrong peer count
        assert!(try_parse(&["--ranks", "2", "--rank", "2", "--peers", "a:1", "in", "out"]).is_err());
        assert!(try_parse(&["--rank", "0", "--peers", "a:1", "in", "out"]).is_err());
        assert!(
            try_parse(&["--ranks", "4", "--rank", "0", "--peers", "a:1", "in", "out"]).is_err()
        );
        // the network model belongs to the simulated cluster
        assert!(try_parse(&["--listen", "a:1", "--net", "10g", "in", "out"]).is_err());
    }

    #[test]
    fn accel_multirank_rejected() {
        let spec = train_spec();
        let parsed = spec
            .parse(["-k", "1", "--ranks", "4", "in", "out"].map(String::from))
            .unwrap();
        assert!(parse_cli(&parsed).is_err());
    }

    #[test]
    fn bad_enum_value_rejected() {
        let spec = train_spec();
        let parsed = spec
            .parse(["-g", "triangular", "in", "out"].map(String::from))
            .unwrap();
        assert!(parse_cli(&parsed).is_err());
    }
}
